//! Cross-module integration tests: every engine path against the oracle,
//! the AOT kernel end-to-end, monitoring over a live engine run, and the
//! scenario API (registry sets through `ScenarioRunner`, `RunReport`
//! JSON round-trips) at a quick scale.

use std::cell::RefCell;
use std::rc::Rc;

use oct::coordinator::{
    find_set, wide_area_penalty, Framework, RunReport, ScenarioRunner, Testbed, TopologySpec,
    WorkloadSpec,
};
use oct::hadoop::mapreduce::execute_malstone;
use oct::malstone::join::{bucketize, compromise_table};
use oct::malstone::malgen::{MalGen, MalGenConfig, SECONDS_PER_WEEK};
use oct::malstone::oracle::MalstoneResult;
use oct::malstone::Record;
use oct::monitor::Monitor;
use oct::net::{Cluster, Topology};
use oct::runtime::{default_artifact_dir, MalstoneKernels};
use oct::sector::master::{SectorMaster, Segment};
use oct::sector::sphere::{cpu_aggregator, execute_malstone_with};
use oct::sector::SphereEngine;
use oct::sim::Engine;
use oct::util::json::Json;

fn shards(seed: u64, n_shards: u64, per: usize) -> Vec<Vec<Record>> {
    let g = MalGen::new(MalGenConfig::small(seed));
    (0..n_shards).map(|s| g.generate_shard(s, n_shards, per)).collect()
}

fn oracle_of(shards: &[Vec<Record>], s: u32, w: u32) -> MalstoneResult {
    let all: Vec<Record> = shards.iter().flatten().copied().collect();
    let table = compromise_table(&all);
    let joined = bucketize(&all, &table, s, w, SECONDS_PER_WEEK);
    let mut o = MalstoneResult::zero(s as usize, w as usize);
    o.accumulate(&joined);
    o
}

#[test]
fn all_engines_agree_with_oracle_and_each_other() {
    let sh = shards(99, 6, 3_000);
    let oracle = oracle_of(&sh, 256, 64);
    let mr = execute_malstone(&sh, 8, 256, 64, SECONDS_PER_WEEK);
    let sphere = execute_malstone_with(&sh, 5, 256, 64, SECONDS_PER_WEEK, cpu_aggregator);
    assert_eq!(mr, oracle);
    assert_eq!(sphere, oracle);
}

#[test]
fn aot_kernel_path_is_exact_end_to_end() {
    let dir = default_artifact_dir();
    if !dir.join("meta.json").exists() {
        // simlint: allow(SIM004) — skip notice for a missing optional artifact, not sim output
        eprintln!("skipping: artifacts not built");
        return;
    }
    let k = match MalstoneKernels::load(&dir) {
        Ok(k) => k,
        // Artifacts exist: with pjrt enabled a load failure is a real
        // regression; without it the stub can only decline.
        Err(e) if cfg!(feature = "pjrt") => panic!("artifact load failed: {e}"),
        Err(e) => {
            // simlint: allow(SIM004) — skip notice for a missing optional artifact, not sim output
            eprintln!("skipping: {e}");
            return;
        }
    };
    let sh = shards(7, 4, 2_500);
    let oracle = oracle_of(&sh, k.meta.num_sites as u32, k.meta.num_weeks as u32);
    let via_kernel = execute_malstone_with(
        &sh,
        6,
        k.meta.num_sites as u32,
        k.meta.num_weeks as u32,
        SECONDS_PER_WEEK,
        k.aggregator(),
    );
    assert_eq!(via_kernel, oracle);
    // Ratio graphs agree with the oracle's ratios.
    let ra = k.ratio_a(&oracle).unwrap();
    let want = oracle.ratio_a();
    for (g, w) in ra.iter().zip(&want) {
        assert!((*g as f64 - w).abs() < 1e-6);
    }
}

#[test]
fn monitored_sphere_run_produces_samples_and_finishes() {
    let cluster = Cluster::new(Topology::oct_2009());
    let topo = cluster.topo.clone();
    let nodes: Vec<_> = (0..4).flat_map(|r| topo.racks[r].nodes[..3].to_vec()).collect();
    let mut master = SectorMaster::new(topo.clone());
    let segs: Vec<Segment> =
        nodes.iter().map(|&n| Segment { node: n, bytes: 64 << 20, records: 671_088 }).collect();
    master.register_file("f", segs);
    let mut eng = Engine::new();
    let mon = Monitor::new(topo.clone(), 1.0);
    Monitor::install(&mon, &mut eng, &cluster.net, cluster.pools.clone());
    let done = Rc::new(RefCell::new(false));
    let d = done.clone();
    SphereEngine::simulate(
        &cluster,
        &master,
        &mut eng,
        "f",
        &nodes,
        oct::hadoop::FrameworkParams::sphere(),
        false,
        move |_, _| *d.borrow_mut() = true,
    );
    eng.run_until(3600.0);
    mon.borrow_mut().disable();
    eng.run();
    assert!(*done.borrow(), "sphere run did not finish");
    assert!(mon.borrow().samples_taken() > 3);
    // Some node saw NIC traffic during the exchange (mean over the whole
    // retained history — the job finishes early and later samples are
    // idle).
    let busy = topo
        .node_ids()
        .iter()
        .any(|&n| mon.borrow().node_nic_rate(n, usize::MAX) > 0.0);
    assert!(busy, "monitor saw no traffic");
}

#[test]
fn scenario_runner_preserves_table_shapes_at_quick_scale() {
    let runner = ScenarioRunner::new();
    let t1 = find_set("table1").expect("table1 registered").scaled_down(500);
    let r1 = runner.run_all(&t1.scenarios);
    // Sector < Streams < Hadoop-MR on MalStone-A (reports are ordered
    // framework-major, variant-minor).
    assert!(
        r1[4].simulated_secs < r1[2].simulated_secs && r1[2].simulated_secs < r1[0].simulated_secs,
        "A ordering broken: {} {} {}",
        r1[4].simulated_secs,
        r1[2].simulated_secs,
        r1[0].simulated_secs
    );
    let t2 = find_set("table2").expect("table2 registered").scaled_down(500);
    let r2 = runner.run_all(&t2.scenarios);
    assert!(
        wide_area_penalty(&r2[0], &r2[1]) > wide_area_penalty(&r2[4], &r2[5]),
        "hadoop r3 must out-penalize sector"
    );
}

#[test]
fn run_report_json_roundtrips_through_runner() {
    let sc = Testbed::builder()
        .topology(TopologySpec::Oct2009)
        .framework(Framework::SectorSphere)
        .workload(WorkloadSpec::malstone_a(4_000_000))
        .name("roundtrip-smoke")
        .build();
    let rep = ScenarioRunner::new().with_monitor(1.0).run(&sc);
    assert!(rep.simulated_secs > 0.0);
    assert!(rep.monitor.is_some(), "runner monitor hook produced no summary");
    assert_eq!(rep.site_flows.len(), 4);
    assert_eq!(rep.framework, "sector-sphere");
    let text = rep.to_json().to_string();
    let back = RunReport::from_json(&Json::parse(&text).expect("report JSON parses"))
        .expect("report JSON deserializes");
    assert_eq!(back, rep);
}

#[test]
fn ops_plane_survives_crash_and_flap_end_to_end() {
    use oct::ops::{AlertKind, FaultPlan};
    // One run, two faults: a node crash mid-map-phase and a lightpath
    // flap shortly after. The ops plane must detect both, drain + heal
    // the dead worker, re-provision the wave, and the chained MalStone
    // jobs must still complete — with everything in the JSON report.
    let sc = Testbed::builder()
        .topology(TopologySpec::Oct2009)
        .framework(Framework::HadoopMr)
        .workload(WorkloadSpec::malstone_a(50_000_000))
        .faults(FaultPlan::new().node_crash(15.0, 7).lightpath_flap(25.0, 0.05))
        .name("ops-e2e")
        .build();
    let rep = ScenarioRunner::new().run(&sc);
    assert!(rep.simulated_secs > 25.0);
    let ops = rep.ops.as_ref().expect("ops report");
    assert_eq!(ops.crashed_nodes, 1);
    assert_eq!(ops.dead_declared, 1);
    assert_eq!(ops.false_dead, 0);
    assert!(ops.detection_latency_max > 0.0);
    assert!(ops.detection_latency_max <= 8.0 * ops.heartbeat_interval);
    assert!(ops.reexecuted_tasks >= 1);
    let kinds: Vec<AlertKind> = ops.alerts.iter().map(|a| a.kind).collect();
    assert!(kinds.contains(&AlertKind::NodeDead), "{kinds:?}");
    assert!(kinds.contains(&AlertKind::WanDegraded), "{kinds:?}");
    assert!(kinds.contains(&AlertKind::WanRestored), "{kinds:?}");
    // Two remediation intents: the drain and the wave re-provisioning.
    assert!(ops.remediation_ops >= 2);
    // Telemetry overhead is real WAN traffic, and small.
    assert!(ops.telemetry_wan_bytes > 0.0);
    assert!(ops.telemetry_wan_bytes < 0.01 * rep.wan_bytes);
    // The enriched report round-trips.
    let text = rep.to_json().to_string();
    let back = RunReport::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, rep);
}

#[test]
fn service_scenario_reports_latency_quantiles_end_to_end() {
    use oct::service::{RoutePolicy, ServiceSpec};
    // A two-replica service under random routing: users on the
    // replica-less sites must cross the WAN, and the report must carry
    // per-site and global latency quantiles that survive a round-trip.
    let sc = Testbed::builder()
        .topology(TopologySpec::Oct2009)
        .placement(oct::coordinator::Placement::PerSite(8))
        .framework(Framework::Service)
        .workload(WorkloadSpec::malstone_a(4_000))
        .service(ServiceSpec::new(vec![0, 1], RoutePolicy::Random))
        .name("itest/service")
        .build();
    let rep = ScenarioRunner::new().run(&sc);
    let s = rep.service.as_ref().expect("service report");
    assert_eq!(s.requests, 4_000);
    assert_eq!(s.completed, s.requests + s.retries);
    assert_eq!(s.sites.len(), 4);
    assert_eq!(s.sites.iter().map(|site| site.requests).sum::<u64>(), s.requests);
    assert!(s.p50_ms > 0.0 && s.p50_ms <= s.p99_ms && s.p99_ms <= s.p999_ms);
    assert!(rep.wan_bytes > 0.0, "remote requests never touched the wave");
    let back = RunReport::from_json(&Json::parse(&rep.to_json().to_string()).unwrap()).unwrap();
    assert_eq!(back, rep);
}

#[test]
fn cli_rejects_scale_zero_with_a_clear_error() {
    use std::process::Command;
    // `oct scenarios <set> 0` would divide every workload to nothing;
    // the CLI must refuse with exit 2 and an error naming the scale
    // argument instead of running degenerate scenarios.
    for args in [
        &["scenarios", "flow-churn", "0"][..],
        &["table1", "0"][..],
        &["trace", "mega-churn", "0"][..],
        &["alerts", "ops", "0"][..],
    ] {
        let out = Command::new(env!("CARGO_BIN_EXE_oct"))
            .args(args)
            .output()
            .expect("oct binary runs");
        assert_eq!(out.status.code(), Some(2), "oct {args:?} should exit 2");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("scale"), "oct {args:?} stderr lacks 'scale': {err}");
    }
}

#[test]
fn gmp_rpc_full_stack_loopback() {
    use oct::gmp::rpc::Handler;
    use oct::gmp::{GmpConfig, GmpEndpoint, RpcClient, RpcServer};
    use std::collections::HashMap;
    use std::time::Duration;
    let ep = GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap();
    let addr = ep.local_addr();
    let mut handlers: HashMap<String, Handler> = HashMap::new();
    handlers.insert("rev".into(), Box::new(|b: &[u8]| b.iter().rev().copied().collect()));
    let _srv = RpcServer::start(ep, handlers);
    let client = RpcClient::new(GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap());
    let out = client.call(addr, "rev", b"abc", Duration::from_secs(2)).unwrap();
    assert_eq!(out, b"cba");
    // Unknown methods surface as Err, not as an error-shaped payload.
    let err = client.call(addr, "missing", b"", Duration::from_secs(2)).unwrap_err();
    assert!(err.to_string().contains("unknown method"), "{err}");
}

#[test]
fn provisioned_tenants_run_end_to_end_on_one_testbed() {
    use oct::coordinator::Placement;
    // Two dedicated-wave tenants plus a grantless one, each paying a
    // real imaging phase, concurrently on one shared testbed. Small
    // image + workload keep the test quick while exercising the whole
    // admission → provision → run → release pipeline.
    let tenant = |name: &str, gbps: Option<f64>| {
        let mut b = Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(4))
            .framework(Framework::SectorSphere)
            .workload(WorkloadSpec::malstone_a(4_000_000))
            .image("itest-image", 0.5)
            .tenant(name, 0)
            .name(&format!("itest/{name}"));
        if let Some(g) = gbps {
            b = b.lightpath(g);
        }
        b.build()
    };
    let group = vec![tenant("alice", Some(10.0)), tenant("bob", Some(10.0)), tenant("carol", None)];
    let reports = ScenarioRunner::new().run_tenants(&group);
    assert_eq!(reports.len(), 3);
    let m = |r: &RunReport, k: &str| {
        r.metric(k).unwrap_or_else(|| panic!("{} missing metric {k}", r.scenario))
    };
    for r in &reports {
        // Every tenant paid imaging before any workload byte moved, and
        // the workload itself completed.
        assert!(m(r, "imaging_secs") > 0.0, "{}", r.scenario);
        assert!(m(r, "provision_secs") >= m(r, "imaging_secs") - 1e-9);
        assert!(m(r, "workload_secs") > 0.0);
        assert_eq!(m(r, "queued_secs"), 0.0, "inventory fits all three");
        assert_eq!(r.nodes, 16);
        // Reports (with tenancy metrics) survive the JSON round-trip.
        let back = RunReport::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(&back, r);
    }
    // The granted tenants paid lightpath signalling; carol did not.
    assert!(m(&reports[0], "lightpath_setup_secs") > 0.0);
    assert!(m(&reports[1], "lightpath_setup_secs") > 0.0);
    assert_eq!(m(&reports[2], "lightpath_setup_secs"), 0.0);
    // All three overlapped with each other (true concurrency).
    for (a, b) in [(0, 1), (0, 2), (1, 2)] {
        assert!(
            m(&reports[a], "started_secs") < reports[b].simulated_secs
                && m(&reports[b], "started_secs") < reports[a].simulated_secs,
            "tenants {a}/{b} did not overlap"
        );
    }
}

#[test]
fn slice_scheduler_queues_and_admits_against_releases() {
    use oct::coordinator::{Provisioner, SliceScheduler};
    use oct::net::Topology;
    use std::rc::Rc;
    // Inventory arithmetic end to end: 32-node sites, three 14-per-site
    // requests — the third must wait for a release, and the admission
    // log must replay onto a provisioner.
    let mut sched = SliceScheduler::new(Rc::new(Topology::oct_2009()), 0.0);
    let a = sched.try_carve("a", 14, None, None).expect("a fits");
    let b = sched.try_carve("b", 14, None, None).expect("b fits");
    assert!(sched.try_carve("c", 14, None, None).is_none(), "4 free per site < 14");
    sched.release(&a);
    let c = sched.try_carve("c", 14, None, None).expect("c admitted after release");
    assert!(c.nodes.iter().all(|n| !b.nodes.contains(n)), "slices overlap");
    let mut prov = Provisioner::oct_2009();
    for op in sched.log().to_vec() {
        prov.apply(&op);
    }
    let tenants: Vec<&str> = prov.slices().iter().map(|s| s.tenant.as_str()).collect();
    assert_eq!(tenants, vec!["b", "c"]);
}

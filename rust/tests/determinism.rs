//! The double-run determinism harness.
//!
//! Every registry set is executed twice at a reduced scale and the two
//! report vectors must serialize to byte-identical JSON. This catches the
//! failure mode simlint's static rules cannot: each `HashMap` instance
//! draws its own `RandomState`, so any hash-ordered iteration that leaks
//! into scheduling, f64 summation, or report assembly diverges *between
//! two runs inside one process* — no cross-process or cross-platform
//! comparison needed.
//!
//! One test per set keeps failures attributable; together they cover
//! every framework, the ops plane, provisioning, and the tenant
//! scheduler. CI's debug-profile job runs these with the FlowNet audit
//! and engine asserts live.

use oct::coordinator::{find_set, RunReport, ScenarioRunner};

/// Run the named set once at `1/div` scale and serialize all its reports.
fn run_serialized(name: &str, div: u64) -> String {
    let set = find_set(name).unwrap_or_else(|| panic!("unknown set {name}")).scaled_down(div);
    let reports: Vec<RunReport> = ScenarioRunner::new().run_set(&set);
    assert!(!reports.is_empty(), "{name}: no reports");
    reports.iter().map(|r| r.to_json().to_string()).collect::<Vec<_>>().join("\n")
}

/// The core assertion: two identically-configured runs must match byte
/// for byte.
fn assert_replays(name: &str, div: u64) {
    let a = run_serialized(name, div);
    let b = run_serialized(name, div);
    if a != b {
        // Point at the first diverging line to keep the failure readable.
        for (i, (la, lb)) in a.lines().zip(b.lines()).enumerate() {
            assert_eq!(la, lb, "{name}: report {i} diverges between runs");
        }
        panic!("{name}: runs differ in report count");
    }
}

// Divisors match the registry's own shape tests: small enough for CI,
// large enough that every phase (map, shuffle, reduce, replication,
// telemetry, provisioning) still executes.

#[test]
fn table1_replays_identically() {
    assert_replays("table1", 200);
}

#[test]
fn table2_replays_identically() {
    assert_replays("table2", 200);
}

#[test]
fn interop_replays_identically() {
    assert_replays("interop", 200);
}

#[test]
fn scale_ladder_replays_identically() {
    assert_replays("scale-ladder", 200);
}

#[test]
fn local_vs_wan_replays_identically() {
    assert_replays("local-vs-wan", 500);
}

#[test]
fn site_dropout_replays_identically() {
    assert_replays("site-dropout", 500);
}

#[test]
fn flow_churn_replays_identically() {
    assert_replays("flow-churn", 100);
}

#[test]
fn mega_churn_replays_identically() {
    // Aggressive divisor: the debug-build FlowNet audit cross-checks a
    // full recompute against the incremental state on every event, so
    // the structured storm runs at 800 transfers / 200 slots here.
    assert_replays("mega-churn", 500);
}

#[test]
fn ops_replays_identically() {
    assert_replays("ops", 100);
}

#[test]
fn tenancy_replays_identically() {
    assert_replays("tenancy", 100);
}

//! The double-run determinism harness.
//!
//! Every registry set is executed twice at a reduced scale and the two
//! report vectors must serialize to byte-identical JSON. This catches the
//! failure mode simlint's static rules cannot: each `HashMap` instance
//! draws its own `RandomState`, so any hash-ordered iteration that leaks
//! into scheduling, f64 summation, or report assembly diverges *between
//! two runs inside one process* — no cross-process or cross-platform
//! comparison needed.
//!
//! One test per set keeps failures attributable; together they cover
//! every framework, the ops plane, provisioning, and the tenant
//! scheduler. CI's debug-profile job runs these with the FlowNet audit
//! and engine asserts live.
//!
//! The cross-thread-count tests at the bottom extend the contract to the
//! sharded parallel engine: `--threads N` must reproduce the `--threads
//! 1` bytes exactly. CI additionally runs the whole harness under
//! `OCT_THREADS=1` and `OCT_THREADS=4` and diffs the two JSON streams.

use oct::coordinator::{find_set, RunReport, ScenarioRunner};
use oct::trace::TraceSpec;

/// Run the named set once at `1/div` scale and serialize all its reports.
/// The runner resolves its worker count from `OCT_THREADS` (default 1),
/// so CI exercises this whole harness at several thread counts.
fn run_serialized(name: &str, div: u64) -> String {
    let set = find_set(name).unwrap_or_else(|| panic!("unknown set {name}")).scaled_down(div);
    let reports: Vec<RunReport> = ScenarioRunner::new().run_set(&set);
    assert!(!reports.is_empty(), "{name}: no reports");
    reports.iter().map(|r| r.to_json().to_string()).collect::<Vec<_>>().join("\n")
}

/// [`run_serialized`] at an explicit worker count, overriding the env.
fn run_serialized_threads(name: &str, div: u64, threads: usize) -> String {
    let set = find_set(name).unwrap_or_else(|| panic!("unknown set {name}")).scaled_down(div);
    let reports: Vec<RunReport> = ScenarioRunner::new().with_threads(threads).run_set(&set);
    assert!(!reports.is_empty(), "{name}: no reports");
    reports.iter().map(|r| r.to_json().to_string()).collect::<Vec<_>>().join("\n")
}

/// Compare two serialized report stacks line by line so a failure points
/// at the first diverging report instead of dumping both documents.
fn assert_same(name: &str, what: &str, a: &str, b: &str) {
    if a != b {
        for (i, (la, lb)) in a.lines().zip(b.lines()).enumerate() {
            assert_eq!(la, lb, "{name}: report {i} diverges ({what})");
        }
        panic!("{name}: runs differ in report count ({what})");
    }
}

/// The core assertion: two identically-configured runs must match byte
/// for byte.
fn assert_replays(name: &str, div: u64) {
    let a = run_serialized(name, div);
    let b = run_serialized(name, div);
    assert_same(name, "between runs", &a, &b);
}

// Divisors match the registry's own shape tests: small enough for CI,
// large enough that every phase (map, shuffle, reduce, replication,
// telemetry, provisioning) still executes.

#[test]
fn table1_replays_identically() {
    assert_replays("table1", 200);
}

#[test]
fn table2_replays_identically() {
    assert_replays("table2", 200);
}

#[test]
fn interop_replays_identically() {
    assert_replays("interop", 200);
}

#[test]
fn scale_ladder_replays_identically() {
    assert_replays("scale-ladder", 200);
}

#[test]
fn local_vs_wan_replays_identically() {
    assert_replays("local-vs-wan", 500);
}

#[test]
fn site_dropout_replays_identically() {
    assert_replays("site-dropout", 500);
}

#[test]
fn flow_churn_replays_identically() {
    assert_replays("flow-churn", 100);
}

#[test]
fn mega_churn_replays_identically() {
    // Aggressive divisor: the debug-build FlowNet audit cross-checks a
    // full recompute against the incremental state on every event, so
    // the structured storm runs at 800 transfers / 200 slots here.
    assert_replays("mega-churn", 500);
}

#[test]
fn ops_replays_identically() {
    assert_replays("ops", 100);
}

#[test]
fn tenancy_replays_identically() {
    assert_replays("tenancy", 100);
}

#[test]
fn service_replays_identically() {
    // 4k requests per scenario across all seven service scenarios —
    // small enough for the debug-build FlowNet audit, large enough that
    // every arrival phase, the WAN path, and the retry path execute.
    assert_replays("service", 500);
}

// ---- cross-thread-count determinism -----------------------------------
//
// The parallel engine's contract is stronger than replayability: the
// *same bytes* at any worker count. Shardable scenarios (mega-churn)
// take the sharded driver at every thread setting including 1, so these
// comparisons pit identical drivers against different interleavings;
// non-shardable sets must ignore the thread setting entirely.

#[test]
fn mega_churn_is_thread_count_invariant() {
    let base = run_serialized_threads("mega-churn", 500, 1);
    for threads in [2, 4, 8] {
        let t = run_serialized_threads("mega-churn", 500, threads);
        assert_same("mega-churn", &format!("1 vs {threads} threads"), &base, &t);
    }
}

#[test]
fn mega_churn_trace_stream_is_thread_count_invariant() {
    // The merged trace stream is a strictly stronger probe than report
    // equality: it exposes the full per-event execution record (every
    // flow start/retune/complete and every cross-shard sync message),
    // not just the aggregates. The exported Chrome-trace bytes must be
    // identical at any worker count.
    let traced = |threads: usize| -> (String, String) {
        let set = find_set("mega-churn").expect("mega-churn registered").scaled_down(500);
        let runner = ScenarioRunner::new().with_threads(threads).with_trace(TraceSpec::new());
        let (reports, stream) = runner.run_set_with_trace(&set);
        assert!(!stream.is_empty(), "traced mega-churn recorded nothing");
        let reports =
            reports.iter().map(|r| r.to_json().to_string()).collect::<Vec<_>>().join("\n");
        (reports, stream.to_chrome_json())
    };
    let (base_reports, base_trace) = traced(1);
    // Tracing must not perturb the reports either.
    let untraced = run_serialized_threads("mega-churn", 500, 1);
    assert_same("mega-churn", "traced vs untraced reports", &base_reports, &untraced);
    for threads in [2, 4] {
        let (reports, trace) = traced(threads);
        let what = format!("traced reports 1 vs {threads} threads");
        assert_same("mega-churn", &what, &base_reports, &reports);
        assert!(
            trace == base_trace,
            "mega-churn: trace stream diverges at {threads} threads \
             (lens {} vs {})",
            base_trace.len(),
            trace.len()
        );
    }
}

#[test]
fn loadgen_arrivals_replay_exactly_with_exact_phase_boundaries() {
    // The service load generator is pure: the worker count (OCT_THREADS,
    // which CI varies across this whole harness) must never leak into
    // arrival plans. Same seed → identical timestamps, every timestamp
    // inside its phase's half-open window, and per-phase request counts
    // exactly equal to the spec's largest-remainder budgets.
    use oct::net::Topology;
    use oct::service::{flash_crowd_phases, LoadGen, RoutePolicy, ServiceSpec};
    let rtt = LoadGen::site_rtt_matrix(&Topology::oct_2009());
    let mut spec = ServiceSpec::new(vec![0, 1, 2, 3], RoutePolicy::Nearest);
    spec.phases = flash_crowd_phases();
    let make = || LoadGen::new(spec.clone(), 8_000, rtt.clone());
    let (a, b) = (make(), make());
    let bounds = a.phase_bounds();
    for site in 0..4u32 {
        let plan = a.gen_site(site);
        assert_eq!(plan, b.gen_site(site), "site {site} plans diverge between generators");
        assert_eq!(plan.len() as u64, a.site_budget(site));
        assert!(plan.windows(2).all(|w| w[0].t <= w[1].t), "site {site} arrivals out of order");
        let budgets = a.phase_budgets(a.site_budget(site));
        for (phase, (&(t0, t1), &budget)) in bounds.iter().zip(&budgets).enumerate() {
            let n = plan.iter().filter(|r| r.t >= t0 && r.t < t1).count() as u64;
            assert_eq!(n, budget, "site {site} phase {phase} count off its exact budget");
        }
    }
}

#[test]
fn service_is_thread_count_invariant() {
    // Requests are homed at their user's site shard; cross-site requests
    // ride the WAN shard. The per-request latency samples, quantiles,
    // and SLO counters must still land on identical bytes at any worker
    // count.
    let base = run_serialized_threads("service", 500, 1);
    for threads in [2, 4] {
        let t = run_serialized_threads("service", 500, threads);
        assert_same("service", &format!("1 vs {threads} threads"), &base, &t);
    }
}

#[test]
fn service_trace_stream_is_thread_count_invariant() {
    // Same probe as the mega-churn trace test: the merged span stream
    // exposes every `service.request` span (start site, replica, retry
    // flag) in merged order, so the exported Chrome-trace bytes must be
    // identical at any worker count — and tracing must not perturb the
    // reports.
    let traced = |threads: usize| -> (String, String) {
        let set = find_set("service").expect("service registered").scaled_down(500);
        let runner = ScenarioRunner::new().with_threads(threads).with_trace(TraceSpec::new());
        let (reports, stream) = runner.run_set_with_trace(&set);
        assert!(!stream.is_empty(), "traced service set recorded nothing");
        let reports =
            reports.iter().map(|r| r.to_json().to_string()).collect::<Vec<_>>().join("\n");
        (reports, stream.to_chrome_json())
    };
    let (base_reports, base_trace) = traced(1);
    let untraced = run_serialized_threads("service", 500, 1);
    assert_same("service", "traced vs untraced reports", &base_reports, &untraced);
    for threads in [2, 4] {
        let (reports, trace) = traced(threads);
        let what = format!("traced reports 1 vs {threads} threads");
        assert_same("service", &what, &base_reports, &reports);
        assert!(
            trace == base_trace,
            "service: trace stream diverges at {threads} threads (lens {} vs {})",
            base_trace.len(),
            trace.len()
        );
    }
}

#[test]
fn registry_sets_are_thread_count_invariant_at_4() {
    for (name, div) in [("table1", 200), ("flow-churn", 100), ("ops", 100), ("tenancy", 100)] {
        let a = run_serialized_threads(name, div, 1);
        let b = run_serialized_threads(name, div, 4);
        assert_same(name, "1 vs 4 threads", &a, &b);
    }
}

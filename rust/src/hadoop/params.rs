//! Framework cost parameters (calibration constants; DESIGN.md §4).
//!
//! Everything the simulator charges a framework for is listed here and
//! overridable, so the Table 1/2 benches can print their parameterization
//! and ablations can vary one knob at a time. Defaults are calibrated so
//! the simulated Table 1/2 land in the paper's measured band; the *shape*
//! (ordering, ratios) is robust to reasonable perturbations — that is
//! asserted by the benches, not the absolute seconds.

use crate::transport::Protocol;

/// Per-framework cost model for a MalStone-style run.
#[derive(Debug, Clone)]
pub struct FrameworkParams {
    pub name: &'static str,
    /// CPU seconds charged per input record in the map/UDF stage.
    pub map_cpu_per_record: f64,
    /// CPU seconds per intermediate record in the reduce/aggregate stage.
    pub reduce_cpu_per_record: f64,
    /// Fixed per-task overhead (JVM start, task setup), seconds.
    pub task_overhead: f64,
    /// Intermediate record bytes (entity/site/week/mark tuple on the wire).
    pub intermediate_record_bytes: f64,
    /// Fraction of input records that survive into the shuffle.
    pub shuffle_selectivity: f64,
    /// Extra disk passes over intermediate data (spill + merge factor).
    pub merge_passes: f64,
    /// Bytes per input record written to HDFS/SDFS as job output (the
    /// naive Java MalStone writes per-visit marked tuples; the streaming
    /// and Sphere implementations aggregate in the reducer/bucket and
    /// emit only histogram-sized output).
    pub output_bytes_per_record: f64,
    /// Transport used for bulk data movement.
    pub protocol: Protocol,
    /// Replication factor for job output files.
    pub output_replication: usize,
    /// Concurrent shuffle fetches per reducer (Hadoop's
    /// `mapred.reduce.parallel.copies`, default 5).
    pub parallel_copies: usize,
    /// MalStone-B emits one intermediate tuple per (visit, window) rather
    /// than per visit; this multiplies intermediate volume and reduce CPU.
    pub variant_b_emit_factor: f64,
}

impl FrameworkParams {
    /// Hadoop 0.18.3 MapReduce with the MalStone job coded in Java.
    /// Dominated by per-record ser/de + object churn in the 2009 runtime.
    pub fn hadoop_mapreduce() -> Self {
        FrameworkParams {
            name: "hadoop-mapreduce",
            map_cpu_per_record: 13.0e-6,
            reduce_cpu_per_record: 9.0e-6,
            task_overhead: 6.0,
            intermediate_record_bytes: 110.0, // Writable-serialized tuple
            shuffle_selectivity: 1.0,         // every visit is joined
            merge_passes: 1.25,               // spill + multi-pass merge
            output_bytes_per_record: 20.0,    // per-visit marked tuples
            protocol: Protocol::tcp(),
            output_replication: 3,
            parallel_copies: 5,
            variant_b_emit_factor: 1.85,
        }
    }

    /// Hadoop Streaming with MalStone in Python: line-oriented text
    /// processing through pipes is *cheaper per record* than the Java
    /// implementation's Writable churn (the paper's Table 1 shows Streams
    /// ~5× faster than the Java job), but it still pays HDFS + TCP.
    pub fn hadoop_streams() -> Self {
        FrameworkParams {
            name: "hadoop-streams",
            map_cpu_per_record: 1.4e-6,
            reduce_cpu_per_record: 1.2e-6,
            task_overhead: 4.0,
            intermediate_record_bytes: 36.0, // tab-separated text line
            shuffle_selectivity: 1.0,
            merge_passes: 0.25,
            output_bytes_per_record: 0.02,   // in-reducer aggregation
            protocol: Protocol::tcp(),
            output_replication: 3,
            parallel_copies: 5,
            variant_b_emit_factor: 1.7,
        }
    }

    /// Hadoop MapReduce with dfs.replication = 1 (Table 2 middle row).
    pub fn hadoop_mapreduce_r1() -> Self {
        FrameworkParams {
            name: "hadoop-mapreduce-r1",
            output_replication: 1,
            ..Self::hadoop_mapreduce()
        }
    }

    /// Sector/Sphere: native C++ UDFs, UDT transport, single replica,
    /// stream-overlapped stages. (Consumed by `sector::sphere`, kept here
    /// so every engine's constants sit side by side.)
    pub fn sphere() -> Self {
        FrameworkParams {
            name: "sector-sphere",
            map_cpu_per_record: 1.5e-6,
            reduce_cpu_per_record: 1.2e-6,
            task_overhead: 0.5,
            intermediate_record_bytes: 24.0, // packed binary tuple
            shuffle_selectivity: 1.0,
            merge_passes: 0.0, // in-memory bucket aggregation
            output_bytes_per_record: 0.02, // bucket-local histograms
            protocol: Protocol::udt(),
            output_replication: 1,
            parallel_copies: 8,
            variant_b_emit_factor: 1.3,
        }
    }

    /// §7 interop: Hadoop MapReduce running over CloudStore/KFS chunk
    /// storage instead of HDFS. The compute-side costs are the Java
    /// job's; the storage swap (chunk leases, rack-oblivious placement)
    /// lives in [`crate::framework::KfsStorage`], not here.
    pub fn cloudstore_mr() -> Self {
        FrameworkParams { name: "cloudstore-mr", ..Self::hadoop_mapreduce() }
    }

    /// §7 interop: MapReduce scheduling and shuffle semantics over Sector
    /// placement — the shuffle and remote reads ride UDT and job output
    /// is a single writer-local copy (Sector replicates lazily), while
    /// per-record CPU stays the Java job's.
    pub fn hadoop_over_sector() -> Self {
        FrameworkParams {
            name: "hadoop-over-sector",
            protocol: Protocol::udt(),
            output_replication: 1,
            ..Self::hadoop_mapreduce()
        }
    }

    /// Intermediate bytes per input record for a MalStone variant.
    pub fn intermediate_bytes_per_record(&self, variant_b: bool) -> f64 {
        let f = if variant_b { self.variant_b_emit_factor } else { 1.0 };
        self.shuffle_selectivity * self.intermediate_record_bytes * f
    }

    /// CPU seconds per input record in reduce for a variant.
    pub fn reduce_cpu(&self, variant_b: bool) -> f64 {
        let f = if variant_b { self.variant_b_emit_factor } else { 1.0 };
        self.reduce_cpu_per_record * self.shuffle_selectivity * f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_record_cost_ordering_matches_table1() {
        let mr = FrameworkParams::hadoop_mapreduce();
        let st = FrameworkParams::hadoop_streams();
        let sp = FrameworkParams::sphere();
        // The Java job is by far the most expensive per record; the
        // python-streaming and native-Sphere costs are comparable (Sphere
        // wins on transport/replication/overlap, not raw per-record CPU).
        assert!(mr.map_cpu_per_record > 5.0 * st.map_cpu_per_record);
        assert!(mr.map_cpu_per_record > 5.0 * sp.map_cpu_per_record);
    }

    #[test]
    fn variant_b_increases_volume() {
        let p = FrameworkParams::hadoop_mapreduce();
        assert!(p.intermediate_bytes_per_record(true) > p.intermediate_bytes_per_record(false));
        assert!(p.reduce_cpu(true) > p.reduce_cpu(false));
    }

    #[test]
    fn replication_variants() {
        assert_eq!(FrameworkParams::hadoop_mapreduce().output_replication, 3);
        assert_eq!(FrameworkParams::hadoop_mapreduce_r1().output_replication, 1);
        assert_eq!(FrameworkParams::sphere().output_replication, 1);
    }

    #[test]
    fn protocols_match_paper() {
        assert_eq!(FrameworkParams::hadoop_mapreduce().protocol.name(), "tcp");
        assert_eq!(FrameworkParams::sphere().protocol.name(), "udt");
    }

    #[test]
    fn interop_params_swap_only_the_intended_layer() {
        let mr = FrameworkParams::hadoop_mapreduce();
        let kfs = FrameworkParams::cloudstore_mr();
        // Storage swap: identical compute + transport costs.
        assert_eq!(kfs.map_cpu_per_record, mr.map_cpu_per_record);
        assert_eq!(kfs.protocol.name(), "tcp");
        assert_eq!(kfs.output_replication, 3);
        let hos = FrameworkParams::hadoop_over_sector();
        // Transport + replication swap: identical compute costs.
        assert_eq!(hos.map_cpu_per_record, mr.map_cpu_per_record);
        assert_eq!(hos.protocol.name(), "udt");
        assert_eq!(hos.output_replication, 1);
    }
}

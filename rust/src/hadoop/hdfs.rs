//! HDFS: namenode metadata, rack-aware replica placement, pipelined
//! replicated writes, locality-aware reads (Hadoop 0.18 semantics).
//!
//! Placement policy (0.18): first replica on the writer, second on a
//! random node in a *different rack*, third in the same rack as the
//! second. In the OCT every rack is its own site, so replicas 2 and 3 of
//! every block cross the WAN over TCP during the write pipeline — the
//! dominant term in Table 2's 3-replica wide-area penalty.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::net::{FlowNet, NodeId, Topology};
use crate::sim::Engine;
use crate::transport::{self, Protocol};
use crate::util::Rng;

/// Identifies an HDFS block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u64);

#[derive(Debug, Clone)]
pub struct HdfsConfig {
    /// Block size in bytes (0.18 default: 64 MB).
    pub block_size: u64,
    pub replication: usize,
}

impl Default for HdfsConfig {
    fn default() -> Self {
        HdfsConfig { block_size: 64 * 1024 * 1024, replication: 3 }
    }
}

#[derive(Debug, Clone)]
pub struct BlockMeta {
    pub id: BlockId,
    pub bytes: u64,
    pub replicas: Vec<NodeId>,
}

#[derive(Debug, Clone, Default)]
pub struct FileMeta {
    pub blocks: Vec<BlockId>,
}

/// Namenode: metadata + placement. Data-plane timing flows through the
/// fluid network via [`write_block`] / read helpers.
pub struct Namenode {
    pub cfg: HdfsConfig,
    topo: Rc<Topology>,
    files: BTreeMap<String, FileMeta>,
    blocks: BTreeMap<BlockId, BlockMeta>,
    next_block: u64,
    rng: Rng,
    /// Bytes stored per node (balancer pressure + test invariants).
    usage: BTreeMap<NodeId, u64>,
    /// Datanode membership: placement only considers these nodes (an HDFS
    /// deployment spans the *cluster it is installed on*, not the whole
    /// testbed — Table 2's "local" setup is a single-site HDFS).
    members: Vec<NodeId>,
}

impl Namenode {
    pub fn new(topo: Rc<Topology>, cfg: HdfsConfig, seed: u64) -> Self {
        let members = topo.node_ids();
        Namenode {
            cfg,
            topo,
            files: BTreeMap::new(),
            blocks: BTreeMap::new(),
            next_block: 0,
            rng: Rng::new(seed),
            usage: BTreeMap::new(),
            members,
        }
    }

    /// An HDFS whose datanodes are exactly `members`.
    pub fn with_members(
        topo: Rc<Topology>,
        cfg: HdfsConfig,
        seed: u64,
        members: Vec<NodeId>,
    ) -> Self {
        assert!(!members.is_empty());
        let mut nn = Self::new(topo, cfg, seed);
        nn.members = members;
        nn
    }

    /// Choose replica targets for a block written from `writer`
    /// (0.18 policy; degrades gracefully on single-rack topologies).
    pub fn place_replicas(&mut self, writer: NodeId) -> Vec<NodeId> {
        let mut out = vec![writer];
        if self.cfg.replication == 1 {
            return out;
        }
        let all = self.members.clone();
        // Second replica: random node on a different rack.
        let remote: Vec<NodeId> =
            all.iter().copied().filter(|&n| !self.topo.same_rack(n, writer)).collect();
        if let Some(&r2) = pick(&mut self.rng, &remote) {
            out.push(r2);
            if self.cfg.replication >= 3 {
                // Third: same rack as the second, different node.
                let peers: Vec<NodeId> = all
                    .iter()
                    .copied()
                    .filter(|&n| self.topo.same_rack(n, r2) && n != r2 && n != writer)
                    .collect();
                if let Some(&r3) = pick(&mut self.rng, &peers) {
                    out.push(r3);
                }
            }
        }
        // Fill any shortfall (single-rack clusters) with *random* distinct
        // members — deterministic fill would hotspot the first datanodes
        // with every block's fallback replicas.
        let mut candidates: Vec<NodeId> =
            all.iter().copied().filter(|n| !out.contains(n)).collect();
        while out.len() < self.cfg.replication && !candidates.is_empty() {
            let i = self.rng.gen_range(candidates.len() as u64) as usize;
            out.push(candidates.swap_remove(i));
        }
        out
    }

    /// Register a file of `bytes` written from `writer`; returns its
    /// blocks (metadata only — pair with [`write_block`] for timing).
    pub fn create_file(&mut self, name: &str, bytes: u64, writer: NodeId) -> Vec<BlockMeta> {
        assert!(!self.files.contains_key(name), "file exists: {name}");
        let nblocks = bytes.div_ceil(self.cfg.block_size).max(1);
        let mut metas = Vec::new();
        let mut ids = Vec::new();
        for i in 0..nblocks {
            let id = BlockId(self.next_block);
            self.next_block += 1;
            let sz = if i == nblocks - 1 {
                bytes - (nblocks - 1) * self.cfg.block_size
            } else {
                self.cfg.block_size
            };
            let replicas = self.place_replicas(writer);
            for &r in &replicas {
                *self.usage.entry(r).or_insert(0) += sz;
            }
            let meta = BlockMeta { id, bytes: sz, replicas };
            self.blocks.insert(id, meta.clone());
            metas.push(meta);
            ids.push(id);
        }
        self.files.insert(name.to_string(), FileMeta { blocks: ids });
        metas
    }

    /// Register a pre-distributed file: one block per (node, bytes) pair,
    /// single local replica (how MalGen-generated shards enter HDFS-land
    /// before a job; also used to model Sector-imported data).
    pub fn register_local_shards(
        &mut self,
        name: &str,
        shards: &[(NodeId, u64)],
    ) -> Vec<BlockMeta> {
        assert!(!self.files.contains_key(name), "file exists: {name}");
        let mut metas = Vec::new();
        let mut ids = Vec::new();
        for &(node, bytes) in shards {
            let mut remaining = bytes;
            while remaining > 0 {
                let sz = remaining.min(self.cfg.block_size);
                remaining -= sz;
                let id = BlockId(self.next_block);
                self.next_block += 1;
                *self.usage.entry(node).or_insert(0) += sz;
                let meta = BlockMeta { id, bytes: sz, replicas: vec![node] };
                self.blocks.insert(id, meta.clone());
                metas.push(meta);
                ids.push(id);
            }
        }
        self.files.insert(name.to_string(), FileMeta { blocks: ids });
        metas
    }

    pub fn file_blocks(&self, name: &str) -> Option<Vec<BlockMeta>> {
        self.files
            .get(name)
            .map(|f| f.blocks.iter().map(|b| self.blocks[b].clone()).collect())
    }

    pub fn block(&self, id: BlockId) -> &BlockMeta {
        &self.blocks[&id]
    }

    /// Closest replica to `reader` (node > rack > site > remote).
    pub fn choose_read_replica(&self, id: BlockId, reader: NodeId) -> NodeId {
        let b = &self.blocks[&id];
        *b.replicas
            .iter()
            .min_by_key(|&&r| self.topo.distance(reader, r))
            .expect("block with no replicas")
    }

    pub fn node_usage(&self, n: NodeId) -> u64 {
        self.usage.get(&n).copied().unwrap_or(0)
    }
}

fn pick<'a>(rng: &mut Rng, xs: &'a [NodeId]) -> Option<&'a NodeId> {
    if xs.is_empty() {
        None
    } else {
        Some(&xs[rng.gen_range(xs.len() as u64) as usize])
    }
}

/// Timed pipelined write of one block from `writer` to `replicas`:
/// a local disk write plus chained network hops (writer→r2→r3 over
/// `proto`), all concurrent (the pipeline streams packets), done when the
/// slowest leg lands. Thin wrapper over the shared replication pipeline
/// every storage model uses ([`crate::framework::pipeline_write`]).
#[allow(clippy::too_many_arguments)]
pub fn write_block<F: FnOnce(&mut Engine) + 'static>(
    net: &Rc<RefCell<FlowNet>>,
    topo: &Rc<Topology>,
    eng: &mut Engine,
    replicas: &[NodeId],
    bytes: u64,
    proto: &Protocol,
    done: F,
) {
    crate::framework::pipeline_write(net, topo, eng, replicas, bytes as f64, proto, done)
}

/// Timed read of one block at `reader`: local disk read if a replica is
/// local, otherwise remote disk read + network transfer.
pub fn read_block<F: FnOnce(&mut Engine) + 'static>(
    net: &Rc<RefCell<FlowNet>>,
    topo: &Rc<Topology>,
    eng: &mut Engine,
    source: NodeId,
    reader: NodeId,
    bytes: u64,
    proto: &Protocol,
    done: F,
) {
    if source == reader {
        transport::disk_read(net, topo, eng, reader, bytes as f64, done);
    } else {
        // Remote: disk read at source, then stream over the network.
        let net2 = net.clone();
        let topo2 = topo.clone();
        let proto = proto.clone();
        transport::disk_read(net, topo, eng, source, bytes as f64, move |eng| {
            transport::send(&net2, &topo2, eng, source, reader, bytes as f64, &proto, done);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    fn topo() -> Rc<Topology> {
        Rc::new(Topology::oct_2009())
    }

    fn nn(topo: &Rc<Topology>, repl: usize) -> Namenode {
        Namenode::new(topo.clone(), HdfsConfig { replication: repl, ..Default::default() }, 1)
    }

    #[test]
    fn placement_policy_invariants() {
        let topo = topo();
        let mut nn = nn(&topo, 3);
        crate::proptest::check("hdfs placement invariants", 50, |rng| {
            let writer = NodeId(rng.gen_range(128) as usize);
            let reps = nn.place_replicas(writer);
            if reps.len() != 3 {
                return Err(format!("wanted 3 replicas, got {}", reps.len()));
            }
            if reps[0] != writer {
                return Err("first replica not writer-local".into());
            }
            let mut uniq = reps.clone();
            uniq.sort();
            uniq.dedup();
            if uniq.len() != reps.len() {
                return Err("duplicate replica nodes".into());
            }
            if topo.same_rack(reps[0], reps[1]) {
                return Err("second replica in writer's rack".into());
            }
            if !topo.same_rack(reps[1], reps[2]) {
                return Err("third replica not in second's rack".into());
            }
            Ok(())
        });
    }

    #[test]
    fn single_replication_is_local_only() {
        let topo = topo();
        let mut nn = nn(&topo, 1);
        let reps = nn.place_replicas(NodeId(5));
        assert_eq!(reps, vec![NodeId(5)]);
    }

    #[test]
    fn file_blocks_and_sizes() {
        let topo = topo();
        let mut nn = nn(&topo, 3);
        let blocks = nn.create_file("f", 150 * 1024 * 1024, NodeId(0));
        assert_eq!(blocks.len(), 3); // 64 + 64 + 22 MB
        assert_eq!(blocks[0].bytes, 64 * 1024 * 1024);
        assert_eq!(blocks[2].bytes, 22 * 1024 * 1024);
        let listed = nn.file_blocks("f").unwrap();
        assert_eq!(listed.len(), 3);
        assert!(nn.node_usage(NodeId(0)) >= 150 * 1024 * 1024);
    }

    #[test]
    fn read_prefers_closest_replica() {
        let topo = topo();
        let mut nn = nn(&topo, 3);
        let blocks = nn.create_file("f", 1024, NodeId(0));
        let b = blocks[0].id;
        // The writer reads locally.
        assert_eq!(nn.choose_read_replica(b, NodeId(0)), NodeId(0));
        // A rack-mate of the writer prefers the writer's copy.
        let r = nn.choose_read_replica(b, NodeId(1));
        assert!(topo.same_rack(r, NodeId(1)));
    }

    #[test]
    fn local_shards_register_one_replica() {
        let topo = topo();
        let mut nn = nn(&topo, 3);
        let shards: Vec<(NodeId, u64)> = (0..4).map(|i| (NodeId(i), 100 * 1024 * 1024)).collect();
        let blocks = nn.register_local_shards("data", &shards);
        assert_eq!(blocks.len(), 8); // 100 MB = 2 blocks each
        for b in &blocks {
            assert_eq!(b.replicas.len(), 1);
        }
    }

    #[test]
    fn pipelined_write_crosses_wan_once_per_hop() {
        let topo = topo();
        let net = FlowNet::new(&topo);
        let mut eng = crate::sim::Engine::new();
        let mut nn = nn(&topo, 3);
        let writer = NodeId(0);
        let reps = nn.place_replicas(writer);
        let done_at = Rc::new(RefCell::new(0.0));
        let d = done_at.clone();
        write_block(&net, &topo, &mut eng, &reps, 64 * 1024 * 1024, &Protocol::tcp(), move |e| {
            *d.borrow_mut() = e.now();
        });
        eng.run();
        let t = *done_at.borrow();
        // Lower bound: disk write of 64 MiB at 65 MB/s ≈ 1.03 s. The WAN
        // TCP hop (window-limited) dominates: ≥ 3 s.
        assert!(t > 3.0, "pipeline write too fast: {t}");
        // And both WAN directions saw traffic only for inter-site hops.
        assert_eq!(net.borrow().completions(), 5); // 3 disks + 2 hops
    }

    #[test]
    fn local_vs_remote_read_times() {
        let topo = topo();
        let net = FlowNet::new(&topo);
        let mut eng = crate::sim::Engine::new();
        let t_local = Rc::new(RefCell::new(0.0));
        let d = t_local.clone();
        let tcp = Protocol::tcp();
        read_block(&net, &topo, &mut eng, NodeId(0), NodeId(0), 65_000_000, &tcp, move |e| {
            *d.borrow_mut() = e.now();
        });
        eng.run();
        let local = *t_local.borrow();
        assert!((local - 1.0).abs() < 0.05, "local read {local}");
        // Cross-site read pays disk + WAN TCP.
        let net2 = FlowNet::new(&topo);
        let mut eng2 = crate::sim::Engine::new();
        let t_remote = Rc::new(RefCell::new(0.0));
        let d2 = t_remote.clone();
        let far = topo.racks[3].nodes[0];
        read_block(&net2, &topo, &mut eng2, far, NodeId(0), 65_000_000, &Protocol::tcp(), move |e| {
            *d2.borrow_mut() = e.now();
        });
        eng2.run();
        assert!(*t_remote.borrow() > 3.0 * local, "remote {} local {local}", t_remote.borrow());
    }
}

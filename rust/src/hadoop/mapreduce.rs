//! The MapReduce engine (JobTracker semantics, Hadoop 0.18).
//!
//! Two faces, one dataflow:
//!
//! - [`MapReduceEngine::simulate`] runs a job's *timing* on the
//!   discrete-event substrate at paper scale: locality-aware map
//!   scheduling onto per-node task slots, input reads from the closest
//!   HDFS replica, map CPU + local spill, an all-to-all shuffle over TCP
//!   with bounded parallel copies, merge passes, reduce CPU, and
//!   replication-pipelined output writes.
//! - [`execute_malstone`] runs the *actual computation* with the same
//!   dataflow decomposition (hash-partition by entity → reduce-side join
//!   and mark → per-site aggregation) on real records in memory; its
//!   result must equal the single-machine oracle bit-for-bit (tested).
//!
//! MalStone = two chained jobs ([`malstone_jobs`]): job 1 joins visits
//! with compromises keyed by entity and writes marked tuples to HDFS
//! (replicated — the term that separates Table 2's 3-replica and
//! 1-replica rows); job 2 aggregates per (site, week) with in-mapper
//! combining, so its shuffle is negligible.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::malstone::join::{bucketize, compromise_table, JoinedRecord};
use crate::malstone::oracle::MalstoneResult;
use crate::malstone::record::{Record, RECORD_BYTES};
use crate::net::{Cluster, NodeId};
use crate::sim::resources::CpuPool;
use crate::sim::Engine;
use crate::transport::{self, Protocol};

use super::hdfs::{self, Namenode};
use super::params::FrameworkParams;

/// One input block: location, bytes, records.
#[derive(Debug, Clone, Copy)]
pub struct InputBlock {
    pub node: NodeId,
    pub bytes: u64,
    pub records: u64,
}

/// A fully-resolved job description for the timing engine.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    /// TaskTracker nodes participating in the job.
    pub nodes: Vec<NodeId>,
    pub input: Vec<InputBlock>,
    pub map_cpu_per_record: f64,
    pub reduce_cpu_per_record: f64,
    pub task_overhead: f64,
    /// Bytes per input record surviving into the shuffle.
    pub intermediate_bytes_per_record: f64,
    /// Bytes per input record written to HDFS as job output.
    pub output_bytes_per_record: f64,
    pub output_replication: usize,
    pub protocol: Protocol,
    pub parallel_copies: usize,
    pub merge_passes: f64,
    pub map_slots_per_node: usize,
    pub reduce_slots_per_node: usize,
    pub num_reducers: usize,
}

/// Timing report for one simulated job.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub name: String,
    pub makespan: f64,
    pub map_phase: f64,
    pub shuffle_reduce_phase: f64,
    pub maps: usize,
    pub reduces: usize,
    pub shuffle_bytes: f64,
    pub output_bytes: f64,
    /// Where the output landed (primary replicas): feeds chained jobs.
    pub output: Vec<InputBlock>,
}

struct MrState {
    cluster: Cluster,
    nn: Rc<RefCell<Namenode>>,
    spec: JobSpec,
    pending_maps: Vec<InputBlock>,
    running_maps: usize,
    map_slots_free: HashMap<NodeId, usize>,
    /// Map output bytes and records accumulated per tasktracker node.
    map_out: HashMap<NodeId, (f64, f64)>,
    maps_done: usize,
    maps_total: usize,
    map_phase_end: f64,
    reducers_done: usize,
    start: f64,
    report_out: Vec<InputBlock>,
    shuffle_bytes: f64,
    output_bytes: f64,
    done_cb: Option<Box<dyn FnOnce(&mut Engine, JobReport)>>,
}

/// The timing engine.
pub struct MapReduceEngine;

impl MapReduceEngine {
    /// Run a job on the event engine; `done` receives the report.
    pub fn simulate<F: FnOnce(&mut Engine, JobReport) + 'static>(
        cluster: &Cluster,
        nn: &Rc<RefCell<Namenode>>,
        eng: &mut Engine,
        spec: JobSpec,
        done: F,
    ) {
        assert!(!spec.nodes.is_empty() && !spec.input.is_empty());
        assert!(spec.num_reducers > 0);
        let maps_total = spec.input.len();
        let map_slots_free =
            spec.nodes.iter().map(|&n| (n, spec.map_slots_per_node)).collect();
        let st = Rc::new(RefCell::new(MrState {
            cluster: cluster.clone(),
            nn: nn.clone(),
            pending_maps: spec.input.clone(),
            running_maps: 0,
            map_slots_free,
            map_out: HashMap::new(),
            maps_done: 0,
            maps_total,
            map_phase_end: 0.0,
            reducers_done: 0,
            start: eng.now(),
            report_out: Vec::new(),
            shuffle_bytes: 0.0,
            output_bytes: 0.0,
            done_cb: Some(Box::new(done)),
            spec,
        }));
        Self::fill_map_slots(&st, eng);
    }

    /// Locality-aware list scheduling: for every node with a free slot,
    /// prefer a pending block hosted on that node, then same-site, then
    /// anything (remote read).
    fn fill_map_slots(st: &Rc<RefCell<MrState>>, eng: &mut Engine) {
        loop {
            let task: Option<(NodeId, InputBlock)> = {
                let mut s = st.borrow_mut();
                if s.pending_maps.is_empty() {
                    None
                } else {
                    let topo = s.cluster.topo.clone();
                    let mut found = None;
                    let nodes: Vec<NodeId> = s.spec.nodes.clone();
                    'outer: for &n in &nodes {
                        if s.map_slots_free[&n] == 0 {
                            continue;
                        }
                        // Best pending block for this node.
                        let mut best: Option<(usize, u32)> = None;
                        for (i, b) in s.pending_maps.iter().enumerate() {
                            let d = topo.distance(n, b.node);
                            if best.map_or(true, |(_, bd)| d < bd) {
                                best = Some((i, d));
                            }
                            if d == 0 {
                                break;
                            }
                        }
                        if let Some((i, _)) = best {
                            let blk = s.pending_maps.swap_remove(i);
                            *s.map_slots_free.get_mut(&n).unwrap() -= 1;
                            s.running_maps += 1;
                            found = Some((n, blk));
                            break 'outer;
                        }
                    }
                    found
                }
            };
            match task {
                Some((node, blk)) => Self::run_map(st, eng, node, blk),
                None => break,
            }
        }
    }

    /// One map task: replica read → CPU → local spill → slot release.
    fn run_map(st: &Rc<RefCell<MrState>>, eng: &mut Engine, node: NodeId, blk: InputBlock) {
        let (cluster, nn, proto, overhead) = {
            let s = st.borrow();
            (s.cluster.clone(), s.nn.clone(), s.spec.protocol.clone(), s.spec.task_overhead)
        };
        // Resolve the closest replica through the namenode. Blocks arrive
        // as InputBlock (node = primary); consult HDFS when present.
        let source = nn.borrow().closest_source(blk.node, node);
        let st2 = st.clone();
        let topo = cluster.topo.clone();
        let net = cluster.net.clone();
        eng.schedule_in(overhead, move |eng| {
            let st3 = st2.clone();
            hdfs::read_block(&net, &topo, eng, source, node, blk.bytes, &proto, move |eng| {
                // CPU stage.
                let (pool, cpu, spill_bytes) = {
                    let s = st3.borrow();
                    let cpu = blk.records as f64 * s.spec.map_cpu_per_record;
                    let spill =
                        blk.records as f64 * s.spec.intermediate_bytes_per_record;
                    (s.cluster.pool(node).clone(), cpu, spill)
                };
                let st4 = st3.clone();
                CpuPool::submit(&pool, eng, cpu, move |eng| {
                    // Local spill of map output.
                    let (net, topo) = {
                        let s = st4.borrow();
                        (s.cluster.net.clone(), s.cluster.topo.clone())
                    };
                    let st5 = st4.clone();
                    transport::disk_write(&net, &topo, eng, node, spill_bytes, move |eng| {
                        Self::map_finished(&st5, eng, node, blk, spill_bytes);
                    });
                });
            });
        });
    }

    fn map_finished(
        st: &Rc<RefCell<MrState>>,
        eng: &mut Engine,
        node: NodeId,
        blk: InputBlock,
        out_bytes: f64,
    ) {
        let all_done = {
            let mut s = st.borrow_mut();
            let e = s.map_out.entry(node).or_insert((0.0, 0.0));
            e.0 += out_bytes;
            e.1 += blk.records as f64;
            s.maps_done += 1;
            s.running_maps -= 1;
            *s.map_slots_free.get_mut(&node).unwrap() += 1;
            if s.maps_done == s.maps_total {
                s.map_phase_end = eng.now();
                true
            } else {
                false
            }
        };
        Self::fill_map_slots(st, eng);
        if all_done {
            Self::start_shuffle(st, eng);
        }
    }

    /// Shuffle + reduce. Reducers are placed round-robin over the job's
    /// nodes; each fetches its partition of every mapper's output with at
    /// most `parallel_copies` concurrent streams.
    fn start_shuffle(st: &Rc<RefCell<MrState>>, eng: &mut Engine) {
        let (reducers, fetch_lists) = {
            let s = st.borrow();
            let r = s.spec.num_reducers;
            let reducers: Vec<NodeId> =
                (0..r).map(|i| s.spec.nodes[i % s.spec.nodes.len()]).collect();
            // Each reducer fetches bytes/r from every mapper node.
            let mut lists: Vec<Vec<(NodeId, f64)>> = vec![Vec::new(); r];
            for (&m, &(bytes, _records)) in {
                let mut v: Vec<_> = s.map_out.iter().collect();
                v.sort_by_key(|(n, _)| n.0);
                v
            } {
                for (ri, list) in lists.iter_mut().enumerate() {
                    let _ = ri;
                    list.push((m, bytes / r as f64));
                }
            }
            (reducers, lists)
        };
        for (ri, (rnode, fetches)) in reducers.into_iter().zip(fetch_lists).enumerate() {
            Self::run_reducer(st, eng, ri, rnode, fetches);
        }
    }

    fn run_reducer(
        st: &Rc<RefCell<MrState>>,
        eng: &mut Engine,
        _ri: usize,
        rnode: NodeId,
        fetches: Vec<(NodeId, f64)>,
    ) {
        let queue = Rc::new(RefCell::new(fetches));
        let inflight = Rc::new(RefCell::new(0usize));
        let fetched = Rc::new(RefCell::new(0.0f64));
        let k = st.borrow().spec.parallel_copies.max(1);
        Self::pump_fetches(st, eng, rnode, queue, inflight, fetched, k);
    }

    fn pump_fetches(
        st: &Rc<RefCell<MrState>>,
        eng: &mut Engine,
        rnode: NodeId,
        queue: Rc<RefCell<Vec<(NodeId, f64)>>>,
        inflight: Rc<RefCell<usize>>,
        fetched: Rc<RefCell<f64>>,
        k: usize,
    ) {
        loop {
            let next = {
                let mut q = queue.borrow_mut();
                if *inflight.borrow() >= k || q.is_empty() {
                    None
                } else {
                    *inflight.borrow_mut() += 1;
                    Some(q.pop().unwrap())
                }
            };
            let Some((mnode, bytes)) = next else { break };
            let (cluster, proto) = {
                let s = st.borrow();
                (s.cluster.clone(), s.spec.protocol.clone())
            };
            let st2 = st.clone();
            let queue2 = queue.clone();
            let inflight2 = inflight.clone();
            let fetched2 = fetched.clone();
            let deliver = move |eng: &mut Engine| {
                *inflight2.borrow_mut() -= 1;
                *fetched2.borrow_mut() += bytes;
                st2.borrow_mut().shuffle_bytes += bytes;
                let done =
                    queue2.borrow().is_empty() && *inflight2.borrow() == 0;
                if done {
                    Self::merge_and_reduce(&st2, eng, rnode, *fetched2.borrow());
                } else {
                    Self::pump_fetches(&st2, eng, rnode, queue2, inflight2, fetched2, k);
                }
            };
            if mnode == rnode {
                // Local partition: already on disk; charge a disk read.
                transport::disk_read(&cluster.net, &cluster.topo, eng, rnode, bytes, deliver);
            } else {
                let net = cluster.net.clone();
                let topo = cluster.topo.clone();
                transport::disk_read(&cluster.net, &cluster.topo, eng, mnode, bytes, move |eng| {
                    transport::send(&net, &topo, eng, mnode, rnode, bytes, &proto, deliver);
                });
            }
        }
    }

    fn merge_and_reduce(st: &Rc<RefCell<MrState>>, eng: &mut Engine, rnode: NodeId, bytes: f64) {
        let (cluster, merge_bytes, cpu, out_bytes, out_records, proto, repl) = {
            let s = st.borrow();
            let total_recs: f64 = s.map_out.values().map(|&(_, r)| r).sum();
            let recs = total_recs / s.spec.num_reducers as f64;
            let merge = 2.0 * s.spec.merge_passes * bytes; // read+write per pass
            let cpu = recs * s.spec.reduce_cpu_per_record;
            let out_b = recs * s.spec.output_bytes_per_record;
            (
                s.cluster.clone(),
                merge,
                cpu,
                out_b,
                recs,
                s.spec.protocol.clone(),
                s.spec.output_replication,
            )
        };
        let st2 = st.clone();
        let net = cluster.net.clone();
        let topo = cluster.topo.clone();
        let finish_output = move |eng: &mut Engine| {
            // Replicated output write through HDFS.
            let st3 = st2.clone();
            let replicas = st2.borrow().nn.borrow_mut().place_replicas_n(rnode, repl);
            let net2 = net.clone();
            let topo2 = topo.clone();
            hdfs::write_block(&net2, &topo2, eng, &replicas, out_bytes.ceil() as u64, &proto, move |eng| {
                let mut s = st3.borrow_mut();
                s.output_bytes += out_bytes;
                s.report_out.push(InputBlock {
                    node: rnode,
                    bytes: out_bytes.ceil() as u64,
                    records: out_records.ceil() as u64,
                });
                s.reducers_done += 1;
                if s.reducers_done == s.spec.num_reducers {
                    let report = JobReport {
                        name: s.spec.name.clone(),
                        makespan: eng.now() - s.start,
                        map_phase: s.map_phase_end - s.start,
                        shuffle_reduce_phase: eng.now() - s.map_phase_end,
                        maps: s.maps_total,
                        reduces: s.spec.num_reducers,
                        shuffle_bytes: s.shuffle_bytes,
                        output_bytes: s.output_bytes,
                        output: s.report_out.clone(),
                    };
                    let cb = s.done_cb.take().unwrap();
                    drop(s);
                    cb(eng, report);
                }
            });
        };
        // Merge passes on disk, then reduce CPU, then output.
        let pool = cluster.pool(rnode).clone();
        let net3 = cluster.net.clone();
        let topo3 = cluster.topo.clone();
        transport::disk_write(&net3, &topo3, eng, rnode, merge_bytes, move |eng| {
            CpuPool::submit(&pool, eng, cpu, finish_output);
        });
    }
}

impl Namenode {
    /// Closest source for a block whose primary copy is on `primary`
    /// (simulation-level shortcut: chained jobs pass primaries around
    /// without registering every intermediate file).
    pub fn closest_source(&self, primary: NodeId, _reader: NodeId) -> NodeId {
        primary
    }

    /// Placement honoring an explicit replication factor.
    pub fn place_replicas_n(&mut self, writer: NodeId, n: usize) -> Vec<NodeId> {
        let saved = self.cfg.replication;
        self.cfg.replication = n;
        let r = self.place_replicas(writer);
        self.cfg.replication = saved;
        r
    }
}

/// Build the two chained MalStone jobs for a framework parameterization.
///
/// `shards`: per-node input (bytes, records). Returns (job1, job2 builder):
/// job2's input is job1's output, so it is constructed from job1's report.
pub fn malstone_jobs(
    params: &FrameworkParams,
    nodes: &[NodeId],
    shards: &[InputBlock],
    variant_b: bool,
    block_size: u64,
) -> (JobSpec, impl Fn(&JobReport) -> JobSpec + use<>) {
    // Split shards into block-sized map inputs.
    let mut input = Vec::new();
    for sh in shards {
        let mut remaining_b = sh.bytes;
        let mut remaining_r = sh.records;
        while remaining_b > 0 {
            let b = remaining_b.min(block_size);
            let r = ((b as f64 / sh.bytes as f64) * sh.records as f64).round() as u64;
            input.push(InputBlock { node: sh.node, bytes: b, records: r.min(remaining_r) });
            remaining_b -= b;
            remaining_r = remaining_r.saturating_sub(r);
        }
    }
    let nreduce = nodes.len() * 2;
    let out_rec_bytes =
        params.output_bytes_per_record * if variant_b { params.variant_b_emit_factor } else { 1.0 };
    let job1 = JobSpec {
        name: format!("malstone-{}-join", if variant_b { "b" } else { "a" }),
        nodes: nodes.to_vec(),
        input,
        map_cpu_per_record: params.map_cpu_per_record,
        reduce_cpu_per_record: params.reduce_cpu(variant_b),
        task_overhead: params.task_overhead,
        intermediate_bytes_per_record: params.intermediate_bytes_per_record(variant_b),
        output_bytes_per_record: out_rec_bytes,
        output_replication: params.output_replication,
        protocol: params.protocol.clone(),
        parallel_copies: params.parallel_copies,
        merge_passes: params.merge_passes,
        map_slots_per_node: 2,
        reduce_slots_per_node: 2,
        num_reducers: nreduce,
    };
    let params2 = params.clone();
    let nodes2 = nodes.to_vec();
    let job2 = move |r1: &JobReport| JobSpec {
        name: r1.name.replace("join", "aggregate"),
        nodes: nodes2.clone(),
        input: r1.output.clone(),
        map_cpu_per_record: params2.map_cpu_per_record * 0.5,
        reduce_cpu_per_record: params2.reduce_cpu_per_record * 0.2,
        task_overhead: params2.task_overhead,
        // In-mapper combining: intermediate is histogram-sized.
        intermediate_bytes_per_record: 0.05,
        output_bytes_per_record: 0.01, // final ratios file is tiny
        output_replication: params2.output_replication,
        protocol: params2.protocol.clone(),
        parallel_copies: params2.parallel_copies,
        merge_passes: 0.0,
        map_slots_per_node: 2,
        reduce_slots_per_node: 2,
        num_reducers: nodes2.len(),
    };
    (job1, job2)
}

/// Execute MalStone for real with MapReduce dataflow semantics: partition
/// map output by entity hash, join+mark per reducer, aggregate per site.
/// Equals the oracle exactly (tested) — this is the correctness face of
/// the engine.
pub fn execute_malstone(
    shards: &[Vec<Record>],
    num_reducers: usize,
    num_sites: u32,
    num_weeks: u32,
    seconds_per_week: u64,
) -> MalstoneResult {
    assert!(num_reducers > 0);
    // Map phase: emit (entity → record) keyed partitions.
    let mut partitions: Vec<Vec<Record>> = vec![Vec::new(); num_reducers];
    for shard in shards {
        for r in shard {
            let h = r.entity_id.wrapping_mul(0x9E3779B97F4A7C15) >> 32;
            partitions[(h % num_reducers as u64) as usize].push(*r);
        }
    }
    // Reduce phase: each reducer holds *all* records of its entities, so
    // the compromise join is local; aggregate histograms and merge.
    let mut global = MalstoneResult::zero(num_sites as usize, num_weeks as usize);
    for part in &partitions {
        let table = compromise_table(part);
        let joined: Vec<JoinedRecord> =
            bucketize(part, &table, num_sites, num_weeks, seconds_per_week);
        let mut partial = MalstoneResult::zero(num_sites as usize, num_weeks as usize);
        partial.accumulate(&joined);
        global.merge(&partial);
    }
    global
}

/// Convenience: per-node shard descriptors for a uniformly distributed
/// workload of `total_records` across `nodes`.
pub fn uniform_shards(nodes: &[NodeId], total_records: u64) -> Vec<InputBlock> {
    let per = total_records.div_ceil(nodes.len() as u64);
    nodes
        .iter()
        .map(|&n| InputBlock { node: n, bytes: per * RECORD_BYTES as u64, records: per })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadoop::hdfs::HdfsConfig;
    use crate::malstone::malgen::{MalGen, MalGenConfig, SECONDS_PER_WEEK};
    use crate::malstone::oracle::MalstoneResult;
    use crate::net::Topology;

    fn small_cluster() -> (Cluster, Rc<RefCell<Namenode>>) {
        let cluster = Cluster::new(Topology::oct_2009());
        let nn = Rc::new(RefCell::new(Namenode::new(
            cluster.topo.clone(),
            HdfsConfig::default(),
            7,
        )));
        (cluster, nn)
    }

    fn run_sim(params: &FrameworkParams, nodes_per_site: usize, records: u64, variant_b: bool) -> (f64, JobReport, JobReport) {
        let (cluster, nn) = small_cluster();
        let topo = cluster.topo.clone();
        let mut nodes = Vec::new();
        for r in 0..4 {
            for i in 0..nodes_per_site {
                nodes.push(topo.racks[r].nodes[i]);
            }
        }
        let shards = uniform_shards(&nodes, records);
        let (job1, job2_of) = malstone_jobs(params, &nodes, &shards, variant_b, 64 * 1024 * 1024);
        let mut eng = Engine::new();
        let total = Rc::new(RefCell::new(None::<(f64, JobReport, JobReport)>));
        let total2 = total.clone();
        let cluster2 = cluster.clone();
        let nn2 = nn.clone();
        MapReduceEngine::simulate(&cluster, &nn, &mut eng, job1, move |eng, r1| {
            let job2 = job2_of(&r1);
            let total3 = total2.clone();
            MapReduceEngine::simulate(&cluster2, &nn2, eng, job2, move |eng, r2| {
                *total3.borrow_mut() = Some((eng.now(), r1, r2));
            });
        });
        eng.run();
        let (t, r1, r2) = total.borrow_mut().take().expect("job did not finish");
        (t, r1, r2)
    }

    #[test]
    fn job_completes_and_accounts_phases() {
        let params = FrameworkParams::hadoop_mapreduce();
        let (t, r1, r2) = run_sim(&params, 2, 8_000_000, false);
        assert!(t > 0.0);
        assert!(r1.map_phase > 0.0);
        assert!(r1.shuffle_reduce_phase > 0.0);
        assert!(r1.makespan >= r1.map_phase);
        assert_eq!(r1.maps, 16); // 100 MB/node = 2 blocks (64+36) × 8 nodes
        assert!(r1.shuffle_bytes > 0.0);
        assert!(r2.makespan > 0.0);
        assert!(r2.makespan < r1.makespan, "aggregate job should be cheap");
    }

    #[test]
    fn streams_faster_than_java_mr() {
        let recs = 20_000_000;
        let (mr, _, _) = run_sim(&FrameworkParams::hadoop_mapreduce(), 2, recs, false);
        let (st, _, _) = run_sim(&FrameworkParams::hadoop_streams(), 2, recs, false);
        assert!(st < mr, "streams {st} !< mapreduce {mr}");
    }

    #[test]
    fn variant_b_slower_than_a() {
        let recs = 20_000_000;
        let (a, _, _) = run_sim(&FrameworkParams::hadoop_mapreduce(), 2, recs, false);
        let (b, _, _) = run_sim(&FrameworkParams::hadoop_mapreduce(), 2, recs, true);
        assert!(b > a, "B {b} !> A {a}");
    }

    #[test]
    fn replication_one_faster() {
        let recs = 20_000_000;
        let (r3, _, _) = run_sim(&FrameworkParams::hadoop_mapreduce(), 2, recs, false);
        let (r1, _, _) = run_sim(&FrameworkParams::hadoop_mapreduce_r1(), 2, recs, false);
        assert!(r1 < r3, "r1 {r1} !< r3 {r3}");
    }

    #[test]
    fn execute_matches_oracle() {
        let g = MalGen::new(MalGenConfig::small(13));
        let shards: Vec<Vec<Record>> = (0..4).map(|s| g.generate_shard(s, 4, 2_000)).collect();
        let all: Vec<Record> = shards.iter().flatten().copied().collect();
        let table = compromise_table(&all);
        let joined = bucketize(&all, &table, 256, 64, SECONDS_PER_WEEK);
        let mut oracle = MalstoneResult::zero(256, 64);
        oracle.accumulate(&joined);
        for reducers in [1, 3, 8] {
            let mr = execute_malstone(&shards, reducers, 256, 64, SECONDS_PER_WEEK);
            assert_eq!(mr, oracle, "mismatch at R={reducers}");
        }
    }

    #[test]
    fn execute_reducer_count_invariant_property() {
        crate::proptest::check("mapreduce reducer-count invariance", 10, |rng| {
            let g = MalGen::new(MalGenConfig::small(rng.next_u64()));
            let shards: Vec<Vec<Record>> =
                (0..3).map(|s| g.generate_shard(s, 3, 500)).collect();
            let a = execute_malstone(&shards, 1, 64, 16, SECONDS_PER_WEEK * 4);
            let r = 2 + rng.gen_range(9) as usize;
            let b = execute_malstone(&shards, r, 64, 16, SECONDS_PER_WEEK * 4);
            if a == b {
                Ok(())
            } else {
                Err(format!("R={r} changed the result"))
            }
        });
    }
}

//! The MapReduce engine (JobTracker semantics, Hadoop 0.18).
//!
//! Two faces, one dataflow:
//!
//! - [`MapReduceEngine::simulate`] runs a job's *timing* as a thin
//!   instantiation of the shared [`crate::framework`] runtime: HDFS
//!   storage ([`crate::framework::HdfsStorage`]), locality-aware slot
//!   scheduling, and a barrier-then-pull shuffle
//!   ([`crate::framework::ExchangeModel::ShufflePull`]) over TCP with
//!   bounded parallel copies, merge passes, reduce CPU, and
//!   replication-pipelined output writes. [`MapReduceEngine::simulate_on`]
//!   swaps the storage layer — the §7 interop scenarios run the same job
//!   over CloudStore/KFS or Sector placement.
//! - [`execute_malstone`] runs the *actual computation* with the same
//!   dataflow decomposition (hash-partition by entity → reduce-side join
//!   and mark → per-site aggregation) on real records in memory; its
//!   result must equal the single-machine oracle bit-for-bit (tested).
//!
//! MalStone = two chained jobs ([`malstone_jobs`]): job 1 joins visits
//! with compromises keyed by entity and writes marked tuples to HDFS
//! (replicated — the term that separates Table 2's 3-replica and
//! 1-replica rows); job 2 aggregates per (site, week) with in-mapper
//! combining, so its shuffle is negligible.

use std::cell::RefCell;
use std::rc::Rc;

use crate::framework::{
    DataflowControl, DataflowEngine, DataflowSpec, ExchangeModel, HdfsStorage, StealPolicy,
    StorageModel, TaskInput,
};
use crate::malstone::join::{bucketize, compromise_table, JoinedRecord};
use crate::malstone::oracle::MalstoneResult;
use crate::malstone::record::{Record, RECORD_BYTES};
use crate::net::{Cluster, NodeId};
use crate::sim::Engine;
use crate::transport::Protocol;

use super::hdfs::Namenode;
use super::params::FrameworkParams;

/// One input block: location, bytes, records.
#[derive(Debug, Clone, Copy)]
pub struct InputBlock {
    pub node: NodeId,
    pub bytes: u64,
    pub records: u64,
}

/// A fully-resolved job description for the timing engine.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    /// TaskTracker nodes participating in the job.
    pub nodes: Vec<NodeId>,
    pub input: Vec<InputBlock>,
    pub map_cpu_per_record: f64,
    pub reduce_cpu_per_record: f64,
    pub task_overhead: f64,
    /// Bytes per input record surviving into the shuffle.
    pub intermediate_bytes_per_record: f64,
    /// Bytes per input record written to HDFS as job output.
    pub output_bytes_per_record: f64,
    pub output_replication: usize,
    pub protocol: Protocol,
    pub parallel_copies: usize,
    pub merge_passes: f64,
    pub map_slots_per_node: usize,
    pub reduce_slots_per_node: usize,
    pub num_reducers: usize,
}

/// Timing report for one simulated job.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub name: String,
    pub makespan: f64,
    pub map_phase: f64,
    pub shuffle_reduce_phase: f64,
    pub maps: usize,
    pub reduces: usize,
    /// Maps that ran away from their input's home node (remote reads).
    pub stolen_maps: usize,
    /// Maps re-executed on survivors after a TaskTracker was declared
    /// lost mid-job (see [`DataflowControl::heal_node`]).
    pub reexecuted_tasks: usize,
    /// All bytes reducers fetched, node-local partitions included.
    pub shuffle_bytes: f64,
    /// The subset of `shuffle_bytes` that crossed the network.
    pub shuffle_remote_bytes: f64,
    pub output_bytes: f64,
    /// Input bytes read through the storage layer.
    pub storage_read_bytes: f64,
    /// Output bytes written through the storage layer, replicas included.
    pub storage_write_bytes: f64,
    /// Where the output landed (primary replicas): feeds chained jobs.
    pub output: Vec<InputBlock>,
}

/// The timing engine: MapReduce semantics instantiated on the shared
/// [`crate::framework`] dataflow runtime.
pub struct MapReduceEngine;

impl MapReduceEngine {
    /// Run a job over HDFS on the event engine; `done` receives the
    /// report. The job's `output_replication` configures the namenode's
    /// placement for output writes.
    pub fn simulate<F: FnOnce(&mut Engine, JobReport) + 'static>(
        cluster: &Cluster,
        nn: &Rc<RefCell<Namenode>>,
        eng: &mut Engine,
        spec: JobSpec,
        done: F,
    ) -> DataflowControl {
        let storage: Rc<RefCell<dyn StorageModel>> =
            Rc::new(RefCell::new(HdfsStorage::new(nn.clone(), spec.output_replication)));
        Self::simulate_on(cluster, storage, eng, spec, done)
    }

    /// Run a job with MapReduce scheduling + shuffle semantics over an
    /// arbitrary storage layer — the §7 interoperability entry point
    /// (MapReduce over CloudStore/KFS chunks, MapReduce over Sector
    /// placement). The returned [`DataflowControl`] is the JobTracker's
    /// failure surface: the ops plane crashes/heals TaskTrackers through
    /// it.
    pub fn simulate_on<F: FnOnce(&mut Engine, JobReport) + 'static>(
        cluster: &Cluster,
        storage: Rc<RefCell<dyn StorageModel>>,
        eng: &mut Engine,
        spec: JobSpec,
        done: F,
    ) -> DataflowControl {
        assert!(!spec.nodes.is_empty() && !spec.input.is_empty());
        assert!(spec.num_reducers > 0);
        let dataflow = DataflowSpec {
            name: spec.name,
            nodes: spec.nodes,
            tasks: spec
                .input
                .iter()
                .map(|b| TaskInput { node: b.node, bytes: b.bytes, records: b.records })
                .collect(),
            slots_per_node: spec.map_slots_per_node,
            task_overhead: spec.task_overhead,
            map_cpu_per_record: spec.map_cpu_per_record,
            reduce_cpu_per_record: spec.reduce_cpu_per_record,
            intermediate_bytes_per_record: spec.intermediate_bytes_per_record,
            output_bytes_per_record: spec.output_bytes_per_record,
            merge_passes: spec.merge_passes,
            num_reducers: spec.num_reducers,
            protocol: spec.protocol,
            exchange: ExchangeModel::ShufflePull { parallel_copies: spec.parallel_copies },
            steal: StealPolicy::Anywhere,
        };
        DataflowEngine::run(cluster, storage, eng, dataflow, move |eng, r| {
            let report = JobReport {
                name: r.name,
                makespan: r.makespan,
                map_phase: r.phase1,
                shuffle_reduce_phase: r.phase2,
                maps: r.tasks,
                reduces: r.reducers,
                stolen_maps: r.remote_tasks,
                reexecuted_tasks: r.reexecuted,
                shuffle_bytes: r.exchange_bytes,
                shuffle_remote_bytes: r.exchange_remote_bytes,
                output_bytes: r.output_bytes,
                storage_read_bytes: r.storage_read_bytes,
                storage_write_bytes: r.storage_write_bytes,
                output: r
                    .output
                    .iter()
                    .map(|t| InputBlock { node: t.node, bytes: t.bytes, records: t.records })
                    .collect(),
            };
            done(eng, report);
        })
    }
}

impl Namenode {
    /// Closest source for a block whose primary copy is on `primary`
    /// (simulation-level shortcut: chained jobs pass primaries around
    /// without registering every intermediate file).
    pub fn closest_source(&self, primary: NodeId, _reader: NodeId) -> NodeId {
        primary
    }

    /// Placement honoring an explicit replication factor.
    pub fn place_replicas_n(&mut self, writer: NodeId, n: usize) -> Vec<NodeId> {
        let saved = self.cfg.replication;
        self.cfg.replication = n;
        let r = self.place_replicas(writer);
        self.cfg.replication = saved;
        r
    }
}

/// Build the two chained MalStone jobs for a framework parameterization.
///
/// `shards`: per-node input (bytes, records). Returns (job1, job2 builder):
/// job2's input is job1's output, so it is constructed from job1's report.
pub fn malstone_jobs(
    params: &FrameworkParams,
    nodes: &[NodeId],
    shards: &[InputBlock],
    variant_b: bool,
    block_size: u64,
) -> (JobSpec, impl Fn(&JobReport) -> JobSpec + use<>) {
    // Split shards into block-sized map inputs.
    let mut input = Vec::new();
    for sh in shards {
        let mut remaining_b = sh.bytes;
        let mut remaining_r = sh.records;
        while remaining_b > 0 {
            let b = remaining_b.min(block_size);
            let r = ((b as f64 / sh.bytes as f64) * sh.records as f64).round() as u64;
            input.push(InputBlock { node: sh.node, bytes: b, records: r.min(remaining_r) });
            remaining_b -= b;
            remaining_r = remaining_r.saturating_sub(r);
        }
    }
    let nreduce = nodes.len() * 2;
    let out_rec_bytes =
        params.output_bytes_per_record * if variant_b { params.variant_b_emit_factor } else { 1.0 };
    let job1 = JobSpec {
        name: format!("malstone-{}-join", if variant_b { "b" } else { "a" }),
        nodes: nodes.to_vec(),
        input,
        map_cpu_per_record: params.map_cpu_per_record,
        reduce_cpu_per_record: params.reduce_cpu(variant_b),
        task_overhead: params.task_overhead,
        intermediate_bytes_per_record: params.intermediate_bytes_per_record(variant_b),
        output_bytes_per_record: out_rec_bytes,
        output_replication: params.output_replication,
        protocol: params.protocol.clone(),
        parallel_copies: params.parallel_copies,
        merge_passes: params.merge_passes,
        map_slots_per_node: 2,
        reduce_slots_per_node: 2,
        num_reducers: nreduce,
    };
    let params2 = params.clone();
    let nodes2 = nodes.to_vec();
    let job2 = move |r1: &JobReport| JobSpec {
        name: r1.name.replace("join", "aggregate"),
        nodes: nodes2.clone(),
        input: r1.output.clone(),
        map_cpu_per_record: params2.map_cpu_per_record * 0.5,
        reduce_cpu_per_record: params2.reduce_cpu_per_record * 0.2,
        task_overhead: params2.task_overhead,
        // In-mapper combining: intermediate is histogram-sized.
        intermediate_bytes_per_record: 0.05,
        output_bytes_per_record: 0.01, // final ratios file is tiny
        output_replication: params2.output_replication,
        protocol: params2.protocol.clone(),
        parallel_copies: params2.parallel_copies,
        merge_passes: 0.0,
        map_slots_per_node: 2,
        reduce_slots_per_node: 2,
        num_reducers: nodes2.len(),
    };
    (job1, job2)
}

/// Execute MalStone for real with MapReduce dataflow semantics: partition
/// map output by entity hash, join+mark per reducer, aggregate per site.
/// Equals the oracle exactly (tested) — this is the correctness face of
/// the engine.
pub fn execute_malstone(
    shards: &[Vec<Record>],
    num_reducers: usize,
    num_sites: u32,
    num_weeks: u32,
    seconds_per_week: u64,
) -> MalstoneResult {
    assert!(num_reducers > 0);
    // Map phase: emit (entity → record) keyed partitions.
    let mut partitions: Vec<Vec<Record>> = vec![Vec::new(); num_reducers];
    for shard in shards {
        for r in shard {
            let h = r.entity_id.wrapping_mul(0x9E3779B97F4A7C15) >> 32;
            partitions[(h % num_reducers as u64) as usize].push(*r);
        }
    }
    // Reduce phase: each reducer holds *all* records of its entities, so
    // the compromise join is local; aggregate histograms and merge.
    let mut global = MalstoneResult::zero(num_sites as usize, num_weeks as usize);
    for part in &partitions {
        let table = compromise_table(part);
        let joined: Vec<JoinedRecord> =
            bucketize(part, &table, num_sites, num_weeks, seconds_per_week);
        let mut partial = MalstoneResult::zero(num_sites as usize, num_weeks as usize);
        partial.accumulate(&joined);
        global.merge(&partial);
    }
    global
}

/// Convenience: per-node shard descriptors for a uniformly distributed
/// workload of `total_records` across `nodes`.
pub fn uniform_shards(nodes: &[NodeId], total_records: u64) -> Vec<InputBlock> {
    let per = total_records.div_ceil(nodes.len() as u64);
    nodes
        .iter()
        .map(|&n| InputBlock { node: n, bytes: per * RECORD_BYTES as u64, records: per })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadoop::hdfs::HdfsConfig;
    use crate::malstone::malgen::{MalGen, MalGenConfig, SECONDS_PER_WEEK};
    use crate::malstone::oracle::MalstoneResult;
    use crate::net::Topology;

    fn small_cluster() -> (Cluster, Rc<RefCell<Namenode>>) {
        let cluster = Cluster::new(Topology::oct_2009());
        let nn = Rc::new(RefCell::new(Namenode::new(
            cluster.topo.clone(),
            HdfsConfig::default(),
            7,
        )));
        (cluster, nn)
    }

    fn run_sim(
        params: &FrameworkParams,
        nodes_per_site: usize,
        records: u64,
        variant_b: bool,
    ) -> (f64, JobReport, JobReport) {
        let (cluster, nn) = small_cluster();
        let topo = cluster.topo.clone();
        let mut nodes = Vec::new();
        for r in 0..4 {
            for i in 0..nodes_per_site {
                nodes.push(topo.racks[r].nodes[i]);
            }
        }
        let shards = uniform_shards(&nodes, records);
        let (job1, job2_of) = malstone_jobs(params, &nodes, &shards, variant_b, 64 * 1024 * 1024);
        let mut eng = Engine::new();
        let total = Rc::new(RefCell::new(None::<(f64, JobReport, JobReport)>));
        let total2 = total.clone();
        let cluster2 = cluster.clone();
        let nn2 = nn.clone();
        MapReduceEngine::simulate(&cluster, &nn, &mut eng, job1, move |eng, r1| {
            let job2 = job2_of(&r1);
            let total3 = total2.clone();
            MapReduceEngine::simulate(&cluster2, &nn2, eng, job2, move |eng, r2| {
                *total3.borrow_mut() = Some((eng.now(), r1, r2));
            });
        });
        eng.run();
        let (t, r1, r2) = total.borrow_mut().take().expect("job did not finish");
        (t, r1, r2)
    }

    #[test]
    fn job_completes_and_accounts_phases() {
        let params = FrameworkParams::hadoop_mapreduce();
        let (t, r1, r2) = run_sim(&params, 2, 8_000_000, false);
        assert!(t > 0.0);
        assert!(r1.map_phase > 0.0);
        assert!(r1.shuffle_reduce_phase > 0.0);
        assert!(r1.makespan >= r1.map_phase);
        assert_eq!(r1.maps, 16); // 100 MB/node = 2 blocks (64+36) × 8 nodes
        assert!(r1.shuffle_bytes > 0.0);
        assert!(r2.makespan > 0.0);
        assert!(r2.makespan < r1.makespan, "aggregate job should be cheap");
    }

    #[test]
    fn streams_faster_than_java_mr() {
        let recs = 20_000_000;
        let (mr, _, _) = run_sim(&FrameworkParams::hadoop_mapreduce(), 2, recs, false);
        let (st, _, _) = run_sim(&FrameworkParams::hadoop_streams(), 2, recs, false);
        assert!(st < mr, "streams {st} !< mapreduce {mr}");
    }

    #[test]
    fn variant_b_slower_than_a() {
        let recs = 20_000_000;
        let (a, _, _) = run_sim(&FrameworkParams::hadoop_mapreduce(), 2, recs, false);
        let (b, _, _) = run_sim(&FrameworkParams::hadoop_mapreduce(), 2, recs, true);
        assert!(b > a, "B {b} !> A {a}");
    }

    #[test]
    fn replication_one_faster() {
        let recs = 20_000_000;
        let (r3, _, _) = run_sim(&FrameworkParams::hadoop_mapreduce(), 2, recs, false);
        let (r1, _, _) = run_sim(&FrameworkParams::hadoop_mapreduce_r1(), 2, recs, false);
        assert!(r1 < r3, "r1 {r1} !< r3 {r3}");
    }

    #[test]
    fn execute_matches_oracle() {
        let g = MalGen::new(MalGenConfig::small(13));
        let shards: Vec<Vec<Record>> = (0..4).map(|s| g.generate_shard(s, 4, 2_000)).collect();
        let all: Vec<Record> = shards.iter().flatten().copied().collect();
        let table = compromise_table(&all);
        let joined = bucketize(&all, &table, 256, 64, SECONDS_PER_WEEK);
        let mut oracle = MalstoneResult::zero(256, 64);
        oracle.accumulate(&joined);
        for reducers in [1, 3, 8] {
            let mr = execute_malstone(&shards, reducers, 256, 64, SECONDS_PER_WEEK);
            assert_eq!(mr, oracle, "mismatch at R={reducers}");
        }
    }

    #[test]
    fn execute_reducer_count_invariant_property() {
        crate::proptest::check("mapreduce reducer-count invariance", 10, |rng| {
            let g = MalGen::new(MalGenConfig::small(rng.next_u64()));
            let shards: Vec<Vec<Record>> =
                (0..3).map(|s| g.generate_shard(s, 3, 500)).collect();
            let a = execute_malstone(&shards, 1, 64, 16, SECONDS_PER_WEEK * 4);
            let r = 2 + rng.gen_range(9) as usize;
            let b = execute_malstone(&shards, r, 64, 16, SECONDS_PER_WEEK * 4);
            if a == b {
                Ok(())
            } else {
                Err(format!("R={r} changed the result"))
            }
        });
    }
}

//! The Hadoop substrate (HDFS + MapReduce + Streaming), version 0.18.3 as
//! benchmarked in Table 1/2 of the paper.
//!
//! Built from scratch against the simulated fabric: [`hdfs`] is the
//! namenode/datanode layer with rack-aware 3-replica pipeline writes (in
//! the OCT each rack is a *site*, so replica #2 crosses the WAN — half of
//! the Table 2 penalty), and [`mapreduce`] is the JobTracker engine with
//! locality-aware map scheduling, TCP shuffle, merge passes, and
//! replicated output writes. Hadoop Streaming is the same engine under
//! different per-record cost parameters ([`params::FrameworkParams`]).

pub mod hdfs;
pub mod mapreduce;
pub mod params;

pub use hdfs::{BlockId, HdfsConfig, Namenode};
pub use mapreduce::{JobReport, JobSpec, MapReduceEngine};
pub use params::FrameworkParams;

//! GMP wire format.
//!
//! Fixed 20-byte header, little-endian:
//!
//! ```text
//! | magic u16 | ver u8 | kind u8 | session u32 | seq u32 | arg u32 | len u32 |
//! ```
//!
//! `arg` is kind-specific: fragment index for `Frag`, fragment count for
//! the first fragment, 0 otherwise. `len` is the payload length.

pub const MAGIC: u16 = 0x474D; // "GM"
pub const VERSION: u8 = 1;
pub const HEADER_LEN: usize = 20;
/// Payload budget per datagram (stay under typical 1500-byte MTU).
pub const MAX_DATAGRAM_PAYLOAD: usize = 1200;

/// Packet kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Single-datagram application message.
    Data = 1,
    /// Acknowledgment of (session, seq).
    Ack = 2,
    /// One fragment of a large message (the UDT-style stream path).
    Frag = 3,
}

impl Kind {
    fn from_u8(x: u8) -> Option<Kind> {
        match x {
            1 => Some(Kind::Data),
            2 => Some(Kind::Ack),
            3 => Some(Kind::Frag),
            _ => None,
        }
    }
}

/// A parsed GMP packet.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Packet {
    pub kind: Kind,
    pub session: u32,
    pub seq: u32,
    pub arg: u32,
    pub payload: Vec<u8>,
}

impl Packet {
    pub fn encode(&self) -> Vec<u8> {
        let mut b = Vec::with_capacity(HEADER_LEN + self.payload.len());
        b.extend_from_slice(&MAGIC.to_le_bytes());
        b.push(VERSION);
        b.push(self.kind as u8);
        b.extend_from_slice(&self.session.to_le_bytes());
        b.extend_from_slice(&self.seq.to_le_bytes());
        b.extend_from_slice(&self.arg.to_le_bytes());
        b.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        b.extend_from_slice(&self.payload);
        b
    }

    pub fn decode(b: &[u8]) -> Result<Packet, String> {
        if b.len() < HEADER_LEN {
            return Err(format!("short packet: {}", b.len()));
        }
        let magic = u16::from_le_bytes([b[0], b[1]]);
        if magic != MAGIC {
            return Err(format!("bad magic {magic:#x}"));
        }
        if b[2] != VERSION {
            return Err(format!("bad version {}", b[2]));
        }
        let kind = Kind::from_u8(b[3]).ok_or_else(|| format!("bad kind {}", b[3]))?;
        let session = u32::from_le_bytes(b[4..8].try_into().unwrap());
        let seq = u32::from_le_bytes(b[8..12].try_into().unwrap());
        let arg = u32::from_le_bytes(b[12..16].try_into().unwrap());
        let len = u32::from_le_bytes(b[16..20].try_into().unwrap()) as usize;
        if b.len() != HEADER_LEN + len {
            return Err(format!("length mismatch: header {len}, actual {}", b.len() - HEADER_LEN));
        }
        Ok(Packet { kind, session, seq, arg, payload: b[HEADER_LEN..].to_vec() })
    }

    pub fn ack(session: u32, seq: u32) -> Packet {
        Packet { kind: Kind::Ack, session, seq, arg: 0, payload: Vec::new() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let p =
            Packet { kind: Kind::Data, session: 7, seq: 42, arg: 0, payload: b"hello".to_vec() };
        assert_eq!(Packet::decode(&p.encode()).unwrap(), p);
    }

    #[test]
    fn ack_is_empty() {
        let a = Packet::ack(1, 2);
        let b = a.encode();
        assert_eq!(b.len(), HEADER_LEN);
        assert_eq!(Packet::decode(&b).unwrap(), a);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Packet::decode(&[0u8; 4]).is_err());
        let mut good = Packet::ack(1, 2).encode();
        good[0] = 0; // magic
        assert!(Packet::decode(&good).is_err());
        let mut vers = Packet::ack(1, 2).encode();
        vers[2] = 9;
        assert!(Packet::decode(&vers).is_err());
        let mut kind = Packet::ack(1, 2).encode();
        kind[3] = 77;
        assert!(Packet::decode(&kind).is_err());
        let mut truncated =
            Packet { kind: Kind::Data, session: 1, seq: 1, arg: 0, payload: vec![1, 2, 3] }
                .encode();
        truncated.pop();
        assert!(Packet::decode(&truncated).is_err());
    }

    #[test]
    fn roundtrip_property() {
        crate::proptest::check("gmp wire roundtrip", 50, |rng| {
            let p = Packet {
                kind: *rng.pick(&[Kind::Data, Kind::Ack, Kind::Frag]),
                session: rng.next_u64() as u32,
                seq: rng.next_u64() as u32,
                arg: rng.next_u64() as u32,
                payload: (0..rng.gen_range(600)).map(|_| rng.next_u64() as u8).collect(),
            };
            let back = Packet::decode(&p.encode()).map_err(|e| e.to_string())?;
            if back == p {
                Ok(())
            } else {
                Err("mismatch".into())
            }
        });
    }
}

//! GMP — the Group Messaging Protocol (paper §4), implemented for real
//! over `std::net::UdpSocket`.
//!
//! "GMP is a connection-less protocol, which uses a single UDP port …
//! Every GMP message contains a session ID and a sequence number. Upon
//! receiving a message, GMP sends back an acknowledgment; if no
//! acknowledgment is received, the message will be sent again. … The
//! sequence number is used to make sure that no duplicated message will
//! be delivered. The session ID is used to differentiate messages from
//! the same address but different processes. If the message size is
//! greater than a single UDP packet can hold, GMP will set up a UDT
//! connection to deliver the large message."
//!
//! [`wire`] is the packet codec; [`endpoint`] the protocol engine
//! (ack/retransmit, dedup, fragmentation with a windowed UDT-like
//! reliable stream for large messages, fault injection for tests); and
//! [`rpc`] the "light-weight high performance RPC mechanism on top of
//! GMP" used by Sector: one request message, one response message.
//!
//! This module is *actual* networking (threads + sockets on loopback in
//! tests); the simulator models GMP's latency analytically via
//! [`crate::transport::control_message_latency`].

pub mod endpoint;
pub mod rpc;
pub mod wire;

pub use endpoint::{FaultSpec, GmpConfig, GmpEndpoint};
pub use rpc::{RpcClient, RpcServer};

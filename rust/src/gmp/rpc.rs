//! The "light-weight high performance RPC mechanism on top of GMP"
//! (paper §4): a request is one GMP message, the response another.
//!
//! Frame layout inside the GMP payload (little-endian):
//! `| tag u8 (0=req, 1=resp, 2=err) | req_id u32 | method_len u16 | method | body |`
//!
//! The error tag keeps server-side failures (unknown method) out of the
//! success-payload channel: an `err` frame surfaces as `Err` on the
//! client, so a handler may legitimately return bytes that *look* like
//! an error message.

use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::endpoint::GmpEndpoint;
use crate::trace::WallSpanLog;

const TAG_REQ: u8 = 0;
const TAG_RESP: u8 = 1;
const TAG_ERR: u8 = 2;

fn encode_frame(tag: u8, req_id: u32, method: &str, body: &[u8]) -> Vec<u8> {
    let mut b = Vec::with_capacity(7 + method.len() + body.len());
    b.push(tag);
    b.extend_from_slice(&req_id.to_le_bytes());
    b.extend_from_slice(&(method.len() as u16).to_le_bytes());
    b.extend_from_slice(method.as_bytes());
    b.extend_from_slice(body);
    b
}

fn decode_frame(b: &[u8]) -> Option<(u8, u32, String, Vec<u8>)> {
    if b.len() < 7 {
        return None;
    }
    let tag = b[0];
    let req_id = u32::from_le_bytes(b[1..5].try_into().ok()?);
    let mlen = u16::from_le_bytes(b[5..7].try_into().ok()?) as usize;
    if b.len() < 7 + mlen {
        return None;
    }
    let method = String::from_utf8(b[7..7 + mlen].to_vec()).ok()?;
    Some((tag, req_id, method, b[7 + mlen..].to_vec()))
}

/// A registered RPC method implementation.
pub type Handler = Box<dyn Fn(&[u8]) -> Vec<u8> + Send + Sync>;

/// RPC server: dispatches registered handlers from a service thread.
pub struct RpcServer {
    ep: Arc<GmpEndpoint>,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl RpcServer {
    /// Start serving `handlers` on `ep`'s inbox.
    pub fn start(ep: Arc<GmpEndpoint>, handlers: HashMap<String, Handler>) -> RpcServer {
        Self::start_traced(ep, handlers, None)
    }

    /// Like [`RpcServer::start`], but each dispatched request records a
    /// `rpc.serve:<method>` span (wall-clock, outside byte-identity) in
    /// `spans`. `ok = false` marks unknown-method dispatches.
    pub fn start_traced(
        ep: Arc<GmpEndpoint>,
        handlers: HashMap<String, Handler>,
        spans: Option<WallSpanLog>,
    ) -> RpcServer {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let ep2 = ep.clone();
        let thread = std::thread::spawn(move || {
            let handlers = handlers;
            while !stop2.load(Ordering::Relaxed) {
                let Some((from, msg)) = ep2.recv_timeout(Duration::from_millis(20)) else {
                    continue;
                };
                let Some((tag, req_id, method, body)) = decode_frame(&msg) else { continue };
                if tag != TAG_REQ {
                    continue;
                }
                // simlint: allow(SIM002) — wall-domain RPC dispatch timing on a live socket, outside simulated time
                let started = Instant::now();
                let (resp_tag, resp_body) = match handlers.get(&method) {
                    Some(h) => (TAG_RESP, h(&body)),
                    None => (TAG_ERR, format!("unknown method {method}").into_bytes()),
                };
                if let Some(log) = &spans {
                    log.record(&format!("rpc.serve:{method}"), started, resp_tag == TAG_RESP);
                }
                let frame = encode_frame(resp_tag, req_id, &method, &resp_body);
                let _ = ep2.send(from, &frame);
            }
        });
        RpcServer { ep, stop, thread: Some(thread) }
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.ep.local_addr()
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

struct ClientShared {
    /// Completed calls: request id → (response tag, body).
    responses: Mutex<HashMap<u32, (u8, Vec<u8>)>>,
    cv: Condvar,
}

/// RPC client: correlates responses by request id; a pump thread drains
/// the endpoint inbox.
pub struct RpcClient {
    ep: Arc<GmpEndpoint>,
    next_id: AtomicU32,
    shared: Arc<ClientShared>,
    stop: Arc<AtomicBool>,
    pump: Option<std::thread::JoinHandle<()>>,
    spans: Option<WallSpanLog>,
}

impl RpcClient {
    pub fn new(ep: Arc<GmpEndpoint>) -> RpcClient {
        let shared = Arc::new(ClientShared {
            responses: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let (s2, st2, ep2) = (shared.clone(), stop.clone(), ep.clone());
        let pump = std::thread::spawn(move || {
            while !st2.load(Ordering::Relaxed) {
                let Some((_from, msg)) = ep2.recv_timeout(Duration::from_millis(20)) else {
                    continue;
                };
                if let Some((tag, req_id, _method, body)) = decode_frame(&msg) {
                    if tag == TAG_RESP || tag == TAG_ERR {
                        s2.responses.lock().unwrap().insert(req_id, (tag, body));
                        s2.cv.notify_all();
                    }
                }
            }
        });
        RpcClient { ep, next_id: AtomicU32::new(1), shared, stop, pump: Some(pump), spans: None }
    }

    /// Record a `rpc.call:<method>` wall-clock span for every [`call`]
    /// (success or failure) into `log`. RPC runs on live sockets with no
    /// simulated clock, so these spans stay outside the deterministic
    /// trace merge by construction.
    ///
    /// [`call`]: RpcClient::call
    pub fn with_span_log(mut self, log: WallSpanLog) -> RpcClient {
        self.spans = Some(log);
        self
    }

    /// Call `method` on the server at `to`; blocks until the response or
    /// `timeout`. A server-side error frame (unknown method) surfaces as
    /// `Err` — never as a success payload.
    pub fn call(
        &self,
        to: SocketAddr,
        method: &str,
        body: &[u8],
        timeout: Duration,
    ) -> std::io::Result<Vec<u8>> {
        let started = Instant::now(); // simlint: allow(SIM002) — wall-domain RPC round-trip timing, outside simulated time
        let out = self.call_inner(to, method, body, timeout);
        if let Some(log) = &self.spans {
            log.record(&format!("rpc.call:{method}"), started, out.is_ok());
        }
        out
    }

    fn call_inner(
        &self,
        to: SocketAddr,
        method: &str,
        body: &[u8],
        timeout: Duration,
    ) -> std::io::Result<Vec<u8>> {
        let req_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let frame = encode_frame(TAG_REQ, req_id, method, body);
        self.ep.send(to, &frame)?;
        let deadline = Instant::now() + timeout; // simlint: allow(SIM002) — real RPC deadline on a live socket, outside simulated time
        let mut resp = self.shared.responses.lock().unwrap();
        loop {
            if let Some((tag, body)) = resp.remove(&req_id) {
                if tag == TAG_ERR {
                    return Err(std::io::Error::other(format!(
                        "rpc {method} to {to} failed: {}",
                        String::from_utf8_lossy(&body)
                    )));
                }
                return Ok(body);
            }
            let now = Instant::now(); // simlint: allow(SIM002) — real RPC deadline on a live socket, outside simulated time
            if now >= deadline {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    format!("rpc {method} to {to} timed out"),
                ));
            }
            let (g, _) = self.shared.cv.wait_timeout(resp, deadline - now).unwrap();
            resp = g;
        }
    }
}

impl Drop for RpcClient {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.pump.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gmp::endpoint::{FaultSpec, GmpConfig};

    fn echo_server() -> (RpcServer, SocketAddr) {
        let ep = GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap();
        let addr = ep.local_addr();
        let mut handlers: HashMap<String, Handler> = HashMap::new();
        handlers.insert("echo".into(), Box::new(|b: &[u8]| b.to_vec()));
        handlers.insert("sum".into(), Box::new(|b: &[u8]| {
            let s: u64 = b.iter().map(|&x| x as u64).sum();
            s.to_le_bytes().to_vec()
        }));
        (RpcServer::start(ep, handlers), addr)
    }

    #[test]
    fn echo_roundtrip() {
        let (_srv, addr) = echo_server();
        let client =
            RpcClient::new(GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap());
        let out = client.call(addr, "echo", b"hello rpc", Duration::from_secs(2)).unwrap();
        assert_eq!(out, b"hello rpc");
    }

    #[test]
    fn compute_handler_and_many_calls() {
        let (_srv, addr) = echo_server();
        let client =
            RpcClient::new(GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap());
        for i in 0..30u8 {
            let out = client.call(addr, "sum", &[i, i, i], Duration::from_secs(2)).unwrap();
            assert_eq!(u64::from_le_bytes(out.try_into().unwrap()), 3 * i as u64);
        }
    }

    #[test]
    fn unknown_method_surfaces_as_err() {
        let (_srv, addr) = echo_server();
        let client =
            RpcClient::new(GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap());
        let err = client.call(addr, "nope", b"", Duration::from_secs(2)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::Other);
        assert!(err.to_string().contains("unknown method nope"), "{err}");
    }

    #[test]
    fn error_frames_are_distinguishable_from_error_looking_payloads() {
        // A handler may legitimately return bytes that look like an error
        // message; only the TAG_ERR frame must surface as Err.
        let ep = GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap();
        let addr = ep.local_addr();
        let mut handlers: HashMap<String, Handler> = HashMap::new();
        handlers.insert(
            "looks-bad".into(),
            Box::new(|_: &[u8]| b"ERR unknown method fake".to_vec()),
        );
        let _srv = RpcServer::start(ep, handlers);
        let client =
            RpcClient::new(GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap());
        let out = client.call(addr, "looks-bad", b"", Duration::from_secs(2)).unwrap();
        assert_eq!(out, b"ERR unknown method fake");
        let err = client.call(addr, "missing", b"", Duration::from_secs(2)).unwrap_err();
        assert!(err.to_string().contains("unknown method missing"), "{err}");
    }

    #[test]
    fn error_tag_survives_faulty_transport() {
        // The error-tag byte on unknown methods must reach the client as
        // `Err` even when the transport drops, duplicates, and reorders
        // datagrams underneath the RPC frames.
        let ep = GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap();
        let addr = ep.local_addr();
        ep.set_fault(FaultSpec { drop_every: 6, dup_every: 5, reorder_every: 4 });
        let mut handlers: HashMap<String, Handler> = HashMap::new();
        handlers.insert("ok".into(), Box::new(|_: &[u8]| b"fine".to_vec()));
        let _srv = RpcServer::start(ep, handlers);
        let cep = GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap();
        cep.set_fault(FaultSpec { drop_every: 7, dup_every: 0, reorder_every: 3 });
        let client = RpcClient::new(cep);
        for i in 0..10 {
            let out = client.call(addr, "ok", &[i], Duration::from_secs(3)).unwrap();
            assert_eq!(out, b"fine");
            let err = client.call(addr, "missing", &[i], Duration::from_secs(3)).unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::Other);
            assert!(err.to_string().contains("unknown method missing"), "{err}");
        }
    }

    #[test]
    fn call_to_dead_server_times_out() {
        let client = RpcClient::new(
            GmpEndpoint::bind(
                "127.0.0.1:0",
                GmpConfig { rto: Duration::from_millis(10), max_retries: 2, ..Default::default() },
            )
            .unwrap(),
        );
        let dead: SocketAddr = "127.0.0.1:9".parse().unwrap();
        let err = client.call(dead, "echo", b"x", Duration::from_millis(200)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut);
    }

    #[test]
    fn rpc_survives_packet_loss() {
        let ep = GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap();
        let addr = ep.local_addr();
        let mut handlers: HashMap<String, Handler> = HashMap::new();
        handlers.insert("echo".into(), Box::new(|b: &[u8]| b.to_vec()));
        let _srv = RpcServer::start(ep, handlers);
        let cep = GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap();
        cep.set_fault(FaultSpec { drop_every: 4, dup_every: 0, reorder_every: 0 });
        let client = RpcClient::new(cep);
        for i in 0..20 {
            let msg = format!("m{i}");
            let out = client.call(addr, "echo", msg.as_bytes(), Duration::from_secs(3)).unwrap();
            assert_eq!(out, msg.as_bytes());
        }
    }

    #[test]
    fn span_log_records_calls_and_dispatches() {
        let ep = GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap();
        let addr = ep.local_addr();
        let mut handlers: HashMap<String, Handler> = HashMap::new();
        handlers.insert("echo".into(), Box::new(|b: &[u8]| b.to_vec()));
        let server_log = WallSpanLog::new();
        let _srv = RpcServer::start_traced(ep, handlers, Some(server_log.clone()));
        let client_log = WallSpanLog::new();
        let client =
            RpcClient::new(GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap())
                .with_span_log(client_log.clone());
        client.call(addr, "echo", b"ping", Duration::from_secs(2)).unwrap();
        client.call(addr, "nope", b"", Duration::from_secs(2)).unwrap_err();
        let calls = client_log.snapshot();
        assert_eq!(calls.len(), 2);
        assert_eq!(calls[0].name, "rpc.call:echo");
        assert!(calls[0].ok);
        assert_eq!(calls[1].name, "rpc.call:nope");
        assert!(!calls[1].ok);
        // The server saw both dispatches; the unknown method is ok=false.
        // (Faulty-transport retransmits can duplicate dispatches, so
        // check membership rather than exact count.)
        let serves = server_log.snapshot();
        assert!(serves.iter().any(|s| s.name == "rpc.serve:echo" && s.ok));
        assert!(serves.iter().any(|s| s.name == "rpc.serve:nope" && !s.ok));
    }

    #[test]
    fn large_rpc_payload() {
        let (_srv, addr) = echo_server();
        let client =
            RpcClient::new(GmpEndpoint::bind("127.0.0.1:0", GmpConfig::default()).unwrap());
        let big: Vec<u8> = (0..50_000u32).map(|i| i as u8).collect();
        let out = client.call(addr, "echo", &big, Duration::from_secs(5)).unwrap();
        assert_eq!(out, big);
    }
}

//! The GMP protocol engine: one UDP socket, a receiver thread, reliable
//! exactly-once datagram messaging, and a windowed fragment stream for
//! large messages.

use std::collections::{HashMap, HashSet, VecDeque};
use std::net::{SocketAddr, UdpSocket};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::wire::{Kind, Packet, MAX_DATAGRAM_PAYLOAD};

/// Tunables.
#[derive(Debug, Clone)]
pub struct GmpConfig {
    /// Retransmission timeout per attempt.
    pub rto: Duration,
    /// Attempts before giving up.
    pub max_retries: u32,
    /// Outstanding fragments per large-message window.
    pub window: usize,
    /// Remembered (session, seq) pairs per peer for dedup.
    pub dedup_capacity: usize,
}

impl Default for GmpConfig {
    fn default() -> Self {
        GmpConfig {
            rto: Duration::from_millis(40),
            max_retries: 8,
            window: 64,
            dedup_capacity: 4096,
        }
    }
}

/// Outgoing fault injection for tests: drop/duplicate/reorder events are
/// driven by a deterministic counter pattern (no RNG in the hot path).
#[derive(Debug, Clone, Default)]
pub struct FaultSpec {
    /// Drop every n-th outgoing packet (0 = never).
    pub drop_every: u32,
    /// Duplicate every n-th outgoing packet (0 = never).
    pub dup_every: u32,
    /// Hold back every n-th outgoing packet and release it after the
    /// *next* send — pairwise reordering (0 = never). A held packet that
    /// never sees a successor stays unsent, exactly like a datagram lost
    /// in a reordering queue; the retransmit path must recover it.
    pub reorder_every: u32,
}

struct PeerState {
    /// Recently delivered (session, seq), for dedup.
    seen: HashSet<(u32, u32)>,
    order: VecDeque<(u32, u32)>,
    /// Partially reassembled large messages: msg seq → (total, chunks).
    partial: HashMap<u32, (u32, HashMap<u32, Vec<u8>>)>,
    /// Large-message ids already delivered (suppress late fragments).
    delivered_msgs: HashSet<u32>,
}

impl PeerState {
    fn new() -> Self {
        PeerState {
            seen: HashSet::new(),
            order: VecDeque::new(),
            partial: HashMap::new(),
            delivered_msgs: HashSet::new(),
        }
    }

    fn remember(&mut self, key: (u32, u32), cap: usize) {
        if self.seen.insert(key) {
            self.order.push_back(key);
            while self.order.len() > cap {
                let old = self.order.pop_front().unwrap();
                self.seen.remove(&old);
            }
        }
    }
}

struct Shared {
    /// Acks received, keyed by (peer, seq).
    acks: Mutex<HashSet<(SocketAddr, u32)>>,
    ack_cv: Condvar,
    peers: Mutex<HashMap<SocketAddr, PeerState>>,
    inbox_tx: Mutex<Sender<(SocketAddr, Vec<u8>)>>,
    stats: Stats,
}

#[derive(Default)]
struct Stats {
    sent: AtomicU32,
    retransmits: AtomicU32,
    delivered: AtomicU32,
    dup_suppressed: AtomicU32,
}

/// A GMP endpoint bound to one UDP port.
pub struct GmpEndpoint {
    socket: UdpSocket,
    session: u32,
    next_seq: AtomicU32,
    cfg: GmpConfig,
    shared: Arc<Shared>,
    inbox: Mutex<Receiver<(SocketAddr, Vec<u8>)>>,
    fault: Mutex<FaultSpec>,
    fault_counter: AtomicU32,
    /// A packet held back by reorder fault injection, released after the
    /// next send.
    held: Mutex<Option<(Vec<u8>, SocketAddr)>>,
    stop: Arc<AtomicBool>,
    rx_thread: Option<std::thread::JoinHandle<()>>,
}

impl GmpEndpoint {
    /// Bind to `addr` (use port 0 for ephemeral) and start the receiver.
    pub fn bind(addr: &str, cfg: GmpConfig) -> std::io::Result<Arc<GmpEndpoint>> {
        let socket = UdpSocket::bind(addr)?;
        socket.set_read_timeout(Some(Duration::from_millis(20)))?;
        // Session id: process-unique (the paper: a restarted process gets
        // a new session so stale dedup state cannot swallow its messages).
        static SESSION_COUNTER: AtomicU32 = AtomicU32::new(1);
        let pid_part = std::process::id();
        let session = pid_part
            .wrapping_mul(2654435761)
            .wrapping_add(SESSION_COUNTER.fetch_add(1, Ordering::Relaxed));
        let (tx, rx) = std::sync::mpsc::channel();
        let shared = Arc::new(Shared {
            acks: Mutex::new(HashSet::new()),
            ack_cv: Condvar::new(),
            peers: Mutex::new(HashMap::new()),
            inbox_tx: Mutex::new(tx),
            stats: Stats::default(),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let mut ep = GmpEndpoint {
            socket: socket.try_clone()?,
            session,
            next_seq: AtomicU32::new(1),
            cfg: cfg.clone(),
            shared: shared.clone(),
            inbox: Mutex::new(rx),
            fault: Mutex::new(FaultSpec::default()),
            fault_counter: AtomicU32::new(0),
            held: Mutex::new(None),
            stop: stop.clone(),
            rx_thread: None,
        };
        let rx_sock = socket;
        let handle = std::thread::spawn(move || Self::rx_loop(rx_sock, shared, stop, cfg));
        ep.rx_thread = Some(handle);
        Ok(Arc::new(ep))
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.socket.local_addr().expect("bound socket")
    }

    pub fn session(&self) -> u32 {
        self.session
    }

    /// Install outgoing fault injection (tests).
    pub fn set_fault(&self, f: FaultSpec) {
        *self.fault.lock().unwrap() = f;
    }

    /// (sent, retransmits, delivered, duplicates suppressed)
    pub fn stats(&self) -> (u32, u32, u32, u32) {
        let s = &self.shared.stats;
        (
            s.sent.load(Ordering::Relaxed),
            s.retransmits.load(Ordering::Relaxed),
            s.delivered.load(Ordering::Relaxed),
            s.dup_suppressed.load(Ordering::Relaxed),
        )
    }

    fn faulty_send(&self, buf: &[u8], to: SocketAddr) {
        let f = self.fault.lock().unwrap().clone();
        let n = self.fault_counter.fetch_add(1, Ordering::Relaxed) + 1;
        let drop = f.drop_every != 0 && n % f.drop_every == 0;
        let dup = f.dup_every != 0 && n % f.dup_every == 0;
        let reorder = f.reorder_every != 0 && n % f.reorder_every == 0;
        if reorder && !drop {
            // Hold this packet back; it goes out *after* the next send.
            let prev = self.held.lock().unwrap().replace((buf.to_vec(), to));
            // Two consecutive reorder triggers: release the older one so
            // at most one packet is ever in the queue.
            if let Some((b, t)) = prev {
                let _ = self.socket.send_to(&b, t);
            }
        } else if !drop {
            let _ = self.socket.send_to(buf, to);
            if dup {
                let _ = self.socket.send_to(buf, to);
            }
            if let Some((b, t)) = self.held.lock().unwrap().take() {
                let _ = self.socket.send_to(&b, t);
            }
        }
        self.shared.stats.sent.fetch_add(1, Ordering::Relaxed);
    }

    /// Reliably send one packet and wait for its ack.
    fn send_reliable(&self, pkt: &Packet, to: SocketAddr) -> std::io::Result<()> {
        let buf = pkt.encode();
        let key = (to, pkt.seq);
        for attempt in 0..self.cfg.max_retries {
            if attempt > 0 {
                self.shared.stats.retransmits.fetch_add(1, Ordering::Relaxed);
            }
            self.faulty_send(&buf, to);
            // Wait for the ack under the condvar.
            let deadline = Instant::now() + self.cfg.rto; // simlint: allow(SIM002) — real UDP retransmit deadline, outside simulated time
            let mut acks = self.shared.acks.lock().unwrap();
            loop {
                if acks.remove(&key) {
                    return Ok(());
                }
                let now = Instant::now(); // simlint: allow(SIM002) — real UDP retransmit deadline, outside simulated time
                if now >= deadline {
                    break;
                }
                let (guard, _t) = self.shared.ack_cv.wait_timeout(acks, deadline - now).unwrap();
                acks = guard;
            }
        }
        Err(std::io::Error::new(
            std::io::ErrorKind::TimedOut,
            format!("no ack from {to} for seq {}", pkt.seq),
        ))
    }

    /// Send a message reliably with exactly-once delivery. Small messages
    /// go as one datagram; large ones through the windowed fragment
    /// stream (the paper's "UDT connection" fallback).
    pub fn send(&self, to: SocketAddr, msg: &[u8]) -> std::io::Result<()> {
        if msg.len() <= MAX_DATAGRAM_PAYLOAD {
            let pkt = Packet {
                kind: Kind::Data,
                session: self.session,
                seq: self.next_seq.fetch_add(1, Ordering::Relaxed),
                arg: 0,
                payload: msg.to_vec(),
            };
            return self.send_reliable(&pkt, to);
        }
        self.send_large(to, msg)
    }

    /// Windowed reliable fragment stream: all fragments share the message
    /// seq in `seq` and carry their index in `arg`; each fragment is
    /// individually acked (selective repeat, window-bounded).
    fn send_large(&self, to: SocketAddr, msg: &[u8]) -> std::io::Result<()> {
        let msg_seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let chunks: Vec<&[u8]> = msg.chunks(MAX_DATAGRAM_PAYLOAD).collect();
        let total = chunks.len() as u32;
        let mut unacked: VecDeque<u32> = (0..total).collect();
        let frag = |idx: u32| -> Packet {
            let mut payload = Vec::with_capacity(chunks[idx as usize].len() + 4);
            payload.extend_from_slice(&total.to_le_bytes());
            payload.extend_from_slice(chunks[idx as usize]);
            Packet { kind: Kind::Frag, session: self.session, seq: msg_seq, arg: idx, payload }
        };
        let mut retries = 0;
        while !unacked.is_empty() {
            // Launch up to `window` outstanding fragments.
            let batch: Vec<u32> = unacked.iter().copied().take(self.cfg.window).collect();
            for &idx in &batch {
                self.faulty_send(&frag(idx).encode(), to);
            }
            // Collect acks until timeout. Frag acks use seq = msg_seq and
            // we track them per fragment via the composite ack key
            // (to, msg_seq ^ (idx.rotate_left(16))) — see rx_loop.
            let deadline = Instant::now() + self.cfg.rto; // simlint: allow(SIM002) — real UDP retransmit deadline, outside simulated time
            loop {
                let mut acks = self.shared.acks.lock().unwrap();
                unacked.retain(|&idx| !acks.remove(&(to, frag_ack_key(msg_seq, idx))));
                if unacked.is_empty() {
                    return Ok(());
                }
                let now = Instant::now(); // simlint: allow(SIM002) — real UDP retransmit deadline, outside simulated time
                if now >= deadline {
                    break;
                }
                let (_guard, timeout) =
                    self.shared.ack_cv.wait_timeout(acks, deadline - now).unwrap();
                if timeout.timed_out() {
                    break;
                }
            }
            retries += 1;
            if retries > self.cfg.max_retries * total.max(4) {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    format!(
                        "large message to {to} stalled with {} fragments unacked",
                        unacked.len()
                    ),
                ));
            }
            self.shared.stats.retransmits.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Blocking receive with timeout. Returns (sender, message).
    pub fn recv_timeout(&self, timeout: Duration) -> Option<(SocketAddr, Vec<u8>)> {
        self.inbox.lock().unwrap().recv_timeout(timeout).ok()
    }

    fn rx_loop(socket: UdpSocket, shared: Arc<Shared>, stop: Arc<AtomicBool>, cfg: GmpConfig) {
        let mut buf = vec![0u8; 65536];
        while !stop.load(Ordering::Relaxed) {
            let (n, from) = match socket.recv_from(&mut buf) {
                Ok(x) => x,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    continue
                }
                Err(_) => break,
            };
            let Ok(pkt) = Packet::decode(&buf[..n]) else { continue };
            match pkt.kind {
                Kind::Ack => {
                    let key = if pkt.arg == u32::MAX {
                        (from, pkt.seq)
                    } else {
                        (from, frag_ack_key(pkt.seq, pkt.arg))
                    };
                    shared.acks.lock().unwrap().insert(key);
                    shared.ack_cv.notify_all();
                }
                Kind::Data => {
                    // Ack unconditionally (the sender may have missed one).
                    let mut ack = Packet::ack(pkt.session, pkt.seq);
                    ack.arg = u32::MAX;
                    let _ = socket.send_to(&ack.encode(), from);
                    let mut peers = shared.peers.lock().unwrap();
                    let peer = peers.entry(from).or_insert_with(PeerState::new);
                    let key = (pkt.session, pkt.seq);
                    if peer.seen.contains(&key) {
                        shared.stats.dup_suppressed.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    peer.remember(key, cfg.dedup_capacity);
                    shared.stats.delivered.fetch_add(1, Ordering::Relaxed);
                    let _ = shared.inbox_tx.lock().unwrap().send((from, pkt.payload));
                }
                Kind::Frag => {
                    let mut ack = Packet::ack(pkt.session, pkt.seq);
                    ack.arg = pkt.arg;
                    let _ = socket.send_to(&ack.encode(), from);
                    if pkt.payload.len() < 4 {
                        continue;
                    }
                    let total = u32::from_le_bytes(pkt.payload[0..4].try_into().unwrap());
                    let chunk = pkt.payload[4..].to_vec();
                    let mut peers = shared.peers.lock().unwrap();
                    let peer = peers.entry(from).or_insert_with(PeerState::new);
                    let msg_key = pkt.seq ^ pkt.session;
                    if peer.delivered_msgs.contains(&msg_key) {
                        shared.stats.dup_suppressed.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    let entry =
                        peer.partial.entry(msg_key).or_insert_with(|| (total, HashMap::new()));
                    entry.1.insert(pkt.arg, chunk);
                    if entry.1.len() as u32 == entry.0 {
                        // Complete: reassemble in index order.
                        let (total, mut chunks) = peer.partial.remove(&msg_key).unwrap();
                        let mut msg = Vec::new();
                        for i in 0..total {
                            msg.extend_from_slice(&chunks.remove(&i).unwrap());
                        }
                        peer.delivered_msgs.insert(msg_key);
                        shared.stats.delivered.fetch_add(1, Ordering::Relaxed);
                        let _ = shared.inbox_tx.lock().unwrap().send((from, msg));
                    }
                }
            }
        }
    }
}

/// Composite ack key for fragment acks (distinct from plain Data acks,
/// which use `arg == u32::MAX`).
fn frag_ack_key(msg_seq: u32, idx: u32) -> u32 {
    msg_seq.wrapping_mul(2654435761) ^ idx.rotate_left(16) ^ 0x5A5A5A5A
}

impl Drop for GmpEndpoint {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.rx_thread.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair(cfg: GmpConfig) -> (Arc<GmpEndpoint>, Arc<GmpEndpoint>) {
        let a = GmpEndpoint::bind("127.0.0.1:0", cfg.clone()).unwrap();
        let b = GmpEndpoint::bind("127.0.0.1:0", cfg).unwrap();
        (a, b)
    }

    #[test]
    fn small_message_delivery() {
        let (a, b) = pair(GmpConfig::default());
        a.send(b.local_addr(), b"ping").unwrap();
        let (from, msg) = b.recv_timeout(Duration::from_secs(2)).expect("delivery");
        assert_eq!(msg, b"ping");
        assert_eq!(from, a.local_addr());
    }

    #[test]
    fn exactly_once_under_drops_and_dups() {
        let (a, b) = pair(GmpConfig::default());
        // Drop every 3rd outgoing packet and duplicate every 4th.
        a.set_fault(FaultSpec { drop_every: 3, dup_every: 4 });
        let n = 50;
        for i in 0..n {
            a.send(b.local_addr(), format!("msg-{i}").as_bytes()).unwrap();
        }
        let mut got = Vec::new();
        while let Some((_, m)) = b.recv_timeout(Duration::from_millis(300)) {
            got.push(String::from_utf8(m).unwrap());
        }
        // Exactly once: all n present, none twice (order may vary).
        got.sort();
        let mut want: Vec<String> = (0..n).map(|i| format!("msg-{i}")).collect();
        want.sort();
        assert_eq!(got, want);
        let (_, retx, _, dups) = a.stats();
        assert!(retx > 0, "fault injection never triggered a retransmit");
        let _ = dups;
    }

    #[test]
    fn exactly_once_under_reordering() {
        let (a, b) = pair(GmpConfig::default());
        // Every 3rd packet is held back and released after its successor:
        // persistent pairwise reordering on the wire.
        a.set_fault(FaultSpec { reorder_every: 3, ..Default::default() });
        let n = 40;
        for i in 0..n {
            a.send(b.local_addr(), format!("r-{i}").as_bytes()).unwrap();
        }
        let mut got = Vec::new();
        while let Some((_, m)) = b.recv_timeout(Duration::from_millis(300)) {
            got.push(String::from_utf8(m).unwrap());
        }
        got.sort();
        let mut want: Vec<String> = (0..n).map(|i| format!("r-{i}")).collect();
        want.sort();
        assert_eq!(got, want, "reordering lost or duplicated a message");
    }

    #[test]
    fn exactly_once_under_drop_dup_and_reorder_combined() {
        let (a, b) = pair(GmpConfig::default());
        a.set_fault(FaultSpec { drop_every: 5, dup_every: 3, reorder_every: 4 });
        let n = 60;
        for i in 0..n {
            a.send(b.local_addr(), format!("m-{i}").as_bytes()).unwrap();
        }
        let mut got = Vec::new();
        while let Some((_, m)) = b.recv_timeout(Duration::from_millis(300)) {
            got.push(String::from_utf8(m).unwrap());
        }
        got.sort();
        let mut want: Vec<String> = (0..n).map(|i| format!("m-{i}")).collect();
        want.sort();
        assert_eq!(got, want);
        let (_, retx, _, _) = a.stats();
        assert!(retx > 0, "drops never forced a retransmit");
    }

    #[test]
    fn large_message_survives_reordering() {
        let (a, b) = pair(GmpConfig { rto: Duration::from_millis(30), ..Default::default() });
        a.set_fault(FaultSpec { reorder_every: 2, ..Default::default() });
        // Multi-fragment message with every other fragment swapped on the
        // wire: reassembly is by fragment index, so the payload must come
        // back intact.
        let msg: Vec<u8> = (0..60_000u32).map(|i| (i.wrapping_mul(31)) as u8).collect();
        a.send(b.local_addr(), &msg).unwrap();
        let (_, got) = b.recv_timeout(Duration::from_secs(5)).expect("delivery under reordering");
        assert_eq!(got, msg);
    }

    #[test]
    fn large_message_roundtrip() {
        let (a, b) = pair(GmpConfig::default());
        // ~300 KiB: hundreds of fragments through the windowed stream.
        let msg: Vec<u8> = (0..300_000u32).map(|i| (i * 2654435761) as u8).collect();
        a.send(b.local_addr(), &msg).unwrap();
        let (_, got) = b.recv_timeout(Duration::from_secs(5)).expect("large delivery");
        assert_eq!(got.len(), msg.len());
        assert_eq!(got, msg, "fragment reassembly corrupted the payload");
    }

    #[test]
    fn large_message_survives_loss() {
        let (a, b) = pair(GmpConfig { rto: Duration::from_millis(30), ..Default::default() });
        a.set_fault(FaultSpec { drop_every: 7, dup_every: 0 });
        let msg: Vec<u8> = (0..100_000u32).map(|i| (i ^ (i >> 8)) as u8).collect();
        a.send(b.local_addr(), &msg).unwrap();
        let (_, got) = b.recv_timeout(Duration::from_secs(5)).expect("delivery under loss");
        assert_eq!(got, msg);
        let (_, retx, _, _) = a.stats();
        assert!(retx > 0);
    }

    #[test]
    fn concurrent_senders_all_delivered() {
        let cfg = GmpConfig::default();
        let b = GmpEndpoint::bind("127.0.0.1:0", cfg.clone()).unwrap();
        let addr = b.local_addr();
        let mut handles = Vec::new();
        for t in 0..4 {
            let cfg = cfg.clone();
            handles.push(std::thread::spawn(move || {
                let a = GmpEndpoint::bind("127.0.0.1:0", cfg).unwrap();
                for i in 0..20 {
                    a.send(addr, format!("t{t}-{i}").as_bytes()).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut got = 0;
        while b.recv_timeout(Duration::from_millis(300)).is_some() {
            got += 1;
        }
        assert_eq!(got, 80);
    }

    #[test]
    fn unreachable_peer_times_out() {
        let a = GmpEndpoint::bind(
            "127.0.0.1:0",
            GmpConfig { rto: Duration::from_millis(10), max_retries: 2, ..Default::default() },
        )
        .unwrap();
        // A port with (almost certainly) no listener.
        let dead: SocketAddr = "127.0.0.1:9".parse().unwrap();
        let err = a.send(dead, b"hello").unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut);
    }

    #[test]
    fn sessions_differ_between_endpoints() {
        let (a, b) = pair(GmpConfig::default());
        assert_ne!(a.session(), b.session());
    }
}

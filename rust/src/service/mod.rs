//! Open-loop, trace-driven service traffic: deterministic load
//! generation, request routing, and SLO accounting.
//!
//! Everything the batch workloads lack lives here. A [`LoadGen`] derives
//! each site's arrival sequence from a per-site [`crate::util::rng::Rng`]
//! stream forked off one fixed master seed, so the sequence is a pure
//! function of the site index — the property the sharded driver's
//! bit-identity rests on. Arrivals are *open-loop*: users issue requests
//! on their own clock (constant, diurnal, or flash-crowd phases), never
//! waiting for earlier responses, so an overloaded replica builds real
//! queueing delay instead of throttling its own offered load.
//!
//! The simulation side (flows, shards, engines) stays in
//! [`crate::coordinator::runner`]; this module owns the deterministic
//! *plan* (who asks what, when, of which replica) and the *accounting*
//! ([`SiteAccum`] per-request latency into allocation-free
//! [`crate::monitor::Series`] windows, rolled up into a
//! [`ServiceReport`] inside report byte-identity).

use std::collections::BTreeMap;

use crate::monitor::Series;
use crate::net::{NodeId, Topology};
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;
use crate::util::stats::percentile_sorted;

/// Master seed of every service load stream; per-site streams are forked
/// from a fresh copy so each is a pure function of the site index.
pub const SERVICE_SEED: u64 = 0x0C7_5E44;

/// Retained per-site latency window (samples). Quantiles are computed
/// over this trailing window; at registry scales every request fits.
pub const SERVICE_SERIES_CAP: usize = 1 << 16;

/// Arrival-histogram resolution: the run's duration is split into this
/// many equal bins to measure offered-load peakedness.
pub const ARRIVAL_BINS: usize = 100;

/// One-way extra delay (seconds) a degraded WAN path adds to each leg of
/// a cross-site request touching the degraded site — the "replica behind
/// a sick wave" axis of the registry's service set.
pub const DEGRADED_WAN_PENALTY_SECS: f64 = 0.4;

/// How a request picks its replica site.
#[derive(Debug, Clone, PartialEq)]
pub enum RoutePolicy {
    /// The user's own site when replicated there, else the replica with
    /// the lowest site-to-site RTT (ties broken by lowest replica index).
    Nearest,
    /// Weighted draw over the replica sites; weights align with
    /// [`ServiceSpec::replica_sites`] and need not be normalized.
    Weighted(Vec<f64>),
    /// Uniform draw over the replica sites.
    Random,
}

impl RoutePolicy {
    /// Resolve the replica site for a request from `user_site`. `u` is
    /// the request's routing draw in `[0, 1)`; `site_rtt[a][b]` is the
    /// site-to-site RTT matrix. Always called (and `u` always drawn)
    /// regardless of policy, so the per-request draw count is fixed.
    pub fn route(&self, user_site: u32, u: f64, replicas: &[u32], site_rtt: &[Vec<f64>]) -> u32 {
        assert!(!replicas.is_empty(), "routing with no replicas");
        match self {
            RoutePolicy::Nearest => {
                if replicas.contains(&user_site) {
                    return user_site;
                }
                let mut best = replicas[0];
                let mut best_rtt = site_rtt[user_site as usize][replicas[0] as usize];
                for &r in &replicas[1..] {
                    let rtt = site_rtt[user_site as usize][r as usize];
                    if rtt < best_rtt {
                        best = r;
                        best_rtt = rtt;
                    }
                }
                best
            }
            RoutePolicy::Weighted(w) => {
                assert_eq!(w.len(), replicas.len(), "weights must align with replica_sites");
                let total: f64 = w.iter().sum();
                assert!(total > 0.0, "weights must sum positive");
                let target = u * total;
                let mut acc = 0.0;
                for (&r, &wi) in replicas.iter().zip(w) {
                    acc += wi;
                    if target < acc {
                        return r;
                    }
                }
                replicas[replicas.len() - 1]
            }
            RoutePolicy::Random => {
                let i = ((u * replicas.len() as f64) as usize).min(replicas.len() - 1);
                replicas[i]
            }
        }
    }
}

/// The intensity shape of one arrival phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PhaseShape {
    /// Uniform rate across the phase.
    Constant,
    /// A day-curve: rate ∝ 1 − 0.8·cos(2πx) over the phase — a deep
    /// night trough and an afternoon peak.
    Diurnal,
}

/// One phase of the arrival process: a `frac` share of the run's
/// duration at `rate_mult` × the base rate, shaped by `shape`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Phase {
    /// Share of the run's duration this phase spans (fracs sum to 1).
    pub frac: f64,
    /// Rate multiplier relative to the base rate.
    pub rate_mult: f64,
    pub shape: PhaseShape,
}

impl Phase {
    pub fn constant(frac: f64, rate_mult: f64) -> Phase {
        Phase { frac, rate_mult, shape: PhaseShape::Constant }
    }

    pub fn diurnal(frac: f64, rate_mult: f64) -> Phase {
        Phase { frac, rate_mult, shape: PhaseShape::Diurnal }
    }
}

/// One full simulated day as a single diurnal phase.
pub fn diurnal_phases() -> Vec<Phase> {
    vec![Phase::diurnal(1.0, 1.0)]
}

/// A flash crowd: steady load, an 8× burst over the middle tenth of the
/// run, steady again.
pub fn flash_crowd_phases() -> Vec<Phase> {
    vec![Phase::constant(0.45, 1.0), Phase::constant(0.1, 8.0), Phase::constant(0.45, 1.0)]
}

/// The service-traffic axis of a scenario: where the service's replicas
/// live, how requests route to them, and the shape of the offered load.
/// The workload's record count is reinterpreted as the total request
/// count; the run's duration is `total_requests / rate_rps`, so scaling
/// the workload down shrinks the run while preserving the offered rate
/// (and therefore the contention) at every scale.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceSpec {
    /// Sites hosting a replica of the service.
    pub replica_sites: Vec<u32>,
    pub policy: RoutePolicy,
    /// Arrival phases (fracs sum to 1). Empty is invalid.
    pub phases: Vec<Phase>,
    /// Aggregate offered rate across all sites, requests/second.
    pub rate_rps: f64,
    /// Mean server service time, seconds (exponentially distributed).
    pub service_time_secs: f64,
    /// GMP-framed request size, bytes.
    pub request_bytes: f64,
    /// Response payload, bytes.
    pub response_bytes: f64,
    /// Latency objective: completions above it count as SLO violations.
    pub slo_secs: f64,
    /// Client timeout: a first completion above it counts as a timeout
    /// and triggers exactly one retry (retried completions never re-arm).
    pub timeout_secs: f64,
    /// `Some(site)` adds [`DEGRADED_WAN_PENALTY_SECS`] to each WAN leg
    /// of any cross-site request touching that site.
    pub degraded_wan_site: Option<u32>,
}

impl ServiceSpec {
    /// A steady-state spec with the default knobs: constant arrivals at
    /// 2000 req/s, 20 ms mean service time, 2 kB requests, 100 kB
    /// responses, a 250 ms SLO, and a 1 s client timeout.
    pub fn new(replica_sites: Vec<u32>, policy: RoutePolicy) -> ServiceSpec {
        ServiceSpec {
            replica_sites,
            policy,
            phases: vec![Phase::constant(1.0, 1.0)],
            rate_rps: 2000.0,
            service_time_secs: 0.02,
            request_bytes: 2e3,
            response_bytes: 1e5,
            slo_secs: 0.25,
            timeout_secs: 1.0,
            degraded_wan_site: None,
        }
    }

    /// Validate against a testbed of `num_sites` sites (panics on a
    /// malformed spec — specs are authored, not user input).
    pub fn validate(&self, num_sites: usize) {
        assert!(!self.replica_sites.is_empty(), "service needs at least one replica site");
        for &r in &self.replica_sites {
            assert!((r as usize) < num_sites, "replica site {r} outside the topology");
        }
        assert!(!self.phases.is_empty(), "service needs at least one arrival phase");
        let frac_sum: f64 = self.phases.iter().map(|p| p.frac).sum();
        assert!((frac_sum - 1.0).abs() < 1e-9, "phase fracs sum to {frac_sum}, not 1");
        for p in &self.phases {
            assert!(p.frac > 0.0 && p.rate_mult > 0.0, "degenerate phase {p:?}");
        }
        assert!(self.rate_rps > 0.0, "offered rate must be positive");
        assert!(self.service_time_secs > 0.0, "service time must be positive");
        assert!(self.slo_secs > 0.0 && self.timeout_secs > 0.0, "SLO/timeout must be positive");
        if let RoutePolicy::Weighted(w) = &self.policy {
            assert_eq!(w.len(), self.replica_sites.len(), "weights align with replica_sites");
        }
    }
}

/// One planned request: when it arrives, who issues it, which replica
/// serves it, and its pre-drawn randomness. Everything downstream (pair
/// choice, gateway choice, flow sizes) is a pure function of these
/// fields, so the plan fully determines the run.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Arrival time, seconds from run start.
    pub t: f64,
    /// Dense per-site request index (0..site budget).
    pub id: u64,
    pub user_site: u32,
    /// Replica site chosen by the route policy.
    pub replica: u32,
    /// Uniform draw in `[0, 1)` mapping to an intra-site user/replica
    /// pair slot.
    pub pair_u: f64,
    /// Server service time, seconds (drawn from the site stream).
    pub service: f64,
}

/// CDF of the diurnal rate curve `1 − 0.8·cos(2πx)` on `[0, 1]`.
fn diurnal_cdf(y: f64) -> f64 {
    y - 0.8 / (2.0 * std::f64::consts::PI) * (2.0 * std::f64::consts::PI * y).sin()
}

/// Inverse-CDF sample of the diurnal curve by bisection (48 halvings:
/// deterministic and exact to ~4e-15, with no lookup-table state).
fn diurnal_inverse(x: f64) -> f64 {
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    for _ in 0..48 {
        let mid = 0.5 * (lo + hi);
        if diurnal_cdf(mid) < x {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// Split `total` across `weights` proportionally with the
/// largest-remainder rule (ties to the lowest index) — deterministic,
/// and the shares sum to `total` exactly.
fn largest_remainder(total: u64, weights: &[f64]) -> Vec<u64> {
    let wsum: f64 = weights.iter().sum();
    assert!(wsum > 0.0, "weights must sum positive");
    let ideal: Vec<f64> = weights.iter().map(|w| total as f64 * w / wsum).collect();
    let mut shares: Vec<u64> = ideal.iter().map(|x| x.floor() as u64).collect();
    let assigned: u64 = shares.iter().sum();
    let mut order: Vec<usize> = (0..weights.len()).collect();
    // Stable sort by descending fractional remainder keeps index order
    // among ties.
    order.sort_by(|&a, &b| {
        let (ra, rb) = (ideal[a] - ideal[a].floor(), ideal[b] - ideal[b].floor());
        rb.partial_cmp(&ra).unwrap()
    });
    for &i in order.iter().take((total - assigned) as usize) {
        shares[i] += 1;
    }
    shares
}

/// The deterministic open-loop load generator: plans every site's
/// request sequence up front from that site's forked RNG stream.
#[derive(Debug, Clone)]
pub struct LoadGen {
    spec: ServiceSpec,
    total: u64,
    num_sites: usize,
    /// Site-to-site RTT matrix, seconds (what `Nearest` routing ranks).
    site_rtt: Vec<Vec<f64>>,
}

impl LoadGen {
    /// Plan `total` requests against `spec` on a testbed whose
    /// site-to-site RTTs are `site_rtt` (square, `num_sites` × same).
    pub fn new(spec: ServiceSpec, total: u64, site_rtt: Vec<Vec<f64>>) -> LoadGen {
        let num_sites = site_rtt.len();
        assert!(num_sites > 0, "load generation needs at least one site");
        assert!(total > 0, "load generation needs at least one request");
        for row in &site_rtt {
            assert_eq!(row.len(), num_sites, "site_rtt must be square");
        }
        spec.validate(num_sites);
        LoadGen { spec, total, num_sites, site_rtt }
    }

    /// Build the RTT matrix of a topology from each site's first node
    /// (intra-site distances are uniform at the fidelity routing needs).
    pub fn site_rtt_matrix(topo: &Topology) -> Vec<Vec<f64>> {
        let firsts: Vec<NodeId> =
            topo.sites.iter().map(|s| topo.racks[s.racks[0].0].nodes[0]).collect();
        firsts.iter().map(|&a| firsts.iter().map(|&b| topo.rtt(a, b)).collect()).collect()
    }

    pub fn spec(&self) -> &ServiceSpec {
        &self.spec
    }

    /// Run duration, seconds: `total / rate_rps` — scale-invariant rate.
    pub fn duration(&self) -> f64 {
        self.total as f64 / self.spec.rate_rps
    }

    /// Requests issued from `site` (the total split evenly, remainder to
    /// the lowest site indices).
    pub fn site_budget(&self, site: u32) -> u64 {
        let n = self.num_sites as u64;
        self.total / n + u64::from((site as u64) < self.total % n)
    }

    /// `[start, end)` of every phase, in run seconds.
    pub fn phase_bounds(&self) -> Vec<(f64, f64)> {
        let d = self.duration();
        let mut t0 = 0.0;
        self.spec
            .phases
            .iter()
            .map(|p| {
                let t1 = t0 + p.frac * d;
                let span = (t0, t1);
                t0 = t1;
                span
            })
            .collect()
    }

    /// Per-phase request counts for a site issuing `site_total` requests:
    /// largest-remainder over the phases' `frac × rate_mult` weights.
    pub fn phase_budgets(&self, site_total: u64) -> Vec<u64> {
        let weights: Vec<f64> = self.spec.phases.iter().map(|p| p.frac * p.rate_mult).collect();
        largest_remainder(site_total, &weights)
    }

    /// Plan `site`'s full request sequence. Pure function of the site
    /// index (a fresh master stream is forked per call), so any shard —
    /// or a test — regenerates an identical plan. Arrivals are
    /// stratified within each phase: request `k` of `m` lands in the
    /// phase's `[k/m, (k+1)/m)` sub-interval (mapped through the phase
    /// shape), so timestamps increase and phase boundaries are exact.
    pub fn gen_site(&self, site: u32) -> Vec<Request> {
        assert!((site as usize) < self.num_sites, "site {site} outside the topology");
        let mut rng = Rng::new(SERVICE_SEED).fork(site as u64);
        let budgets = self.phase_budgets(self.site_budget(site));
        let bounds = self.phase_bounds();
        let mut out = Vec::with_capacity(budgets.iter().sum::<u64>() as usize);
        let mut id = 0u64;
        for ((phase, &m), &(t0, t1)) in self.spec.phases.iter().zip(&budgets).zip(&bounds) {
            for k in 0..m {
                // Fixed draw order per request: arrival jitter, pair
                // slot, route, service time.
                let u = rng.f64();
                let x = (k as f64 + u) / m as f64;
                let x = match phase.shape {
                    PhaseShape::Constant => x,
                    PhaseShape::Diurnal => diurnal_inverse(x),
                };
                let t = t0 + x * (t1 - t0);
                let pair_u = rng.f64();
                let route_u = rng.f64();
                let service = rng.exp(self.spec.service_time_secs);
                let replica =
                    self.spec.policy.route(site, route_u, &self.spec.replica_sites, &self.site_rtt);
                out.push(Request { t, id, user_site: site, replica, pair_u, service });
                id += 1;
            }
        }
        out
    }
}

/// The intra-site request plant derived from a placement: same-rack
/// (user, replica) pairs serving local requests on their own NICs, plus
/// a per-site gateway pool carrying cross-site request/response flows
/// over the rack uplinks and the wave. Pair and gateway node sets are
/// disjoint by construction — the property the sharded driver's
/// link-claim partition rests on (mirrors the mega-churn split).
#[derive(Debug, Clone)]
pub struct ServicePlant {
    pub pairs_by_site: Vec<Vec<(NodeId, NodeId)>>,
    pub gateways_by_site: Vec<Vec<NodeId>>,
}

/// Group a placement into per-site pairs and gateway pools: within each
/// rack's placed group, racks with ≥ 4 nodes reserve their last two for
/// the site's gateway pool, the rest pair off, and odd remainders join
/// the pool.
pub fn service_plant(topo: &Topology, nodes: &[NodeId]) -> ServicePlant {
    let num_sites = topo.sites.len();
    let mut by_rack: BTreeMap<usize, Vec<NodeId>> = BTreeMap::new();
    for &n in nodes {
        by_rack.entry(topo.node(n).rack.0).or_default().push(n);
    }
    let mut pairs_by_site = vec![Vec::new(); num_sites];
    let mut gateways_by_site = vec![Vec::new(); num_sites];
    for group in by_rack.values() {
        let site = topo.node(group[0]).site.0;
        let (paired, pooled) =
            if group.len() >= 4 { group.split_at(group.len() - 2) } else { (&group[..], &[][..]) };
        let mut chunks = paired.chunks_exact(2);
        for c in &mut chunks {
            pairs_by_site[site].push((c[0], c[1]));
        }
        gateways_by_site[site].extend(chunks.remainder());
        gateways_by_site[site].extend(pooled);
    }
    ServicePlant { pairs_by_site, gateways_by_site }
}

/// Per-site request accounting, shared verbatim by the sequential and
/// sharded drivers: counters, the arrival histogram, and the trailing
/// latency window feeding the quantile rollups.
#[derive(Debug, Clone)]
pub struct SiteAccum {
    pub site: u32,
    duration: f64,
    /// Requests issued by this site's users (retries not re-counted).
    pub requests: u64,
    /// Completions recorded (originals + retries).
    pub completed: u64,
    /// First completions that exceeded the client timeout.
    pub timeouts: u64,
    /// Retries issued — equal to `timeouts` by construction (one retry
    /// per timed-out request, retried completions never re-arm).
    pub retries: u64,
    /// Completions (originals and retries) above the SLO.
    pub slo_violations: u64,
    /// Trailing latency window, seconds.
    pub latencies: Series,
    /// Arrival histogram over the run's duration.
    pub bins: Vec<u64>,
}

impl SiteAccum {
    pub fn new(site: u32, duration: f64) -> SiteAccum {
        assert!(duration > 0.0);
        SiteAccum {
            site,
            duration,
            requests: 0,
            completed: 0,
            timeouts: 0,
            retries: 0,
            slo_violations: 0,
            latencies: Series::new(SERVICE_SERIES_CAP),
            bins: vec![0; ARRIVAL_BINS],
        }
    }

    /// Record a planned arrival at `t` (originals only, not retries).
    pub fn arrival(&mut self, t: f64) {
        self.requests += 1;
        let bin = ((t / self.duration) * ARRIVAL_BINS as f64) as usize;
        self.bins[bin.min(ARRIVAL_BINS - 1)] += 1;
    }

    /// Record a completion observed at `now` with end-to-end `latency`.
    /// Returns `true` when the completion timed out and the caller owes
    /// exactly one retry (never for already-retried requests).
    pub fn complete(&mut self, now: f64, latency: f64, spec: &ServiceSpec, retried: bool) -> bool {
        self.completed += 1;
        self.latencies.push(now, latency);
        if latency > spec.slo_secs {
            self.slo_violations += 1;
        }
        if !retried && latency > spec.timeout_secs {
            self.timeouts += 1;
            self.retries += 1;
            return true;
        }
        false
    }
}

/// One site's rollup inside a [`ServiceReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct SiteService {
    pub site: u32,
    pub requests: u64,
    pub slo_violations: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
}

/// The service-traffic section of a run report: global and per-site
/// request counts, latency quantiles, and SLO accounting. Inside report
/// equality and serialization — byte-identical across thread counts.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceReport {
    pub requests: u64,
    pub completed: u64,
    pub timeouts: u64,
    pub retries: u64,
    pub slo_violations: u64,
    /// Completions per simulated second over the whole run.
    pub goodput_rps: f64,
    /// Peak arrival-bin rate over the mean rate (≈1 for steady load,
    /// ≫1 for a flash crowd) — offered-load peakedness.
    pub offered_peak_x: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub sites: Vec<SiteService>,
}

impl ServiceReport {
    /// Roll per-site accumulators (in site order) into the report.
    /// Global quantiles run over the concatenation of the per-site
    /// windows in site order, so any driver that fills the same accums
    /// produces the same bytes.
    pub fn assemble(accums: &[SiteAccum], finished_at: f64) -> ServiceReport {
        let mut requests = 0;
        let mut completed = 0;
        let mut timeouts = 0;
        let mut retries = 0;
        let mut slo_violations = 0;
        let mut all: Vec<f64> = Vec::new();
        let mut bins = vec![0u64; ARRIVAL_BINS];
        let sites: Vec<SiteService> = accums
            .iter()
            .map(|a| {
                requests += a.requests;
                completed += a.completed;
                timeouts += a.timeouts;
                retries += a.retries;
                slo_violations += a.slo_violations;
                all.extend(a.latencies.values());
                for (b, &x) in bins.iter_mut().zip(&a.bins) {
                    *b += x;
                }
                SiteService {
                    site: a.site,
                    requests: a.requests,
                    slo_violations: a.slo_violations,
                    p50_ms: a.latencies.p50() * 1e3,
                    p99_ms: a.latencies.p99() * 1e3,
                    p999_ms: a.latencies.p999() * 1e3,
                }
            })
            .collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let peak = bins.iter().copied().max().unwrap_or(0);
        ServiceReport {
            requests,
            completed,
            timeouts,
            retries,
            slo_violations,
            goodput_rps: if finished_at > 0.0 { completed as f64 / finished_at } else { 0.0 },
            offered_peak_x: if requests > 0 {
                peak as f64 * ARRIVAL_BINS as f64 / requests as f64
            } else {
                0.0
            },
            p50_ms: percentile_sorted(&all, 50.0) * 1e3,
            p99_ms: percentile_sorted(&all, 99.0) * 1e3,
            p999_ms: percentile_sorted(&all, 99.9) * 1e3,
            sites,
        }
    }

    /// The flat metric view merged into `RunReport::metrics` (keys are
    /// sorted there with everything else).
    pub fn metrics(&self) -> Vec<(String, f64)> {
        vec![
            ("requests".to_string(), self.requests as f64),
            ("completed".to_string(), self.completed as f64),
            ("timeouts".to_string(), self.timeouts as f64),
            ("retries".to_string(), self.retries as f64),
            ("slo_violations".to_string(), self.slo_violations as f64),
            ("goodput_rps".to_string(), self.goodput_rps),
            ("offered_peak_x".to_string(), self.offered_peak_x),
            ("latency_p50_ms".to_string(), self.p50_ms),
            ("latency_p99_ms".to_string(), self.p99_ms),
            ("latency_p999_ms".to_string(), self.p999_ms),
        ]
    }

    pub fn to_json(&self) -> Json {
        let sites: Vec<Json> = self
            .sites
            .iter()
            .map(|s| {
                obj(vec![
                    ("site", Json::Num(s.site as f64)),
                    ("requests", Json::Num(s.requests as f64)),
                    ("slo_violations", Json::Num(s.slo_violations as f64)),
                    ("p50_ms", Json::Num(s.p50_ms)),
                    ("p99_ms", Json::Num(s.p99_ms)),
                    ("p999_ms", Json::Num(s.p999_ms)),
                ])
            })
            .collect();
        obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("timeouts", Json::Num(self.timeouts as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("slo_violations", Json::Num(self.slo_violations as f64)),
            ("goodput_rps", Json::Num(self.goodput_rps)),
            ("offered_peak_x", Json::Num(self.offered_peak_x)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("p999_ms", Json::Num(self.p999_ms)),
            ("sites", Json::Arr(sites)),
        ])
    }

    /// Parse back from JSON (round-trips [`ServiceReport::to_json`]).
    pub fn from_json(j: &Json) -> Result<ServiceReport, String> {
        fn num(j: &Json, k: &str) -> Result<f64, String> {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing number '{k}'"))
        }
        let sites = match j.get("sites") {
            Some(Json::Arr(xs)) => xs
                .iter()
                .map(|x| {
                    Ok(SiteService {
                        site: num(x, "site")? as u32,
                        requests: num(x, "requests")? as u64,
                        slo_violations: num(x, "slo_violations")? as u64,
                        p50_ms: num(x, "p50_ms")?,
                        p99_ms: num(x, "p99_ms")?,
                        p999_ms: num(x, "p999_ms")?,
                    })
                })
                .collect::<Result<Vec<SiteService>, String>>()?,
            _ => return Err("missing array 'sites'".to_string()),
        };
        Ok(ServiceReport {
            requests: num(j, "requests")? as u64,
            completed: num(j, "completed")? as u64,
            timeouts: num(j, "timeouts")? as u64,
            retries: num(j, "retries")? as u64,
            slo_violations: num(j, "slo_violations")? as u64,
            goodput_rps: num(j, "goodput_rps")?,
            offered_peak_x: num(j, "offered_peak_x")?,
            p50_ms: num(j, "p50_ms")?,
            p99_ms: num(j, "p99_ms")?,
            p999_ms: num(j, "p999_ms")?,
            sites,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::check;

    fn rtt4() -> Vec<Vec<f64>> {
        // Rough OCT shape: sites 1–2 close, 0–3 far apart.
        vec![
            vec![30e-6, 0.020, 0.021, 0.070],
            vec![0.020, 30e-6, 0.002, 0.055],
            vec![0.021, 0.002, 30e-6, 0.050],
            vec![0.070, 0.055, 0.050, 30e-6],
        ]
    }

    fn spec(policy: RoutePolicy) -> ServiceSpec {
        ServiceSpec::new(vec![0, 1, 2, 3], policy)
    }

    #[test]
    fn loadgen_is_reproducible() {
        let lg = LoadGen::new(spec(RoutePolicy::Nearest), 10_000, rtt4());
        for site in 0..4 {
            assert_eq!(lg.gen_site(site), lg.gen_site(site), "site {site} diverged");
        }
        // Distinct sites draw distinct streams.
        assert_ne!(lg.gen_site(0)[0].service, lg.gen_site(1)[0].service);
    }

    #[test]
    fn site_budgets_sum_to_total() {
        let lg = LoadGen::new(spec(RoutePolicy::Random), 10_003, rtt4());
        let sum: u64 = (0..4).map(|s| lg.site_budget(s)).sum();
        assert_eq!(sum, 10_003);
        assert_eq!(lg.site_budget(0), 2501);
        assert_eq!(lg.site_budget(3), 2500);
        assert_eq!(lg.gen_site(0).len(), 2501);
    }

    #[test]
    fn phase_boundaries_are_exact() {
        let mut sp = spec(RoutePolicy::Nearest);
        sp.phases = flash_crowd_phases();
        let lg = LoadGen::new(sp, 8_000, rtt4());
        let bounds = lg.phase_bounds();
        assert_eq!(bounds.len(), 3);
        assert!((bounds[2].1 - lg.duration()).abs() < 1e-9);
        let budgets = lg.phase_budgets(lg.site_budget(1));
        assert_eq!(budgets.iter().sum::<u64>(), lg.site_budget(1));
        // The burst phase carries 0.8/1.7 of the weight in 0.1 of the time.
        assert!(budgets[1] > budgets[0], "burst {} vs steady {}", budgets[1], budgets[0]);
        let reqs = lg.gen_site(1);
        let mut cursor = 0usize;
        for (&m, &(t0, t1)) in budgets.iter().zip(&bounds) {
            let phase = &reqs[cursor..cursor + m as usize];
            assert!(phase.iter().all(|r| r.t >= t0 && r.t < t1), "phase [{t0},{t1}) leaked");
            cursor += m as usize;
        }
        assert_eq!(cursor, reqs.len());
        // Stratified arrivals are nondecreasing across the whole run.
        assert!(reqs.windows(2).all(|w| w[0].t <= w[1].t));
    }

    #[test]
    fn diurnal_inverse_matches_its_cdf() {
        for i in 0..=100 {
            let x = i as f64 / 100.0;
            let y = diurnal_inverse(x);
            assert!((0.0..=1.0).contains(&y));
            assert!((diurnal_cdf(y) - x).abs() < 1e-12, "x={x} y={y}");
        }
        // The curve troughs at x=0: inverting a small mass reaches far
        // into the night (few arrivals near t=0).
        assert!(diurnal_inverse(0.01) > 0.05);
    }

    #[test]
    fn diurnal_phase_respects_bounds_and_order() {
        let mut sp = spec(RoutePolicy::Random);
        sp.phases = diurnal_phases();
        let lg = LoadGen::new(sp, 4_000, rtt4());
        let reqs = lg.gen_site(2);
        let d = lg.duration();
        assert!(reqs.iter().all(|r| r.t >= 0.0 && r.t < d));
        assert!(reqs.windows(2).all(|w| w[0].t <= w[1].t));
        // Day curve: the middle half of the day carries most arrivals.
        let mid = reqs.iter().filter(|r| r.t > 0.25 * d && r.t < 0.75 * d).count();
        assert!(mid * 2 > reqs.len(), "mid-day {} of {}", mid, reqs.len());
    }

    #[test]
    fn route_policies_pick_sanely() {
        let rtt = rtt4();
        // Nearest: own site when replicated there …
        assert_eq!(RoutePolicy::Nearest.route(2, 0.9, &[0, 1, 2, 3], &rtt), 2);
        // … else the lowest-RTT replica (site 1 → site 2 at 2 ms).
        assert_eq!(RoutePolicy::Nearest.route(1, 0.0, &[0, 2, 3], &rtt), 2);
        // Tie-break: equal RTTs go to the lowest replica index.
        let flat = vec![vec![1.0; 4]; 4];
        assert_eq!(RoutePolicy::Nearest.route(0, 0.5, &[1, 3], &flat), 1);
        // Weighted: the draw walks the cumulative weights in order.
        let w = RoutePolicy::Weighted(vec![1.0, 3.0]);
        assert_eq!(w.route(0, 0.1, &[1, 2], &rtt), 1);
        assert_eq!(w.route(0, 0.9, &[1, 2], &rtt), 2);
        // Random: uniform slots over the replica list.
        assert_eq!(RoutePolicy::Random.route(0, 0.0, &[1, 3], &rtt), 1);
        assert_eq!(RoutePolicy::Random.route(0, 0.99, &[1, 3], &rtt), 3);
    }

    #[test]
    fn accum_counts_slo_timeouts_and_retries_once() {
        let mut sp = spec(RoutePolicy::Nearest);
        sp.slo_secs = 0.1;
        sp.timeout_secs = 0.5;
        let mut a = SiteAccum::new(0, 10.0);
        a.arrival(0.0);
        a.arrival(9.9999);
        // Fast completion: inside SLO, no retry.
        assert!(!a.complete(0.05, 0.05, &sp, false));
        // Slow but under timeout: SLO violation only.
        assert!(!a.complete(0.3, 0.3, &sp, false));
        // Past the timeout: violation + timeout + one retry owed.
        assert!(a.complete(1.0, 0.9, &sp, false));
        // The retry's own completion never re-arms, however slow.
        assert!(!a.complete(2.0, 0.9, &sp, true));
        assert_eq!(a.requests, 2);
        assert_eq!(a.completed, 4);
        assert_eq!(a.slo_violations, 3);
        assert_eq!(a.timeouts, 1);
        assert_eq!(a.retries, 1);
        assert_eq!(a.bins[0], 1);
        assert_eq!(a.bins[ARRIVAL_BINS - 1], 1);
    }

    #[test]
    fn report_assembles_and_roundtrips() {
        let sp = spec(RoutePolicy::Nearest);
        let mut a0 = SiteAccum::new(0, 10.0);
        let mut a1 = SiteAccum::new(1, 10.0);
        for i in 0..100 {
            let t = i as f64 * 0.1;
            a0.arrival(t);
            a0.complete(t, 0.01 + i as f64 * 1e-4, &sp, false);
            a1.arrival(t);
            a1.complete(t, 0.02 + i as f64 * 1e-4, &sp, false);
        }
        let rep = ServiceReport::assemble(&[a0, a1], 10.0);
        assert_eq!(rep.requests, 200);
        assert_eq!(rep.completed, 200);
        assert_eq!(rep.goodput_rps, 20.0);
        assert!(rep.p50_ms <= rep.p99_ms && rep.p99_ms <= rep.p999_ms);
        assert!(rep.sites[0].p50_ms < rep.sites[1].p50_ms);
        // Steady arrivals: the peak bin sits near the mean rate.
        assert!(rep.offered_peak_x < 1.5, "peak_x {}", rep.offered_peak_x);
        let text = rep.to_json().to_string();
        let back = ServiceReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, rep);
    }

    #[test]
    fn flash_crowd_concentrates_arrivals() {
        let mut sp = spec(RoutePolicy::Nearest);
        sp.phases = flash_crowd_phases();
        let lg = LoadGen::new(sp.clone(), 8_000, rtt4());
        let mut a = SiteAccum::new(0, lg.duration());
        for r in lg.gen_site(0) {
            a.arrival(r.t);
        }
        let rep = ServiceReport::assemble(&[a], lg.duration());
        // The burst packs ~47% of requests into 10% of the bins.
        assert!(rep.offered_peak_x > 3.0, "peak_x {}", rep.offered_peak_x);
    }

    #[test]
    fn loadgen_reproducibility_property() {
        // Random spec shapes: the plan must replay bit-identically and
        // respect its phase boundaries (the boundary-exactness property
        // the sharded driver leans on).
        check("loadgen replays and respects phase bounds", 25, |rng| {
            let total = 200 + rng.gen_range(2_000);
            let nphase = 1 + rng.gen_range(4) as usize;
            let mut fracs: Vec<f64> = (0..nphase).map(|_| 0.1 + rng.f64()).collect();
            let fsum: f64 = fracs.iter().sum();
            for f in &mut fracs {
                *f /= fsum;
            }
            // Re-normalize exactly: largest phase absorbs the residual.
            let resid = 1.0 - fracs.iter().sum::<f64>();
            fracs[0] += resid;
            let phases: Vec<Phase> = fracs
                .iter()
                .map(|&f| {
                    if rng.chance(0.5) {
                        Phase::constant(f, 0.5 + rng.f64() * 4.0)
                    } else {
                        Phase::diurnal(f, 0.5 + rng.f64() * 4.0)
                    }
                })
                .collect();
            let mut sp = ServiceSpec::new(vec![0, 2], RoutePolicy::Random);
            sp.phases = phases;
            sp.rate_rps = 100.0 + rng.f64() * 5000.0;
            let lg = LoadGen::new(sp, total, rtt4());
            let site = rng.gen_range(4) as u32;
            let a = lg.gen_site(site);
            let b = lg.gen_site(site);
            if a != b {
                return Err(format!("site {site} replayed differently"));
            }
            let budgets = lg.phase_budgets(lg.site_budget(site));
            if budgets.iter().sum::<u64>() != lg.site_budget(site) {
                return Err("phase budgets lost requests".to_string());
            }
            let bounds = lg.phase_bounds();
            let mut cursor = 0usize;
            for (&m, &(t0, t1)) in budgets.iter().zip(&bounds) {
                for r in &a[cursor..cursor + m as usize] {
                    if r.t < t0 || r.t >= t1 {
                        return Err(format!("t={} outside [{t0},{t1})", r.t));
                    }
                    if r.replica != 0 && r.replica != 2 {
                        return Err(format!("replica {} not in the set", r.replica));
                    }
                }
                cursor += m as usize;
            }
            Ok(())
        });
    }

    #[test]
    fn largest_remainder_is_exact_and_stable() {
        assert_eq!(largest_remainder(10, &[1.0, 1.0, 1.0]), vec![4, 3, 3]);
        assert_eq!(largest_remainder(7, &[0.45, 0.8, 0.45]), vec![2, 3, 2]);
        assert_eq!(largest_remainder(0, &[1.0, 2.0]), vec![0, 0]);
    }

    #[test]
    fn plant_partitions_pairs_and_gateways_disjointly() {
        let topo = Topology::oct_2009();
        let nodes = crate::coordinator::Placement::PerSite(8).select(&topo);
        let plant = service_plant(&topo, &nodes);
        assert_eq!(plant.pairs_by_site.len(), 4);
        let mut seen = std::collections::BTreeSet::new();
        for site in 0..4 {
            assert!(!plant.pairs_by_site[site].is_empty(), "site {site} has no pairs");
            assert!(!plant.gateways_by_site[site].is_empty(), "site {site} has no gateways");
            for &(a, b) in &plant.pairs_by_site[site] {
                assert_eq!(topo.node(a).rack, topo.node(b).rack, "pairs stay intra-rack");
                assert!(seen.insert(a) && seen.insert(b), "node reused");
            }
            for &g in &plant.gateways_by_site[site] {
                assert_eq!(topo.node(g).site.0, site);
                assert!(seen.insert(g), "gateway reused");
            }
        }
        assert_eq!(seen.len(), nodes.len());
    }
}

//! The collector: periodic sampling of every node's resources plus the
//! WAN links, with hierarchical rollups (node → rack → site → testbed).

use std::cell::RefCell;
use std::rc::Rc;

use crate::net::topology::LinkKind;
use crate::net::{FlowNet, LinkId, NodeId, Topology};
use crate::sim::resources::CpuPool;
use crate::sim::Engine;
use crate::util::json::{obj, Json};

use super::series::Series;

/// One sampled observation of a node (all values are utilizations in
/// [0, 1] except the NIC rates, which are bytes/s).
#[derive(Debug, Clone, Copy, Default)]
pub struct NodeSample {
    pub cpu: f64,
    pub disk: f64,
    pub nic_in: f64,
    pub nic_out: f64,
}

const SERIES_CAP: usize = 4096;

/// The monitoring system: per-node and per-WAN-link time series.
pub struct Monitor {
    topo: Rc<Topology>,
    interval: f64,
    enabled: bool,
    cpu: Vec<Series>,
    disk: Vec<Series>,
    nic_in: Vec<Series>,
    nic_out: Vec<Series>,
    /// WAN link series in link order (a plain sorted Vec: no per-sample
    /// key collection or hashing).
    wan: Vec<(LinkId, Series)>,
    /// Exact bytes drained from WAN link counters across all samples
    /// (the ring-buffer series only retains the trailing window).
    wan_bytes_drained: f64,
    /// Exact bytes drained from every node's disk link — the storage
    /// layer's observable (HDFS/KFS/Sector reads, spills, merges and
    /// replica writes all land on disk links).
    disk_bytes_drained: f64,
    /// When the previous sample was taken — rates divide by the *actual*
    /// elapsed time, so off-schedule samples (e.g. a final sample at run
    /// end) don't overstate or understate throughput.
    last_sample: f64,
    samples_taken: u64,
}

impl Monitor {
    pub fn new(topo: Rc<Topology>, interval: f64) -> Rc<RefCell<Monitor>> {
        assert!(interval > 0.0);
        let n = topo.num_nodes();
        let wan: Vec<(LinkId, Series)> = topo
            .links
            .iter()
            .enumerate()
            .filter(|(_, l)| l.kind == LinkKind::Wan)
            .map(|(i, _)| (LinkId(i), Series::new(SERIES_CAP)))
            .collect();
        Rc::new(RefCell::new(Monitor {
            topo,
            interval,
            enabled: true,
            cpu: (0..n).map(|_| Series::new(SERIES_CAP)).collect(),
            disk: (0..n).map(|_| Series::new(SERIES_CAP)).collect(),
            nic_in: (0..n).map(|_| Series::new(SERIES_CAP)).collect(),
            nic_out: (0..n).map(|_| Series::new(SERIES_CAP)).collect(),
            wan,
            wan_bytes_drained: 0.0,
            disk_bytes_drained: 0.0,
            last_sample: 0.0,
            samples_taken: 0,
        }))
    }

    pub fn interval(&self) -> f64 {
        self.interval
    }

    pub fn samples_taken(&self) -> u64 {
        self.samples_taken
    }

    /// Stop future scheduled samples (lets the event heap drain).
    pub fn disable(&mut self) {
        self.enabled = false;
    }

    /// Take one sample of every node and WAN link right now. Rates divide
    /// drained byte counters by the time actually elapsed since the
    /// previous sample — which equals the configured interval on schedule,
    /// but stays correct for off-schedule samples too. A second sample at
    /// the same instant is a no-op (no time has passed to measure).
    pub fn sample_all(
        &mut self,
        eng: &Engine,
        net: &Rc<RefCell<FlowNet>>,
        pools: &[Rc<RefCell<CpuPool>>],
    ) {
        let now = eng.now();
        let dt = now - self.last_sample;
        if dt <= 0.0 {
            return;
        }
        self.last_sample = now;
        let mut netm = net.borrow_mut();
        for (i, node) in self.topo.nodes.iter().enumerate() {
            let cpu = pools
                .get(i)
                .map(|p| p.borrow_mut().take_utilization(now, dt))
                .unwrap_or(0.0);
            let disk_bytes = netm.take_link_bytes(node.disk, now);
            self.disk_bytes_drained += disk_bytes;
            let disk = (disk_bytes / dt / self.topo.link(node.disk).capacity).min(1.0);
            let inb = netm.take_link_bytes(node.nic_rx, now) / dt;
            let outb = netm.take_link_bytes(node.nic_tx, now) / dt;
            self.cpu[i].push(now, cpu);
            self.disk[i].push(now, disk);
            self.nic_in[i].push(now, inb);
            self.nic_out[i].push(now, outb);
        }
        for (l, series) in self.wan.iter_mut() {
            let bytes = netm.take_link_bytes(*l, now);
            self.wan_bytes_drained += bytes;
            series.push(now, bytes / dt);
        }
        self.samples_taken += 1;
    }

    /// Install the periodic sampling loop on the engine. Sampling stops
    /// when [`Monitor::disable`] is called (the next tick unschedules).
    pub fn install(
        mon: &Rc<RefCell<Monitor>>,
        eng: &mut Engine,
        net: &Rc<RefCell<FlowNet>>,
        pools: Vec<Rc<RefCell<CpuPool>>>,
    ) {
        let interval = mon.borrow().interval;
        Self::tick(mon.clone(), eng, net.clone(), Rc::new(pools), interval);
    }

    fn tick(
        mon: Rc<RefCell<Monitor>>,
        eng: &mut Engine,
        net: Rc<RefCell<FlowNet>>,
        pools: Rc<Vec<Rc<RefCell<CpuPool>>>>,
        interval: f64,
    ) {
        eng.schedule_in(interval, move |eng| {
            if !mon.borrow().enabled {
                return;
            }
            mon.borrow_mut().sample_all(eng, &net, &pools);
            Self::tick(mon.clone(), eng, net, pools, interval);
        });
    }

    // ---- accessors & rollups -----------------------------------------

    /// Latest sample for a node.
    pub fn node_sample(&self, n: NodeId) -> NodeSample {
        NodeSample {
            cpu: self.cpu[n.0].last().map(|(_, v)| v).unwrap_or(0.0),
            disk: self.disk[n.0].last().map(|(_, v)| v).unwrap_or(0.0),
            nic_in: self.nic_in[n.0].last().map(|(_, v)| v).unwrap_or(0.0),
            nic_out: self.nic_out[n.0].last().map(|(_, v)| v).unwrap_or(0.0),
        }
    }

    /// Recent mean NIC throughput (in+out, bytes/s) per node — the metric
    /// Figure 3 colors by and the straggler detector consumes.
    pub fn node_nic_rate(&self, n: NodeId, window: usize) -> f64 {
        self.nic_in[n.0].recent_mean(window) + self.nic_out[n.0].recent_mean(window)
    }

    /// (p50, p99) of per-node NIC throughput across the nodes that saw
    /// any traffic: each node is represented by its recent mean over
    /// `window` samples, and the quantiles are taken across nodes. This
    /// is the rollup `RunReport` monitor summaries carry and the shape
    /// the ops-plane hotspot detector mirrors in-band.
    pub fn nic_rate_quantiles(&self, window: usize) -> (f64, f64) {
        let rates: Vec<f64> = (0..self.topo.num_nodes())
            .map(|i| self.node_nic_rate(NodeId(i), window))
            .filter(|&r| r > 0.0)
            .collect();
        (
            crate::util::stats::percentile(&rates, 50.0),
            crate::util::stats::percentile(&rates, 99.0),
        )
    }

    pub fn node_cpu_series(&self, n: NodeId) -> &Series {
        &self.cpu[n.0]
    }

    /// Mean CPU utilization across a site's nodes (site rollup).
    pub fn site_cpu(&self, site: usize) -> f64 {
        let nodes: Vec<usize> = self
            .topo
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.site.0 == site)
            .map(|(i, _)| i)
            .collect();
        if nodes.is_empty() {
            return 0.0;
        }
        nodes.iter().map(|&i| self.cpu[i].last().map(|(_, v)| v).unwrap_or(0.0)).sum::<f64>()
            / nodes.len() as f64
    }

    /// Testbed-wide mean CPU utilization.
    pub fn testbed_cpu(&self) -> f64 {
        let sites = self.topo.sites.len();
        if sites == 0 {
            return 0.0;
        }
        (0..sites).map(|s| self.site_cpu(s)).sum::<f64>() / sites as f64
    }

    /// Sector-style per-link aggregate throughput: the latest sampled
    /// bytes/s on each WAN link, labeled.
    pub fn wan_throughput(&self) -> Vec<(String, f64)> {
        let mut v: Vec<(String, f64)> = self
            .wan
            .iter()
            .map(|(l, s)| {
                (self.topo.link(*l).label.clone(), s.last().map(|(_, v)| v).unwrap_or(0.0))
            })
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Total bytes the sampler has drained from WAN link counters over
    /// the whole run (exact — not limited to the series' retained
    /// window). Run reports add this to the post-final-sample residue
    /// to recover a run's WAN total.
    pub fn wan_bytes_observed(&self) -> f64 {
        self.wan_bytes_drained
    }

    /// Total bytes the sampler has drained from node disk links over the
    /// whole run — the storage layer's counterpart to
    /// [`Monitor::wan_bytes_observed`], a sampling-based cross-check of
    /// the framework runtime's `storage_read_bytes`/`storage_write_bytes`
    /// accounting.
    pub fn disk_bytes_observed(&self) -> f64 {
        self.disk_bytes_drained
    }

    /// Export the latest frame as JSON (the web UI's data feed).
    pub fn frame_json(&self, now: f64) -> Json {
        let nodes: Vec<Json> = (0..self.topo.num_nodes())
            .map(|i| {
                let s = self.node_sample(NodeId(i));
                obj(vec![
                    ("node", Json::Str(self.topo.nodes[i].name.clone())),
                    ("site", Json::Num(self.topo.nodes[i].site.0 as f64)),
                    ("cpu", Json::Num(s.cpu)),
                    ("disk", Json::Num(s.disk)),
                    ("nic_in", Json::Num(s.nic_in)),
                    ("nic_out", Json::Num(s.nic_out)),
                ])
            })
            .collect();
        let wan: Vec<Json> = self
            .wan_throughput()
            .into_iter()
            .map(|(label, bps)| obj(vec![("link", Json::Str(label)), ("bps", Json::Num(bps))]))
            .collect();
        obj(vec![("t", Json::Num(now)), ("nodes", Json::Arr(nodes)), ("wan", Json::Arr(wan))])
    }

    pub fn topology(&self) -> &Rc<Topology> {
        &self.topo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topology::NodeSpec;
    use crate::transport;

    fn small_topo() -> Rc<Topology> {
        let mut t = Topology::new();
        let a = t.add_site("a");
        let b = t.add_site("b");
        let spec = NodeSpec { nic_bps: 100.0, disk_bps: 50.0, cpu_slots: 2 };
        t.add_rack(a, 2, &spec, 1000.0);
        t.add_rack(b, 2, &spec, 1000.0);
        t.connect_sites(a, b, 200.0, 0.01);
        Rc::new(t)
    }

    fn pools(topo: &Topology) -> Vec<Rc<RefCell<CpuPool>>> {
        topo.nodes.iter().map(|n| CpuPool::new(n.cpu_slots)).collect()
    }

    #[test]
    fn sampling_captures_nic_activity() {
        let topo = small_topo();
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let ps = pools(&topo);
        let mon = Monitor::new(topo.clone(), 1.0);
        Monitor::install(&mon, &mut eng, &net, ps.clone());
        // Saturate node0's NIC for 10 s.
        let path = topo.path(topo.racks[0].nodes[0], topo.racks[0].nodes[1]);
        FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, |_| {});
        eng.run_until(10.0);
        mon.borrow_mut().disable();
        eng.run();
        let m = mon.borrow();
        assert!(m.samples_taken() >= 9);
        let s = m.node_sample(NodeId(0));
        assert!(s.nic_out > 50.0, "nic_out={}", s.nic_out); // ~100 B/s while active
        let s1 = m.node_sample(NodeId(1));
        assert!(s1.nic_in > 50.0);
    }

    #[test]
    fn nic_quantile_rollup_covers_active_nodes_only() {
        let topo = small_topo();
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let ps = pools(&topo);
        let mon = Monitor::new(topo.clone(), 1.0);
        Monitor::install(&mon, &mut eng, &net, ps);
        // Node0 streams to node1 at 100 B/s; nodes 2 and 3 stay idle.
        let path = topo.path(topo.racks[0].nodes[0], topo.racks[0].nodes[1]);
        FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, |_| {});
        eng.run_until(10.0);
        mon.borrow_mut().disable();
        eng.run();
        let m = mon.borrow();
        let (p50, p99) = m.nic_rate_quantiles(10);
        // Both active nodes carry ~100 B/s (one tx, one rx); idle nodes
        // are excluded rather than dragging the median to zero.
        assert!(p50 > 50.0, "p50={p50}");
        assert!(p99 >= p50, "p99={p99} < p50={p50}");
        assert!(p99 < 150.0, "p99={p99}");
    }

    #[test]
    fn nic_rate_quantiles_with_window_beyond_retention() {
        // A rollup window wider than the samples actually retained must
        // clamp to the full window, not index past the ring or skew the
        // mean with phantom zeros.
        let topo = small_topo();
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let ps = pools(&topo);
        let mon = Monitor::new(topo.clone(), 1.0);
        Monitor::install(&mon, &mut eng, &net, ps);
        let path = topo.path(topo.racks[0].nodes[0], topo.racks[0].nodes[1]);
        FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, |_| {});
        eng.run_until(6.0);
        mon.borrow_mut().disable();
        eng.run();
        let m = mon.borrow();
        // Only a handful of samples exist; ask for vastly more.
        let (p50, p99) = m.nic_rate_quantiles(1_000_000);
        assert!(p50.is_finite() && p99.is_finite());
        assert!(p50 > 50.0, "p50={p50}");
        assert!(p99 >= p50, "p99={p99} < p50={p50}");
        // The oversized window degrades to "all retained samples", so
        // any window at least that large gives the same rollup.
        assert_eq!((p50, p99), m.nic_rate_quantiles(usize::MAX));
        // And an empty monitor rolls up to zeros, not a panic.
        let idle = Monitor::new(small_topo(), 1.0);
        assert_eq!(idle.borrow().nic_rate_quantiles(1_000_000), (0.0, 0.0));
    }

    #[test]
    fn cpu_utilization_sampled() {
        let topo = small_topo();
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let ps = pools(&topo);
        let mon = Monitor::new(topo.clone(), 1.0);
        Monitor::install(&mon, &mut eng, &net, ps.clone());
        // Fill both slots of node0 for 5 s.
        for _ in 0..2 {
            CpuPool::submit(&ps[0], &mut eng, 5.0, |_| {});
        }
        eng.run_until(4.0);
        mon.borrow_mut().disable();
        eng.run();
        let m = mon.borrow();
        let cpu = m.node_cpu_series(NodeId(0)).recent_mean(3);
        assert!(cpu > 0.9, "cpu={cpu}");
        assert!(m.node_cpu_series(NodeId(2)).recent_mean(3) < 0.05);
    }

    #[test]
    fn wan_rollup_sees_cross_site_flow() {
        let topo = small_topo();
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let ps = pools(&topo);
        let mon = Monitor::new(topo.clone(), 1.0);
        Monitor::install(&mon, &mut eng, &net, ps);
        let src = topo.racks[0].nodes[0];
        let dst = topo.racks[1].nodes[0];
        let udt = transport::Protocol::udt();
        transport::send(&net, &topo, &mut eng, src, dst, 500.0, &udt, |_| {});
        eng.run_until(4.0);
        mon.borrow_mut().disable();
        eng.run();
        let m = mon.borrow();
        let wan = m.wan_throughput();
        assert!(wan.iter().any(|(_, bps)| *bps > 10.0), "{wan:?}");
        // The observed-byte rollup sees (at least) the sampled transfer.
        assert!(m.wan_bytes_observed() > 100.0, "{}", m.wan_bytes_observed());
    }

    #[test]
    fn disk_rollup_observes_storage_traffic() {
        let topo = small_topo();
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let ps = pools(&topo);
        let mon = Monitor::new(topo.clone(), 1.0);
        Monitor::install(&mon, &mut eng, &net, ps);
        // A 200-byte storage read on node0's 50 B/s disk.
        transport::disk_read(&net, &topo, &mut eng, topo.racks[0].nodes[0], 200.0, |_| {});
        eng.run_until(6.0);
        mon.borrow_mut().disable();
        eng.run();
        let m = mon.borrow();
        assert!(
            (m.disk_bytes_observed() - 200.0).abs() < 1e-6,
            "disk bytes {}",
            m.disk_bytes_observed()
        );
        // Disk traffic is not WAN traffic.
        assert_eq!(m.wan_bytes_observed(), 0.0);
    }

    #[test]
    fn off_schedule_sample_uses_actual_elapsed_time() {
        let topo = small_topo();
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let ps = pools(&topo);
        // The interval says 1 s, but the only sample is taken at t=2.5.
        let mon = Monitor::new(topo.clone(), 1.0);
        let path = topo.path(topo.racks[0].nodes[0], topo.racks[0].nodes[1]);
        FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, |_| {});
        eng.run_until(2.5);
        mon.borrow_mut().sample_all(&eng, &net, &ps);
        let m = mon.borrow();
        let s = m.node_sample(NodeId(0));
        // 250 B drained over 2.5 s = 100 B/s — not 250 B/s (the old code
        // divided by the nominal interval regardless of elapsed time).
        assert!((s.nic_out - 100.0).abs() < 1e-6, "nic_out={}", s.nic_out);
        assert_eq!(m.samples_taken(), 1);
    }

    #[test]
    fn repeated_sample_at_same_instant_is_a_noop() {
        let topo = small_topo();
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let ps = pools(&topo);
        let mon = Monitor::new(topo.clone(), 1.0);
        eng.run_until(1.0);
        mon.borrow_mut().sample_all(&eng, &net, &ps);
        assert_eq!(mon.borrow().samples_taken(), 1);
        // No time has elapsed: there is nothing to rate, so nothing is
        // recorded (previously this pushed a bogus zero-rate sample).
        mon.borrow_mut().sample_all(&eng, &net, &ps);
        assert_eq!(mon.borrow().samples_taken(), 1);
    }

    #[test]
    fn site_and_testbed_rollups() {
        let topo = small_topo();
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let ps = pools(&topo);
        let mon = Monitor::new(topo.clone(), 0.5);
        Monitor::install(&mon, &mut eng, &net, ps.clone());
        // Only site 0 is busy.
        for i in 0..2 {
            for _ in 0..2 {
                CpuPool::submit(&ps[i], &mut eng, 3.0, |_| {});
            }
        }
        eng.run_until(2.0);
        mon.borrow_mut().disable();
        eng.run();
        let m = mon.borrow();
        assert!(m.site_cpu(0) > 0.9);
        assert!(m.site_cpu(1) < 0.05);
        let tb = m.testbed_cpu();
        assert!(tb > 0.4 && tb < 0.6, "testbed={tb}");
    }

    #[test]
    fn frame_json_exports() {
        let topo = small_topo();
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let ps = pools(&topo);
        let mon = Monitor::new(topo.clone(), 1.0);
        Monitor::install(&mon, &mut eng, &net, ps);
        eng.run_until(2.0);
        mon.borrow_mut().disable();
        eng.run();
        let frame = mon.borrow().frame_json(eng.now());
        let parsed = crate::util::json::Json::parse(&frame.to_string()).unwrap();
        assert_eq!(
            parsed.get("nodes").map(|n| matches!(n, Json::Arr(v) if v.len() == 4)),
            Some(true)
        );
    }
}

//! Figure 3 as an ANSI terminal heatmap.
//!
//! "Each block represents a server node, and each group of blocks
//! represents a cluster. The color of each block represents the usage of a
//! particular resource … green/light side means idle; red/dark side means
//! busy." The renderer prints one block ('█') per node, grouped by site,
//! colored along a green→yellow→red 256-color gradient, with a per-site
//! mean column and a legend.

use crate::net::NodeId;

use super::collector::Monitor;

/// Which resource to color by.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    Cpu,
    Disk,
    /// NIC in+out as a fraction of NIC capacity (Figure 3's default).
    Network,
}

fn gradient_color(u: f64) -> u8 {
    // xterm-256 approximation of green → yellow → orange → red.
    const STOPS: [u8; 7] = [46, 82, 118, 154, 220, 208, 196];
    let u = u.clamp(0.0, 1.0);
    STOPS[((u * (STOPS.len() - 1) as f64).round()) as usize]
}

fn utilization(mon: &Monitor, metric: Metric, node: NodeId) -> f64 {
    let s = mon.node_sample(node);
    match metric {
        Metric::Cpu => s.cpu,
        Metric::Disk => s.disk,
        Metric::Network => {
            let topo = mon.topology();
            let cap = topo.link(topo.node(node).nic_tx).capacity
                + topo.link(topo.node(node).nic_rx).capacity;
            if cap > 0.0 {
                ((s.nic_in + s.nic_out) / cap).min(1.0)
            } else {
                0.0
            }
        }
    }
}

/// Render the current frame. With `ansi = false`, uses a plain character
/// ramp (` .:-=+*#%@`) instead of colors (for logs and tests).
pub fn render_heatmap(mon: &Monitor, metric: Metric, ansi: bool) -> String {
    let topo = mon.topology().clone();
    let mut out = String::new();
    let title = match metric {
        Metric::Cpu => "cpu",
        Metric::Disk => "disk",
        Metric::Network => "network IO",
    };
    out.push_str(&format!("OCT monitor — per-node {title} utilization\n"));
    const RAMP: &[u8] = b" .:-=+*#%@";
    for (si, site) in topo.sites.iter().enumerate() {
        let mut blocks = String::new();
        let mut acc = 0.0;
        let mut count = 0usize;
        for rack in &site.racks {
            for &n in &topo.racks[rack.0].nodes {
                let u = utilization(mon, metric, n);
                acc += u;
                count += 1;
                if ansi {
                    blocks.push_str(&format!("\x1b[38;5;{}m█\x1b[0m", gradient_color(u)));
                } else {
                    let idx = ((u * (RAMP.len() - 1) as f64).round()) as usize;
                    blocks.push(RAMP[idx.min(RAMP.len() - 1)] as char);
                }
            }
            blocks.push(' ');
        }
        let mean = if count > 0 { acc / count as f64 } else { 0.0 };
        out.push_str(&format!("  {si} {:<20} [{blocks}] mean {:5.1}%\n", site.name, mean * 100.0));
    }
    out.push_str("  legend: idle ");
    if ansi {
        for i in 0..=6 {
            out.push_str(&format!("\x1b[38;5;{}m█\x1b[0m", gradient_color(i as f64 / 6.0)));
        }
    } else {
        out.push_str(std::str::from_utf8(RAMP).unwrap());
    }
    out.push_str(" busy\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topology::{NodeSpec, Topology};
    use crate::net::FlowNet;
    use crate::sim::resources::CpuPool;
    use crate::sim::Engine;
    use std::cell::RefCell;
    use std::rc::Rc;

    fn monitored_run() -> (Rc<RefCell<Monitor>>, Engine) {
        let mut t = Topology::new();
        let a = t.add_site("alpha");
        let b = t.add_site("beta");
        let spec = NodeSpec { nic_bps: 100.0, disk_bps: 100.0, cpu_slots: 2 };
        t.add_rack(a, 3, &spec, 1000.0);
        t.add_rack(b, 3, &spec, 1000.0);
        t.connect_sites(a, b, 500.0, 0.01);
        let topo = Rc::new(t);
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let pools: Vec<Rc<RefCell<CpuPool>>> =
            topo.nodes.iter().map(|n| CpuPool::new(n.cpu_slots)).collect();
        let mon = Monitor::new(topo.clone(), 1.0);
        Monitor::install(&mon, &mut eng, &net, pools);
        // Busy site alpha only.
        let path = topo.path(topo.racks[0].nodes[0], topo.racks[0].nodes[1]);
        FlowNet::start(&net, &mut eng, path, 1e4, f64::INFINITY, |_| {});
        eng.run_until(5.0);
        mon.borrow_mut().disable();
        eng.run_until(6.0);
        (mon, eng)
    }

    #[test]
    fn plain_render_shows_sites_and_activity() {
        let (mon, _eng) = monitored_run();
        let s = render_heatmap(&mon.borrow(), Metric::Network, false);
        assert!(s.contains("alpha"));
        assert!(s.contains("beta"));
        assert!(s.contains("legend"));
        // Site alpha's blocks must show nonzero utilization characters.
        let alpha_line = s.lines().find(|l| l.contains("alpha")).unwrap();
        assert!(alpha_line.chars().any(|c| "=+*#%@".contains(c)), "{alpha_line}");
    }

    #[test]
    fn ansi_render_has_colors() {
        let (mon, _eng) = monitored_run();
        let s = render_heatmap(&mon.borrow(), Metric::Network, true);
        assert!(s.contains("\x1b[38;5;"));
        assert!(s.matches('█').count() >= 6);
    }

    #[test]
    fn gradient_endpoints() {
        assert_eq!(gradient_color(0.0), 46); // green
        assert_eq!(gradient_color(1.0), 196); // red
    }

    #[test]
    fn cpu_metric_renders() {
        let (mon, _eng) = monitored_run();
        let s = render_heatmap(&mon.borrow(), Metric::Cpu, false);
        assert!(s.contains("cpu"));
    }

    #[test]
    fn zero_capacity_nic_reads_as_idle_not_nan() {
        // A node provisioned with no NIC bandwidth must render idle
        // (0.0), not divide 0/0 into NaN and poison the site mean.
        let mut t = Topology::new();
        let a = t.add_site("airgap");
        let spec = NodeSpec { nic_bps: 0.0, disk_bps: 100.0, cpu_slots: 1 };
        t.add_rack(a, 2, &spec, 1000.0);
        let topo = Rc::new(t);
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let pools: Vec<Rc<RefCell<CpuPool>>> =
            topo.nodes.iter().map(|n| CpuPool::new(n.cpu_slots)).collect();
        let mon = Monitor::new(topo.clone(), 1.0);
        Monitor::install(&mon, &mut eng, &net, pools);
        eng.run_until(3.0);
        mon.borrow_mut().disable();
        eng.run_until(4.0);
        let m = mon.borrow();
        for n in topo.node_ids() {
            let u = utilization(&m, Metric::Network, n);
            assert_eq!(u, 0.0, "node {n:?} read {u}");
        }
        let s = render_heatmap(&m, Metric::Network, false);
        let line = s.lines().find(|l| l.contains("airgap")).unwrap();
        assert!(line.contains("mean   0.0%"), "{line}");
        assert!(!s.contains("NaN"), "{s}");
    }

    #[test]
    fn drained_node_returns_to_idle_after_traffic_stops() {
        // Finite transfer: the node is busy while it drains, then its
        // utilization falls back to 0.0 once the flow completes and the
        // monitor keeps sampling (the "drained node" frame of Figure 3).
        let mut t = Topology::new();
        let a = t.add_site("alpha");
        let spec = NodeSpec { nic_bps: 100.0, disk_bps: 100.0, cpu_slots: 2 };
        t.add_rack(a, 2, &spec, 1000.0);
        let topo = Rc::new(t);
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let pools: Vec<Rc<RefCell<CpuPool>>> =
            topo.nodes.iter().map(|n| CpuPool::new(n.cpu_slots)).collect();
        let mon = Monitor::new(topo.clone(), 1.0);
        Monitor::install(&mon, &mut eng, &net, pools);
        let src = topo.racks[0].nodes[0];
        let path = topo.path(src, topo.racks[0].nodes[1]);
        // 500 bytes at ~100 B/s: done by t≈5, sampling continues to 12.
        FlowNet::start(&net, &mut eng, path, 500.0, f64::INFINITY, |_| {});
        eng.run_until(3.0);
        assert!(
            utilization(&mon.borrow(), Metric::Network, src) > 0.0,
            "node should be busy mid-transfer"
        );
        eng.run_until(12.0);
        mon.borrow_mut().disable();
        eng.run_until(13.0);
        let m = mon.borrow();
        assert_eq!(utilization(&m, Metric::Network, src), 0.0);
        let s = render_heatmap(&m, Metric::Network, false);
        let line = s.lines().find(|l| l.contains("alpha")).unwrap();
        assert!(line.contains("mean   0.0%"), "{line}");
    }
}

//! Fixed-capacity ring-buffer time series for monitor metrics.

/// A bounded time series of (time, value) samples. Old samples are
/// overwritten once capacity is reached (the web UI only ever showed a
/// trailing window).
#[derive(Debug, Clone)]
pub struct Series {
    cap: usize,
    buf: Vec<(f64, f64)>,
    head: usize,
    len: usize,
}

impl Series {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Series { cap, buf: vec![(0.0, 0.0); cap], head: 0, len: 0 }
    }

    pub fn push(&mut self, t: f64, v: f64) {
        self.buf[self.head] = (t, v);
        self.head = (self.head + 1) % self.cap;
        self.len = (self.len + 1).min(self.cap);
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Most recent sample.
    pub fn last(&self) -> Option<(f64, f64)> {
        if self.len == 0 {
            None
        } else {
            Some(self.buf[(self.head + self.cap - 1) % self.cap])
        }
    }

    /// Samples oldest→newest.
    pub fn iter(&self) -> impl Iterator<Item = (f64, f64)> + '_ {
        let start = (self.head + self.cap - self.len) % self.cap;
        (0..self.len).map(move |i| self.buf[(start + i) % self.cap])
    }

    /// Mean of the most recent `n` values.
    pub fn recent_mean(&self, n: usize) -> f64 {
        let take = n.min(self.len);
        if take == 0 {
            return 0.0;
        }
        let vals: Vec<f64> = self.iter().map(|(_, v)| v).collect();
        vals[vals.len() - take..].iter().sum::<f64>() / take as f64
    }

    pub fn values(&self) -> Vec<f64> {
        self.iter().map(|(_, v)| v).collect()
    }

    /// Linear-interpolated quantile of the retained values, `q` in
    /// [0, 100]. 0.0 when the series is empty.
    pub fn quantile(&self, q: f64) -> f64 {
        crate::util::stats::percentile(&self.values(), q)
    }

    /// Median of the retained window (the hotspot detector's robust
    /// per-node rate estimate).
    pub fn p50(&self) -> f64 {
        self.quantile(50.0)
    }

    /// 99th percentile of the retained window (tail rollup for monitor
    /// summaries).
    pub fn p99(&self) -> f64 {
        self.quantile(99.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_last() {
        let mut s = Series::new(4);
        assert!(s.last().is_none());
        s.push(1.0, 10.0);
        s.push(2.0, 20.0);
        assert_eq!(s.last(), Some((2.0, 20.0)));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn wraps_overwriting_oldest() {
        let mut s = Series::new(3);
        for i in 0..5 {
            s.push(i as f64, i as f64 * 10.0);
        }
        assert_eq!(s.len(), 3);
        let items: Vec<_> = s.iter().collect();
        assert_eq!(items, vec![(2.0, 20.0), (3.0, 30.0), (4.0, 40.0)]);
    }

    #[test]
    fn quantile_rollups() {
        let mut s = Series::new(200);
        for i in 1..=100 {
            s.push(i as f64, i as f64);
        }
        assert_eq!(s.p50(), 50.5);
        // Interpolated 99th over 1..=100: rank 98.01 → 99.01.
        assert!((s.p99() - 99.01).abs() < 1e-9, "{}", s.p99());
        assert_eq!(s.quantile(0.0), 1.0);
        assert_eq!(s.quantile(100.0), 100.0);
        assert_eq!(Series::new(4).p50(), 0.0);
        // Quantiles see only the retained window after wrap.
        let mut w = Series::new(3);
        for v in [100.0, 1.0, 2.0, 3.0] {
            w.push(v, v);
        }
        assert_eq!(w.p50(), 2.0);
    }

    #[test]
    fn recent_mean_window() {
        let mut s = Series::new(10);
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.push(v, v);
        }
        assert_eq!(s.recent_mean(2), 3.5);
        assert_eq!(s.recent_mean(100), 2.5);
        assert_eq!(Series::new(3).recent_mean(2), 0.0);
    }
}

//! Fixed-capacity ring-buffer time series for monitor metrics.

/// A bounded time series of (time, value) samples. Old samples are
/// overwritten once capacity is reached (the web UI only ever showed a
/// trailing window).
#[derive(Debug, Clone)]
pub struct Series {
    cap: usize,
    buf: Vec<(f64, f64)>,
    head: usize,
    len: usize,
    /// Reusable sort buffer for [`Series::quantile`]: grows to `cap`
    /// once, then every rollup is allocation-free. Interior mutability
    /// keeps the rollup API `&self` (the monitor holds series behind
    /// shared borrows on the hot sampling path).
    scratch: std::cell::RefCell<Vec<f64>>,
}

impl Series {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Series {
            cap,
            buf: vec![(0.0, 0.0); cap],
            head: 0,
            len: 0,
            scratch: std::cell::RefCell::new(Vec::new()),
        }
    }

    pub fn push(&mut self, t: f64, v: f64) {
        self.buf[self.head] = (t, v);
        self.head = (self.head + 1) % self.cap;
        self.len = (self.len + 1).min(self.cap);
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Most recent sample.
    pub fn last(&self) -> Option<(f64, f64)> {
        if self.len == 0 {
            None
        } else {
            Some(self.buf[(self.head + self.cap - 1) % self.cap])
        }
    }

    /// Samples oldest→newest.
    pub fn iter(&self) -> impl Iterator<Item = (f64, f64)> + '_ {
        let start = (self.head + self.cap - self.len) % self.cap;
        (0..self.len).map(move |i| self.buf[(start + i) % self.cap])
    }

    /// Mean of the most recent `n` values. Walks the ring directly —
    /// no intermediate collection — since the hotspot detector calls
    /// this per node per sampling tick.
    pub fn recent_mean(&self, n: usize) -> f64 {
        let take = n.min(self.len);
        if take == 0 {
            return 0.0;
        }
        let start = (self.head + self.cap - take) % self.cap;
        let mut sum = 0.0;
        for i in 0..take {
            sum += self.buf[(start + i) % self.cap].1;
        }
        sum / take as f64
    }

    pub fn values(&self) -> Vec<f64> {
        self.iter().map(|(_, v)| v).collect()
    }

    /// Linear-interpolated quantile of the retained values, `q` in
    /// [0, 100]. 0.0 when the series is empty. Sorts into the reusable
    /// scratch buffer, so steady-state rollups allocate nothing.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        let mut v = self.scratch.borrow_mut();
        v.clear();
        v.extend(self.iter().map(|(_, x)| x));
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        crate::util::stats::percentile_sorted(&v, q)
    }

    /// Median of the retained window (the hotspot detector's robust
    /// per-node rate estimate).
    pub fn p50(&self) -> f64 {
        self.quantile(50.0)
    }

    /// 99th percentile of the retained window (tail rollup for monitor
    /// summaries).
    pub fn p99(&self) -> f64 {
        self.quantile(99.0)
    }

    /// 99.9th percentile — the extreme-tail rollup for wide windows
    /// (only meaningful once the window retains ≳1000 samples).
    pub fn p999(&self) -> f64 {
        self.quantile(99.9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_last() {
        let mut s = Series::new(4);
        assert!(s.last().is_none());
        s.push(1.0, 10.0);
        s.push(2.0, 20.0);
        assert_eq!(s.last(), Some((2.0, 20.0)));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn wraps_overwriting_oldest() {
        let mut s = Series::new(3);
        for i in 0..5 {
            s.push(i as f64, i as f64 * 10.0);
        }
        assert_eq!(s.len(), 3);
        let items: Vec<_> = s.iter().collect();
        assert_eq!(items, vec![(2.0, 20.0), (3.0, 30.0), (4.0, 40.0)]);
    }

    #[test]
    fn quantile_rollups() {
        let mut s = Series::new(200);
        for i in 1..=100 {
            s.push(i as f64, i as f64);
        }
        assert_eq!(s.p50(), 50.5);
        // Interpolated 99th over 1..=100: rank 98.01 → 99.01.
        assert!((s.p99() - 99.01).abs() < 1e-9, "{}", s.p99());
        assert_eq!(s.quantile(0.0), 1.0);
        assert_eq!(s.quantile(100.0), 100.0);
        assert_eq!(Series::new(4).p50(), 0.0);
        // Quantiles see only the retained window after wrap.
        let mut w = Series::new(3);
        for v in [100.0, 1.0, 2.0, 3.0] {
            w.push(v, v);
        }
        assert_eq!(w.p50(), 2.0);
    }

    #[test]
    fn recent_mean_window() {
        let mut s = Series::new(10);
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.push(v, v);
        }
        assert_eq!(s.recent_mean(2), 3.5);
        assert_eq!(s.recent_mean(100), 2.5);
        assert_eq!(Series::new(3).recent_mean(2), 0.0);
    }

    #[test]
    fn recent_mean_walks_the_ring_after_wrap() {
        // The ring-direct walk must skip overwritten samples exactly like
        // the old collect-then-slice path did.
        let mut s = Series::new(3);
        for v in [100.0, 1.0, 2.0, 3.0] {
            s.push(v, v);
        }
        assert_eq!(s.recent_mean(1), 3.0);
        assert_eq!(s.recent_mean(2), 2.5);
        assert_eq!(s.recent_mean(3), 2.0);
        assert_eq!(s.recent_mean(10), 2.0);
    }

    #[test]
    fn quantiles_survive_degenerate_windows() {
        // Empty window: every quantile is 0.0 — never NaN, never a panic.
        let empty = Series::new(8);
        for q in [0.0, 50.0, 99.0, 99.9, 100.0] {
            let v = empty.quantile(q);
            assert_eq!(v, 0.0, "empty window quantile({q})");
            assert!(!v.is_nan());
        }
        assert_eq!(empty.p999(), 0.0);
        // Single sample: every quantile collapses to that sample.
        let mut one = Series::new(8);
        one.push(1.0, 42.0);
        for q in [0.0, 50.0, 99.0, 99.9, 100.0] {
            assert_eq!(one.quantile(q), 42.0, "single-sample quantile({q})");
        }
        // All-equal samples: interpolation between equal neighbors must
        // not drift or produce NaN.
        let mut flat = Series::new(16);
        for i in 0..10 {
            flat.push(i as f64, 7.0);
        }
        for q in [0.0, 50.0, 99.0, 99.9, 100.0] {
            assert_eq!(flat.quantile(q), 7.0, "all-equal quantile({q})");
        }
        // p999 on a 10-sample window: the rank interpolates inside the
        // top pair — finite, ordered after p99, bounded by the max.
        let mut s = Series::new(16);
        for i in 1..=10 {
            s.push(i as f64, i as f64);
        }
        let (p99, p999) = (s.p99(), s.p999());
        assert!(p999.is_finite() && !p999.is_nan());
        assert!(p99 <= p999 && p999 <= 10.0, "{p99} / {p999}");
        assert!((p999 - 9.991).abs() < 1e-9, "{p999}");
    }

    #[test]
    fn p999_tail_and_scratch_reuse() {
        let mut s = Series::new(2000);
        for i in 1..=1000 {
            s.push(i as f64, i as f64);
        }
        // Interpolated 99.9th over 1..=1000: rank 998.001 → 999.001.
        assert!((s.p999() - 999.001).abs() < 1e-9, "{}", s.p999());
        // Repeated rollups reuse the scratch buffer and stay stable.
        assert_eq!(s.p50(), 500.5);
        assert_eq!(s.p50(), 500.5);
        assert_eq!(s.quantile(100.0), 1000.0);
        assert_eq!(Series::new(4).p999(), 0.0);
    }
}

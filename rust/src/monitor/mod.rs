//! The OCT monitoring and visualization system (paper §3, Figure 3).
//!
//! The real testbed ran a lightweight collector on every node recording
//! CPU, memory, disk, and NIC utilization, aggregated per rack/site with a
//! web heatmap ("each block represents a server node … green/light means
//! idle; red/dark means busy"). Here the collector samples the simulated
//! substrate (CPU pools and the fluid network's link counters) on a fixed
//! cadence, stores ring-buffer time series, rolls them up along the
//! node→rack→site→testbed hierarchy — including Sector's per-*link*
//! aggregate throughput used to spot bad network segments — and renders
//! Figure 3 as an ANSI terminal heatmap plus a JSON export.
//!
//! The detector reproduces the paper's §8 observation that "just one or
//! two nodes with slightly inferior performance" can drag a whole run:
//! nodes whose utilization or throughput persistently lags the cluster
//! median are flagged for blacklisting (Sector consumes this feedback).

pub mod collector;
pub mod detect;
pub mod heatmap;
pub mod series;

pub use collector::{Monitor, NodeSample};
pub use detect::{detect_stragglers, StragglerReport};
pub use heatmap::render_heatmap;
pub use series::Series;

//! Underperformer detection (paper §3, §8).
//!
//! "It was through this system that the sometimes dramatic impact on an
//! application of just one or two nodes with slightly inferior performance
//! was first noted." Sector uses the same signal to "remove nodes and/or
//! network segments that exhibit poor performance". The detector compares
//! each node's recent metric to the cluster median: anything persistently
//! below `threshold × median` (for throughput-like metrics) is flagged.

use crate::net::{NodeId, Topology};

use super::collector::Monitor;

/// A flagged underperformer.
#[derive(Debug, Clone, PartialEq)]
pub struct StragglerReport {
    pub node: NodeId,
    pub metric: String,
    pub value: f64,
    pub cluster_median: f64,
}

/// Flag nodes whose recent mean NIC throughput is below
/// `threshold × median` of nodes *doing comparable work* (only nodes with
/// nonzero activity participate; an idle rack is not a straggler).
pub fn detect_stragglers(
    mon: &Monitor,
    topo: &Topology,
    window: usize,
    threshold: f64,
) -> Vec<StragglerReport> {
    assert!((0.0..1.0).contains(&threshold));
    let rates: Vec<(NodeId, f64)> = topo
        .node_ids()
        .into_iter()
        .map(|n| (n, mon.node_nic_rate(n, window)))
        .collect();
    let active: Vec<f64> = rates.iter().map(|(_, r)| *r).filter(|&r| r > 0.0).collect();
    if active.len() < 3 {
        return Vec::new(); // not enough signal
    }
    let median = crate::util::stats::median(&active);
    if median <= 0.0 {
        return Vec::new();
    }
    rates
        .into_iter()
        .filter(|&(_, r)| r > 0.0 && r < threshold * median)
        .map(|(node, value)| StragglerReport {
            node,
            metric: "nic_rate".into(),
            value,
            cluster_median: median,
        })
        .collect()
}

/// Same analysis over CPU-speed-like series (used in tests and by Sphere's
/// blacklist when CPU, not network, is the lagging resource).
pub fn detect_slow_values(values: &[(NodeId, f64)], threshold: f64) -> Vec<NodeId> {
    let active: Vec<f64> = values.iter().map(|&(_, v)| v).filter(|&v| v > 0.0).collect();
    if active.len() < 3 {
        return Vec::new();
    }
    let median = crate::util::stats::median(&active);
    values
        .iter()
        .filter(|&&(_, v)| v > 0.0 && v < threshold * median)
        .map(|&(n, _)| n)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topology::NodeSpec;
    use crate::net::{FlowNet, Topology};
    use crate::sim::resources::CpuPool;
    use crate::sim::Engine;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn detects_injected_slow_node() {
        // 8 nodes move data at full NIC rate; one node's NIC is degraded.
        let mut t = Topology::new();
        let s = t.add_site("s");
        let spec = NodeSpec { nic_bps: 100.0, disk_bps: 1e9, cpu_slots: 2 };
        t.add_rack(s, 8, &spec, 10_000.0);
        let slow = t.racks[0].nodes[7];
        let slow_tx = t.node(slow).nic_tx;
        t.set_link_capacity(slow_tx, 40.0); // "slightly inferior" NIC
        let topo = Rc::new(t);
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let pools: Vec<Rc<RefCell<CpuPool>>> =
            topo.nodes.iter().map(|n| CpuPool::new(n.cpu_slots)).collect();
        let mon = Monitor::new(topo.clone(), 1.0);
        Monitor::install(&mon, &mut eng, &net, pools);
        // Every node streams to its neighbor for 20 s.
        for i in 0..8 {
            let path = topo.path(topo.racks[0].nodes[i], topo.racks[0].nodes[(i + 1) % 8]);
            FlowNet::start(&net, &mut eng, path, 1e5, f64::INFINITY, |_| {});
        }
        eng.run_until(20.0);
        mon.borrow_mut().disable();
        eng.run_until(21.0);
        let reports = detect_stragglers(&mon.borrow(), &topo, 10, 0.75);
        // The degraded node is flagged; its downstream peer (which receives
        // at the degraded rate) may legitimately be flagged with it — the
        // paper's "nodes and/or network segments".
        assert!(
            reports.iter().any(|r| r.node == slow),
            "slow node not flagged: {reports:?}"
        );
        assert!(reports.len() <= 2, "over-flagging: {reports:?}");
        for r in &reports {
            assert!(r.value < r.cluster_median);
        }
    }

    #[test]
    fn healthy_cluster_flags_nothing() {
        let mut t = Topology::new();
        let s = t.add_site("s");
        let spec = NodeSpec { nic_bps: 100.0, disk_bps: 1e9, cpu_slots: 2 };
        t.add_rack(s, 6, &spec, 10_000.0);
        let topo = Rc::new(t);
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let pools: Vec<Rc<RefCell<CpuPool>>> =
            topo.nodes.iter().map(|n| CpuPool::new(n.cpu_slots)).collect();
        let mon = Monitor::new(topo.clone(), 1.0);
        Monitor::install(&mon, &mut eng, &net, pools);
        for i in 0..6 {
            let path = topo.path(topo.racks[0].nodes[i], topo.racks[0].nodes[(i + 1) % 6]);
            FlowNet::start(&net, &mut eng, path, 1e5, f64::INFINITY, |_| {});
        }
        eng.run_until(10.0);
        mon.borrow_mut().disable();
        eng.run_until(11.0);
        assert!(detect_stragglers(&mon.borrow(), &topo, 5, 0.7).is_empty());
    }

    #[test]
    fn idle_nodes_not_stragglers() {
        let vals = vec![
            (NodeId(0), 100.0),
            (NodeId(1), 100.0),
            (NodeId(2), 95.0),
            (NodeId(3), 0.0), // idle, not slow
        ];
        assert!(detect_slow_values(&vals, 0.7).is_empty());
    }

    #[test]
    fn slow_values_detector() {
        let vals = vec![
            (NodeId(0), 100.0),
            (NodeId(1), 110.0),
            (NodeId(2), 90.0),
            (NodeId(3), 30.0),
        ];
        assert_eq!(detect_slow_values(&vals, 0.7), vec![NodeId(3)]);
    }

    #[test]
    fn too_few_samples_no_flags() {
        let vals = vec![(NodeId(0), 100.0), (NodeId(1), 10.0)];
        assert!(detect_slow_values(&vals, 0.7).is_empty());
    }
}

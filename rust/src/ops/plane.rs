//! The in-simulation operations plane (paper §4): distributed monitoring
//! whose telemetry is *in-band*, a central health state machine, anomaly
//! detectors, and closed-loop remediation.
//!
//! Three tiers, mirroring the paper's monitoring system:
//!
//! - **SensorAgent** (per placed node): every heartbeat interval it reads
//!   its node-local counters (free — they are on the box) and ships a
//!   GMP-framed heartbeat+sample message to its site's aggregator as a
//!   *real simulated flow*, consuming NIC and rack-uplink bandwidth. A
//!   crashed node's sensor goes dark — that silence *is* the failure
//!   signal.
//! - **Aggregator** (per site, first placed node): batches its site's
//!   samples plus a link-capacity probe of the shared wave and relays one
//!   summary message across the WAN to the central service each
//!   aggregation interval.
//! - **OpsService** (central, first placed node): tracks per-node
//!   `Healthy → Suspect → Dead` on missed heartbeats, runs hotspot /
//!   straggler / WAN-degradation detectors over the relayed samples,
//!   appends to an alert log, and closes the loop: a `Dead` verdict emits
//!   an [`Op::DrainNode`] plus an [`Op::ImageNode`] re-imaging intent
//!   (see [`RECOVERY_IMAGE`]) and invokes the dataflow's heal hook
//!   (re-executing lost tasks); a degraded wave emits
//!   [`Op::SetWanCapacity`] and invokes the lightpath-restore hook.
//!
//! Because detection rides the same simulated network as the workload,
//! monitoring overhead (telemetry bytes on the WAN), detection latency
//! (heartbeat cadence × thresholds + relay delay), and failure response
//! (re-execution cost) are all *measured*, not assumed — the
//! [`OpsReport`] carried by every ops-enabled `RunReport` quantifies
//! them.
//!
//! The ops plane's telemetry and remediation hops cut across every flow
//! domain (node → site aggregator → central service) through shared
//! closure state rather than the sharded engine's latency-bounded
//! channels, so ops-enabled scenarios always run on the sequential
//! engine — [`crate::coordinator::ScenarioRunner`]'s shardable gate
//! excludes them by shape.

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};
use std::rc::Rc;

use crate::coordinator::provision::Op;
use crate::monitor::Series;
use crate::net::topology::LinkKind;
use crate::net::{Cluster, FlowNet, LinkId, NodeId, Topology};
use crate::sim::Engine;
use crate::trace::Arg;
use crate::util::json::{obj, Json};

/// GMP fixed header prepended to every telemetry datagram (see
/// [`crate::gmp::wire::HEADER_LEN`]).
pub const GMP_HEADER_BYTES: f64 = crate::gmp::wire::HEADER_LEN as f64;
/// Endpoint processing before a datagram hits the wire / after it lands.
const GMP_PROC_SECS: f64 = 40e-6;
/// Fixed part of a site summary (site id, counts, wave probe).
const SITE_SUMMARY_BYTES: f64 = 48.0;
/// Per-node entry relayed inside a site summary.
const PER_NODE_ENTRY_BYTES: f64 = 24.0;
/// Retained per-node rate reports at the central service.
const RATE_SERIES_CAP: usize = 64;

/// Image a dead node is queued to be rebuilt with: the remediation path
/// emits an [`Op::ImageNode`] with this name right after the drain, so a
/// replay of the ops log brings the box back as a freshly-imaged spare
/// instead of whatever half-state it died in.
pub const RECOVERY_IMAGE: &str = "oct-recovery-baseline";

/// Operations-plane tunables. The defaults give second-scale detection:
/// `Suspect` after 3 missed heartbeats, `Dead` after 5.
#[derive(Debug, Clone)]
pub struct OpsConfig {
    /// Sensor heartbeat+sample cadence, simulated seconds.
    pub heartbeat_interval: f64,
    /// Aggregator relay cadence.
    pub aggregate_interval: f64,
    /// Central health-check sweep cadence.
    pub check_interval: f64,
    /// Heartbeats missed before `Healthy → Suspect`.
    pub suspect_missed: f64,
    /// Heartbeats missed before `Suspect → Dead` (drain + re-execute).
    pub dead_missed: f64,
    /// A node is a hotspot when its reported NIC rate exceeds this
    /// multiple of the cluster median.
    pub hotspot_factor: f64,
    /// A node is a straggler when its reported NIC rate falls below this
    /// fraction of the cluster median.
    pub straggler_factor: f64,
    /// The wave is degraded when its probed capacity falls below this
    /// fraction of nominal.
    pub wan_degraded_fraction: f64,
    /// Sample payload bytes per heartbeat (on top of the GMP header).
    pub sample_bytes: f64,
    /// When false, detection still runs but remediation hooks do not fire
    /// (observe-only mode).
    pub self_heal: bool,
}

impl Default for OpsConfig {
    fn default() -> Self {
        OpsConfig {
            heartbeat_interval: 1.0,
            aggregate_interval: 1.0,
            check_interval: 1.0,
            suspect_missed: 3.0,
            dead_missed: 5.0,
            hotspot_factor: 4.0,
            straggler_factor: 0.5,
            wan_degraded_fraction: 0.75,
            sample_bytes: 64.0,
            self_heal: true,
        }
    }
}

/// Per-node health as seen by the central service.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Healthy,
    Suspect,
    Dead,
}

/// What an alert is about.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlertKind {
    NodeSuspect,
    NodeDead,
    NodeRecovered,
    Hotspot,
    Straggler,
    WanDegraded,
    WanRestored,
    TasksReExecuted,
}

impl AlertKind {
    pub fn name(&self) -> &'static str {
        match self {
            AlertKind::NodeSuspect => "node-suspect",
            AlertKind::NodeDead => "node-dead",
            AlertKind::NodeRecovered => "node-recovered",
            AlertKind::Hotspot => "hotspot",
            AlertKind::Straggler => "straggler",
            AlertKind::WanDegraded => "wan-degraded",
            AlertKind::WanRestored => "wan-restored",
            AlertKind::TasksReExecuted => "tasks-reexecuted",
        }
    }

    pub fn parse(s: &str) -> Option<AlertKind> {
        [
            AlertKind::NodeSuspect,
            AlertKind::NodeDead,
            AlertKind::NodeRecovered,
            AlertKind::Hotspot,
            AlertKind::Straggler,
            AlertKind::WanDegraded,
            AlertKind::WanRestored,
            AlertKind::TasksReExecuted,
        ]
        .into_iter()
        .find(|k| k.name() == s)
    }
}

/// One entry of the central service's alert log.
#[derive(Debug, Clone, PartialEq)]
pub struct Alert {
    /// Simulated time the alert fired.
    pub t: f64,
    pub kind: AlertKind,
    /// What it concerns (a node name, or `"wave"`).
    pub subject: String,
    pub detail: String,
}

impl Alert {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("t", Json::Num(self.t)),
            ("kind", Json::Str(self.kind.name().to_string())),
            ("subject", Json::Str(self.subject.clone())),
            ("detail", Json::Str(self.detail.clone())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Alert, String> {
        let kind_s = j.get("kind").and_then(Json::as_str).ok_or("missing alert 'kind'")?;
        Ok(Alert {
            t: j.get("t").and_then(Json::as_f64).ok_or("missing alert 't'")?,
            kind: AlertKind::parse(kind_s).ok_or_else(|| format!("unknown alert kind '{kind_s}'"))?,
            subject: j
                .get("subject")
                .and_then(Json::as_str)
                .ok_or("missing alert 'subject'")?
                .to_string(),
            detail: j
                .get("detail")
                .and_then(Json::as_str)
                .ok_or("missing alert 'detail'")?
                .to_string(),
        })
    }
}

/// The operations plane's contribution to a `RunReport`.
#[derive(Debug, Clone, PartialEq)]
pub struct OpsReport {
    pub heartbeat_interval: f64,
    /// Telemetry messages shipped (heartbeats + site summaries).
    pub telemetry_msgs: u64,
    /// Total telemetry bytes, GMP framing included.
    pub telemetry_bytes: f64,
    /// The subset of telemetry bytes whose path crossed the WAN.
    pub telemetry_wan_bytes: f64,
    /// Nodes that actually crashed (ground truth from the fault plan).
    pub crashed_nodes: usize,
    /// Nodes the service declared `Dead`.
    pub dead_declared: usize,
    /// `Dead` verdicts on nodes that never crashed (false positives).
    pub false_dead: usize,
    /// Worst crash → `Dead`-verdict gap, seconds (0 when nothing died).
    pub detection_latency_max: f64,
    /// Tasks re-executed by the heal hook across the run.
    pub reexecuted_tasks: usize,
    /// Remediation intents emitted (drains, wave re-provisioning).
    pub remediation_ops: usize,
    pub alerts: Vec<Alert>,
}

impl OpsReport {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("heartbeat_interval", Json::Num(self.heartbeat_interval)),
            ("telemetry_msgs", Json::Num(self.telemetry_msgs as f64)),
            ("telemetry_bytes", Json::Num(self.telemetry_bytes)),
            ("telemetry_wan_bytes", Json::Num(self.telemetry_wan_bytes)),
            ("crashed_nodes", Json::Num(self.crashed_nodes as f64)),
            ("dead_declared", Json::Num(self.dead_declared as f64)),
            ("false_dead", Json::Num(self.false_dead as f64)),
            ("detection_latency_max", Json::Num(self.detection_latency_max)),
            ("reexecuted_tasks", Json::Num(self.reexecuted_tasks as f64)),
            ("remediation_ops", Json::Num(self.remediation_ops as f64)),
            ("alerts", Json::Arr(self.alerts.iter().map(Alert::to_json).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<OpsReport, String> {
        fn num(j: &Json, k: &str) -> Result<f64, String> {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing number '{k}'"))
        }
        let alerts = match j.get("alerts") {
            Some(Json::Arr(xs)) => xs.iter().map(Alert::from_json).collect::<Result<_, _>>()?,
            _ => return Err("missing array 'alerts'".to_string()),
        };
        Ok(OpsReport {
            heartbeat_interval: num(j, "heartbeat_interval")?,
            telemetry_msgs: num(j, "telemetry_msgs")? as u64,
            telemetry_bytes: num(j, "telemetry_bytes")?,
            telemetry_wan_bytes: num(j, "telemetry_wan_bytes")?,
            crashed_nodes: num(j, "crashed_nodes")? as usize,
            dead_declared: num(j, "dead_declared")? as usize,
            false_dead: num(j, "false_dead")? as usize,
            detection_latency_max: num(j, "detection_latency_max")?,
            reexecuted_tasks: num(j, "reexecuted_tasks")? as usize,
            remediation_ops: num(j, "remediation_ops")? as usize,
            alerts,
        })
    }
}

/// A node's latest relayed observation.
struct NodeReport {
    node: NodeId,
    sent_at: f64,
    nic_rate: f64,
}

struct NodeHealth {
    health: Health,
    /// Send-timestamp of the newest heartbeat relayed to central.
    last_heard: f64,
    /// Reported NIC rate history (hotspot/straggler detection uses the
    /// per-node [`Series::p50`] as its robust rate estimate).
    rates: Series,
}

type DeadHook = Box<dyn FnMut(&mut Engine, NodeId) -> usize>;
type WanRestoreHook = Box<dyn FnMut(&mut Engine)>;

/// The running operations plane. Use through `Rc<RefCell<_>>` (like
/// [`crate::monitor::Monitor`]); [`OpsPlane::install`] starts the sensor,
/// aggregator, and health-check loops on the engine.
pub struct OpsPlane {
    cfg: OpsConfig,
    topo: Rc<Topology>,
    net: Rc<RefCell<FlowNet>>,
    nodes: Vec<NodeId>,
    aggregator_of_site: BTreeMap<usize, NodeId>,
    central: NodeId,
    enabled: bool,
    /// Ground truth: crashed nodes and when (set by fault injection).
    crashed: BTreeMap<NodeId, f64>,
    telemetry_msgs: u64,
    telemetry_bytes: f64,
    telemetry_wan_bytes: f64,
    /// Aggregator buffers: site → samples since the last relay.
    agg_pending: BTreeMap<usize, Vec<NodeReport>>,
    /// Central service state.
    tracked: BTreeMap<NodeId, NodeHealth>,
    alerts: Vec<Alert>,
    ops_log: Vec<Op>,
    dead_declared: usize,
    false_dead: usize,
    detection_latency_max: f64,
    reexecuted_tasks: usize,
    hot_flagged: BTreeSet<NodeId>,
    slow_flagged: BTreeSet<NodeId>,
    /// The shared wave's links with their nominal capacities.
    wan_links: Vec<(LinkId, f64)>,
    /// Latest probed aggregate wave capacity (starts at nominal).
    wan_observed: f64,
    wan_degraded: bool,
    dead_hook: Option<DeadHook>,
    wan_restore_hook: Option<WanRestoreHook>,
}

impl OpsPlane {
    /// Build the plane over a deployment (`nodes` = the scenario's placed
    /// nodes) and start its loops. Aggregators are each site's first
    /// placed node; the central service runs on the first placed node
    /// overall.
    pub fn install(
        cluster: &Cluster,
        nodes: &[NodeId],
        cfg: OpsConfig,
        eng: &mut Engine,
    ) -> Rc<RefCell<OpsPlane>> {
        assert!(!nodes.is_empty(), "ops plane needs at least one node");
        assert!(cfg.heartbeat_interval > 0.0 && cfg.aggregate_interval > 0.0);
        assert!(cfg.check_interval > 0.0);
        assert!(cfg.dead_missed > cfg.suspect_missed);
        let topo = cluster.topo.clone();
        let mut aggregator_of_site = BTreeMap::new();
        for &n in nodes {
            aggregator_of_site.entry(topo.node(n).site.0).or_insert(n);
        }
        let now = eng.now();
        let tracked = nodes
            .iter()
            .map(|&n| {
                (
                    n,
                    NodeHealth {
                        health: Health::Healthy,
                        last_heard: now,
                        rates: Series::new(RATE_SERIES_CAP),
                    },
                )
            })
            .collect();
        let netb = cluster.net.borrow();
        let wan_links: Vec<(LinkId, f64)> = topo
            .links
            .iter()
            .enumerate()
            .filter(|(_, l)| l.kind == LinkKind::Wan)
            .map(|(i, _)| (LinkId(i), netb.capacity(LinkId(i))))
            .collect();
        drop(netb);
        let wan_nominal: f64 = wan_links.iter().map(|(_, c)| c).sum();
        let plane = Rc::new(RefCell::new(OpsPlane {
            central: nodes[0],
            nodes: nodes.to_vec(),
            aggregator_of_site,
            topo,
            net: cluster.net.clone(),
            enabled: true,
            crashed: BTreeMap::new(),
            telemetry_msgs: 0,
            telemetry_bytes: 0.0,
            telemetry_wan_bytes: 0.0,
            agg_pending: BTreeMap::new(),
            tracked,
            alerts: Vec::new(),
            ops_log: Vec::new(),
            dead_declared: 0,
            false_dead: 0,
            detection_latency_max: 0.0,
            reexecuted_tasks: 0,
            hot_flagged: BTreeSet::new(),
            slow_flagged: BTreeSet::new(),
            wan_links,
            wan_observed: wan_nominal,
            wan_degraded: false,
            dead_hook: None,
            wan_restore_hook: None,
            cfg,
        }));
        {
            let p = plane.borrow();
            // Stagger sensors across the heartbeat interval so 100+ nodes
            // don't synchronize into one event storm.
            for (i, &n) in p.nodes.iter().enumerate() {
                let offset =
                    p.cfg.heartbeat_interval * (i as f64 + 1.0) / (p.nodes.len() as f64 + 1.0);
                Self::sensor_tick(plane.clone(), eng, n, offset);
            }
            let sites: Vec<(usize, NodeId)> = {
                let mut v: Vec<_> = p.aggregator_of_site.iter().map(|(&s, &a)| (s, a)).collect();
                v.sort_unstable();
                v
            };
            for (site, agg) in sites {
                Self::aggregator_tick(plane.clone(), eng, site, agg, p.cfg.aggregate_interval);
            }
            Self::check_tick(plane.clone(), eng, p.cfg.check_interval);
        }
        plane
    }

    /// Wire the `Dead`-verdict remediation: called with the dead node,
    /// returns how many tasks it re-queued (the dataflow's heal).
    pub fn set_dead_hook(&mut self, hook: DeadHook) {
        self.dead_hook = Some(hook);
    }

    /// Wire the degraded-wave remediation (re-provision to nominal).
    pub fn set_wan_restore_hook(&mut self, hook: WanRestoreHook) {
        self.wan_restore_hook = Some(hook);
    }

    /// Fault-injection ground truth: the node halted at `now`. Its sensor
    /// stops at the next tick; detection must come from the silence.
    pub fn mark_crashed(&mut self, node: NodeId, now: f64) {
        self.crashed.entry(node).or_insert(now);
    }

    /// Stop all loops at their next tick (lets the event heap drain).
    pub fn disable(&mut self) {
        self.enabled = false;
    }

    pub fn alerts(&self) -> &[Alert] {
        &self.alerts
    }

    /// Remediation intents emitted so far (replayable against a
    /// [`crate::coordinator::Provisioner`] seeded with the same base).
    pub fn ops_log(&self) -> &[Op] {
        &self.ops_log
    }

    pub fn health_of(&self, node: NodeId) -> Option<Health> {
        self.tracked.get(&node).map(|h| h.health)
    }

    /// The shared wave's links with their nominal capacities (snapshot at
    /// install time) — the restore targets remediation drives back to.
    pub fn wan_nominals(&self) -> &[(LinkId, f64)] {
        &self.wan_links
    }

    /// Snapshot the run's operations metrics.
    pub fn report(&self) -> OpsReport {
        OpsReport {
            heartbeat_interval: self.cfg.heartbeat_interval,
            telemetry_msgs: self.telemetry_msgs,
            telemetry_bytes: self.telemetry_bytes,
            telemetry_wan_bytes: self.telemetry_wan_bytes,
            crashed_nodes: self.crashed.len(),
            dead_declared: self.dead_declared,
            false_dead: self.false_dead,
            detection_latency_max: self.detection_latency_max,
            reexecuted_tasks: self.reexecuted_tasks,
            remediation_ops: self.ops_log.len(),
            alerts: self.alerts.clone(),
        }
    }

    fn alert(&mut self, t: f64, kind: AlertKind, subject: impl Into<String>, detail: String) {
        self.alerts.push(Alert { t, kind, subject: subject.into(), detail });
    }

    // ---- telemetry transport -----------------------------------------

    /// Ship `bytes` of telemetry from `src` to `dst` as a real flow
    /// (GMP-style: connectionless, one-way latency then line-rate
    /// datagrams), then deliver. Loopback messages skip the network.
    fn ship<F: FnOnce(&mut Engine) + 'static>(
        plane: &Rc<RefCell<OpsPlane>>,
        eng: &mut Engine,
        src: NodeId,
        dst: NodeId,
        bytes: f64,
        deliver: F,
    ) {
        let shipped = {
            let mut p = plane.borrow_mut();
            p.telemetry_msgs += 1;
            p.telemetry_bytes += bytes;
            if src != dst && p.topo.node(src).site != p.topo.node(dst).site {
                p.telemetry_wan_bytes += bytes;
            }
            if src == dst {
                None
            } else {
                Some((p.net.clone(), p.topo.route(src, dst), 0.5 * p.topo.rtt(src, dst)))
            }
        };
        match shipped {
            None => {
                eng.schedule_in(GMP_PROC_SECS, deliver);
            }
            Some((net, route, owd)) => {
                eng.schedule_in(owd + GMP_PROC_SECS, move |eng| {
                    FlowNet::start_route(&net, eng, route, bytes, f64::INFINITY, deliver);
                });
            }
        }
    }

    // ---- sensor tier --------------------------------------------------

    fn sensor_tick(plane: Rc<RefCell<OpsPlane>>, eng: &mut Engine, node: NodeId, delay: f64) {
        eng.schedule_in(delay, move |eng| {
            let (enabled, crashed, hb) = {
                let p = plane.borrow();
                (p.enabled, p.crashed.contains_key(&node), p.cfg.heartbeat_interval)
            };
            if !enabled || crashed {
                return; // dark: a dead box sends nothing
            }
            Self::send_sample(&plane, eng, node);
            Self::sensor_tick(plane, eng, node, hb);
        });
    }

    fn send_sample(plane: &Rc<RefCell<OpsPlane>>, eng: &mut Engine, node: NodeId) {
        let (agg, site, bytes, nic_rate) = {
            let p = plane.borrow();
            let site = p.topo.node(node).site.0;
            let agg = p.aggregator_of_site[&site];
            let nd = p.topo.node(node);
            let n = p.net.borrow();
            let nic = n.link_rate(nd.nic_tx) + n.link_rate(nd.nic_rx);
            (agg, site, GMP_HEADER_BYTES + p.cfg.sample_bytes, nic)
        };
        let report = NodeReport { node, sent_at: eng.now(), nic_rate };
        let plane2 = plane.clone();
        Self::ship(plane, eng, node, agg, bytes, move |_eng| {
            let mut p = plane2.borrow_mut();
            // A crashed aggregator drops whatever lands on it.
            if !p.crashed.contains_key(&agg) {
                p.agg_pending.entry(site).or_default().push(report);
            }
        });
    }

    // ---- aggregator tier ----------------------------------------------

    fn aggregator_tick(
        plane: Rc<RefCell<OpsPlane>>,
        eng: &mut Engine,
        site: usize,
        agg: NodeId,
        interval: f64,
    ) {
        eng.schedule_in(interval, move |eng| {
            let (enabled, crashed) = {
                let p = plane.borrow();
                (p.enabled, p.crashed.contains_key(&agg))
            };
            if !enabled || crashed {
                return; // the site goes dark with its aggregator
            }
            Self::relay_site(&plane, eng, site, agg);
            Self::aggregator_tick(plane, eng, site, agg, interval);
        });
    }

    fn relay_site(plane: &Rc<RefCell<OpsPlane>>, eng: &mut Engine, site: usize, agg: NodeId) {
        let (central, reports, wan_obs, bytes) = {
            let mut p = plane.borrow_mut();
            let reports = p.agg_pending.remove(&site).unwrap_or_default();
            // Link-capacity probe of the shared wave (the aggregator's
            // site edge terminates on it): what an iperf/SNMP probe of the
            // lightpath would read right now.
            let n = p.net.borrow();
            let wan_obs: f64 = p.wan_links.iter().map(|(l, _)| n.capacity(*l)).sum();
            drop(n);
            let bytes = GMP_HEADER_BYTES
                + SITE_SUMMARY_BYTES
                + PER_NODE_ENTRY_BYTES * reports.len() as f64;
            (p.central, reports, wan_obs, bytes)
        };
        let plane2 = plane.clone();
        Self::ship(plane, eng, agg, central, bytes, move |eng| {
            Self::central_ingest(&plane2, eng, reports, wan_obs);
        });
    }

    // ---- central service ----------------------------------------------

    fn central_ingest(
        plane: &Rc<RefCell<OpsPlane>>,
        eng: &mut Engine,
        reports: Vec<NodeReport>,
        wan_obs: f64,
    ) {
        let now = eng.now();
        let mut p = plane.borrow_mut();
        if p.crashed.contains_key(&p.central) {
            return; // the summary landed on a dead box
        }
        for r in reports {
            let Some(h) = p.tracked.get_mut(&r.node) else { continue };
            if r.sent_at > h.last_heard {
                h.last_heard = r.sent_at;
            }
            h.rates.push(r.sent_at, r.nic_rate);
            // A heartbeat clears suspicion; Dead is sticky (drained).
            let recovered = h.health == Health::Suspect;
            if recovered {
                h.health = Health::Healthy;
            }
            if recovered {
                let name = p.topo.node(r.node).name.clone();
                p.alert(now, AlertKind::NodeRecovered, name, "heartbeat resumed".to_string());
                if let Some(rec) = eng.recorder() {
                    let dom = p.topo.node(r.node).site.0 as u16;
                    rec.instant(now, dom, r.node.0 as u32, "alert.recovered", 0, &[]);
                }
            }
        }
        p.wan_observed = wan_obs;
    }

    fn check_tick(plane: Rc<RefCell<OpsPlane>>, eng: &mut Engine, interval: f64) {
        eng.schedule_in(interval, move |eng| {
            let halted = {
                let p = plane.borrow();
                // A crashed central halts with its host: the plane goes
                // dark (no failover modeled) instead of a dead box still
                // issuing verdicts and remediation.
                !p.enabled || p.crashed.contains_key(&p.central)
            };
            if halted {
                return;
            }
            Self::run_checks(&plane, eng);
            Self::check_tick(plane, eng, interval);
        });
    }

    /// One health sweep: the state machine, the detectors, and — outside
    /// the plane borrow — the remediation hooks.
    fn run_checks(plane: &Rc<RefCell<OpsPlane>>, eng: &mut Engine) {
        let now = eng.now();
        let mut newly_dead: Vec<NodeId> = Vec::new();
        let mut restore_wan = false;
        {
            let mut p = plane.borrow_mut();
            let hb = p.cfg.heartbeat_interval;
            let suspect_after = p.cfg.suspect_missed * hb;
            let dead_after = p.cfg.dead_missed * hb;
            // Health state machine on heartbeat staleness.
            let nodes = p.nodes.clone();
            for n in nodes {
                let silent = now - p.tracked[&n].last_heard;
                let health = p.tracked[&n].health;
                match health {
                    Health::Healthy if silent > suspect_after => {
                        p.tracked.get_mut(&n).unwrap().health = Health::Suspect;
                        let name = p.topo.node(n).name.clone();
                        p.alert(
                            now,
                            AlertKind::NodeSuspect,
                            name,
                            format!("no heartbeat for {silent:.1}s"),
                        );
                        if let Some(rec) = eng.recorder() {
                            let dom = p.topo.node(n).site.0 as u16;
                            rec.instant(now, dom, n.0 as u32, "alert.suspect", 0, &[]);
                        }
                    }
                    Health::Suspect if silent > dead_after => {
                        p.tracked.get_mut(&n).unwrap().health = Health::Dead;
                        p.dead_declared += 1;
                        let fault_t = p.crashed.get(&n).copied();
                        match fault_t {
                            Some(t0) => {
                                let latency = now - t0;
                                if latency > p.detection_latency_max {
                                    p.detection_latency_max = latency;
                                }
                            }
                            None => p.false_dead += 1,
                        }
                        let name = p.topo.node(n).name.clone();
                        p.alert(
                            now,
                            AlertKind::NodeDead,
                            name,
                            format!("no heartbeat for {silent:.1}s; draining"),
                        );
                        // The causal link back to the injection: alert.dead
                        // carries the fault's injection time, so a trace
                        // viewer can measure detection latency span-to-span.
                        if let Some(rec) = eng.recorder() {
                            let dom = p.topo.node(n).site.0 as u16;
                            match fault_t {
                                Some(t0) => rec.instant(
                                    now,
                                    dom,
                                    n.0 as u32,
                                    "alert.dead",
                                    0,
                                    &[("fault_t", Arg::F(t0))],
                                ),
                                None => rec.instant(now, dom, n.0 as u32, "alert.dead", 0, &[]),
                            }
                        }
                        // Drain now, and queue a bare-metal re-image so
                        // the box re-enters the pool clean — the
                        // provisioning half of the remediation intent.
                        p.ops_log.push(Op::DrainNode { node: n.0 });
                        p.ops_log.push(Op::ImageNode {
                            node: n.0,
                            image: RECOVERY_IMAGE.to_string(),
                        });
                        newly_dead.push(n);
                    }
                    _ => {}
                }
            }
            // Hotspot / straggler detectors over relayed rates. Each node
            // is represented by the median of its reported history
            // (Series::p50 — robust to single-sample spikes).
            let rates: Vec<(NodeId, f64)> = p
                .nodes
                .iter()
                .filter(|n| p.tracked[n].health != Health::Dead)
                .map(|&n| (n, p.tracked[&n].rates.p50()))
                .collect();
            let active: Vec<f64> = rates.iter().map(|&(_, r)| r).filter(|&r| r > 0.0).collect();
            if active.len() >= 3 {
                let median = crate::util::stats::percentile(&active, 50.0);
                if median > 0.0 {
                    for &(n, r) in &rates {
                        if r <= 0.0 {
                            continue; // idle, not slow
                        }
                        if r > p.cfg.hotspot_factor * median && p.hot_flagged.insert(n) {
                            let name = p.topo.node(n).name.clone();
                            p.alert(
                                now,
                                AlertKind::Hotspot,
                                name,
                                format!("nic {r:.0} B/s vs median {median:.0} B/s"),
                            );
                            if let Some(rec) = eng.recorder() {
                                let dom = p.topo.node(n).site.0 as u16;
                                let a = [("rate", Arg::F(r))];
                                rec.instant(now, dom, n.0 as u32, "alert.hotspot", 0, &a);
                            }
                        }
                        if r < p.cfg.straggler_factor * median && p.slow_flagged.insert(n) {
                            let name = p.topo.node(n).name.clone();
                            p.alert(
                                now,
                                AlertKind::Straggler,
                                name,
                                format!("nic {r:.0} B/s vs median {median:.0} B/s"),
                            );
                            if let Some(rec) = eng.recorder() {
                                let dom = p.topo.node(n).site.0 as u16;
                                let a = [("rate", Arg::F(r))];
                                rec.instant(now, dom, n.0 as u32, "alert.straggler", 0, &a);
                            }
                        }
                    }
                }
            }
            // WAN degradation from the aggregators' wave probe.
            let nominal: f64 = p.wan_links.iter().map(|&(_, c)| c).sum();
            if !p.wan_degraded
                && nominal > 0.0
                && p.wan_observed < p.cfg.wan_degraded_fraction * nominal
            {
                p.wan_degraded = true;
                let obs = p.wan_observed;
                p.alert(
                    now,
                    AlertKind::WanDegraded,
                    "wave",
                    format!("probed {obs:.2e} B/s of nominal {nominal:.2e} B/s"),
                );
                if let Some(rec) = eng.recorder() {
                    let wan = (p.topo.num_domains() - 1) as u16;
                    let a = [("observed", Arg::F(obs)), ("nominal", Arg::F(nominal))];
                    rec.instant(now, wan, 0, "alert.wan_degraded", 0, &a);
                }
                // Replayable intent: re-provision the shared wave back to
                // nominal (any site pair addresses the shared links).
                let gbps = p.wan_links.iter().map(|&(_, c)| c).fold(0.0, f64::max) * 8.0 / 1e9;
                p.ops_log.push(Op::SetWanCapacity { a: 0, b: 1, gbps });
                restore_wan = p.cfg.self_heal;
            }
        }
        // Remediation, with the plane borrow released: hooks reach into
        // the dataflow and the fluid network.
        for n in newly_dead {
            let hook = plane.borrow_mut().dead_hook.take();
            if let Some(mut h) = hook {
                let requeued = h(eng, n);
                let mut p = plane.borrow_mut();
                p.reexecuted_tasks += requeued;
                if requeued > 0 {
                    let name = p.topo.node(n).name.clone();
                    p.alert(
                        now,
                        AlertKind::TasksReExecuted,
                        name,
                        format!("{requeued} lost task(s) re-queued on survivors"),
                    );
                    let dom = p.topo.node(n).site.0 as u16;
                    if let Some(rec) = eng.recorder() {
                        let a = [("requeued", Arg::U(requeued as u64))];
                        rec.instant(now, dom, n.0 as u32, "alert.reexec", 0, &a);
                    }
                }
                p.dead_hook = Some(h);
            }
        }
        if restore_wan {
            let hook = plane.borrow_mut().wan_restore_hook.take();
            if let Some(mut h) = hook {
                h(eng);
                let mut p = plane.borrow_mut();
                p.wan_degraded = false; // restored; a later flap re-arms
                // The last relayed probe predates the restore; reset the
                // observed capacity to nominal so the next sweep doesn't
                // re-detect the already-healed flap from a stale reading.
                p.wan_observed = p.wan_links.iter().map(|&(_, c)| c).sum();
                p.alert(now, AlertKind::WanRestored, "wave", "re-provisioned to nominal".into());
                if let Some(rec) = eng.recorder() {
                    let wan = (p.topo.num_domains() - 1) as u16;
                    rec.instant(now, wan, 0, "alert.wan_restored", 0, &[]);
                }
                p.wan_restore_hook = Some(h);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topology::NodeSpec;

    fn two_site_cluster() -> Cluster {
        let mut t = Topology::new();
        let a = t.add_site("a");
        let b = t.add_site("b");
        let spec = NodeSpec::default();
        t.add_rack(a, 2, &spec, 1.25e9);
        t.add_rack(b, 2, &spec, 1.25e9);
        t.connect_sites(a, b, 1.25e9, 0.04);
        Cluster::new(t)
    }

    fn drive(plane: &Rc<RefCell<OpsPlane>>, eng: &mut Engine, until: f64) {
        eng.run_until(until);
        plane.borrow_mut().disable();
        eng.run();
    }

    #[test]
    fn healthy_cluster_stays_healthy_and_accounts_telemetry() {
        let cluster = two_site_cluster();
        let nodes = cluster.topo.node_ids();
        let mut eng = Engine::new();
        let plane = OpsPlane::install(&cluster, &nodes, OpsConfig::default(), &mut eng);
        drive(&plane, &mut eng, 20.0);
        let p = plane.borrow();
        for &n in &nodes {
            assert_eq!(p.health_of(n), Some(Health::Healthy), "{n:?}");
        }
        let r = p.report();
        // ~20 beats × 4 nodes + ~20 relays × 2 sites.
        assert!(r.telemetry_msgs > 80, "{}", r.telemetry_msgs);
        assert!(r.telemetry_bytes > 0.0);
        // Site b's aggregator relays across the WAN to central (site a).
        assert!(r.telemetry_wan_bytes > 0.0);
        assert!(r.telemetry_wan_bytes < r.telemetry_bytes);
        assert_eq!(r.dead_declared, 0);
        assert_eq!(r.false_dead, 0);
        assert_eq!(r.detection_latency_max, 0.0);
        assert!(r.alerts.is_empty(), "{:?}", r.alerts);
        // The telemetry actually crossed the fluid network.
        assert!(cluster.net.borrow().completions() > 40);
    }

    #[test]
    fn crash_is_detected_within_bound_and_drained() {
        let cluster = two_site_cluster();
        let nodes = cluster.topo.node_ids();
        let victim = nodes[3]; // site b, not an aggregator (node 2 is)
        let mut eng = Engine::new();
        let cfg = OpsConfig::default();
        let plane = OpsPlane::install(&cluster, &nodes, cfg.clone(), &mut eng);
        let healed: Rc<RefCell<Vec<NodeId>>> = Rc::new(RefCell::new(Vec::new()));
        {
            let h = healed.clone();
            plane.borrow_mut().set_dead_hook(Box::new(move |_eng, n| {
                h.borrow_mut().push(n);
                3 // pretend three tasks were re-queued
            }));
        }
        let p2 = plane.clone();
        eng.schedule_at(5.0, move |eng| {
            p2.borrow_mut().mark_crashed(victim, eng.now());
        });
        drive(&plane, &mut eng, 30.0);
        let p = plane.borrow();
        assert_eq!(p.health_of(victim), Some(Health::Dead));
        let r = p.report();
        assert_eq!(r.crashed_nodes, 1);
        assert_eq!(r.dead_declared, 1);
        assert_eq!(r.false_dead, 0, "healthy nodes mis-declared: {:?}", r.alerts);
        // Bounded detection: dead threshold + heartbeat phase + relay +
        // check-tick granularity.
        let bound = (cfg.dead_missed + 3.0) * cfg.heartbeat_interval;
        assert!(
            r.detection_latency_max > 0.0 && r.detection_latency_max <= bound,
            "latency {} vs bound {bound}",
            r.detection_latency_max
        );
        assert_eq!(r.reexecuted_tasks, 3);
        assert_eq!(*healed.borrow(), vec![victim]);
        assert!(p.ops_log().contains(&Op::DrainNode { node: victim.0 }));
        // The drain is followed by a queued re-image of the dead box.
        assert!(p
            .ops_log()
            .contains(&Op::ImageNode { node: victim.0, image: RECOVERY_IMAGE.to_string() }));
        // The remediation intents replay onto a provisioner: the box ends
        // drained and stamped with the recovery image.
        let mut prov = crate::coordinator::Provisioner::oct_2009();
        for op in p.ops_log().to_vec() {
            prov.apply(&op);
        }
        assert!(prov.drained().contains(&victim));
        assert_eq!(prov.node_image(victim.0), Some(RECOVERY_IMAGE));
        let kinds: Vec<AlertKind> = r.alerts.iter().map(|a| a.kind).collect();
        assert!(kinds.contains(&AlertKind::NodeSuspect));
        assert!(kinds.contains(&AlertKind::NodeDead));
        assert!(kinds.contains(&AlertKind::TasksReExecuted));
        // The alert names the right box.
        let dead: Vec<&Alert> =
            r.alerts.iter().filter(|a| a.kind == AlertKind::NodeDead).collect();
        assert_eq!(dead.len(), 1);
        assert_eq!(dead[0].subject, cluster.topo.node(victim).name);
    }

    #[test]
    fn traced_crash_emits_alert_instants_with_fault_link() {
        use crate::trace::{Recorder, Stream, TraceSpec};
        let cluster = two_site_cluster();
        let nodes = cluster.topo.node_ids();
        let victim = nodes[3];
        let mut eng = Engine::new();
        eng.set_recorder(Recorder::new(&TraceSpec::new()));
        let plane = OpsPlane::install(&cluster, &nodes, OpsConfig::default(), &mut eng);
        plane.borrow_mut().set_dead_hook(Box::new(|_eng, _n| 2));
        let p2 = plane.clone();
        eng.schedule_at(5.0, move |eng| {
            p2.borrow_mut().mark_crashed(victim, eng.now());
        });
        drive(&plane, &mut eng, 30.0);
        let mut s = Stream::new(2);
        s.absorb(eng.take_recorder().unwrap());
        let js = s.to_chrome_json();
        assert!(js.contains("alert.suspect"), "{js}");
        assert!(js.contains("alert.dead"), "{js}");
        assert!(js.contains("alert.reexec"), "{js}");
        // The dead verdict links back to the injection time of the fault
        // that caused it.
        assert!(js.contains("\"fault_t\":5"), "{js}");
    }

    #[test]
    fn wan_degradation_detected_and_self_healed() {
        let cluster = two_site_cluster();
        let nodes = cluster.topo.node_ids();
        let mut eng = Engine::new();
        let plane = OpsPlane::install(&cluster, &nodes, OpsConfig::default(), &mut eng);
        let wan: Vec<(LinkId, f64)> = plane.borrow().wan_nominals().to_vec();
        assert_eq!(wan.len(), 2, "two directed WAN links");
        {
            let net = cluster.net.clone();
            let wl = wan.clone();
            plane.borrow_mut().set_wan_restore_hook(Box::new(move |eng| {
                for &(l, cap) in &wl {
                    FlowNet::set_capacity(&net, eng, l, cap);
                }
            }));
        }
        // A lightpath flap at t=5: both directions drop to 5% of nominal.
        let net = cluster.net.clone();
        let wl = wan.clone();
        eng.schedule_at(5.0, move |eng| {
            for &(l, cap) in &wl {
                FlowNet::set_capacity(&net, eng, l, cap * 0.05);
            }
        });
        drive(&plane, &mut eng, 20.0);
        let p = plane.borrow();
        let kinds: Vec<AlertKind> = p.alerts().iter().map(|a| a.kind).collect();
        assert!(kinds.contains(&AlertKind::WanDegraded), "{kinds:?}");
        assert!(kinds.contains(&AlertKind::WanRestored), "{kinds:?}");
        assert!(p
            .ops_log()
            .iter()
            .any(|op| matches!(op, Op::SetWanCapacity { gbps, .. } if (*gbps - 10.0).abs() < 0.1)));
        // The wave is back at nominal.
        let netb = cluster.net.borrow();
        for &(l, cap) in &wan {
            assert!((netb.capacity(l) - cap).abs() < 1.0, "link {l:?} not restored");
        }
        // No node false positives along the way.
        assert_eq!(p.report().false_dead, 0);
    }

    #[test]
    fn crashed_central_goes_dark_without_false_verdicts() {
        let cluster = two_site_cluster();
        let nodes = cluster.topo.node_ids();
        let central = nodes[0];
        let mut eng = Engine::new();
        let plane = OpsPlane::install(&cluster, &nodes, OpsConfig::default(), &mut eng);
        let p2 = plane.clone();
        eng.schedule_at(5.0, move |eng| {
            p2.borrow_mut().mark_crashed(central, eng.now());
        });
        drive(&plane, &mut eng, 30.0);
        let p = plane.borrow();
        let r = p.report();
        // The service halted with its host: no verdicts, no remediation —
        // the plane goes dark rather than rogue.
        assert_eq!(r.dead_declared, 0);
        assert_eq!(r.false_dead, 0);
        assert!(r.alerts.is_empty(), "{:?}", r.alerts);
        assert!(p.ops_log().is_empty());
    }

    #[test]
    fn detectors_flag_hotspot_and_straggler_once() {
        let cluster = two_site_cluster();
        let nodes = cluster.topo.node_ids();
        let mut eng = Engine::new();
        let plane = OpsPlane::install(&cluster, &nodes, OpsConfig::default(), &mut eng);
        // Synthetic relayed samples: node0 blazing, node3 crawling, the
        // middle two at the median.
        for tick in 0..5 {
            let t = tick as f64;
            let reports = vec![
                NodeReport { node: nodes[0], sent_at: t, nic_rate: 1000.0 },
                NodeReport { node: nodes[1], sent_at: t, nic_rate: 100.0 },
                NodeReport { node: nodes[2], sent_at: t, nic_rate: 110.0 },
                NodeReport { node: nodes[3], sent_at: t, nic_rate: 10.0 },
            ];
            OpsPlane::central_ingest(&plane, &mut eng, reports, f64::INFINITY);
        }
        // Two sweeps: flagged exactly once each, not re-alerted.
        OpsPlane::run_checks(&plane, &mut eng);
        OpsPlane::run_checks(&plane, &mut eng);
        let p = plane.borrow();
        let hot: Vec<&Alert> =
            p.alerts().iter().filter(|a| a.kind == AlertKind::Hotspot).collect();
        let slow: Vec<&Alert> =
            p.alerts().iter().filter(|a| a.kind == AlertKind::Straggler).collect();
        assert_eq!(hot.len(), 1, "{:?}", p.alerts());
        assert_eq!(slow.len(), 1, "{:?}", p.alerts());
        assert_eq!(hot[0].subject, cluster.topo.node(nodes[0]).name);
        assert_eq!(slow[0].subject, cluster.topo.node(nodes[3]).name);
    }

    #[test]
    fn ops_report_json_roundtrips() {
        let r = OpsReport {
            heartbeat_interval: 1.0,
            telemetry_msgs: 123,
            telemetry_bytes: 4567.0,
            telemetry_wan_bytes: 890.5,
            crashed_nodes: 1,
            dead_declared: 1,
            false_dead: 0,
            detection_latency_max: 5.25,
            reexecuted_tasks: 3,
            remediation_ops: 2,
            alerts: vec![
                Alert {
                    t: 25.0,
                    kind: AlertKind::NodeDead,
                    subject: "node003".into(),
                    detail: "no heartbeat for 5.2s; draining".into(),
                },
                Alert {
                    t: 26.0,
                    kind: AlertKind::TasksReExecuted,
                    subject: "node003".into(),
                    detail: "3 lost task(s) re-queued on survivors".into(),
                },
            ],
        };
        let text = r.to_json().to_string();
        let back = OpsReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
        assert_eq!(AlertKind::parse("wan-degraded"), Some(AlertKind::WanDegraded));
        assert_eq!(AlertKind::parse("nope"), None);
    }
}

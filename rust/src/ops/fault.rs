//! Scheduled fault injection: the `FaultPlan` axis of a
//! [`crate::coordinator::Scenario`].
//!
//! A plan is plain data — *what breaks, when* — applied by the scenario
//! runner through the substrate's live hooks: node crashes go through the
//! ops plane (sensor goes dark) and the dataflow's
//! [`crate::framework::DataflowControl`] (in-flight work is lost), NIC
//! degradations and lightpath flaps through
//! [`crate::net::FlowNet::set_capacity`]. Node indices refer to the
//! scenario's *placement* (0 = first placed node), so plans stay valid
//! across topologies and placements.

/// One kind of injected failure.
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// The node halts: heartbeats stop, in-flight phase-1 tasks are lost
    /// (re-executed only after the ops plane declares the node dead).
    NodeCrash { node: usize },
    /// The node's NIC degrades to `factor` of nominal capacity in both
    /// directions (a flaky transceiver — the paper's "slightly inferior
    /// performance" straggler, network flavor).
    NicDegrade { node: usize, factor: f64 },
    /// The shared wide-area wave degrades to `factor` of nominal capacity
    /// (a lightpath flap); remediation re-provisions it to nominal.
    LightpathFlap { factor: f64 },
}

/// A fault scheduled at an absolute simulated time.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    pub at: f64,
    pub fault: Fault,
}

/// The scenario's fault schedule (empty by default).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Crash placed node `node` at simulated time `at`.
    pub fn node_crash(mut self, at: f64, node: usize) -> FaultPlan {
        assert!(at >= 0.0);
        self.events.push(FaultEvent { at, fault: Fault::NodeCrash { node } });
        self
    }

    /// Degrade placed node `node`'s NIC to `factor` of nominal at `at`.
    pub fn nic_degrade(mut self, at: f64, node: usize, factor: f64) -> FaultPlan {
        assert!(at >= 0.0);
        assert!(factor > 0.0 && factor <= 1.0, "degrade factor must be in (0, 1]");
        self.events.push(FaultEvent { at, fault: Fault::NicDegrade { node, factor } });
        self
    }

    /// Degrade the shared wave to `factor` of nominal at `at`.
    pub fn lightpath_flap(mut self, at: f64, factor: f64) -> FaultPlan {
        assert!(at >= 0.0);
        assert!(factor > 0.0 && factor <= 1.0, "flap factor must be in (0, 1]");
        self.events.push(FaultEvent { at, fault: Fault::LightpathFlap { factor } });
        self
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Fault times divided by `div`, tracking
    /// [`crate::coordinator::Scenario::scaled_down`]: run time is ~linear
    /// in workload scale, so a fault keeps its *relative* position in the
    /// run.
    pub fn scaled_down(&self, div: u64) -> FaultPlan {
        assert!(div > 0);
        FaultPlan {
            events: self
                .events
                .iter()
                .map(|e| FaultEvent { at: e.at / div as f64, fault: e.fault.clone() })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_collects_events_in_order() {
        let plan = FaultPlan::new()
            .node_crash(100.0, 7)
            .nic_degrade(50.0, 3, 0.25)
            .lightpath_flap(10.0, 0.1);
        assert_eq!(plan.len(), 3);
        assert!(!plan.is_empty());
        assert_eq!(plan.events[0].fault, Fault::NodeCrash { node: 7 });
        assert_eq!(plan.events[1].at, 50.0);
        assert_eq!(plan.events[2].fault, Fault::LightpathFlap { factor: 0.1 });
        assert!(FaultPlan::new().is_empty());
    }

    #[test]
    fn scaling_divides_times_not_targets() {
        let plan = FaultPlan::new().node_crash(2000.0, 7).lightpath_flap(300.0, 0.05);
        let s = plan.scaled_down(100);
        assert_eq!(s.events[0].at, 20.0);
        assert_eq!(s.events[0].fault, Fault::NodeCrash { node: 7 });
        assert_eq!(s.events[1].at, 3.0);
        assert_eq!(plan.scaled_down(1), plan);
    }

    #[test]
    #[should_panic(expected = "degrade factor")]
    fn rejects_zero_factor() {
        let _ = FaultPlan::new().nic_degrade(1.0, 0, 0.0);
    }
}

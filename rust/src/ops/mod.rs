//! The operations plane: in-band distributed monitoring, fault injection,
//! and self-healing (paper §4, §8; operating experience from
//! arXiv:0808.1802 and arXiv:1601.00323).
//!
//! Where [`crate::monitor`] is the *omniscient* sampler (it reads every
//! counter for free — right for rendering Figure 3), this module is the
//! *distributed* pipeline the paper actually ran: per-node sensors ship
//! GMP-framed heartbeat+sample messages as real simulated flows, per-site
//! aggregators roll them up and relay across the WAN, and a central
//! service runs a `Healthy → Suspect → Dead` health state machine,
//! hotspot / straggler / WAN-degradation detectors, an alert log, and
//! closed-loop remediation (drain dead nodes and re-execute their lost
//! tasks, re-provision a flapped lightpath). Monitoring overhead,
//! detection latency, and failure response thereby become measurable
//! outputs of a run instead of assumptions.
//!
//! [`FaultPlan`] is the injection side: scheduled node crashes, NIC
//! degradations, and lightpath flaps, carried by a
//! [`crate::coordinator::Scenario`] and applied mid-run by the scenario
//! runner. The `ops` scenario set in [`crate::coordinator::registry`]
//! shape-checks the closed loop end to end — bounded detection latency,
//! telemetry ≪ workload WAN bytes, and a MalStone job that completes
//! despite a mid-run crash.

pub mod fault;
pub mod plane;

pub use fault::{Fault, FaultEvent, FaultPlan};
pub use plane::{Alert, AlertKind, Health, OpsConfig, OpsPlane, OpsReport, RECOVERY_IMAGE};

//! Fluid flow network with max-min fair sharing and per-flow rate caps.
//!
//! Every bulk transfer in the simulated testbed — HDFS pipeline writes,
//! MapReduce shuffle fetches, Sphere segment reads and bucket writes, and
//! disk I/O (a disk is a link) — is a *flow* over a path of capacity links.
//! Active flows share each link max-min fairly (progressive water-filling),
//! and each flow additionally carries a transport cap: the maximum rate its
//! protocol can sustain on its path (TCP's `MSS/(RTT·√p)` ceiling on high
//! bandwidth-delay-product paths, UDT's near-capacity rate — see
//! [`crate::transport`]). The cap is what makes the wide-area penalty of
//! Table 2 emerge from mechanism rather than from a hard-coded constant.
//!
//! Built for churn at 10k+ active flows: flows live in a slab (`Vec` plus
//! free list) addressed by dense slot indices, every link keeps an index
//! list of the active flows crossing it, and `reallocate()` water-fills
//! over persistent scratch arrays — zero allocation per call in steady
//! state. Completions are scheduled on the event engine as a *single
//! cancellable timer*: any change to the flow set cancels and reschedules
//! it, so the event heap holds at most one completion event per network
//! instead of one stale event per reallocation.

use std::cell::RefCell;
use std::cmp::Ordering;
use std::rc::Rc;

use crate::sim::{Engine, TimerId};

use super::topology::{LinkId, Topology};

/// Identifies a flow. Real ids are `(slot, generation)` pairs, so a stale
/// id can never alias a different flow after its slab slot is reused; the
/// reserved [`FlowId::COMPLETED`] value denotes a transfer that finished
/// before it ever occupied a slot (zero-byte flows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlowId(u64);

impl FlowId {
    /// The id of a flow that completed immediately (zero bytes). Never
    /// allocated to a live flow — `flow_rate` answers 0 for it forever,
    /// no matter how many flows the network has started since.
    pub const COMPLETED: FlowId = FlowId(u64::MAX);

    /// True for ids of transfers that completed at start (zero bytes).
    pub fn is_completed(self) -> bool {
        self.0 == u64::MAX
    }

    fn new(slot: u32, gen: u32) -> FlowId {
        let id = ((gen as u64) << 32) | slot as u64;
        debug_assert_ne!(id, u64::MAX, "flow id collides with COMPLETED");
        FlowId(id)
    }

    fn slot(self) -> u32 {
        self.0 as u32
    }

    fn gen(self) -> u32 {
        (self.0 >> 32) as u32
    }
}

type Callback = Box<dyn FnOnce(&mut Engine)>;

struct FlowState {
    path: Vec<LinkId>,
    remaining: f64,
    rate: f64,
    cap: f64,
    /// Bytes at birth, kept for the debug-build conservation audit:
    /// a completing flow must have delivered (almost) all of them.
    birth_bytes: f64,
    /// Monotone birth order: completion callbacks fire in this order, so
    /// slab slot reuse cannot perturb deterministic replays.
    birth: u64,
    /// This flow's position in `FlowNet::active`, and in each path link's
    /// `link_flows` list (parallel to `path`) — departures are O(path)
    /// swap_removes instead of O(active flows) scans.
    active_pos: u32,
    link_pos: Vec<u32>,
    done: Option<Callback>,
}

/// One slab slot; `gen` survives reuse and stamps issued [`FlowId`]s.
struct Slot {
    gen: u32,
    state: Option<FlowState>,
}

/// Persistent water-filling scratch. Per-link arrays are sized to the
/// topology at construction; `frozen` grows with the slab. Nothing here
/// is meaningful between `reallocate` calls — each call rewrites the
/// entries it reads.
#[derive(Default)]
struct Scratch {
    /// Remaining capacity per link (valid for this call's touched links).
    remaining: Vec<f64>,
    /// Unfrozen flows crossing each link (valid for touched links).
    users: Vec<u32>,
    /// Whether a touched link has saturated this call.
    saturated: Vec<bool>,
    /// Links with at least one active flow this call.
    touched: Vec<u32>,
    /// Per-slot frozen flag (valid for this call's active slots).
    frozen: Vec<bool>,
}

/// The fluid network. Use through an `Rc<RefCell<_>>` handle.
pub struct FlowNet {
    capacity: Vec<f64>,
    /// Current aggregate rate per link (for utilization sampling).
    link_rate: Vec<f64>,
    /// Cumulative bytes carried per link (monitor counters).
    link_bytes: Vec<f64>,
    /// Flow slab: slot indices are dense and recycled through `free`.
    slots: Vec<Slot>,
    free: Vec<u32>,
    /// Slots of currently-active flows (unordered).
    active: Vec<u32>,
    /// Active slots sorted by ascending `(cap, slot)`. Caps are immutable
    /// per flow, so this is maintained incrementally (binary-search
    /// insert/remove) instead of re-sorted inside `reallocate`.
    by_cap: Vec<u32>,
    /// Per-link index lists: active slots crossing each link.
    link_flows: Vec<Vec<u32>>,
    next_birth: u64,
    last_advance: f64,
    completions: u64,
    /// High-water mark of `active.len()` (concurrency metrics).
    peak_active: usize,
    /// The single pending completion event, if any.
    timer: Option<TimerId>,
    scratch: Scratch,
}

impl FlowNet {
    pub fn new(topo: &Topology) -> Rc<RefCell<FlowNet>> {
        let capacity: Vec<f64> = topo.links.iter().map(|l| l.capacity).collect();
        let n = capacity.len();
        Rc::new(RefCell::new(FlowNet {
            capacity,
            link_rate: vec![0.0; n],
            link_bytes: vec![0.0; n],
            slots: Vec::new(),
            free: Vec::new(),
            active: Vec::new(),
            by_cap: Vec::new(),
            link_flows: vec![Vec::new(); n],
            next_birth: 0,
            last_advance: 0.0,
            completions: 0,
            peak_active: 0,
            timer: None,
            scratch: Scratch {
                remaining: vec![0.0; n],
                users: vec![0; n],
                saturated: vec![false; n],
                ..Scratch::default()
            },
        }))
    }

    /// Total completed flows (sanity/metrics).
    pub fn completions(&self) -> u64 {
        self.completions
    }

    /// Number of currently active flows.
    pub fn active(&self) -> usize {
        self.active.len()
    }

    /// Most flows ever simultaneously active — exact (updated on every
    /// arrival), so concurrency metrics don't depend on when a consumer
    /// happens to sample.
    pub fn peak_active(&self) -> usize {
        self.peak_active
    }

    /// Current utilization of a link in [0, 1].
    pub fn link_utilization(&self, l: LinkId) -> f64 {
        if self.capacity[l.0] <= 0.0 {
            0.0
        } else {
            (self.link_rate[l.0] / self.capacity[l.0]).min(1.0)
        }
    }

    /// Current aggregate rate on a link, bytes/s.
    pub fn link_rate(&self, l: LinkId) -> f64 {
        self.link_rate[l.0]
    }

    /// Current configured capacity of a link, bytes/s — the live value,
    /// which [`FlowNet::set_capacity`] (provisioning, fault injection)
    /// may have moved away from the topology's nominal. The ops plane's
    /// aggregators read this as their link-probe observable.
    pub fn capacity(&self, l: LinkId) -> f64 {
        self.capacity[l.0]
    }

    /// Cumulative bytes carried by a link since the last call (monitor
    /// sampling). `now` must be the current engine time.
    pub fn take_link_bytes(&mut self, l: LinkId, now: f64) -> f64 {
        self.advance(now);
        std::mem::take(&mut self.link_bytes[l.0])
    }

    /// Peek cumulative bytes without resetting.
    pub fn link_bytes(&self, l: LinkId) -> f64 {
        self.link_bytes[l.0]
    }

    /// Current rate of a flow (0 if finished; stale ids of completed flows
    /// stay 0 even after their slab slot is reused).
    pub fn flow_rate(&self, id: FlowId) -> f64 {
        if id.is_completed() {
            return 0.0;
        }
        match self.slots.get(id.slot() as usize) {
            Some(slot) if slot.gen == id.gen() => {
                slot.state.as_ref().map(|f| f.rate).unwrap_or(0.0)
            }
            _ => 0.0,
        }
    }

    // ---- slab plumbing -----------------------------------------------

    fn insert(&mut self, mut state: FlowState) -> FlowId {
        // Record where this flow will sit in the index lists (links are
        // distinct along a path, so each list's length is its position).
        state.active_pos = self.active.len() as u32;
        state.link_pos =
            state.path.iter().map(|&LinkId(l)| self.link_flows[l].len() as u32).collect();
        let s = match self.free.pop() {
            Some(s) => {
                self.slots[s as usize].state = Some(state);
                s
            }
            None => {
                assert!(self.slots.len() < u32::MAX as usize, "flow slab full");
                self.slots.push(Slot { gen: 0, state: Some(state) });
                self.scratch.frozen.push(false);
                (self.slots.len() - 1) as u32
            }
        };
        self.active.push(s);
        self.peak_active = self.peak_active.max(self.active.len());
        let pos = self.by_cap_position(s).unwrap_or_else(|p| p);
        self.by_cap.insert(pos, s);
        let slot = &self.slots[s as usize];
        for &LinkId(l) in &slot.state.as_ref().unwrap().path {
            self.link_flows[l].push(s);
        }
        FlowId::new(s, slot.gen)
    }

    /// Binary-search `by_cap` for slot `s` (whose state must be present).
    /// `Ok` is the slot's position, `Err` its insertion point — the
    /// `(cap, slot)` key is unique, so a present slot is always `Ok`.
    fn by_cap_position(&self, s: u32) -> Result<usize, usize> {
        let cap = self.flow(s).cap;
        self.by_cap.binary_search_by(|&x| {
            let cx = self.flow(x).cap;
            cx.partial_cmp(&cap).unwrap_or(Ordering::Equal).then(x.cmp(&s))
        })
    }

    /// Remove a departing flow from the slab and every index list in
    /// O(path length): stored positions make each removal a `swap_remove`,
    /// with the displaced flow's position fixed up in place.
    fn release(&mut self, s: u32) -> FlowState {
        // Drop from the cap order while the slot still answers for its cap.
        let pos = self.by_cap_position(s).expect("flow missing from cap order");
        self.by_cap.remove(pos);
        let state = self.slots[s as usize].state.take().expect("releasing empty slot");
        // Bump the generation so stale ids stop resolving to this slot.
        self.slots[s as usize].gen = self.slots[s as usize].gen.wrapping_add(1);
        self.free.push(s);
        let p = state.active_pos as usize;
        debug_assert_eq!(self.active[p], s, "active index out of sync");
        self.active.swap_remove(p);
        if p < self.active.len() {
            let moved = self.active[p];
            self.slots[moved as usize].state.as_mut().expect("moved slot inactive").active_pos =
                p as u32;
        }
        for (i, &LinkId(l)) in state.path.iter().enumerate() {
            let lf = &mut self.link_flows[l];
            let p = state.link_pos[i] as usize;
            debug_assert_eq!(lf[p], s, "link index out of sync");
            lf.swap_remove(p);
            if p < lf.len() {
                let moved = lf[p];
                let old_last = lf.len() as u32; // index the moved entry vacated
                debug_assert_ne!(moved, s, "path repeats a link");
                let m = self.slots[moved as usize].state.as_mut().expect("moved slot inactive");
                for (j, &pl) in m.path.iter().enumerate() {
                    if pl == LinkId(l) && m.link_pos[j] == old_last {
                        m.link_pos[j] = p as u32;
                        break;
                    }
                }
            }
        }
        state
    }

    fn flow(&self, s: u32) -> &FlowState {
        self.slots[s as usize].state.as_ref().expect("inactive slot")
    }

    // ---- internal fluid mechanics ------------------------------------

    /// Progress all flows to `now`, accruing per-link byte counters.
    fn advance(&mut self, now: f64) {
        let dt = now - self.last_advance;
        if dt <= 0.0 {
            return;
        }
        for &s in &self.active {
            let f = self.slots[s as usize].state.as_mut().expect("inactive slot in active list");
            if f.rate > 0.0 {
                f.remaining = (f.remaining - f.rate * dt).max(0.0);
            }
        }
        for (l, rate) in self.link_rate.iter().enumerate() {
            if *rate > 0.0 {
                self.link_bytes[l] += rate * dt;
            }
        }
        self.last_advance = now;
    }

    /// Max-min fair allocation via progressive water-filling, honoring
    /// per-flow caps. Dense-array rework of the classic loop: all unfrozen
    /// flows ride one shared water level, links saturate in rounds and
    /// freeze exactly the flows in their index lists, and cap freezes walk
    /// the incrementally-maintained `by_cap` order. Every buffer is
    /// persistent scratch — zero allocation per call in steady state.
    /// Cost: O(active + links) setup plus O(rounds × (touched links +
    /// freezes)); rounds ≤ #distinct freeze levels (saturated links +
    /// distinct binding caps).
    fn reallocate(&mut self) {
        for r in self.link_rate.iter_mut() {
            *r = 0.0;
        }
        if self.active.is_empty() {
            return;
        }

        let sc = &mut self.scratch;
        // Every active flow starts unfrozen, so each link's initial user
        // count is just its index-list length.
        sc.touched.clear();
        for (l, lf) in self.link_flows.iter().enumerate() {
            if !lf.is_empty() {
                sc.touched.push(l as u32);
                sc.users[l] = lf.len() as u32;
                sc.remaining[l] = self.capacity[l];
                sc.saturated[l] = false;
            }
        }
        for &s in &self.active {
            sc.frozen[s as usize] = false;
        }
        debug_assert_eq!(self.by_cap.len(), self.active.len(), "cap order out of sync");

        // Relative epsilons: with capacities ~1e8 B/s, one ulp of water-
        // filling residue (~1e-8) must count as "saturated", or the loop
        // spins shaving dust off the same link without freezing anything.
        let link_eps = |cap: f64| cap * 1e-9 + 1e-9;
        let cap_eps = |cap: f64| if cap.is_finite() { cap * 1e-9 + 1e-9 } else { 0.0 };

        // The shared rate of every still-unfrozen flow (all receive the
        // same uniform increments, so one scalar tracks them all).
        let mut level = 0.0f64;
        let mut unfrozen = self.active.len();
        let mut cap_ptr = 0usize;
        let max_iters = self.active.len() + sc.touched.len() + 8;
        let mut iters = 0usize;
        while unfrozen > 0 {
            iters += 1;
            // Smallest feasible uniform increment across unfrozen flows.
            let mut inc = f64::INFINITY;
            for &l in &sc.touched {
                let l = l as usize;
                if sc.users[l] > 0 {
                    inc = inc.min(sc.remaining[l].max(0.0) / sc.users[l] as f64);
                }
            }
            while cap_ptr < self.by_cap.len() && sc.frozen[self.by_cap[cap_ptr] as usize] {
                cap_ptr += 1;
            }
            if cap_ptr < self.by_cap.len() {
                let cap = self.slots[self.by_cap[cap_ptr] as usize].state.as_ref().unwrap().cap;
                inc = inc.min(cap - level);
            }
            if !inc.is_finite() {
                break; // all paths uncapacitated? cannot happen with real links
            }
            let inc = inc.max(0.0);
            level += inc;
            for &l in &sc.touched {
                let l = l as usize;
                if sc.users[l] > 0 {
                    sc.remaining[l] -= inc * sc.users[l] as f64;
                }
            }
            let mut froze_any = false;
            // (a) Cap freezes: the sorted prefix whose cap the level reached.
            while cap_ptr < self.by_cap.len() {
                let s = self.by_cap[cap_ptr] as usize;
                if sc.frozen[s] {
                    cap_ptr += 1;
                    continue;
                }
                let f = self.slots[s].state.as_mut().unwrap();
                if f.cap.is_finite() && level >= f.cap - cap_eps(f.cap) {
                    f.rate = level;
                    for &LinkId(l) in &f.path {
                        sc.users[l] -= 1;
                    }
                    sc.frozen[s] = true;
                    froze_any = true;
                    unfrozen -= 1;
                    cap_ptr += 1;
                } else {
                    break;
                }
            }
            // (b) Link freezes: newly saturated links freeze every unfrozen
            // flow in their index lists.
            for &l in &sc.touched {
                let l = l as usize;
                if sc.saturated[l] || sc.remaining[l] > link_eps(self.capacity[l]) {
                    continue;
                }
                sc.saturated[l] = true;
                for &s in &self.link_flows[l] {
                    let s = s as usize;
                    if sc.frozen[s] {
                        continue;
                    }
                    let f = self.slots[s].state.as_mut().unwrap();
                    f.rate = level;
                    for &LinkId(pl) in &f.path {
                        sc.users[pl] -= 1;
                    }
                    sc.frozen[s] = true;
                    froze_any = true;
                    unfrozen -= 1;
                }
            }
            if unfrozen > 0 && (!froze_any || iters >= max_iters) {
                // Each productive round must freeze something; if nothing
                // froze (fp dust) or the bound is exhausted, everyone left
                // keeps the current level — feasible by construction, off
                // by at most one epsilon of fairness.
                break;
            }
        }
        if unfrozen > 0 {
            for &s in &self.active {
                if !sc.frozen[s as usize] {
                    self.slots[s as usize].state.as_mut().unwrap().rate = level;
                }
            }
        }

        for &s in &self.active {
            let f = self.slots[s as usize].state.as_ref().unwrap();
            for &LinkId(l) in &f.path {
                self.link_rate[l] += f.rate;
            }
        }
        #[cfg(debug_assertions)]
        self.audit();
    }

    /// Structural self-audit of the slab, index lists, and allocation,
    /// compiled only under `debug_assertions` and run after every
    /// `reallocate`. O(active × path + links) — debug/test workloads
    /// tolerate it; release builds pay nothing.
    #[cfg(debug_assertions)]
    fn audit(&self) {
        assert_eq!(self.by_cap.len(), self.active.len(), "cap order length mismatch");
        for w in self.by_cap.windows(2) {
            let (a, b) = (w[0], w[1]);
            // Strict lexicographic (cap, slot) order; keys are unique.
            assert!(
                (self.flow(a).cap, a) < (self.flow(b).cap, b),
                "by_cap order violated at slots {a},{b}"
            );
        }
        for (l, lf) in self.link_flows.iter().enumerate() {
            let sum: f64 = lf.iter().map(|&s| self.flow(s).rate).sum();
            let eps = self.capacity[l] * 1e-6 + 1e-6;
            assert!(
                sum <= self.capacity[l] + eps,
                "link {l} oversubscribed: {sum} > {}",
                self.capacity[l]
            );
            assert!(
                (sum - self.link_rate[l]).abs() <= eps,
                "link {l} rate ledger drift: recomputed {sum}, ledger {}",
                self.link_rate[l]
            );
            for (p, &s) in lf.iter().enumerate() {
                let f = self.flow(s);
                let cross = f
                    .path
                    .iter()
                    .zip(&f.link_pos)
                    .any(|(&pl, &lp)| pl == LinkId(l) && lp as usize == p);
                assert!(cross, "link {l} entry {p} (slot {s}) lacks a back-reference");
            }
        }
        for (p, &s) in self.active.iter().enumerate() {
            let f = self.flow(s); // panics if the slot lost its state
            assert_eq!(f.active_pos as usize, p, "active index out of sync at {p}");
            assert!(f.remaining >= 0.0, "negative residual bytes on slot {s}");
            assert!(f.rate >= 0.0 && f.rate.is_finite(), "bad rate on slot {s}");
            assert_eq!(f.path.len(), f.link_pos.len(), "path/link_pos length mismatch");
            for (&LinkId(l), &lp) in f.path.iter().zip(&f.link_pos) {
                assert_eq!(
                    self.link_flows[l].get(lp as usize),
                    Some(&s),
                    "slot {s} missing from link {l} index list"
                );
            }
        }
    }

    fn next_completion(&self) -> Option<f64> {
        let mut best: Option<f64> = None;
        for &s in &self.active {
            let f = self.flow(s);
            if f.rate > 0.0 {
                let t = f.remaining / f.rate;
                best = Some(match best {
                    Some(b) => b.min(t),
                    None => t,
                });
            }
        }
        best
    }

    // ---- public operations (handle-based: callbacks need the net) -----

    /// Start a transfer of `bytes` along `path` with transport cap
    /// `cap_bps` (bytes/s; `f64::INFINITY` for uncapped). `done` fires on
    /// the engine when the last byte arrives. Zero-byte flows complete
    /// immediately and return [`FlowId::COMPLETED`].
    pub fn start<F: FnOnce(&mut Engine) + 'static>(
        net: &Rc<RefCell<FlowNet>>,
        eng: &mut Engine,
        path: Vec<LinkId>,
        bytes: f64,
        cap_bps: f64,
        done: F,
    ) -> FlowId {
        assert!(bytes >= 0.0 && cap_bps > 0.0);
        if bytes <= 0.0 {
            eng.schedule_in(0.0, done);
            return FlowId::COMPLETED;
        }
        assert!(!path.is_empty(), "flow with empty path");
        let id = {
            let mut n = net.borrow_mut();
            n.advance(eng.now());
            let birth = n.next_birth;
            n.next_birth += 1;
            let id = n.insert(FlowState {
                path,
                remaining: bytes,
                rate: 0.0,
                cap: cap_bps,
                birth_bytes: bytes,
                birth,
                active_pos: 0,    // assigned by insert
                link_pos: Vec::new(),
                done: Some(Box::new(done)),
            });
            n.reallocate();
            id
        };
        Self::reschedule(net, eng);
        id
    }

    /// Change a link's capacity at runtime (network provisioning §2.1) and
    /// reallocate.
    pub fn set_capacity(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine, l: LinkId, capacity: f64) {
        Self::set_capacities(net, eng, &[(l, capacity)]);
    }

    /// Retune several links in one shot — a lightpath grant or teardown
    /// moves a whole directed wave pair (and a flap restore moves every
    /// wave link) — paying a single `advance` + water-filling pass +
    /// completion-timer re-arm for the batch instead of one per link.
    pub fn set_capacities(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine, changes: &[(LinkId, f64)]) {
        if changes.is_empty() {
            return;
        }
        {
            let mut n = net.borrow_mut();
            n.advance(eng.now());
            for &(l, capacity) in changes {
                assert!(capacity > 0.0);
                n.capacity[l.0] = capacity;
            }
            n.reallocate();
        }
        Self::reschedule(net, eng);
    }

    /// (Re)arm the single completion timer: cancel the outstanding one and
    /// schedule at the new earliest completion. The engine frees the old
    /// callback immediately, so the heap carries at most one completion
    /// event (plus transient markers) per network regardless of churn.
    fn reschedule(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine) {
        let (old, dt) = {
            let mut n = net.borrow_mut();
            (n.timer.take(), n.next_completion())
        };
        if let Some(t) = old {
            eng.cancel(t);
        }
        let Some(dt) = dt else { return };
        let net2 = net.clone();
        let id = eng.schedule_in(dt.max(0.0), move |eng| {
            Self::on_completion(&net2, eng);
        });
        net.borrow_mut().timer = Some(id);
    }

    fn on_completion(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine) {
        let callbacks = {
            let mut n = net.borrow_mut();
            n.timer = None; // this event *is* the timer; it just fired
            n.advance(eng.now());
            // A flow is done when within an epsilon that is relative to
            // its rate (1 ns of transfer) — pure absolute epsilons leave
            // residues whose completion dt falls below the clock's ulp
            // and the event loop stops advancing time.
            let mut finished: Vec<u32> = Vec::new();
            for &s in &n.active {
                let f = n.flow(s);
                if f.remaining <= 1e-6 + f.rate * 1e-9 {
                    finished.push(s);
                }
            }
            if finished.is_empty() {
                // This event fired because a completion was due; force
                // progress by completing the nearest flow (fp dust).
                let mut best: Option<(f64, u64, u32)> = None;
                for &s in &n.active {
                    let f = n.flow(s);
                    if f.rate > 0.0 {
                        let t = f.remaining / f.rate;
                        let better = match best {
                            None => true,
                            Some((bt, bb, _)) => t < bt || (t == bt && f.birth < bb),
                        };
                        if better {
                            best = Some((t, f.birth, s));
                        }
                    }
                }
                if let Some((_, _, s)) = best {
                    finished.push(s);
                }
            }
            // Deterministic callback order: flow birth (insertion) order,
            // immune to slab slot recycling.
            finished.sort_unstable_by_key(|&s| n.flow(s).birth);
            let mut cbs = Vec::with_capacity(finished.len());
            for s in finished {
                let mut f = n.release(s);
                // Byte conservation: a completing flow has delivered its
                // birth bytes up to fp dust (the forced-progress path above
                // can carry slightly more residue than the epsilon test).
                debug_assert!(
                    f.remaining <= 1e-3 + f.birth_bytes * 1e-6,
                    "completion leaks bytes: {} of {} undelivered",
                    f.remaining,
                    f.birth_bytes
                );
                n.completions += 1;
                if let Some(cb) = f.done.take() {
                    cbs.push(cb);
                }
            }
            n.reallocate();
            cbs
        };
        // Run callbacks without holding the borrow; they may start flows.
        for cb in callbacks {
            cb(eng);
        }
        Self::reschedule(net, eng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topology::{NodeSpec, Topology};
    use std::cell::{Cell, RefCell};
    use std::rc::Rc;

    fn two_site_topo() -> Topology {
        let mut t = Topology::new();
        let a = t.add_site("a");
        let b = t.add_site("b");
        let spec = NodeSpec { nic_bps: 100.0, disk_bps: 50.0, cpu_slots: 4 };
        t.add_rack(a, 4, &spec, 1000.0);
        t.add_rack(b, 4, &spec, 1000.0);
        t.connect_sites(a, b, 200.0, 0.01);
        t
    }

    #[test]
    fn single_flow_runs_at_bottleneck() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done_at = Rc::new(RefCell::new(0.0));
        let d = done_at.clone();
        // NIC (100 B/s) is the bottleneck: 1000 B takes 10 s.
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, move |e| {
            *d.borrow_mut() = e.now();
        });
        eng.run();
        assert!((*done_at.borrow() - 10.0).abs() < 1e-6);
        assert_eq!(net.borrow().completions(), 1);
    }

    #[test]
    fn two_flows_share_fairly() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let times = Rc::new(RefCell::new(Vec::new()));
        // Both flows leave node0: share its 100 B/s NIC → 50 B/s each.
        for dst in [1, 2] {
            let times = times.clone();
            let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[dst]);
            FlowNet::start(&net, &mut eng, path, 500.0, f64::INFINITY, move |e| {
                times.borrow_mut().push(e.now());
            });
        }
        eng.run();
        let ts = times.borrow();
        assert!((ts[0] - 10.0).abs() < 1e-6 && (ts[1] - 10.0).abs() < 1e-6, "{ts:?}");
    }

    #[test]
    fn departure_releases_bandwidth() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done = Rc::new(RefCell::new(Vec::new()));
        // Flow 1: 250 B, flow 2: 750 B, same NIC. Phase 1: both at 50 B/s
        // until t=5 (flow1 done). Phase 2: flow2 at 100 B/s for its
        // remaining 500 B → done at t=10.
        for bytes in [250.0, 750.0] {
            let done = done.clone();
            let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
            FlowNet::start(&net, &mut eng, path, bytes, f64::INFINITY, move |e| {
                done.borrow_mut().push(e.now());
            });
        }
        eng.run();
        let d = done.borrow();
        assert!((d[0] - 5.0).abs() < 1e-6, "{d:?}");
        assert!((d[1] - 10.0).abs() < 1e-6, "{d:?}");
        // Both flows overlapped; the high-water mark saw them together.
        assert_eq!(net.borrow().peak_active(), 2);
        assert_eq!(net.borrow().active(), 0);
    }

    #[test]
    fn transport_cap_limits_rate() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done_at = Rc::new(RefCell::new(0.0));
        let d = done_at.clone();
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        // Cap 20 B/s though the path allows 100 → 1000 B takes 50 s.
        FlowNet::start(&net, &mut eng, path, 1000.0, 20.0, move |e| {
            *d.borrow_mut() = e.now();
        });
        eng.run();
        assert!((*done_at.borrow() - 50.0).abs() < 1e-6);
    }

    #[test]
    fn capped_flow_leaves_bandwidth_for_others() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done = Rc::new(RefCell::new(Vec::new()));
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        // Capped flow takes 20 B/s; uncapped flow gets the remaining 80.
        for (bytes, cap) in [(200.0, 20.0), (800.0, f64::INFINITY)] {
            let done = done.clone();
            FlowNet::start(&net, &mut eng, path.clone(), bytes, cap, move |e| {
                done.borrow_mut().push(e.now());
            });
        }
        eng.run();
        let d = done.borrow();
        assert!((d[0] - 10.0).abs() < 1e-6 && (d[1] - 10.0).abs() < 1e-6, "{d:?}");
    }

    #[test]
    fn wan_link_contention() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done = Rc::new(RefCell::new(Vec::new()));
        // Three cross-site flows from distinct sources share the 200 B/s
        // WAN link: ~66.7 B/s each (NICs are 100, not binding).
        for src in 0..3 {
            let done = done.clone();
            let path = t.path(t.racks[0].nodes[src], t.racks[1].nodes[src]);
            FlowNet::start(&net, &mut eng, path, 200.0, f64::INFINITY, move |e| {
                done.borrow_mut().push(e.now());
            });
        }
        eng.run();
        for &d in done.borrow().iter() {
            assert!((d - 3.0).abs() < 1e-6, "{d}");
        }
    }

    #[test]
    fn zero_byte_flow_completes_immediately() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let hit = Rc::new(RefCell::new(false));
        let h = hit.clone();
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        let id = FlowNet::start(&net, &mut eng, path, 0.0, f64::INFINITY, move |_| {
            *h.borrow_mut() = true
        });
        assert!(id.is_completed());
        eng.run();
        assert!(*hit.borrow());
    }

    #[test]
    fn zero_byte_flow_id_never_aliases_real_flows() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        let z = FlowNet::start(&net, &mut eng, path.clone(), 0.0, f64::INFINITY, |_| {});
        // Real flows never mint the reserved id, so `flow_rate` keeps
        // answering 0 for the completed flow — not for someone else.
        let real = FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, |_| {});
        assert!(z.is_completed() && !real.is_completed());
        assert_ne!(z, real);
        assert_eq!(net.borrow().flow_rate(z), 0.0);
        assert!(net.borrow().flow_rate(real) > 0.0);
        eng.run();
    }

    #[test]
    fn stale_flow_ids_do_not_alias_reused_slots() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        let a = FlowNet::start(&net, &mut eng, path.clone(), 100.0, f64::INFINITY, |_| {});
        eng.run(); // flow a completes; its slab slot is recycled
        assert_eq!(net.borrow().active(), 0);
        let b = FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, |_| {});
        // b reuses a's slot under a new generation: a's id must read 0
        // while b reports a live rate.
        assert_ne!(a, b);
        assert_eq!(net.borrow().flow_rate(a), 0.0);
        assert!((net.borrow().flow_rate(b) - 100.0).abs() < 1e-6);
        eng.run();
    }

    #[test]
    fn capacity_change_reallocates() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done_at = Rc::new(RefCell::new(0.0));
        let d = done_at.clone();
        let n0 = t.racks[0].nodes[0];
        let n1 = t.racks[0].nodes[1];
        let path = t.path(n0, n1);
        let tx = t.node(n0).nic_tx;
        let rx = t.node(n1).nic_rx;
        FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, move |e| {
            *d.borrow_mut() = e.now();
        });
        // At t=5 (500 B left), upgrade both NICs to 500 B/s → 1 more second.
        let net2 = net.clone();
        eng.schedule_at(5.0, move |e| {
            FlowNet::set_capacity(&net2, e, tx, 500.0);
            FlowNet::set_capacity(&net2, e, rx, 500.0);
        });
        eng.run();
        assert!((*done_at.borrow() - 6.0).abs() < 1e-6, "{}", done_at.borrow());
    }

    #[test]
    fn batched_capacity_change_reallocates_once() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done_at = Rc::new(RefCell::new(0.0));
        let d = done_at.clone();
        let n0 = t.racks[0].nodes[0];
        let n1 = t.racks[0].nodes[1];
        let tx = t.node(n0).nic_tx;
        let rx = t.node(n1).nic_rx;
        FlowNet::start(&net, &mut eng, t.path(n0, n1), 1000.0, f64::INFINITY, move |e| {
            *d.borrow_mut() = e.now();
        });
        // Same retune as `capacity_change_reallocates`, as one batch: at
        // t=5 (500 B left) both NICs jump to 500 B/s → 1 more second.
        let net2 = net.clone();
        eng.schedule_at(5.0, move |e| {
            FlowNet::set_capacities(&net2, e, &[(tx, 500.0), (rx, 500.0)]);
        });
        eng.run();
        assert!((*done_at.borrow() - 6.0).abs() < 1e-6, "{}", done_at.borrow());
        // An empty batch is a no-op (no timer churn, no borrow).
        FlowNet::set_capacities(&net, &mut eng, &[]);
        assert_eq!(net.borrow().active(), 0);
    }

    #[test]
    fn link_byte_counters_accumulate() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let n0 = t.racks[0].nodes[0];
        let path = t.path(n0, t.racks[0].nodes[1]);
        FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, |_| {});
        eng.run();
        let now = eng.now();
        let bytes = net.borrow_mut().take_link_bytes(t.node(n0).nic_tx, now);
        assert!((bytes - 1000.0).abs() < 1e-6);
        // Counter resets after take.
        let again = net.borrow_mut().take_link_bytes(t.node(n0).nic_tx, now);
        assert_eq!(again, 0.0);
    }

    #[test]
    fn allocation_invariants_property() {
        crate::proptest::check("maxmin: feasible, capped, nonzero", 40, |rng| {
            let t = two_site_topo();
            let net = FlowNet::new(&t);
            let mut eng = Engine::new();
            let nflows = 1 + rng.gen_range(12) as usize;
            for _ in 0..nflows {
                let src = t.racks[rng.gen_range(2) as usize].nodes[rng.gen_range(4) as usize];
                let mut dst = src;
                while dst == src {
                    dst = t.racks[rng.gen_range(2) as usize].nodes[rng.gen_range(4) as usize];
                }
                let cap = if rng.chance(0.5) { 5.0 + rng.f64() * 200.0 } else { f64::INFINITY };
                FlowNet::start(&net, &mut eng, t.path(src, dst), 1e7, cap, |_| {});
            }
            let n = net.borrow();
            // (1) per-link feasibility
            for (l, &rate) in n.link_rate.iter().enumerate() {
                if rate > n.capacity[l] + 1e-6 {
                    return Err(format!("link {l} over capacity: {rate} > {}", n.capacity[l]));
                }
            }
            for &s in &n.active {
                let f = n.flow(s);
                // (2) cap respected
                if f.rate > f.cap + 1e-6 {
                    return Err(format!("flow over cap: {} > {}", f.rate, f.cap));
                }
                // (3) no starvation
                if f.rate <= 0.0 {
                    return Err("starved flow".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn work_conservation_property() {
        // With a single bottleneck and no caps, the bottleneck is saturated.
        crate::proptest::check("maxmin work conserving", 30, |rng| {
            let t = two_site_topo();
            let net = FlowNet::new(&t);
            let mut eng = Engine::new();
            let k = 2 + rng.gen_range(3) as usize;
            for i in 0..k {
                // All flows out of node0 → its NIC is the shared bottleneck.
                let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1 + (i % 3)]);
                FlowNet::start(&net, &mut eng, path, 1e6, f64::INFINITY, |_| {});
            }
            let n = net.borrow();
            let nic = t.node(t.racks[0].nodes[0]).nic_tx;
            let rate = n.link_rate(nic);
            if (rate - 100.0).abs() < 1e-6 {
                Ok(())
            } else {
                Err(format!("bottleneck not saturated: {rate}"))
            }
        });
    }

    /// Each completion spawns a successor until `left` drains — arrival/
    /// departure churn with slab slot recycling on every hop.
    fn spawn_chain(
        net: &Rc<RefCell<FlowNet>>,
        eng: &mut Engine,
        paths: &Rc<Vec<Vec<LinkId>>>,
        k: usize,
        left: &Rc<Cell<usize>>,
        bytes: f64,
    ) {
        if left.get() == 0 {
            return;
        }
        left.set(left.get() - 1);
        let net2 = net.clone();
        let paths2 = paths.clone();
        let left2 = left.clone();
        let path = paths[k % paths.len()].clone();
        FlowNet::start(net, eng, path, bytes, f64::INFINITY, move |e| {
            spawn_chain(&net2, e, &paths2, k + 1, &left2, bytes);
        });
    }

    #[test]
    fn engine_heap_stays_small_under_flow_churn() {
        // The single cancellable completion timer keeps the event heap
        // O(active flows): one live completion event regardless of how
        // many reallocations churn produces (the old generation-counter
        // scheme left one stale event behind per reallocation).
        crate::proptest::check("flow churn keeps heap O(active)", 10, |rng| {
            let t = two_site_topo();
            let net = FlowNet::new(&t);
            let mut eng = Engine::new();
            let mut paths = Vec::new();
            for r in 0..2usize {
                for i in 0..4usize {
                    let src = t.racks[r].nodes[i];
                    let dst = t.racks[1 - r].nodes[(i + 1) % 4];
                    paths.push(t.path(src, dst));
                }
            }
            let paths = Rc::new(paths);
            let chains = 2 + rng.gen_range(6) as usize;
            let total = 40 + rng.gen_range(80) as usize;
            let left = Rc::new(Cell::new(total));
            let bytes = 50.0 + rng.f64() * 500.0;
            for c in 0..chains {
                spawn_chain(&net, &mut eng, &paths, c, &left, bytes);
            }
            let active0 = net.borrow().active();
            if eng.pending() > active0 + 2 {
                return Err(format!("{} events for {active0} flows", eng.pending()));
            }
            while eng.step() {
                let active = net.borrow().active();
                if eng.pending() > active + 2 {
                    return Err(format!("{} live events for {active} active flows", eng.pending()));
                }
                if eng.heap_len() > 2 * eng.pending() + 66 {
                    return Err(format!(
                        "heap {} for {} live events",
                        eng.heap_len(),
                        eng.pending()
                    ));
                }
            }
            // Every spawn consumes one unit of budget, so exactly `total`
            // flows ever start — and each must complete exactly once.
            if net.borrow().completions() != total as u64 {
                return Err(format!(
                    "{} completions for {} flows",
                    net.borrow().completions(),
                    total
                ));
            }
            Ok(())
        });
    }
}

//! Fluid flow network with max-min fair sharing, per-flow rate caps,
//! same-path flow aggregation, and incremental water-filling over
//! site-sharded flow domains.
//!
//! Every bulk transfer in the simulated testbed — HDFS pipeline writes,
//! MapReduce shuffle fetches, Sphere segment reads and bucket writes, and
//! disk I/O (a disk is a link) — is a *flow* over a path of capacity links.
//! Active flows share each link max-min fairly (progressive water-filling),
//! and each flow additionally carries a transport cap: the maximum rate its
//! protocol can sustain on its path (TCP's `MSS/(RTT·√p)` ceiling on high
//! bandwidth-delay-product paths, UDT's near-capacity rate — see
//! [`crate::transport`]). The cap is what makes the wide-area penalty of
//! Table 2 emerge from mechanism rather than from a hard-coded constant.
//!
//! Three mechanisms carry this to ~1M concurrent flows:
//!
//! 1. **Same-path aggregation.** Flows sharing an identical `(path, cap)`
//!    collapse into one *aggregate* with `weight` members. Max-min fairness
//!    gives identical rates to identical flows, so an aggregate is a single
//!    water-filling participant of weight `w`; members differ only in their
//!    completion *targets* on the aggregate's cumulative served-bytes axis
//!    (a min-heap of targets). A storm of same-route transfers costs
//!    O(distinct paths), not O(flows).
//!
//! 2. **Incremental reallocation.** An arrival, departure, or capacity
//!    retune only perturbs rates inside the connected component (links ↔
//!    aggregates sharing them) reachable from the touched links. The
//!    recompute seeds a worklist with those links, discovers affected
//!    components, and water-fills each component in a canonical order.
//!    Untouched components keep their stored rates — which are *bitwise*
//!    what a full recompute would produce, because a component's fill
//!    depends only on its member set, weights, caps, and capacities (see
//!    `fill_component`). A debug-build audit re-runs the full recompute
//!    after every event and asserts bitwise equality.
//!
//! 3. **Flow domains.** Links are partitioned by [`Domain`]: one per site
//!    plus the WAN. Each domain owns a completion-timer lane — a lazy
//!    min-heap of aggregate deadlines behind one cancellable engine event
//!    (see [`TimerBank`]) — so completion scheduling is sharded by site
//!    instead of funneling through one global timer.
//!
//! Determinism: incremental and full (`FlowNetConfig::incremental =
//! false`) modes run identical code everywhere except which components get
//! re-filled, and a re-fill of a clean component reproduces its rates
//! bitwise. Stored deadlines are only recomputed when an aggregate's rate
//! changes bitwise or its membership changes, so the two modes schedule
//! byte-identical event sequences — the `flow_scale` bench asserts equal
//! `RunReport` JSON while timing the speedup.

use std::cell::RefCell;
use std::cmp::{Ordering, Reverse};
use std::collections::{BTreeMap, BinaryHeap};
use std::rc::Rc;

use crate::sim::{Engine, TimerBank};
use crate::trace::Arg;

use super::topology::{Domain, LinkId, Route, Topology};

/// Identifies a flow. Real ids are `(slot, generation)` pairs naming the
/// *aggregate* a flow joined, so a stale id can never alias a different
/// aggregate after its slab slot is reused; the reserved
/// [`FlowId::COMPLETED`] value denotes a transfer that finished before it
/// ever occupied a slot (zero-byte flows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlowId(u64);

impl FlowId {
    /// The id of a flow that completed immediately (zero bytes). Never
    /// allocated to a live flow — `flow_rate` answers 0 for it forever,
    /// no matter how many flows the network has started since.
    pub const COMPLETED: FlowId = FlowId(u64::MAX);

    /// True for ids of transfers that completed at start (zero bytes).
    pub fn is_completed(self) -> bool {
        self.0 == u64::MAX
    }

    fn new(slot: u32, gen: u32) -> FlowId {
        let id = ((gen as u64) << 32) | slot as u64;
        debug_assert_ne!(id, u64::MAX, "flow id collides with COMPLETED");
        FlowId(id)
    }

    fn slot(self) -> u32 {
        self.0 as u32
    }

    fn gen(self) -> u32 {
        (self.0 >> 32) as u32
    }
}

/// Tuning knobs for the flow core. The defaults are what production
/// callers want; the non-default corners exist so benches and property
/// tests can pin either optimization off and compare results.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlowNetConfig {
    /// Collapse flows sharing an identical `(path, cap)` into weighted
    /// aggregates. Off: every flow is its own aggregate of weight 1.
    pub aggregate: bool,
    /// Reallocate only the connected components touched by an event.
    /// Off: every event re-fills every component (same code path, seeded
    /// with every link) — the oracle the incremental mode must match.
    pub incremental: bool,
}

impl Default for FlowNetConfig {
    fn default() -> FlowNetConfig {
        FlowNetConfig { aggregate: true, incremental: true }
    }
}

type Callback = Box<dyn FnOnce(&mut Engine)>;

/// One member of an aggregate: completes when the aggregate's cumulative
/// per-member served bytes (`base`) reach `target`. Ordered by
/// `(target, birth)` — targets are non-negative finite, so IEEE bit order
/// is numeric order and doubles as a total order for the member heap.
struct Member {
    target_bits: u64,
    birth: u64,
    /// Bytes at birth, kept for the debug-build conservation audit.
    bytes: f64,
    done: Option<Callback>,
}

impl PartialEq for Member {
    fn eq(&self, other: &Member) -> bool {
        (self.target_bits, self.birth) == (other.target_bits, other.birth)
    }
}
impl Eq for Member {}
impl PartialOrd for Member {
    fn partial_cmp(&self, other: &Member) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Member {
    fn cmp(&self, other: &Member) -> Ordering {
        (self.target_bits, self.birth).cmp(&(other.target_bits, other.birth))
    }
}

/// A same-path aggregate: one water-filling participant of weight
/// `weight`, serving every member at `member_rate` simultaneously.
struct AggState {
    path: Vec<LinkId>,
    cap: f64,
    /// Cached `cap.to_bits()`: half of the aggregation key, and the
    /// canonical cap-freeze sort key inside `fill_component`.
    cap_bits: u64,
    /// Third key component: 0 when aggregating, the founding member's
    /// birth otherwise (making every aggregate unique).
    key_salt: u64,
    /// Member count; the aggregate contributes `weight × member_rate`
    /// to every path link.
    weight: u32,
    member_rate: f64,
    /// Cumulative bytes served *per member* since the aggregate was
    /// created. A member joining with `B` bytes completes at
    /// `base == base_at_join + B` — its heap target.
    base: f64,
    /// Founding member's birth: deadline-heap tiebreak and a stable
    /// identity across the aggregate's whole lifetime.
    birth: u64,
    /// Completion-timer lane (site index, or `num_sites` for WAN paths).
    lane: u32,
    /// Absolute completion time of the head member; recomputed *only*
    /// when `member_rate` changes bitwise or membership changes, so both
    /// reallocation modes preserve deadline bits identically.
    deadline: f64,
    /// Sequence number of the aggregate's valid lane-heap entry (global
    /// counter: slot reuse can never revalidate a stale entry).
    seq: u64,
    /// In the current event's deadline-refresh list (dedupe flag).
    needs_refresh: bool,
    members: BinaryHeap<Reverse<Member>>,
    /// Position in `FlowNet::active` and in each path link's `link_aggs`
    /// list (parallel to `path`) — departures are O(path) swap_removes.
    active_pos: u32,
    link_pos: Vec<u32>,
}

/// One slab slot; `gen` survives reuse and stamps issued [`FlowId`]s.
struct Slot {
    gen: u32,
    state: Option<AggState>,
}

/// Lane-heap entry: `(deadline_bits, aggregate birth, slot, seq)` under
/// `Reverse` — a lazy-deletion min-heap keyed by deadline with a
/// deterministic total tiebreak.
type LaneEntry = (u64, u64, u32, u64);

/// Persistent recompute scratch. Per-link arrays are sized to the
/// topology at construction; per-slot arrays grow with the slab. Nothing
/// here is meaningful between `recompute` calls except `seeds` (the
/// caller stages dirty links there) and `refresh` (drained by
/// `flush_refresh`).
#[derive(Default)]
struct Scratch {
    /// Remaining capacity per link (valid for this fill's component).
    remaining: Vec<f64>,
    /// Unfrozen *weight* crossing each link (valid for the component).
    users: Vec<u32>,
    /// Whether a component link has saturated this fill.
    saturated: Vec<bool>,
    /// Per-slot frozen flag (valid for the component's aggregates).
    frozen: Vec<bool>,
    /// BFS visit stamps (per link / per slot) — `stamp` bumps per call,
    /// so clearing is O(1).
    link_mark: Vec<u64>,
    agg_mark: Vec<u64>,
    stamp: u64,
    /// Dirty links staged by the caller before `recompute`.
    seeds: Vec<u32>,
    /// BFS worklist, and the current component's links / aggregates.
    queue: Vec<u32>,
    comp_links: Vec<u32>,
    comp_aggs: Vec<u32>,
    /// Aggregates whose deadline must be recomputed this event (rate bits
    /// changed, or membership changed).
    refresh: Vec<u32>,
}

/// The fluid network. Use through an `Rc<RefCell<_>>` handle.
pub struct FlowNet {
    cfg: FlowNetConfig,
    capacity: Vec<f64>,
    /// Current aggregate rate per link (for utilization sampling).
    link_rate: Vec<f64>,
    /// Cumulative bytes carried per link (monitor counters).
    link_bytes: Vec<f64>,
    /// Each link's flow domain (copied from the topology) and the site
    /// count, for deriving an aggregate's timer lane from its path.
    link_domain: Vec<Domain>,
    num_sites: usize,
    /// When set (sorted, deduplicated): the only links this network is
    /// allowed to carry flows on — the per-shard link partition of the
    /// parallel engine. Bounds the per-advance byte sweep and the
    /// full-recompute seed set to O(claimed) instead of O(all links).
    claimed: Option<Vec<u32>>,
    /// Aggregate slab: slot indices are dense and recycled through `free`.
    slots: Vec<Slot>,
    free: Vec<u32>,
    /// Slots of currently-active aggregates (unordered).
    active: Vec<u32>,
    /// Per-link index lists: active aggregate slots crossing each link.
    link_aggs: Vec<Vec<u32>>,
    /// Aggregation index: `(cap_bits, key_salt, path)` → slot.
    index: BTreeMap<(u64, u64, Vec<LinkId>), u32>,
    /// Per-domain lazy deadline heaps, one completion-timer lane each.
    lane_heaps: Vec<BinaryHeap<Reverse<LaneEntry>>>,
    timers: TimerBank,
    /// Monotone source for `AggState::seq`.
    deadline_seq: u64,
    /// Live member (flow) count across all aggregates.
    active_members: usize,
    next_birth: u64,
    last_advance: f64,
    completions: u64,
    /// High-water mark of `active_members` (concurrency metrics).
    peak_active: usize,
    /// Self-profiler: connected components water-filled since
    /// construction, and links visited by those fills. Deterministic (a
    /// pure function of the event sequence and the incremental flag);
    /// the debug audit's full-recompute probe excludes itself.
    prof_refills: u64,
    prof_dirty_links: u64,
    scratch: Scratch,
}

impl FlowNet {
    pub fn new(topo: &Topology) -> Rc<RefCell<FlowNet>> {
        FlowNet::new_with(topo, FlowNetConfig::default())
    }

    /// A network with explicit [`FlowNetConfig`] knobs (benches and
    /// property tests pin aggregation or incrementality off).
    pub fn new_with(topo: &Topology, cfg: FlowNetConfig) -> Rc<RefCell<FlowNet>> {
        let capacity: Vec<f64> = topo.links.iter().map(|l| l.capacity).collect();
        let link_domain: Vec<Domain> = topo.links.iter().map(|l| l.domain).collect();
        let n = capacity.len();
        let lanes = topo.num_domains();
        Rc::new(RefCell::new(FlowNet {
            cfg,
            capacity,
            link_rate: vec![0.0; n],
            link_bytes: vec![0.0; n],
            link_domain,
            num_sites: lanes - 1,
            claimed: None,
            slots: Vec::new(),
            free: Vec::new(),
            active: Vec::new(),
            link_aggs: vec![Vec::new(); n],
            index: BTreeMap::new(),
            lane_heaps: (0..lanes).map(|_| BinaryHeap::new()).collect(),
            timers: TimerBank::new(lanes),
            deadline_seq: 0,
            active_members: 0,
            next_birth: 0,
            last_advance: 0.0,
            completions: 0,
            peak_active: 0,
            prof_refills: 0,
            prof_dirty_links: 0,
            scratch: Scratch {
                remaining: vec![0.0; n],
                users: vec![0; n],
                saturated: vec![false; n],
                link_mark: vec![0; n],
                ..Scratch::default()
            },
        }))
    }

    /// The configuration this network runs under.
    pub fn config(&self) -> FlowNetConfig {
        self.cfg
    }

    /// Restrict this network to a claimed subset of the topology's links
    /// — the per-shard partition used by the parallel engine
    /// ([`crate::sim::par`]): every shard instantiates the full link
    /// table (so `LinkId`s stay globally meaningful) but only routes
    /// flows over its own domain's links. Claiming shrinks the
    /// full-recompute seed set and the per-advance byte-accrual sweep to
    /// O(claimed links); all stored numbers are bitwise unchanged,
    /// because an unclaimed link never has users and so always carries
    /// rate 0. Admitting a flow that crosses an unclaimed link is a
    /// shard-partition bug (debug-asserted).
    pub fn claim_links(&mut self, links: &[LinkId]) {
        let mut v: Vec<u32> = links.iter().map(|l| l.0 as u32).collect();
        v.sort_unstable();
        v.dedup();
        if let Some(&hi) = v.last() {
            assert!((hi as usize) < self.capacity.len(), "claimed link {hi} out of range");
        }
        self.claimed = Some(v);
    }

    /// Total completed flows (sanity/metrics). Counts members, not
    /// aggregates.
    pub fn completions(&self) -> u64 {
        self.completions
    }

    /// Number of currently active flows (aggregate members).
    pub fn active(&self) -> usize {
        self.active_members
    }

    /// Number of currently active aggregates (water-filling participants).
    pub fn aggregates(&self) -> usize {
        self.active.len()
    }

    /// Most flows ever simultaneously active — exact (updated on every
    /// arrival), so concurrency metrics don't depend on when a consumer
    /// happens to sample.
    /// Self-profiler counters: `(components re-filled, links visited by
    /// those fills)` — the recompute scope this network actually paid
    /// for. Folded into the run's `ProfileReport` by the runner.
    pub fn profile_counters(&self) -> (u64, u64) {
        (self.prof_refills, self.prof_dirty_links)
    }

    pub fn peak_active(&self) -> usize {
        self.peak_active
    }

    /// Current utilization of a link in [0, 1].
    pub fn link_utilization(&self, l: LinkId) -> f64 {
        if self.capacity[l.0] <= 0.0 {
            0.0
        } else {
            (self.link_rate[l.0] / self.capacity[l.0]).min(1.0)
        }
    }

    /// Current aggregate rate on a link, bytes/s.
    pub fn link_rate(&self, l: LinkId) -> f64 {
        self.link_rate[l.0]
    }

    /// Current configured capacity of a link, bytes/s — the live value,
    /// which [`FlowNet::set_capacity`] (provisioning, fault injection)
    /// may have moved away from the topology's nominal. The ops plane's
    /// aggregators read this as their link-probe observable.
    pub fn capacity(&self, l: LinkId) -> f64 {
        self.capacity[l.0]
    }

    /// Cumulative bytes carried by a link since the last call (monitor
    /// sampling). `now` must be the current engine time.
    pub fn take_link_bytes(&mut self, l: LinkId, now: f64) -> f64 {
        self.advance(now);
        std::mem::take(&mut self.link_bytes[l.0])
    }

    /// Peek cumulative bytes without resetting.
    pub fn link_bytes(&self, l: LinkId) -> f64 {
        self.link_bytes[l.0]
    }

    /// Current per-member rate of the aggregate a flow id names (0 once
    /// the aggregate is gone; stale ids stay 0 even after their slab slot
    /// is reused).
    pub fn flow_rate(&self, id: FlowId) -> f64 {
        if id.is_completed() {
            return 0.0;
        }
        match self.slots.get(id.slot() as usize) {
            Some(slot) if slot.gen == id.gen() => {
                slot.state.as_ref().map(|a| a.member_rate).unwrap_or(0.0)
            }
            _ => 0.0,
        }
    }

    // ---- slab plumbing -----------------------------------------------

    fn agg(&self, s: u32) -> &AggState {
        self.slots[s as usize].state.as_ref().expect("inactive slot")
    }

    fn insert_agg(&mut self, mut state: AggState) -> u32 {
        // Record where this aggregate will sit in the index lists (links
        // are distinct along a path, so each list's length is its slot).
        state.active_pos = self.active.len() as u32;
        state.link_pos =
            state.path.iter().map(|&LinkId(l)| self.link_aggs[l].len() as u32).collect();
        let s = match self.free.pop() {
            Some(s) => {
                self.slots[s as usize].state = Some(state);
                s
            }
            None => {
                assert!(self.slots.len() < u32::MAX as usize, "aggregate slab full");
                self.slots.push(Slot { gen: 0, state: Some(state) });
                (self.slots.len() - 1) as u32
            }
        };
        self.active.push(s);
        for &LinkId(l) in &self.slots[s as usize].state.as_ref().unwrap().path {
            self.link_aggs[l].push(s);
        }
        s
    }

    /// Remove an empty aggregate from the slab and every index in
    /// O(path length): stored positions make each removal a `swap_remove`,
    /// with the displaced aggregate's position fixed up in place. The
    /// generation bump invalidates outstanding flow ids; the vanished
    /// state invalidates outstanding lane-heap entries.
    fn release_agg(&mut self, s: u32) {
        let state = self.slots[s as usize].state.take().expect("releasing empty slot");
        self.slots[s as usize].gen = self.slots[s as usize].gen.wrapping_add(1);
        self.free.push(s);
        let p = state.active_pos as usize;
        debug_assert_eq!(self.active[p], s, "active index out of sync");
        self.active.swap_remove(p);
        if p < self.active.len() {
            let moved = self.active[p];
            self.slots[moved as usize].state.as_mut().expect("moved slot inactive").active_pos =
                p as u32;
        }
        for (i, &LinkId(l)) in state.path.iter().enumerate() {
            let la = &mut self.link_aggs[l];
            let p = state.link_pos[i] as usize;
            debug_assert_eq!(la[p], s, "link index out of sync");
            la.swap_remove(p);
            if p < la.len() {
                let moved = la[p];
                let old_last = la.len() as u32; // index the moved entry vacated
                debug_assert_ne!(moved, s, "path repeats a link");
                let m = self.slots[moved as usize].state.as_mut().expect("moved slot inactive");
                for (j, &pl) in m.path.iter().enumerate() {
                    if pl == LinkId(l) && m.link_pos[j] == old_last {
                        m.link_pos[j] = p as u32;
                        break;
                    }
                }
            }
        }
        let removed = self.index.remove(&(state.cap_bits, state.key_salt, state.path));
        debug_assert_eq!(removed, Some(s), "aggregation index out of sync");
    }

    /// The completion-timer lane for a path: the one site every link
    /// belongs to, or the WAN lane if the path crosses domains.
    fn derive_lane(&self, path: &[LinkId]) -> u32 {
        let mut site: Option<u32> = None;
        for &LinkId(l) in path {
            match self.link_domain[l] {
                Domain::Wan => return self.num_sites as u32,
                Domain::Site(s) => {
                    if site.is_some() && site != Some(s) {
                        return self.num_sites as u32;
                    }
                    site = Some(s);
                }
            }
        }
        site.unwrap_or(0)
    }

    // ---- internal fluid mechanics ------------------------------------

    /// Progress all aggregates to `now`, accruing per-member served bytes
    /// and per-link byte counters. Identical in both reallocation modes:
    /// it reads only stored rates, which the modes keep bitwise equal.
    fn advance(&mut self, now: f64) {
        let dt = now - self.last_advance;
        if dt <= 0.0 {
            return;
        }
        for &s in &self.active {
            let a = self.slots[s as usize].state.as_mut().expect("inactive slot in active list");
            if a.member_rate > 0.0 {
                a.base += a.member_rate * dt;
            }
        }
        // Claimed nets sweep only their own links: unclaimed links can
        // never carry rate here, so skipping them changes no bytes.
        if let Some(claimed) = &self.claimed {
            for &l in claimed {
                let rate = self.link_rate[l as usize];
                if rate > 0.0 {
                    self.link_bytes[l as usize] += rate * dt;
                }
            }
        } else {
            for (l, rate) in self.link_rate.iter().enumerate() {
                if *rate > 0.0 {
                    self.link_bytes[l] += rate * dt;
                }
            }
        }
        self.last_advance = now;
    }

    /// Reallocate rates. Incremental mode starts from the dirty links the
    /// caller staged in `scratch.seeds`; full mode seeds every link. Both
    /// then run the same machinery: discover each affected connected
    /// component (links ↔ aggregates sharing them) and water-fill it in
    /// isolation. A component untouched by this event re-fills to the
    /// exact bits it already stores — which is why incremental mode can
    /// skip it without changing any downstream arithmetic.
    fn recompute(&mut self) {
        self.recompute_impl(!self.cfg.incremental);
    }

    fn recompute_impl(&mut self, full: bool) {
        let mut sc = std::mem::take(&mut self.scratch);
        if full {
            sc.seeds.clear();
            // Claimed nets only ever host aggregates over claimed links
            // (admit debug-asserts it), so seeding the claim reaches
            // every component a whole-table seeding would.
            match &self.claimed {
                Some(c) => sc.seeds.extend_from_slice(c),
                None => sc.seeds.extend(0..self.link_aggs.len() as u32),
            }
        }
        sc.stamp += 1;
        let stamp = sc.stamp;
        if sc.agg_mark.len() < self.slots.len() {
            sc.agg_mark.resize(self.slots.len(), 0);
        }
        if sc.frozen.len() < self.slots.len() {
            sc.frozen.resize(self.slots.len(), false);
        }
        let mut si = 0;
        while si < sc.seeds.len() {
            let seed = sc.seeds[si];
            si += 1;
            if sc.link_mark[seed as usize] == stamp {
                continue;
            }
            sc.link_mark[seed as usize] = stamp;
            sc.comp_links.clear();
            sc.comp_aggs.clear();
            sc.queue.clear();
            sc.queue.push(seed);
            while let Some(l) = sc.queue.pop() {
                sc.comp_links.push(l);
                for &s in &self.link_aggs[l as usize] {
                    if sc.agg_mark[s as usize] == stamp {
                        continue;
                    }
                    sc.agg_mark[s as usize] = stamp;
                    sc.comp_aggs.push(s);
                    for &LinkId(pl) in &self.agg(s).path {
                        if sc.link_mark[pl] != stamp {
                            sc.link_mark[pl] = stamp;
                            sc.queue.push(pl as u32);
                        }
                    }
                }
            }
            self.prof_refills += 1;
            self.prof_dirty_links += sc.comp_links.len() as u64;
            self.fill_component(&mut sc);
        }
        sc.seeds.clear();
        self.scratch = sc;
    }

    /// Mark an aggregate frozen at `level`, retiring its weight from its
    /// path links. Flags it for a deadline refresh iff the rate actually
    /// moved (bitwise) — the discipline that keeps both reallocation
    /// modes' deadline bits identical.
    fn freeze_agg(&mut self, sc: &mut Scratch, s: u32, level: f64) {
        let a = self.slots[s as usize].state.as_mut().expect("freezing empty slot");
        if a.member_rate.to_bits() != level.to_bits() && !a.needs_refresh {
            a.needs_refresh = true;
            sc.refresh.push(s);
        }
        a.member_rate = level;
        let w = a.weight;
        for &LinkId(l) in &a.path {
            sc.users[l] -= w;
        }
        sc.frozen[s as usize] = true;
    }

    /// Water-fill one connected component (`sc.comp_links` /
    /// `sc.comp_aggs`) in a canonical order. The result depends *only* on
    /// the component's membership, weights, caps, and link capacities —
    /// not on discovery order, seed order, or anything outside the
    /// component: links enter `inc` through an order-free `min`, per-link
    /// updates commute within a round, cap freezes walk a `(cap_bits,
    /// slot)` sort, and link freezes commute (saturation reads only
    /// `remaining`, which freezes never touch). That invariance is what
    /// makes re-filling a clean component reproduce its stored bits.
    fn fill_component(&mut self, sc: &mut Scratch) {
        sc.comp_links.sort_unstable();
        sc.comp_aggs.sort_unstable_by_key(|&s| (self.agg(s).cap_bits, s));
        for &l in &sc.comp_links {
            let l = l as usize;
            sc.remaining[l] = self.capacity[l];
            sc.users[l] = 0;
            sc.saturated[l] = false;
        }
        for &s in &sc.comp_aggs {
            sc.frozen[s as usize] = false;
            let a = self.agg(s);
            debug_assert!(a.weight > 0, "zero-weight aggregate in fill");
            for &LinkId(l) in &a.path {
                sc.users[l] += a.weight;
            }
        }

        // Relative epsilons: with capacities ~1e8 B/s, one ulp of water-
        // filling residue (~1e-8) must count as "saturated", or the loop
        // spins shaving dust off the same link without freezing anything.
        let link_eps = |cap: f64| cap * 1e-9 + 1e-9;
        let cap_eps = |cap: f64| if cap.is_finite() { cap * 1e-9 + 1e-9 } else { 0.0 };

        // The shared per-member rate of every still-unfrozen aggregate
        // (uniform increments, so one scalar tracks them all).
        let mut level = 0.0f64;
        let mut unfrozen = sc.comp_aggs.len();
        let mut cap_ptr = 0usize;
        let max_iters = sc.comp_aggs.len() + sc.comp_links.len() + 8;
        let mut iters = 0usize;
        while unfrozen > 0 {
            iters += 1;
            // Smallest feasible uniform increment across the component.
            let mut inc = f64::INFINITY;
            for &l in &sc.comp_links {
                let l = l as usize;
                if sc.users[l] > 0 {
                    inc = inc.min(sc.remaining[l].max(0.0) / sc.users[l] as f64);
                }
            }
            while cap_ptr < sc.comp_aggs.len() && sc.frozen[sc.comp_aggs[cap_ptr] as usize] {
                cap_ptr += 1;
            }
            if cap_ptr < sc.comp_aggs.len() {
                let cap = self.agg(sc.comp_aggs[cap_ptr]).cap;
                if cap.is_finite() {
                    inc = inc.min(cap - level);
                }
            }
            if !inc.is_finite() {
                break; // all paths uncapacitated? cannot happen with real links
            }
            let inc = inc.max(0.0);
            level += inc;
            for &l in &sc.comp_links {
                let l = l as usize;
                if sc.users[l] > 0 {
                    sc.remaining[l] -= inc * sc.users[l] as f64;
                }
            }
            let mut froze_any = false;
            // (a) Cap freezes: the sorted prefix whose cap the level reached.
            while cap_ptr < sc.comp_aggs.len() {
                let s = sc.comp_aggs[cap_ptr];
                if sc.frozen[s as usize] {
                    cap_ptr += 1;
                    continue;
                }
                let cap = self.agg(s).cap;
                if cap.is_finite() && level >= cap - cap_eps(cap) {
                    self.freeze_agg(sc, s, level);
                    froze_any = true;
                    unfrozen -= 1;
                    cap_ptr += 1;
                } else {
                    break;
                }
            }
            // (b) Link freezes: newly saturated links freeze every unfrozen
            // aggregate in their index lists.
            for li in 0..sc.comp_links.len() {
                let l = sc.comp_links[li] as usize;
                if sc.saturated[l] || sc.remaining[l] > link_eps(self.capacity[l]) {
                    continue;
                }
                sc.saturated[l] = true;
                for ai in 0..self.link_aggs[l].len() {
                    let s = self.link_aggs[l][ai];
                    if sc.frozen[s as usize] {
                        continue;
                    }
                    self.freeze_agg(sc, s, level);
                    froze_any = true;
                    unfrozen -= 1;
                }
            }
            if unfrozen > 0 && (!froze_any || iters >= max_iters) {
                // Each productive round must freeze something; if nothing
                // froze (fp dust) or the bound is exhausted, everyone left
                // keeps the current level — feasible by construction, off
                // by at most one epsilon of fairness.
                break;
            }
        }
        if unfrozen > 0 {
            for i in 0..sc.comp_aggs.len() {
                let s = sc.comp_aggs[i];
                if !sc.frozen[s as usize] {
                    let a = self.slots[s as usize].state.as_mut().unwrap();
                    if a.member_rate.to_bits() != level.to_bits() && !a.needs_refresh {
                        a.needs_refresh = true;
                        sc.refresh.push(s);
                    }
                    a.member_rate = level;
                }
            }
        }

        // Re-derive the component's link-rate ledger. Index-list order is
        // a function of the insert/release history, which both
        // reallocation modes share — so a clean link's recomputed sum is
        // bitwise the value it already stores.
        for &l in &sc.comp_links {
            let l = l as usize;
            let mut sum = 0.0;
            for &s in &self.link_aggs[l] {
                let a = self.agg(s);
                sum += a.weight as f64 * a.member_rate;
            }
            self.link_rate[l] = sum;
        }
    }

    // ---- deadlines & timer lanes -------------------------------------

    /// Recompute the deadline of every aggregate flagged this event and
    /// push fresh lane-heap entries. Called after `recompute` at every
    /// mutation point; the flag set (rate bits changed ∪ membership
    /// changed) is identical in both reallocation modes, so deadlines are
    /// recomputed at identical `(now, base)` pairs and stay bitwise equal.
    fn flush_refresh(&mut self, eng: &mut Engine) {
        let now = self.last_advance;
        let mut list = std::mem::take(&mut self.scratch.refresh);
        for &s in &list {
            let Some(a) = self.slots[s as usize].state.as_mut() else {
                continue; // released later in the same event
            };
            if !a.needs_refresh {
                continue; // slot reused within the event; not this flag
            }
            a.needs_refresh = false;
            self.deadline_seq += 1;
            a.seq = self.deadline_seq;
            a.deadline = match a.members.peek() {
                Some(Reverse(m)) if a.member_rate > 0.0 => {
                    now + (f64::from_bits(m.target_bits) - a.base).max(0.0) / a.member_rate
                }
                _ => f64::INFINITY,
            };
            if a.deadline.is_finite() {
                let entry = (a.deadline.to_bits(), a.birth, s, a.seq);
                let lane = a.lane as usize;
                self.lane_heaps[lane].push(Reverse(entry));
            }
            // Every refresh is a retune (rate bits moved or membership
            // changed) — record the new shared rate.
            if let Some(rec) = eng.recorder() {
                let tl = a.path.first().map_or(0, |l| l.0 as u32);
                let rate = [("rate", Arg::F(a.member_rate))];
                rec.instant(now, a.lane as u16, tl, "flow.retune", a.birth, &rate);
            }
        }
        list.clear();
        self.scratch.refresh = list;
    }

    /// The lane's earliest valid deadline, popping stale entries (seq
    /// mismatch or released aggregate) as they surface.
    fn lane_min(&mut self, lane: usize) -> Option<f64> {
        loop {
            let Reverse((dl, _, s, seq)) = *self.lane_heaps[lane].peek()?;
            let valid =
                self.slots[s as usize].state.as_ref().map_or(false, |a| a.seq == seq);
            if valid {
                return Some(f64::from_bits(dl));
            }
            self.lane_heaps[lane].pop();
        }
    }

    /// Re-arm every lane at its current earliest deadline. [`TimerBank`]
    /// makes a same-deadline re-arm a no-op, so this is cheap and — more
    /// importantly — leaves event sequence numbers untouched for lanes an
    /// event didn't move.
    fn rearm_all(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine) {
        let mut n = net.borrow_mut();
        for lane in 0..n.lane_heaps.len() {
            match n.lane_min(lane) {
                Some(at) => {
                    let net2 = net.clone();
                    n.timers.arm(eng, lane, at, move |e| Self::on_timer(&net2, e, lane));
                }
                None => n.timers.disarm(eng, lane),
            }
        }
    }

    // ---- public operations (handle-based: callbacks need the net) -----

    /// Start a transfer of `bytes` along `path` with transport cap
    /// `cap_bps` (bytes/s; `f64::INFINITY` for uncapped). `done` fires on
    /// the engine when the last byte arrives. Zero-byte flows complete
    /// immediately and return [`FlowId::COMPLETED`]. The flow's domain
    /// (timer lane) is derived from the path's links; callers that
    /// already hold a [`Route`] should use [`FlowNet::start_route`].
    pub fn start<F: FnOnce(&mut Engine) + 'static>(
        net: &Rc<RefCell<FlowNet>>,
        eng: &mut Engine,
        path: Vec<LinkId>,
        bytes: f64,
        cap_bps: f64,
        done: F,
    ) -> FlowId {
        Self::start_inner(net, eng, path, bytes, cap_bps, Box::new(done), None)
    }

    /// [`FlowNet::start`] for callers holding a domain-annotated
    /// [`Route`] (from [`Topology::route`] and friends) — skips the
    /// per-link domain derivation.
    pub fn start_route<F: FnOnce(&mut Engine) + 'static>(
        net: &Rc<RefCell<FlowNet>>,
        eng: &mut Engine,
        route: Route,
        bytes: f64,
        cap_bps: f64,
        done: F,
    ) -> FlowId {
        let lane = {
            let n = net.borrow();
            let lane = route.domain.lane(n.num_sites) as u32;
            debug_assert_eq!(lane, n.derive_lane(&route.path), "route domain mismatch");
            lane
        };
        Self::start_inner(net, eng, route.path, bytes, cap_bps, Box::new(done), Some(lane))
    }

    fn start_inner(
        net: &Rc<RefCell<FlowNet>>,
        eng: &mut Engine,
        path: Vec<LinkId>,
        bytes: f64,
        cap_bps: f64,
        done: Callback,
        lane: Option<u32>,
    ) -> FlowId {
        assert!(bytes >= 0.0 && cap_bps > 0.0);
        if bytes <= 0.0 {
            eng.schedule_in(0.0, done);
            return FlowId::COMPLETED;
        }
        assert!(!path.is_empty(), "flow with empty path");
        let id = {
            let mut n = net.borrow_mut();
            n.advance(eng.now());
            let t = eng.now();
            if eng.recorder().is_some() {
                // Flow spans are keyed by the member's birth counter —
                // stable across slot reuse and identical in both
                // reallocation modes.
                let birth = n.next_birth;
                let dom = lane.unwrap_or_else(|| n.derive_lane(&path)) as u16;
                let tl = path.first().map_or(0, |l| l.0 as u32);
                if let Some(rec) = eng.recorder() {
                    rec.begin(t, dom, tl, "flow", birth, &[("bytes", Arg::F(bytes))]);
                }
            }
            let id = n.admit(path, bytes, cap_bps, done, lane);
            n.recompute();
            n.flush_refresh(eng);
            #[cfg(debug_assertions)]
            n.audit();
            id
        };
        Self::rearm_all(net, eng);
        id
    }

    /// Join an existing aggregate or found a new one; stages the touched
    /// path as recompute seeds and flags the aggregate for a deadline
    /// refresh (membership changed).
    fn admit(
        &mut self,
        path: Vec<LinkId>,
        bytes: f64,
        cap: f64,
        done: Callback,
        lane: Option<u32>,
    ) -> FlowId {
        #[cfg(debug_assertions)]
        if let Some(claimed) = &self.claimed {
            for &LinkId(l) in &path {
                assert!(
                    claimed.binary_search(&(l as u32)).is_ok(),
                    "flow admitted over unclaimed link {l}"
                );
            }
        }
        let birth = self.next_birth;
        self.next_birth += 1;
        let cap_bits = cap.to_bits();
        let salt = if self.cfg.aggregate { 0 } else { birth };
        self.active_members += 1;
        self.peak_active = self.peak_active.max(self.active_members);
        let key = (cap_bits, salt, path);
        if let Some(&s) = self.index.get(&key) {
            let a = self.slots[s as usize].state.as_mut().expect("indexed slot inactive");
            let target = a.base + bytes;
            a.members.push(Reverse(Member {
                target_bits: target.to_bits(),
                birth,
                bytes,
                done: Some(done),
            }));
            a.weight += 1;
            if !a.needs_refresh {
                a.needs_refresh = true;
                self.scratch.refresh.push(s);
            }
            self.scratch.seeds.clear();
            for &LinkId(l) in &key.2 {
                self.scratch.seeds.push(l as u32);
            }
            FlowId::new(s, self.slots[s as usize].gen)
        } else {
            let (_, _, path) = key;
            let lane = lane.unwrap_or_else(|| self.derive_lane(&path));
            let mut members = BinaryHeap::new();
            members.push(Reverse(Member {
                target_bits: bytes.to_bits(),
                birth,
                bytes,
                done: Some(done),
            }));
            let state = AggState {
                path: path.clone(),
                cap,
                cap_bits,
                key_salt: salt,
                weight: 1,
                member_rate: 0.0,
                base: 0.0,
                birth,
                lane,
                deadline: f64::INFINITY,
                seq: 0,
                needs_refresh: true,
                members,
                active_pos: 0, // assigned by insert_agg
                link_pos: Vec::new(),
            };
            let s = self.insert_agg(state);
            self.index.insert((cap_bits, salt, path), s);
            self.scratch.refresh.push(s);
            self.scratch.seeds.clear();
            for &LinkId(l) in &self.slots[s as usize].state.as_ref().unwrap().path {
                self.scratch.seeds.push(l as u32);
            }
            FlowId::new(s, self.slots[s as usize].gen)
        }
    }

    /// Change a link's capacity at runtime (network provisioning §2.1) and
    /// reallocate.
    pub fn set_capacity(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine, l: LinkId, capacity: f64) {
        Self::set_capacities(net, eng, &[(l, capacity)]);
    }

    /// Retune several links in one shot — a lightpath grant or teardown
    /// moves a whole directed wave pair (and a flap restore moves every
    /// wave link) — paying a single `advance` + reallocation + timer
    /// re-arm for the batch instead of one per link. The changed links
    /// are exactly the recompute seeds, so only their components re-fill.
    pub fn set_capacities(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine, changes: &[(LinkId, f64)]) {
        if changes.is_empty() {
            return;
        }
        {
            let mut n = net.borrow_mut();
            n.advance(eng.now());
            n.scratch.seeds.clear();
            for &(LinkId(l), capacity) in changes {
                assert!(capacity > 0.0);
                n.capacity[l] = capacity;
                n.scratch.seeds.push(l as u32);
            }
            let t = eng.now();
            if let Some(rec) = eng.recorder() {
                for &(LinkId(l), capacity) in changes {
                    let dom = n.link_domain[l].lane(n.num_sites) as u16;
                    let cap = [("capacity", Arg::F(capacity))];
                    rec.instant(t, dom, l as u32, "link.retune", 0, &cap);
                }
            }
            n.recompute();
            n.flush_refresh(eng);
            #[cfg(debug_assertions)]
            n.audit();
        }
        Self::rearm_all(net, eng);
    }

    /// Pop every member of aggregate `s` whose target the served-bytes
    /// axis has reached, within an epsilon relative to the member rate
    /// (1 ns of transfer) — pure absolute epsilons leave residues whose
    /// completion dt falls below the clock's ulp and the event loop stops
    /// advancing time. Returns whether membership changed.
    fn drain_completed(&mut self, s: u32, out: &mut Vec<(u64, u32, Callback)>) -> bool {
        let a = self.slots[s as usize].state.as_mut().expect("draining empty slot");
        let tl = a.path.first().map_or(0, |l| l.0 as u32);
        let mut any = false;
        loop {
            let due = match a.members.peek() {
                Some(Reverse(m)) => {
                    f64::from_bits(m.target_bits) - a.base <= 1e-6 + a.member_rate * 1e-9
                }
                None => false,
            };
            if !due {
                break;
            }
            let Reverse(mut m) = a.members.pop().expect("peeked member vanished");
            // Byte conservation: a completing member has been served its
            // birth bytes up to fp dust (the forced-progress path can
            // carry slightly more residue than the epsilon test).
            debug_assert!(
                f64::from_bits(m.target_bits) - a.base <= 1e-3 + m.bytes * 1e-6,
                "completion leaks bytes: {} of {} undelivered",
                f64::from_bits(m.target_bits) - a.base,
                m.bytes
            );
            a.weight -= 1;
            any = true;
            self.completions += 1;
            self.active_members -= 1;
            if let Some(cb) = m.done.take() {
                out.push((m.birth, tl, cb));
            }
        }
        if any && !a.needs_refresh {
            a.needs_refresh = true;
            self.scratch.refresh.push(s);
        }
        any
    }

    /// Forced progress: the lane timer fired for this aggregate but fp
    /// dust kept its head member outside the epsilon — complete it anyway
    /// (mirrors the old global core's nearest-flow forcing).
    fn force_head(&mut self, s: u32, out: &mut Vec<(u64, u32, Callback)>) {
        let a = self.slots[s as usize].state.as_mut().expect("forcing empty slot");
        let tl = a.path.first().map_or(0, |l| l.0 as u32);
        let Reverse(mut m) = a.members.pop().expect("forcing memberless aggregate");
        debug_assert!(
            f64::from_bits(m.target_bits) - a.base <= 1e-3 + m.bytes * 1e-6,
            "forced completion leaks bytes: {} of {} undelivered",
            f64::from_bits(m.target_bits) - a.base,
            m.bytes
        );
        a.weight -= 1;
        self.completions += 1;
        self.active_members -= 1;
        if let Some(cb) = m.done.take() {
            out.push((m.birth, tl, cb));
        }
        if !a.needs_refresh {
            a.needs_refresh = true;
            self.scratch.refresh.push(s);
        }
    }

    /// A domain lane's completion timer fired: drain due aggregates,
    /// release empties, reallocate from the touched paths, refresh moved
    /// deadlines, re-arm, and only then run completion callbacks (birth
    /// order) outside the borrow.
    fn on_timer(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine, lane: usize) {
        let mut finished: Vec<(u64, u32, Callback)> = Vec::new();
        {
            let mut n = net.borrow_mut();
            let n = &mut *n; // plain &mut: field-disjoint borrows below
            n.timers.fired(lane);
            let now = eng.now();
            n.advance(now);
            // Pop every valid entry that is due. Each live aggregate has
            // at most one valid entry (every push bumps `seq`), so this
            // visits each due aggregate once, in deterministic
            // (deadline, birth, slot) order.
            let mut touched: Vec<u32> = Vec::new();
            let mut first_due: Option<u32> = None;
            loop {
                let Some(&Reverse((dl, _, s, seq))) = n.lane_heaps[lane].peek() else {
                    break;
                };
                let valid =
                    n.slots[s as usize].state.as_ref().map_or(false, |a| a.seq == seq);
                if !valid {
                    n.lane_heaps[lane].pop();
                    continue;
                }
                if f64::from_bits(dl) > now {
                    break;
                }
                n.lane_heaps[lane].pop();
                if first_due.is_none() {
                    first_due = Some(s);
                }
                if n.drain_completed(s, &mut finished) {
                    touched.push(s);
                }
                // A due aggregate whose head stayed put (fp dust) had its
                // entry consumed; `drain_completed` / the refresh flag
                // re-issues one at a recomputed deadline.
                else {
                    let a = n.slots[s as usize].state.as_mut().expect("due slot inactive");
                    if !a.needs_refresh {
                        a.needs_refresh = true;
                        n.scratch.refresh.push(s);
                    }
                }
            }
            if finished.is_empty() {
                if let Some(s) = first_due {
                    n.force_head(s, &mut finished);
                    touched.push(s);
                }
            }
            // Deterministic callback order: member birth (insertion)
            // order, immune to slab slot recycling.
            finished.sort_unstable_by_key(|&(b, _, _)| b);
            // Close the flow spans here, inside the engine event, in the
            // same birth order the callbacks will run in.
            if let Some(rec) = eng.recorder() {
                for (birth, tl, _) in finished.iter() {
                    rec.end(now, lane as u16, *tl, "flow", *birth, &[]);
                }
            }
            // Seeds: the paths of every aggregate whose weight changed —
            // collected before releases tear the paths down.
            n.scratch.seeds.clear();
            for &s in &touched {
                let a = n.slots[s as usize].state.as_ref().expect("touched slot inactive");
                for &LinkId(l) in &a.path {
                    n.scratch.seeds.push(l as u32);
                }
            }
            for &s in &touched {
                if n.agg(s).weight == 0 {
                    n.release_agg(s);
                }
            }
            n.recompute();
            n.flush_refresh(eng);
            #[cfg(debug_assertions)]
            n.audit();
        }
        Self::rearm_all(net, eng);
        // Run callbacks without holding the borrow; they may start flows.
        for (_, _, cb) in finished {
            cb(eng);
        }
    }

    /// Self-audit, compiled only under `debug_assertions` and run after
    /// every mutation point: structural invariants of the slab, index
    /// lists, and aggregation index, feasibility of the allocation, and —
    /// the incremental-mode proof obligation — a full from-scratch
    /// recompute over every component, asserting it reproduces the stored
    /// rates *bitwise*. Release builds pay nothing.
    #[cfg(debug_assertions)]
    fn audit(&mut self) {
        assert!(self.scratch.refresh.is_empty(), "unflushed deadline refreshes");
        let mut members = 0usize;
        for (p, &s) in self.active.iter().enumerate() {
            let a = self.agg(s); // panics if the slot lost its state
            assert_eq!(a.active_pos as usize, p, "active index out of sync at {p}");
            assert!(a.weight > 0, "empty aggregate survived completion");
            assert_eq!(a.weight as usize, a.members.len(), "weight/member mismatch");
            members += a.weight as usize;
            assert!(a.member_rate >= 0.0 && a.member_rate.is_finite(), "bad rate on slot {s}");
            assert!(a.member_rate <= a.cap + a.cap * 1e-6 + 1e-6, "rate above cap on slot {s}");
            assert!(a.base >= 0.0, "negative served bytes on slot {s}");
            assert_eq!(a.path.len(), a.link_pos.len(), "path/link_pos length mismatch");
            for (&LinkId(l), &lp) in a.path.iter().zip(&a.link_pos) {
                assert_eq!(
                    self.link_aggs[l].get(lp as usize),
                    Some(&s),
                    "slot {s} missing from link {l} index list"
                );
            }
            assert_eq!(
                self.index.get(&(a.cap_bits, a.key_salt, a.path.clone())),
                Some(&s),
                "slot {s} missing from aggregation index"
            );
        }
        assert_eq!(members, self.active_members, "member count out of sync");
        assert_eq!(self.index.len(), self.active.len(), "index/active length mismatch");
        for (l, la) in self.link_aggs.iter().enumerate() {
            let sum: f64 = la.iter().map(|&s| self.agg(s).weight as f64 * self.agg(s).member_rate).sum();
            let eps = self.capacity[l] * 1e-6 + 1e-6;
            assert!(
                sum <= self.capacity[l] + eps,
                "link {l} oversubscribed: {sum} > {}",
                self.capacity[l]
            );
            assert!(
                (sum - self.link_rate[l]).abs() <= eps,
                "link {l} rate ledger drift: recomputed {sum}, ledger {}",
                self.link_rate[l]
            );
            for (p, &s) in la.iter().enumerate() {
                let a = self.agg(s);
                let cross = a
                    .path
                    .iter()
                    .zip(&a.link_pos)
                    .any(|(&pl, &lp)| pl == LinkId(l) && lp as usize == p);
                assert!(cross, "link {l} entry {p} (slot {s}) lacks a back-reference");
            }
        }
        // Incremental == full, bitwise: re-running the water-filling from
        // scratch over *every* component must reproduce the stored rates
        // exactly — if incremental maintenance left any component stale,
        // either a rate snapshot differs or the re-fill flags a deadline
        // refresh. (A clean re-fill flags nothing, so this probe is
        // side-effect-free.)
        let rates: Vec<(u32, u64)> =
            self.active.iter().map(|&s| (s, self.agg(s).member_rate.to_bits())).collect();
        let link_rates: Vec<u64> = self.link_rate.iter().map(|r| r.to_bits()).collect();
        // The probe below is a debug-only shadow recompute; keep it out
        // of the self-profiler so counters match release builds.
        let (pr, pd) = (self.prof_refills, self.prof_dirty_links);
        self.recompute_impl(true);
        self.prof_refills = pr;
        self.prof_dirty_links = pd;
        assert!(
            self.scratch.refresh.is_empty(),
            "full recompute moved rates the incremental pass left stale"
        );
        for &(s, bits) in &rates {
            assert_eq!(
                self.agg(s).member_rate.to_bits(),
                bits,
                "slot {s}: incremental rate diverges from full recompute"
            );
        }
        for (l, &bits) in link_rates.iter().enumerate() {
            assert_eq!(
                self.link_rate[l].to_bits(),
                bits,
                "link {l}: incremental ledger diverges from full recompute"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topology::{NodeSpec, Topology};
    use std::cell::{Cell, RefCell};
    use std::rc::Rc;

    fn two_site_topo() -> Topology {
        let mut t = Topology::new();
        let a = t.add_site("a");
        let b = t.add_site("b");
        let spec = NodeSpec { nic_bps: 100.0, disk_bps: 50.0, cpu_slots: 4 };
        t.add_rack(a, 4, &spec, 1000.0);
        t.add_rack(b, 4, &spec, 1000.0);
        t.connect_sites(a, b, 200.0, 0.01);
        t
    }

    #[test]
    fn single_flow_runs_at_bottleneck() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done_at = Rc::new(RefCell::new(0.0));
        let d = done_at.clone();
        // NIC (100 B/s) is the bottleneck: 1000 B takes 10 s.
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, move |e| {
            *d.borrow_mut() = e.now();
        });
        eng.run();
        assert!((*done_at.borrow() - 10.0).abs() < 1e-6);
        assert_eq!(net.borrow().completions(), 1);
    }

    #[test]
    fn traced_flow_emits_begin_retune_end() {
        use crate::trace::{Recorder, Stream, TraceSpec};
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        eng.set_recorder(Recorder::new(&TraceSpec::new()));
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, |_| {});
        eng.run();
        let mut s = Stream::new(2);
        s.absorb(eng.take_recorder().unwrap());
        let js = s.to_chrome_json();
        // One begin, at least one retune (rate 100 on admit), one end.
        assert_eq!(js.matches("\"ph\":\"b\"").count(), 1, "{js}");
        assert_eq!(js.matches("\"ph\":\"e\"").count(), 1, "{js}");
        assert!(js.contains("flow.retune"), "{js}");
        assert!(js.contains("\"rate\":100"), "{js}");
        // Untraced runs pay only the recorder branch: counters intact.
        let (refills, dirty) = net.borrow().profile_counters();
        assert!(refills >= 2 && dirty >= refills, "refills={refills} dirty={dirty}");
    }

    #[test]
    fn two_flows_share_fairly() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let times = Rc::new(RefCell::new(Vec::new()));
        // Both flows leave node0: share its 100 B/s NIC → 50 B/s each.
        for dst in [1, 2] {
            let times = times.clone();
            let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[dst]);
            FlowNet::start(&net, &mut eng, path, 500.0, f64::INFINITY, move |e| {
                times.borrow_mut().push(e.now());
            });
        }
        eng.run();
        let ts = times.borrow();
        assert!((ts[0] - 10.0).abs() < 1e-6 && (ts[1] - 10.0).abs() < 1e-6, "{ts:?}");
    }

    #[test]
    fn departure_releases_bandwidth() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done = Rc::new(RefCell::new(Vec::new()));
        // Flow 1: 250 B, flow 2: 750 B, same NIC. Phase 1: both at 50 B/s
        // until t=5 (flow1 done). Phase 2: flow2 at 100 B/s for its
        // remaining 500 B → done at t=10. (Same path and cap, so the two
        // flows ride one aggregate with member targets 250 and 750.)
        for bytes in [250.0, 750.0] {
            let done = done.clone();
            let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
            FlowNet::start(&net, &mut eng, path, bytes, f64::INFINITY, move |e| {
                done.borrow_mut().push(e.now());
            });
        }
        assert_eq!(net.borrow().aggregates(), 1);
        eng.run();
        let d = done.borrow();
        assert!((d[0] - 5.0).abs() < 1e-6, "{d:?}");
        assert!((d[1] - 10.0).abs() < 1e-6, "{d:?}");
        // Both flows overlapped; the high-water mark saw them together.
        assert_eq!(net.borrow().peak_active(), 2);
        assert_eq!(net.borrow().active(), 0);
    }

    #[test]
    fn transport_cap_limits_rate() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done_at = Rc::new(RefCell::new(0.0));
        let d = done_at.clone();
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        // Cap 20 B/s though the path allows 100 → 1000 B takes 50 s.
        FlowNet::start(&net, &mut eng, path, 1000.0, 20.0, move |e| {
            *d.borrow_mut() = e.now();
        });
        eng.run();
        assert!((*done_at.borrow() - 50.0).abs() < 1e-6);
    }

    #[test]
    fn capped_flow_leaves_bandwidth_for_others() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done = Rc::new(RefCell::new(Vec::new()));
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        // Capped flow takes 20 B/s; uncapped flow gets the remaining 80.
        // Distinct caps keep them in distinct aggregates.
        for (bytes, cap) in [(200.0, 20.0), (800.0, f64::INFINITY)] {
            let done = done.clone();
            FlowNet::start(&net, &mut eng, path.clone(), bytes, cap, move |e| {
                done.borrow_mut().push(e.now());
            });
        }
        assert_eq!(net.borrow().aggregates(), 2);
        eng.run();
        let d = done.borrow();
        assert!((d[0] - 10.0).abs() < 1e-6 && (d[1] - 10.0).abs() < 1e-6, "{d:?}");
    }

    /// Drive the same intra-rack flow mix on an unrestricted net and on
    /// one that claimed only the involved links: completions, completion
    /// times and per-link byte counters must agree bitwise.
    fn run_site_flows(claim: bool) -> (u64, Vec<f64>, Vec<u64>) {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let mut links = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        links.extend(t.path(t.racks[0].nodes[1], t.racks[0].nodes[0]));
        links.extend(t.path(t.racks[0].nodes[0], t.racks[0].nodes[2]));
        if claim {
            net.borrow_mut().claim_links(&links);
        }
        let done = Rc::new(RefCell::new(Vec::new()));
        for (src, dst, bytes) in [(0, 1, 400.0), (1, 0, 250.0), (0, 2, 700.0)] {
            let done = done.clone();
            let path = t.path(t.racks[0].nodes[src], t.racks[0].nodes[dst]);
            FlowNet::start(&net, &mut eng, path, bytes, f64::INFINITY, move |e| {
                done.borrow_mut().push(e.now());
            });
        }
        eng.run();
        let n = net.borrow();
        let bytes: Vec<u64> = links.iter().map(|&l| n.link_bytes(l).to_bits()).collect();
        (n.completions(), done.borrow().clone(), bytes)
    }

    #[test]
    fn claimed_net_is_bitwise_identical_on_its_links() {
        let unclaimed = run_site_flows(false);
        let claimed = run_site_flows(true);
        assert_eq!(unclaimed, claimed);
        assert_eq!(claimed.0, 3);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "unclaimed link")]
    fn admitting_over_unclaimed_link_is_a_bug() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let claim = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        net.borrow_mut().claim_links(&claim);
        // Cross-site: traverses uplinks and the WAN link, none claimed.
        let path = t.path(t.racks[0].nodes[0], t.racks[1].nodes[0]);
        FlowNet::start(&net, &mut eng, path, 100.0, f64::INFINITY, |_| {});
    }

    #[test]
    fn wan_link_contention() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done = Rc::new(RefCell::new(Vec::new()));
        // Three cross-site flows from distinct sources share the 200 B/s
        // WAN link: ~66.7 B/s each (NICs are 100, not binding).
        for src in 0..3 {
            let done = done.clone();
            let path = t.path(t.racks[0].nodes[src], t.racks[1].nodes[src]);
            FlowNet::start(&net, &mut eng, path, 200.0, f64::INFINITY, move |e| {
                done.borrow_mut().push(e.now());
            });
        }
        eng.run();
        for &d in done.borrow().iter() {
            assert!((d - 3.0).abs() < 1e-6, "{d}");
        }
    }

    #[test]
    fn zero_byte_flow_completes_immediately() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let hit = Rc::new(RefCell::new(false));
        let h = hit.clone();
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        let id = FlowNet::start(&net, &mut eng, path, 0.0, f64::INFINITY, move |_| {
            *h.borrow_mut() = true
        });
        assert!(id.is_completed());
        eng.run();
        assert!(*hit.borrow());
    }

    #[test]
    fn zero_byte_flow_id_never_aliases_real_flows() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        let z = FlowNet::start(&net, &mut eng, path.clone(), 0.0, f64::INFINITY, |_| {});
        // Real flows never mint the reserved id, so `flow_rate` keeps
        // answering 0 for the completed flow — not for someone else.
        let real = FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, |_| {});
        assert!(z.is_completed() && !real.is_completed());
        assert_ne!(z, real);
        assert_eq!(net.borrow().flow_rate(z), 0.0);
        assert!(net.borrow().flow_rate(real) > 0.0);
        eng.run();
    }

    #[test]
    fn stale_flow_ids_do_not_alias_reused_slots() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        let a = FlowNet::start(&net, &mut eng, path.clone(), 100.0, f64::INFINITY, |_| {});
        eng.run(); // flow a completes; its aggregate's slab slot is recycled
        assert_eq!(net.borrow().active(), 0);
        let b = FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, |_| {});
        // b reuses a's slot under a new generation: a's id must read 0
        // while b reports a live rate.
        assert_ne!(a, b);
        assert_eq!(net.borrow().flow_rate(a), 0.0);
        assert!((net.borrow().flow_rate(b) - 100.0).abs() < 1e-6);
        eng.run();
    }

    #[test]
    fn capacity_change_reallocates() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done_at = Rc::new(RefCell::new(0.0));
        let d = done_at.clone();
        let n0 = t.racks[0].nodes[0];
        let n1 = t.racks[0].nodes[1];
        let path = t.path(n0, n1);
        let tx = t.node(n0).nic_tx;
        let rx = t.node(n1).nic_rx;
        FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, move |e| {
            *d.borrow_mut() = e.now();
        });
        // At t=5 (500 B left), upgrade both NICs to 500 B/s → 1 more second.
        let net2 = net.clone();
        eng.schedule_at(5.0, move |e| {
            FlowNet::set_capacity(&net2, e, tx, 500.0);
            FlowNet::set_capacity(&net2, e, rx, 500.0);
        });
        eng.run();
        assert!((*done_at.borrow() - 6.0).abs() < 1e-6, "{}", done_at.borrow());
    }

    #[test]
    fn batched_capacity_change_reallocates_once() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done_at = Rc::new(RefCell::new(0.0));
        let d = done_at.clone();
        let n0 = t.racks[0].nodes[0];
        let n1 = t.racks[0].nodes[1];
        let tx = t.node(n0).nic_tx;
        let rx = t.node(n1).nic_rx;
        FlowNet::start(&net, &mut eng, t.path(n0, n1), 1000.0, f64::INFINITY, move |e| {
            *d.borrow_mut() = e.now();
        });
        // Same retune as `capacity_change_reallocates`, as one batch: at
        // t=5 (500 B left) both NICs jump to 500 B/s → 1 more second.
        let net2 = net.clone();
        eng.schedule_at(5.0, move |e| {
            FlowNet::set_capacities(&net2, e, &[(tx, 500.0), (rx, 500.0)]);
        });
        eng.run();
        assert!((*done_at.borrow() - 6.0).abs() < 1e-6, "{}", done_at.borrow());
        // An empty batch is a no-op (no timer churn, no borrow).
        FlowNet::set_capacities(&net, &mut eng, &[]);
        assert_eq!(net.borrow().active(), 0);
    }

    #[test]
    fn link_byte_counters_accumulate() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let n0 = t.racks[0].nodes[0];
        let path = t.path(n0, t.racks[0].nodes[1]);
        FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, |_| {});
        eng.run();
        let now = eng.now();
        let bytes = net.borrow_mut().take_link_bytes(t.node(n0).nic_tx, now);
        assert!((bytes - 1000.0).abs() < 1e-6);
        // Counter resets after take.
        let again = net.borrow_mut().take_link_bytes(t.node(n0).nic_tx, now);
        assert_eq!(again, 0.0);
    }

    #[test]
    fn same_path_flows_collapse_into_one_aggregate() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done = Rc::new(RefCell::new(Vec::new()));
        // Eight flows over one path: one aggregate of weight 8 on a
        // 100 B/s NIC. The NIC stays saturated until the last byte, so
        // the k-th completion lands where the cumulative byte count says:
        // first member (100 B at 100/8 B/s) at t=8, last at 3600/100=36.
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        for k in 0..8 {
            let done = done.clone();
            FlowNet::start(&net, &mut eng, path.clone(), 100.0 * (k + 1) as f64, f64::INFINITY, move |e| {
                done.borrow_mut().push(e.now());
            });
        }
        {
            let n = net.borrow();
            assert_eq!(n.aggregates(), 1);
            assert_eq!(n.active(), 8);
            assert_eq!(n.peak_active(), 8);
        }
        eng.run();
        let d = done.borrow();
        assert_eq!(d.len(), 8);
        assert!((d[0] - 8.0).abs() < 1e-6, "{d:?}");
        assert!((d[7] - 36.0).abs() < 1e-6, "{d:?}");
        assert!(d.windows(2).all(|w| w[0] <= w[1]), "{d:?}");
        assert_eq!(net.borrow().completions(), 8);
        assert_eq!(net.borrow().aggregates(), 0);
    }

    #[test]
    fn aggregation_off_keeps_one_aggregate_per_flow() {
        let t = two_site_topo();
        let cfg = FlowNetConfig { aggregate: false, incremental: true };
        let net = FlowNet::new_with(&t, cfg);
        let mut eng = Engine::new();
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        for _ in 0..4 {
            FlowNet::start(&net, &mut eng, path.clone(), 500.0, f64::INFINITY, |_| {});
        }
        assert_eq!(net.borrow().aggregates(), 4);
        assert_eq!(net.borrow().active(), 4);
        eng.run();
        assert_eq!(net.borrow().completions(), 4);
    }

    #[test]
    fn completion_timers_shard_by_domain() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        // One site-a flow, one site-b flow, one cross-site flow: three
        // lanes armed, each holding exactly its own aggregate's deadline.
        let a = FlowNet::start(
            &net,
            &mut eng,
            t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]),
            100.0,
            f64::INFINITY,
            |_| {},
        );
        let b = FlowNet::start(
            &net,
            &mut eng,
            t.path(t.racks[1].nodes[0], t.racks[1].nodes[1]),
            100.0,
            f64::INFINITY,
            |_| {},
        );
        let w = FlowNet::start(
            &net,
            &mut eng,
            t.path(t.racks[0].nodes[2], t.racks[1].nodes[2]),
            100.0,
            f64::INFINITY,
            |_| {},
        );
        {
            let n = net.borrow();
            assert_eq!(n.agg(a.slot()).lane, 0);
            assert_eq!(n.agg(b.slot()).lane, 1);
            assert_eq!(n.agg(w.slot()).lane, 2);
            assert_eq!(n.timers.armed(), 3);
        }
        eng.run();
        assert_eq!(net.borrow().completions(), 3);
        assert_eq!(net.borrow().timers.armed(), 0);
    }

    #[test]
    fn allocation_invariants_property() {
        crate::proptest::check("maxmin: feasible, capped, nonzero", 40, |rng| {
            let t = two_site_topo();
            let net = FlowNet::new(&t);
            let mut eng = Engine::new();
            let nflows = 1 + rng.gen_range(12) as usize;
            for _ in 0..nflows {
                let src = t.racks[rng.gen_range(2) as usize].nodes[rng.gen_range(4) as usize];
                let mut dst = src;
                while dst == src {
                    dst = t.racks[rng.gen_range(2) as usize].nodes[rng.gen_range(4) as usize];
                }
                let cap = if rng.chance(0.5) { 5.0 + rng.f64() * 200.0 } else { f64::INFINITY };
                FlowNet::start(&net, &mut eng, t.path(src, dst), 1e7, cap, |_| {});
            }
            let n = net.borrow();
            // (1) per-link feasibility
            for (l, &rate) in n.link_rate.iter().enumerate() {
                if rate > n.capacity[l] + 1e-6 {
                    return Err(format!("link {l} over capacity: {rate} > {}", n.capacity[l]));
                }
            }
            for &s in &n.active {
                let a = n.agg(s);
                // (2) cap respected
                if a.member_rate > a.cap + 1e-6 {
                    return Err(format!("aggregate over cap: {} > {}", a.member_rate, a.cap));
                }
                // (3) no starvation
                if a.member_rate <= 0.0 {
                    return Err("starved aggregate".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn work_conservation_property() {
        // With a single bottleneck and no caps, the bottleneck is saturated.
        crate::proptest::check("maxmin work conserving", 30, |rng| {
            let t = two_site_topo();
            let net = FlowNet::new(&t);
            let mut eng = Engine::new();
            let k = 2 + rng.gen_range(3) as usize;
            for i in 0..k {
                // All flows out of node0 → its NIC is the shared bottleneck.
                let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1 + (i % 3)]);
                FlowNet::start(&net, &mut eng, path, 1e6, f64::INFINITY, |_| {});
            }
            let n = net.borrow();
            let nic = t.node(t.racks[0].nodes[0]).nic_tx;
            let rate = n.link_rate(nic);
            if (rate - 100.0).abs() < 1e-6 {
                Ok(())
            } else {
                Err(format!("bottleneck not saturated: {rate}"))
            }
        });
    }

    /// Textbook from-scratch progressive water-filling over weighted
    /// `(path, weight, cap)` participants — the oracle the incremental
    /// core is checked against.
    fn oracle_rates(caps: &[f64], aggs: &[(Vec<usize>, u32, f64)]) -> Vec<f64> {
        let mut rem: Vec<f64> = caps.to_vec();
        let mut users = vec![0u64; caps.len()];
        for (path, w, _) in aggs {
            for &l in path {
                users[l] += *w as u64;
            }
        }
        let mut rate = vec![0.0f64; aggs.len()];
        let mut frozen = vec![false; aggs.len()];
        let mut level = 0.0f64;
        let mut left = aggs.len();
        for _ in 0..(2 * aggs.len() + caps.len() + 8) {
            if left == 0 {
                break;
            }
            let mut inc = f64::INFINITY;
            for l in 0..caps.len() {
                if users[l] > 0 {
                    inc = inc.min(rem[l].max(0.0) / users[l] as f64);
                }
            }
            for (i, (_, _, cap)) in aggs.iter().enumerate() {
                if !frozen[i] && cap.is_finite() {
                    inc = inc.min(cap - level);
                }
            }
            if !inc.is_finite() {
                break;
            }
            level += inc.max(0.0);
            for l in 0..caps.len() {
                if users[l] > 0 {
                    rem[l] -= inc.max(0.0) * users[l] as f64;
                }
            }
            let mut froze = false;
            for (i, (path, w, cap)) in aggs.iter().enumerate() {
                if frozen[i] {
                    continue;
                }
                let capped = cap.is_finite() && level >= cap - (cap * 1e-9 + 1e-9);
                let saturated = path.iter().any(|&l| rem[l] <= caps[l] * 1e-9 + 1e-9);
                if capped || saturated {
                    frozen[i] = true;
                    rate[i] = level;
                    froze = true;
                    left -= 1;
                    for &l in path {
                        users[l] -= *w as u64;
                    }
                }
            }
            if !froze {
                break;
            }
        }
        for (i, r) in rate.iter_mut().enumerate() {
            if !frozen[i] {
                *r = level;
            }
        }
        rate
    }

    /// Drive one random event against a set of nets kept in lockstep.
    fn random_event(
        rng: &mut crate::util::Rng,
        t: &Topology,
        nets: &[&Rc<RefCell<FlowNet>>],
        engs: &mut [Engine],
        now: &mut f64,
    ) {
        match rng.gen_range(4) {
            0 | 1 => {
                let src = t.racks[rng.gen_range(2) as usize].nodes[rng.gen_range(4) as usize];
                let mut dst = src;
                while dst == src {
                    dst = t.racks[rng.gen_range(2) as usize].nodes[rng.gen_range(4) as usize];
                }
                let bytes = 10.0 + rng.f64() * 5000.0;
                let cap = if rng.chance(0.3) { 5.0 + rng.f64() * 150.0 } else { f64::INFINITY };
                for (net, eng) in nets.iter().zip(engs.iter_mut()) {
                    FlowNet::start(net, eng, t.path(src, dst), bytes, cap, |_| {});
                }
            }
            2 => {
                let node = t.racks[rng.gen_range(2) as usize].nodes[rng.gen_range(4) as usize];
                let l = if rng.chance(0.5) { t.node(node).nic_tx } else { t.node(node).nic_rx };
                let cap = 20.0 + rng.f64() * 480.0;
                for (net, eng) in nets.iter().zip(engs.iter_mut()) {
                    FlowNet::set_capacity(net, eng, l, cap);
                }
            }
            _ => {
                *now += 0.1 + rng.f64() * 4.0;
                for eng in engs.iter_mut() {
                    eng.run_until(*now);
                }
            }
        }
    }

    #[test]
    fn incremental_rates_match_oracle_after_every_event() {
        // Satellite: after every start/finish/retune on a randomized
        // sequence, the incrementally maintained rates equal a
        // from-scratch global water-filling pass within epsilon.
        crate::proptest::check("incremental vs from-scratch oracle", 25, |rng| {
            let t = two_site_topo();
            let net = FlowNet::new(&t);
            let mut engs = [Engine::new()];
            let mut now = 0.0;
            for _ in 0..40 {
                random_event(rng, &t, &[&net], &mut engs, &mut now);
                let n = net.borrow();
                let aggs: Vec<(Vec<usize>, u32, f64)> = n
                    .active
                    .iter()
                    .map(|&s| {
                        let a = n.agg(s);
                        (a.path.iter().map(|l| l.0).collect(), a.weight, a.cap)
                    })
                    .collect();
                let want = oracle_rates(&n.capacity, &aggs);
                for (i, &s) in n.active.iter().enumerate() {
                    let got = n.agg(s).member_rate;
                    if (got - want[i]).abs() > 1e-6 * want[i].abs().max(1.0) {
                        return Err(format!(
                            "slot {s}: incremental {got} vs oracle {}",
                            want[i]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn incremental_and_full_modes_stay_bitwise_identical() {
        // The claim the flow_scale bench's report-equality assertion
        // rests on: both reallocation modes hold bitwise-equal state
        // after every event — rates, served bytes, deadlines, ledgers.
        crate::proptest::check("incremental == full, bitwise", 15, |rng| {
            let t = two_site_topo();
            let inc = FlowNet::new_with(&t, FlowNetConfig { aggregate: true, incremental: true });
            let full = FlowNet::new_with(&t, FlowNetConfig { aggregate: true, incremental: false });
            let mut engs = [Engine::new(), Engine::new()];
            let mut now = 0.0;
            for step in 0..40 {
                random_event(rng, &t, &[&inc, &full], &mut engs, &mut now);
                let a = inc.borrow();
                let b = full.borrow();
                if a.completions != b.completions || a.active_members != b.active_members {
                    return Err(format!("step {step}: population diverged"));
                }
                if a.active != b.active {
                    return Err(format!("step {step}: active sets diverged"));
                }
                for &s in &a.active {
                    let (x, y) = (a.agg(s), b.agg(s));
                    if x.member_rate.to_bits() != y.member_rate.to_bits()
                        || x.base.to_bits() != y.base.to_bits()
                        || x.deadline.to_bits() != y.deadline.to_bits()
                        || x.weight != y.weight
                    {
                        return Err(format!("step {step}: aggregate {s} diverged"));
                    }
                }
                for l in 0..a.link_rate.len() {
                    if a.link_rate[l].to_bits() != b.link_rate[l].to_bits() {
                        return Err(format!("step {step}: link {l} ledger diverged"));
                    }
                }
            }
            Ok(())
        });
    }

    /// Each completion spawns a successor until `left` drains — arrival/
    /// departure churn with slab slot recycling on every hop.
    fn spawn_chain(
        net: &Rc<RefCell<FlowNet>>,
        eng: &mut Engine,
        paths: &Rc<Vec<Vec<LinkId>>>,
        k: usize,
        left: &Rc<Cell<usize>>,
        bytes: f64,
    ) {
        if left.get() == 0 {
            return;
        }
        left.set(left.get() - 1);
        let net2 = net.clone();
        let paths2 = paths.clone();
        let left2 = left.clone();
        let path = paths[k % paths.len()].clone();
        FlowNet::start(net, eng, path, bytes, f64::INFINITY, move |e| {
            spawn_chain(&net2, e, &paths2, k + 1, &left2, bytes);
        });
    }

    #[test]
    fn engine_heap_stays_small_under_flow_churn() {
        // Sharded completion timers keep the event heap O(armed lanes):
        // one live completion event per domain regardless of how many
        // reallocations churn produces.
        crate::proptest::check("flow churn keeps heap O(active)", 10, |rng| {
            let t = two_site_topo();
            let net = FlowNet::new(&t);
            let mut eng = Engine::new();
            let mut paths = Vec::new();
            for r in 0..2usize {
                for i in 0..4usize {
                    let src = t.racks[r].nodes[i];
                    let dst = t.racks[1 - r].nodes[(i + 1) % 4];
                    paths.push(t.path(src, dst));
                }
            }
            let paths = Rc::new(paths);
            let chains = 2 + rng.gen_range(6) as usize;
            let total = 40 + rng.gen_range(80) as usize;
            let left = Rc::new(Cell::new(total));
            let bytes = 50.0 + rng.f64() * 500.0;
            for c in 0..chains {
                spawn_chain(&net, &mut eng, &paths, c, &left, bytes);
            }
            let active0 = net.borrow().active();
            if eng.pending() > active0 + 2 {
                return Err(format!("{} events for {active0} flows", eng.pending()));
            }
            while eng.step() {
                let active = net.borrow().active();
                if eng.pending() > active + 2 {
                    return Err(format!("{} live events for {active} active flows", eng.pending()));
                }
                if eng.heap_len() > 2 * eng.pending() + 66 {
                    return Err(format!(
                        "heap {} for {} live events",
                        eng.heap_len(),
                        eng.pending()
                    ));
                }
            }
            // Every spawn consumes one unit of budget, so exactly `total`
            // flows ever start — and each must complete exactly once.
            if net.borrow().completions() != total as u64 {
                return Err(format!(
                    "{} completions for {} flows",
                    net.borrow().completions(),
                    total
                ));
            }
            Ok(())
        });
    }
}

//! Fluid flow network with max-min fair sharing and per-flow rate caps.
//!
//! Every bulk transfer in the simulated testbed — HDFS pipeline writes,
//! MapReduce shuffle fetches, Sphere segment reads and bucket writes, and
//! disk I/O (a disk is a link) — is a *flow* over a path of capacity links.
//! Active flows share each link max-min fairly (progressive water-filling),
//! and each flow additionally carries a transport cap: the maximum rate its
//! protocol can sustain on its path (TCP's `MSS/(RTT·√p)` ceiling on high
//! bandwidth-delay-product paths, UDT's near-capacity rate — see
//! [`crate::transport`]). The cap is what makes the wide-area penalty of
//! Table 2 emerge from mechanism rather than from a hard-coded constant.
//!
//! Completions are scheduled on the event engine; any change to the flow
//! set reallocates rates and reschedules (a generation counter invalidates
//! stale completion events).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::sim::Engine;

use super::topology::{LinkId, Topology};

/// Identifies an active flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FlowId(u64);

type Callback = Box<dyn FnOnce(&mut Engine)>;

struct FlowState {
    path: Vec<LinkId>,
    remaining: f64,
    rate: f64,
    cap: f64,
    done: Option<Callback>,
}

/// The fluid network. Use through an `Rc<RefCell<_>>` handle.
pub struct FlowNet {
    capacity: Vec<f64>,
    /// Current aggregate rate per link (for utilization sampling).
    link_rate: Vec<f64>,
    /// Cumulative bytes carried per link (monitor counters).
    link_bytes: Vec<f64>,
    flows: HashMap<u64, FlowState>,
    next_id: u64,
    last_advance: f64,
    generation: u64,
    completions: u64,
}

impl FlowNet {
    pub fn new(topo: &Topology) -> Rc<RefCell<FlowNet>> {
        let capacity: Vec<f64> = topo.links.iter().map(|l| l.capacity).collect();
        let n = capacity.len();
        Rc::new(RefCell::new(FlowNet {
            capacity,
            link_rate: vec![0.0; n],
            link_bytes: vec![0.0; n],
            flows: HashMap::new(),
            next_id: 0,
            last_advance: 0.0,
            generation: 0,
            completions: 0,
        }))
    }

    /// Total completed flows (sanity/metrics).
    pub fn completions(&self) -> u64 {
        self.completions
    }

    /// Number of currently active flows.
    pub fn active(&self) -> usize {
        self.flows.len()
    }

    /// Current utilization of a link in [0, 1].
    pub fn link_utilization(&self, l: LinkId) -> f64 {
        if self.capacity[l.0] <= 0.0 {
            0.0
        } else {
            (self.link_rate[l.0] / self.capacity[l.0]).min(1.0)
        }
    }

    /// Current aggregate rate on a link, bytes/s.
    pub fn link_rate(&self, l: LinkId) -> f64 {
        self.link_rate[l.0]
    }

    /// Cumulative bytes carried by a link since the last call (monitor
    /// sampling). `now` must be the current engine time.
    pub fn take_link_bytes(&mut self, l: LinkId, now: f64) -> f64 {
        self.advance(now);
        std::mem::take(&mut self.link_bytes[l.0])
    }

    /// Peek cumulative bytes without resetting.
    pub fn link_bytes(&self, l: LinkId) -> f64 {
        self.link_bytes[l.0]
    }

    /// Current rate of a flow (0 if finished).
    pub fn flow_rate(&self, id: FlowId) -> f64 {
        self.flows.get(&id.0).map(|f| f.rate).unwrap_or(0.0)
    }

    // ---- internal fluid mechanics ------------------------------------

    /// Progress all flows to `now`, accruing per-link byte counters.
    fn advance(&mut self, now: f64) {
        let dt = now - self.last_advance;
        if dt <= 0.0 {
            return;
        }
        for f in self.flows.values_mut() {
            if f.rate > 0.0 {
                f.remaining = (f.remaining - f.rate * dt).max(0.0);
            }
        }
        for (l, rate) in self.link_rate.iter().enumerate() {
            if *rate > 0.0 {
                self.link_bytes[l] += rate * dt;
            }
        }
        self.last_advance = now;
    }

    /// Max-min fair allocation via progressive water-filling, honoring
    /// per-flow caps. O(iterations × (flows + links)); iterations ≤
    /// #distinct bottlenecks.
    fn reallocate(&mut self) {
        for r in self.link_rate.iter_mut() {
            *r = 0.0;
        }
        if self.flows.is_empty() {
            return;
        }
        let mut remaining_cap = self.capacity.clone();
        // (flow id, frozen?) — deterministic iteration order for replays.
        let mut ids: Vec<u64> = self.flows.keys().copied().collect();
        ids.sort_unstable();
        let mut rate: HashMap<u64, f64> = ids.iter().map(|&i| (i, 0.0)).collect();
        let mut frozen: HashMap<u64, bool> = ids.iter().map(|&i| (i, false)).collect();
        let mut users: Vec<u32> = vec![0; self.capacity.len()];

        // Relative epsilons: with capacities ~1e8 B/s, one ulp of water-
        // filling residue (~1e-8) must count as "saturated", or the loop
        // spins shaving dust off the same link without freezing anything.
        let link_eps = |cap: f64| cap * 1e-9 + 1e-9;
        let max_iters = ids.len() + self.capacity.len() + 8;
        let mut iters = 0usize;
        loop {
            iters += 1;
            // Count unfrozen users per link.
            for u in users.iter_mut() {
                *u = 0;
            }
            let mut any = false;
            for &id in &ids {
                if !frozen[&id] {
                    any = true;
                    for &LinkId(l) in &self.flows[&id].path {
                        users[l] += 1;
                    }
                }
            }
            if !any {
                break;
            }
            // Smallest feasible uniform increment across unfrozen flows.
            let mut inc = f64::INFINITY;
            for (l, &u) in users.iter().enumerate() {
                if u > 0 {
                    inc = inc.min(remaining_cap[l].max(0.0) / u as f64);
                }
            }
            for &id in &ids {
                if !frozen[&id] {
                    inc = inc.min(self.flows[&id].cap - rate[&id]);
                }
            }
            if !inc.is_finite() {
                break; // all paths uncapacitated? cannot happen with real links
            }
            let inc = inc.max(0.0);
            // Apply the increment and freeze whatever bottomed out.
            for &id in &ids {
                if frozen[&id] {
                    continue;
                }
                *rate.get_mut(&id).unwrap() += inc;
                for &LinkId(l) in &self.flows[&id].path {
                    remaining_cap[l] -= inc;
                }
            }
            let mut froze_any = false;
            for &id in &ids {
                if frozen[&id] {
                    continue;
                }
                let f = &self.flows[&id];
                let cap_eps = if f.cap.is_finite() { f.cap * 1e-9 + 1e-9 } else { 0.0 };
                let hit_cap = f.cap.is_finite() && rate[&id] >= f.cap - cap_eps;
                let hit_link = f
                    .path
                    .iter()
                    .any(|&LinkId(l)| remaining_cap[l] <= link_eps(self.capacity[l]));
                if hit_cap || hit_link {
                    *frozen.get_mut(&id).unwrap() = true;
                    froze_any = true;
                }
            }
            if !froze_any || iters >= max_iters {
                // Each productive iteration must freeze something; if
                // nothing froze (fp dust) or we exhausted the bound,
                // freeze everything at current rates — feasible by
                // construction, off by at most one epsilon of fairness.
                for &id in &ids {
                    *frozen.get_mut(&id).unwrap() = true;
                }
                break;
            }
        }

        for (&id, r) in &rate {
            let f = self.flows.get_mut(&id).unwrap();
            f.rate = *r;
            for &LinkId(l) in &f.path {
                self.link_rate[l] += *r;
            }
        }
    }

    fn next_completion(&self) -> Option<f64> {
        let mut best: Option<f64> = None;
        for f in self.flows.values() {
            if f.rate > 0.0 {
                let t = f.remaining / f.rate;
                best = Some(match best {
                    Some(b) => b.min(t),
                    None => t,
                });
            }
        }
        best
    }

    // ---- public operations (handle-based: callbacks need the net) -----

    /// Start a transfer of `bytes` along `path` with transport cap
    /// `cap_bps` (bytes/s; `f64::INFINITY` for uncapped). `done` fires on
    /// the engine when the last byte arrives. Zero-byte flows complete
    /// immediately.
    pub fn start<F: FnOnce(&mut Engine) + 'static>(
        net: &Rc<RefCell<FlowNet>>,
        eng: &mut Engine,
        path: Vec<LinkId>,
        bytes: f64,
        cap_bps: f64,
        done: F,
    ) -> FlowId {
        assert!(bytes >= 0.0 && cap_bps > 0.0);
        if bytes == 0.0 {
            eng.schedule_in(0.0, done);
            return FlowId(u64::MAX);
        }
        assert!(!path.is_empty(), "flow with empty path");
        let id = {
            let mut n = net.borrow_mut();
            n.advance(eng.now());
            let id = n.next_id;
            n.next_id += 1;
            n.flows.insert(
                id,
                FlowState { path, remaining: bytes, rate: 0.0, cap: cap_bps, done: Some(Box::new(done)) },
            );
            n.reallocate();
            FlowId(id)
        };
        Self::reschedule(net, eng);
        id
    }

    /// Change a link's capacity at runtime (network provisioning §2.1) and
    /// reallocate.
    pub fn set_capacity(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine, l: LinkId, capacity: f64) {
        assert!(capacity > 0.0);
        {
            let mut n = net.borrow_mut();
            n.advance(eng.now());
            n.capacity[l.0] = capacity;
            n.reallocate();
        }
        Self::reschedule(net, eng);
    }

    fn reschedule(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine) {
        let (gen, dt) = {
            let mut n = net.borrow_mut();
            n.generation += 1;
            (n.generation, n.next_completion())
        };
        let Some(dt) = dt else { return };
        let net = net.clone();
        eng.schedule_in(dt.max(0.0), move |eng| {
            if net.borrow().generation != gen {
                return; // superseded by a later reallocation
            }
            Self::on_completion(&net, eng);
        });
    }

    fn on_completion(net: &Rc<RefCell<FlowNet>>, eng: &mut Engine) {
        let callbacks = {
            let mut n = net.borrow_mut();
            n.advance(eng.now());
            // A flow is done when within an epsilon that is relative to
            // its rate (1 ns of transfer) — pure absolute epsilons leave
            // residues whose completion dt falls below the clock's ulp
            // and the event loop stops advancing time.
            let mut finished: Vec<u64> = n
                .flows
                .iter()
                .filter(|(_, f)| f.remaining <= 1e-6 + f.rate * 1e-9)
                .map(|(&id, _)| id)
                .collect();
            if finished.is_empty() {
                // This event fired because a completion was due; force
                // progress by completing the nearest flow (fp dust).
                if let Some((&id, _)) = n
                    .flows
                    .iter()
                    .filter(|(_, f)| f.rate > 0.0)
                    .min_by(|a, b| {
                        let ta = a.1.remaining / a.1.rate;
                        let tb = b.1.remaining / b.1.rate;
                        ta.partial_cmp(&tb).unwrap()
                    })
                {
                    finished.push(id);
                }
            }
            let mut cbs = Vec::new();
            let mut ids = finished;
            ids.sort_unstable(); // deterministic callback order
            for id in ids {
                let mut f = n.flows.remove(&id).unwrap();
                n.completions += 1;
                if let Some(cb) = f.done.take() {
                    cbs.push(cb);
                }
            }
            n.reallocate();
            cbs
        };
        // Run callbacks without holding the borrow; they may start flows.
        for cb in callbacks {
            cb(eng);
        }
        Self::reschedule(net, eng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topology::{NodeSpec, Topology};
    use std::cell::RefCell;
    use std::rc::Rc;

    fn two_site_topo() -> Topology {
        let mut t = Topology::new();
        let a = t.add_site("a");
        let b = t.add_site("b");
        let spec = NodeSpec { nic_bps: 100.0, disk_bps: 50.0, cpu_slots: 4 };
        t.add_rack(a, 4, &spec, 1000.0);
        t.add_rack(b, 4, &spec, 1000.0);
        t.connect_sites(a, b, 200.0, 0.01);
        t
    }

    #[test]
    fn single_flow_runs_at_bottleneck() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done_at = Rc::new(RefCell::new(0.0));
        let d = done_at.clone();
        // NIC (100 B/s) is the bottleneck: 1000 B takes 10 s.
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, move |e| {
            *d.borrow_mut() = e.now();
        });
        eng.run();
        assert!((*done_at.borrow() - 10.0).abs() < 1e-6);
        assert_eq!(net.borrow().completions(), 1);
    }

    #[test]
    fn two_flows_share_fairly() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let times = Rc::new(RefCell::new(Vec::new()));
        // Both flows leave node0: share its 100 B/s NIC → 50 B/s each.
        for dst in [1, 2] {
            let times = times.clone();
            let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[dst]);
            FlowNet::start(&net, &mut eng, path, 500.0, f64::INFINITY, move |e| {
                times.borrow_mut().push(e.now());
            });
        }
        eng.run();
        let ts = times.borrow();
        assert!((ts[0] - 10.0).abs() < 1e-6 && (ts[1] - 10.0).abs() < 1e-6, "{ts:?}");
    }

    #[test]
    fn departure_releases_bandwidth() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done = Rc::new(RefCell::new(Vec::new()));
        // Flow 1: 250 B, flow 2: 750 B, same NIC. Phase 1: both at 50 B/s
        // until t=5 (flow1 done). Phase 2: flow2 at 100 B/s for its
        // remaining 500 B → done at t=10.
        for bytes in [250.0, 750.0] {
            let done = done.clone();
            let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
            FlowNet::start(&net, &mut eng, path, bytes, f64::INFINITY, move |e| {
                done.borrow_mut().push(e.now());
            });
        }
        eng.run();
        let d = done.borrow();
        assert!((d[0] - 5.0).abs() < 1e-6, "{d:?}");
        assert!((d[1] - 10.0).abs() < 1e-6, "{d:?}");
    }

    #[test]
    fn transport_cap_limits_rate() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done_at = Rc::new(RefCell::new(0.0));
        let d = done_at.clone();
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        // Cap 20 B/s though the path allows 100 → 1000 B takes 50 s.
        FlowNet::start(&net, &mut eng, path, 1000.0, 20.0, move |e| {
            *d.borrow_mut() = e.now();
        });
        eng.run();
        assert!((*done_at.borrow() - 50.0).abs() < 1e-6);
    }

    #[test]
    fn capped_flow_leaves_bandwidth_for_others() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done = Rc::new(RefCell::new(Vec::new()));
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        // Capped flow takes 20 B/s; uncapped flow gets the remaining 80.
        for (bytes, cap) in [(200.0, 20.0), (800.0, f64::INFINITY)] {
            let done = done.clone();
            FlowNet::start(&net, &mut eng, path.clone(), bytes, cap, move |e| {
                done.borrow_mut().push(e.now());
            });
        }
        eng.run();
        let d = done.borrow();
        assert!((d[0] - 10.0).abs() < 1e-6 && (d[1] - 10.0).abs() < 1e-6, "{d:?}");
    }

    #[test]
    fn wan_link_contention() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done = Rc::new(RefCell::new(Vec::new()));
        // Three cross-site flows from distinct sources share the 200 B/s
        // WAN link: ~66.7 B/s each (NICs are 100, not binding).
        for src in 0..3 {
            let done = done.clone();
            let path = t.path(t.racks[0].nodes[src], t.racks[1].nodes[src]);
            FlowNet::start(&net, &mut eng, path, 200.0, f64::INFINITY, move |e| {
                done.borrow_mut().push(e.now());
            });
        }
        eng.run();
        for &d in done.borrow().iter() {
            assert!((d - 3.0).abs() < 1e-6, "{d}");
        }
    }

    #[test]
    fn zero_byte_flow_completes_immediately() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let hit = Rc::new(RefCell::new(false));
        let h = hit.clone();
        let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1]);
        FlowNet::start(&net, &mut eng, path, 0.0, f64::INFINITY, move |_| *h.borrow_mut() = true);
        eng.run();
        assert!(*hit.borrow());
    }

    #[test]
    fn capacity_change_reallocates() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done_at = Rc::new(RefCell::new(0.0));
        let d = done_at.clone();
        let n0 = t.racks[0].nodes[0];
        let n1 = t.racks[0].nodes[1];
        let path = t.path(n0, n1);
        let tx = t.node(n0).nic_tx;
        let rx = t.node(n1).nic_rx;
        FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, move |e| {
            *d.borrow_mut() = e.now();
        });
        // At t=5 (500 B left), upgrade both NICs to 500 B/s → 1 more second.
        let net2 = net.clone();
        eng.schedule_at(5.0, move |e| {
            FlowNet::set_capacity(&net2, e, tx, 500.0);
            FlowNet::set_capacity(&net2, e, rx, 500.0);
        });
        eng.run();
        assert!((*done_at.borrow() - 6.0).abs() < 1e-6, "{}", done_at.borrow());
    }

    #[test]
    fn link_byte_counters_accumulate() {
        let t = two_site_topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let n0 = t.racks[0].nodes[0];
        let path = t.path(n0, t.racks[0].nodes[1]);
        FlowNet::start(&net, &mut eng, path, 1000.0, f64::INFINITY, |_| {});
        eng.run();
        let now = eng.now();
        let bytes = net.borrow_mut().take_link_bytes(t.node(n0).nic_tx, now);
        assert!((bytes - 1000.0).abs() < 1e-6);
        // Counter resets after take.
        let again = net.borrow_mut().take_link_bytes(t.node(n0).nic_tx, now);
        assert_eq!(again, 0.0);
    }

    #[test]
    fn allocation_invariants_property() {
        crate::proptest::check("maxmin: feasible, capped, nonzero", 40, |rng| {
            let t = two_site_topo();
            let net = FlowNet::new(&t);
            let mut eng = Engine::new();
            let nflows = 1 + rng.gen_range(12) as usize;
            for _ in 0..nflows {
                let src = t.racks[rng.gen_range(2) as usize].nodes[rng.gen_range(4) as usize];
                let mut dst = src;
                while dst == src {
                    dst = t.racks[rng.gen_range(2) as usize].nodes[rng.gen_range(4) as usize];
                }
                let cap = if rng.chance(0.5) { 5.0 + rng.f64() * 200.0 } else { f64::INFINITY };
                FlowNet::start(&net, &mut eng, t.path(src, dst), 1e7, cap, |_| {});
            }
            let n = net.borrow();
            // (1) per-link feasibility
            for (l, &rate) in n.link_rate.iter().enumerate() {
                if rate > n.capacity[l] + 1e-6 {
                    return Err(format!("link {l} over capacity: {rate} > {}", n.capacity[l]));
                }
            }
            for f in n.flows.values() {
                // (2) cap respected
                if f.rate > f.cap + 1e-6 {
                    return Err(format!("flow over cap: {} > {}", f.rate, f.cap));
                }
                // (3) no starvation
                if f.rate <= 0.0 {
                    return Err("starved flow".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn work_conservation_property() {
        // With a single bottleneck and no caps, the bottleneck is saturated.
        crate::proptest::check("maxmin work conserving", 30, |rng| {
            let t = two_site_topo();
            let net = FlowNet::new(&t);
            let mut eng = Engine::new();
            let k = 2 + rng.gen_range(3) as usize;
            for i in 0..k {
                // All flows out of node0 → its NIC is the shared bottleneck.
                let path = t.path(t.racks[0].nodes[0], t.racks[0].nodes[1 + (i % 3)]);
                FlowNet::start(&net, &mut eng, path, 1e6, f64::INFINITY, |_| {});
            }
            let n = net.borrow();
            let nic = t.node(t.racks[0].nodes[0]).nic_tx;
            let rate = n.link_rate(nic);
            if (rate - 100.0).abs() < 1e-6 {
                Ok(())
            } else {
                Err(format!("bottleneck not saturated: {rate}"))
            }
        });
    }
}

//! Testbed topology: sites, racks, nodes, links, RTT matrix.
//!
//! `Topology::oct_2009()` reconstructs Figure 2 of the paper: four racks of
//! 32 nodes at JHU (Baltimore), StarLight (Chicago), UIC (Chicago), and
//! Calit2/UCSD (San Diego), each node with a dual-core×2 CPU, 1 TB SATA
//! disk and 1GE NIC, racks uplinked at 10 Gb/s into a dedicated lightpath
//! mesh. All capacities are **bytes/second**; times are seconds.

use std::collections::BTreeMap;

/// Index newtypes — cheap, `Copy`, and keep call sites honest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SiteId(pub usize);
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RackId(pub usize);
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LinkId(pub usize);

/// What a capacity link models (for monitoring labels and heatmaps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    NicTx,
    NicRx,
    RackUp,
    RackDown,
    Wan,
    Disk,
}

/// The flow-domain partition of the testbed: every link belongs to
/// exactly one domain — its site for intra-site plumbing (NICs, rack
/// uplinks, disks), or the shared wide-area domain for wave links. The
/// fluid network shards its completion timers and capacity batches along
/// this boundary: per-site traffic never wakes another site's lane, and
/// only WAN-crossing flows ride the shared lane. (Rate *coupling* still
/// follows the link-sharing graph, which may span domains — domains
/// shard event plumbing, the water-filling components guarantee
/// correctness.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Domain {
    /// All links physically inside one site.
    Site(u32),
    /// The wide-area waves shared between sites.
    Wan,
}

impl Domain {
    /// Dense lane index for per-domain arrays: sites first, WAN last.
    pub fn lane(self, num_sites: usize) -> usize {
        match self {
            Domain::Site(s) => s as usize,
            Domain::Wan => num_sites,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Link {
    pub kind: LinkKind,
    /// Capacity in bytes/second.
    pub capacity: f64,
    pub label: String,
    /// Which flow domain this link belongs to (fixed at construction).
    pub domain: Domain,
}

/// A domain-aware path: the link sequence plus the flow domain the
/// resulting flow's completion timer lives in — its site when the path
/// stays inside one site, [`Domain::Wan`] when it crosses a wave.
/// Produced by [`Topology::route`] / [`Topology::disk_route`], or derived
/// from a raw link path with [`Topology::route_over`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Route {
    pub path: Vec<LinkId>,
    pub domain: Domain,
}

#[derive(Debug, Clone)]
pub struct Site {
    pub name: String,
    pub racks: Vec<RackId>,
}

#[derive(Debug, Clone)]
pub struct Rack {
    pub site: SiteId,
    pub nodes: Vec<NodeId>,
    pub uplink_tx: LinkId,
    pub uplink_rx: LinkId,
}

#[derive(Debug, Clone)]
pub struct Node {
    pub rack: RackId,
    pub site: SiteId,
    pub name: String,
    pub nic_tx: LinkId,
    pub nic_rx: LinkId,
    pub disk: LinkId,
    /// CPU slots (Hadoop task slots / Sphere SPE threads).
    pub cpu_slots: usize,
}

/// Hardware constants for building racks (2009-plausible; DESIGN.md §4).
#[derive(Debug, Clone)]
pub struct NodeSpec {
    /// NIC bytes/s each direction (1GE ≈ 940 Mb/s goodput).
    pub nic_bps: f64,
    /// Disk sequential bytes/s (single 1 TB SATA).
    pub disk_bps: f64,
    pub cpu_slots: usize,
}

impl Default for NodeSpec {
    fn default() -> Self {
        NodeSpec { nic_bps: 117.5e6, disk_bps: 65.0e6, cpu_slots: 4 }
    }
}

#[derive(Debug, Clone, Default)]
pub struct Topology {
    pub sites: Vec<Site>,
    pub racks: Vec<Rack>,
    pub nodes: Vec<Node>,
    pub links: Vec<Link>,
    /// Directed WAN link per ordered site pair.
    wan: BTreeMap<(SiteId, SiteId), LinkId>,
    /// One-way latency between sites, seconds (symmetric).
    site_owd: BTreeMap<(SiteId, SiteId), f64>,
}

impl Topology {
    pub fn new() -> Self {
        Topology::default()
    }

    /// The four-site, 128-node testbed of Figure 2 with CiscoWave RTTs.
    ///
    /// The CiscoWave is **one shared 10 Gb/s wave** spanning the US —
    /// "a 10Gb/s network that connects the various data centers" — not a
    /// dedicated lambda per site pair. All inter-site traffic contends
    /// for the same duplex backbone; per-pair RTTs follow fiber routes.
    pub fn oct_2009() -> Self {
        let mut t = Topology::new();
        let spec = NodeSpec::default();
        let jhu = t.add_site("JHU-Baltimore");
        let sl = t.add_site("StarLight-Chicago");
        let uic = t.add_site("UIC-Chicago");
        let ucsd = t.add_site("Calit2-UCSD");
        for site in [jhu, sl, uic, ucsd] {
            t.add_rack(site, 32, &spec, 1.25e9);
        }
        let rtts = [
            (jhu, sl, 0.022),
            (jhu, uic, 0.022),
            (jhu, ucsd, 0.075),
            (sl, uic, 0.001),
            (sl, ucsd, 0.058),
            (uic, ucsd, 0.058),
        ];
        t.connect_shared_wave(&[jhu, sl, uic, ucsd], 1.25e9, &rtts);
        t
    }

    /// Join `sites` with a single shared duplex wave of `bps` per
    /// direction (east/west lambdas). Every ordered site pair maps onto
    /// one of the two directed backbone links.
    pub fn connect_shared_wave(
        &mut self,
        sites: &[SiteId],
        bps: f64,
        rtts: &[(SiteId, SiteId, f64)],
    ) {
        let (east, west) = self.add_wave(bps, "wave");
        self.route_over_wave(sites, east, west);
        for &(a, b, rtt) in rtts {
            self.site_owd.insert((a, b), rtt / 2.0);
            self.site_owd.insert((b, a), rtt / 2.0);
        }
    }

    /// Add a duplex wave — a directed `east`/`west` link pair of `bps`
    /// per direction — without routing any site pair over it. Dynamic
    /// lightpath provisioning creates capacity this way: the lambda
    /// exists in the fiber plant from construction (the fluid network's
    /// link set is fixed), and a later [`Topology::route_over_wave`] on a
    /// tenant's topology *view* directs that tenant's inter-site traffic
    /// onto it. Returns `(east, west)`.
    pub fn add_wave(&mut self, bps: f64, label: &str) -> (LinkId, LinkId) {
        let east = self.add_link(LinkKind::Wan, bps, format!("wan.{label}.east"), Domain::Wan);
        let west = self.add_link(LinkKind::Wan, bps, format!("wan.{label}.west"), Domain::Wan);
        (east, west)
    }

    /// Route every ordered pair among `sites` over the directed wave pair
    /// `(east, west)`: lower→higher site index rides east, the reverse
    /// rides west. Replaces any previous routing for those pairs; RTTs
    /// are a fiber-route property and are left untouched. Combined with
    /// [`Topology::add_wave`] this lets each tenant slice of one shared
    /// testbed see the same nodes and racks but its own wide-area wave.
    pub fn route_over_wave(&mut self, sites: &[SiteId], east: LinkId, west: LinkId) {
        for (i, &a) in sites.iter().enumerate() {
            for &b in &sites[i + 1..] {
                self.wan.insert((a, b), east);
                self.wan.insert((b, a), west);
            }
        }
    }

    pub fn add_site(&mut self, name: &str) -> SiteId {
        let id = SiteId(self.sites.len());
        self.sites.push(Site { name: name.to_string(), racks: Vec::new() });
        id
    }

    fn add_link(&mut self, kind: LinkKind, capacity: f64, label: String, domain: Domain) -> LinkId {
        assert!(capacity > 0.0, "link capacity must be positive: {label}");
        let id = LinkId(self.links.len());
        self.links.push(Link { kind, capacity, label, domain });
        id
    }

    /// Add a rack of `n` identical nodes with a 2×`uplink_bps` switch uplink.
    pub fn add_rack(&mut self, site: SiteId, n: usize, spec: &NodeSpec, uplink_bps: f64) -> RackId {
        let rid = RackId(self.racks.len());
        let dom = Domain::Site(site.0 as u32);
        let up = self.add_link(LinkKind::RackUp, uplink_bps, format!("rack{}.up", rid.0), dom);
        let down =
            self.add_link(LinkKind::RackDown, uplink_bps, format!("rack{}.down", rid.0), dom);
        self.racks.push(Rack { site, nodes: Vec::new(), uplink_tx: up, uplink_rx: down });
        self.sites[site.0].racks.push(rid);
        for _ in 0..n {
            self.add_node(rid, spec);
        }
        rid
    }

    pub fn add_node(&mut self, rack: RackId, spec: &NodeSpec) -> NodeId {
        let nid = NodeId(self.nodes.len());
        let site = self.racks[rack.0].site;
        let dom = Domain::Site(site.0 as u32);
        let tx = self.add_link(LinkKind::NicTx, spec.nic_bps, format!("node{}.tx", nid.0), dom);
        let rx = self.add_link(LinkKind::NicRx, spec.nic_bps, format!("node{}.rx", nid.0), dom);
        let disk =
            self.add_link(LinkKind::Disk, spec.disk_bps, format!("node{}.disk", nid.0), dom);
        self.nodes.push(Node {
            rack,
            site,
            name: format!("node{:03}", nid.0),
            nic_tx: tx,
            nic_rx: rx,
            disk,
            cpu_slots: spec.cpu_slots,
        });
        self.racks[rack.0].nodes.push(nid);
        nid
    }

    /// Create (or replace) the directed WAN links between two sites.
    pub fn connect_sites(&mut self, a: SiteId, b: SiteId, bps: f64, rtt: f64) {
        assert_ne!(a, b);
        for (x, y) in [(a, b), (b, a)] {
            let lid = self.add_link(
                LinkKind::Wan,
                bps,
                format!("wan.{}->{}", self.sites[x.0].name, self.sites[y.0].name),
                Domain::Wan,
            );
            self.wan.insert((x, y), lid);
        }
        self.site_owd.insert((a, b), rtt / 2.0);
        self.site_owd.insert((b, a), rtt / 2.0);
    }

    pub fn wan_link(&self, from: SiteId, to: SiteId) -> Option<LinkId> {
        self.wan.get(&(from, to)).copied()
    }

    /// All node ids, in creation order.
    pub fn node_ids(&self) -> Vec<NodeId> {
        (0..self.nodes.len()).map(NodeId).collect()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0]
    }

    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0]
    }

    pub fn set_link_capacity(&mut self, id: LinkId, capacity: f64) {
        assert!(capacity > 0.0);
        self.links[id.0].capacity = capacity;
    }

    pub fn same_rack(&self, a: NodeId, b: NodeId) -> bool {
        self.nodes[a.0].rack == self.nodes[b.0].rack
    }

    pub fn same_site(&self, a: NodeId, b: NodeId) -> bool {
        self.nodes[a.0].site == self.nodes[b.0].site
    }

    /// Network path (sequence of capacity links) from `a` to `b`.
    /// Intra-rack: NICs only (the ToR switch is non-blocking). Intra-site:
    /// NICs + both rack uplinks. Inter-site: + the WAN link.
    pub fn path(&self, a: NodeId, b: NodeId) -> Vec<LinkId> {
        assert_ne!(a, b, "no self-path");
        let na = &self.nodes[a.0];
        let nb = &self.nodes[b.0];
        let mut p = vec![na.nic_tx];
        if na.rack != nb.rack {
            p.push(self.racks[na.rack.0].uplink_tx);
            if na.site != nb.site {
                p.push(
                    self.wan_link(na.site, nb.site)
                        .unwrap_or_else(|| panic!("no WAN link {:?}->{:?}", na.site, nb.site)),
                );
            }
            p.push(self.racks[nb.rack.0].uplink_rx);
        }
        p.push(nb.nic_rx);
        p
    }

    /// The flow domain of one link.
    pub fn link_domain(&self, l: LinkId) -> Domain {
        self.links[l.0].domain
    }

    /// Number of flow-domain lanes: one per site plus the WAN lane.
    pub fn num_domains(&self) -> usize {
        self.sites.len() + 1
    }

    /// Minimum one-way delay between any two sites, seconds — the
    /// physical floor under every cross-site interaction, and therefore
    /// the lookahead available to the conservative parallel engine
    /// ([`crate::sim::par`]): no event in one site's domain can influence
    /// another site's domain sooner than this. `None` for a single-site
    /// topology (no WAN coupling at all).
    pub fn min_wan_owd(&self) -> Option<f64> {
        self.site_owd.values().copied().fold(None, |m, d| Some(m.map_or(d, |m: f64| m.min(d))))
    }

    /// Domain-aware path from `a` to `b`: [`Topology::path`] plus the
    /// domain the flow's completion timer lives in (the shared site, or
    /// [`Domain::Wan`] for inter-site traffic).
    pub fn route(&self, a: NodeId, b: NodeId) -> Route {
        let domain = if self.same_site(a, b) {
            Domain::Site(self.nodes[a.0].site.0 as u32)
        } else {
            Domain::Wan
        };
        Route { path: self.path(a, b), domain }
    }

    /// The single-link route over a node's disk spindle (disk I/O is a
    /// flow too); always lives in the node's site domain.
    pub fn disk_route(&self, n: NodeId) -> Route {
        let nd = &self.nodes[n.0];
        Route { path: vec![nd.disk], domain: Domain::Site(nd.site.0 as u32) }
    }

    /// Wrap a raw link path into a [`Route`], deriving the domain from
    /// the links: any WAN-domain link puts the flow on the shared lane,
    /// otherwise it lives on its (single) site's lane.
    pub fn route_over(&self, path: Vec<LinkId>) -> Route {
        let mut domain = self.link_domain(path[0]);
        for &l in &path[1..] {
            if self.link_domain(l) != domain {
                domain = Domain::Wan;
                break;
            }
        }
        Route { path, domain }
    }

    /// Round-trip time between two nodes, seconds.
    pub fn rtt(&self, a: NodeId, b: NodeId) -> f64 {
        if a == b {
            return 30e-6;
        }
        let (na, nb) = (&self.nodes[a.0], &self.nodes[b.0]);
        if na.rack == nb.rack {
            100e-6 // ToR switch hop
        } else if na.site == nb.site {
            300e-6
        } else {
            2.0 * self.site_owd.get(&(na.site, nb.site)).copied().unwrap_or(0.025) + 300e-6
        }
    }

    /// Topological distance used by placement policies (0 = same node).
    pub fn distance(&self, a: NodeId, b: NodeId) -> u32 {
        if a == b {
            0
        } else if self.same_rack(a, b) {
            1
        } else if self.same_site(a, b) {
            2
        } else {
            3
        }
    }

    /// Total node count.
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// A compact multi-line description (the `oct topology` CLI output).
    pub fn describe(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "Topology: {} sites, {} racks, {} nodes, {} links",
            self.sites.len(),
            self.racks.len(),
            self.nodes.len(),
            self.links.len()
        );
        for (i, site) in self.sites.iter().enumerate() {
            let nodes: usize = site.racks.iter().map(|r| self.racks[r.0].nodes.len()).sum();
            let _ = writeln!(
                s,
                "  site {} {:<20} {} rack(s), {} nodes",
                i,
                site.name,
                site.racks.len(),
                nodes
            );
        }
        for ((a, b), lid) in &self.wan {
            if a.0 < b.0 {
                let rtt = 2.0 * self.site_owd[&(*a, *b)];
                let _ = writeln!(
                    s,
                    "  wan  {} <-> {}  {:.1} Gb/s  rtt {:.1} ms",
                    self.sites[a.0].name,
                    self.sites[b.0].name,
                    self.links[lid.0].capacity * 8.0 / 1e9,
                    rtt * 1e3
                );
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oct_2009_matches_figure2() {
        let t = Topology::oct_2009();
        assert_eq!(t.sites.len(), 4);
        assert_eq!(t.racks.len(), 4);
        assert_eq!(t.num_nodes(), 128);
        // Every ordered site pair has a WAN link.
        for a in 0..4 {
            for b in 0..4 {
                if a != b {
                    assert!(t.wan_link(SiteId(a), SiteId(b)).is_some());
                }
            }
        }
        // Chicago pair is ~1 ms RTT, coast-to-coast is the longest.
        let sl0 = t.racks[1].nodes[0];
        let uic0 = t.racks[2].nodes[0];
        let jhu0 = t.racks[0].nodes[0];
        let ucsd0 = t.racks[3].nodes[0];
        assert!(t.rtt(sl0, uic0) < 0.002);
        assert!(t.rtt(jhu0, ucsd0) > 0.07);
    }

    #[test]
    fn min_wan_owd_is_the_chicago_pair() {
        // StarLight–UIC at 1 ms RTT is the closest pair: 0.5 ms one-way.
        // This is the parallel engine's lookahead floor, so pin it.
        let t = Topology::oct_2009();
        assert_eq!(t.min_wan_owd(), Some(0.0005));
        // A single-site topology has no WAN coupling at all.
        let mut solo = Topology::new();
        let s = solo.add_site("only");
        solo.add_rack(s, 4, &NodeSpec::default(), 1.25e9);
        assert_eq!(solo.min_wan_owd(), None);
    }

    #[test]
    fn paths_have_expected_links() {
        let t = Topology::oct_2009();
        let a = t.racks[0].nodes[0];
        let b = t.racks[0].nodes[1];
        let c = t.racks[1].nodes[0];
        assert_eq!(t.path(a, b).len(), 2); // intra-rack: two NICs
        let p = t.path(a, c); // inter-site: nic, up, wan, down, nic
        assert_eq!(p.len(), 5);
        assert_eq!(t.link(p[2]).kind, LinkKind::Wan);
    }

    #[test]
    fn distance_hierarchy() {
        let t = Topology::oct_2009();
        let a = t.racks[0].nodes[0];
        let b = t.racks[0].nodes[5];
        let c = t.racks[1].nodes[0];
        assert_eq!(t.distance(a, a), 0);
        assert_eq!(t.distance(a, b), 1);
        assert_eq!(t.distance(a, c), 3);
    }

    #[test]
    fn multi_rack_site_distance_two() {
        let mut t = Topology::new();
        let s = t.add_site("x");
        let spec = NodeSpec::default();
        let r1 = t.add_rack(s, 2, &spec, 1.25e9);
        let r2 = t.add_rack(s, 2, &spec, 1.25e9);
        let a = t.racks[r1.0].nodes[0];
        let b = t.racks[r2.0].nodes[0];
        assert_eq!(t.distance(a, b), 2);
        assert_eq!(t.path(a, b).len(), 4); // no WAN hop
    }

    #[test]
    fn provisioning_grows_topology() {
        let mut t = Topology::oct_2009();
        let spec = NodeSpec::default();
        // §2.2: two more racks (MIT-LL, PSC) toward ~250 nodes.
        let mit = t.add_site("MIT-LL");
        t.add_rack(mit, 30, &spec, 1.25e9);
        for s in 0..4 {
            t.connect_sites(SiteId(s), mit, 1.25e9, 0.030);
        }
        assert_eq!(t.num_nodes(), 158);
        let a = t.racks[0].nodes[0];
        let m = t.racks[4].nodes[0];
        assert_eq!(t.path(a, m).len(), 5);
    }

    #[test]
    fn tenant_view_routes_over_its_own_wave() {
        let mut master = Topology::oct_2009();
        let shared = master.wan_link(SiteId(0), SiteId(3)).unwrap();
        let (east, west) = master.add_wave(1.25e9, "tenant-a");
        assert_eq!(master.link(east).kind, LinkKind::Wan);
        assert!(master.link(west).label.contains("tenant-a"));
        // Adding the wave routes nothing: the master still uses the
        // shared CiscoWave for every pair.
        assert_eq!(master.wan_link(SiteId(0), SiteId(3)), Some(shared));
        // A tenant view of the same physical testbed re-routes onto the
        // dedicated wave; the master is untouched.
        let mut view = master.clone();
        let sites: Vec<SiteId> = (0..view.sites.len()).map(SiteId).collect();
        view.route_over_wave(&sites, east, west);
        assert_eq!(view.wan_link(SiteId(0), SiteId(3)), Some(east));
        assert_eq!(view.wan_link(SiteId(3), SiteId(0)), Some(west));
        assert_eq!(master.wan_link(SiteId(0), SiteId(3)), Some(shared));
        // Paths computed through the view cross the tenant wave; RTTs
        // are unchanged (same fiber route).
        let a = view.racks[0].nodes[0];
        let b = view.racks[3].nodes[0];
        let p = view.path(a, b);
        assert!(p.contains(&east), "{p:?}");
        assert_eq!(view.rtt(a, b), master.rtt(a, b));
    }

    #[test]
    fn links_partition_into_domains() {
        let t = Topology::oct_2009();
        for (i, link) in t.links.iter().enumerate() {
            match link.kind {
                LinkKind::Wan => assert_eq!(link.domain, Domain::Wan, "{}", link.label),
                _ => {
                    let Domain::Site(s) = link.domain else {
                        panic!("{} not in a site domain", link.label);
                    };
                    assert!((s as usize) < t.sites.len(), "link {i} in bogus site {s}");
                }
            }
        }
        // Lane indexing: sites first, WAN last.
        assert_eq!(Domain::Site(2).lane(4), 2);
        assert_eq!(Domain::Wan.lane(4), 4);
        assert_eq!(t.num_domains(), 5);
    }

    #[test]
    fn routes_carry_their_domain() {
        let t = Topology::oct_2009();
        let a = t.racks[0].nodes[0];
        let b = t.racks[0].nodes[1];
        let c = t.racks[1].nodes[0];
        let local = t.route(a, b);
        assert_eq!(local.domain, Domain::Site(0));
        assert_eq!(local.path, t.path(a, b));
        let wide = t.route(a, c);
        assert_eq!(wide.domain, Domain::Wan);
        assert_eq!(wide.path, t.path(a, c));
        // Disk routes live on the node's site lane.
        assert_eq!(t.disk_route(c).domain, Domain::Site(1));
        assert_eq!(t.disk_route(c).path, vec![t.node(c).disk]);
        // Deriving from a raw path agrees with the node-pair route.
        assert_eq!(t.route_over(t.path(a, b)), local);
        assert_eq!(t.route_over(t.path(a, c)), wide);
    }

    #[test]
    fn describe_mentions_sites() {
        let d = Topology::oct_2009().describe();
        assert!(d.contains("StarLight"));
        assert!(d.contains("128 nodes"));
    }
}

//! A provisioned cluster: topology + fluid network + per-node CPU pools,
//! the bundle every distributed engine runs against.

use std::cell::RefCell;
use std::rc::Rc;

use crate::sim::resources::CpuPool;

use super::flows::{FlowNet, FlowNetConfig};
use super::topology::{NodeId, Topology};

/// Shared simulation substrate handles.
#[derive(Clone)]
pub struct Cluster {
    pub topo: Rc<Topology>,
    pub net: Rc<RefCell<FlowNet>>,
    pub pools: Vec<Rc<RefCell<CpuPool>>>,
}

impl Cluster {
    pub fn new(topo: Topology) -> Cluster {
        Cluster::with_config(topo, FlowNetConfig::default())
    }

    /// A cluster whose fluid network runs under a non-default
    /// [`FlowNetConfig`] — the flow-scale bench uses this to run the same
    /// scenario with incremental reallocation on and off and compare the
    /// reports byte for byte.
    pub fn with_config(topo: Topology, cfg: FlowNetConfig) -> Cluster {
        let topo = Rc::new(topo);
        let net = FlowNet::new_with(&topo, cfg);
        let pools = topo.nodes.iter().map(|n| CpuPool::new(n.cpu_slots)).collect();
        Cluster { topo, net, pools }
    }

    pub fn pool(&self, n: NodeId) -> &Rc<RefCell<CpuPool>> {
        &self.pools[n.0]
    }

    /// Degrade a node's CPU speed (straggler injection).
    pub fn set_node_speed(&self, n: NodeId, speed: f64) {
        self.pools[n.0].borrow_mut().set_speed(speed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_pools_per_node() {
        let c = Cluster::new(Topology::oct_2009());
        assert_eq!(c.pools.len(), 128);
        assert_eq!(c.pool(NodeId(0)).borrow().slots(), 4);
    }
}

//! The simulated OCT network fabric.
//!
//! [`topology`] describes the physical testbed — sites, racks, nodes, NICs,
//! rack uplinks, the 10 Gb/s CiscoWave WAN mesh, and per-node disks (a disk
//! is just another capacity link; see DESIGN.md §2). [`flows`] is a
//! fluid-flow network on top of the event engine: active transfers share
//! link capacity max-min fairly, subject to per-flow transport caps (a TCP
//! flow on a high-RTT path cannot use its fair share — that asymmetry is
//! the mechanism behind Table 2's wide-area penalties).

pub mod cluster;
pub mod flows;
pub mod topology;

pub use cluster::Cluster;
pub use flows::{FlowId, FlowNet, FlowNetConfig};
pub use topology::{Domain, LinkId, NodeId, RackId, Route, SiteId, Topology};

//! `oct` — the Open Cloud Testbed CLI (leader entrypoint).
//!
//! Subcommands map one-to-one onto the paper's artifacts:
//!
//! ```text
//! oct topology              # Figure 2: the 4-site testbed description
//! oct table1 [scale]        # Table 1: MalStone-A/B × three frameworks
//! oct table2 [scale]        # Table 2: local vs distributed penalty
//! oct monitor [secs]        # Figure 3: live ANSI heatmap of a run
//! oct provision             # §2.2: growth-plan provisioning demo
//! oct kernel-check          # load AOT artifacts, verify vs oracle
//! oct version
//! ```

use oct::coordinator::experiment::{format_table1, format_table2, run_table1, run_table2};
use oct::coordinator::Provisioner;
use oct::net::Topology;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "topology" => print!("{}", Topology::oct_2009().describe()),
        "table1" => {
            let scale = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(100);
            println!("Table 1 at scale 1/{scale} (10B records ÷ {scale}; shape-preserving)");
            print!("{}", format_table1(&run_table1(scale)));
        }
        "table2" => {
            let scale = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(100);
            println!("Table 2 at scale 1/{scale} (15B records ÷ {scale}; shape-preserving)");
            print!("{}", format_table2(&run_table2(scale)));
        }
        "monitor" => {
            let secs: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(30.0);
            oct_monitor_demo(secs);
        }
        "provision" => {
            let mut p = Provisioner::oct_2009();
            println!("before expansion:\n{}", p.topology().describe());
            p.expand_2009_plan();
            println!("after §2.2 expansion plan:\n{}", p.topology().describe());
            println!("provisioning log: {} ops", p.log().len());
        }
        "kernel-check" => match oct::runtime::MalstoneKernels::load(&oct::runtime::default_artifact_dir()) {
            Ok(k) => {
                println!("PJRT platform: {}", k.platform());
                println!(
                    "artifacts ok: hist batch {} → planes {}×{}",
                    k.meta.batch, k.meta.num_sites, k.meta.num_weeks
                );
            }
            Err(e) => {
                eprintln!("artifact load failed: {e:#}");
                std::process::exit(1);
            }
        },
        "version" => println!("oct {}", oct::version()),
        _ => {
            eprintln!(
                "usage: oct <topology|table1 [scale]|table2 [scale]|monitor [secs]|provision|kernel-check|version>"
            );
            std::process::exit(2);
        }
    }
}

/// A compressed Figure-3 demo: run a Sphere scan on the 2009 testbed and
/// print heatmap frames as simulated time advances.
fn oct_monitor_demo(secs: f64) {
    use oct::hadoop::FrameworkParams;
    use oct::monitor::heatmap::Metric;
    use oct::monitor::{render_heatmap, Monitor};
    use oct::net::Cluster;
    use oct::sector::master::{SectorMaster, Segment};
    use oct::sector::SphereEngine;
    use oct::sim::Engine;

    let cluster = Cluster::new(Topology::oct_2009());
    let mut master = SectorMaster::new(cluster.topo.clone());
    let nodes: Vec<_> = cluster.topo.node_ids();
    let seg_records: u64 = 671_088; // 64 MB of 100-byte records
    let segs: Vec<Segment> = nodes
        .iter()
        .flat_map(|&n| {
            (0..2).map(move |_| Segment { node: n, bytes: seg_records * 100, records: seg_records })
        })
        .collect();
    master.register_file("demo", segs);
    let mut eng = Engine::new();
    let mon = Monitor::new(cluster.topo.clone(), 1.0);
    Monitor::install(&mon, &mut eng, &cluster.net, cluster.pools.clone());
    let done = std::rc::Rc::new(std::cell::RefCell::new(false));
    let d = done.clone();
    SphereEngine::simulate(
        &cluster,
        &master,
        &mut eng,
        "demo",
        &nodes,
        FrameworkParams::sphere(),
        false,
        move |_, r| {
            println!("sphere run finished: {:.1}s simulated", r.makespan);
            *d.borrow_mut() = true;
        },
    );
    let mut t = 0.0;
    while !*done.borrow() && t < secs {
        t += 5.0;
        eng.run_until(t);
        println!("— t = {t:.0}s —");
        print!("{}", render_heatmap(&mon.borrow(), Metric::Network, true));
    }
    mon.borrow_mut().disable();
    eng.run();
    let m = mon.borrow();
    println!("WAN link throughput (latest):");
    for (label, bps) in m.wan_throughput() {
        println!("  {label:<30} {}", oct::util::units::fmt_rate(bps * 8.0));
    }
}

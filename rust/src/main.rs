//! `oct` — the Open Cloud Testbed CLI (leader entrypoint).
//!
//! Subcommands map onto the paper's artifacts and the scenario registry:
//!
//! ```text
//! oct topology                        # Figure 2: the 4-site testbed description
//! oct table1 [scale]                  # Table 1 set through the ScenarioRunner
//! oct table2 [scale]                  # Table 2 set through the ScenarioRunner
//! oct scenarios                       # list the registered scenario sets
//! oct scenarios <set> [scale] [--json]  # run one set; --json emits RunReport lines
//! oct alerts <set> [scale]            # run one set; print the ops alert log as JSON lines
//! oct trace <set> [scale] [--out f]   # run one set traced; emit Chrome Trace Format JSON
//! oct monitor [secs]                  # Figure 3: live ANSI heatmap of a run
//! oct provision                       # §2.2: growth-plan provisioning demo
//! oct slices                          # tenant-slice admission demo (SliceScheduler)
//! oct kernel-check                    # load AOT artifacts, verify vs oracle
//! oct help [command]                  # usage, or one command's details (exit 0)
//! oct version
//! ```
//!
//! `oct help`, `oct --help`, and `oct <command> --help` print usage and
//! exit 0; unknown subcommands print usage to stderr and exit non-zero,
//! and unknown scenario sets list the registered set names.

use oct::coordinator::{
    find_set, format_checks, format_reports, scenario_sets, set_names, ScenarioRunner,
    SliceScheduler, DEFAULT_SPARE_WAVE_GBPS,
};
use oct::coordinator::Provisioner;
use oct::net::Topology;
use oct::trace::TraceSpec;

const USAGE: &str = "usage: oct <command>  (oct help <command> for details)
  topology                         Figure 2: the 4-site testbed description
  table1 [scale]                   Table 1 scenario set (default scale 1/100)
  table2 [scale]                   Table 2 scenario set (default scale 1/100)
  scenarios                        list registered scenario sets
  scenarios <set> [scale] [--json] run one set through the ScenarioRunner
  alerts <set> [scale]             run one set; print the ops alert log as JSON lines
  trace <set> [scale] [--out FILE] run one set traced; emit Chrome Trace Format JSON
  --threads N                      worker threads for shardable scenarios (any
                                   scenario-running command; byte-identical output)
  --trace FILE                     record sim-time spans during any scenario-running
                                   command and write the Chrome trace to FILE
  monitor [secs]                   Figure 3: live ANSI heatmap of a run
  provision                        §2.2 growth-plan provisioning demo
  slices                           tenant-slice admission demo (carve/queue/release)
  kernel-check                     load AOT artifacts, verify geometry
  help [command]                   this summary, or one command's usage
  version                          print the crate version";

/// Per-subcommand usage details (`oct help <command>`).
fn detailed_usage(cmd: &str) -> Option<&'static str> {
    Some(match cmd {
        "topology" => "usage: oct topology\n\
             Print the Figure-2 testbed: 4 sites x 32 nodes, rack uplinks, and the\n\
             shared 10 Gb/s CiscoWave with per-pair RTTs.",
        "table1" => "usage: oct table1 [scale]\n\
             Run the Table 1 set (MalStone-A/B x three frameworks, 10B records) at\n\
             1/scale of the paper workload (default 100) and evaluate its shape\n\
             checks. Exit 0 = all checks pass, 1 = a check failed.",
        "table2" => "usage: oct table2 [scale]\n\
             Run the Table 2 set (local vs distributed wide-area penalty,\n\
             15B records) at 1/scale (default 100) with its shape checks.",
        "scenarios" => "usage: oct scenarios [<set> [scale]] [--json] [--threads N]\n\
             Without arguments: list the registered scenario sets.\n\
             With a set name: run it at 1/scale (default 100, must be >= 1)\n\
             through the ScenarioRunner (tenancy groups run concurrently on one\n\
             testbed), print a report table and the set's shape-check verdicts.\n\
             --json emits one RunReport JSON line per scenario plus one line per\n\
             check. Exit 0 = all checks pass, 1 = a check failed, 2 = unknown set.\n\
             --threads N (or OCT_THREADS=N) runs shardable scenarios on the\n\
             parallel engine with N worker threads; reports are byte-identical\n\
             to --threads 1. Accepted by every scenario-running command.",
        "trace" => "usage: oct trace <set> [scale] [--out FILE] [--threads N]\n\
             Run one registry set at 1/scale (default 100) with sim-time tracing\n\
             enabled and emit the merged span stream as Chrome Trace Format JSON\n\
             (one pid per site/WAN/control domain, one tid per lane) — load it at\n\
             ui.perfetto.dev or chrome://tracing. Without --out the JSON goes to\n\
             stdout and the summary line to stderr. The merged stream is\n\
             byte-identical at any --threads / OCT_THREADS value. Exit 0 = ran,\n\
             2 = unknown set.",
        "alerts" => "usage: oct alerts <set> [scale]\n\
             Run one set and print every ops-enabled scenario's alert log as JSON\n\
             lines plus a per-scenario summary line (ready for jq).",
        "monitor" => "usage: oct monitor [secs]\n\
             Figure 3: run a Sphere scan over the full testbed and render the\n\
             monitoring heatmap as ANSI frames for `secs` simulated seconds\n\
             (default 30).",
        "provision" => "usage: oct provision\n\
             Apply the paper's §2.2 growth plan (MIT-LL and PSC racks, 10 Gb/s\n\
             interconnects) to the 2009 testbed and print the before/after\n\
             topology plus the replayable op log length.",
        "slices" => "usage: oct slices\n\
             Walk the tenant-slice admission demo: carve two 20-node slices with\n\
             dedicated 10 Gb/s lightpath grants, show a third request queueing\n\
             against exhausted spare spectrum, release a slice, and admit the\n\
             queued tenant. Prints the inventory at each step and the replayable\n\
             carve/release op log.",
        "kernel-check" => "usage: oct kernel-check\n\
             Load the AOT-compiled JAX/Pallas artifacts (pjrt feature) and verify\n\
             their geometry against the build metadata.",
        "version" => "usage: oct version\n\
             Print the crate version.",
        "help" => "usage: oct help [command]\n\
             Print the command summary, or one command's detailed usage.",
        _ => return None,
    })
}

/// Parse an optional `[scale]` argument (default 100). Every workload is
/// divided by scale, so 0 would run degenerate scenarios (and divide by
/// zero): reject it loudly instead of unwrapping to the default.
fn parse_scale(arg: Option<&String>) -> u64 {
    match arg.map(|s| s.parse::<u64>()) {
        Some(Ok(0)) => {
            eprintln!("oct: scale must be >= 1 (workloads run at 1/scale; 0 is degenerate)");
            std::process::exit(2);
        }
        Some(Ok(n)) => n,
        _ => 100,
    }
}

/// Print help for `topic` (general usage when `None`). Returns the
/// process exit code: 0, or 2 for an unknown topic.
fn print_help(topic: Option<&str>) -> i32 {
    match topic {
        None => {
            println!("{USAGE}");
            0
        }
        Some(t) => match detailed_usage(t) {
            Some(d) => {
                println!("{d}");
                0
            }
            None => {
                eprintln!("oct: no such command '{t}'\n{USAGE}");
                2
            }
        },
    }
}

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    // `--threads N` is accepted anywhere on the line; the parallel engine
    // produces byte-identical reports at any thread count, so the flag
    // composes with every scenario-running command.
    let threads: Option<usize> = match args.iter().position(|a| a == "--threads") {
        Some(i) => {
            let n: usize = args
                .get(i + 1)
                .and_then(|s| s.parse().ok())
                .filter(|&n| n >= 1)
                .unwrap_or_else(|| {
                    eprintln!("oct: --threads needs a positive integer\n{USAGE}");
                    std::process::exit(2);
                });
            args.drain(i..=i + 1);
            Some(n)
        }
        None => None,
    };
    // `--trace FILE` composes the same way: any scenario-running command
    // records sim-time spans and writes the Chrome trace to FILE.
    let trace_out: Option<String> = match args.iter().position(|a| a == "--trace") {
        Some(i) => {
            let Some(f) = args.get(i + 1).cloned().filter(|f| !f.starts_with('-')) else {
                eprintln!("oct: --trace needs an output file\n{USAGE}");
                std::process::exit(2);
            };
            args.drain(i..=i + 1);
            Some(f)
        }
        None => None,
    };
    // `oct --help` and `oct <command> --help` both land here, exit 0.
    if args.iter().any(|a| a == "--help" || a == "-h") {
        let topic = args.iter().find(|a| *a != "--help" && *a != "-h");
        std::process::exit(print_help(topic.map(String::as_str)));
    }
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "topology" => print!("{}", Topology::oct_2009().describe()),
        "table1" | "table2" => {
            let scale = parse_scale(args.get(1));
            std::process::exit(run_set_cli(cmd, scale, false, threads, trace_out.as_deref()));
        }
        "scenarios" => {
            let json = args.iter().any(|a| a.as_str() == "--json");
            let rest: Vec<&String> =
                args[1..].iter().filter(|a| a.as_str() != "--json").collect();
            match rest.first() {
                None => list_scenario_sets(),
                Some(name) => {
                    let scale = parse_scale(rest.get(1).copied());
                    let trace = trace_out.as_deref();
                    std::process::exit(run_set_cli(name, scale, json, threads, trace));
                }
            }
        }
        "trace" => match args.get(1) {
            None => {
                eprintln!("oct: trace needs a scenario set; try `oct trace mega-churn`\n{USAGE}");
                std::process::exit(2);
            }
            Some(name) => {
                let name = name.clone();
                let out: Option<String> = match args.iter().position(|a| a == "--out") {
                    Some(i) => {
                        let Some(f) = args.get(i + 1).cloned().filter(|f| !f.starts_with('-'))
                        else {
                            eprintln!("oct: --out needs an output file\n{USAGE}");
                            std::process::exit(2);
                        };
                        args.drain(i..=i + 1);
                        Some(f)
                    }
                    None => trace_out.clone(),
                };
                let scale = parse_scale(args.get(2));
                std::process::exit(run_trace_cli(&name, scale, out.as_deref(), threads));
            }
        },
        "alerts" => match args.get(1) {
            None => {
                eprintln!("oct: alerts needs a scenario set; try `oct alerts ops`\n{USAGE}");
                std::process::exit(2);
            }
            Some(name) => {
                let scale = parse_scale(args.get(2));
                std::process::exit(run_alerts_cli(name, scale, threads));
            }
        },
        "monitor" => {
            let secs: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(30.0);
            oct_monitor_demo(secs);
        }
        "provision" => {
            let mut p = Provisioner::oct_2009();
            println!("before expansion:\n{}", p.topology().describe());
            p.expand_2009_plan();
            println!("after §2.2 expansion plan:\n{}", p.topology().describe());
            println!("provisioning log: {} ops", p.log().len());
        }
        "slices" => oct_slices_demo(),
        "kernel-check" => {
            match oct::runtime::MalstoneKernels::load(&oct::runtime::default_artifact_dir()) {
                Ok(k) => {
                    println!("PJRT platform: {}", k.platform());
                    println!(
                        "artifacts ok: hist batch {} → planes {}×{}",
                        k.meta.batch, k.meta.num_sites, k.meta.num_weeks
                    );
                }
                Err(e) => {
                    eprintln!("artifact load failed: {e}");
                    std::process::exit(1);
                }
            }
        }
        "version" => println!("oct {}", oct::version()),
        "help" => std::process::exit(print_help(args.get(1).map(String::as_str))),
        _ => {
            eprintln!("oct: unknown command '{cmd}'\n{USAGE}");
            std::process::exit(2);
        }
    }
}

/// The `oct slices` walkthrough: tenant-slice admission against finite
/// inventory — carve, deny, release, admit — with the replayable op log.
fn oct_slices_demo() {
    let topo = std::rc::Rc::new(Topology::oct_2009());
    let mut sched = SliceScheduler::new(topo, DEFAULT_SPARE_WAVE_GBPS);
    println!(
        "inventory: {} free nodes, {} Gb/s spare wave spectrum",
        sched.free_nodes(),
        sched.spare_gbps()
    );
    let alice = sched.try_carve("alice", 5, Some(10.0), None).expect("alice fits");
    let bob = sched.try_carve("bob", 5, Some(10.0), None).expect("bob fits");
    for s in [&alice, &bob] {
        println!(
            "carved '{}': {} nodes (5/site), {} Gb/s dedicated wave",
            s.tenant,
            s.nodes.len(),
            s.lightpath_gbps.unwrap()
        );
    }
    println!(
        "inventory: {} free nodes, {} Gb/s spare spectrum",
        sched.free_nodes(),
        sched.spare_gbps()
    );
    match sched.try_carve("eve", 5, Some(10.0), None) {
        Some(_) => println!("eve admitted (unexpected)"),
        None => println!("eve's 10 Gb/s request QUEUES: spare spectrum exhausted"),
    }
    sched.release(&alice);
    println!("alice released her slice");
    match sched.try_carve("eve", 5, Some(10.0), None) {
        Some(s) => println!("eve admitted after the release: {} nodes", s.nodes.len()),
        None => println!("eve still queued (unexpected)"),
    }
    println!("admission log ({} replayable ops):", sched.log().len());
    for op in sched.log() {
        println!("  {op:?}");
    }
    println!("run the full multi-tenant experiment: oct scenarios tenancy 100");
}

/// List the registry: one line per set.
fn list_scenario_sets() {
    println!("scenario sets (run with `oct scenarios <name> [scale] [--json]`):");
    for set in scenario_sets() {
        println!(
            "  {:<14} {} scenario(s){}  {}",
            set.name,
            set.scenarios.len(),
            if set.has_checks() { ", shape-checked" } else { "" },
            set.description
        );
    }
}

/// Run one registry set traced and emit the merged span stream as Chrome
/// Trace Format JSON (to `out`, or stdout when `None`). Exit code 0 on
/// success, 1 on a write failure, 2 on an unknown set.
fn run_trace_cli(name: &str, scale: u64, out: Option<&str>, threads: Option<usize>) -> i32 {
    let Some(set) = find_set(name) else {
        eprintln!(
            "oct: unknown scenario set '{name}'; registered sets: {}",
            set_names().join(", ")
        );
        return 2;
    };
    let set = set.scaled_down(scale);
    let mut runner = ScenarioRunner::new().with_trace(TraceSpec::new());
    if let Some(n) = threads {
        runner = runner.with_threads(n);
    }
    let (reports, stream) = runner.run_set_with_trace(&set);
    let js = stream.to_chrome_json();
    eprintln!(
        "{}: {} scenario(s), {} span event(s){} → {}",
        set.name,
        reports.len(),
        stream.len(),
        if stream.dropped > 0 {
            format!(" ({} dropped at the ring cap)", stream.dropped)
        } else {
            String::new()
        },
        out.unwrap_or("stdout")
    );
    match out {
        Some(path) => {
            if let Err(e) = std::fs::write(path, &js) {
                eprintln!("oct: writing {path}: {e}");
                return 1;
            }
        }
        None => println!("{js}"),
    }
    0
}

/// Run one registry set; returns the process exit code (0 = all checks
/// pass, 1 = a shape check failed, 2 = unknown set).
fn run_set_cli(
    name: &str,
    scale: u64,
    json: bool,
    threads: Option<usize>,
    trace_out: Option<&str>,
) -> i32 {
    let Some(set) = find_set(name) else {
        eprintln!(
            "oct: unknown scenario set '{name}'; registered sets: {}",
            set_names().join(", ")
        );
        return 2;
    };
    let set = set.scaled_down(scale);
    if !json {
        println!("{}: {} (scale 1/{scale}; shape-preserving)", set.name, set.description);
    }
    let mut runner = ScenarioRunner::new();
    if let Some(n) = threads {
        runner = runner.with_threads(n);
    }
    if trace_out.is_some() {
        runner = runner.with_trace(TraceSpec::new());
    }
    // `run_set` executes tenancy groups concurrently on one shared
    // testbed and returns reports in scenario order. Tracing never
    // changes a report byte, so the traced path reuses the same flow.
    let (reports, stream) = runner.run_set_with_trace(&set);
    if let Some(path) = trace_out {
        if let Err(e) = std::fs::write(path, stream.to_chrome_json()) {
            eprintln!("oct: writing {path}: {e}");
            return 1;
        }
        eprintln!("trace: {} span event(s) → {path}", stream.len());
    }
    if json {
        for r in &reports {
            println!("{}", r.to_json());
        }
    } else {
        print!("{}", format_reports(&reports));
    }
    let checks = set.run_checks(&reports);
    if json {
        // Shape checks ride along as JSON lines so scripted consumers
        // can tell which check produced a non-zero exit.
        use oct::util::json::{obj, Json};
        for c in &checks {
            let line = obj(vec![
                ("check", Json::Str(c.name.clone())),
                ("pass", Json::Bool(c.pass)),
                ("detail", Json::Str(c.detail.clone())),
            ]);
            println!("{line}");
        }
    } else {
        print!("{}", format_checks(&checks));
    }
    if checks.iter().any(|c| !c.pass) {
        1
    } else {
        0
    }
}

/// Run one registry set and print every scenario's ops alert log as JSON
/// lines (`{"scenario": ..., "t": ..., "kind": ..., "subject": ...,
/// "detail": ...}`), ready for `jq`. Scenarios without an ops plane emit
/// nothing. Exit code 0 on success, 2 on an unknown set.
fn run_alerts_cli(name: &str, scale: u64, threads: Option<usize>) -> i32 {
    use oct::util::json::{obj, Json};
    let Some(set) = find_set(name) else {
        eprintln!(
            "oct: unknown scenario set '{name}'; registered sets: {}",
            set_names().join(", ")
        );
        return 2;
    };
    let set = set.scaled_down(scale);
    let mut runner = ScenarioRunner::new();
    if let Some(n) = threads {
        runner = runner.with_threads(n);
    }
    for sc in &set.scenarios {
        let rep = runner.run(sc);
        let Some(ops) = rep.ops else { continue };
        for a in &ops.alerts {
            let mut line = a.to_json();
            if let Json::Obj(m) = &mut line {
                m.insert("scenario".to_string(), Json::Str(rep.scenario.clone()));
            }
            println!("{line}");
        }
        let summary = obj(vec![
            ("scenario", Json::Str(rep.scenario.clone())),
            ("kind", Json::Str("summary".to_string())),
            ("alerts", Json::Num(ops.alerts.len() as f64)),
            ("dead_declared", Json::Num(ops.dead_declared as f64)),
            ("false_dead", Json::Num(ops.false_dead as f64)),
            ("detection_latency_max", Json::Num(ops.detection_latency_max)),
            ("reexecuted_tasks", Json::Num(ops.reexecuted_tasks as f64)),
            ("telemetry_wan_bytes", Json::Num(ops.telemetry_wan_bytes)),
        ]);
        println!("{summary}");
    }
    0
}

/// A compressed Figure-3 demo: run a Sphere scan on the 2009 testbed and
/// print heatmap frames as simulated time advances.
fn oct_monitor_demo(secs: f64) {
    use oct::hadoop::FrameworkParams;
    use oct::monitor::heatmap::Metric;
    use oct::monitor::{render_heatmap, Monitor};
    use oct::net::Cluster;
    use oct::sector::master::{SectorMaster, Segment};
    use oct::sector::SphereEngine;
    use oct::sim::Engine;

    let cluster = Cluster::new(Topology::oct_2009());
    let mut master = SectorMaster::new(cluster.topo.clone());
    let nodes: Vec<_> = cluster.topo.node_ids();
    let seg_records: u64 = 671_088; // 64 MB of 100-byte records
    let segs: Vec<Segment> = nodes
        .iter()
        .flat_map(|&n| {
            (0..2).map(move |_| Segment { node: n, bytes: seg_records * 100, records: seg_records })
        })
        .collect();
    master.register_file("demo", segs);
    let mut eng = Engine::new();
    let mon = Monitor::new(cluster.topo.clone(), 1.0);
    Monitor::install(&mon, &mut eng, &cluster.net, cluster.pools.clone());
    let done = std::rc::Rc::new(std::cell::RefCell::new(false));
    let d = done.clone();
    SphereEngine::simulate(
        &cluster,
        &master,
        &mut eng,
        "demo",
        &nodes,
        FrameworkParams::sphere(),
        false,
        move |_, r| {
            println!("sphere run finished: {:.1}s simulated", r.makespan);
            *d.borrow_mut() = true;
        },
    );
    let mut t = 0.0;
    while !*done.borrow() && t < secs {
        t += 5.0;
        eng.run_until(t);
        println!("— t = {t:.0}s —");
        print!("{}", render_heatmap(&mon.borrow(), Metric::Network, true));
    }
    mon.borrow_mut().disable();
    eng.run();
    let m = mon.borrow();
    println!("WAN link throughput (latest):");
    for (label, bps) in m.wan_throughput() {
        println!("  {label:<30} {}", oct::util::units::fmt_rate(bps * 8.0));
    }
}

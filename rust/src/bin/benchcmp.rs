//! `benchcmp` — gate bench results against committed baselines.
//!
//! ```text
//! benchcmp <baseline.json> <current.json> [--tolerance PCT] [--machine-tolerance PCT]
//! ```
//!
//! Both files are `BENCH_*.json` documents written by the bench binaries
//! (`flow_churn`, `flow_scale`, `engine_parallel`). The comparison:
//!
//! - The `bench` names must match, or the tool errors (exit 2).
//! - Scale guard: if any workload-shape field present in both documents
//!   (`scale_div`, `transfers`, `concurrency`, `threads`) differs, the
//!   runs are not comparable — a note is printed and nothing gates
//!   (exit 0). CI runs benches at reduced scale; regression gating only
//!   engages against a baseline recorded at the same scale.
//! - Wall-clock metrics (`*_wall_secs`) may grow by at most the
//!   tolerance (default 20%); throughput and speedup metrics
//!   (`*_per_sec*`, `speedup_*`) may shrink by at most the tolerance.
//!   These are **machine-dependent**: `--machine-tolerance` (default:
//!   the regular tolerance) loosens just them, so CI can run on slower
//!   shared hardware without also loosening the deterministic gates.
//! - Self-profiler counters (`profile_*`) are engine-deterministic at a
//!   fixed scale, so they gate at the strict `--tolerance`: counter
//!   growth (more re-fills, more dirty links, more stalls) is the
//!   structural "why" behind a wall-time regression.
//!   `profile_lookahead_utilization` gates downward (higher is better);
//!   every other `profile_*` gates upward.
//! - A `null` on either side skips that metric: baseline `null` means
//!   "not yet recorded on a reference machine", current `null` means the
//!   bench skipped that leg. Gating starts once a maintainer commits a
//!   measured baseline.
//! - `reports_byte_identical` is absolute: `true` in the baseline and
//!   anything else now is a failure regardless of tolerance.
//!
//! Exit codes: 0 = within tolerance (or nothing comparable), 1 = a
//! regression beyond tolerance, 2 = usage / IO / parse error.

use oct::util::json::Json;

/// Fields that define the workload shape: if they differ, wall-clock
/// numbers are not comparable.
const SCALE_FIELDS: &[&str] = &["scale_div", "transfers", "concurrency", "threads"];

fn load(path: &str) -> Result<Json, String> {
    let src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    Json::parse(&src).map_err(|e| format!("{path}: {e}"))
}

/// The numeric fields of `doc` (nulls and non-numbers excluded).
fn numeric_fields(doc: &Json) -> Vec<(String, f64)> {
    match doc {
        Json::Obj(m) => m
            .iter()
            .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
            .collect(),
        _ => Vec::new(),
    }
}

/// `Some(true)` when smaller is better for this metric, `Some(false)`
/// when larger is, `None` when the field does not gate (counts, shape).
fn lower_is_better(key: &str) -> Option<bool> {
    if key.ends_with("wall_secs") {
        return Some(true);
    }
    if key.contains("per_sec") || key.starts_with("speedup") {
        return Some(false);
    }
    if key == "profile_lookahead_utilization" {
        return Some(false);
    }
    if key.starts_with("profile_") {
        return Some(true);
    }
    None
}

/// True for metrics whose value depends on the host (clock, throughput,
/// speedup) rather than on the engine's deterministic execution — these
/// gate against `--machine-tolerance`.
fn is_machine_dependent(key: &str) -> bool {
    key.ends_with("wall_secs") || key.contains("per_sec") || key.starts_with("speedup")
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut tolerance = 0.20f64;
    let mut machine_tolerance: Option<f64> = None;
    let mut files: Vec<&String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--tolerance" || args[i] == "--machine-tolerance" {
            let pct = args
                .get(i + 1)
                .and_then(|s| s.parse::<f64>().ok())
                .map(|p| p / 100.0)
                .unwrap_or_else(|| {
                    eprintln!("benchcmp: {} needs a percentage", args[i]);
                    std::process::exit(2);
                });
            if args[i] == "--tolerance" {
                tolerance = pct;
            } else {
                machine_tolerance = Some(pct);
            }
            i += 2;
        } else {
            files.push(&args[i]);
            i += 1;
        }
    }
    if files.len() != 2 {
        eprintln!(
            "usage: benchcmp <baseline.json> <current.json> \
             [--tolerance PCT] [--machine-tolerance PCT]"
        );
        std::process::exit(2);
    }
    let machine_tolerance = machine_tolerance.unwrap_or(tolerance);
    let (baseline_path, current_path) = (files[0].as_str(), files[1].as_str());
    let (baseline, current) = match (load(baseline_path), load(current_path)) {
        (Ok(b), Ok(c)) => (b, c),
        (b, c) => {
            for e in [b.err(), c.err()].into_iter().flatten() {
                eprintln!("benchcmp: {e}");
            }
            std::process::exit(2);
        }
    };
    let name = baseline.get("bench").and_then(Json::as_str).unwrap_or("?").to_string();
    if current.get("bench").and_then(Json::as_str) != Some(name.as_str()) {
        eprintln!("benchcmp: bench names differ: {baseline_path} vs {current_path}");
        std::process::exit(2);
    }

    for f in SCALE_FIELDS {
        let (b, c) = (baseline.get(f).and_then(Json::as_f64), current.get(f).and_then(Json::as_f64));
        if let (Some(b), Some(c)) = (b, c) {
            if b != c {
                println!(
                    "{name}: {f} differs (baseline {b}, current {c}) — runs not comparable, nothing gated"
                );
                std::process::exit(0);
            }
        }
    }

    let base_fields = numeric_fields(&baseline);
    let mut failed = false;
    let mut gated = 0usize;
    for (key, b) in &base_fields {
        let Some(lower) = lower_is_better(key) else { continue };
        let Some(c) = current.get(key).and_then(Json::as_f64) else {
            println!("{name}: {key} missing/null in current run — skipped");
            continue;
        };
        gated += 1;
        let tol = if is_machine_dependent(key) { machine_tolerance } else { tolerance };
        let (worse, limit) = if lower {
            (c > b * (1.0 + tol), b * (1.0 + tol))
        } else {
            (c < b * (1.0 - tol), b * (1.0 - tol))
        };
        if worse {
            eprintln!(
                "{name}: REGRESSION {key}: baseline {b:.4}, current {c:.4} (limit {limit:.4})"
            );
            failed = true;
        } else {
            println!("{name}: {key} ok: baseline {b:.4}, current {c:.4}");
        }
    }

    if baseline.get("reports_byte_identical") == Some(&Json::Bool(true))
        && current.get("reports_byte_identical") != Some(&Json::Bool(true))
    {
        eprintln!("{name}: REGRESSION reports_byte_identical: baseline true, current not");
        failed = true;
    }

    if gated == 0 {
        println!("{name}: no recorded baseline metrics yet — nothing gated");
    }
    if failed {
        std::process::exit(1);
    }
    println!(
        "{name}: within tolerance (counters {:.0}%, machine metrics {:.0}%)",
        tolerance * 100.0,
        machine_tolerance * 100.0
    );
}

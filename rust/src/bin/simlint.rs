//! `simlint` — the determinism lint pass for the simulation core.
//!
//! Scans every `.rs` file under the crate's `src/` (or an explicit root
//! passed on the command line) for the SIM00x rules documented in
//! [`oct::lint`]. Exit codes: 0 clean, 1 findings, 2 usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

use oct::lint::{report_json, scan_tree, RULES};

fn usage() {
    println!("usage: simlint [--json] [ROOT]");
    println!();
    println!("Determinism lint for the oct simulation core. Scans ROOT (default:");
    println!("the crate's src/ directory) for the rules below; waive a finding");
    println!("with `// simlint: allow(SIMxxx) — <reason>` on the same line or a");
    println!("comment-only line above. Unjustified waivers are SIM000 findings.");
    println!();
    for (id, desc) in RULES {
        println!("  {id}  {desc}");
    }
}

/// The scan root: an explicit CLI argument, else the crate sources. The
/// compile-time manifest dir is correct for `cargo run`; the bare `src`
/// fallbacks cover a relocated binary run from the repo or crate root.
fn resolve_root(cli: Option<PathBuf>) -> Option<PathBuf> {
    if let Some(p) = cli {
        return p.is_dir().then_some(p);
    }
    let candidates =
        [PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src"), "rust/src".into(), "src".into()];
    candidates.into_iter().find(|p| p.is_dir())
}

fn main() -> ExitCode {
    let mut json = false;
    let mut root_arg: Option<PathBuf> = None;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--help" | "-h" => {
                usage();
                return ExitCode::SUCCESS;
            }
            a if a.starts_with('-') => {
                eprintln!("simlint: unknown flag `{a}`");
                usage();
                return ExitCode::from(2);
            }
            a if root_arg.is_none() => root_arg = Some(PathBuf::from(a)),
            a => {
                eprintln!("simlint: unexpected extra argument `{a}`");
                return ExitCode::from(2);
            }
        }
    }

    let Some(root) = resolve_root(root_arg) else {
        eprintln!("simlint: no source root found (pass one explicitly: simlint <dir>)");
        return ExitCode::from(2);
    };

    let findings = match scan_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("simlint: scan of {} failed: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    if json {
        println!("{}", report_json(&findings));
    } else if findings.is_empty() {
        println!("simlint: clean ({})", root.display());
    } else {
        for f in &findings {
            println!("{f}");
        }
        println!("simlint: {} finding(s) in {}", findings.len(), root.display());
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

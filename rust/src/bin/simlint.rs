//! `simlint` — the determinism lint pass for the simulation core.
//!
//! Scans every `.rs` file under the crate's `src/`, `benches/`, and
//! `tests/` (or an explicit root passed on the command line) for the
//! SIM00x rules documented in [`oct::lint`]. Exit codes: 0 clean, 1
//! findings, 2 usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

use oct::lint::{report_json, scan_crate, scan_tree, Finding, RULES};

fn usage() {
    println!("usage: simlint [--json] [ROOT]");
    println!();
    println!("Determinism lint for the oct simulation core. Scans the crate's");
    println!("src/, benches/, and tests/ roots (or just ROOT when given) for the");
    println!("rules below; waive a finding with `// simlint: allow(SIMxxx) —");
    println!("<reason>` on the same line or a comment-only line above.");
    println!("Unjustified waivers are SIM000 findings.");
    println!();
    for (id, desc) in RULES {
        println!("  {id}  {desc}");
    }
}

/// Run the scan: an explicit CLI root scans that single tree; otherwise
/// the whole crate (src/benches/tests) is scanned. The compile-time
/// manifest dir is correct for `cargo run`; the bare fallbacks cover a
/// relocated binary run from the repo or crate root.
fn run_scan(cli: Option<PathBuf>) -> Option<(PathBuf, std::io::Result<Vec<Finding>>)> {
    if let Some(p) = cli {
        if !p.is_dir() {
            return None;
        }
        let f = scan_tree(&p);
        return Some((p, f));
    }
    let candidates: [PathBuf; 3] =
        [PathBuf::from(env!("CARGO_MANIFEST_DIR")), "rust".into(), ".".into()];
    let root = candidates.into_iter().find(|p| p.join("src").is_dir())?;
    let f = scan_crate(&root);
    Some((root, f))
}

fn main() -> ExitCode {
    let mut json = false;
    let mut root_arg: Option<PathBuf> = None;
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--help" | "-h" => {
                usage();
                return ExitCode::SUCCESS;
            }
            a if a.starts_with('-') => {
                eprintln!("simlint: unknown flag `{a}`");
                usage();
                return ExitCode::from(2);
            }
            a if root_arg.is_none() => root_arg = Some(PathBuf::from(a)),
            a => {
                eprintln!("simlint: unexpected extra argument `{a}`");
                return ExitCode::from(2);
            }
        }
    }

    let Some((root, scan)) = run_scan(root_arg) else {
        eprintln!("simlint: no source root found (pass one explicitly: simlint <dir>)");
        return ExitCode::from(2);
    };

    let findings = match scan {
        Ok(f) => f,
        Err(e) => {
            eprintln!("simlint: scan of {} failed: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    if json {
        println!("{}", report_json(&findings));
    } else if findings.is_empty() {
        println!("simlint: clean ({})", root.display());
    } else {
        for f in &findings {
            println!("{f}");
        }
        println!("simlint: {} finding(s) in {}", findings.len(), root.display());
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

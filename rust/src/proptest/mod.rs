//! Minimal in-tree property-based testing (the proptest crate is not
//! available in the offline build environment). Provides seeded case
//! generation with failure-seed reporting so a failing property can be
//! replayed deterministically:
//!
//! ```text
//! property failed: flow allocation exceeds capacity
//!   case 37 of 100, replay with OCT_PROP_SEED=0x1b4f...
//! ```
//!
//! Usage:
//! ```no_run
//! use oct::proptest::check;
//! check("addition commutes", 100, |rng| {
//!     let (a, b) = (rng.gen_range(1000) as i64, rng.gen_range(1000) as i64);
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use crate::util::Rng;

/// Run `cases` randomized cases of `prop`. Panics (test failure) on the
/// first `Err`, printing the case seed for replay. Honors `OCT_PROP_SEED`
/// to replay a single failing case.
pub fn check<F>(name: &str, cases: u32, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    if let Ok(seed_str) = std::env::var("OCT_PROP_SEED") {
        let seed = parse_seed(&seed_str);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on replay seed {seed:#x}: {msg}");
        }
        return;
    }
    // Derive per-case seeds from the property name so adding cases to one
    // property does not shift the streams of another.
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed: {msg}\n  case {case} of {cases}, replay with OCT_PROP_SEED={seed:#x}"
            );
        }
    }
}

fn parse_seed(s: &str) -> u64 {
    let s = s.trim();
    if let Some(hex) = s.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).expect("bad OCT_PROP_SEED")
    } else {
        s.parse().expect("bad OCT_PROP_SEED")
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivially true", 50, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn cases_are_deterministic_across_runs() {
        let mut first = Vec::new();
        check("dump", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check("dump", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}

//! Discrete-event simulation core.
//!
//! The testbed substitution (DESIGN.md §0) runs every distributed engine —
//! Hadoop MapReduce, Hadoop Streaming, Sphere — as processes inside a
//! deterministic discrete-event simulator. The engine is a classic
//! time-ordered event heap with closure events; substrate state is shared
//! through `Rc<RefCell<...>>` handles (single-threaded by design: replays
//! are bit-identical for a given seed).

mod engine;
pub mod resources;

pub use engine::{Countdown, Engine, TimerBank, TimerId};

//! Discrete-event simulation core.
//!
//! The testbed substitution (DESIGN.md §0) runs every distributed engine —
//! Hadoop MapReduce, Hadoop Streaming, Sphere — as processes inside a
//! deterministic discrete-event simulator. The engine is a classic
//! time-ordered event heap with closure events; substrate state is shared
//! through `Rc<RefCell<...>>` handles, so a single shard is strictly
//! single-threaded and replays are bit-identical for a given seed.
//!
//! [`par`] scales that out without giving the determinism up: shards
//! (one engine per flow domain) run under a conservative lookahead
//! protocol whose message ordering is encoded into the event keys
//! ([`Engine::schedule_msg`]), so any thread count reproduces the exact
//! sequential execution, byte for byte. It is the only module in the
//! crate permitted to spawn threads (simlint SIM006).

mod engine;
pub mod par;
pub mod resources;

pub use engine::{Countdown, Engine, SimTime, TimerBank, TimerId};

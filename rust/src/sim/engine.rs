//! The event heap: virtual clock, closure events, cancellable timers.
//!
//! Cancellation is O(1) and *eager about memory*: the heap stores only
//! `(time, seq)` markers while the callbacks live in a side table keyed by
//! seq. `cancel` drops the callback immediately (no closure lingers until
//! its scheduled time), a stale marker is purged when it reaches the top of
//! the heap, and cancelling an already-executed or unknown id is a true
//! no-op — nothing accumulates across a long run. When stale markers
//! outnumber live events the heap is compacted, so heap size stays O(live
//! events), not O(total cancellations).

use std::cell::{Cell, RefCell};
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::collections::HashMap;
use std::rc::Rc;

use crate::trace::{ProfileReport, Recorder, SchedProfile};

/// Simulated time in seconds.
pub type SimTime = f64;

/// Handle for cancelling a scheduled event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TimerId(u64);

type EventFn = Box<dyn FnOnce(&mut Engine)>;

/// Heap marker: ordering key only. The callback lives in `Engine::events`
/// so `cancel` can free it without touching the heap.
#[derive(Debug, Clone, Copy)]
struct Scheduled {
    time: SimTime,
    seq: u64,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    // BinaryHeap is a max-heap; invert so the earliest (time, seq) pops first.
    // Ties break by insertion order (seq), making execution deterministic.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Discrete-event engine.
pub struct Engine {
    now: SimTime,
    seq: u64,
    heap: BinaryHeap<Scheduled>,
    /// Live (scheduled, not yet executed, not cancelled) callbacks by seq.
    events: HashMap<u64, EventFn>,
    executed: u64,
    /// Self-profiler hot-path counters (always on; see [`crate::trace`]).
    timers_armed: u64,
    timers_cancelled: u64,
    msgs_scheduled: u64,
    /// Scheduler-lane profile, filled in by the [`crate::sim::par`] pump
    /// (zero for sequential engines; wall-derived, outside identity).
    sched: SchedProfile,
    /// Deterministic trace recorder, installed per run when a scenario
    /// asks for tracing. Boxed so the off-by-default case costs one
    /// pointer.
    recorder: Option<Box<Recorder>>,
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    pub fn new() -> Self {
        Engine {
            now: 0.0,
            seq: 0,
            heap: BinaryHeap::new(),
            events: HashMap::new(),
            executed: 0,
            timers_armed: 0,
            timers_cancelled: 0,
            msgs_scheduled: 0,
            sched: SchedProfile::default(),
            recorder: None,
        }
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events executed so far (used by the perf benches).
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Install a deterministic trace recorder on this engine.
    /// Instrumentation sites emit through [`Engine::recorder`]; a run
    /// without one records nothing and pays one branch per site.
    pub fn set_recorder(&mut self, rec: Recorder) {
        self.recorder = Some(Box::new(rec));
    }

    /// The installed trace recorder, if any. Emission through this
    /// accessor happens inside engine-event execution, which is what
    /// makes every recorded stream deterministic.
    pub fn recorder(&mut self) -> Option<&mut Recorder> {
        self.recorder.as_deref_mut()
    }

    /// Remove and return the recorder (the harvest step at run end).
    pub fn take_recorder(&mut self) -> Option<Recorder> {
        self.recorder.take().map(|b| *b)
    }

    /// The scheduler-lane profile slot, written by the parallel pump
    /// ([`crate::sim::par`]) at shard pump boundaries.
    pub fn sched_mut(&mut self) -> &mut SchedProfile {
        &mut self.sched
    }

    /// Snapshot this engine's self-profiler counters. The water-filling
    /// scope counters live on [`crate::net::FlowNet`] and are folded in
    /// by the runner; `sched` is `Some` only for engines driven by the
    /// parallel pump.
    pub fn profile(&self) -> ProfileReport {
        ProfileReport {
            events: self.executed,
            timers_armed: self.timers_armed,
            timers_cancelled: self.timers_cancelled,
            channel_messages: self.msgs_scheduled,
            refill_components: 0,
            dirty_links: 0,
            sched: if self.sched.rounds > 0 { Some(self.sched.clone()) } else { None },
        }
    }

    /// Schedule `f` at absolute time `t` (must be >= now).
    pub fn schedule_at<F: FnOnce(&mut Engine) + 'static>(&mut self, t: SimTime, f: F) -> TimerId {
        assert!(t >= self.now - 1e-9, "scheduling into the past: t={t} now={}", self.now);
        assert!(t.is_finite(), "non-finite event time");
        let seq = self.seq;
        self.seq += 1;
        self.timers_armed += 1;
        self.events.insert(seq, Box::new(f));
        self.heap.push(Scheduled { time: t.max(self.now), seq });
        // Invariant: every live callback has a heap marker (markers without
        // callbacks are stale-but-harmless; the reverse would lose events).
        debug_assert!(self.heap.len() >= self.events.len());
        TimerId(seq)
    }

    /// Schedule `f` after a delay of `dt` seconds.
    pub fn schedule_in<F: FnOnce(&mut Engine) + 'static>(&mut self, dt: SimTime, f: F) -> TimerId {
        assert!(dt >= 0.0, "negative delay {dt}");
        let now = self.now;
        self.schedule_at(now + dt, f)
    }

    /// Schedule a cross-shard message delivery at absolute time `at`.
    ///
    /// Message events carry an *encoded* sequence key instead of drawing
    /// from the local counter: bit 63 tags the event as a message, bits
    /// 48..63 carry the input-channel index, and the low 48 bits carry the
    /// channel's own delivery counter. Two consequences, both load-bearing
    /// for the parallel engine's bit-identity guarantee
    /// (see [`crate::sim::par`]):
    ///
    /// 1. At equal timestamps every *local* event (seq < 2⁶³) sorts before
    ///    every message, and messages order among themselves by
    ///    `(channel, msg_seq)` — a (time, domain, seq) order that does not
    ///    depend on *when* the receiving shard drained its channels.
    /// 2. Scheduling a message does not consume a local sequence number,
    ///    so the local event order is byte-identical whether deliveries
    ///    are interleaved (threads > 1) or batched (threads = 1).
    pub fn schedule_msg<F: FnOnce(&mut Engine) + 'static>(
        &mut self,
        at: SimTime,
        channel: u16,
        msg_seq: u64,
        f: F,
    ) -> TimerId {
        assert!(at >= self.now - 1e-9, "message into the past: at={at} now={}", self.now);
        assert!(at.is_finite(), "non-finite message time");
        assert!(channel < 1 << 15, "channel index overflows the tag bits");
        assert!(msg_seq < 1 << 48, "per-channel message sequence overflow");
        let seq = (1u64 << 63) | ((channel as u64) << 48) | msg_seq;
        self.msgs_scheduled += 1;
        let prev = self.events.insert(seq, Box::new(f));
        assert!(prev.is_none(), "duplicate message key (channel {channel}, seq {msg_seq})");
        self.heap.push(Scheduled { time: at.max(self.now), seq });
        debug_assert!(self.heap.len() >= self.events.len());
        TimerId(seq)
    }

    /// Cancel a scheduled event. Idempotent; cancelling an already-executed
    /// (or never-issued) id is a no-op. The callback is dropped immediately;
    /// the heap marker is purged when it pops or at the next compaction.
    pub fn cancel(&mut self, id: TimerId) {
        if self.events.remove(&id.0).is_some() {
            self.timers_cancelled += 1;
            self.maybe_compact();
            // Invariant: after a cancellation-triggered compaction pass the
            // heap is O(live) — at most 2× the live events plus the small
            // compaction floor. (Between cancels, while stepping, stale
            // markers may transiently exceed this share.)
            debug_assert!(self.heap.len() <= (2 * self.events.len()).max(64));
        }
    }

    /// Rebuild the heap without stale (cancelled) markers once they
    /// outnumber live events. Amortized O(1) per cancellation; keeps the
    /// heap at most 2× the live event count (plus a small floor).
    fn maybe_compact(&mut self) {
        if self.heap.len() > 64 && self.heap.len() > 2 * self.events.len() {
            let mut live = std::mem::take(&mut self.heap).into_vec();
            live.retain(|ev| self.events.contains_key(&ev.seq));
            self.heap = BinaryHeap::from(live);
        }
    }

    /// Run a single event. Returns false when no live event remains.
    pub fn step(&mut self) -> bool {
        while let Some(ev) = self.heap.pop() {
            let Some(f) = self.events.remove(&ev.seq) else {
                continue; // stale marker of a cancelled event: purge
            };
            // Invariant: event times never run backwards (monotone clock).
            debug_assert!(
                ev.time >= self.now - 1e-9,
                "event time {} precedes clock {}",
                ev.time,
                self.now
            );
            self.now = ev.time.max(self.now);
            self.executed += 1;
            f(self);
            return true;
        }
        false
    }

    /// Run until the heap is exhausted.
    pub fn run(&mut self) {
        while self.step() {}
    }

    /// Run every live event scheduled at or before `t` (events exactly at
    /// `t` included). Afterwards the clock rests at `t` even if the heap
    /// drained earlier — or beyond `t` if it was already past it.
    pub fn run_until(&mut self, t: SimTime) {
        loop {
            // Purge stale markers at the top so `peek` reflects the next
            // event that will actually execute — otherwise a cancelled
            // marker before `t` could let `step` run a live event past it.
            while let Some(ev) = self.heap.peek() {
                if self.events.contains_key(&ev.seq) {
                    break;
                }
                self.heap.pop();
            }
            match self.heap.peek() {
                Some(ev) if ev.time <= t => {
                    self.step();
                }
                _ => break,
            }
        }
        if self.now < t {
            self.now = t;
        }
    }

    /// Run every live event scheduled *strictly before* `t`. Unlike
    /// [`Engine::run_until`] the boundary is exclusive and the clock is
    /// never bumped to `t` — it rests at the last executed event. This is
    /// the conservative-PDES pump primitive: a shard may only execute
    /// events below its input horizon (events *at* the horizon could still
    /// be preempted by an incoming message at that exact time), and its
    /// clock must keep reporting real progress, not the horizon.
    pub fn run_before(&mut self, t: SimTime) {
        while let Some(nt) = self.next_time() {
            if nt < t {
                self.step();
            } else {
                break;
            }
        }
    }

    /// Time of the earliest live event, if any. Purges stale (cancelled)
    /// markers from the top of the heap so the answer reflects an event
    /// that will actually execute.
    pub fn next_time(&mut self) -> Option<SimTime> {
        while let Some(ev) = self.heap.peek() {
            if self.events.contains_key(&ev.seq) {
                return Some(ev.time);
            }
            self.heap.pop();
        }
        None
    }

    /// Number of pending (non-cancelled) events. Exact and O(1).
    pub fn pending(&self) -> usize {
        self.events.len()
    }

    /// Heap entries including not-yet-purged cancelled markers — a
    /// test/debug observable for the O(live) heap-size invariant.
    pub fn heap_len(&self) -> usize {
        self.heap.len()
    }
}

/// A countdown latch for multi-party barriers on the engine: the last of
/// `parties` calls to [`Countdown::arrive`] runs the action, once.
///
/// This is the provisioning hook the coordinator hangs workload start on —
/// "all placed nodes imaged" and "lightpath granted" each arrive
/// independently, and the workload launches the instant both are in — but
/// it is generic: any fan-in of independently-completing simulated work
/// can gate a continuation on one.
pub struct Countdown {
    remaining: Cell<usize>,
    action: RefCell<Option<EventFn>>,
}

impl Countdown {
    /// A latch that runs `action` after `parties` arrivals.
    pub fn new<F: FnOnce(&mut Engine) + 'static>(parties: usize, action: F) -> Rc<Countdown> {
        assert!(parties > 0, "countdown needs at least one party");
        Rc::new(Countdown {
            remaining: Cell::new(parties),
            action: RefCell::new(Some(Box::new(action))),
        })
    }

    /// One party is done. The final arrival runs the action immediately
    /// (inside the current event). Arriving more times than the latch has
    /// parties is a bug and panics.
    pub fn arrive(self: &Rc<Self>, eng: &mut Engine) {
        let r = self.remaining.get();
        assert!(r > 0, "countdown over-arrived");
        self.remaining.set(r - 1);
        if r == 1 {
            let action = self.action.borrow_mut().take();
            if let Some(f) = action {
                f(eng);
            }
        }
    }

    /// Parties still outstanding.
    pub fn pending(&self) -> usize {
        self.remaining.get()
    }
}

/// A bank of per-lane cancellable timers — the sharded generalization of
/// the "single completion timer" pattern: each lane (one per flow domain
/// in [`crate::net::FlowNet`]) carries at most one live engine event, so
/// the heap stays O(armed lanes) no matter how much churn re-arms them.
///
/// Re-arming a lane at its *current* deadline (bitwise-equal `f64`) is a
/// no-op: the existing event already fires then, and skipping the
/// cancel+reschedule keeps event sequence numbers — and therefore
/// deterministic tie-breaking — independent of how often a caller
/// recomputes an unchanged deadline.
///
/// Contract: the scheduled callback must call [`TimerBank::fired`] for
/// its lane before doing anything else, so the bank knows the stored id
/// is spent.
pub struct TimerBank {
    lanes: Vec<Option<(SimTime, TimerId)>>,
}

impl TimerBank {
    /// A bank of `lanes` initially-disarmed timers.
    pub fn new(lanes: usize) -> TimerBank {
        TimerBank { lanes: vec![None; lanes] }
    }

    /// Number of lanes.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// The lane's current deadline, if armed.
    pub fn deadline(&self, lane: usize) -> Option<SimTime> {
        self.lanes[lane].map(|(t, _)| t)
    }

    /// Arm `lane` to run `f` at absolute time `at`, replacing any earlier
    /// arm. If the lane is already armed at exactly `at`, the existing
    /// event is kept and `f` is dropped.
    pub fn arm<F: FnOnce(&mut Engine) + 'static>(
        &mut self,
        eng: &mut Engine,
        lane: usize,
        at: SimTime,
        f: F,
    ) {
        if let Some((t, _)) = self.lanes[lane] {
            if t == at {
                return; // same deadline: the live event stands
            }
        }
        self.disarm(eng, lane);
        let id = eng.schedule_at(at.max(eng.now()), f);
        self.lanes[lane] = Some((at, id));
    }

    /// Cancel the lane's pending timer, if any.
    pub fn disarm(&mut self, eng: &mut Engine, lane: usize) {
        if let Some((_, id)) = self.lanes[lane].take() {
            eng.cancel(id);
        }
    }

    /// The lane's timer fired: forget the spent id (callbacks call this
    /// first). Returns the deadline it was armed at.
    pub fn fired(&mut self, lane: usize) -> Option<SimTime> {
        self.lanes[lane].take().map(|(t, _)| t)
    }

    /// Number of currently armed lanes.
    pub fn armed(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn events_run_in_time_order() {
        let mut e = Engine::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for (t, tag) in [(3.0, 'c'), (1.0, 'a'), (2.0, 'b')] {
            let log = log.clone();
            e.schedule_at(t, move |eng| {
                log.borrow_mut().push((eng.now(), tag));
            });
        }
        e.run();
        assert_eq!(*log.borrow(), vec![(1.0, 'a'), (2.0, 'b'), (3.0, 'c')]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut e = Engine::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for tag in ['x', 'y', 'z'] {
            let log = log.clone();
            e.schedule_at(5.0, move |_| log.borrow_mut().push(tag));
        }
        e.run();
        assert_eq!(*log.borrow(), vec!['x', 'y', 'z']);
    }

    #[test]
    fn nested_scheduling_works() {
        let mut e = Engine::new();
        let hits = Rc::new(RefCell::new(0));
        let h = hits.clone();
        e.schedule_at(1.0, move |eng| {
            let h2 = h.clone();
            eng.schedule_in(1.5, move |eng2| {
                assert!((eng2.now() - 2.5).abs() < 1e-12);
                *h2.borrow_mut() += 1;
            });
        });
        e.run();
        assert_eq!(*hits.borrow(), 1);
    }

    #[test]
    fn cancel_prevents_execution() {
        let mut e = Engine::new();
        let hits = Rc::new(RefCell::new(0));
        let h = hits.clone();
        let id = e.schedule_at(1.0, move |_| *h.borrow_mut() += 1);
        e.cancel(id);
        e.run();
        assert_eq!(*hits.borrow(), 0);
    }

    #[test]
    fn profile_counters_track_hot_paths() {
        let mut e = Engine::new();
        let id = e.schedule_at(1.0, |_| {});
        e.schedule_at(2.0, |_| {});
        e.cancel(id);
        e.schedule_msg(3.0, 0, 0, |_| {});
        e.run();
        let p = e.profile();
        assert_eq!(p.timers_armed, 2);
        assert_eq!(p.timers_cancelled, 1);
        assert_eq!(p.channel_messages, 1);
        assert_eq!(p.events, 2); // one local + one message; the cancelled one never runs
        assert!(p.sched.is_none(), "sequential engines report no scheduler-lane profile");
    }

    #[test]
    fn recorder_rides_the_engine_and_harvests_out() {
        let mut e = Engine::new();
        assert!(e.recorder().is_none());
        e.set_recorder(crate::trace::Recorder::new(&crate::trace::TraceSpec::with_cap(8)));
        e.schedule_at(1.0, |eng| {
            let t = eng.now();
            if let Some(rec) = eng.recorder() {
                rec.instant(t, 0, 0, "tick", 0, &[]);
            }
        });
        e.run();
        let rec = e.take_recorder().expect("recorder installed");
        assert_eq!(rec.len(), 1);
        assert!(e.recorder().is_none(), "take_recorder removes it");
    }

    #[test]
    fn stale_cancel_is_a_noop_and_pending_stays_exact() {
        let mut e = Engine::new();
        let id1 = e.schedule_at(1.0, |_| {});
        e.schedule_at(2.0, |_| {});
        assert_eq!(e.pending(), 2);
        assert!(e.step()); // executes id1
        // Cancelling the already-executed event must not undercount the
        // remaining live event or retain any state.
        e.cancel(id1);
        e.cancel(id1); // doubly stale: still a no-op
        assert_eq!(e.pending(), 1);
        e.run();
        assert_eq!(e.pending(), 0);
        assert_eq!(e.heap_len(), 0);
    }

    #[test]
    fn cancelled_markers_are_compacted() {
        let mut e = Engine::new();
        for _ in 0..1000 {
            let id = e.schedule_at(1e6, |_| {});
            e.cancel(id);
            assert!(e.heap_len() <= 2 * e.pending() + 66, "heap {}", e.heap_len());
        }
        assert_eq!(e.pending(), 0);
        assert!(e.heap_len() <= 66, "heap {}", e.heap_len());
        e.run();
        assert_eq!(e.executed(), 0);
    }

    #[test]
    fn run_until_does_not_step_past_cancelled_head() {
        let mut e = Engine::new();
        let hits = Rc::new(RefCell::new(0));
        let h = hits.clone();
        let early = e.schedule_at(1.0, |_| {});
        e.schedule_at(10.0, move |_| *h.borrow_mut() += 1);
        e.cancel(early);
        // The cancelled t=1 marker must not trick run_until(5) into
        // executing the t=10 event.
        e.run_until(5.0);
        assert_eq!(*hits.borrow(), 0);
        assert_eq!(e.now(), 5.0);
        e.run();
        assert_eq!(*hits.borrow(), 1);
    }

    #[test]
    fn run_until_stops_at_boundary() {
        let mut e = Engine::new();
        let hits = Rc::new(RefCell::new(Vec::new()));
        for t in [1.0, 2.0, 3.0, 4.0] {
            let h = hits.clone();
            e.schedule_at(t, move |_| h.borrow_mut().push(t));
        }
        e.run_until(2.5);
        assert_eq!(*hits.borrow(), vec![1.0, 2.0]);
        assert_eq!(e.now(), 2.5);
        e.run();
        assert_eq!(*hits.borrow(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn scheduling_past_panics() {
        let mut e = Engine::new();
        e.schedule_at(5.0, |_| {});
        e.run();
        e.schedule_at(1.0, |_| {});
    }

    #[test]
    fn countdown_fires_once_after_all_arrivals() {
        let mut e = Engine::new();
        let hits = Rc::new(RefCell::new(0));
        let h = hits.clone();
        let latch = Countdown::new(3, move |_| *h.borrow_mut() += 1);
        assert_eq!(latch.pending(), 3);
        latch.arrive(&mut e);
        latch.arrive(&mut e);
        assert_eq!(*hits.borrow(), 0, "fired early");
        latch.arrive(&mut e);
        assert_eq!(*hits.borrow(), 1);
        assert_eq!(latch.pending(), 0);
        // The action can schedule follow-up work on the engine.
        let h2 = hits.clone();
        let latch2 = Countdown::new(1, move |eng| {
            let h3 = h2.clone();
            eng.schedule_in(1.0, move |_| *h3.borrow_mut() += 10);
        });
        latch2.arrive(&mut e);
        e.run();
        assert_eq!(*hits.borrow(), 11);
    }

    #[test]
    #[should_panic(expected = "over-arrived")]
    fn countdown_over_arrival_panics() {
        let mut e = Engine::new();
        let latch = Countdown::new(1, |_| {});
        latch.arrive(&mut e);
        latch.arrive(&mut e);
    }

    #[test]
    fn timer_bank_one_event_per_lane() {
        let mut e = Engine::new();
        let mut bank = TimerBank::new(3);
        let hits = Rc::new(RefCell::new(Vec::new()));
        // Re-arm lane 0 a hundred times: only the last deadline survives,
        // and the heap never accumulates stale events beyond O(live).
        for i in 0..100 {
            let h = hits.clone();
            bank.arm(&mut e, 0, 100.0 - i as f64, move |eng| h.borrow_mut().push(eng.now()));
        }
        assert_eq!(bank.deadline(0), Some(1.0));
        assert_eq!(e.pending(), 1);
        let h = hits.clone();
        bank.arm(&mut e, 2, 5.0, move |eng| h.borrow_mut().push(eng.now()));
        assert_eq!(bank.armed(), 2);
        assert_eq!(bank.lanes(), 3);
        e.run();
        assert_eq!(*hits.borrow(), vec![1.0, 5.0]);
    }

    #[test]
    fn timer_bank_same_deadline_rearm_is_noop() {
        let mut e = Engine::new();
        let mut bank = TimerBank::new(1);
        let hits = Rc::new(RefCell::new(0));
        let h = hits.clone();
        bank.arm(&mut e, 0, 2.0, move |_| *h.borrow_mut() += 1);
        let seq_before = e.pending();
        // Same bitwise deadline: the original event must stand (the new
        // closure is dropped, no cancel/reschedule churn).
        let h = hits.clone();
        bank.arm(&mut e, 0, 2.0, move |_| *h.borrow_mut() += 100);
        assert_eq!(e.pending(), seq_before);
        e.run();
        assert_eq!(*hits.borrow(), 1);
    }

    #[test]
    fn timer_bank_disarm_and_fired() {
        let mut e = Engine::new();
        let mut bank = TimerBank::new(2);
        let hits = Rc::new(RefCell::new(0));
        let h = hits.clone();
        bank.arm(&mut e, 0, 1.0, move |_| *h.borrow_mut() += 1);
        bank.disarm(&mut e, 0);
        assert_eq!(bank.deadline(0), None);
        assert_eq!(e.pending(), 0);
        let h = hits.clone();
        bank.arm(&mut e, 1, 3.0, move |_| *h.borrow_mut() += 10);
        // `fired` hands back the armed deadline and clears the lane (the
        // callback contract); the event itself still runs.
        assert_eq!(bank.fired(1), Some(3.0));
        assert_eq!(bank.armed(), 0);
        e.run();
        assert_eq!(*hits.borrow(), 10);
    }

    #[test]
    fn messages_sort_after_local_events_at_equal_time() {
        let mut e = Engine::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        // Deliver the message first, then schedule local events at the
        // same timestamp: the locals must still run first (bit 63 tags
        // messages into a later tie-break class regardless of insertion
        // order).
        let l = log.clone();
        e.schedule_msg(5.0, 0, 0, move |_| l.borrow_mut().push("msg"));
        for tag in ["a", "b"] {
            let l = log.clone();
            e.schedule_at(5.0, move |_| l.borrow_mut().push(tag));
        }
        e.run();
        assert_eq!(*log.borrow(), vec!["a", "b", "msg"]);
    }

    #[test]
    fn messages_order_by_channel_then_sequence() {
        let mut e = Engine::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        // Insert deliberately out of (channel, seq) order; execution must
        // sort by the encoded key, not insertion order.
        for (ch, seq) in [(1u16, 0u64), (0, 1), (1, 1), (0, 0)] {
            let l = log.clone();
            e.schedule_msg(2.0, ch, seq, move |_| l.borrow_mut().push((ch, seq)));
        }
        e.run();
        assert_eq!(*log.borrow(), vec![(0, 0), (0, 1), (1, 0), (1, 1)]);
        assert_eq!(e.executed(), 4);
    }

    #[test]
    fn messages_do_not_consume_local_sequence_numbers() {
        // Two runs that differ only in whether a message was interleaved
        // between local schedules must execute the locals in the same
        // relative order — the message lane must not shift local seqs.
        let order = |with_msg: bool| {
            let mut e = Engine::new();
            let log = Rc::new(RefCell::new(Vec::new()));
            let l = log.clone();
            e.schedule_at(1.0, move |_| l.borrow_mut().push('a'));
            if with_msg {
                e.schedule_msg(1.0, 3, 7, |_| {});
            }
            let l = log.clone();
            e.schedule_at(1.0, move |_| l.borrow_mut().push('b'));
            e.run();
            log.borrow().clone()
        };
        assert_eq!(order(false), vec!['a', 'b']);
        assert_eq!(order(true), vec!['a', 'b']);
    }

    #[test]
    #[should_panic(expected = "duplicate message key")]
    fn duplicate_message_key_panics() {
        let mut e = Engine::new();
        e.schedule_msg(1.0, 2, 9, |_| {});
        e.schedule_msg(1.5, 2, 9, |_| {});
    }

    #[test]
    fn run_before_is_strict_and_keeps_the_clock_honest() {
        let mut e = Engine::new();
        let hits = Rc::new(RefCell::new(Vec::new()));
        for t in [1.0, 2.0, 3.0] {
            let h = hits.clone();
            e.schedule_at(t, move |_| h.borrow_mut().push(t));
        }
        // Strict boundary: the t=2 event is NOT executed by run_before(2),
        // and the clock rests at the last executed event (1.0), not at the
        // horizon — a shard's published progress must be real.
        e.run_before(2.0);
        assert_eq!(*hits.borrow(), vec![1.0]);
        assert_eq!(e.now(), 1.0);
        assert_eq!(e.next_time(), Some(2.0));
        e.run_before(f64::INFINITY);
        assert_eq!(*hits.borrow(), vec![1.0, 2.0, 3.0]);
        assert_eq!(e.next_time(), None);
    }

    #[test]
    fn next_time_skips_cancelled_heads() {
        let mut e = Engine::new();
        let early = e.schedule_at(1.0, |_| {});
        e.schedule_at(4.0, |_| {});
        e.cancel(early);
        assert_eq!(e.next_time(), Some(4.0));
        // run_before must not be fooled by a stale earlier marker either.
        let hits = Rc::new(RefCell::new(0));
        let h = hits.clone();
        e.schedule_at(2.0, move |_| *h.borrow_mut() += 1);
        e.run_before(3.0);
        assert_eq!(*hits.borrow(), 1);
        assert_eq!(e.pending(), 1);
    }

    #[test]
    fn timer_bank_lanes_are_isolated() {
        let mut e = Engine::new();
        let mut bank = TimerBank::new(4);
        let hits = Rc::new(RefCell::new(Vec::new()));
        for lane in 0..4 {
            let h = hits.clone();
            bank.arm(&mut e, lane, 10.0 + lane as f64, move |_| h.borrow_mut().push(lane));
        }
        // Disarming and re-arming lane 1 must leave the other lanes'
        // deadlines and events untouched.
        bank.disarm(&mut e, 1);
        let h = hits.clone();
        bank.arm(&mut e, 1, 20.0, move |_| h.borrow_mut().push(100));
        assert_eq!(bank.deadline(0), Some(10.0));
        assert_eq!(bank.deadline(1), Some(20.0));
        assert_eq!(bank.deadline(2), Some(12.0));
        assert_eq!(bank.deadline(3), Some(13.0));
        e.run();
        assert_eq!(*hits.borrow(), vec![0, 2, 3, 100]);
    }

    #[test]
    fn timer_bank_cancel_then_rearm_same_lane() {
        // The per-flow completion-timer pattern: a flow's deadline moves
        // when bandwidth shifts — cancel, then re-arm the same lane at the
        // new time. Only the final arm may fire.
        let mut e = Engine::new();
        let mut bank = TimerBank::new(1);
        let hits = Rc::new(RefCell::new(Vec::new()));
        let h = hits.clone();
        bank.arm(&mut e, 0, 5.0, move |_| h.borrow_mut().push(5.0));
        bank.disarm(&mut e, 0);
        let h = hits.clone();
        bank.arm(&mut e, 0, 3.0, move |_| h.borrow_mut().push(3.0));
        // Re-arm without an explicit disarm: arm() replaces the pending
        // event itself when the deadline differs.
        let h = hits.clone();
        bank.arm(&mut e, 0, 7.0, move |eng| {
            h.borrow_mut().push(eng.now());
        });
        assert_eq!(e.pending(), 1);
        e.run();
        assert_eq!(*hits.borrow(), vec![7.0]);
    }

    #[test]
    fn timer_bank_stale_cancel_after_fire_is_noop() {
        let mut e = Engine::new();
        let bank = Rc::new(RefCell::new(TimerBank::new(2)));
        let hits = Rc::new(RefCell::new(0));
        let (b2, h2) = (bank.clone(), hits.clone());
        bank.borrow_mut().arm(&mut e, 0, 1.0, move |_| {
            b2.borrow_mut().fired(0);
            *h2.borrow_mut() += 1;
        });
        let h2 = hits.clone();
        bank.borrow_mut().arm(&mut e, 1, 2.0, |_| {});
        e.schedule_at(3.0, move |_| *h2.borrow_mut() += 10);
        e.run_until(1.5);
        // Lane 0 already fired; disarming it now must not cancel anything
        // (in particular not a recycled seq belonging to another event).
        let mut b = bank.borrow_mut();
        b.disarm(&mut e, 0);
        b.disarm(&mut e, 0); // doubly stale
        assert_eq!(b.deadline(0), None);
        drop(b);
        e.run();
        assert_eq!(*hits.borrow(), 11);
        assert_eq!(e.pending(), 0);
    }

    #[test]
    fn timer_bank_heap_bounded_under_churn_property() {
        crate::proptest::check("timer bank heap O(lanes) under re-arm churn", 20, |rng| {
            let mut e = Engine::new();
            let lanes = 8;
            let mut bank = TimerBank::new(lanes);
            for _ in 0..2000 {
                let lane = rng.gen_range(lanes as u64) as usize;
                if rng.chance(0.15) {
                    bank.disarm(&mut e, lane);
                } else {
                    let at = e.now() + 1.0 + rng.f64() * 50.0;
                    bank.arm(&mut e, lane, at, |_| {});
                }
                if rng.chance(0.1) {
                    e.step();
                }
                // The whole point of the bank: however hard churn re-arms
                // the lanes, live events stay <= lanes and the heap stays
                // O(lanes), never O(total re-arms).
                if e.pending() > lanes {
                    return Err(format!("{} live events for {lanes} lanes", e.pending()));
                }
                if e.heap_len() > 2 * lanes + 66 {
                    return Err(format!("heap {} for {lanes} lanes", e.heap_len()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn clock_monotone_property() {
        crate::proptest::check("engine clock monotone", 50, |rng| {
            let mut e = Engine::new();
            let times = Rc::new(RefCell::new(Vec::new()));
            for _ in 0..100 {
                let t = rng.f64() * 100.0;
                let times = times.clone();
                e.schedule_at(t, move |eng| times.borrow_mut().push(eng.now()));
            }
            e.run();
            let ts = times.borrow();
            if ts.windows(2).all(|w| w[0] <= w[1]) {
                Ok(())
            } else {
                Err("clock went backwards".into())
            }
        });
    }

    #[test]
    fn heap_stays_linear_in_live_events_property() {
        crate::proptest::check("engine heap O(live) under cancel churn", 20, |rng| {
            let mut e = Engine::new();
            let mut ids: Vec<TimerId> = Vec::new();
            for _ in 0..2000 {
                let t = e.now() + rng.f64() * 10.0;
                ids.push(e.schedule_at(t, |_| {}));
                if rng.chance(0.7) && !ids.is_empty() {
                    // May hit executed ids too — stale cancels must stay no-ops.
                    let k = rng.gen_range(ids.len() as u64) as usize;
                    e.cancel(ids.swap_remove(k));
                }
                if rng.chance(0.2) {
                    e.step();
                }
                if e.heap_len() > 2 * e.pending() + 66 {
                    return Err(format!("heap {} for {} live", e.heap_len(), e.pending()));
                }
            }
            e.run();
            if e.pending() != 0 || e.heap_len() != 0 {
                return Err("drain left residue".into());
            }
            Ok(())
        });
    }
}

//! The event heap: virtual clock, closure events, cancellable timers.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::collections::HashSet;

/// Simulated time in seconds.
pub type SimTime = f64;

/// Handle for cancelling a scheduled event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TimerId(u64);

type EventFn = Box<dyn FnOnce(&mut Engine)>;

struct Scheduled {
    time: SimTime,
    seq: u64,
    f: EventFn,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    // BinaryHeap is a max-heap; invert so the earliest (time, seq) pops first.
    // Ties break by insertion order (seq), making execution deterministic.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Discrete-event engine.
pub struct Engine {
    now: SimTime,
    seq: u64,
    heap: BinaryHeap<Scheduled>,
    cancelled: HashSet<u64>,
    executed: u64,
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    pub fn new() -> Self {
        Engine { now: 0.0, seq: 0, heap: BinaryHeap::new(), cancelled: HashSet::new(), executed: 0 }
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events executed so far (used by the perf benches).
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Schedule `f` at absolute time `t` (must be >= now).
    pub fn schedule_at<F: FnOnce(&mut Engine) + 'static>(&mut self, t: SimTime, f: F) -> TimerId {
        assert!(t >= self.now - 1e-9, "scheduling into the past: t={t} now={}", self.now);
        assert!(t.is_finite(), "non-finite event time");
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { time: t.max(self.now), seq, f: Box::new(f) });
        TimerId(seq)
    }

    /// Schedule `f` after a delay of `dt` seconds.
    pub fn schedule_in<F: FnOnce(&mut Engine) + 'static>(&mut self, dt: SimTime, f: F) -> TimerId {
        assert!(dt >= 0.0, "negative delay {dt}");
        let now = self.now;
        self.schedule_at(now + dt, f)
    }

    /// Cancel a scheduled event. Idempotent; cancelling an already-executed
    /// event is a no-op.
    pub fn cancel(&mut self, id: TimerId) {
        self.cancelled.insert(id.0);
    }

    /// Run a single event. Returns false when the heap is empty.
    pub fn step(&mut self) -> bool {
        while let Some(ev) = self.heap.pop() {
            if self.cancelled.remove(&ev.seq) {
                continue;
            }
            debug_assert!(ev.time >= self.now - 1e-9);
            self.now = ev.time.max(self.now);
            self.executed += 1;
            (ev.f)(self);
            return true;
        }
        false
    }

    /// Run until the heap is exhausted.
    pub fn run(&mut self) {
        while self.step() {}
    }

    /// Run until virtual time passes `t` or the heap empties. Events
    /// scheduled exactly at `t` are executed. Afterwards `now() >= t` only
    /// if events reached it; the clock never advances past executed events.
    pub fn run_until(&mut self, t: SimTime) {
        loop {
            match self.heap.peek() {
                Some(ev) if ev.time <= t => {
                    self.step();
                }
                _ => break,
            }
        }
        if self.now < t {
            self.now = t;
        }
    }

    /// Number of pending (non-cancelled) events. O(n); test/debug helper.
    pub fn pending(&self) -> usize {
        self.heap.len() - self.cancelled.len().min(self.heap.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn events_run_in_time_order() {
        let mut e = Engine::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for (t, tag) in [(3.0, 'c'), (1.0, 'a'), (2.0, 'b')] {
            let log = log.clone();
            e.schedule_at(t, move |eng| {
                log.borrow_mut().push((eng.now(), tag));
            });
        }
        e.run();
        assert_eq!(*log.borrow(), vec![(1.0, 'a'), (2.0, 'b'), (3.0, 'c')]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut e = Engine::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for tag in ['x', 'y', 'z'] {
            let log = log.clone();
            e.schedule_at(5.0, move |_| log.borrow_mut().push(tag));
        }
        e.run();
        assert_eq!(*log.borrow(), vec!['x', 'y', 'z']);
    }

    #[test]
    fn nested_scheduling_works() {
        let mut e = Engine::new();
        let hits = Rc::new(RefCell::new(0));
        let h = hits.clone();
        e.schedule_at(1.0, move |eng| {
            let h2 = h.clone();
            eng.schedule_in(1.5, move |eng2| {
                assert!((eng2.now() - 2.5).abs() < 1e-12);
                *h2.borrow_mut() += 1;
            });
        });
        e.run();
        assert_eq!(*hits.borrow(), 1);
    }

    #[test]
    fn cancel_prevents_execution() {
        let mut e = Engine::new();
        let hits = Rc::new(RefCell::new(0));
        let h = hits.clone();
        let id = e.schedule_at(1.0, move |_| *h.borrow_mut() += 1);
        e.cancel(id);
        e.run();
        assert_eq!(*hits.borrow(), 0);
    }

    #[test]
    fn run_until_stops_at_boundary() {
        let mut e = Engine::new();
        let hits = Rc::new(RefCell::new(Vec::new()));
        for t in [1.0, 2.0, 3.0, 4.0] {
            let h = hits.clone();
            e.schedule_at(t, move |_| h.borrow_mut().push(t));
        }
        e.run_until(2.5);
        assert_eq!(*hits.borrow(), vec![1.0, 2.0]);
        assert_eq!(e.now(), 2.5);
        e.run();
        assert_eq!(*hits.borrow(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn scheduling_past_panics() {
        let mut e = Engine::new();
        e.schedule_at(5.0, |_| {});
        e.run();
        e.schedule_at(1.0, |_| {});
    }

    #[test]
    fn clock_monotone_property() {
        crate::proptest::check("engine clock monotone", 50, |rng| {
            let mut e = Engine::new();
            let times = Rc::new(RefCell::new(Vec::new()));
            for _ in 0..100 {
                let t = rng.f64() * 100.0;
                let times = times.clone();
                e.schedule_at(t, move |eng| times.borrow_mut().push(eng.now()));
            }
            e.run();
            let ts = times.borrow();
            if ts.windows(2).all(|w| w[0] <= w[1]) {
                Ok(())
            } else {
                Err("clock went backwards".into())
            }
        });
    }
}

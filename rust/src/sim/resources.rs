//! Node-local compute resources: a slot-based CPU pool per simulated node.
//!
//! Disk and network bandwidth are fluid resources handled by the max-min
//! flow network (`net::FlowNet` — a disk is just a link). CPU is different:
//! engines schedule discrete tasks onto a bounded number of slots (Hadoop
//! 0.18's fixed map/reduce slots per TaskTracker, Sphere's SPE threads), so
//! the CPU pool is a FIFO slot queue with per-node speed factors — the
//! speed factor is how the paper's "one or two nodes with slightly inferior
//! performance" stragglers are injected.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use super::Engine;

type Callback = Box<dyn FnOnce(&mut Engine)>;

struct Pending {
    demand_secs: f64,
    done: Callback,
}

/// A fixed-slot FIFO CPU pool (one per simulated node).
pub struct CpuPool {
    slots: usize,
    busy: usize,
    /// Relative speed: 1.0 nominal, 0.5 = half speed (straggler).
    speed: f64,
    queue: VecDeque<Pending>,
    /// Cumulative busy slot-seconds, for monitor utilization sampling.
    busy_time: f64,
    last_change: f64,
    util_acc: f64,
}

impl CpuPool {
    pub fn new(slots: usize) -> Rc<RefCell<CpuPool>> {
        assert!(slots > 0);
        Rc::new(RefCell::new(CpuPool {
            slots,
            busy: 0,
            speed: 1.0,
            queue: VecDeque::new(),
            busy_time: 0.0,
            last_change: 0.0,
            util_acc: 0.0,
        }))
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn busy(&self) -> usize {
        self.busy
    }

    pub fn set_speed(&mut self, speed: f64) {
        assert!(speed > 0.0);
        self.speed = speed;
    }

    pub fn speed(&self) -> f64 {
        self.speed
    }

    fn account(&mut self, now: f64) {
        let dt = now - self.last_change;
        if dt > 0.0 {
            self.util_acc += dt * self.busy as f64 / self.slots as f64;
            self.busy_time += dt * self.busy as f64;
            self.last_change = now;
        }
    }

    /// Mean utilization in [0,1] since the last call (monitor sampling).
    pub fn take_utilization(&mut self, now: f64, window: f64) -> f64 {
        self.account(now);
        let u = if window > 0.0 { (self.util_acc / window).min(1.0) } else { 0.0 };
        self.util_acc = 0.0;
        u
    }

    /// Submit a task needing `demand_secs` of nominal CPU time; `done` fires
    /// when it completes (queueing + execution). FIFO when all slots busy.
    pub fn submit<F: FnOnce(&mut Engine) + 'static>(
        pool: &Rc<RefCell<CpuPool>>,
        eng: &mut Engine,
        demand_secs: f64,
        done: F,
    ) {
        assert!(demand_secs >= 0.0);
        let done: Callback = Box::new(done);
        let start_now = {
            let mut p = pool.borrow_mut();
            p.account(eng.now());
            if p.busy < p.slots {
                p.busy += 1;
                None
            } else {
                Some(())
            }
        };
        match start_now {
            None => Self::start(pool.clone(), eng, demand_secs, done),
            Some(()) => pool.borrow_mut().queue.push_back(Pending { demand_secs, done }),
        }
    }

    fn start(pool: Rc<RefCell<CpuPool>>, eng: &mut Engine, demand_secs: f64, done: Callback) {
        let dur = demand_secs / pool.borrow().speed;
        eng.schedule_in(dur, move |eng| {
            done(eng);
            // Free the slot and start the next queued task, if any.
            let next = {
                let mut p = pool.borrow_mut();
                p.account(eng.now());
                match p.queue.pop_front() {
                    Some(t) => Some(t),
                    None => {
                        p.busy -= 1;
                        None
                    }
                }
            };
            if let Some(t) = next {
                Self::start(pool.clone(), eng, t.demand_secs, t.done);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_slot_serializes_tasks() {
        let mut eng = Engine::new();
        let pool = CpuPool::new(1);
        let log = Rc::new(RefCell::new(Vec::new()));
        for i in 0..3 {
            let log = log.clone();
            CpuPool::submit(&pool, &mut eng, 2.0, move |e| log.borrow_mut().push((i, e.now())));
        }
        eng.run();
        assert_eq!(*log.borrow(), vec![(0, 2.0), (1, 4.0), (2, 6.0)]);
    }

    #[test]
    fn parallel_slots_overlap() {
        let mut eng = Engine::new();
        let pool = CpuPool::new(4);
        let done = Rc::new(RefCell::new(Vec::new()));
        for _ in 0..4 {
            let d = done.clone();
            CpuPool::submit(&pool, &mut eng, 3.0, move |e| d.borrow_mut().push(e.now()));
        }
        eng.run();
        assert_eq!(*done.borrow(), vec![3.0; 4]);
    }

    #[test]
    fn straggler_speed_scales_duration() {
        let mut eng = Engine::new();
        let pool = CpuPool::new(1);
        pool.borrow_mut().set_speed(0.5);
        let t = Rc::new(RefCell::new(0.0));
        let t2 = t.clone();
        CpuPool::submit(&pool, &mut eng, 2.0, move |e| *t2.borrow_mut() = e.now());
        eng.run();
        assert_eq!(*t.borrow(), 4.0);
    }

    #[test]
    fn queue_drains_fifo() {
        let mut eng = Engine::new();
        let pool = CpuPool::new(2);
        let order = Rc::new(RefCell::new(Vec::new()));
        for i in 0..6 {
            let o = order.clone();
            CpuPool::submit(&pool, &mut eng, 1.0, move |_| o.borrow_mut().push(i));
        }
        eng.run();
        assert_eq!(*order.borrow(), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(pool.borrow().busy(), 0);
    }

    #[test]
    fn utilization_accounting() {
        let mut eng = Engine::new();
        let pool = CpuPool::new(2);
        // One slot busy for 4s out of an 8s window => 25% pool utilization.
        CpuPool::submit(&pool, &mut eng, 4.0, |_| {});
        eng.run();
        eng.run_until(8.0);
        let u = pool.borrow_mut().take_utilization(8.0, 8.0);
        assert!((u - 0.25).abs() < 1e-9, "u={u}");
    }

    #[test]
    fn makespan_property_matches_slot_bound() {
        crate::proptest::check("cpu pool makespan bound", 30, |rng| {
            let mut eng = Engine::new();
            let slots = 1 + rng.gen_range(4) as usize;
            let pool = CpuPool::new(slots);
            let n = 1 + rng.gen_range(20) as usize;
            let mut total = 0.0;
            let mut maxd = 0.0f64;
            let end = Rc::new(RefCell::new(0.0f64));
            for _ in 0..n {
                let d = 0.1 + rng.f64();
                total += d;
                maxd = maxd.max(d);
                let end = end.clone();
                CpuPool::submit(&pool, &mut eng, d, move |e| {
                    let mut m = end.borrow_mut();
                    *m = m.max(e.now());
                });
            }
            eng.run();
            let makespan = *end.borrow();
            let lower = (total / slots as f64).max(maxd);
            // FIFO list scheduling is within 2x of the lower bound.
            if makespan + 1e-9 >= lower && makespan <= 2.0 * lower + maxd {
                Ok(())
            } else {
                Err(format!("makespan={makespan} lower={lower}"))
            }
        });
    }
}

//! Conservative (lookahead) parallel execution of sharded simulations —
//! the only module in the crate allowed to touch `std::thread` (enforced
//! by simlint rule SIM006; ambient parallelism anywhere else is a
//! determinism hazard).
//!
//! # Model
//!
//! A simulation is split into `n` *shards*, each owning a private
//! [`Engine`] plus whatever domain state the application wires in through
//! [`ShardApp`]. Shards interact only via *messages* on latency-bounded
//! channels: a message sent at simulated time `t` is delivered at exactly
//! `t + L`, where the lookahead `L` is uniform across channels and
//! strictly positive. The Open Cloud Testbed's architecture provides that
//! bound for free — sites couple only through dedicated wide-area
//! lightpaths whose one-way delay is bounded below by
//! [`Topology::min_wan_owd`](crate::net::Topology::min_wan_owd) — which
//! is exactly what a conservative PDES needs to let shards run ahead of
//! each other safely.
//!
//! # Synchronization protocol
//!
//! Every shard publishes an *earliest output time* (EOT): a promise never
//! again to send a message delivered before that time. A shard's
//! *earliest input time* (EIT) is the minimum EOT over its peers; events
//! strictly below the EIT cannot be preempted by any future message, so
//! they are safe to execute. Each pump round therefore:
//!
//! 1. reads every peer's EOT (`Acquire`) — *before* draining, so a
//!    message counted on by an observed EOT is never missed;
//! 2. drains its input queues in fixed channel order, turning each
//!    message into an engine event keyed by [`Engine::schedule_msg`];
//! 3. executes local events strictly below the EIT
//!    ([`Engine::run_before`]);
//! 4. flushes its outbox into the peer queues, then re-publishes
//!    `min(next local event, EIT) + L` (`Release`, monotone).
//!
//! Queue pushes happen-before the EOT store, so observing an EOT implies
//! observing every message below it; monotone publication keeps that
//! promise transitive across shards. `L > 0` forces the EOT lattice to
//! strictly rise until it clears the global minimum event time, so the
//! scheme cannot deadlock.
//!
//! # Determinism
//!
//! Thread count is **not allowed** to change results: `threads = 1` runs
//! the very same pump code round-robin on the calling thread, and any
//! `threads = N` run is bit-identical to it. This holds by construction,
//! not by testing-and-hoping:
//!
//! * deliveries execute in [`Engine::schedule_msg`]'s encoded
//!   `(time, channel, per-channel seq)` order, so *when* a receiver
//!   happens to drain its queues cannot reorder execution;
//! * message scheduling does not consume local sequence numbers, so the
//!   local tie-break order is independent of delivery interleaving;
//! * the conservative horizon guarantees no event runs until every
//!   message that could precede it has arrived.
//!
//! The cross-thread-count determinism tests in `tests/determinism.rs`
//! and the `engine_parallel` bench check the resulting byte-identity of
//! whole `RunReport`s end to end.
//!
//! # Self-observation
//!
//! When tracing is enabled the pump emits a `sync.msg` instant per
//! cross-shard delivery — from *inside* the scheduled message event, so
//! the emission order is the deterministic execution order, never the
//! wall-clock drain order. The pump also feeds the engine's
//! [`SchedProfile`](crate::trace::SchedProfile) at round boundaries
//! (rounds, horizon stalls, host seconds per stage); those numbers
//! depend on peer thread speed and stay outside byte-identity.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::engine::{Engine, SimTime};

/// A shard's outbound mailbox. Cloneable so application closures running
/// inside engine events can capture it; the pump flushes it into the
/// cross-shard queues at the end of every round.
pub struct Outbox<M> {
    buf: Rc<RefCell<Vec<(usize, SimTime, M)>>>,
}

impl<M> Clone for Outbox<M> {
    fn clone(&self) -> Self {
        Outbox { buf: self.buf.clone() }
    }
}

impl<M> Outbox<M> {
    fn new() -> Self {
        Outbox { buf: Rc::new(RefCell::new(Vec::new())) }
    }

    /// Queue `msg` for shard `to`, stamped with the current simulated
    /// time; it will be delivered at `eng.now() + L`.
    pub fn send(&self, eng: &Engine, to: usize, msg: M) {
        self.buf.borrow_mut().push((to, eng.now(), msg));
    }
}

/// The application half of a shard: domain state plus the three hooks the
/// pump drives. Created on the worker thread by a `Send` factory, so the
/// state itself is free to use `Rc`/`RefCell` exactly like sequential
/// simulation code — it never crosses a thread boundary.
pub trait ShardApp {
    /// Cross-shard message payload.
    type Msg: Send + 'static;
    /// Per-shard result, collected in shard-index order by
    /// [`run_sharded`].
    type Out: Send + 'static;

    /// Seed initial local events (and optionally initial messages).
    fn init(&mut self, eng: &mut Engine, out: &Outbox<Self::Msg>);

    /// A message from shard `from` arriving at its delivery time
    /// (`eng.now()` is the delivery time when this runs).
    fn on_msg(&mut self, eng: &mut Engine, from: usize, msg: Self::Msg, out: &Outbox<Self::Msg>);

    /// True once this shard is *certain* no further message will ever
    /// arrive for it. The pump finishes a shard when its engine is
    /// drained and either this holds or every peer has already finished.
    /// Reporting `true` while a peer still owes this shard a message is
    /// an application bug; [`run_sharded`] panics if any queue ends
    /// non-empty.
    fn quiescent(&self) -> bool;

    /// Produce the shard's result. Called exactly once, after the engine
    /// has fully drained.
    fn finish(&mut self, eng: &mut Engine) -> Self::Out;
}

/// Cross-shard state: one EOT slot per shard and one FIFO queue per
/// ordered shard pair (`from * n + to`), delivery-time-stamped.
struct Shared<M> {
    eot: Vec<AtomicU64>,
    queues: Vec<Mutex<VecDeque<(SimTime, M)>>>,
}

/// One shard's event pump: engine + app + channel cursors. Deliberately
/// `!Send` (the app state is `Rc`-based); in threaded mode each pump is
/// built and driven on a single worker thread, in inline mode all pumps
/// share the calling thread.
struct Pump<A: ShardApp> {
    idx: usize,
    n: usize,
    latency: SimTime,
    eng: Engine,
    app: Rc<RefCell<A>>,
    outbox: Outbox<A::Msg>,
    /// Per-input-channel delivery counters: the low 48 bits of the
    /// message event keys. FIFO queues + deterministic sender order make
    /// these identical across thread counts.
    in_seq: Vec<u64>,
    /// Last EOT this shard published (publication is monotone).
    published: SimTime,
    shared: Arc<Shared<A::Msg>>,
    finished: bool,
    out: Option<A::Out>,
}

impl<A: ShardApp> Pump<A> {
    fn new(idx: usize, n: usize, latency: SimTime, shared: Arc<Shared<A::Msg>>, mut app: A) -> Self {
        let mut eng = Engine::new();
        let outbox = Outbox::new();
        app.init(&mut eng, &outbox);
        Pump {
            idx,
            n,
            latency,
            eng,
            app: Rc::new(RefCell::new(app)),
            outbox,
            in_seq: vec![0; n],
            published: 0.0,
            shared,
            finished: false,
            out: None,
        }
    }

    /// One conservative round. Returns true if anything moved — an event
    /// executed, a message arrived, the published horizon rose, or the
    /// shard finished — so callers can detect a global stall.
    fn round(&mut self) -> bool {
        debug_assert!(!self.finished);
        let mut progress = false;
        // simlint: allow(SIM002) — pump-boundary wall sampling feeds SchedProfile, outside identity
        let t0 = std::time::Instant::now();

        // 1. Read peer horizons BEFORE draining: a message promised by an
        // EOT observed here is guaranteed to already sit in the queue.
        let mut eit = f64::INFINITY;
        for (j, slot) in self.shared.eot.iter().enumerate() {
            if j != self.idx {
                eit = eit.min(f64::from_bits(slot.load(Ordering::Acquire)));
            }
        }

        // 2. Drain input channels in fixed order; every message becomes
        // an engine event keyed by (time, channel, per-channel seq).
        let mut drained_any = false;
        let mut batch: Vec<(SimTime, A::Msg)> = Vec::new();
        for from in 0..self.n {
            if from == self.idx {
                continue;
            }
            {
                let mut q = self.shared.queues[from * self.n + self.idx].lock().unwrap();
                batch.extend(q.drain(..));
            }
            for (at, msg) in batch.drain(..) {
                let seq = self.in_seq[from];
                self.in_seq[from] += 1;
                let app = self.app.clone();
                let out = self.outbox.clone();
                let to = self.idx;
                self.eng.schedule_msg(at, from as u16, seq, move |eng| {
                    // Emitted inside the message event: the recorder sees
                    // the deterministic execution order, not drain order.
                    let t = eng.now();
                    if let Some(rec) = eng.recorder() {
                        rec.instant(t, to as u16, from as u32, "sync.msg", 0, &[]);
                    }
                    app.borrow_mut().on_msg(eng, from, msg, &out);
                });
                drained_any = true;
            }
        }
        progress |= drained_any;
        // simlint: allow(SIM002) — pump-boundary wall sampling feeds SchedProfile, outside identity
        let t1 = std::time::Instant::now();

        // 3. Execute the safe region. EIT == ∞ means every peer has
        // finished: nothing can arrive anymore, drain unconditionally.
        let before = self.eng.executed();
        if eit == f64::INFINITY {
            self.eng.run();
        } else {
            self.eng.run_before(eit);
        }
        let ran_any = self.eng.executed() > before;
        progress |= ran_any;
        // simlint: allow(SIM002) — pump-boundary wall sampling feeds SchedProfile, outside identity
        let t2 = std::time::Instant::now();

        // 4. Flush the outbox, THEN publish: queue pushes must
        // happen-before the Release store so a reader observing the new
        // horizon observes every message below it.
        for (to, sent_at, msg) in self.outbox.buf.borrow_mut().drain(..) {
            debug_assert!(to != self.idx, "shard messaging itself");
            let deliver_at = sent_at + self.latency;
            debug_assert!(
                deliver_at >= self.published,
                "send at {sent_at} breaks the published horizon {}",
                self.published
            );
            self.shared.queues[self.idx * self.n + to].lock().unwrap().push_back((deliver_at, msg));
        }

        // Book the round into the scheduler-lane profile before the
        // finish path below hands the engine to `ShardApp::finish` (which
        // is where shard profiles get harvested).
        // simlint: allow(SIM002) — pump-boundary wall sampling feeds SchedProfile, outside identity
        let t3 = std::time::Instant::now();
        {
            let sched = self.eng.sched_mut();
            sched.rounds += 1;
            if !drained_any && !ran_any {
                sched.stalled_rounds += 1;
            }
            sched.host_drain_secs += t1.duration_since(t0).as_secs_f64();
            sched.host_run_secs += t2.duration_since(t1).as_secs_f64();
            sched.host_publish_secs += t3.duration_since(t2).as_secs_f64();
        }

        if self.eng.pending() == 0 && (eit == f64::INFINITY || self.app.borrow().quiescent()) {
            let result = self.app.borrow_mut().finish(&mut self.eng);
            self.out = Some(result);
            self.finished = true;
            self.shared.eot[self.idx].store(f64::INFINITY.to_bits(), Ordering::Release);
            return true;
        }

        let next = self.eng.next_time().unwrap_or(f64::INFINITY);
        let bound = (next.min(eit) + self.latency).max(self.published);
        progress |= bound > self.published;
        self.published = bound;
        self.shared.eot[self.idx].store(bound.to_bits(), Ordering::Release);
        progress
    }
}

/// Drive `pumps` round-robin until all finish. `stall_is_fatal` is set in
/// inline mode, where a full zero-progress pass over every live pump is
/// provably a bug (with L > 0 the horizon lattice must rise); worker
/// threads instead yield, since a thread's local stall just means it is
/// waiting on a peer thread.
fn drive<A: ShardApp>(pumps: &mut [Pump<A>], stall_is_fatal: bool) {
    loop {
        let mut progress = false;
        let mut all_done = true;
        for p in pumps.iter_mut() {
            if p.finished {
                continue;
            }
            progress |= p.round();
            all_done &= p.finished;
        }
        if all_done {
            return;
        }
        if !progress {
            if stall_is_fatal {
                panic!("parallel engine stalled: no shard can make progress");
            }
            std::thread::yield_now();
        }
    }
}

/// Run `factories.len()` shards to completion and return their results in
/// shard-index order.
///
/// `latency` is the lookahead `L` (strictly positive — it is the whole
/// basis of the conservative synchronization). `threads` is clamped to
/// `1..=shards`; `threads == 1` runs every pump inline on the calling
/// thread with **bit-identical** results to any multi-threaded run (see
/// the module docs for why that is structural, not incidental).
pub fn run_sharded<A, F>(latency: SimTime, factories: Vec<F>, threads: usize) -> Vec<A::Out>
where
    A: ShardApp + 'static,
    F: FnOnce() -> A + Send + 'static,
{
    assert!(
        latency.is_finite() && latency > 0.0,
        "conservative sync needs strictly positive finite lookahead, got {latency}"
    );
    let n = factories.len();
    assert!(n > 0, "no shards");
    assert!(n <= 1 << 15, "shard count overflows the message channel tag");
    let shared = Arc::new(Shared {
        eot: (0..n).map(|_| AtomicU64::new(0.0f64.to_bits())).collect(),
        queues: (0..n * n).map(|_| Mutex::new(VecDeque::new())).collect(),
    });
    let threads = threads.clamp(1, n);

    let mut outs: Vec<Option<A::Out>> = (0..n).map(|_| None).collect();
    if threads == 1 {
        let mut pumps: Vec<Pump<A>> = factories
            .into_iter()
            .enumerate()
            .map(|(i, f)| Pump::new(i, n, latency, shared.clone(), f()))
            .collect();
        drive(&mut pumps, true);
        for p in pumps {
            outs[p.idx] = p.out;
        }
    } else {
        // Deal shards round-robin onto workers; each worker builds its
        // pumps locally (the app state is !Send by design) and returns
        // (shard index, result) pairs.
        let mut per_worker: Vec<Vec<(usize, F)>> = (0..threads).map(|_| Vec::new()).collect();
        for (i, f) in factories.into_iter().enumerate() {
            per_worker[i % threads].push((i, f));
        }
        let handles: Vec<_> = per_worker
            .into_iter()
            .map(|mine| {
                let shared = shared.clone();
                std::thread::spawn(move || {
                    let mut pumps: Vec<Pump<A>> = mine
                        .into_iter()
                        .map(|(i, f)| Pump::new(i, n, latency, shared.clone(), f()))
                        .collect();
                    drive(&mut pumps, false);
                    pumps.into_iter().map(|p| (p.idx, p.out)).collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            for (i, out) in h.join().expect("shard worker panicked") {
                outs[i] = out;
            }
        }
    }

    for (k, q) in shared.queues.iter().enumerate() {
        assert!(
            q.lock().unwrap().is_empty(),
            "message from shard {} to finished shard {} was never delivered \
             (quiescent() lied)",
            k / n,
            k % n
        );
    }
    outs.into_iter().map(|o| o.expect("shard finished without a result")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two shards volley a counter back and forth `limit` times; each
    /// logs every delivery time. Exercises both termination paths: the
    /// shard holding the final message finishes via `quiescent`, the
    /// other via the all-peers-finished (EIT == ∞) rule.
    struct PingPong {
        idx: usize,
        limit: u64,
        log: Vec<(SimTime, u64)>,
        done: bool,
    }

    impl ShardApp for PingPong {
        type Msg = u64;
        type Out = Vec<(SimTime, u64)>;

        fn init(&mut self, eng: &mut Engine, out: &Outbox<u64>) {
            if self.idx == 0 {
                out.send(eng, 1, 1);
            }
        }

        fn on_msg(&mut self, eng: &mut Engine, from: usize, msg: u64, out: &Outbox<u64>) {
            self.log.push((eng.now(), msg));
            if msg < self.limit {
                out.send(eng, from, msg + 1);
            } else {
                self.done = true;
            }
        }

        fn quiescent(&self) -> bool {
            self.done
        }

        fn finish(&mut self, _eng: &mut Engine) -> Vec<(SimTime, u64)> {
            std::mem::take(&mut self.log)
        }
    }

    fn ping_pong(threads: usize) -> Vec<Vec<(SimTime, u64)>> {
        let mk = |idx: usize| move || PingPong { idx, limit: 20, log: Vec::new(), done: false };
        run_sharded(0.25, vec![mk(0), mk(1)], threads)
    }

    #[test]
    fn ping_pong_terminates_and_is_thread_count_invariant() {
        let seq = ping_pong(1);
        // Shard 1 sees the odd counters at L, 3L, ...; shard 0 the evens.
        assert_eq!(seq[1][0], (0.25, 1));
        assert_eq!(seq[0][0], (0.5, 2));
        assert_eq!(seq[0].len() + seq[1].len(), 20);
        assert_eq!(seq[1].last(), Some(&(0.25 * 19.0, 19)));
        for threads in [2, 4] {
            assert_eq!(ping_pong(threads), seq, "threads={threads} diverged");
        }
    }

    /// Fan-in at one timestamp: shards 1..=3 each send their id to shard
    /// 0 from a local event at t = 1, so all three deliveries land at
    /// exactly 1 + L. Shard 0 also has its own local event at that very
    /// time. Expected order: the local event first (messages sort after
    /// locals at equal times), then the messages in channel order — on
    /// every thread count.
    struct FanIn {
        idx: usize,
        log: Rc<RefCell<Vec<i64>>>,
        received: usize,
    }

    impl ShardApp for FanIn {
        type Msg = usize;
        type Out = Vec<i64>;

        fn init(&mut self, eng: &mut Engine, out: &Outbox<usize>) {
            if self.idx == 0 {
                let log = self.log.clone();
                eng.schedule_at(1.0 + 0.125, move |_| log.borrow_mut().push(-1));
            } else {
                let idx = self.idx;
                let out = out.clone();
                eng.schedule_at(1.0, move |eng| out.send(eng, 0, idx));
            }
        }

        fn on_msg(&mut self, _eng: &mut Engine, from: usize, msg: usize, _out: &Outbox<usize>) {
            assert_eq!(from, msg);
            self.log.borrow_mut().push(from as i64);
            self.received += 1;
        }

        fn quiescent(&self) -> bool {
            self.idx != 0 || self.received == 3
        }

        fn finish(&mut self, _eng: &mut Engine) -> Vec<i64> {
            self.log.borrow().clone()
        }
    }

    #[test]
    fn equal_time_fanin_orders_local_then_channel() {
        for threads in [1, 2, 4] {
            let outs = run_sharded(
                0.125,
                (0..4)
                    .map(|idx| {
                        move || FanIn { idx, log: Rc::new(RefCell::new(Vec::new())), received: 0 }
                    })
                    .collect::<Vec<_>>(),
                threads,
            );
            assert_eq!(outs[0], vec![-1, 1, 2, 3], "threads={threads}");
        }
    }

    /// A recorder installed in `init` sees one `sync.msg` instant per
    /// delivery, the merged Chrome export is byte-identical across
    /// thread counts, and the pump books scheduler-lane rounds.
    #[test]
    fn pump_emits_sync_msg_instants_and_books_sched_rounds() {
        use crate::trace::{ProfileReport, Recorder, Stream, TraceSpec};
        struct Traced {
            idx: usize,
            limit: u64,
            done: bool,
        }
        impl ShardApp for Traced {
            type Msg = u64;
            type Out = (Recorder, ProfileReport);
            fn init(&mut self, eng: &mut Engine, out: &Outbox<u64>) {
                eng.set_recorder(Recorder::new(&TraceSpec::new()));
                if self.idx == 0 {
                    out.send(eng, 1, 1);
                }
            }
            fn on_msg(&mut self, eng: &mut Engine, from: usize, msg: u64, out: &Outbox<u64>) {
                if msg < self.limit {
                    out.send(eng, from, msg + 1);
                } else {
                    self.done = true;
                }
            }
            fn quiescent(&self) -> bool {
                self.done
            }
            fn finish(&mut self, eng: &mut Engine) -> Self::Out {
                (eng.take_recorder().expect("recorder installed in init"), eng.profile())
            }
        }
        let run = |threads: usize| {
            let mk = |idx: usize| move || Traced { idx, limit: 8, done: false };
            let outs = run_sharded(0.5, vec![mk(0), mk(1)], threads);
            let mut stream = Stream::new(2);
            let mut profile = ProfileReport::default();
            for (rec, p) in outs {
                stream.absorb(rec);
                profile.add(&p);
            }
            (stream.to_chrome_json(), profile)
        };
        let (js1, p1) = run(1);
        assert_eq!(js1.matches("sync.msg").count(), 8, "one instant per delivery");
        assert_eq!(p1.channel_messages, 8);
        assert!(p1.sched.as_ref().expect("pump books sched profile").rounds > 0);
        let (js2, p2) = run(2);
        assert_eq!(js1, js2, "trace bytes diverge across thread counts");
        assert_eq!(p1, p2, "deterministic profile counters diverge");
    }

    #[test]
    fn quiescent_shard_with_no_traffic_finishes() {
        struct Idle;
        impl ShardApp for Idle {
            type Msg = ();
            type Out = u8;
            fn init(&mut self, _eng: &mut Engine, _out: &Outbox<()>) {}
            fn on_msg(&mut self, _e: &mut Engine, _f: usize, _m: (), _o: &Outbox<()>) {
                unreachable!("no one sends to an Idle shard");
            }
            fn quiescent(&self) -> bool {
                true
            }
            fn finish(&mut self, _eng: &mut Engine) -> u8 {
                7
            }
        }
        for threads in [1, 3] {
            let outs = run_sharded(1.0, (0..3).map(|_| || Idle).collect::<Vec<_>>(), threads);
            assert_eq!(outs, vec![7, 7, 7]);
        }
    }
}

//! The scenario registry: named, declarative sets of scenarios.
//!
//! `table1` and `table2` are cross-products over frameworks (× variants,
//! × placements) rather than hand-written drivers, and new sweeps — the
//! §7 `interop` compositions, a scale ladder, a local-vs-wide-area pair,
//! per-site dropout — are one-liner additions. Every set can carry a
//! *shape check*: the paper's reproduction criteria (ordering, ratios,
//! penalty bands) evaluated over the set's [`RunReport`]s.
//!
//! List with `oct scenarios`; run with `oct scenarios <set> [scale]`.

use crate::ops::{AlertKind, FaultPlan, OpsConfig, OpsReport};
use crate::service::{diurnal_phases, flash_crowd_phases, RoutePolicy, ServiceReport, ServiceSpec};

use super::runner::{
    flow_churn_concurrency, mega_churn_concurrency, wide_area_penalty, RunReport, ShapeCheck,
};
use super::scenario::{Framework, Placement, Scenario, Testbed, TopologySpec, Variant, WorkloadSpec};

/// A named group of scenarios with an optional shape check.
#[derive(Debug, Clone)]
pub struct ScenarioSet {
    pub name: &'static str,
    pub description: &'static str,
    pub scenarios: Vec<Scenario>,
    check: Option<fn(&[RunReport]) -> Vec<ShapeCheck>>,
}

impl ScenarioSet {
    /// The set with every workload (and paper reference) divided by `div`.
    pub fn scaled_down(&self, div: u64) -> ScenarioSet {
        ScenarioSet {
            name: self.name,
            description: self.description,
            scenarios: self.scenarios.iter().map(|s| s.scaled_down(div)).collect(),
            check: self.check,
        }
    }

    /// Evaluate the set's shape check over reports produced in scenario
    /// order (empty when the set carries no check).
    pub fn run_checks(&self, reports: &[RunReport]) -> Vec<ShapeCheck> {
        match self.check {
            Some(f) => f(reports),
            None => Vec::new(),
        }
    }

    pub fn has_checks(&self) -> bool {
        self.check.is_some()
    }
}

/// All registered scenario sets, at paper scale.
pub fn scenario_sets() -> Vec<ScenarioSet> {
    vec![
        table1_set(),
        table2_set(),
        interop_set(),
        scale_ladder_set(),
        local_vs_wan_set(),
        site_dropout_set(),
        flow_churn_set(),
        mega_churn_set(),
        ops_set(),
        tenancy_set(),
        service_set(),
    ]
}

/// Registered set names (CLI error messages and docs).
pub fn set_names() -> Vec<&'static str> {
    scenario_sets().iter().map(|s| s.name).collect()
}

/// Look up one set by name.
pub fn find_set(name: &str) -> Option<ScenarioSet> {
    scenario_sets().into_iter().find(|s| s.name == name)
}

fn workload(variant: Variant, records: u64) -> WorkloadSpec {
    match variant {
        Variant::A => WorkloadSpec::malstone_a(records),
        Variant::B => WorkloadSpec::malstone_b(records),
    }
}

/// Table 1: MalStone-A/B × {Hadoop-MR, Hadoop Streams, Sector/Sphere} on
/// 20 OCT nodes (5 per site), 10B records.
fn table1_set() -> ScenarioSet {
    let paper = [
        (Framework::HadoopMr, 454.0 * 60.0 + 13.0, 840.0 * 60.0 + 50.0),
        (Framework::HadoopStreams, 87.0 * 60.0 + 29.0, 142.0 * 60.0 + 32.0),
        (Framework::SectorSphere, 33.0 * 60.0 + 40.0, 43.0 * 60.0 + 44.0),
    ];
    let mut scenarios = Vec::new();
    for (fw, pa, pb) in paper {
        for (variant, psecs) in [(Variant::A, pa), (Variant::B, pb)] {
            scenarios.push(
                Testbed::builder()
                    .topology(TopologySpec::Oct2009)
                    .placement(Placement::PerSite(5))
                    .framework(fw)
                    .workload(workload(variant, 10_000_000_000))
                    .name(&format!("table1/{}/{}", fw.name(), variant.letter()))
                    .paper_secs(psecs)
                    .build(),
            );
        }
    }
    ScenarioSet {
        name: "table1",
        description: "Table 1: MalStone-A/B × three frameworks on 20 OCT nodes (10B records)",
        scenarios,
        check: Some(check_table1),
    }
}

fn check_table1(r: &[RunReport]) -> Vec<ShapeCheck> {
    if r.len() != 6 {
        return vec![ShapeCheck::new(
            "table1 arity",
            false,
            format!("expected 6 reports, got {}", r.len()),
        )];
    }
    let t = |i: usize| r[i].simulated_secs;
    let (mr_a, mr_b, st_a, st_b, sp_a, sp_b) = (t(0), t(1), t(2), t(3), t(4), t(5));
    let mut out = vec![
        ShapeCheck::new(
            "A ordering: sector < streams < hadoop-mr",
            sp_a < st_a && st_a < mr_a,
            format!("{sp_a:.0}s < {st_a:.0}s < {mr_a:.0}s"),
        ),
        ShapeCheck::new(
            "B ordering: sector < streams < hadoop-mr",
            sp_b < st_b && st_b < mr_b,
            format!("{sp_b:.0}s < {st_b:.0}s < {mr_b:.0}s"),
        ),
        ShapeCheck::new(
            "sector speedup over hadoop-mr (A)",
            mr_a / sp_a > 5.0,
            format!("{:.1}× (paper 13.5×)", mr_a / sp_a),
        ),
        ShapeCheck::new(
            "sector speedup over hadoop-mr (B)",
            mr_b / sp_b > 5.0,
            format!("{:.1}× (paper 19.2×)", mr_b / sp_b),
        ),
    ];
    for i in [0usize, 2, 4] {
        out.push(ShapeCheck::new(
            format!("{}: B > A", r[i].framework),
            r[i + 1].simulated_secs > r[i].simulated_secs,
            format!("B {:.0}s vs A {:.0}s", r[i + 1].simulated_secs, r[i].simulated_secs),
        ));
    }
    out
}

/// Table 2: 15B records, 28 nodes in one site vs 7×4 across the testbed;
/// Hadoop at 3 and 1 replicas, and Sector.
fn table2_set() -> ScenarioSet {
    let paper = [
        (Framework::HadoopMr, 8650.0, 11600.0),
        (Framework::HadoopMrR1, 7300.0, 9600.0),
        (Framework::SectorSphere, 4200.0, 4400.0),
    ];
    let mut scenarios = Vec::new();
    for (fw, p_local, p_dist) in paper {
        for (tag, placement, psecs) in [
            ("local", Placement::SingleSite { site: 0, nodes: 28 }, p_local),
            ("dist", Placement::PerSite(7), p_dist),
        ] {
            scenarios.push(
                Testbed::builder()
                    .topology(TopologySpec::Oct2009)
                    .placement(placement)
                    .framework(fw)
                    .workload(WorkloadSpec::malstone_a(15_000_000_000))
                    .name(&format!("table2/{}/{}", fw.name(), tag))
                    .paper_secs(psecs)
                    .build(),
            );
        }
    }
    ScenarioSet {
        name: "table2",
        description: "Table 2: local vs distributed wide-area penalty (15B records, 28 nodes)",
        scenarios,
        check: Some(check_table2),
    }
}

fn check_table2(r: &[RunReport]) -> Vec<ShapeCheck> {
    if r.len() != 6 {
        return vec![ShapeCheck::new(
            "table2 arity",
            false,
            format!("expected 6 reports, got {}", r.len()),
        )];
    }
    let r3 = wide_area_penalty(&r[0], &r[1]);
    let r1 = wide_area_penalty(&r[2], &r[3]);
    let sec = wide_area_penalty(&r[4], &r[5]);
    vec![
        ShapeCheck::new(
            "hadoop 3-replica penalty is large",
            r3 > 0.15,
            format!("{:+.1}% (paper +34.1%)", r3 * 100.0),
        ),
        ShapeCheck::new(
            "hadoop 1-replica penalty is real",
            r1 > 0.04,
            format!("{:+.1}% (paper +31.5%)", r1 * 100.0),
        ),
        ShapeCheck::new(
            "sector penalty is negligible",
            sec.abs() < 0.06,
            format!("{:+.1}% (paper +4.8%)", sec * 100.0),
        ),
        ShapeCheck::new(
            "sector out-penalized by both hadoop rows",
            sec < r1 && sec < r3,
            format!(
                "sector {:+.1}% vs r1 {:+.1}% / r3 {:+.1}%",
                sec * 100.0,
                r1 * 100.0,
                r3 * 100.0
            ),
        ),
        ShapeCheck::new(
            "1-replica hadoop faster than 3-replica",
            r[2].simulated_secs < r[0].simulated_secs && r[3].simulated_secs < r[1].simulated_secs,
            format!("local {:.0}s<{:.0}s dist {:.0}s<{:.0}s",
                r[2].simulated_secs, r[0].simulated_secs, r[3].simulated_secs, r[1].simulated_secs),
        ),
        ShapeCheck::new(
            "sector fastest distributed",
            r[5].simulated_secs < r[3].simulated_secs,
            format!("{:.0}s < {:.0}s", r[5].simulated_secs, r[3].simulated_secs),
        ),
        ShapeCheck::new(
            "distributed runs cross the WAN, local runs do not",
            r[1].wan_bytes > 0.0
                && r[3].wan_bytes > 0.0
                && r[5].wan_bytes > 0.0
                && r[0].wan_bytes == 0.0
                && r[2].wan_bytes == 0.0
                && r[4].wan_bytes == 0.0,
            format!("dist {:.2e}/{:.2e}/{:.2e}B, local {:.0}/{:.0}/{:.0}B",
                r[1].wan_bytes, r[3].wan_bytes, r[5].wan_bytes,
                r[0].wan_bytes, r[2].wan_bytes, r[4].wan_bytes),
        ),
    ]
}

/// The paper's §7 interoperability studies: cross-framework compositions
/// of the shared framework runtime's storage × schedule × exchange
/// layers, bracketed by the two stock stacks. `cloudstore-mr` swaps the
/// storage layer only (Hadoop MapReduce over KFS chunk storage:
/// chunk-lease writes, rack-oblivious placement); `hadoop-over-sector`
/// swaps transport + replication only (MapReduce scheduling over Sector
/// placement with a UDT exchange and single lazy-replicated output).
fn interop_set() -> ScenarioSet {
    let frameworks = [
        Framework::HadoopMr,
        Framework::CloudStoreMr,
        Framework::HadoopOverSector,
        Framework::SectorSphere,
    ];
    let scenarios = frameworks
        .into_iter()
        .map(|fw| {
            Testbed::builder()
                .topology(TopologySpec::Oct2009)
                .placement(Placement::PerSite(5))
                .framework(fw)
                .workload(WorkloadSpec::malstone_a(10_000_000_000))
                .name(&format!("interop/{}", fw.name()))
                .build()
        })
        .collect();
    ScenarioSet {
        name: "interop",
        description: "§7 interop: Hadoop over KFS chunks, MapReduce over Sector+UDT, vs the stock stacks",
        scenarios,
        check: Some(check_interop),
    }
}

fn check_interop(r: &[RunReport]) -> Vec<ShapeCheck> {
    if r.len() != 4 {
        return vec![ShapeCheck::new(
            "interop arity",
            false,
            format!("expected 4 reports, got {}", r.len()),
        )];
    }
    let (mr, kfs, hos, sphere) =
        (r[0].simulated_secs, r[1].simulated_secs, r[2].simulated_secs, r[3].simulated_secs);
    let metric = |rep: &RunReport, k: &str| rep.metric(k).unwrap_or(f64::NAN);
    let storage_ratio = kfs / mr;
    vec![
        ShapeCheck::new(
            "transport+replication swap wins: hadoop-over-sector < hadoop-mr",
            hos < mr,
            format!("{hos:.0}s < {mr:.0}s (UDT exchange + single lazy replica)"),
        ),
        ShapeCheck::new(
            "storage swap is second-order: cloudstore-mr within 0.9-2.5x of hadoop-mr",
            storage_ratio > 0.9 && storage_ratio < 2.5,
            format!("{storage_ratio:.2}x (chunk leases + rack-oblivious placement)"),
        ),
        ShapeCheck::new(
            "the exchange dominates the storage layer: hadoop-over-sector < cloudstore-mr",
            hos < kfs,
            format!("{hos:.0}s < {kfs:.0}s"),
        ),
        ShapeCheck::new(
            "the native stack still wins: sector-sphere fastest",
            sphere < hos && sphere < kfs && sphere < mr,
            format!("{sphere:.0}s vs {hos:.0}/{kfs:.0}/{mr:.0}s"),
        ),
        ShapeCheck::new(
            "per-layer metrics flow into every report",
            r.iter().all(|rep| {
                metric(rep, "storage_read_bytes") > 0.0
                    && metric(rep, "exchange_bytes") > 0.0
                    && metric(rep, "exchange_remote_bytes") <= metric(rep, "exchange_bytes")
                    && metric(rep, "stolen_tasks") >= 0.0
            }),
            "storage_read / exchange (total ≥ remote) / stolen_tasks present".to_string(),
        ),
        ShapeCheck::new(
            "replication shows up in storage writes: kfs(3 replicas) > hadoop-over-sector(1)",
            metric(&r[1], "storage_write_bytes") > 2.0 * metric(&r[2], "storage_write_bytes"),
            format!(
                "{:.2e}B vs {:.2e}B",
                metric(&r[1], "storage_write_bytes"),
                metric(&r[2], "storage_write_bytes")
            ),
        ),
        ShapeCheck::new(
            "every interop run crossed the WAN",
            r.iter().all(|rep| rep.wan_bytes > 0.0),
            format!(
                "{:.2e}/{:.2e}/{:.2e}/{:.2e}B",
                r[0].wan_bytes, r[1].wan_bytes, r[2].wan_bytes, r[3].wan_bytes
            ),
        ),
    ]
}

/// A Sector/Sphere scale ladder on the Table-1 layout: 2.5B → 5B → 10B
/// records. The simulator is shape-preserving in scale, so the ladder
/// should be monotone and roughly linear.
fn scale_ladder_set() -> ScenarioSet {
    let scenarios = [2_500_000_000u64, 5_000_000_000, 10_000_000_000]
        .into_iter()
        .map(|records| {
            Testbed::builder()
                .topology(TopologySpec::Oct2009)
                .placement(Placement::PerSite(5))
                .framework(Framework::SectorSphere)
                .workload(WorkloadSpec::malstone_a(records))
                .name(&format!("scale-ladder/sector-sphere/{}M", records / 1_000_000))
                .build()
        })
        .collect();
    ScenarioSet {
        name: "scale-ladder",
        description: "Sector/Sphere MalStone-A at 2.5B/5B/10B records on 20 nodes (scaling sweep)",
        scenarios,
        check: Some(check_scale_ladder),
    }
}

fn check_scale_ladder(r: &[RunReport]) -> Vec<ShapeCheck> {
    if r.len() != 3 {
        return vec![ShapeCheck::new(
            "ladder arity",
            false,
            format!("expected 3 reports, got {}", r.len()),
        )];
    }
    let (t1, t2, t3) = (r[0].simulated_secs, r[1].simulated_secs, r[2].simulated_secs);
    let ratio = t3 / t1;
    vec![
        ShapeCheck::new(
            "time grows monotonically with scale",
            t1 < t2 && t2 < t3,
            format!("{t1:.0}s < {t2:.0}s < {t3:.0}s"),
        ),
        ShapeCheck::new(
            "4× records cost roughly 4× time",
            ratio > 2.0 && ratio < 8.0,
            format!("{ratio:.1}× for 4× records"),
        ),
    ]
}

/// The wide-area pair Table 2 does not cover: Hadoop Streams local vs
/// distributed. Streams moves its shuffle over TCP too, so it should pay
/// a positive penalty.
fn local_vs_wan_set() -> ScenarioSet {
    let scenarios = [
        ("local", Placement::SingleSite { site: 0, nodes: 28 }),
        ("dist", Placement::PerSite(7)),
    ]
    .into_iter()
    .map(|(tag, placement)| {
        Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(placement)
            .framework(Framework::HadoopStreams)
            .workload(WorkloadSpec::malstone_a(15_000_000_000))
            .name(&format!("local-vs-wan/hadoop-streams/{tag}"))
            .build()
    })
    .collect();
    ScenarioSet {
        name: "local-vs-wan",
        description: "Hadoop Streams local-vs-wide-area pair (the row Table 2 leaves out)",
        scenarios,
        check: Some(check_local_vs_wan),
    }
}

fn check_local_vs_wan(r: &[RunReport]) -> Vec<ShapeCheck> {
    if r.len() != 2 {
        return vec![ShapeCheck::new(
            "pair arity",
            false,
            format!("expected 2 reports, got {}", r.len()),
        )];
    }
    let pen = wide_area_penalty(&r[0], &r[1]);
    vec![
        ShapeCheck::new(
            "streams pays a positive wide-area penalty",
            pen > 0.0,
            format!("{:+.1}%", pen * 100.0),
        ),
        ShapeCheck::new(
            "only the distributed run crosses the WAN",
            r[1].wan_bytes > 0.0 && r[0].wan_bytes == 0.0,
            format!("dist {:.2e}B, local {:.0}B", r[1].wan_bytes, r[0].wan_bytes),
        ),
    ]
}

/// Per-site dropout: the full 7×4 Sector layout vs the same sweep with
/// the UCSD site dropped (21 nodes carrying the same data) — the
/// provisioning question "what does losing a site cost?".
fn site_dropout_set() -> ScenarioSet {
    let scenarios = vec![
        Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(7))
            .framework(Framework::SectorSphere)
            .workload(WorkloadSpec::malstone_a(15_000_000_000))
            .name("site-dropout/sector-sphere/full")
            .build(),
        Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSiteExcept { per_site: 7, excluded_site: 3 })
            .framework(Framework::SectorSphere)
            .workload(WorkloadSpec::malstone_a(15_000_000_000))
            .name("site-dropout/sector-sphere/drop-ucsd")
            .build(),
    ];
    ScenarioSet {
        name: "site-dropout",
        description: "Sector/Sphere with one site dropped: the cost of losing Calit2-UCSD",
        scenarios,
        check: Some(check_site_dropout),
    }
}

fn check_site_dropout(r: &[RunReport]) -> Vec<ShapeCheck> {
    if r.len() != 2 {
        return vec![ShapeCheck::new(
            "dropout arity",
            false,
            format!("expected 2 reports, got {}", r.len()),
        )];
    }
    let ratio = r[1].simulated_secs / r[0].simulated_secs;
    vec![ShapeCheck::new(
        "dropping a site slows the run (more work per node)",
        ratio > 1.05,
        format!(
            "{:.0}s on 21 nodes vs {:.0}s on 28 ({ratio:.2}×)",
            r[1].simulated_secs, r[0].simulated_secs
        ),
    )]
}

/// Fluid-network churn stress: 24k segment/shuffle transfers over the
/// 120-node testbed (30 per site — the paper's active node count), with
/// [`flow_churn_concurrency`] of them in flight at once. At full scale
/// that is 6000 concurrent flows contending for NICs, rack uplinks, and
/// the shared CiscoWave — the load the slab/per-link-index `FlowNet` and
/// the cancellable completion timer exist for. Not a paper table: a
/// substrate scaling scenario (the Sector/Sphere companion experiments
/// run thousands of concurrent segment transfers).
fn flow_churn_set() -> ScenarioSet {
    let scenarios = vec![
        Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(30))
            .framework(Framework::FlowChurn)
            // records = transfers for the churn driver.
            .workload(WorkloadSpec::malstone_a(24_000))
            .name("flow-churn/oct120/24k-transfers")
            .build(),
    ];
    ScenarioSet {
        name: "flow-churn",
        description: "fluid-network churn: 24k transfers, thousands concurrent, on 120 nodes",
        scenarios,
        check: Some(check_flow_churn),
    }
}

fn check_flow_churn(r: &[RunReport]) -> Vec<ShapeCheck> {
    if r.len() != 1 {
        return vec![ShapeCheck::new(
            "churn arity",
            false,
            format!("expected 1 report, got {}", r.len()),
        )];
    }
    let r = &r[0];
    let metric = |k: &str| r.metric(k).unwrap_or(f64::NAN);
    let total = r.total_records;
    let target = flow_churn_concurrency(total) as f64;
    vec![
        ShapeCheck::new(
            "every transfer completed",
            metric("flows") == total as f64 && metric("net_completions") == total as f64,
            format!(
                "{:.0} of {total} transfers, {:.0} network completions",
                metric("flows"),
                metric("net_completions")
            ),
        ),
        ShapeCheck::new(
            // `peak_active` is FlowNet's own exact high-water mark (not
            // the driver's launched−done bookkeeping), so this actually
            // fails if the network serializes the load. Transport setup
            // staggers entry; half the target is the conservative floor
            // for genuinely concurrent flows.
            "network-level concurrency reached the target band",
            metric("peak_active") >= (target / 2.0).max(1.0),
            format!(
                "peak {:.0} flows active in-net (target {target:.0} in flight, observed peak {:.0})",
                metric("peak_active"),
                metric("peak_inflight"),
            ),
        ),
        ShapeCheck::new(
            "churn crossed the WAN",
            r.wan_bytes > 0.0,
            format!("{:.2e} WAN bytes", r.wan_bytes),
        ),
        ShapeCheck::new(
            "simulated time advanced",
            r.simulated_secs > 0.0,
            format!("{:.1}s simulated", r.simulated_secs),
        ),
    ]
}

/// Flow-domain scaling stress: 400k *structured* transfers over the
/// 120-node testbed with [`mega_churn_concurrency`] of them — ~100k —
/// in flight at once. Unlike `flow-churn`'s all-pairs storm, every
/// concurrency slot is pinned to a disjoint intra-rack partner pair
/// (plus a thin cross-site stream on the shared wave), so each arrival
/// or departure touches a two-link flow component no matter how many
/// flows are in the air. Not a paper table: the substrate scenario
/// behind the incremental water-filling + same-path aggregation
/// refactor, and the workload the `flow_scale` bench replays against
/// the pre-refactor global reallocator.
fn mega_churn_set() -> ScenarioSet {
    let scenarios = vec![
        Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(30))
            .framework(Framework::MegaChurn)
            // records = transfers for the churn driver.
            .workload(WorkloadSpec::malstone_a(400_000))
            .name("mega-churn/oct120/400k-transfers")
            .build(),
    ];
    ScenarioSet {
        name: "mega-churn",
        description: "flow domains at scale: 400k structured transfers, ~100k concurrent, on 120 nodes",
        scenarios,
        check: Some(check_mega_churn),
    }
}

fn check_mega_churn(r: &[RunReport]) -> Vec<ShapeCheck> {
    if r.len() != 1 {
        return vec![ShapeCheck::new(
            "mega-churn arity",
            false,
            format!("expected 1 report, got {}", r.len()),
        )];
    }
    let r = &r[0];
    let metric = |k: &str| r.metric(k).unwrap_or(f64::NAN);
    let total = r.total_records;
    let target = mega_churn_concurrency(total) as f64;
    vec![
        ShapeCheck::new(
            "every transfer completed",
            metric("flows") == total as f64 && metric("net_completions") == total as f64,
            format!(
                "{:.0} of {total} transfers, {:.0} network completions",
                metric("flows"),
                metric("net_completions")
            ),
        ),
        ShapeCheck::new(
            // `peak_active` counts flows (aggregate members), tracked by
            // the net itself; transport setup staggers entry, so half the
            // slot target is the conservative concurrency floor.
            "network-level concurrency reached the target band",
            metric("peak_active") >= (target / 2.0).max(1.0),
            format!(
                "peak {:.0} flows active in-net (target {target:.0} slots, observed peak {:.0})",
                metric("peak_active"),
                metric("peak_inflight"),
            ),
        ),
        ShapeCheck::new(
            "the WAN slots crossed the wave",
            r.wan_bytes > 0.0,
            format!("{:.2e} WAN bytes", r.wan_bytes),
        ),
        ShapeCheck::new(
            "simulated time advanced",
            r.simulated_secs > 0.0,
            format!("{:.1}s simulated", r.simulated_secs),
        ),
    ]
}

/// The operations-plane family: closed-loop failure handling under the
/// in-band monitoring pipeline. Four scenarios, one axis each:
///
/// 1. **crash-rerun** — MalStone-A on Hadoop with a mid-map-phase node
///    crash: silence → `Suspect` → `Dead` → drain + re-execute, and the
///    job still completes.
/// 2. **healthy** — the fault-free twin: the false-positive and
///    telemetry-overhead baseline (and the "what did the crash cost?"
///    reference time).
/// 3. **lightpath-flap** — the shared wave drops to 5% mid-run; the
///    aggregators' capacity probes catch it and remediation re-provisions
///    the wave to nominal (dynamic lightpath provisioning, §2.1).
/// 4. **nic-straggler** — one node's NIC degrades under a flow-churn
///    load; the central detectors flag it as a straggler (paper §8's
///    "one or two nodes with slightly inferior performance").
fn ops_set() -> ScenarioSet {
    let scenarios = vec![
        Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(5))
            .framework(Framework::HadoopMr)
            .workload(WorkloadSpec::malstone_a(10_000_000_000))
            // Node 7 (site 1, not an aggregator) dies ~7% into the run —
            // well inside job 1's map phase at every scale.
            .faults(FaultPlan::new().node_crash(2000.0, 7))
            .name("ops/crash-rerun/hadoop-mr")
            .build(),
        Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(5))
            .framework(Framework::HadoopMr)
            .workload(WorkloadSpec::malstone_a(10_000_000_000))
            .ops(OpsConfig::default())
            .name("ops/healthy/hadoop-mr")
            .build(),
        Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(5))
            .framework(Framework::SectorSphere)
            .workload(WorkloadSpec::malstone_a(10_000_000_000))
            .faults(FaultPlan::new().lightpath_flap(300.0, 0.05))
            .name("ops/lightpath-flap/sector-sphere")
            .build(),
        Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(5))
            .framework(Framework::FlowChurn)
            // records = transfers for the churn driver.
            .workload(WorkloadSpec::malstone_a(240_000))
            .faults(FaultPlan::new().nic_degrade(500.0, 3, 0.15))
            .name("ops/nic-straggler/flow-churn")
            .build(),
    ];
    ScenarioSet {
        name: "ops",
        description: "operations plane: crash→detect→drain→re-execute, lightpath self-healing, straggler flagging",
        scenarios,
        check: Some(check_ops),
    }
}

fn check_ops(r: &[RunReport]) -> Vec<ShapeCheck> {
    if r.len() != 4 {
        return vec![ShapeCheck::new(
            "ops arity",
            false,
            format!("expected 4 reports, got {}", r.len()),
        )];
    }
    let (crash, healthy, flap, churn) = (&r[0], &r[1], &r[2], &r[3]);
    fn ops(rep: &RunReport) -> &OpsReport {
        rep.ops.as_ref().expect("ops scenario without ops report")
    }
    let has = |rep: &RunReport, kind: AlertKind| ops(rep).alerts.iter().any(|a| a.kind == kind);
    let co = ops(crash);
    let ho = ops(healthy);
    let bound = 8.0 * co.heartbeat_interval;
    vec![
        ShapeCheck::new(
            "malstone-A completes despite a mid-run node crash",
            crash.simulated_secs > 0.0 && crash.metric("job2_makespan").is_some(),
            format!("{:.0}s simulated, both chained jobs reported", crash.simulated_secs),
        ),
        ShapeCheck::new(
            "exactly the crashed node is declared dead; the healthy twin sees none",
            co.crashed_nodes == 1
                && co.dead_declared == 1
                && co.false_dead == 0
                && ho.dead_declared == 0
                && ho.false_dead == 0,
            format!(
                "crash run {}/{} dead (false {}), healthy run {} dead",
                co.dead_declared, co.crashed_nodes, co.false_dead, ho.dead_declared
            ),
        ),
        ShapeCheck::new(
            "detection latency bounded by k·heartbeat",
            co.detection_latency_max > 0.0 && co.detection_latency_max <= bound,
            format!(
                "{:.1}s ≤ {bound:.1}s (missed-beat thresholds + relay + sweep)",
                co.detection_latency_max
            ),
        ),
        ShapeCheck::new(
            "the dead worker's lost tasks re-execute on survivors",
            co.reexecuted_tasks >= 1
                && crash.metric("reexecuted_tasks").unwrap_or(0.0) >= 1.0
                && co.remediation_ops >= 1,
            format!(
                "{} task(s) re-executed, {} remediation op(s)",
                co.reexecuted_tasks, co.remediation_ops
            ),
        ),
        ShapeCheck::new(
            "losing a node costs time: crash run slower than its healthy twin",
            crash.simulated_secs > healthy.simulated_secs,
            format!("{:.0}s vs {:.0}s", crash.simulated_secs, healthy.simulated_secs),
        ),
        ShapeCheck::new(
            "telemetry is real WAN traffic but ≪ workload WAN bytes",
            [crash, healthy].iter().all(|rep| {
                let o = ops(rep);
                o.telemetry_wan_bytes > 0.0 && o.telemetry_wan_bytes < 0.01 * rep.wan_bytes
            }),
            format!(
                "crash {:.2e}B of {:.2e}B, healthy {:.2e}B of {:.2e}B",
                co.telemetry_wan_bytes, crash.wan_bytes, ho.telemetry_wan_bytes, healthy.wan_bytes
            ),
        ),
        ShapeCheck::new(
            "lightpath flap detected and self-healed mid-run",
            has(flap, AlertKind::WanDegraded)
                && has(flap, AlertKind::WanRestored)
                && ops(flap).remediation_ops >= 1
                && flap.simulated_secs > 0.0,
            format!(
                "{} alert(s), {} remediation op(s), {:.0}s simulated",
                ops(flap).alerts.len(),
                ops(flap).remediation_ops,
                flap.simulated_secs
            ),
        ),
        ShapeCheck::new(
            // PerSite(5) on the 2009 testbed: placed index 3 is node003.
            "the degraded NIC is flagged as a straggler by name",
            ops(churn)
                .alerts
                .iter()
                .any(|a| a.kind == AlertKind::Straggler && a.subject == "node003"),
            format!(
                "straggler alerts: {:?}",
                ops(churn)
                    .alerts
                    .iter()
                    .filter(|a| a.kind == AlertKind::Straggler)
                    .map(|a| a.subject.as_str())
                    .collect::<Vec<_>>()
            ),
        ),
        ShapeCheck::new(
            "churn completes every transfer under the degraded NIC",
            churn.metric("flows") == Some(churn.total_records as f64)
                && ops(churn).dead_declared == 0,
            format!(
                "{:.0} of {} transfers, {} dead declared",
                churn.metric("flows").unwrap_or(f64::NAN),
                churn.total_records,
                ops(churn).dead_declared
            ),
        ),
    ]
}

/// The dynamic-provisioning / multi-tenancy family: the abstract's
/// "flexible compute node and network provisioning capabilities" as a
/// measured scenario axis. Eight scenarios in three movements:
///
/// 1. **solo baselines** — Sphere MalStone-A on a freshly-imaged slice
///    behind a full 10 Gb/s lightpath grant, the same behind an
///    under-provisioned 0.5 Gb/s grant (setup latency identical, only
///    the spectrum differs), and a solo segment-transfer storm on the
///    shared wave.
/// 2. **dedicated waves** — tenants alice and bob run the Sphere
///    workload *concurrently* on disjoint slices of one testbed, each
///    behind its own wave: isolation means each stays within band of
///    the solo run. Tenant eve asks for a third 10 Gb/s grant the spare
///    spectrum cannot cover and queues until a release — admission
///    control against finite inventory, measured as `queued_secs`.
/// 3. **shared wave** — tenants carol and dave run the transfer storm
///    concurrently over the *same* default wave: measurable
///    interference against the solo storm.
///
/// Every run pays a measured provisioning phase (4 GB image fetched
/// from site depots + install + lightpath signalling) reported as
/// `imaging_secs` / `lightpath_setup_secs` / `provision_secs` metrics;
/// shape checks compare `workload_secs` so provisioning and admission
/// wait never pollute the throughput comparisons.
fn tenancy_set() -> ScenarioSet {
    let image = ("oct-malstone-2.4", 4.0);
    let sphere = |name: &str| {
        Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(5))
            .framework(Framework::SectorSphere)
            .workload(WorkloadSpec::malstone_a(10_000_000_000))
            .image(image.0, image.1)
            .name(name)
    };
    let churn = |name: &str| {
        Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(10))
            .framework(Framework::FlowChurn)
            // records = transfers for the churn driver: enough in flight
            // (total/4 per tenant) to keep 40 NICs saturated, so the
            // shared wave — not the edge — is the binding resource and
            // wave interference is measurable.
            .workload(WorkloadSpec::malstone_a(240_000))
            .image(image.0, image.1)
            .name(name)
    };
    let scenarios = vec![
        sphere("tenancy/solo/sphere-full").lightpath(10.0).build(),
        sphere("tenancy/solo/sphere-thin").lightpath(0.5).build(),
        churn("tenancy/solo/churn").build(),
        sphere("tenancy/tenant/alice").lightpath(10.0).tenant("alice", 0).build(),
        sphere("tenancy/tenant/bob").lightpath(10.0).tenant("bob", 0).build(),
        sphere("tenancy/tenant/eve").lightpath(10.0).tenant("eve", 0).build(),
        churn("tenancy/tenant/carol").tenant("carol", 1).build(),
        churn("tenancy/tenant/dave").tenant("dave", 1).build(),
    ];
    ScenarioSet {
        name: "tenancy",
        description: "provisioning + slices: imaging/lightpath latency, queued admission, wave isolation vs interference",
        scenarios,
        check: Some(check_tenancy),
    }
}

fn check_tenancy(r: &[RunReport]) -> Vec<ShapeCheck> {
    if r.len() != 8 {
        return vec![ShapeCheck::new(
            "tenancy arity",
            false,
            format!("expected 8 reports, got {}", r.len()),
        )];
    }
    let m = |i: usize, k: &str| r[i].metric(k).unwrap_or(f64::NAN);
    let wl = |i: usize| m(i, "workload_secs");
    let overlap = |a: usize, b: usize| {
        m(a, "started_secs") < r[b].simulated_secs && m(b, "started_secs") < r[a].simulated_secs
    };
    let iso_lo = 0.75;
    let iso_hi = 1.3;
    vec![
        ShapeCheck::new(
            "every run pays a measured imaging phase",
            (0..8).all(|i| m(i, "imaging_secs") > 0.0 && m(i, "provision_secs") > 0.0),
            format!(
                "imaging {:.0}s..{:.0}s before any workload byte moves",
                (0..8).map(|i| m(i, "imaging_secs")).fold(f64::INFINITY, f64::min),
                (0..8).map(|i| m(i, "imaging_secs")).fold(0.0, f64::max)
            ),
        ),
        ShapeCheck::new(
            "lightpath grants pay their signalling latency",
            [0usize, 1, 3, 4, 5].iter().all(|&i| m(i, "lightpath_setup_secs") > 0.0)
                && m(2, "lightpath_setup_secs") == 0.0,
            format!(
                "setup {:.0}s on granted runs, 0 on the shared-wave storm",
                m(0, "lightpath_setup_secs")
            ),
        ),
        ShapeCheck::new(
            "an under-provisioned wave costs time: 0.5 Gb/s > 1.2x the 10 Gb/s run",
            wl(1) > 1.2 * wl(0),
            format!("{:.0}s vs {:.0}s ({:.2}x)", wl(1), wl(0), wl(1) / wl(0)),
        ),
        ShapeCheck::new(
            "concurrent tenant runs complete and overlap in time",
            (3..8).all(|i| wl(i) > 0.0) && overlap(3, 4) && overlap(6, 7),
            format!(
                "alice {:.0}s/bob {:.0}s and carol {:.0}s/dave {:.0}s ran concurrently",
                wl(3), wl(4), wl(6), wl(7)
            ),
        ),
        ShapeCheck::new(
            "disjoint waves isolate: each dedicated tenant within band of the solo run",
            wl(3) > iso_lo * wl(0)
                && wl(3) < iso_hi * wl(0)
                && wl(4) > iso_lo * wl(0)
                && wl(4) < iso_hi * wl(0),
            format!(
                "alice {:.2}x, bob {:.2}x of solo {:.0}s (band {iso_lo}-{iso_hi})",
                wl(3) / wl(0),
                wl(4) / wl(0),
                wl(0)
            ),
        ),
        ShapeCheck::new(
            "spectrum is finite: eve queues until a wave frees, then completes",
            m(5, "queued_secs") > 0.0
                && m(3, "queued_secs") == 0.0
                && m(4, "queued_secs") == 0.0
                && wl(5) > 0.0,
            format!(
                "eve queued {:.0}s for a 10 Gb/s grant from a 20 Gb/s spare pool",
                m(5, "queued_secs")
            ),
        ),
        ShapeCheck::new(
            "a shared wave interferes: each storm tenant > 1.15x the solo storm",
            wl(6) > 1.15 * wl(2) && wl(7) > 1.15 * wl(2),
            format!(
                "carol {:.2}x, dave {:.2}x of solo {:.0}s",
                wl(6) / wl(2), wl(7) / wl(2), wl(2)
            ),
        ),
        ShapeCheck::new(
            "the storms completed every transfer",
            [2usize, 6, 7].iter().all(|&i| r[i].metric("flows") == Some(r[i].total_records as f64)),
            format!(
                "{:.0}/{:.0}/{:.0} transfers",
                m(2, "flows"), m(6, "flows"), m(7, "flows")
            ),
        ),
    ]
}

/// The user-facing service family: an open-loop, trace-driven
/// request/response workload against replicas of one service placed
/// across the testbed's sites (records = requests). Seven scenarios in
/// three movements:
///
/// 1. **arrival shapes** — `steady` (constant rate, nearest routing,
///    replicas everywhere: every request stays on its home site),
///    `diurnal` (one sinusoidal day compressed into the run, random
///    routing so the wave carries a steady share), and `flash` (an 8×
///    burst over the middle tenth of the run: the open-loop generator
///    keeps offering load no matter how the service keeps up).
/// 2. **wan-degraded** — two replicas behind a 50/50 weighted router
///    while site 1's wave access degrades: remote requests touching the
///    degraded site pay a fixed per-leg penalty, blowing through the SLO
///    and (in the tail) the retry timeout.
/// 3. **replica ladder** — the same demand against 1, 2, and 4 replica
///    sites: fewer replicas mean more WAN hops and a fatter latency
///    distribution.
fn service_set() -> ScenarioSet {
    let base = |name: &str, spec: ServiceSpec| {
        Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(30))
            .framework(Framework::Service)
            // records = requests for the service driver.
            .workload(WorkloadSpec::malstone_a(2_000_000))
            .service(spec)
            .name(name)
            .build()
    };
    let all = vec![0u32, 1, 2, 3];
    let mut diurnal = ServiceSpec::new(all.clone(), RoutePolicy::Random);
    diurnal.phases = diurnal_phases();
    let mut flash = ServiceSpec::new(all.clone(), RoutePolicy::Nearest);
    flash.phases = flash_crowd_phases();
    let mut degraded = ServiceSpec::new(vec![0, 1], RoutePolicy::Weighted(vec![1.0, 1.0]));
    degraded.degraded_wan_site = Some(1);
    let scenarios = vec![
        base("service/steady", ServiceSpec::new(all.clone(), RoutePolicy::Nearest)),
        base("service/diurnal", diurnal),
        base("service/flash", flash),
        base("service/wan-degraded", degraded),
        base("service/r1", ServiceSpec::new(vec![0], RoutePolicy::Nearest)),
        base("service/r2", ServiceSpec::new(vec![0, 1], RoutePolicy::Nearest)),
        base("service/r4", ServiceSpec::new(all, RoutePolicy::Nearest)),
    ];
    ScenarioSet {
        name: "service",
        description: "open-loop service traffic: steady/diurnal/flash arrivals, degraded WAN, replica ladder",
        scenarios,
        check: Some(check_service),
    }
}

fn check_service(r: &[RunReport]) -> Vec<ShapeCheck> {
    if r.len() != 7 {
        return vec![ShapeCheck::new(
            "service arity",
            false,
            format!("expected 7 reports, got {}", r.len()),
        )];
    }
    fn svc(rep: &RunReport) -> &ServiceReport {
        rep.service.as_ref().expect("service scenario without service report")
    }
    let (steady, flash, degraded) = (svc(&r[0]), svc(&r[2]), svc(&r[3]));
    let (r1, r4) = (svc(&r[4]), svc(&r[6]));
    let slo_frac = |s: &ServiceReport| s.slo_violations as f64 / s.requests as f64;
    vec![
        ShapeCheck::new(
            "every request is accounted for (completed = requests + retries)",
            r.iter().all(|rep| {
                let s = svc(rep);
                s.requests > 0
                    && s.completed == s.requests + s.retries
                    && s.sites.iter().map(|site| site.requests).sum::<u64>() == s.requests
            }),
            format!(
                "{} requests across the set",
                r.iter().map(|rep| svc(rep).requests).sum::<u64>()
            ),
        ),
        ShapeCheck::new(
            "latency quantiles are ordered: 0 < p50 <= p99 <= p999",
            r.iter().all(|rep| {
                let s = svc(rep);
                s.p50_ms > 0.0 && s.p50_ms <= s.p99_ms && s.p99_ms <= s.p999_ms
            }),
            format!("steady p50/p99/p999 {:.1}/{:.1}/{:.1}ms",
                steady.p50_ms, steady.p99_ms, steady.p999_ms),
        ),
        ShapeCheck::new(
            "goodput flows and simulated time advances in every run",
            r.iter().all(|rep| svc(rep).goodput_rps > 0.0 && rep.simulated_secs > 0.0),
            format!("steady {:.0} req/s over {:.1}s", steady.goodput_rps, r[0].simulated_secs),
        ),
        ShapeCheck::new(
            "retries fire exactly once per timeout",
            r.iter().all(|rep| svc(rep).retries == svc(rep).timeouts),
            format!(
                "{} timeouts / {} retries across the set",
                r.iter().map(|rep| svc(rep).timeouts).sum::<u64>(),
                r.iter().map(|rep| svc(rep).retries).sum::<u64>()
            ),
        ),
        ShapeCheck::new(
            "the flash crowd concentrates offered load",
            flash.offered_peak_x > 1.5 * steady.offered_peak_x,
            format!("peak {:.1}x mean vs steady {:.1}x", flash.offered_peak_x,
                steady.offered_peak_x),
        ),
        ShapeCheck::new(
            "a degraded wave blows the SLO; the steady run barely misses it",
            slo_frac(degraded) > 0.05 && slo_frac(steady) < 0.01,
            format!(
                "degraded {:.1}% vs steady {:.3}% past the SLO",
                slo_frac(degraded) * 100.0,
                slo_frac(steady) * 100.0
            ),
        ),
        ShapeCheck::new(
            "nearest routing with replicas everywhere never crosses the WAN; one replica does",
            r[0].wan_bytes == 0.0 && r[4].wan_bytes > 0.0,
            format!("steady {:.0}B vs r1 {:.2e}B on the wave", r[0].wan_bytes, r[4].wan_bytes),
        ),
        ShapeCheck::new(
            "the replica ladder pays for distance: r1 median above r4's",
            r1.p50_ms > r4.p50_ms,
            format!("{:.1}ms on 1 replica vs {:.1}ms on 4", r1.p50_ms, r4.p50_ms),
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::runner::{all_pass, ScenarioRunner};

    // Scaled-down runs keep the event count small while preserving shape.
    const SCALE: u64 = 200;

    fn run_set(name: &str, div: u64) -> (ScenarioSet, Vec<RunReport>) {
        let set = find_set(name).unwrap().scaled_down(div);
        let reports = ScenarioRunner::new().run_all(&set.scenarios);
        (set, reports)
    }

    fn assert_checks_pass(set: &ScenarioSet, reports: &[RunReport]) {
        let checks = set.run_checks(reports);
        assert!(!checks.is_empty());
        for c in &checks {
            assert!(c.pass, "{}: {}", c.name, c.detail);
        }
        assert!(all_pass(&checks));
    }

    #[test]
    fn table1_shape_holds() {
        let (set, reports) = run_set("table1", SCALE);
        assert_eq!(reports.len(), 6);
        assert_checks_pass(&set, &reports);
    }

    #[test]
    fn table2_shape_holds() {
        let (set, reports) = run_set("table2", SCALE);
        assert_eq!(reports.len(), 6);
        assert_checks_pass(&set, &reports);
    }

    #[test]
    fn scale_ladder_is_monotone() {
        let (set, reports) = run_set("scale-ladder", SCALE);
        assert_checks_pass(&set, &reports);
    }

    #[test]
    fn new_pair_sets_hold_shape() {
        let (set, reports) = run_set("local-vs-wan", 500);
        assert_checks_pass(&set, &reports);
        let (set, reports) = run_set("site-dropout", 500);
        assert_checks_pass(&set, &reports);
    }

    #[test]
    fn interop_shape_holds() {
        let (set, reports) = run_set("interop", SCALE);
        assert_eq!(reports.len(), 4);
        assert_eq!(reports[1].framework, "cloudstore-mr");
        assert_eq!(reports[2].framework, "hadoop-over-sector");
        assert_checks_pass(&set, &reports);
    }

    #[test]
    fn flow_churn_shape_holds() {
        // 1/100 scale: 240 transfers, 60 concurrent, on all 120 nodes.
        let (set, reports) = run_set("flow-churn", 100);
        assert_eq!(reports[0].nodes, 120);
        assert_checks_pass(&set, &reports);
    }

    #[test]
    fn mega_churn_shape_holds() {
        // 1/500 scale: 800 transfers, 200 slots in flight, on all 120
        // nodes — the structured pair/WAN mix at a debug-friendly size.
        let (set, reports) = run_set("mega-churn", 500);
        assert_eq!(reports[0].nodes, 120);
        assert_checks_pass(&set, &reports);
    }

    #[test]
    fn ops_shape_holds() {
        // 1/100 scale: the crash lands at t=20s, comfortably inside the
        // ~76s map phase; the flap at t=3s inside the ~20s sphere run.
        let (set, reports) = run_set("ops", 100);
        assert_eq!(reports.len(), 4);
        assert!(reports.iter().all(|r| r.ops.is_some()));
        assert_checks_pass(&set, &reports);
    }

    #[test]
    fn tenancy_shape_holds() {
        // 1/100 scale — exactly what `oct scenarios tenancy 100` runs.
        let set = find_set("tenancy").unwrap().scaled_down(100);
        let reports = ScenarioRunner::new().run_set(&set);
        assert_eq!(reports.len(), 8);
        // Reports come back in scenario order even though the tenant
        // groups execute concurrently after the solos.
        for (sc, rep) in set.scenarios.iter().zip(&reports) {
            assert_eq!(sc.name, rep.scenario);
        }
        assert_checks_pass(&set, &reports);
    }

    #[test]
    fn service_shape_holds() {
        // 1/200 scale: 10k requests per scenario across all seven
        // service scenarios on the full 120-node testbed.
        let (set, reports) = run_set("service", SCALE);
        assert_eq!(reports.len(), 7);
        assert_eq!(reports[0].nodes, 120);
        assert!(reports.iter().all(|r| r.service.is_some()));
        assert_checks_pass(&set, &reports);
    }

    #[test]
    fn registry_lists_expected_sets() {
        let names: Vec<&str> = set_names();
        for expect in [
            "table1",
            "table2",
            "interop",
            "scale-ladder",
            "local-vs-wan",
            "site-dropout",
            "flow-churn",
            "mega-churn",
            "ops",
            "tenancy",
            "service",
        ] {
            assert!(names.contains(&expect), "missing set {expect}");
        }
        assert!(find_set("no-such-set").is_none());
        // Scaling a set scales every scenario and its paper reference.
        let t1 = find_set("table1").unwrap().scaled_down(100);
        assert_eq!(t1.scenarios[0].workload.total_records, 100_000_000);
        assert!(t1.scenarios[0].paper_secs.unwrap() < 300.0);
        assert!(t1.has_checks());
    }
}

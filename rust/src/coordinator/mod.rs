//! The OCT coordinator: testbed configuration, node/network provisioning,
//! and the experiment runner that regenerates the paper's tables.
//!
//! - [`config`]: a dependency-free TOML-subset parser for testbed and
//!   experiment configs (`examples/*.toml` style).
//! - [`provision`]: the paper's "flexible compute node and network
//!   provisioning" service — grow the testbed (§2.2's expansion to ~250
//!   nodes), retune links, drain nodes.
//! - [`experiment`]: Table 1 / Table 2 drivers plus the correctness
//!   harness that cross-checks every engine against the oracle and the
//!   AOT kernel path.

pub mod config;
pub mod experiment;
pub mod provision;

pub use config::Config;
pub use experiment::{run_table1, run_table2, Table1Row, Table2Row};
pub use provision::Provisioner;

//! The OCT coordinator: testbed configuration, node/network provisioning,
//! and the unified scenario API every experiment runs through.
//!
//! - [`config`]: a dependency-free TOML-subset parser for testbed and
//!   experiment configs (`examples/*.toml` style).
//! - [`provision`]: the paper's "flexible compute node and network
//!   provisioning" service — grow the testbed (§2.2's expansion to ~250
//!   nodes), retune links, drain nodes, stamp node images, provision and
//!   tear down lightpaths, and carve tenant slices, all as a replayable
//!   [`Op`] log; [`SliceScheduler`] admits or queues slice requests
//!   against the finite inventory.
//! - [`scenario`]: describe an experiment as data — [`Testbed::builder`]
//!   yields a [`Scenario`] from a topology spec, a placement, a
//!   framework, and a MalStone workload, plus a provisioning axis
//!   ([`ImageSpec`], [`LightpathSpec`]) and a tenancy marker
//!   ([`TenantSpec`]).
//! - [`runner`]: [`ScenarioRunner`] executes any scenario on the
//!   simulated substrate and returns a structured, JSON-serializable
//!   [`RunReport`] (simulated seconds, per-site flow stats, monitor
//!   summary, paper reference; ops-enabled runs add an
//!   [`crate::ops::OpsReport`] with detection latency, telemetry
//!   overhead, and the alert log; provisioned runs pay measured imaging
//!   and lightpath-setup latency before the workload starts). Scenarios
//!   may carry a [`crate::ops::FaultPlan`] — node crashes, NIC
//!   degradations, lightpath flaps — applied mid-run through the live
//!   substrate hooks, with the [`crate::ops`] plane detecting and
//!   self-healing. [`ScenarioRunner::run_tenants`] runs a group of
//!   tenant scenarios concurrently on one shared testbed, each on its
//!   own slice.
//! - [`registry`]: named [`ScenarioSet`]s — `table1`/`table2` as
//!   declarative cross-products plus sweeps (the §7 `interop`
//!   compositions, scale ladder, local-vs-wide-area, site dropout,
//!   multi-tenant `tenancy`, and the open-loop `service` request/response
//!   family with SLO shape checks) with shape checks.
//! - [`experiment`]: paper-style table presentation over registry
//!   reports ([`table1_rows`]/[`table2_rows`] + formatters).
//!
//! # The scenario API
//!
//! ```
//! use oct::coordinator::{Framework, ScenarioRunner, Testbed, TopologySpec, WorkloadSpec};
//!
//! let scenario = Testbed::builder()
//!     .topology(TopologySpec::Oct2009)
//!     .framework(Framework::SectorSphere)
//!     .workload(WorkloadSpec::malstone_a(2_000_000))
//!     .name("doc-smoke")
//!     .build();
//! let report = ScenarioRunner::new().run(&scenario);
//! assert!(report.simulated_secs > 0.0);
//! assert_eq!(report.framework, "sector-sphere");
//! ```

pub mod config;
pub mod experiment;
pub mod provision;
pub mod registry;
pub mod runner;
pub mod scenario;

pub use config::Config;
pub use experiment::{format_table1, format_table2, table1_rows, table2_rows, Table1Row, Table2Row};
pub use provision::{
    Lightpath, Op, Provisioner, Slice, SliceRecord, SliceScheduler, DEFAULT_SPARE_WAVE_GBPS,
    LIGHTPATH_FLOOR_BPS,
};
pub use registry::{find_set, scenario_sets, set_names, ScenarioSet};
pub use runner::{
    all_pass, flow_churn_concurrency, format_checks, format_reports, mega_churn_concurrency,
    wide_area_penalty, MonitorSummary, RunReport, ScenarioRunner, ShapeCheck, SiteFlow, WallStats,
};
pub use scenario::{
    Framework, ImageSpec, LightpathSpec, Placement, ProvisioningSpec, Scenario, TenantSpec,
    Testbed, TestbedBuilder, TopologySpec, Variant, WorkloadSpec,
};

//! The unified scenario API: describe *what* to run — topology, node
//! placement, framework, workload — as plain data, then hand the
//! [`Scenario`] to a [`crate::coordinator::runner::ScenarioRunner`].
//!
//! Every experiment in the repo (Tables 1–2, the benches, the examples,
//! the integration tests, and the new registry sweeps) is a `Scenario`
//! built through [`Testbed::builder`]; nothing hand-wires topology +
//! framework + workload anymore.

use std::fmt;
use std::rc::Rc;

use crate::hadoop::FrameworkParams;
use crate::net::{NodeId, Topology};
use crate::ops::{FaultPlan, OpsConfig};
use crate::service::ServiceSpec;
use crate::trace::TraceSpec;

/// How to build the physical testbed for a run.
#[derive(Clone)]
pub enum TopologySpec {
    /// Figure 2: the four-site, 128-node 2009 testbed on the CiscoWave.
    Oct2009,
    /// Builder sugar over the same physical testbed: defaults the
    /// placement to 28 nodes on one site (the "local" half of a
    /// wide-area-penalty pair). An explicit `.placement(..)` wins, so
    /// the *placement* label — not this spec — records locality.
    Local { site: usize },
    /// Any topology: the builder closure runs once per scenario run.
    Custom(Rc<dyn Fn() -> Topology>),
}

impl TopologySpec {
    /// Materialize the topology.
    pub fn build(&self) -> Topology {
        match self {
            TopologySpec::Oct2009 | TopologySpec::Local { .. } => Topology::oct_2009(),
            TopologySpec::Custom(f) => f(),
        }
    }

    /// Short label for reports. `Local` labels as the physical testbed
    /// it builds — locality is a placement property, and labeling it
    /// here would misdescribe runs whose placement was overridden.
    pub fn label(&self) -> String {
        match self {
            TopologySpec::Oct2009 | TopologySpec::Local { .. } => "oct-2009".to_string(),
            TopologySpec::Custom(_) => "custom".to_string(),
        }
    }
}

impl fmt::Debug for TopologySpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

/// Which nodes of the topology host data and compute.
#[derive(Clone)]
pub enum Placement {
    /// The first `n` nodes of every site (Table 1's 5×4, Table 2's 7×4).
    PerSite(usize),
    /// The first `nodes` nodes of one site (Table 2's 28-local runs).
    SingleSite { site: usize, nodes: usize },
    /// Per-site placement with one site dropped — the site-dropout sweep.
    PerSiteExcept { per_site: usize, excluded_site: usize },
    /// Any selection rule.
    Custom(Rc<dyn Fn(&Topology) -> Vec<NodeId>>),
}

impl Placement {
    /// Resolve the placement against a topology.
    pub fn select(&self, topo: &Topology) -> Vec<NodeId> {
        match self {
            Placement::PerSite(n) => Self::per_site(topo, *n, None),
            Placement::PerSiteExcept { per_site, excluded_site } => {
                Self::per_site(topo, *per_site, Some(*excluded_site))
            }
            Placement::SingleSite { site, nodes } => {
                assert!(*site < topo.sites.len(), "placement site {site} out of range");
                let mut out = Vec::new();
                for rid in &topo.sites[*site].racks {
                    for &node in &topo.racks[rid.0].nodes {
                        if out.len() == *nodes {
                            return out;
                        }
                        out.push(node);
                    }
                }
                out
            }
            Placement::Custom(f) => f(topo),
        }
    }

    fn per_site(topo: &Topology, per_site: usize, excluded: Option<usize>) -> Vec<NodeId> {
        let mut out = Vec::new();
        for (i, site) in topo.sites.iter().enumerate() {
            if excluded == Some(i) {
                continue;
            }
            let mut left = per_site;
            for rid in &site.racks {
                for &node in &topo.racks[rid.0].nodes {
                    if left == 0 {
                        break;
                    }
                    out.push(node);
                    left -= 1;
                }
            }
        }
        out
    }

    /// Short label for reports.
    pub fn label(&self) -> String {
        match self {
            Placement::PerSite(n) => format!("per-site-{n}"),
            Placement::SingleSite { site, nodes } => format!("site{site}-{nodes}"),
            Placement::PerSiteExcept { per_site, excluded_site } => {
                format!("per-site-{per_site}-minus-site{excluded_site}")
            }
            Placement::Custom(_) => "custom".to_string(),
        }
    }
}

impl fmt::Debug for Placement {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

/// The distributed data-processing framework under test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Framework {
    HadoopMr,
    /// Hadoop MapReduce with `dfs.replication = 1` (Table 2's middle row).
    HadoopMrR1,
    HadoopStreams,
    SectorSphere,
    /// §7 interop: Hadoop MapReduce scheduling + TCP shuffle over
    /// CloudStore/KFS chunk storage (chunk-lease writes, rack-oblivious
    /// placement) — see [`crate::framework::KfsStorage`].
    CloudStoreMr,
    /// §7 interop: MapReduce scheduling + shuffle semantics over Sector
    /// placement with a UDT exchange and single lazy-replicated output.
    HadoopOverSector,
    /// Not a data-processing framework but a substrate stress driver: a
    /// synthetic storm of concurrent point-to-point transfers (Sector
    /// segment shuttles / shuffle fetches) that exercises the fluid
    /// network's arrival/departure churn path. The workload's record
    /// count is reinterpreted as the number of transfers.
    FlowChurn,
    /// The flow-domain stress driver: like [`Framework::FlowChurn`] the
    /// workload's record count is a transfer count, but the traffic is
    /// *structured* — disjoint intra-rack partner pairs carrying many
    /// concurrent same-path streams each, plus a thin cross-site stream
    /// over the shared wave — so hundreds of thousands of flows stay in
    /// flight while each arrival/departure touches only its own pair's
    /// links. This is the shape incremental water-filling and same-path
    /// aggregation exist for; the `flow_scale` bench runs it against the
    /// pre-refactor global core.
    MegaChurn,
    /// Open-loop user-facing service traffic: a deterministic
    /// [`crate::service::LoadGen`] drives request/response flows against
    /// replicas of a service placed across sites, with per-request
    /// latency rolled into SLO quantiles (see [`crate::service`]). The
    /// workload's record count is reinterpreted as the total request
    /// count; like the churn drivers it is absent from
    /// [`Framework::ALL`].
    Service,
}

impl Framework {
    /// The paper's headline data-processing frameworks — the enumeration
    /// cross-product sets sweep over. [`Framework::FlowChurn`] and
    /// [`Framework::MegaChurn`] are deliberately absent (they reinterpret
    /// the workload's record count as a transfer count, so including them
    /// in a MalStone sweep would be nonsense); the §7 interop compositions
    /// live in their own `interop` registry set rather than every sweep.
    pub const ALL: [Framework; 4] = [
        Framework::HadoopMr,
        Framework::HadoopMrR1,
        Framework::HadoopStreams,
        Framework::SectorSphere,
    ];

    /// The calibrated cost model for this framework.
    pub fn params(&self) -> FrameworkParams {
        match self {
            Framework::HadoopMr => FrameworkParams::hadoop_mapreduce(),
            Framework::HadoopMrR1 => FrameworkParams::hadoop_mapreduce_r1(),
            Framework::HadoopStreams => FrameworkParams::hadoop_streams(),
            Framework::CloudStoreMr => FrameworkParams::cloudstore_mr(),
            Framework::HadoopOverSector => FrameworkParams::hadoop_over_sector(),
            // Churn and service traffic drive raw transfers; the cost
            // model goes unused, but Sphere's (UDT transport) is the
            // closest in spirit.
            Framework::SectorSphere
            | Framework::FlowChurn
            | Framework::MegaChurn
            | Framework::Service => FrameworkParams::sphere(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Framework::HadoopMr => "hadoop-mapreduce",
            Framework::HadoopMrR1 => "hadoop-mapreduce-r1",
            Framework::HadoopStreams => "hadoop-streams",
            Framework::SectorSphere => "sector-sphere",
            Framework::CloudStoreMr => "cloudstore-mr",
            Framework::HadoopOverSector => "hadoop-over-sector",
            Framework::FlowChurn => "flow-churn",
            Framework::MegaChurn => "mega-churn",
            Framework::Service => "service",
        }
    }
}

/// A node-imaging requirement: before the workload may start, every
/// placed node must be brought from bare metal to `Ready(name)` — the
/// image is fetched from the node's site depot as a real flow, installed
/// at disk speed, and the node rebooted, all on the event engine. The
/// measured latency lands in the run's `imaging_secs` metric.
#[derive(Debug, Clone, PartialEq)]
pub struct ImageSpec {
    /// Image name (what [`crate::coordinator::Provisioner::image_node`]
    /// records).
    pub name: String,
    /// Image size in bytes (fetched over the fabric, written to disk).
    pub bytes: f64,
}

impl ImageSpec {
    /// An image of `gb` gigabytes.
    ///
    /// ```
    /// use oct::coordinator::ImageSpec;
    /// let img = ImageSpec::new("hadoop-0.18.3", 4.0);
    /// assert_eq!(img.bytes, 4.0e9);
    /// ```
    pub fn new(name: &str, gb: f64) -> ImageSpec {
        assert!(gb > 0.0, "image must have positive size");
        ImageSpec { name: name.to_string(), bytes: gb * 1e9 }
    }
}

/// A dynamic-lightpath grant: the run's wide-area wave starts dark (at
/// the control-path floor), is provisioned to `gbps` per direction after
/// `setup_secs` of signalling, and the workload waits for the grant. An
/// under-provisioned grant (below the testbed's nominal 10 Gb/s) is a
/// first-class scenario axis: the run completes, slower.
#[derive(Debug, Clone, PartialEq)]
pub struct LightpathSpec {
    /// Granted capacity per direction, Gb/s.
    pub gbps: f64,
    /// Signalling/setup latency before the wave lights, seconds.
    pub setup_secs: f64,
}

impl LightpathSpec {
    /// Lightpath setup on dynamic optical networks of the era (the
    /// paper's [13]) took tens of seconds of control-plane signalling.
    pub const DEFAULT_SETUP_SECS: f64 = 30.0;

    /// A grant of `gbps` per direction with the default setup latency.
    pub fn gbps(gbps: f64) -> LightpathSpec {
        assert!(gbps > 0.0, "lightpath grant must be positive");
        LightpathSpec { gbps, setup_secs: Self::DEFAULT_SETUP_SECS }
    }
}

/// The provisioning axis of a scenario: what must be set up — and paid
/// for in simulated time — before the workload starts. Empty by default
/// (the testbed is assumed pre-imaged and pre-lit, as every pre-existing
/// scenario was).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ProvisioningSpec {
    pub image: Option<ImageSpec>,
    pub lightpath: Option<LightpathSpec>,
}

impl ProvisioningSpec {
    /// True when the scenario requires no provisioning phase at all.
    pub fn is_empty(&self) -> bool {
        self.image.is_none() && self.lightpath.is_none()
    }
}

/// Marks a scenario as one tenant of a concurrent multi-tenant group:
/// scenarios sharing a `group` id are carved onto slices of *one* shared
/// testbed and run concurrently by
/// [`crate::coordinator::ScenarioRunner::run_tenants`].
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub tenant: String,
    pub group: u32,
}

/// MalStone variant: A (point-in-time ratios) or B (cumulative windows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    A,
    B,
}

impl Variant {
    pub fn letter(&self) -> char {
        match self {
            Variant::A => 'A',
            Variant::B => 'B',
        }
    }

    pub fn is_b(&self) -> bool {
        matches!(self, Variant::B)
    }
}

/// A MalStone workload at some scale.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkloadSpec {
    pub variant: Variant,
    pub total_records: u64,
}

impl WorkloadSpec {
    pub fn malstone_a(total_records: u64) -> Self {
        assert!(total_records > 0);
        WorkloadSpec { variant: Variant::A, total_records }
    }

    pub fn malstone_b(total_records: u64) -> Self {
        assert!(total_records > 0);
        WorkloadSpec { variant: Variant::B, total_records }
    }

    /// Divide the record count by `div` (shape-preserving quick runs).
    pub fn scaled_down(&self, div: u64) -> WorkloadSpec {
        assert!(div > 0);
        WorkloadSpec { variant: self.variant, total_records: (self.total_records / div).max(1) }
    }
}

/// A fully-described experiment, ready for the runner.
#[derive(Clone)]
pub struct Scenario {
    pub name: String,
    pub topology: TopologySpec,
    pub placement: Placement,
    pub framework: Framework,
    pub workload: WorkloadSpec,
    /// Paper-measured reference time in seconds, when the scenario
    /// reproduces a published row (scaled along with the workload).
    pub paper_secs: Option<f64>,
    /// Scheduled faults applied mid-run (empty = nothing breaks). A
    /// non-empty plan implicitly enables the operations plane.
    pub fault_plan: FaultPlan,
    /// Operations-plane configuration. `Some` installs the in-band
    /// sensor/aggregator/service pipeline even on fault-free runs
    /// (overhead and false-positive baselines).
    pub ops: Option<OpsConfig>,
    /// What must be provisioned (imaging, lightpath) before the workload
    /// starts; the run pays the measured latency.
    pub provisioning: ProvisioningSpec,
    /// `Some` marks this scenario as one tenant of a concurrent group.
    pub tenancy: Option<TenantSpec>,
    /// `Some` records a deterministic sim-time trace of the run (span
    /// and instant events, ring-bounded per shard) harvestable as a
    /// Chrome Trace via the runner. Off by default: tracing must never
    /// change a report byte.
    pub trace: Option<TraceSpec>,
    /// Service-traffic axis for [`Framework::Service`] scenarios: where
    /// the replicas live, how requests route, and the arrival shape.
    /// `None` with `Framework::Service` falls back to
    /// [`crate::service::ServiceSpec::new`]'s defaults over all sites.
    pub service: Option<ServiceSpec>,
}

impl Scenario {
    /// The same scenario with the workload (and paper reference) divided
    /// by `div` — timing is ~linear in scale, so shape is preserved. The
    /// name records the divisor (names often embed record counts).
    /// Fault times scale with the workload so a fault keeps its relative
    /// position in the run; ops cadences do not (detection-latency bounds
    /// stay in absolute heartbeats at every scale). Provisioning does not
    /// scale either: image sizes and lightpath signalling latency are
    /// properties of the testbed, not the workload.
    pub fn scaled_down(&self, div: u64) -> Scenario {
        assert!(div > 0);
        Scenario {
            name: if div == 1 { self.name.clone() } else { format!("{}/÷{div}", self.name) },
            topology: self.topology.clone(),
            placement: self.placement.clone(),
            framework: self.framework,
            workload: self.workload.scaled_down(div),
            paper_secs: self.paper_secs.map(|p| p / div as f64),
            fault_plan: self.fault_plan.scaled_down(div),
            ops: self.ops.clone(),
            provisioning: self.provisioning.clone(),
            tenancy: self.tenancy.clone(),
            trace: self.trace.clone(),
            service: self.service.clone(),
        }
    }

    /// One-line human description.
    pub fn describe(&self) -> String {
        let faults = if self.fault_plan.is_empty() {
            String::new()
        } else {
            format!(" + {} fault(s)", self.fault_plan.len())
        };
        let mut provision = String::new();
        if let Some(img) = &self.provisioning.image {
            provision.push_str(&format!(" + image {}", img.name));
        }
        if let Some(lp) = &self.provisioning.lightpath {
            provision.push_str(&format!(" + lightpath {} Gb/s", lp.gbps));
        }
        let tenant = match &self.tenancy {
            Some(t) => format!(" [tenant {}]", t.tenant),
            None => String::new(),
        };
        format!(
            "{}: {} malstone-{} {} records on {} / {}{}{}{}",
            self.name,
            self.framework.name(),
            self.workload.variant.letter(),
            self.workload.total_records,
            self.topology.label(),
            self.placement.label(),
            faults,
            provision,
            tenant,
        )
    }
}

impl fmt::Debug for Scenario {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.describe())
    }
}

/// Entry point of the builder chain: `Testbed::builder()…build()`.
pub struct Testbed;

impl Testbed {
    pub fn builder() -> TestbedBuilder {
        TestbedBuilder {
            name: None,
            topology: TopologySpec::Oct2009,
            placement: None,
            framework: Framework::SectorSphere,
            workload: WorkloadSpec::malstone_a(2_000_000),
            paper_secs: None,
            fault_plan: FaultPlan::new(),
            ops: None,
            provisioning: ProvisioningSpec::default(),
            tenancy: None,
            trace: None,
            service: None,
        }
    }
}

/// Builder for [`Scenario`]. Defaults: the 2009 testbed, 5 nodes per
/// site (Table 1's layout), Sector/Sphere, MalStone-A at a 2M-record
/// smoke scale.
#[derive(Clone)]
pub struct TestbedBuilder {
    name: Option<String>,
    topology: TopologySpec,
    placement: Option<Placement>,
    framework: Framework,
    workload: WorkloadSpec,
    paper_secs: Option<f64>,
    fault_plan: FaultPlan,
    ops: Option<OpsConfig>,
    provisioning: ProvisioningSpec,
    tenancy: Option<TenantSpec>,
    trace: Option<TraceSpec>,
    service: Option<ServiceSpec>,
}

impl TestbedBuilder {
    pub fn topology(mut self, t: TopologySpec) -> Self {
        self.topology = t;
        self
    }

    pub fn placement(mut self, p: Placement) -> Self {
        self.placement = Some(p);
        self
    }

    pub fn framework(mut self, f: Framework) -> Self {
        self.framework = f;
        self
    }

    pub fn workload(mut self, w: WorkloadSpec) -> Self {
        self.workload = w;
        self
    }

    pub fn name(mut self, name: &str) -> Self {
        self.name = Some(name.to_string());
        self
    }

    pub fn paper_secs(mut self, secs: f64) -> Self {
        self.paper_secs = Some(secs);
        self
    }

    /// Schedule faults for the run (implicitly enables the ops plane).
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = plan;
        self
    }

    /// Install the operations plane with this configuration (fault-free
    /// runs included — overhead / false-positive baselines).
    pub fn ops(mut self, cfg: OpsConfig) -> Self {
        self.ops = Some(cfg);
        self
    }

    /// Require every placed node to be imaged with `name` (`gb`
    /// gigabytes) before the workload starts; the run pays the measured
    /// imaging latency.
    pub fn image(mut self, name: &str, gb: f64) -> Self {
        self.provisioning.image = Some(ImageSpec::new(name, gb));
        self
    }

    /// Require a dynamic lightpath grant of `gbps` per direction (default
    /// setup latency) before the workload starts. Grants below the
    /// testbed's nominal wave model an under-provisioned path.
    pub fn lightpath(mut self, gbps: f64) -> Self {
        self.provisioning.lightpath = Some(LightpathSpec::gbps(gbps));
        self
    }

    /// Set the full provisioning axis at once.
    pub fn provisioning(mut self, p: ProvisioningSpec) -> Self {
        self.provisioning = p;
        self
    }

    /// Mark this scenario as tenant `name` of concurrent group `group`
    /// (see [`crate::coordinator::ScenarioRunner::run_tenants`]).
    pub fn tenant(mut self, name: &str, group: u32) -> Self {
        self.tenancy = Some(TenantSpec { tenant: name.to_string(), group });
        self
    }

    /// Record a deterministic sim-time trace of the run with this spec
    /// (harvest it through the runner's `run_with_trace`).
    pub fn trace(mut self, spec: TraceSpec) -> Self {
        self.trace = Some(spec);
        self
    }

    /// Set the service-traffic axis (pair with
    /// [`TestbedBuilder::framework`]`(Framework::Service)`; the workload's
    /// record count becomes the total request count).
    pub fn service(mut self, spec: ServiceSpec) -> Self {
        self.service = Some(spec);
        self
    }

    pub fn build(self) -> Scenario {
        // `Local { site }` topologies default to the Table-2 local layout
        // (28 nodes on that site); everything else to Table 1's 5×4.
        let placement = self.placement.unwrap_or_else(|| match self.topology {
            TopologySpec::Local { site } => Placement::SingleSite { site, nodes: 28 },
            _ => Placement::PerSite(5),
        });
        let name = self.name.unwrap_or_else(|| {
            format!(
                "{}-malstone-{}-{}rec-{}",
                self.framework.name(),
                self.workload.variant.letter().to_ascii_lowercase(),
                self.workload.total_records,
                placement.label(),
            )
        });
        Scenario {
            name,
            topology: self.topology,
            placement,
            framework: self.framework,
            workload: self.workload,
            paper_secs: self.paper_secs,
            fault_plan: self.fault_plan,
            ops: self.ops,
            provisioning: self.provisioning,
            tenancy: self.tenancy,
            trace: self.trace,
            service: self.service,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_site_placement_counts() {
        let topo = Topology::oct_2009();
        let nodes = Placement::PerSite(5).select(&topo);
        assert_eq!(nodes.len(), 20);
        // Five from each of the four sites.
        for s in 0..4 {
            assert_eq!(nodes.iter().filter(|&&n| topo.node(n).site.0 == s).count(), 5);
        }
    }

    #[test]
    fn single_site_placement_stays_local() {
        let topo = Topology::oct_2009();
        let nodes = Placement::SingleSite { site: 2, nodes: 28 }.select(&topo);
        assert_eq!(nodes.len(), 28);
        assert!(nodes.iter().all(|&n| topo.node(n).site.0 == 2));
    }

    #[test]
    fn per_site_except_drops_one_site() {
        let topo = Topology::oct_2009();
        let nodes = Placement::PerSiteExcept { per_site: 7, excluded_site: 3 }.select(&topo);
        assert_eq!(nodes.len(), 21);
        assert!(nodes.iter().all(|&n| topo.node(n).site.0 != 3));
    }

    #[test]
    fn custom_placement_and_topology() {
        let spec = TopologySpec::Custom(Rc::new(Topology::oct_2009));
        let topo = spec.build();
        assert_eq!(topo.num_nodes(), 128);
        let pl = Placement::Custom(Rc::new(|t: &Topology| t.racks[0].nodes[..2].to_vec()));
        assert_eq!(pl.select(&topo).len(), 2);
        assert_eq!(spec.label(), "custom");
    }

    #[test]
    fn builder_defaults_and_naming() {
        let sc = Testbed::builder().framework(Framework::HadoopStreams).build();
        assert_eq!(sc.framework, Framework::HadoopStreams);
        assert!(sc.name.contains("hadoop-streams"), "{}", sc.name);
        assert!(matches!(sc.placement, Placement::PerSite(5)));
        let local = Testbed::builder().topology(TopologySpec::Local { site: 1 }).build();
        assert!(matches!(local.placement, Placement::SingleSite { site: 1, nodes: 28 }));
    }

    #[test]
    fn fault_plan_rides_the_builder_and_scales() {
        let sc = Testbed::builder()
            .framework(Framework::HadoopMr)
            .faults(FaultPlan::new().node_crash(2000.0, 7))
            .ops(OpsConfig::default())
            .name("faulty")
            .build();
        assert_eq!(sc.fault_plan.len(), 1);
        assert!(sc.ops.is_some());
        assert!(sc.describe().contains("+ 1 fault(s)"), "{}", sc.describe());
        let s = sc.scaled_down(100);
        assert_eq!(s.fault_plan.events[0].at, 20.0);
        // Ops cadences stay absolute across scaling.
        assert_eq!(s.ops.unwrap().heartbeat_interval, sc.ops.unwrap().heartbeat_interval);
        // Default scenarios carry no faults and no ops plane.
        let plain = Testbed::builder().build();
        assert!(plain.fault_plan.is_empty());
        assert!(plain.ops.is_none());
    }

    #[test]
    fn provisioning_axis_rides_the_builder() {
        let sc = Testbed::builder()
            .image("sector-sphere-1.24", 4.0)
            .lightpath(2.5)
            .tenant("alice", 0)
            .name("provisioned")
            .build();
        assert!(!sc.provisioning.is_empty());
        let img = sc.provisioning.image.as_ref().unwrap();
        assert_eq!(img.name, "sector-sphere-1.24");
        assert_eq!(img.bytes, 4.0e9);
        let lp = sc.provisioning.lightpath.as_ref().unwrap();
        assert_eq!(lp.gbps, 2.5);
        assert_eq!(lp.setup_secs, LightpathSpec::DEFAULT_SETUP_SECS);
        assert_eq!(sc.tenancy.as_ref().unwrap().tenant, "alice");
        let d = sc.describe();
        assert!(d.contains("image sector-sphere-1.24"), "{d}");
        assert!(d.contains("lightpath 2.5 Gb/s"), "{d}");
        assert!(d.contains("[tenant alice]"), "{d}");
        // Scaling divides the workload but not the testbed's provisioning
        // constants (image size, signalling latency).
        let s = sc.scaled_down(100);
        assert_eq!(s.provisioning, sc.provisioning);
        assert_eq!(s.tenancy, sc.tenancy);
        // Default scenarios carry no provisioning phase.
        let plain = Testbed::builder().build();
        assert!(plain.provisioning.is_empty());
        assert!(plain.tenancy.is_none());
    }

    #[test]
    fn trace_axis_rides_the_builder_and_survives_scaling() {
        let sc = Testbed::builder().trace(TraceSpec::with_cap(1024)).name("traced").build();
        assert_eq!(sc.trace.as_ref().unwrap().cap, 1024);
        // Scaling preserves the trace spec: ring capacity bounds memory,
        // not workload size.
        assert_eq!(sc.scaled_down(100).trace, sc.trace);
        // Off by default — tracing must be opt-in.
        assert!(Testbed::builder().build().trace.is_none());
    }

    #[test]
    fn workload_and_scenario_scaling() {
        let w = WorkloadSpec::malstone_b(10_000_000_000);
        let s = w.scaled_down(200);
        assert_eq!(s.total_records, 50_000_000);
        assert!(s.variant.is_b());
        let sc = Testbed::builder().workload(w).paper_secs(1000.0).name("x").build();
        let sc2 = sc.scaled_down(100);
        assert_eq!(sc2.workload.total_records, 100_000_000);
        assert_eq!(sc2.paper_secs, Some(10.0));
        assert_eq!(sc2.name, "x/÷100");
        assert_eq!(sc.scaled_down(1).name, "x");
    }
}

//! The single execution path for every scenario: [`ScenarioRunner`] turns
//! a [`Scenario`] into a structured, JSON-serializable [`RunReport`].
//!
//! The runner owns all the substrate wiring the old free-function drivers
//! duplicated — cluster construction, HDFS namenode setup, Sector segment
//! registration, chained MapReduce jobs, optional monitoring — and
//! augments the simulated makespan with per-site flow statistics read
//! from [`crate::net::flows::FlowNet`]'s link counters, engine-specific
//! metrics, and the paper reference carried by the scenario.

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::rc::Rc;

use crate::framework::{DataflowControl, HdfsStorage, KfsStorage, SectorStorage, StorageModel};
use crate::hadoop::hdfs::{HdfsConfig, Namenode};
use crate::hadoop::mapreduce::{malstone_jobs, uniform_shards, JobReport, MapReduceEngine};
use crate::hadoop::FrameworkParams;
use crate::malstone::record::RECORD_BYTES;
use crate::monitor::Monitor;
use crate::net::topology::LinkKind;
use crate::net::{Cluster, FlowNet, FlowNetConfig, LinkId, NodeId, SiteId, Topology};
use crate::ops::{Fault, OpsConfig, OpsPlane, OpsReport};
use crate::sector::master::{SectorMaster, Segment};
use crate::sector::sphere::SphereReport;
use crate::sector::SphereEngine;
use crate::service::{
    service_plant, LoadGen, Request, RoutePolicy, ServiceReport, ServiceSpec, SiteAccum,
    DEGRADED_WAN_PENALTY_SECS,
};
use crate::sim::par::{run_sharded, Outbox, ShardApp};
use crate::sim::{Countdown, Engine};
use crate::trace::{Arg, ProfileReport, Recorder, Stream, TraceSpec};
use crate::transport::{self, Protocol};
use crate::util::json::{obj, Json};
use crate::util::rng::Rng;

use super::provision::{Slice, SliceScheduler, DEFAULT_SPARE_WAVE_GBPS, LIGHTPATH_FLOOR_BPS};
use super::registry::ScenarioSet;
use super::scenario::{Framework, ImageSpec, LightpathSpec, Placement, Scenario, WorkloadSpec};

/// Shared handle to the omniscient sampler installed by
/// [`ScenarioRunner::with_monitor`].
type MonitorHandle = Rc<RefCell<Monitor>>;

/// Traffic through one site's rack uplinks over a run (bytes).
#[derive(Debug, Clone, PartialEq)]
pub struct SiteFlow {
    pub site: String,
    pub nodes_used: usize,
    pub uplink_tx_bytes: f64,
    pub uplink_rx_bytes: f64,
}

/// Summary of the monitoring series collected during a run.
#[derive(Debug, Clone, PartialEq)]
pub struct MonitorSummary {
    pub samples: u64,
    /// Nodes whose NIC series saw any traffic.
    pub busy_nodes: usize,
    /// Median per-node NIC rate across busy nodes, bytes/s (the hotspot
    /// detector's baseline).
    pub nic_rate_p50: f64,
    /// 99th-percentile per-node NIC rate across busy nodes, bytes/s.
    pub nic_rate_p99: f64,
}

/// Host-side cost of producing a report — measurement *about* a run,
/// never an input to one. Wall time varies with the machine and the
/// thread count, so it is excluded from [`RunReport`] equality and from
/// its JSON serialization: reports stay byte-comparable across thread
/// counts (the determinism harness depends on that).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WallStats {
    /// Real seconds the run took on the host.
    pub wall_secs: f64,
    /// Engine events executed per real second (all shards summed).
    pub events_per_sec: f64,
}

/// The structured result of one scenario run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub scenario: String,
    pub framework: String,
    pub variant: String,
    pub topology: String,
    pub placement: String,
    pub nodes: usize,
    pub total_records: u64,
    /// Simulated makespan, seconds.
    pub simulated_secs: f64,
    /// Paper-measured reference (already scaled with the workload).
    pub paper_secs: Option<f64>,
    /// Bytes that crossed WAN links.
    pub wan_bytes: f64,
    /// Per-site rack-uplink traffic.
    pub site_flows: Vec<SiteFlow>,
    /// Engine-specific metrics (sorted by key).
    pub metrics: Vec<(String, f64)>,
    pub monitor: Option<MonitorSummary>,
    /// Operations-plane results (detection latency, telemetry overhead,
    /// alerts, remediation) for ops-enabled runs.
    pub ops: Option<OpsReport>,
    /// Service-traffic results (request counts, latency quantiles, SLO
    /// accounting) for [`Framework::Service`] runs.
    pub service: Option<ServiceReport>,
    /// Engine hot-path counters: always on, deterministic, inside the
    /// report's equality and serialization (its `sched` side-channel is
    /// wall-derived and excluded by [`ProfileReport`] itself).
    pub profile: ProfileReport,
    /// Host-side timing; see [`WallStats`] for why it is outside the
    /// report's equality and serialization.
    pub wall: Option<WallStats>,
}

/// Everything except `wall`: two runs of the same scenario are the same
/// run no matter how long the host took or how many threads it used.
impl PartialEq for RunReport {
    fn eq(&self, other: &RunReport) -> bool {
        self.scenario == other.scenario
            && self.framework == other.framework
            && self.variant == other.variant
            && self.topology == other.topology
            && self.placement == other.placement
            && self.nodes == other.nodes
            && self.total_records == other.total_records
            && self.simulated_secs == other.simulated_secs
            && self.paper_secs == other.paper_secs
            && self.wan_bytes == other.wan_bytes
            && self.site_flows == other.site_flows
            && self.metrics == other.metrics
            && self.monitor == other.monitor
            && self.ops == other.ops
            && self.service == other.service
            && self.profile == other.profile
    }
}

impl RunReport {
    /// Simulated-over-paper ratio, when a reference exists.
    pub fn paper_ratio(&self) -> Option<f64> {
        self.paper_secs.map(|p| self.simulated_secs / p)
    }

    /// Look up an engine-specific metric by key.
    pub fn metric(&self, key: &str) -> Option<f64> {
        self.metrics.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }

    /// Serialize to the crate's dependency-free JSON value.
    pub fn to_json(&self) -> Json {
        let flows: Vec<Json> = self
            .site_flows
            .iter()
            .map(|f| {
                obj(vec![
                    ("site", Json::Str(f.site.clone())),
                    ("nodes_used", Json::Num(f.nodes_used as f64)),
                    ("uplink_tx_bytes", Json::Num(f.uplink_tx_bytes)),
                    ("uplink_rx_bytes", Json::Num(f.uplink_rx_bytes)),
                ])
            })
            .collect();
        let metrics =
            Json::Obj(self.metrics.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect());
        let monitor = match &self.monitor {
            Some(m) => obj(vec![
                ("samples", Json::Num(m.samples as f64)),
                ("busy_nodes", Json::Num(m.busy_nodes as f64)),
                ("nic_rate_p50", Json::Num(m.nic_rate_p50)),
                ("nic_rate_p99", Json::Num(m.nic_rate_p99)),
            ]),
            None => Json::Null,
        };
        let ops = match &self.ops {
            Some(o) => o.to_json(),
            None => Json::Null,
        };
        let service = match &self.service {
            Some(s) => s.to_json(),
            None => Json::Null,
        };
        obj(vec![
            ("scenario", Json::Str(self.scenario.clone())),
            ("framework", Json::Str(self.framework.clone())),
            ("variant", Json::Str(self.variant.clone())),
            ("topology", Json::Str(self.topology.clone())),
            ("placement", Json::Str(self.placement.clone())),
            ("nodes", Json::Num(self.nodes as f64)),
            ("total_records", Json::Num(self.total_records as f64)),
            ("simulated_secs", Json::Num(self.simulated_secs)),
            ("paper_secs", self.paper_secs.map(Json::Num).unwrap_or(Json::Null)),
            ("wan_bytes", Json::Num(self.wan_bytes)),
            ("site_flows", Json::Arr(flows)),
            ("metrics", metrics),
            ("monitor", monitor),
            ("ops", ops),
            ("profile", self.profile.to_json()),
            ("service", service),
        ])
    }

    /// Parse a report back from JSON (round-trips [`RunReport::to_json`]).
    pub fn from_json(j: &Json) -> Result<RunReport, String> {
        fn num(j: &Json, k: &str) -> Result<f64, String> {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing number '{k}'"))
        }
        fn string(j: &Json, k: &str) -> Result<String, String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("missing string '{k}'"))
        }
        let site_flows = match j.get("site_flows") {
            Some(Json::Arr(xs)) => xs
                .iter()
                .map(|x| {
                    Ok(SiteFlow {
                        site: string(x, "site")?,
                        nodes_used: num(x, "nodes_used")? as usize,
                        uplink_tx_bytes: num(x, "uplink_tx_bytes")?,
                        uplink_rx_bytes: num(x, "uplink_rx_bytes")?,
                    })
                })
                .collect::<Result<Vec<SiteFlow>, String>>()?,
            _ => return Err("missing array 'site_flows'".to_string()),
        };
        let metrics = match j.get("metrics") {
            Some(Json::Obj(m)) => m
                .iter()
                .map(|(k, v)| {
                    v.as_f64().map(|x| (k.clone(), x)).ok_or_else(|| format!("bad metric '{k}'"))
                })
                .collect::<Result<Vec<(String, f64)>, String>>()?,
            _ => return Err("missing object 'metrics'".to_string()),
        };
        let monitor = match j.get("monitor") {
            None | Some(Json::Null) => None,
            Some(m) => Some(MonitorSummary {
                samples: num(m, "samples")? as u64,
                busy_nodes: num(m, "busy_nodes")? as usize,
                nic_rate_p50: num(m, "nic_rate_p50")?,
                nic_rate_p99: num(m, "nic_rate_p99")?,
            }),
        };
        let ops = match j.get("ops") {
            None | Some(Json::Null) => None,
            Some(o) => Some(OpsReport::from_json(o)?),
        };
        let service = match j.get("service") {
            None | Some(Json::Null) => None,
            Some(s) => Some(ServiceReport::from_json(s)?),
        };
        // Pre-profile reports (older baselines) parse with zeroed
        // counters rather than failing.
        let profile = j.get("profile").map(ProfileReport::from_json).unwrap_or_default();
        let paper_secs = match j.get("paper_secs") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_f64().ok_or("bad 'paper_secs'")?),
        };
        Ok(RunReport {
            scenario: string(j, "scenario")?,
            framework: string(j, "framework")?,
            variant: string(j, "variant")?,
            topology: string(j, "topology")?,
            placement: string(j, "placement")?,
            nodes: num(j, "nodes")? as usize,
            total_records: num(j, "total_records")? as u64,
            simulated_secs: num(j, "simulated_secs")?,
            paper_secs,
            wan_bytes: num(j, "wan_bytes")?,
            site_flows,
            metrics,
            monitor,
            ops,
            service,
            profile,
            wall: None,
        })
    }
}

/// One verdict from a scenario set's shape check.
#[derive(Debug, Clone)]
pub struct ShapeCheck {
    pub name: String,
    pub pass: bool,
    pub detail: String,
}

impl ShapeCheck {
    pub fn new(name: impl Into<String>, pass: bool, detail: impl Into<String>) -> ShapeCheck {
        ShapeCheck { name: name.into(), pass, detail: detail.into() }
    }
}

/// True when every check passed (vacuously true for checkless sets).
pub fn all_pass(checks: &[ShapeCheck]) -> bool {
    checks.iter().all(|c| c.pass)
}

/// The wide-area penalty of a local/distributed report pair — the
/// single definition shared by shape checks, benches, and tests.
pub fn wide_area_penalty(local: &RunReport, dist: &RunReport) -> f64 {
    (dist.simulated_secs - local.simulated_secs) / local.simulated_secs
}

/// Render reports as an aligned table (the CLI / bench output).
pub fn format_reports(reports: &[RunReport]) -> String {
    use crate::util::units::{fmt_bytes, fmt_paper_time};
    let mut s = String::new();
    s.push_str(&format!(
        "{:<40} {:>10} {:>10} {:>9} {:>10} {:>9} {:>10}\n",
        "scenario", "simulated", "paper", "sim/paper", "wan", "wall", "events/s"
    ));
    for r in reports {
        let paper = r.paper_secs.map(fmt_paper_time).unwrap_or_else(|| "-".to_string());
        let ratio = r.paper_ratio().map(|x| format!("{x:.2}")).unwrap_or_else(|| "-".to_string());
        let wall =
            r.wall.map(|w| format!("{:.2}s", w.wall_secs)).unwrap_or_else(|| "-".to_string());
        let eps =
            r.wall.map(|w| format!("{:.2e}", w.events_per_sec)).unwrap_or_else(|| "-".to_string());
        s.push_str(&format!(
            "{:<40} {:>10} {:>10} {:>9} {:>10} {:>9} {:>10}\n",
            r.scenario,
            fmt_paper_time(r.simulated_secs),
            paper,
            ratio,
            fmt_bytes(r.wan_bytes as u64),
            wall,
            eps,
        ));
    }
    s
}

/// Render shape-check verdicts, one per line.
pub fn format_checks(checks: &[ShapeCheck]) -> String {
    let mut s = String::new();
    for c in checks {
        let verdict = if c.pass { "PASS" } else { "FAIL" };
        s.push_str(&format!("{} {} — {}\n", verdict, c.name, c.detail));
    }
    s
}

enum Outcome {
    Hadoop { finished_at: f64, job1: JobReport, job2: JobReport },
    Sphere { finished_at: f64, report: SphereReport },
    FlowChurn { finished_at: f64, flows: u64, peak_inflight: u64, peak_active: u64 },
    Service { finished_at: f64, report: ServiceReport },
}

/// Simulated-time record of a run's admission and provisioning phases,
/// filled in by engine events as each arm completes.
#[derive(Debug, Clone, Default)]
struct ProvisionTimes {
    /// Engine time the run was admitted (slice carved; 0 for solo runs).
    admitted_at: f64,
    /// Admission wait (tenancy queueing; 0 when admitted immediately).
    queued_secs: f64,
    /// All placed nodes imaged, relative to admission (0 = no image).
    imaging_secs: f64,
    /// Lightpath signalling latency actually paid (0 = no grant).
    lightpath_setup_secs: f64,
    /// Engine time the workload proper started.
    started_at: f64,
}

/// A scenario in flight on some engine: everything needed to assemble
/// its [`RunReport`] once its outcome lands.
struct ActiveRun {
    sc: Scenario,
    cluster: Cluster,
    nodes: Vec<NodeId>,
    outcome: Rc<RefCell<Option<Outcome>>>,
    ops: Option<Rc<RefCell<OpsPlane>>>,
    times: Rc<RefCell<ProvisionTimes>>,
}

/// How [`ScenarioRunner::launch`] should place and wire a run.
struct LaunchCtx {
    /// Admission wait already paid (tenancy queueing).
    queued_secs: f64,
    /// Pre-carved slice nodes (tenancy) instead of the placement.
    nodes: Option<Vec<NodeId>>,
    /// The links a lightpath grant applies to; defaults to every
    /// WAN-kind link of the run's topology view.
    wave_links: Option<Vec<LinkId>>,
}

impl LaunchCtx {
    fn solo() -> LaunchCtx {
        LaunchCtx { queued_secs: 0.0, nodes: None, wave_links: None }
    }
}

/// Executes scenarios on the discrete-event substrate.
#[derive(Debug, Clone, Default)]
pub struct ScenarioRunner {
    monitor_interval: Option<f64>,
    ops_override: Option<OpsConfig>,
    flow_cfg: FlowNetConfig,
    threads: Option<usize>,
    trace_override: Option<TraceSpec>,
}

impl ScenarioRunner {
    pub fn new() -> ScenarioRunner {
        ScenarioRunner::default()
    }

    /// Sample the monitoring system every `interval` simulated seconds
    /// during runs; the report then carries a [`MonitorSummary`].
    pub fn with_monitor(mut self, interval: f64) -> ScenarioRunner {
        assert!(interval > 0.0);
        self.monitor_interval = Some(interval);
        self
    }

    /// Install the operations plane on every run with this configuration,
    /// overriding whatever the scenario carries.
    pub fn with_ops(mut self, cfg: OpsConfig) -> ScenarioRunner {
        self.ops_override = Some(cfg);
        self
    }

    /// Run every scenario's fluid network under `cfg`. The `flow_scale`
    /// bench uses this to run the same scenario with incremental
    /// reallocation on and off and compare the reports byte for byte.
    pub fn with_flow_config(mut self, cfg: FlowNetConfig) -> ScenarioRunner {
        self.flow_cfg = cfg;
        self
    }

    /// Use `n` worker threads for shardable runs (currently
    /// [`Framework::MegaChurn`] without monitor/ops/fault/provisioning/
    /// tenancy axes); overrides the `OCT_THREADS` environment variable.
    /// Thread count never changes a report's bytes — only its
    /// [`WallStats`].
    pub fn with_threads(mut self, n: usize) -> ScenarioRunner {
        assert!(n >= 1, "at least one worker thread");
        self.threads = Some(n);
        self
    }

    /// Trace every run with this spec, overriding whatever the scenario
    /// carries. Harvest the merged stream through
    /// [`ScenarioRunner::run_with_trace`] /
    /// [`ScenarioRunner::run_set_with_trace`].
    pub fn with_trace(mut self, spec: TraceSpec) -> ScenarioRunner {
        self.trace_override = Some(spec);
        self
    }

    /// The effective trace spec of a run: the runner override wins, else
    /// the scenario's own axis, else tracing stays off.
    fn trace_spec(&self, sc: &Scenario) -> Option<TraceSpec> {
        self.trace_override.clone().or_else(|| sc.trace.clone())
    }

    /// Worker threads for shardable runs: the builder override, else the
    /// `OCT_THREADS` environment variable, else 1.
    fn threads(&self) -> usize {
        self.threads
            .or_else(|| std::env::var("OCT_THREADS").ok().and_then(|v| v.parse().ok()))
            .unwrap_or(1)
            .max(1)
    }

    /// Run one scenario to completion and assemble its report. Scenarios
    /// with a non-empty provisioning axis pay imaging / lightpath setup
    /// in simulated time before the workload starts, and report the
    /// split as `imaging_secs` / `lightpath_setup_secs` /
    /// `provision_secs` / `workload_secs` metrics.
    ///
    /// Shardable scenarios (see [`ScenarioRunner::with_threads`]) run on
    /// the parallel engine — `threads = 1` and `threads = N` take the
    /// same path and produce byte-identical reports; everything else
    /// runs sequentially. Either way the report carries [`WallStats`].
    pub fn run(&self, sc: &Scenario) -> RunReport {
        self.run_traced(sc).0
    }

    /// Like [`ScenarioRunner::run`], also returning the merged
    /// deterministic trace [`Stream`]. The stream is empty unless the
    /// scenario (or [`ScenarioRunner::with_trace`]) carries a
    /// [`TraceSpec`]; the report is byte-identical either way.
    pub fn run_with_trace(&self, sc: &Scenario) -> (RunReport, Stream) {
        self.run_traced(sc)
    }

    fn run_traced(&self, sc: &Scenario) -> (RunReport, Stream) {
        // simlint: allow(SIM002) — wall-clock timing *about* the run (throughput reporting); it never feeds back into simulated time.
        let t0 = std::time::Instant::now();
        let (mut rep, executed, stream) = if self.mega_shardable(sc) {
            self.run_mega_sharded(sc)
        } else if self.service_shardable(sc) {
            self.run_service_sharded(sc)
        } else {
            self.run_sequential(sc)
        };
        let wall_secs = t0.elapsed().as_secs_f64();
        rep.wall = Some(WallStats {
            wall_secs,
            events_per_sec: if wall_secs > 0.0 { executed as f64 / wall_secs } else { 0.0 },
        });
        (rep, stream)
    }

    /// The single-engine path: one event heap drives the whole testbed.
    fn run_sequential(&self, sc: &Scenario) -> (RunReport, u64, Stream) {
        let cluster = Cluster::with_config(sc.topology.build(), self.flow_cfg);
        let mut eng = Engine::new();
        if let Some(spec) = self.trace_spec(sc) {
            eng.set_recorder(Recorder::new(&spec));
        }
        let mon = self.monitor_interval.map(|iv| {
            let m = Monitor::new(cluster.topo.clone(), iv);
            Monitor::install(&m, &mut eng, &cluster.net, cluster.pools.clone());
            m
        });
        let run = self.launch(&cluster, sc, &mut eng, LaunchCtx::solo());
        self.drive(&mut eng, std::slice::from_ref(&run), &mon);
        let executed = eng.executed();
        let mut profile = eng.profile();
        let (refills, dirty) = cluster.net.borrow().profile_counters();
        profile.refill_components += refills;
        profile.dirty_links += dirty;
        let mut stream = Stream::new(cluster.topo.sites.len());
        if let Some(rec) = eng.take_recorder() {
            stream.absorb(rec);
        }
        let mut rep = self.assemble(&run, mon);
        rep.profile = profile;
        (rep, executed, stream)
    }

    /// True when a scenario can take the sharded engine path: a plain
    /// mega-churn run. The monitor, the ops plane, fault plans,
    /// provisioning, and tenancy all move telemetry or control across
    /// flow domains outside the shard channels (see
    /// [`crate::ops::plane`] and [`crate::framework::runtime`]), so any
    /// of those axes keeps the sequential engine. The gate is on the
    /// scenario's *shape*, never on the thread count — a `threads = 1`
    /// run of a shardable scenario uses the sharded engine inline, so
    /// cross-thread-count comparisons compare the same driver.
    fn mega_shardable(&self, sc: &Scenario) -> bool {
        sc.framework == Framework::MegaChurn
            && self.monitor_interval.is_none()
            && self.ops_override.is_none()
            && sc.ops.is_none()
            && sc.fault_plan.is_empty()
            && sc.provisioning.is_empty()
            && sc.tenancy.is_none()
    }

    /// Same shape gate as [`ScenarioRunner::mega_shardable`], for
    /// [`Framework::Service`] runs: any composed axis (monitor, ops,
    /// faults, provisioning, tenancy) keeps the sequential engine.
    fn service_shardable(&self, sc: &Scenario) -> bool {
        sc.framework == Framework::Service
            && self.monitor_interval.is_none()
            && self.ops_override.is_none()
            && sc.ops.is_none()
            && sc.fault_plan.is_empty()
            && sc.provisioning.is_empty()
            && sc.tenancy.is_none()
    }

    /// The sharded mega-churn driver: one shard per site plus a WAN
    /// shard, run on the conservative parallel engine
    /// ([`crate::sim::par`]). Each site shard owns its intra-rack pair
    /// slots end to end; WAN slots stay *homed* at a site shard (which
    /// owns their RNG stream and transfer budget) but their flows run on
    /// the WAN shard, commanded over the shard channels — the
    /// cross-domain traffic the lookahead synchronization bounds.
    ///
    /// Every shard derives the full slot plan deterministically from an
    /// identical clone of the built plant, so the factories share no
    /// state; link claims
    /// partition the plant (pair NICs per site shard; uplinks, waves,
    /// and pool NICs on the WAN shard), which
    /// [`FlowNet::claim_links`] turns into both a scope cut for full
    /// recomputes and a debug-build disjointness audit.
    fn run_mega_sharded(&self, sc: &Scenario) -> (RunReport, u64, Stream) {
        // Build the topology and placement once, here: `Scenario` itself
        // can carry `Rc` builder closures and must not cross threads, so
        // each factory captures only plain `Send` data — an identical
        // clone of the deterministically built plant.
        let topo = sc.topology.build();
        let nodes = sc.placement.select(&topo);
        let total = sc.workload.total_records.max(1);
        let num_sites = topo.sites.len();
        // Lookahead: the modeled control-plane dispatch latency plus the
        // tightest WAN one-way delay — no cross-domain command or
        // completion report can land sooner.
        let lookahead = MEGA_CMD_SECS + topo.min_wan_owd().unwrap_or(0.0);
        let flow_cfg = self.flow_cfg;
        let trace = self.trace_spec(sc);
        let factories: Vec<_> = (0..=num_sites)
            .map(|idx| {
                let topo = topo.clone();
                let nodes = nodes.clone();
                let trace = trace.clone();
                move || MegaShard::build(topo, nodes, total, idx, flow_cfg, trace)
            })
            .collect();
        let outs = run_sharded(lookahead, factories, self.threads());

        let mut flows = 0u64;
        let mut net_completions = 0u64;
        let mut peak_inflight = 0u64;
        let mut peak_active = 0u64;
        let mut executed = 0u64;
        let mut finished_at = 0.0f64;
        let mut link_bytes: BTreeMap<usize, f64> = BTreeMap::new();
        let mut profile = ProfileReport::default();
        // Recorders absorb in shard-index order — together with the
        // canonical (time, domain) sort this fixes the exported order at
        // any thread count.
        let mut stream = Stream::new(num_sites);
        for o in outs {
            flows += o.done;
            net_completions += o.net_completions;
            peak_inflight += o.peak_inflight;
            peak_active += o.peak_active;
            executed += o.executed;
            finished_at = finished_at.max(o.finished_at);
            profile.add(&o.profile);
            // Claims are disjoint, so each link lands from exactly one
            // shard: the merge is a relabeling, not a float reduction.
            for &(l, b) in &o.link_bytes {
                *link_bytes.entry(l as usize).or_insert(0.0) += b;
            }
            if let Some(rec) = o.recorder {
                stream.absorb(rec);
            }
        }
        let bytes_of = |l: LinkId| link_bytes.get(&l.0).copied().unwrap_or(0.0);

        let mut metrics: Vec<(String, f64)> = vec![
            ("flows".to_string(), flows as f64),
            ("peak_inflight".to_string(), peak_inflight as f64),
            ("peak_active".to_string(), peak_active as f64),
            ("net_completions".to_string(), net_completions as f64),
        ];
        metrics.sort_by(|a, b| a.0.cmp(&b.0));

        let site_flows: Vec<SiteFlow> = topo
            .sites
            .iter()
            .enumerate()
            .map(|(i, site)| {
                let mut tx = 0.0;
                let mut rx = 0.0;
                for rid in &site.racks {
                    tx += bytes_of(topo.racks[rid.0].uplink_tx);
                    rx += bytes_of(topo.racks[rid.0].uplink_rx);
                }
                SiteFlow {
                    site: site.name.clone(),
                    nodes_used: nodes.iter().filter(|&&n| topo.node(n).site.0 == i).count(),
                    uplink_tx_bytes: tx,
                    uplink_rx_bytes: rx,
                }
            })
            .collect();
        let wan_bytes: f64 = topo
            .links
            .iter()
            .enumerate()
            .filter(|(_, l)| l.kind == LinkKind::Wan)
            .map(|(i, _)| bytes_of(LinkId(i)))
            .sum();

        let rep = RunReport {
            scenario: sc.name.clone(),
            framework: sc.framework.name().to_string(),
            variant: sc.workload.variant.letter().to_string(),
            topology: sc.topology.label(),
            placement: sc.placement.label(),
            nodes: nodes.len(),
            total_records: sc.workload.total_records,
            simulated_secs: finished_at,
            paper_secs: sc.paper_secs,
            wan_bytes,
            site_flows,
            metrics,
            monitor: None,
            ops: None,
            service: None,
            profile,
            wall: None,
        };
        (rep, executed, stream)
    }

    /// The sharded service-traffic driver: one shard per site plus a WAN
    /// shard (the mega-churn partition). Each site shard owns its users'
    /// full request plan — regenerated identically from the site's forked
    /// RNG stream — and serves *local* requests end to end on its own
    /// pair NICs. Cross-site requests are commanded over the shard
    /// channels to the WAN shard, which carries their gateway request and
    /// response flows over the rack uplinks and the wave and reports
    /// completion back; both hops model GMP command framing and are
    /// covered by the lookahead.
    fn run_service_sharded(&self, sc: &Scenario) -> (RunReport, u64, Stream) {
        let topo = sc.topology.build();
        let nodes = sc.placement.select(&topo);
        let total = sc.workload.total_records.max(1);
        let num_sites = topo.sites.len();
        let spec = sc.service.clone().unwrap_or_else(|| default_service_spec(&topo));
        let lookahead = SERVICE_CMD_SECS + topo.min_wan_owd().unwrap_or(0.0);
        let flow_cfg = self.flow_cfg;
        let trace = self.trace_spec(sc);
        let factories: Vec<_> = (0..=num_sites)
            .map(|idx| {
                let topo = topo.clone();
                let nodes = nodes.clone();
                let spec = spec.clone();
                let trace = trace.clone();
                move || ServiceShard::build(topo, nodes, spec, total, idx, flow_cfg, trace)
            })
            .collect();
        let outs = run_sharded(lookahead, factories, self.threads());

        let mut executed = 0u64;
        let mut finished_at = 0.0f64;
        let mut link_bytes: BTreeMap<usize, f64> = BTreeMap::new();
        let mut profile = ProfileReport::default();
        let mut accums: Vec<SiteAccum> = Vec::new();
        let mut stream = Stream::new(num_sites);
        for o in outs {
            executed += o.executed;
            finished_at = finished_at.max(o.finished_at);
            profile.add(&o.profile);
            for &(l, b) in &o.link_bytes {
                *link_bytes.entry(l as usize).or_insert(0.0) += b;
            }
            // Site shards land in site order; the WAN shard carries none.
            accums.extend(o.accum);
            if let Some(rec) = o.recorder {
                stream.absorb(rec);
            }
        }
        let report = ServiceReport::assemble(&accums, finished_at);
        let bytes_of = |l: LinkId| link_bytes.get(&l.0).copied().unwrap_or(0.0);

        let mut metrics = report.metrics();
        metrics.sort_by(|a, b| a.0.cmp(&b.0));

        let site_flows: Vec<SiteFlow> = topo
            .sites
            .iter()
            .enumerate()
            .map(|(i, site)| {
                let mut tx = 0.0;
                let mut rx = 0.0;
                for rid in &site.racks {
                    tx += bytes_of(topo.racks[rid.0].uplink_tx);
                    rx += bytes_of(topo.racks[rid.0].uplink_rx);
                }
                SiteFlow {
                    site: site.name.clone(),
                    nodes_used: nodes.iter().filter(|&&n| topo.node(n).site.0 == i).count(),
                    uplink_tx_bytes: tx,
                    uplink_rx_bytes: rx,
                }
            })
            .collect();
        let wan_bytes: f64 = topo
            .links
            .iter()
            .enumerate()
            .filter(|(_, l)| l.kind == LinkKind::Wan)
            .map(|(i, _)| bytes_of(LinkId(i)))
            .sum();

        let rep = RunReport {
            scenario: sc.name.clone(),
            framework: sc.framework.name().to_string(),
            variant: sc.workload.variant.letter().to_string(),
            topology: sc.topology.label(),
            placement: sc.placement.label(),
            nodes: nodes.len(),
            total_records: sc.workload.total_records,
            simulated_secs: finished_at,
            paper_secs: sc.paper_secs,
            wan_bytes,
            site_flows,
            metrics,
            monitor: None,
            ops: None,
            service: Some(report),
            profile,
            wall: None,
        };
        (rep, executed, stream)
    }

    /// Wire a scenario onto an engine: ops plane, faults, and either an
    /// immediate workload start (no provisioning — byte-identical to the
    /// pre-provisioning behavior) or a provisioning barrier that starts
    /// the workload once all nodes are imaged *and* the lightpath grant
    /// lands.
    fn launch(
        &self,
        cluster: &Cluster,
        sc: &Scenario,
        eng: &mut Engine,
        ctx: LaunchCtx,
    ) -> ActiveRun {
        let nodes = ctx.nodes.unwrap_or_else(|| sc.placement.select(&cluster.topo));
        assert!(!nodes.is_empty(), "scenario '{}' selected no nodes", sc.name);
        // The live dataflow's failure surface, filled in as jobs start
        // (chained jobs swap in their own control).
        let control: Rc<RefCell<Option<DataflowControl>>> = Rc::new(RefCell::new(None));
        // A fault plan implies the ops plane (something must detect and
        // heal); an explicit config installs it even fault-free.
        let ops_cfg = self
            .ops_override
            .clone()
            .or_else(|| sc.ops.clone())
            .or_else(|| (!sc.fault_plan.is_empty()).then(OpsConfig::default));
        let ops = ops_cfg.map(|cfg| {
            let plane = OpsPlane::install(cluster, &nodes, cfg, eng);
            install_remediation(&plane, cluster, &control);
            plane
        });
        // Ground truth of crashed nodes (fault-plan side, independent of
        // detection): chained jobs exclude them from their worker sets.
        let failed: Rc<RefCell<BTreeSet<NodeId>>> = Rc::new(RefCell::new(BTreeSet::new()));
        schedule_faults(sc, cluster, &nodes, eng, &ops, &control, &failed);
        let outcome: Rc<RefCell<Option<Outcome>>> = Rc::new(RefCell::new(None));
        let times = Rc::new(RefCell::new(ProvisionTimes {
            admitted_at: eng.now(),
            queued_secs: ctx.queued_secs,
            started_at: eng.now(),
            ..Default::default()
        }));
        if sc.provisioning.is_empty() {
            start_framework(cluster, &nodes, sc, eng, &outcome, &control, &failed);
        } else {
            // The ops plane snapshots WAN nominals at install and would
            // "heal" an under-provisioned grant back to them; the two
            // axes stay separate until the plane learns about grants.
            assert!(
                ops.is_none(),
                "scenario '{}': provisioning and the ops plane are not composable yet",
                sc.name
            );
            let (c2, n2, s2) = (cluster.clone(), nodes.clone(), sc.clone());
            let (o2, ct2, f2, t2) =
                (outcome.clone(), control.clone(), failed.clone(), times.clone());
            let go = Countdown::new(2, move |eng| {
                t2.borrow_mut().started_at = eng.now();
                start_framework(&c2, &n2, &s2, eng, &o2, &ct2, &f2);
            });
            match &sc.provisioning.image {
                Some(img) => start_imaging(cluster, &nodes, img, eng, go.clone(), times.clone()),
                None => go.arrive(eng),
            }
            match &sc.provisioning.lightpath {
                Some(lp) => {
                    let links = ctx.wave_links.unwrap_or_else(|| wan_kind_links(&cluster.topo));
                    start_lightpath(cluster, &links, lp, eng, go.clone(), times.clone());
                }
                None => go.arrive(eng),
            }
        }
        ActiveRun { sc: sc.clone(), cluster: cluster.clone(), nodes, outcome, ops, times }
    }

    /// Pump the engine until every run's outcome lands; monitor/ops loops
    /// reschedule themselves forever, so those runs advance in chunks and
    /// are disabled before the final drain.
    fn drive(&self, eng: &mut Engine, runs: &[ActiveRun], mon: &Option<MonitorHandle>) {
        let pending = |runs: &[ActiveRun]| runs.iter().any(|r| r.outcome.borrow().is_none());
        if mon.is_some() || runs.iter().any(|r| r.ops.is_some()) {
            let chunk = (self.monitor_interval.unwrap_or(1.0) * 64.0).max(60.0);
            let mut t = eng.now();
            // Even unscaled paper runs finish within ~1e5 simulated
            // seconds; 1e8 is far past any legitimate scenario.
            while pending(runs) {
                t += chunk;
                eng.run_until(t);
                assert!(t < 1e8, "{} did not converge by t={t:.0}s", stalled(runs));
            }
            if let Some(m) = mon {
                m.borrow_mut().disable();
            }
            for r in runs {
                if let Some(o) = &r.ops {
                    o.borrow_mut().disable();
                }
            }
            eng.run();
        } else {
            eng.run();
        }
    }

    /// Fold a finished run (plus the shared network's counters) into its
    /// report.
    fn assemble(&self, run: &ActiveRun, mon: Option<MonitorHandle>) -> RunReport {
        let ActiveRun { sc, cluster, nodes, outcome, ops, times } = run;
        let out = outcome
            .borrow_mut()
            .take()
            .unwrap_or_else(|| panic!("scenario '{}' did not complete", sc.name));

        let mut metrics: Vec<(String, f64)> = Vec::new();
        let mut service_report: Option<ServiceReport> = None;
        let finished_at = match out {
            Outcome::Hadoop { finished_at, job1, job2 } => {
                metrics.push(("job1_makespan".to_string(), job1.makespan));
                metrics.push(("job1_map_phase".to_string(), job1.map_phase));
                metrics.push(("job1_shuffle_bytes".to_string(), job1.shuffle_bytes));
                metrics.push(("job1_output_bytes".to_string(), job1.output_bytes));
                metrics.push(("job2_makespan".to_string(), job2.makespan));
                metrics.push(("maps".to_string(), job1.maps as f64));
                metrics.push(("reduces".to_string(), job1.reduces as f64));
                // Per-layer accounting from the shared framework runtime.
                metrics.push((
                    "storage_read_bytes".to_string(),
                    job1.storage_read_bytes + job2.storage_read_bytes,
                ));
                metrics.push((
                    "storage_write_bytes".to_string(),
                    job1.storage_write_bytes + job2.storage_write_bytes,
                ));
                metrics.push((
                    "exchange_bytes".to_string(),
                    job1.shuffle_bytes + job2.shuffle_bytes,
                ));
                metrics.push((
                    "exchange_remote_bytes".to_string(),
                    job1.shuffle_remote_bytes + job2.shuffle_remote_bytes,
                ));
                metrics.push((
                    "stolen_tasks".to_string(),
                    (job1.stolen_maps + job2.stolen_maps) as f64,
                ));
                metrics.push((
                    "reexecuted_tasks".to_string(),
                    (job1.reexecuted_tasks + job2.reexecuted_tasks) as f64,
                ));
                finished_at
            }
            Outcome::Sphere { finished_at, report } => {
                metrics.push(("scan_phase".to_string(), report.scan_phase));
                metrics.push(("aggregate_phase".to_string(), report.aggregate_phase));
                metrics.push(("segments".to_string(), report.segments as f64));
                metrics.push(("stolen_segments".to_string(), report.stolen_segments as f64));
                // Per-layer accounting from the shared framework runtime;
                // `exchange_bytes`/`exchange_remote_bytes` mean the same
                // thing for every framework (total incl. node-local /
                // network-crossing subset).
                metrics.push(("exchange_bytes".to_string(), report.exchange_total_bytes));
                metrics.push(("exchange_remote_bytes".to_string(), report.exchange_bytes));
                metrics.push(("storage_read_bytes".to_string(), report.storage_read_bytes));
                metrics.push(("storage_write_bytes".to_string(), report.storage_write_bytes));
                metrics.push(("stolen_tasks".to_string(), report.stolen_segments as f64));
                metrics.push((
                    "reexecuted_tasks".to_string(),
                    report.reexecuted_segments as f64,
                ));
                finished_at
            }
            Outcome::FlowChurn { finished_at, flows, peak_inflight, peak_active } => {
                metrics.push(("flows".to_string(), flows as f64));
                metrics.push(("peak_inflight".to_string(), peak_inflight as f64));
                metrics.push(("peak_active".to_string(), peak_active as f64));
                metrics.push((
                    "net_completions".to_string(),
                    cluster.net.borrow().completions() as f64,
                ));
                finished_at
            }
            Outcome::Service { finished_at, report } => {
                metrics.extend(report.metrics());
                service_report = Some(report);
                finished_at
            }
        };
        // Provisioned and tenant runs report their admission/provisioning
        // split; plain runs keep their pre-provisioning metric set.
        if !sc.provisioning.is_empty() || sc.tenancy.is_some() {
            let t = times.borrow();
            metrics.push(("queued_secs".to_string(), t.queued_secs));
            metrics.push(("imaging_secs".to_string(), t.imaging_secs));
            metrics.push(("lightpath_setup_secs".to_string(), t.lightpath_setup_secs));
            metrics.push(("provision_secs".to_string(), t.started_at - t.admitted_at));
            metrics.push(("started_secs".to_string(), t.started_at));
            metrics.push(("workload_secs".to_string(), finished_at - t.started_at));
        }
        metrics.sort_by(|a, b| a.0.cmp(&b.0));

        let netb = cluster.net.borrow();
        let site_flows: Vec<SiteFlow> = cluster
            .topo
            .sites
            .iter()
            .enumerate()
            .map(|(i, site)| {
                let mut tx = 0.0;
                let mut rx = 0.0;
                for rid in &site.racks {
                    tx += netb.link_bytes(cluster.topo.racks[rid.0].uplink_tx);
                    rx += netb.link_bytes(cluster.topo.racks[rid.0].uplink_rx);
                }
                SiteFlow {
                    site: site.name.clone(),
                    nodes_used: nodes.iter().filter(|&&n| cluster.topo.node(n).site.0 == i).count(),
                    uplink_tx_bytes: tx,
                    uplink_rx_bytes: rx,
                }
            })
            .collect();
        // The monitor drains WAN byte counters as it samples; add the
        // observed series back to the residual for the run total.
        let mut wan_bytes: f64 = cluster
            .topo
            .links
            .iter()
            .enumerate()
            .filter(|(_, l)| l.kind == LinkKind::Wan)
            .map(|(i, _)| netb.link_bytes(LinkId(i)))
            .sum();
        let monitor = mon.map(|m| {
            let m = m.borrow();
            wan_bytes += m.wan_bytes_observed();
            let busy = cluster
                .topo
                .node_ids()
                .iter()
                .filter(|&&n| m.node_nic_rate(n, usize::MAX) > 0.0)
                .count();
            let (nic_rate_p50, nic_rate_p99) = m.nic_rate_quantiles(usize::MAX);
            MonitorSummary {
                samples: m.samples_taken(),
                busy_nodes: busy,
                nic_rate_p50,
                nic_rate_p99,
            }
        });
        let ops_report = ops.as_ref().map(|o| o.borrow().report());

        RunReport {
            scenario: sc.name.clone(),
            framework: sc.framework.name().to_string(),
            variant: sc.workload.variant.letter().to_string(),
            topology: sc.topology.label(),
            placement: sc.placement.label(),
            nodes: nodes.len(),
            total_records: sc.workload.total_records,
            simulated_secs: finished_at,
            paper_secs: sc.paper_secs,
            wan_bytes,
            site_flows,
            metrics,
            monitor,
            ops: ops_report,
            service: service_report,
            profile: ProfileReport::default(),
            wall: None,
        }
    }

    /// Run a slice of scenarios in order.
    pub fn run_all(&self, scenarios: &[Scenario]) -> Vec<RunReport> {
        scenarios.iter().map(|sc| self.run(sc)).collect()
    }

    /// Run a group of tenant scenarios concurrently on **one** shared
    /// testbed (one engine, one fluid network, one CPU-pool set).
    ///
    /// Each tenant asks a [`SliceScheduler`] for a [`Slice`]
    /// (`PerSite(n)` nodes from every site plus an optional lightpath
    /// grant); admission is FIFO, and a tenant that does not fit the
    /// finite inventory queues until a running tenant completes and
    /// releases. Tenant names must be unique within a group, and every
    /// scenario must declare the same topology — the group shares one
    /// testbed, built from the first scenario's spec. A granted tenant
    /// gets a *dedicated wave*: pre-added
    /// dark to the shared fiber plant, routed only by that tenant's
    /// topology view, lit at admission after the signalling latency, and
    /// darkened again at release; grantless tenants share the testbed's
    /// default wave. Reports come back in input order with
    /// `queued_secs` / `provision_secs` / `workload_secs` separating
    /// waiting, provisioning, and running; network byte counters
    /// (`wan_bytes`, site flows) are testbed-wide totals shared by every
    /// tenant's report. Fault plans, the ops plane, and the monitor are
    /// not composed with multi-tenancy yet.
    pub fn run_tenants(&self, scenarios: &[Scenario]) -> Vec<RunReport> {
        self.run_tenants_traced(scenarios).0
    }

    /// The traced core of [`ScenarioRunner::run_tenants`]: one engine
    /// (hence one recorder) serves the whole group, so the group shares
    /// one merged stream — and, like wall stats, one group-wide
    /// [`ProfileReport`] per report. Tracing turns on when the runner
    /// override or *any* tenant scenario carries a [`TraceSpec`].
    fn run_tenants_traced(&self, scenarios: &[Scenario]) -> (Vec<RunReport>, Stream) {
        // simlint: allow(SIM002) — wall-clock timing *about* the shared-testbed run; it never feeds back into simulated time.
        let t0 = std::time::Instant::now();
        assert!(!scenarios.is_empty(), "empty tenant group");
        assert!(
            self.monitor_interval.is_none() && self.ops_override.is_none(),
            "monitor/ops are not composed with multi-tenancy yet"
        );
        for sc in scenarios {
            assert!(
                sc.tenancy.is_some(),
                "run_tenants takes tenant-marked scenarios ('{}')",
                sc.name
            );
            assert!(
                sc.fault_plan.is_empty() && sc.ops.is_none(),
                "fault/ops axes are not composed with multi-tenancy yet ('{}')",
                sc.name
            );
            // The group shares ONE testbed, built from the first
            // scenario's spec — a tenant declaring a different topology
            // would silently run on the wrong hardware.
            assert!(
                sc.topology.label() == scenarios[0].topology.label(),
                "tenant scenario '{}' declares topology '{}' but the group runs on '{}'",
                sc.name,
                sc.topology.label(),
                scenarios[0].topology.label()
            );
        }
        let mut seen = BTreeSet::new();
        for sc in scenarios {
            let tenant = &sc.tenancy.as_ref().unwrap().tenant;
            assert!(seen.insert(tenant.clone()), "duplicate tenant '{tenant}' in one group");
        }
        // One shared physical testbed from the first scenario's spec,
        // with a dark wave pre-added per lightpath tenant: the fluid
        // network's link set is fixed at construction, so the lambda
        // exists from t=0 (at granted capacity in the topology, for the
        // transport models' nominal-rate caps) and admission lights it.
        let mut master = scenarios[0].topology.build();
        let sites: Vec<SiteId> = (0..master.sites.len()).map(SiteId).collect();
        let waves: Vec<Option<(LinkId, LinkId)>> = scenarios
            .iter()
            .map(|sc| {
                sc.provisioning.lightpath.as_ref().map(|lp| {
                    let tenant = &sc.tenancy.as_ref().unwrap().tenant;
                    master.add_wave(lp.gbps * 1e9 / 8.0, tenant)
                })
            })
            .collect();
        let cluster = Cluster::with_config(master, self.flow_cfg);
        let mut sched = SliceScheduler::new(cluster.topo.clone(), DEFAULT_SPARE_WAVE_GBPS);
        let mut eng = Engine::new();
        let spec = self
            .trace_override
            .clone()
            .or_else(|| scenarios.iter().find_map(|sc| sc.trace.clone()));
        if let Some(spec) = spec {
            eng.set_recorder(Recorder::new(&spec));
        }
        // Dark waves idle at the control floor until their tenant lights
        // them through its provisioning phase.
        let dark: Vec<(LinkId, f64)> = waves
            .iter()
            .flatten()
            .flat_map(|&(east, west)| [(east, LIGHTPATH_FLOOR_BPS), (west, LIGHTPATH_FLOOR_BPS)])
            .collect();
        FlowNet::set_capacities(&cluster.net, &mut eng, &dark);

        struct Tenant {
            run: Option<ActiveRun>,
            slice: Option<Slice>,
            released: bool,
        }
        let mut tenants: Vec<Tenant> = scenarios
            .iter()
            .map(|_| Tenant { run: None, slice: None, released: false })
            .collect();
        let mut queue: VecDeque<usize> = (0..scenarios.len()).collect();
        loop {
            // Completed tenants return their slice (and darken their
            // wave — the runtime teardown) so queued tenants can admit.
            for t in tenants.iter_mut() {
                if t.released {
                    continue;
                }
                let done = t.run.as_ref().is_some_and(|r| r.outcome.borrow().is_some());
                if done {
                    let slice = t.slice.as_ref().expect("launched tenant has a slice");
                    if let Some((east, west)) = slice.wave {
                        FlowNet::set_capacities(
                            &cluster.net,
                            &mut eng,
                            &[(east, LIGHTPATH_FLOOR_BPS), (west, LIGHTPATH_FLOOR_BPS)],
                        );
                    }
                    sched.release(slice);
                    t.released = true;
                }
            }
            // FIFO admission from the head while the inventory fits.
            while let Some(&i) = queue.front() {
                let sc = &scenarios[i];
                let per_site = match sc.placement {
                    Placement::PerSite(n) => n,
                    _ => panic!("tenant scenario '{}' must use PerSite placement", sc.name),
                };
                let grant = sc.provisioning.lightpath.as_ref().map(|lp| lp.gbps);
                let tenant = sc.tenancy.as_ref().unwrap().tenant.clone();
                match sched.try_carve(&tenant, per_site, grant, waves[i]) {
                    None => break, // the head waits for a release
                    Some(slice) => {
                        queue.pop_front();
                        let t = eng.now();
                        if let Some(rec) = eng.recorder() {
                            let dom = cluster.topo.num_domains() as u16;
                            let a = [("tenant", Arg::S(tenant.clone()))];
                            rec.instant(t, dom, 0, "tenant.admit", 0, &a);
                        }
                        // The tenant's view of the shared testbed: same
                        // nodes, racks, and substrate handles, but its
                        // own wide-area routing. Grantless tenants ride
                        // the default wave — their view IS the master,
                        // so share the Rc instead of deep-cloning.
                        let topo = match waves[i] {
                            Some((east, west)) => {
                                let mut view = (*cluster.topo).clone();
                                view.route_over_wave(&sites, east, west);
                                Rc::new(view)
                            }
                            None => cluster.topo.clone(),
                        };
                        let vcluster = Cluster {
                            topo,
                            net: cluster.net.clone(),
                            pools: cluster.pools.clone(),
                        };
                        let ctx = LaunchCtx {
                            queued_secs: eng.now(),
                            nodes: Some(slice.nodes.clone()),
                            wave_links: waves[i].map(|(east, west)| vec![east, west]),
                        };
                        let run = self.launch(&vcluster, sc, &mut eng, ctx);
                        tenants[i].run = Some(run);
                        tenants[i].slice = Some(slice);
                    }
                }
            }
            if tenants.iter().all(|t| t.released) {
                break;
            }
            assert!(
                eng.step(),
                "tenancy group stalled: a queued slice request exceeds the total inventory"
            );
        }
        eng.run(); // drain trailing events (teardown timers etc.)
        // One engine ran the whole group, so every tenant's report
        // carries the same (group-wide) wall stats, profile counters,
        // and trace stream.
        let wall_secs = t0.elapsed().as_secs_f64();
        let wall = Some(WallStats {
            wall_secs,
            events_per_sec: if wall_secs > 0.0 { eng.executed() as f64 / wall_secs } else { 0.0 },
        });
        let mut profile = eng.profile();
        let (refills, dirty) = cluster.net.borrow().profile_counters();
        profile.refill_components += refills;
        profile.dirty_links += dirty;
        let mut stream = Stream::new(cluster.topo.sites.len());
        if let Some(rec) = eng.take_recorder() {
            stream.absorb(rec);
        }
        let reps = tenants
            .iter()
            .map(|t| {
                let mut rep = self.assemble(t.run.as_ref().expect("tenant never launched"), None);
                rep.wall = wall;
                rep.profile = profile.clone();
                rep
            })
            .collect();
        (reps, stream)
    }

    /// Run a whole [`ScenarioSet`]: solo scenarios sequentially (each on
    /// a fresh testbed), then each tenancy group concurrently through
    /// [`ScenarioRunner::run_tenants`]. Reports come back in the set's
    /// scenario order regardless of execution order, so shape checks
    /// index as usual.
    pub fn run_set(&self, set: &ScenarioSet) -> Vec<RunReport> {
        self.run_set_traced(set).0
    }

    /// Like [`ScenarioRunner::run_set`], also returning the set's merged
    /// trace: per-scenario streams concatenated in set order (the
    /// canonical export re-sorts by time within each run's events).
    pub fn run_set_with_trace(&self, set: &ScenarioSet) -> (Vec<RunReport>, Stream) {
        self.run_set_traced(set)
    }

    fn run_set_traced(&self, set: &ScenarioSet) -> (Vec<RunReport>, Stream) {
        let mut out: Vec<Option<RunReport>> = Vec::new();
        out.resize_with(set.scenarios.len(), || None);
        let mut stream = Stream::new(0);
        let mut groups: BTreeMap<u32, Vec<usize>> = BTreeMap::new();
        for (i, sc) in set.scenarios.iter().enumerate() {
            match &sc.tenancy {
                None => {
                    let (rep, s) = self.run_traced(sc);
                    out[i] = Some(rep);
                    stream.append(s);
                }
                Some(t) => groups.entry(t.group).or_default().push(i),
            }
        }
        for idxs in groups.into_values() {
            let group: Vec<Scenario> = idxs.iter().map(|&i| set.scenarios[i].clone()).collect();
            let (reps, s) = self.run_tenants_traced(&group);
            stream.append(s);
            for (i, rep) in idxs.iter().zip(reps) {
                out[*i] = Some(rep);
            }
        }
        (out.into_iter().map(|r| r.expect("every scenario ran")).collect(), stream)
    }
}

/// Kick off the scenario's framework on the engine — the workload
/// proper; any provisioning latency has already been paid by the caller.
fn start_framework(
    cluster: &Cluster,
    nodes: &[NodeId],
    sc: &Scenario,
    eng: &mut Engine,
    outcome: &Rc<RefCell<Option<Outcome>>>,
    control: &Rc<RefCell<Option<DataflowControl>>>,
    failed: &Rc<RefCell<BTreeSet<NodeId>>>,
) {
    match sc.framework {
        Framework::SectorSphere => {
            start_sphere(cluster, nodes, &sc.workload, eng, outcome.clone(), control)
        }
        Framework::FlowChurn => {
            start_flow_churn(cluster, nodes, &sc.workload, eng, outcome.clone())
        }
        Framework::MegaChurn => {
            start_mega_churn(cluster, nodes, &sc.workload, eng, outcome.clone())
        }
        Framework::Service => start_service(cluster, nodes, sc, eng, outcome.clone()),
        _ => {
            let params = sc.framework.params();
            let storage = build_storage(sc.framework, cluster, nodes, &params);
            start_mapreduce(
                cluster,
                nodes,
                params,
                storage,
                &sc.workload,
                eng,
                outcome.clone(),
                control.clone(),
                failed.clone(),
            )
        }
    }
}

/// Per-node install+reboot time after the image lands on disk, on top of
/// the disk-speed write of the image itself.
const IMAGE_BOOT_SECS: f64 = 30.0;

/// The site's image depot: the first node of the site's first rack. A
/// depot serves every tenant's fetches (it is infrastructure, not tenant
/// compute), so imaging contention across concurrent slices is real.
fn image_depot(topo: &Topology, n: NodeId) -> NodeId {
    let site = topo.node(n).site;
    topo.racks[topo.sites[site.0].racks[0].0].nodes[0]
}

/// Image every placed node: fetch the image from the node's site depot
/// as a real flow (depot NICs are the bottleneck when a whole slice
/// images at once), then write it to disk and reboot. Arrives on `done`
/// when the last node reports Ready, recording `imaging_secs`.
fn start_imaging(
    cluster: &Cluster,
    nodes: &[NodeId],
    img: &ImageSpec,
    eng: &mut Engine,
    done: Rc<Countdown>,
    times: Rc<RefCell<ProvisionTimes>>,
) {
    let admitted = eng.now();
    let dom = cluster.topo.num_domains() as u16; // control pseudo-domain
    let mut span = 0;
    if let Some(rec) = eng.recorder() {
        span = rec.fresh_id();
        let a = [("image", Arg::S(img.name.clone())), ("bytes", Arg::F(img.bytes))];
        rec.begin(admitted, dom, 0, "provision.image", span, &a);
    }
    let all = Countdown::new(nodes.len(), move |eng| {
        let t = eng.now();
        times.borrow_mut().imaging_secs = t - admitted;
        if span != 0 {
            if let Some(rec) = eng.recorder() {
                rec.end(t, dom, 0, "provision.image", span, &[]);
            }
        }
        done.arrive(eng);
    });
    for &n in nodes {
        let depot = image_depot(&cluster.topo, n);
        let install = img.bytes / cluster.topo.link(cluster.topo.node(n).disk).capacity
            + IMAGE_BOOT_SECS;
        let all2 = all.clone();
        let finish = move |eng: &mut Engine| {
            eng.schedule_in(install, move |eng| all2.arrive(eng));
        };
        if depot == n {
            // The depot images itself from its local copy: install only.
            eng.schedule_in(0.0, finish);
        } else {
            let route = cluster.topo.route(depot, n);
            FlowNet::start_route(&cluster.net, eng, route, img.bytes, f64::INFINITY, finish);
        }
    }
}

/// Light a lightpath: the wave's links drop to the control floor at
/// request time, and after the signalling latency the grant lands at
/// `gbps` per direction — only then does the workload start. Grants
/// below nominal model an under-provisioned path.
fn start_lightpath(
    cluster: &Cluster,
    links: &[LinkId],
    lp: &LightpathSpec,
    eng: &mut Engine,
    done: Rc<Countdown>,
    times: Rc<RefCell<ProvisionTimes>>,
) {
    assert!(!links.is_empty(), "lightpath grant on a WAN-less topology");
    let requested = eng.now();
    let wan_dom = (cluster.topo.num_domains() - 1) as u16;
    let mut span = 0;
    if let Some(rec) = eng.recorder() {
        span = rec.fresh_id();
        let a = [("gbps", Arg::F(lp.gbps))];
        rec.begin(requested, wan_dom, 0, "provision.lightpath", span, &a);
    }
    let floor: Vec<(LinkId, f64)> = links.iter().map(|&l| (l, LIGHTPATH_FLOOR_BPS)).collect();
    FlowNet::set_capacities(&cluster.net, eng, &floor);
    let grant: Vec<(LinkId, f64)> = links.iter().map(|&l| (l, lp.gbps * 1e9 / 8.0)).collect();
    let net = cluster.net.clone();
    let setup = lp.setup_secs;
    eng.schedule_in(setup, move |eng| {
        FlowNet::set_capacities(&net, eng, &grant);
        times.borrow_mut().lightpath_setup_secs = setup;
        let t = eng.now();
        if span != 0 {
            if let Some(rec) = eng.recorder() {
                rec.end(t, wan_dom, 0, "provision.lightpath", span, &[]);
            }
        }
        done.arrive(eng);
    });
}

/// Names of the runs still awaiting an outcome (convergence diagnostics).
fn stalled(runs: &[ActiveRun]) -> String {
    let names: Vec<&str> = runs
        .iter()
        .filter(|r| r.outcome.borrow().is_none())
        .map(|r| r.sc.name.as_str())
        .collect();
    format!("scenario(s) [{}]", names.join(", "))
}

/// Every WAN-kind link of a topology (the default target of a solo run's
/// lightpath grant: the testbed's shared wave).
fn wan_kind_links(topo: &Topology) -> Vec<LinkId> {
    topo.links
        .iter()
        .enumerate()
        .filter(|(_, l)| l.kind == LinkKind::Wan)
        .map(|(i, _)| LinkId(i))
        .collect()
}

/// The storage layer a framework's jobs write through — where the §7
/// interop compositions diverge from the stock stacks.
fn build_storage(
    fw: Framework,
    cluster: &Cluster,
    nodes: &[NodeId],
    params: &FrameworkParams,
) -> Rc<RefCell<dyn StorageModel>> {
    match fw {
        Framework::CloudStoreMr => Rc::new(RefCell::new(KfsStorage::new(
            cluster.topo.clone(),
            nodes.to_vec(),
            params.output_replication,
            42,
        ))),
        Framework::HadoopOverSector => Rc::new(RefCell::new(SectorStorage::new())),
        _ => {
            let nn = Rc::new(RefCell::new(Namenode::with_members(
                cluster.topo.clone(),
                HdfsConfig { replication: params.output_replication, ..Default::default() },
                42,
                nodes.to_vec(),
            )));
            Rc::new(RefCell::new(HdfsStorage::new(nn, params.output_replication)))
        }
    }
}

/// Wire the ops plane's closed-loop remediation into the live substrate:
/// a `Dead` verdict heals the running dataflow (drain + re-execute its
/// lost tasks on survivors), and a degraded-wave verdict re-provisions
/// the shared wave back to nominal capacity.
fn install_remediation(
    plane: &Rc<RefCell<OpsPlane>>,
    cluster: &Cluster,
    control: &Rc<RefCell<Option<DataflowControl>>>,
) {
    let ctrl = control.clone();
    plane.borrow_mut().set_dead_hook(Box::new(move |eng, node| {
        let c = ctrl.borrow().clone();
        match c {
            Some(c) => c.heal_node(eng, node),
            None => 0,
        }
    }));
    // Restore targets come from the plane's own install-time snapshot, so
    // detection threshold and remediation target can never disagree.
    let nominal = plane.borrow().wan_nominals().to_vec();
    if !nominal.is_empty() {
        let net = cluster.net.clone();
        plane.borrow_mut().set_wan_restore_hook(Box::new(move |eng| {
            FlowNet::set_capacities(&net, eng, &nominal);
        }));
    }
}

/// Every WAN link with its current (nominal, pre-fault) capacity.
fn wan_capacities(cluster: &Cluster) -> Vec<(LinkId, f64)> {
    let netb = cluster.net.borrow();
    cluster
        .topo
        .links
        .iter()
        .enumerate()
        .filter(|(_, l)| l.kind == LinkKind::Wan)
        .map(|(i, _)| (LinkId(i), netb.capacity(LinkId(i))))
        .collect()
}

/// Schedule the scenario's fault plan onto the engine: crashes darken the
/// node's sensor and doom the dataflow's in-flight work; NIC and
/// lightpath degradations retune fluid-network capacities mid-run.
fn schedule_faults(
    sc: &Scenario,
    cluster: &Cluster,
    nodes: &[NodeId],
    eng: &mut Engine,
    ops: &Option<Rc<RefCell<OpsPlane>>>,
    control: &Rc<RefCell<Option<DataflowControl>>>,
    failed: &Rc<RefCell<BTreeSet<NodeId>>>,
) {
    for ev in &sc.fault_plan.events {
        match ev.fault {
            Fault::NodeCrash { node } => {
                assert!(node < nodes.len(), "crash target {node} outside the placement");
                let n = nodes[node];
                let dom = cluster.topo.node(n).site.0 as u16;
                let plane = ops.as_ref().expect("a fault plan implies the ops plane").clone();
                let ctrl = control.clone();
                let failed = failed.clone();
                eng.schedule_at(ev.at, move |eng| {
                    let t = eng.now();
                    if let Some(rec) = eng.recorder() {
                        rec.instant(t, dom, n.0 as u32, "fault.crash", 0, &[]);
                    }
                    failed.borrow_mut().insert(n);
                    plane.borrow_mut().mark_crashed(n, eng.now());
                    let c = ctrl.borrow().clone();
                    if let Some(c) = c {
                        c.crash_node(n);
                    }
                });
            }
            Fault::NicDegrade { node, factor } => {
                assert!(node < nodes.len(), "degrade target {node} outside the placement");
                let nd = cluster.topo.node(nodes[node]);
                let dom = nd.site.0 as u16;
                let lane = nodes[node].0 as u32;
                let (tx, rx) = (nd.nic_tx, nd.nic_rx);
                let (ctx, crx) = {
                    let netb = cluster.net.borrow();
                    (netb.capacity(tx), netb.capacity(rx))
                };
                let net = cluster.net.clone();
                eng.schedule_at(ev.at, move |eng| {
                    let t = eng.now();
                    if let Some(rec) = eng.recorder() {
                        let a = [("factor", Arg::F(factor))];
                        rec.instant(t, dom, lane, "fault.nic", 0, &a);
                    }
                    FlowNet::set_capacity(&net, eng, tx, ctx * factor);
                    FlowNet::set_capacity(&net, eng, rx, crx * factor);
                });
            }
            Fault::LightpathFlap { factor } => {
                let wan = wan_capacities(cluster);
                assert!(!wan.is_empty(), "lightpath flap on a WAN-less topology");
                let wan_dom = (cluster.topo.num_domains() - 1) as u16;
                let net = cluster.net.clone();
                eng.schedule_at(ev.at, move |eng| {
                    let t = eng.now();
                    if let Some(rec) = eng.recorder() {
                        let a = [("factor", Arg::F(factor))];
                        rec.instant(t, wan_dom, 0, "fault.wave", 0, &a);
                    }
                    for &(l, cap) in &wan {
                        FlowNet::set_capacity(&net, eng, l, cap * factor);
                    }
                });
            }
        }
    }
}

/// Run the two chained MalStone MapReduce jobs over `storage`, publishing
/// each job's [`DataflowControl`] so the ops plane can fail/heal workers
/// mid-run.
#[allow(clippy::too_many_arguments)]
fn start_mapreduce(
    cluster: &Cluster,
    nodes: &[NodeId],
    params: FrameworkParams,
    storage: Rc<RefCell<dyn StorageModel>>,
    w: &WorkloadSpec,
    eng: &mut Engine,
    out: Rc<RefCell<Option<Outcome>>>,
    control: Rc<RefCell<Option<DataflowControl>>>,
    failed: Rc<RefCell<BTreeSet<NodeId>>>,
) {
    let shards = uniform_shards(nodes, w.total_records);
    let (job1, job2_of) =
        malstone_jobs(&params, nodes, &shards, w.variant.is_b(), 64 * 1024 * 1024);
    let cluster2 = cluster.clone();
    let storage2 = storage.clone();
    let control2 = control.clone();
    let c1 = MapReduceEngine::simulate_on(cluster, storage, eng, job1, move |eng, r1| {
        // The chained aggregate job is submitted against the testbed's
        // live membership: a crashed node never re-registers. Its crash
        // marks carry over so any job-1 output stranded on a dead box is
        // re-read from a survivor (the storage-read redirect).
        let mut job2 = job2_of(&r1);
        let dead = failed.borrow().clone();
        if !dead.is_empty() {
            job2.nodes.retain(|n| !dead.contains(n));
            assert!(!job2.nodes.is_empty(), "every worker crashed");
        }
        let out2 = out.clone();
        let c2 = MapReduceEngine::simulate_on(&cluster2, storage2, eng, job2, move |eng, r2| {
            *out2.borrow_mut() =
                Some(Outcome::Hadoop { finished_at: eng.now(), job1: r1, job2: r2 });
        });
        for &n in &dead {
            c2.crash_node(n);
        }
        *control2.borrow_mut() = Some(c2);
    });
    *control.borrow_mut() = Some(c1);
}

/// How many transfers the flow-churn driver keeps in flight for a run of
/// `total` transfers: a quarter of the run, floored at 1 and capped at
/// 6000 (thousands of concurrent flows at paper scale, a handful in
/// scaled-down test runs). Shared with the registry's shape check.
pub fn flow_churn_concurrency(total: u64) -> u64 {
    (total / 4).clamp(1, 6000)
}

/// The fluid-network stress driver behind [`Framework::FlowChurn`]: keep a
/// target number of point-to-point transfers in flight between random
/// placed nodes (Sector segment shuttles over UDT, shuffle fetches over
/// TCP), replacing each completed transfer with a fresh one until `total`
/// have run. Every arrival and departure reallocates the whole network —
/// the churn path the slab/per-link-index rework exists for.
fn start_flow_churn(
    cluster: &Cluster,
    nodes: &[NodeId],
    w: &WorkloadSpec,
    eng: &mut Engine,
    out: Rc<RefCell<Option<Outcome>>>,
) {
    assert!(nodes.len() >= 2, "flow churn needs at least two nodes");
    let total = w.total_records.max(1);
    let target = flow_churn_concurrency(total);
    let st = Rc::new(RefCell::new(ChurnState {
        rng: Rng::new(0x0C7_C4A11),
        launched: 0,
        done: 0,
        peak_inflight: 0,
    }));
    // The churn path only needs the net and topology handles; cloning the
    // whole Cluster per transfer would copy its pools Vec into every
    // pending completion closure.
    let env = Rc::new(ChurnEnv {
        net: cluster.net.clone(),
        topo: cluster.topo.clone(),
        nodes: nodes.to_vec(),
    });
    for _ in 0..target.min(total) {
        launch_churn_flow(&env, total, eng, &st, &out);
    }
}

/// Shared immutable context of one churn run (a single `Rc` per closure).
struct ChurnEnv {
    net: Rc<RefCell<FlowNet>>,
    topo: Rc<Topology>,
    nodes: Vec<NodeId>,
}

struct ChurnState {
    rng: Rng,
    launched: u64,
    done: u64,
    /// Most transfers simultaneously in flight (launched − done): equals
    /// the driver's target by construction — a bookkeeping figure. The
    /// independent observable is [`FlowNet::peak_active`].
    peak_inflight: u64,
}

fn launch_churn_flow(
    env: &Rc<ChurnEnv>,
    total: u64,
    eng: &mut Engine,
    st: &Rc<RefCell<ChurnState>>,
    out: &Rc<RefCell<Option<Outcome>>>,
) {
    let (src, dst, bytes, proto) = {
        let mut s = st.borrow_mut();
        s.launched += 1;
        let inflight = s.launched - s.done;
        if inflight > s.peak_inflight {
            s.peak_inflight = inflight;
        }
        let src = env.nodes[s.rng.gen_range(env.nodes.len() as u64) as usize];
        let mut dst = src;
        while dst == src {
            dst = env.nodes[s.rng.gen_range(env.nodes.len() as u64) as usize];
        }
        // Segment-sized transfers (1–64 MB), half over UDT, half over TCP.
        let bytes = (1.0 + s.rng.f64() * 63.0) * 1e6;
        let proto = if s.rng.chance(0.5) { Protocol::udt() } else { Protocol::tcp() };
        (src, dst, bytes, proto)
    };
    let env2 = env.clone();
    let st2 = st.clone();
    let out2 = out.clone();
    transport::send(&env.net, &env.topo, eng, src, dst, bytes, &proto, move |eng| {
        let (done, launched) = {
            let mut s = st2.borrow_mut();
            s.done += 1;
            (s.done, s.launched)
        };
        if launched < total {
            launch_churn_flow(&env2, total, eng, &st2, &out2);
        } else if done == total {
            let s = st2.borrow();
            *out2.borrow_mut() = Some(Outcome::FlowChurn {
                finished_at: eng.now(),
                flows: s.done,
                peak_inflight: s.peak_inflight,
                // Exact network-level concurrency, tracked by the net
                // itself (no completion-batch sampling skew).
                peak_active: env2.net.borrow().peak_active() as u64,
            });
        }
    });
}

/// How many transfers the mega-churn driver keeps in flight for a run of
/// `total` transfers: a quarter of the run, floored at 1 and capped at
/// 150 000 (~100k concurrent at the registry set's full scale). Shared
/// with the registry's shape check.
pub fn mega_churn_concurrency(total: u64) -> u64 {
    (total / 4).clamp(1, 150_000)
}

/// Of every 16 mega-churn slots, one rides the shared wide-area wave;
/// the rest stay on their intra-rack partner pair.
const MEGA_WAN_SLOT_STRIDE: u64 = 16;

/// The flow-domain stress driver behind [`Framework::MegaChurn`]: keep a
/// very large number of transfers in flight, but *structured* — each
/// concurrency slot is pinned to a disjoint intra-rack partner pair
/// (pair traffic touches only the two NICs involved, since the ToR is
/// non-blocking), with every sixteenth slot drawing a cross-site pair
/// from a small per-rack WAN pool instead. Arrivals and departures on a
/// pair therefore dirty a two-link flow component no matter how many
/// other pairs are storming — the workload incremental water-filling
/// and same-path aggregation exist for. A per-flow global reallocator
/// pays O(all flows) on every one of those events; that asymmetry is
/// what the `flow_scale` bench measures.
fn start_mega_churn(
    cluster: &Cluster,
    nodes: &[NodeId],
    w: &WorkloadSpec,
    eng: &mut Engine,
    out: Rc<RefCell<Option<Outcome>>>,
) {
    assert!(nodes.len() >= 2, "mega churn needs at least two nodes");
    let total = w.total_records.max(1);
    let target = mega_churn_concurrency(total);
    let (pairs, wan_pool) = mega_pairs(&cluster.topo, nodes);
    let st = Rc::new(RefCell::new(ChurnState {
        rng: Rng::new(0x0C7_3E6A),
        launched: 0,
        done: 0,
        peak_inflight: 0,
    }));
    let env = Rc::new(MegaEnv {
        net: cluster.net.clone(),
        topo: cluster.topo.clone(),
        pairs,
        wan_pool,
    });
    for slot in 0..target.min(total) {
        launch_mega_flow(&env, total, slot, eng, &st, &out);
    }
}

/// The mega-churn traffic structure, shared by the sequential and
/// sharded drivers: group the placement by rack, reserve the last two
/// placed nodes of each full rack group for the WAN pool, and pair off
/// the rest. Pair and pool node sets are disjoint by construction — the
/// property the sharded driver's link-claim partition rests on.
fn mega_pairs(topo: &Topology, nodes: &[NodeId]) -> (Vec<(NodeId, NodeId)>, Vec<NodeId>) {
    let mut by_rack: BTreeMap<usize, Vec<NodeId>> = BTreeMap::new();
    for &n in nodes {
        by_rack.entry(topo.node(n).rack.0).or_default().push(n);
    }
    let mut pairs: Vec<(NodeId, NodeId)> = Vec::new();
    let mut wan_pool: Vec<NodeId> = Vec::new();
    for group in by_rack.values() {
        let (paired, pooled) =
            if group.len() >= 4 { group.split_at(group.len() - 2) } else { (&group[..], &[][..]) };
        let mut chunks = paired.chunks_exact(2);
        for c in &mut chunks {
            pairs.push((c[0], c[1]));
        }
        wan_pool.extend(chunks.remainder());
        wan_pool.extend(pooled);
    }
    (pairs, wan_pool)
}

/// Shared immutable context of one mega-churn run.
struct MegaEnv {
    net: Rc<RefCell<FlowNet>>,
    topo: Rc<Topology>,
    /// Disjoint intra-rack partner pairs; slot `i` drives pair `i % len`.
    pairs: Vec<(NodeId, NodeId)>,
    /// Cross-rack endpoints for the WAN slots.
    wan_pool: Vec<NodeId>,
}

fn launch_mega_flow(
    env: &Rc<MegaEnv>,
    total: u64,
    slot: u64,
    eng: &mut Engine,
    st: &Rc<RefCell<ChurnState>>,
    out: &Rc<RefCell<Option<Outcome>>>,
) {
    let (src, dst, bytes, proto) = {
        let mut s = st.borrow_mut();
        s.launched += 1;
        let inflight = s.launched - s.done;
        if inflight > s.peak_inflight {
            s.peak_inflight = inflight;
        }
        let wan_slot = env.wan_pool.len() >= 2
            && (env.pairs.is_empty() || slot % MEGA_WAN_SLOT_STRIDE == MEGA_WAN_SLOT_STRIDE - 1);
        let (src, dst) = if wan_slot {
            let src = env.wan_pool[s.rng.gen_range(env.wan_pool.len() as u64) as usize];
            let mut dst = src;
            while dst == src {
                dst = env.wan_pool[s.rng.gen_range(env.wan_pool.len() as u64) as usize];
            }
            (src, dst)
        } else {
            let (a, b) = env.pairs[(slot % env.pairs.len() as u64) as usize];
            if s.rng.chance(0.5) {
                (a, b)
            } else {
                (b, a)
            }
        };
        // Smaller than flow-churn's segments (1–16 MB) so slots turn
        // over quickly: the point is arrival/departure rate, not bytes.
        let bytes = (1.0 + s.rng.f64() * 15.0) * 1e6;
        let proto = if s.rng.chance(0.5) { Protocol::udt() } else { Protocol::tcp() };
        (src, dst, bytes, proto)
    };
    let env2 = env.clone();
    let st2 = st.clone();
    let out2 = out.clone();
    transport::send(&env.net, &env.topo, eng, src, dst, bytes, &proto, move |eng| {
        let (done, launched) = {
            let mut s = st2.borrow_mut();
            s.done += 1;
            (s.done, s.launched)
        };
        if launched < total {
            launch_mega_flow(&env2, total, slot, eng, &st2, &out2);
        } else if done == total {
            let s = st2.borrow();
            *out2.borrow_mut() = Some(Outcome::FlowChurn {
                finished_at: eng.now(),
                flows: s.done,
                peak_inflight: s.peak_inflight,
                peak_active: env2.net.borrow().peak_active() as u64,
            });
        }
    });
}

/// Modeled dispatch latency of a cross-domain mega-churn control message
/// (a coordinator command to start a WAN transfer, or the completion
/// report coming back). Together with
/// [`Topology::min_wan_owd`](crate::net::Topology::min_wan_owd) it is
/// the sharded engine's lookahead: no shard can affect another sooner.
const MEGA_CMD_SECS: f64 = 0.05;

/// Cross-shard control traffic of the sharded mega-churn driver.
enum MegaMsg {
    /// Home shard → WAN shard: run one WAN transfer for `slot`.
    Start { slot: u64, src: NodeId, dst: NodeId, bytes: f64, udt: bool },
    /// WAN shard → home shard: `slot`'s transfer completed.
    Done { slot: u64 },
}

/// One shard's final accounting, merged in shard-index order by
/// [`ScenarioRunner::run`]'s sharded path.
struct MegaOut {
    done: u64,
    peak_inflight: u64,
    peak_active: u64,
    net_completions: u64,
    finished_at: f64,
    executed: u64,
    /// Final byte counters of this shard's claimed links.
    link_bytes: Vec<(u32, f64)>,
    /// This shard's engine + flow-core hot-path counters.
    profile: ProfileReport,
    /// This shard's trace ring (`Some` only on traced runs), harvested
    /// off the engine at finish and merged in shard-index order.
    recorder: Option<Recorder>,
}

/// One concurrency slot owned by a shard: its private RNG stream and the
/// transfers it still owes.
struct MegaSlot {
    rng: Rng,
    remaining: u64,
    /// `Some` pins the slot to an intra-rack pair on this shard's own
    /// network; `None` marks a WAN slot whose transfers run remotely.
    pair: Option<(NodeId, NodeId)>,
}

struct MegaState {
    slots: BTreeMap<u64, MegaSlot>,
    launched: u64,
    done: u64,
    peak_inflight: u64,
    /// WAN transfers commanded but not yet reported done.
    outstanding: u64,
}

/// Shared immutable context of one mega-churn shard (the sharded
/// counterpart of [`MegaEnv`]); engine events capture it by `Rc`.
struct MegaEnvS {
    wan_shard: usize,
    topo: Rc<Topology>,
    net: Rc<RefCell<FlowNet>>,
    wan_pool: Vec<NodeId>,
    st: RefCell<MegaState>,
}

/// One shard of the sharded mega-churn driver: site shards drive their
/// pair slots locally; the WAN shard executes commanded cross-site
/// transfers and reports completions back.
struct MegaShard {
    env: Rc<MegaEnvS>,
    is_wan: bool,
    claimed: Vec<LinkId>,
    /// `Some` installs a per-shard trace recorder at init.
    trace: Option<TraceSpec>,
}

impl MegaShard {
    /// Derive shard `idx`'s complete view of the run from an identical
    /// clone of the plant: every shard computes the same pair/pool
    /// split, slot budgets, and RNG streams from the same inputs, so no
    /// state crosses threads except [`MegaMsg`]s.
    fn build(
        topo: Topology,
        nodes: Vec<NodeId>,
        total: u64,
        idx: usize,
        flow_cfg: FlowNetConfig,
        trace: Option<TraceSpec>,
    ) -> MegaShard {
        let topo = Rc::new(topo);
        assert!(nodes.len() >= 2, "mega churn needs at least two nodes");
        let (pairs, wan_pool) = mega_pairs(&topo, &nodes);
        let num_sites = topo.sites.len();
        let wan_shard = num_sites;
        let is_wan = idx == wan_shard;
        let slots = mega_churn_concurrency(total).min(total);

        // Link claims partition the plant: a pair flow touches only its
        // two NICs (the ToR is non-blocking), a WAN flow touches pool
        // NICs, uplinks, and waves — never a pair NIC. The pair/pool
        // node sets are disjoint, so the claims are too (the claimed
        // nets' debug-build admission audit re-checks every path).
        let mut claimed: Vec<LinkId> = Vec::new();
        if is_wan {
            for (i, l) in topo.links.iter().enumerate() {
                if l.kind == LinkKind::Wan {
                    claimed.push(LinkId(i));
                }
            }
            for r in &topo.racks {
                claimed.push(r.uplink_tx);
                claimed.push(r.uplink_rx);
            }
            for &n in &wan_pool {
                claimed.push(topo.node(n).nic_tx);
                claimed.push(topo.node(n).nic_rx);
            }
        } else {
            for &(a, b) in &pairs {
                if topo.node(a).site.0 == idx {
                    claimed.push(topo.node(a).nic_tx);
                    claimed.push(topo.node(a).nic_rx);
                    claimed.push(topo.node(b).nic_tx);
                    claimed.push(topo.node(b).nic_rx);
                }
            }
        }
        claimed.sort_unstable_by_key(|l| l.0);
        claimed.dedup_by_key(|l| l.0);
        let net = FlowNet::new_with(&topo, flow_cfg);
        net.borrow_mut().claim_links(&claimed);

        let mut slot_map: BTreeMap<u64, MegaSlot> = BTreeMap::new();
        for slot in 0..slots {
            let wan_slot = wan_pool.len() >= 2
                && (pairs.is_empty() || slot % MEGA_WAN_SLOT_STRIDE == MEGA_WAN_SLOT_STRIDE - 1);
            let pair = (!wan_slot).then(|| pairs[(slot % pairs.len() as u64) as usize]);
            // WAN slots spread their homes round-robin over the site
            // shards; a pair slot lives where its pair does.
            let home = match pair {
                Some((a, _)) => topo.node(a).site.0,
                None => (slot % num_sites as u64) as usize,
            };
            if home != idx {
                continue;
            }
            slot_map.insert(
                slot,
                MegaSlot {
                    // A pure function of the slot index: forking a fresh
                    // master gives every slot the same stream under any
                    // shard layout and any thread count.
                    rng: Rng::new(0x0C7_3E6A).fork(slot),
                    remaining: total / slots + u64::from(slot < total % slots),
                    pair,
                },
            );
        }
        MegaShard {
            env: Rc::new(MegaEnvS {
                wan_shard,
                topo,
                net,
                wan_pool,
                st: RefCell::new(MegaState {
                    slots: slot_map,
                    launched: 0,
                    done: 0,
                    peak_inflight: 0,
                    outstanding: 0,
                }),
            }),
            is_wan,
            claimed,
            trace,
        }
    }
}

/// Start one transfer for `slot` on its home shard: a pair slot runs on
/// this shard's own claimed links; a WAN slot commands the WAN shard
/// over the channel. The draw order matches [`launch_mega_flow`], from
/// the slot's private stream.
fn launch_mega_slot(env: &Rc<MegaEnvS>, out: &Outbox<MegaMsg>, eng: &mut Engine, slot: u64) {
    enum Go {
        Local { src: NodeId, dst: NodeId, bytes: f64, udt: bool },
        Wan { src: NodeId, dst: NodeId, bytes: f64, udt: bool },
    }
    let go = {
        let mut st = env.st.borrow_mut();
        let st = &mut *st;
        st.launched += 1;
        let inflight = st.launched - st.done;
        if inflight > st.peak_inflight {
            st.peak_inflight = inflight;
        }
        let slot_st = st.slots.get_mut(&slot).expect("launching an unowned slot");
        debug_assert!(slot_st.remaining > 0, "launching an exhausted slot");
        match slot_st.pair {
            Some((a, b)) => {
                let (src, dst) = if slot_st.rng.chance(0.5) { (a, b) } else { (b, a) };
                let bytes = (1.0 + slot_st.rng.f64() * 15.0) * 1e6;
                let udt = slot_st.rng.chance(0.5);
                Go::Local { src, dst, bytes, udt }
            }
            None => {
                let pool = &env.wan_pool;
                let src = pool[slot_st.rng.gen_range(pool.len() as u64) as usize];
                let mut dst = src;
                while dst == src {
                    dst = pool[slot_st.rng.gen_range(pool.len() as u64) as usize];
                }
                let bytes = (1.0 + slot_st.rng.f64() * 15.0) * 1e6;
                let udt = slot_st.rng.chance(0.5);
                st.outstanding += 1;
                Go::Wan { src, dst, bytes, udt }
            }
        }
    };
    match go {
        Go::Local { src, dst, bytes, udt } => {
            let proto = if udt { Protocol::udt() } else { Protocol::tcp() };
            let (env2, out2) = (env.clone(), out.clone());
            transport::send(&env.net, &env.topo, eng, src, dst, bytes, &proto, move |eng| {
                finish_mega_slot(&env2, &out2, eng, slot);
            });
        }
        Go::Wan { src, dst, bytes, udt } => {
            out.send(eng, env.wan_shard, MegaMsg::Start { slot, src, dst, bytes, udt });
        }
    }
}

/// One of `slot`'s transfers completed (locally, or via a WAN shard
/// report): count it and relaunch while the slot still owes transfers.
fn finish_mega_slot(env: &Rc<MegaEnvS>, out: &Outbox<MegaMsg>, eng: &mut Engine, slot: u64) {
    let relaunch = {
        let mut st = env.st.borrow_mut();
        st.done += 1;
        let slot_st = st.slots.get_mut(&slot).expect("finishing an unowned slot");
        slot_st.remaining -= 1;
        slot_st.remaining > 0
    };
    if relaunch {
        launch_mega_slot(env, out, eng, slot);
    }
}

impl ShardApp for MegaShard {
    type Msg = MegaMsg;
    type Out = MegaOut;

    fn init(&mut self, eng: &mut Engine, out: &Outbox<MegaMsg>) {
        if let Some(spec) = &self.trace {
            eng.set_recorder(Recorder::new(spec));
        }
        let slots: Vec<u64> = self.env.st.borrow().slots.keys().copied().collect();
        for slot in slots {
            launch_mega_slot(&self.env, out, eng, slot);
        }
    }

    fn on_msg(&mut self, eng: &mut Engine, from: usize, msg: MegaMsg, out: &Outbox<MegaMsg>) {
        match msg {
            MegaMsg::Start { slot, src, dst, bytes, udt } => {
                debug_assert!(self.is_wan, "transfer command sent to a site shard");
                let proto = if udt { Protocol::udt() } else { Protocol::tcp() };
                let out2 = out.clone();
                let env = &self.env;
                transport::send(&env.net, &env.topo, eng, src, dst, bytes, &proto, move |eng| {
                    out2.send(eng, from, MegaMsg::Done { slot });
                });
            }
            MegaMsg::Done { slot } => {
                debug_assert!(!self.is_wan, "completion report sent to the WAN shard");
                self.env.st.borrow_mut().outstanding -= 1;
                finish_mega_slot(&self.env, out, eng, slot);
            }
        }
    }

    fn quiescent(&self) -> bool {
        // A site shard knows its traffic completely: once every owned
        // slot's budget is spent and no WAN command is outstanding,
        // nothing can ever arrive for it. The WAN shard cannot know
        // whether more commands are coming, so it never self-declares;
        // it finishes once every site shard has (the EIT = ∞ rule).
        if self.is_wan {
            return false;
        }
        let st = self.env.st.borrow();
        st.outstanding == 0 && st.slots.values().all(|s| s.remaining == 0)
    }

    fn finish(&mut self, eng: &mut Engine) -> MegaOut {
        let st = self.env.st.borrow();
        let netb = self.env.net.borrow();
        let mut profile = eng.profile();
        let (refills, dirty) = netb.profile_counters();
        profile.refill_components += refills;
        profile.dirty_links += dirty;
        MegaOut {
            done: st.done,
            peak_inflight: st.peak_inflight,
            peak_active: netb.peak_active() as u64,
            net_completions: netb.completions(),
            finished_at: eng.now(),
            executed: eng.executed(),
            link_bytes: self.claimed.iter().map(|&l| (l.0 as u32, netb.link_bytes(l))).collect(),
            profile,
            recorder: eng.take_recorder(),
        }
    }
}

/// Modeled dispatch latency of a service-plane control hop (the GMP
/// command framing that hands a cross-site request to the WAN plane, or
/// the completion report coming back). Together with
/// [`Topology::min_wan_owd`](crate::net::Topology::min_wan_owd) it is
/// the sharded service driver's lookahead; the sequential driver pays
/// the same hop on its single engine so both model the same control
/// path.
const SERVICE_CMD_SECS: f64 = 0.005;

/// The service axis used when a [`Framework::Service`] scenario carries
/// no explicit [`ServiceSpec`]: every site hosts a replica, nearest
/// routing, steady arrivals.
fn default_service_spec(topo: &Topology) -> ServiceSpec {
    ServiceSpec::new((0..topo.sites.len() as u32).collect(), RoutePolicy::Nearest)
}

/// Globally unique trace-span id of one request attempt: site and
/// per-site request index packed, retries marked in the top bit.
fn service_span_id(site: u32, id: u64, retried: bool) -> u64 {
    (u64::from(retried) << 63) | ((site as u64) << 40) | id
}

/// Shared state of the sequential service driver: one engine, one fluid
/// network, every site's plan and accumulator side by side.
struct ServiceSeqEnv {
    net: Rc<RefCell<FlowNet>>,
    topo: Rc<Topology>,
    spec: ServiceSpec,
    pairs: Vec<Vec<(NodeId, NodeId)>>,
    gateways: Vec<Vec<NodeId>>,
    /// The cross-plane command-hop latency the sharded driver pays over
    /// its channels, mirrored here so both drivers model the same
    /// control path.
    hop: f64,
    plans: Vec<Vec<Request>>,
    st: RefCell<ServiceSeqState>,
    out: Rc<RefCell<Option<Outcome>>>,
}

struct ServiceSeqState {
    cursors: Vec<usize>,
    arrived: u64,
    planned: u64,
    /// Requests launched (originals + retries) but not yet completed.
    open: u64,
    accums: Vec<SiteAccum>,
}

/// The sequential service driver (composed axes — monitor, ops, faults,
/// provisioning, tenancy — keep this path; see
/// [`ScenarioRunner::run`]'s shape gate).
fn start_service(
    cluster: &Cluster,
    nodes: &[NodeId],
    sc: &Scenario,
    eng: &mut Engine,
    out: Rc<RefCell<Option<Outcome>>>,
) {
    let topo = cluster.topo.clone();
    let spec = sc.service.clone().unwrap_or_else(|| default_service_spec(&topo));
    let total = sc.workload.total_records.max(1);
    let lg = LoadGen::new(spec.clone(), total, LoadGen::site_rtt_matrix(&topo));
    let plant = service_plant(&topo, nodes);
    let num_sites = topo.sites.len();
    let duration = lg.duration();
    let plans: Vec<Vec<Request>> = (0..num_sites as u32).map(|s| lg.gen_site(s)).collect();
    let planned: u64 = plans.iter().map(|p| p.len() as u64).sum();
    let hop = SERVICE_CMD_SECS + topo.min_wan_owd().unwrap_or(0.0);
    let env = Rc::new(ServiceSeqEnv {
        net: cluster.net.clone(),
        topo,
        spec,
        pairs: plant.pairs_by_site,
        gateways: plant.gateways_by_site,
        hop,
        plans,
        st: RefCell::new(ServiceSeqState {
            cursors: vec![0; num_sites],
            arrived: 0,
            planned,
            open: 0,
            accums: (0..num_sites as u32).map(|s| SiteAccum::new(s, duration)).collect(),
        }),
        out,
    });
    for site in 0..num_sites {
        schedule_seq_arrival(&env, eng, site);
    }
}

/// Chain `site`'s next planned arrival: each arrival event processes one
/// request and schedules the next, keeping one pending arrival per site
/// on the heap no matter how many requests the plan holds.
fn schedule_seq_arrival(env: &Rc<ServiceSeqEnv>, eng: &mut Engine, site: usize) {
    let cursor = env.st.borrow().cursors[site];
    if cursor >= env.plans[site].len() {
        return;
    }
    let t = env.plans[site][cursor].t;
    let env2 = env.clone();
    eng.schedule_at(t, move |eng| {
        {
            let mut st = env2.st.borrow_mut();
            st.cursors[site] += 1;
            st.arrived += 1;
            st.accums[site].arrival(env2.plans[site][cursor].t);
        }
        launch_seq_request(&env2, eng, site, cursor, false);
        schedule_seq_arrival(&env2, eng, site);
    });
}

fn launch_seq_request(
    env: &Rc<ServiceSeqEnv>,
    eng: &mut Engine,
    site: usize,
    k: usize,
    retried: bool,
) {
    let req = &env.plans[site][k];
    let start = eng.now();
    env.st.borrow_mut().open += 1;
    let span = service_span_id(site as u32, req.id, retried);
    if let Some(rec) = eng.recorder() {
        let a = [("replica", Arg::U(req.replica as u64)), ("retry", Arg::U(u64::from(retried)))];
        rec.begin(start, site as u16, req.replica, "service.request", span, &a);
    }
    if req.replica as usize == site {
        let pairs = &env.pairs[site];
        assert!(!pairs.is_empty(), "site {site} serves local requests but has no pairs");
        let (src, dst) = pairs[((req.pair_u * pairs.len() as f64) as usize).min(pairs.len() - 1)];
        let service = req.service;
        let (reqb, resp) = (env.spec.request_bytes, env.spec.response_bytes);
        let env2 = env.clone();
        let udt = Protocol::udt();
        transport::send(&env.net, &env.topo, eng, src, dst, reqb, &udt, move |eng| {
            let env3 = env2.clone();
            eng.schedule_in(service, move |eng| {
                let env4 = env3.clone();
                let udt = Protocol::udt();
                transport::send(&env3.net, &env3.topo, eng, dst, src, resp, &udt, move |eng| {
                    finish_seq_request(&env4, eng, site, k, retried, start);
                });
            });
        });
    } else {
        // Mirror the sharded driver's command hop to the WAN plane.
        let env2 = env.clone();
        eng.schedule_in(env.hop, move |eng| {
            seq_remote_request(&env2, eng, site, k, retried, start);
        });
    }
}

/// The "WAN plane" half of a sequential cross-site request: optional
/// degraded-path penalty, gateway request flow, server service time,
/// optional penalty again, gateway response flow, completion-report hop.
fn seq_remote_request(
    env: &Rc<ServiceSeqEnv>,
    eng: &mut Engine,
    site: usize,
    k: usize,
    retried: bool,
    start: f64,
) {
    let req = &env.plans[site][k];
    let (user, replica) = (site as u32, req.replica);
    let gsrc = &env.gateways[site];
    let gdst = &env.gateways[replica as usize];
    assert!(
        !gsrc.is_empty() && !gdst.is_empty(),
        "cross-site requests need gateway nodes at both sites"
    );
    let gw_src = gsrc[(req.id % gsrc.len() as u64) as usize];
    let gw_dst = gdst[(req.id % gdst.len() as u64) as usize];
    let penalty = matches!(env.spec.degraded_wan_site, Some(d) if d == user || d == replica);
    let delay = if penalty { DEGRADED_WAN_PENALTY_SECS } else { 0.0 };
    let service = req.service;
    let (reqb, resp) = (env.spec.request_bytes, env.spec.response_bytes);
    let hop = env.hop;
    let env2 = env.clone();
    eng.schedule_in(delay, move |eng| {
        let env3 = env2.clone();
        let udt = Protocol::udt();
        transport::send(&env2.net, &env2.topo, eng, gw_src, gw_dst, reqb, &udt, move |eng| {
            let env4 = env3.clone();
            eng.schedule_in(service + delay, move |eng| {
                let env5 = env4.clone();
                let udt = Protocol::udt();
                transport::send(&env4.net, &env4.topo, eng, gw_dst, gw_src, resp, &udt, move |eng| {
                    let env6 = env5.clone();
                    eng.schedule_in(hop, move |eng| {
                        finish_seq_request(&env6, eng, site, k, retried, start);
                    });
                });
            });
        });
    });
}

fn finish_seq_request(
    env: &Rc<ServiceSeqEnv>,
    eng: &mut Engine,
    site: usize,
    k: usize,
    retried: bool,
    start: f64,
) {
    let now = eng.now();
    let req = &env.plans[site][k];
    let span = service_span_id(site as u32, req.id, retried);
    if let Some(rec) = eng.recorder() {
        rec.end(now, site as u16, req.replica, "service.request", span, &[]);
    }
    let (owe, finished) = {
        let mut st = env.st.borrow_mut();
        let owe = st.accums[site].complete(now, now - start, &env.spec, retried);
        st.open -= 1;
        (owe, !owe && st.open == 0 && st.arrived == st.planned)
    };
    if owe {
        launch_seq_request(env, eng, site, k, true);
    } else if finished {
        let st = env.st.borrow();
        let report = ServiceReport::assemble(&st.accums, now);
        *env.out.borrow_mut() = Some(Outcome::Service { finished_at: now, report });
    }
}

/// Cross-shard control traffic of the sharded service driver — the GMP
/// command framing a cross-site request rides between its home site
/// shard and the WAN shard.
enum ServiceMsg {
    /// Home shard → WAN shard: run one cross-site request's gateway
    /// request / service / response chain. The WAN shard derives the
    /// gateway endpoints and any degraded-path penalty from its own
    /// identical plant and spec clones, so the message stays small.
    Req { key: u64, user_site: u32, replica: u32, id: u64, service: f64 },
    /// WAN shard → home shard: the chain completed.
    Done { key: u64 },
}

/// One service shard's final accounting, merged in shard-index order.
struct ServiceOut {
    /// `Some` on site shards (they land in site order); the WAN shard
    /// carries none.
    accum: Option<SiteAccum>,
    finished_at: f64,
    executed: u64,
    link_bytes: Vec<(u32, f64)>,
    profile: ProfileReport,
    recorder: Option<Recorder>,
}

/// A cross-site request commanded to the WAN shard and not yet reported
/// done; the home shard keeps the measurement anchor.
struct ServicePending {
    start: f64,
    idx: usize,
    retried: bool,
}

struct ServiceShardState {
    cursor: usize,
    /// Requests launched (originals + retries) but not yet completed.
    open: u64,
    pending: BTreeMap<u64, ServicePending>,
    accum: Option<SiteAccum>,
}

/// Shared immutable context of one service shard; engine events capture
/// it by `Rc`.
struct ServiceEnvS {
    site: usize,
    wan_shard: usize,
    topo: Rc<Topology>,
    net: Rc<RefCell<FlowNet>>,
    spec: ServiceSpec,
    /// This site's full request plan (empty on the WAN shard).
    plan: Vec<Request>,
    /// This site's intra-rack (user, replica) pairs.
    pairs: Vec<(NodeId, NodeId)>,
    /// Every site's gateway pool (the WAN shard routes with it).
    gateways: Vec<Vec<NodeId>>,
    st: RefCell<ServiceShardState>,
}

/// One shard of the sharded service driver: site shards regenerate and
/// drive their own request plans; the WAN shard executes commanded
/// cross-site gateway chains and reports completions back.
struct ServiceShard {
    env: Rc<ServiceEnvS>,
    is_wan: bool,
    claimed: Vec<LinkId>,
    trace: Option<TraceSpec>,
}

impl ServiceShard {
    /// Derive shard `idx`'s complete view of the run from identical
    /// clones of the plant and spec: every shard computes the same
    /// pair/gateway split and the same per-site plans (each a pure
    /// function of the site's forked RNG stream), so no state crosses
    /// threads except [`ServiceMsg`]s.
    fn build(
        topo: Topology,
        nodes: Vec<NodeId>,
        spec: ServiceSpec,
        total: u64,
        idx: usize,
        flow_cfg: FlowNetConfig,
        trace: Option<TraceSpec>,
    ) -> ServiceShard {
        let topo = Rc::new(topo);
        let lg = LoadGen::new(spec.clone(), total, LoadGen::site_rtt_matrix(&topo));
        let plant = service_plant(&topo, &nodes);
        let num_sites = topo.sites.len();
        let wan_shard = num_sites;
        let is_wan = idx == wan_shard;

        // Link claims partition the plant exactly like mega-churn: a
        // local request touches only its pair's NICs (the ToR is
        // non-blocking); a cross-site chain touches gateway NICs,
        // uplinks, and waves — never a pair NIC.
        let mut claimed: Vec<LinkId> = Vec::new();
        if is_wan {
            for (i, l) in topo.links.iter().enumerate() {
                if l.kind == LinkKind::Wan {
                    claimed.push(LinkId(i));
                }
            }
            for r in &topo.racks {
                claimed.push(r.uplink_tx);
                claimed.push(r.uplink_rx);
            }
            for pool in &plant.gateways_by_site {
                for &n in pool {
                    claimed.push(topo.node(n).nic_tx);
                    claimed.push(topo.node(n).nic_rx);
                }
            }
        } else {
            for &(a, b) in &plant.pairs_by_site[idx] {
                claimed.push(topo.node(a).nic_tx);
                claimed.push(topo.node(a).nic_rx);
                claimed.push(topo.node(b).nic_tx);
                claimed.push(topo.node(b).nic_rx);
            }
        }
        claimed.sort_unstable_by_key(|l| l.0);
        claimed.dedup_by_key(|l| l.0);
        let net = FlowNet::new_with(&topo, flow_cfg);
        net.borrow_mut().claim_links(&claimed);

        let plan = if is_wan { Vec::new() } else { lg.gen_site(idx as u32) };
        let accum = (!is_wan).then(|| SiteAccum::new(idx as u32, lg.duration()));
        ServiceShard {
            env: Rc::new(ServiceEnvS {
                site: idx,
                wan_shard,
                topo,
                net,
                spec,
                plan,
                pairs: if is_wan { Vec::new() } else { plant.pairs_by_site[idx].clone() },
                gateways: plant.gateways_by_site,
                st: RefCell::new(ServiceShardState {
                    cursor: 0,
                    open: 0,
                    pending: BTreeMap::new(),
                    accum,
                }),
            }),
            is_wan,
            claimed,
            trace,
        }
    }
}

/// Chain this shard's next planned arrival (one pending arrival event at
/// a time, however large the plan).
fn schedule_service_arrival(env: &Rc<ServiceEnvS>, out: &Outbox<ServiceMsg>, eng: &mut Engine) {
    let cursor = env.st.borrow().cursor;
    if cursor >= env.plan.len() {
        return;
    }
    let t = env.plan[cursor].t;
    let (env2, out2) = (env.clone(), out.clone());
    eng.schedule_at(t, move |eng| {
        {
            let mut st = env2.st.borrow_mut();
            st.cursor += 1;
            st.accum.as_mut().expect("arrivals on the WAN shard").arrival(env2.plan[cursor].t);
        }
        launch_service_request(&env2, &out2, eng, cursor, false);
        schedule_service_arrival(&env2, &out2, eng);
    });
}

/// Start one request attempt at its home shard: local requests run their
/// request/service/response chain on this shard's own pair NICs;
/// cross-site requests are commanded to the WAN shard over the channel.
fn launch_service_request(
    env: &Rc<ServiceEnvS>,
    out: &Outbox<ServiceMsg>,
    eng: &mut Engine,
    k: usize,
    retried: bool,
) {
    let req = &env.plan[k];
    let start = eng.now();
    env.st.borrow_mut().open += 1;
    let span = service_span_id(env.site as u32, req.id, retried);
    if let Some(rec) = eng.recorder() {
        let a = [("replica", Arg::U(req.replica as u64)), ("retry", Arg::U(u64::from(retried)))];
        rec.begin(start, env.site as u16, req.replica, "service.request", span, &a);
    }
    if req.replica as usize == env.site {
        assert!(!env.pairs.is_empty(), "site {} serves local requests but has no pairs", env.site);
        let pi = ((req.pair_u * env.pairs.len() as f64) as usize).min(env.pairs.len() - 1);
        let (src, dst) = env.pairs[pi];
        let service = req.service;
        let (reqb, resp) = (env.spec.request_bytes, env.spec.response_bytes);
        let (env2, out2) = (env.clone(), out.clone());
        let udt = Protocol::udt();
        transport::send(&env.net, &env.topo, eng, src, dst, reqb, &udt, move |eng| {
            let (env3, out3) = (env2.clone(), out2.clone());
            eng.schedule_in(service, move |eng| {
                let (env4, out4) = (env3.clone(), out3.clone());
                let udt = Protocol::udt();
                transport::send(&env3.net, &env3.topo, eng, dst, src, resp, &udt, move |eng| {
                    finish_service_request(&env4, &out4, eng, k, retried, start);
                });
            });
        });
    } else {
        let key = (u64::from(retried) << 63) | req.id;
        env.st.borrow_mut().pending.insert(key, ServicePending { start, idx: k, retried });
        out.send(
            eng,
            env.wan_shard,
            ServiceMsg::Req {
                key,
                user_site: env.site as u32,
                replica: req.replica,
                id: req.id,
                service: req.service,
            },
        );
    }
}

/// One request attempt completed at its home shard (locally, or via a
/// WAN-shard report): record the latency and relaunch once on a timeout.
fn finish_service_request(
    env: &Rc<ServiceEnvS>,
    out: &Outbox<ServiceMsg>,
    eng: &mut Engine,
    k: usize,
    retried: bool,
    start: f64,
) {
    let now = eng.now();
    let req = &env.plan[k];
    let span = service_span_id(env.site as u32, req.id, retried);
    if let Some(rec) = eng.recorder() {
        rec.end(now, env.site as u16, req.replica, "service.request", span, &[]);
    }
    let owe = {
        let mut st = env.st.borrow_mut();
        st.open -= 1;
        st.accum.as_mut().expect("completions on the WAN shard").complete(
            now,
            now - start,
            &env.spec,
            retried,
        )
    };
    if owe {
        launch_service_request(env, out, eng, k, true);
    }
}

/// One commanded cross-site chain as the WAN shard executes it: the
/// resolved gateway endpoints, the per-leg degraded-path delay, and the
/// completion-report address.
#[derive(Clone, Copy)]
struct WanChain {
    reply_to: usize,
    key: u64,
    gw_src: NodeId,
    gw_dst: NodeId,
    service: f64,
    delay: f64,
}

/// Run one gateway request flow → server service time → gateway
/// response flow chain on the WAN shard, then report `Done` back to the
/// request's home shard.
fn run_wan_chain(env: &Rc<ServiceEnvS>, out: &Outbox<ServiceMsg>, eng: &mut Engine, c: WanChain) {
    let (reqb, resp) = (env.spec.request_bytes, env.spec.response_bytes);
    let (env2, out2) = (env.clone(), out.clone());
    let udt = Protocol::udt();
    transport::send(&env.net, &env.topo, eng, c.gw_src, c.gw_dst, reqb, &udt, move |eng| {
        let (env3, out3) = (env2.clone(), out2.clone());
        eng.schedule_in(c.service + c.delay, move |eng| {
            let out4 = out3.clone();
            let udt = Protocol::udt();
            let (net, topo) = (env3.net.clone(), env3.topo.clone());
            transport::send(&net, &topo, eng, c.gw_dst, c.gw_src, resp, &udt, move |eng| {
                out4.send(eng, c.reply_to, ServiceMsg::Done { key: c.key });
            });
        });
    });
}

impl ShardApp for ServiceShard {
    type Msg = ServiceMsg;
    type Out = ServiceOut;

    fn init(&mut self, eng: &mut Engine, out: &Outbox<ServiceMsg>) {
        if let Some(spec) = &self.trace {
            eng.set_recorder(Recorder::new(spec));
        }
        if !self.is_wan {
            schedule_service_arrival(&self.env, out, eng);
        }
    }

    fn on_msg(&mut self, eng: &mut Engine, from: usize, msg: ServiceMsg, out: &Outbox<ServiceMsg>) {
        match msg {
            ServiceMsg::Req { key, user_site, replica, id, service } => {
                debug_assert!(self.is_wan, "request command sent to a site shard");
                let env = &self.env;
                let gsrc = &env.gateways[user_site as usize];
                let gdst = &env.gateways[replica as usize];
                assert!(
                    !gsrc.is_empty() && !gdst.is_empty(),
                    "cross-site requests need gateway nodes at both sites"
                );
                let gw_src = gsrc[(id % gsrc.len() as u64) as usize];
                let gw_dst = gdst[(id % gdst.len() as u64) as usize];
                let penalty =
                    matches!(env.spec.degraded_wan_site, Some(d) if d == user_site || d == replica);
                let delay = if penalty { DEGRADED_WAN_PENALTY_SECS } else { 0.0 };
                let chain = WanChain { reply_to: from, key, gw_src, gw_dst, service, delay };
                let (env2, out2) = (env.clone(), out.clone());
                eng.schedule_in(delay, move |eng| {
                    run_wan_chain(&env2, &out2, eng, chain);
                });
            }
            ServiceMsg::Done { key } => {
                debug_assert!(!self.is_wan, "completion report sent to the WAN shard");
                let p = self
                    .env
                    .st
                    .borrow_mut()
                    .pending
                    .remove(&key)
                    .expect("completion report for an unknown request");
                finish_service_request(&self.env, out, eng, p.idx, p.retried, p.start);
            }
        }
    }

    fn quiescent(&self) -> bool {
        // A site shard knows its traffic completely: once every planned
        // arrival has been processed and every attempt (local flows and
        // commanded WAN chains alike) has completed, nothing can ever
        // arrive for it. The WAN shard cannot know whether more commands
        // are coming, so it never self-declares (the EIT = ∞ rule).
        if self.is_wan {
            return false;
        }
        let st = self.env.st.borrow();
        st.cursor == self.env.plan.len() && st.open == 0
    }

    fn finish(&mut self, eng: &mut Engine) -> ServiceOut {
        let netb = self.env.net.borrow();
        let mut profile = eng.profile();
        let (refills, dirty) = netb.profile_counters();
        profile.refill_components += refills;
        profile.dirty_links += dirty;
        ServiceOut {
            accum: self.env.st.borrow_mut().accum.take(),
            finished_at: eng.now(),
            executed: eng.executed(),
            link_bytes: self.claimed.iter().map(|&l| (l.0 as u32, netb.link_bytes(l))).collect(),
            profile,
            recorder: eng.take_recorder(),
        }
    }
}

fn start_sphere(
    cluster: &Cluster,
    nodes: &[NodeId],
    w: &WorkloadSpec,
    eng: &mut Engine,
    out: Rc<RefCell<Option<Outcome>>>,
    control: &Rc<RefCell<Option<DataflowControl>>>,
) {
    let mut master = SectorMaster::new(cluster.topo.clone());
    master.register_file("malstone", sector_segments(nodes, w.total_records));
    let c = SphereEngine::simulate(
        cluster,
        &master,
        eng,
        "malstone",
        nodes,
        FrameworkParams::sphere(),
        w.variant.is_b(),
        move |eng, r| {
            *out.borrow_mut() = Some(Outcome::Sphere { finished_at: eng.now(), report: r });
        },
    );
    *control.borrow_mut() = Some(c);
}

/// Sector stores each node's shard as several 64 MB segments so SPE
/// slots stay busy and stealing has granularity (like the real SDFS).
fn sector_segments(nodes: &[NodeId], total_records: u64) -> Vec<Segment> {
    let per = total_records.div_ceil(nodes.len() as u64);
    let seg_bytes: u64 = 64 * 1024 * 1024;
    let mut segments = Vec::new();
    for &n in nodes {
        let mut remaining_b = per * RECORD_BYTES as u64;
        let mut remaining_r = per;
        while remaining_b > 0 {
            let b = remaining_b.min(seg_bytes);
            let r = ((b as f64 / (per * RECORD_BYTES as u64) as f64) * per as f64).round() as u64;
            segments.push(Segment { node: n, bytes: b, records: r.min(remaining_r).max(1) });
            remaining_b -= b;
            remaining_r = remaining_r.saturating_sub(r);
        }
    }
    segments
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scenario::{Placement, Testbed, TopologySpec};

    fn smoke(framework: Framework, records: u64) -> Scenario {
        Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .framework(framework)
            .workload(WorkloadSpec::malstone_a(records))
            .name("runner-smoke")
            .build()
    }

    #[test]
    fn report_json_roundtrip() {
        let rep = ScenarioRunner::new().run(&smoke(Framework::SectorSphere, 2_000_000));
        assert!(rep.simulated_secs > 0.0);
        let text = rep.to_json().to_string();
        let back = RunReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, rep);
    }

    #[test]
    fn hadoop_run_reports_metrics_and_flows() {
        let rep = ScenarioRunner::new().run(&smoke(Framework::HadoopStreams, 4_000_000));
        assert!(rep.simulated_secs > 0.0);
        assert_eq!(rep.site_flows.len(), 4);
        assert!(rep.metrics.iter().any(|(k, _)| k == "job1_makespan"));
        // Per-site placement shuffles across sites → WAN traffic.
        assert!(rep.wan_bytes > 0.0, "wan_bytes = {}", rep.wan_bytes);
        // Metrics are sorted (JSON round-trip relies on it).
        let keys: Vec<&str> = rep.metrics.iter().map(|(k, _)| k.as_str()).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted);
    }

    #[test]
    fn single_site_run_keeps_wan_quiet() {
        let sc = Testbed::builder()
            .framework(Framework::SectorSphere)
            .placement(Placement::SingleSite { site: 0, nodes: 5 })
            .workload(WorkloadSpec::malstone_a(2_000_000))
            .name("local-smoke")
            .build();
        let rep = ScenarioRunner::new().run(&sc);
        assert_eq!(rep.wan_bytes, 0.0);
        assert_eq!(rep.site_flows[0].nodes_used, 5);
        assert_eq!(rep.site_flows[1].nodes_used, 0);
    }

    #[test]
    fn interop_runs_report_per_layer_metrics() {
        let hos = ScenarioRunner::new().run(&smoke(Framework::HadoopOverSector, 4_000_000));
        assert!(hos.simulated_secs > 0.0);
        assert_eq!(hos.framework, "hadoop-over-sector");
        let metric = |rep: &RunReport, k: &str| {
            rep.metric(k).unwrap_or_else(|| panic!("missing metric {k}"))
        };
        assert!(metric(&hos, "storage_read_bytes") > 0.0);
        assert!(metric(&hos, "storage_write_bytes") > 0.0);
        assert!(metric(&hos, "exchange_bytes") > 0.0);
        assert!(metric(&hos, "stolen_tasks") >= 0.0);
        // KFS writes 3 synchronous replicas; Sector writes one: the
        // storage layer shows up in the write accounting.
        let kfs = ScenarioRunner::new().run(&smoke(Framework::CloudStoreMr, 4_000_000));
        assert_eq!(kfs.framework, "cloudstore-mr");
        assert!(
            metric(&kfs, "storage_write_bytes") > 2.0 * metric(&hos, "storage_write_bytes"),
            "kfs {} vs sector {}",
            metric(&kfs, "storage_write_bytes"),
            metric(&hos, "storage_write_bytes")
        );
        // Reports stay JSON-round-trippable with the new metrics.
        let text = kfs.to_json().to_string();
        let back = RunReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, kfs);
    }

    #[test]
    fn flow_churn_run_reports_churn_metrics() {
        let sc = Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(30)) // the 120-node paper config
            .framework(Framework::FlowChurn)
            .workload(WorkloadSpec::malstone_a(200)) // records = transfers
            .name("churn-smoke")
            .build();
        let rep = ScenarioRunner::new().run(&sc);
        assert_eq!(rep.nodes, 120);
        assert!(rep.simulated_secs > 0.0);
        let metric =
            |k: &str| rep.metric(k).unwrap_or_else(|| panic!("missing metric {k}"));
        assert_eq!(metric("flows"), 200.0);
        assert_eq!(metric("net_completions"), 200.0);
        assert_eq!(metric("peak_inflight"), flow_churn_concurrency(200) as f64);
        // Independent of the driver's bookkeeping: the network itself must
        // have held a solid fraction of the 50-transfer target at once
        // (setup overhead staggers entry, so exact equality is not owed).
        assert!(
            metric("peak_active") >= 25.0,
            "peak_active = {}",
            metric("peak_active")
        );
        // Random pairs over four sites cross the WAN.
        assert!(rep.wan_bytes > 0.0);
        let text = rep.to_json().to_string();
        let back = RunReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, rep);
    }

    #[test]
    fn mega_churn_sharded_is_thread_count_invariant() {
        let sc = Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(30))
            .framework(Framework::MegaChurn)
            .workload(WorkloadSpec::malstone_a(800))
            .name("mega-sharded-smoke")
            .build();
        let one = ScenarioRunner::new().with_threads(1).run(&sc);
        for threads in [2, 4, 8] {
            let n = ScenarioRunner::new().with_threads(threads).run(&sc);
            assert_eq!(
                n.to_json().to_string(),
                one.to_json().to_string(),
                "threads={threads} diverged"
            );
        }
        let m = |k: &str| one.metric(k).unwrap_or_else(|| panic!("missing metric {k}"));
        assert_eq!(m("flows"), 800.0);
        assert_eq!(m("net_completions"), 800.0);
        // Every slot is in flight at t = 0, before any completion, so the
        // summed per-shard peaks equal the slot target exactly.
        assert_eq!(m("peak_inflight"), mega_churn_concurrency(800) as f64);
        assert!(m("peak_active") >= 100.0, "peak_active = {}", m("peak_active"));
        assert!(one.wan_bytes > 0.0, "WAN slots crossed the wave");
        assert!(one.simulated_secs > 0.0);
        assert_eq!(one.site_flows.len(), 4);
    }

    #[test]
    fn wall_stats_ride_along_but_stay_out_of_identity() {
        let rep = ScenarioRunner::new().run(&smoke(Framework::SectorSphere, 2_000_000));
        let w = rep.wall.expect("every run carries wall stats");
        assert!(w.wall_secs > 0.0);
        assert!(w.events_per_sec > 0.0);
        // Serialization drops them (reports must stay byte-comparable
        // across machines and thread counts), and equality ignores them.
        let back = RunReport::from_json(&Json::parse(&rep.to_json().to_string()).unwrap()).unwrap();
        assert!(back.wall.is_none());
        assert_eq!(back, rep);
        assert!(!rep.to_json().to_string().contains("wall"));
    }

    #[test]
    fn composed_axes_keep_the_sequential_mega_driver() {
        // A composed axis (here the monitor) forces the sequential
        // driver; the plain twin takes the sharded engine. Both must
        // land every transfer.
        let sc = Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(30))
            .framework(Framework::MegaChurn)
            .workload(WorkloadSpec::malstone_a(400))
            .name("mega-axes")
            .build();
        let sharded = ScenarioRunner::new().run(&sc);
        let sequential = ScenarioRunner::new().with_monitor(5.0).run(&sc);
        assert_eq!(sharded.metric("flows"), Some(400.0));
        assert_eq!(sequential.metric("flows"), Some(400.0));
        assert!(sequential.monitor.is_some(), "monitored run kept its summary");
    }

    fn service_scenario(records: u64) -> Scenario {
        Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(8))
            .framework(Framework::Service)
            .workload(WorkloadSpec::malstone_a(records))
            .name("service-smoke")
            .build()
    }

    #[test]
    fn service_run_reports_slo_quantiles() {
        let rep = ScenarioRunner::new().run(&service_scenario(4_000));
        let s = rep.service.as_ref().expect("service report");
        assert_eq!(s.requests, 4_000);
        assert_eq!(s.completed, s.requests + s.retries);
        assert_eq!(s.timeouts, s.retries);
        assert!(s.goodput_rps > 0.0);
        assert!(s.p50_ms > 0.0 && s.p50_ms <= s.p99_ms && s.p99_ms <= s.p999_ms);
        assert_eq!(s.sites.len(), 4);
        // Nearest routing with replicas everywhere keeps traffic local.
        assert_eq!(rep.wan_bytes, 0.0);
        assert_eq!(rep.metric("requests"), Some(4_000.0));
        assert_eq!(rep.metric("latency_p50_ms"), Some(s.p50_ms));
        let text = rep.to_json().to_string();
        let back = RunReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, rep);
    }

    #[test]
    fn service_sharded_is_thread_count_invariant() {
        let mut sc = service_scenario(4_000);
        // Two replica sites + random routing: most requests command the
        // WAN shard, exercising both channel directions.
        sc.service = Some(ServiceSpec::new(vec![0, 1], RoutePolicy::Random));
        let one = ScenarioRunner::new().with_threads(1).run(&sc);
        for threads in [2, 4] {
            let n = ScenarioRunner::new().with_threads(threads).run(&sc);
            assert_eq!(
                n.to_json().to_string(),
                one.to_json().to_string(),
                "threads={threads} diverged"
            );
        }
        assert!(one.wan_bytes > 0.0, "random routing crossed the wave");
        let s = one.service.as_ref().expect("service report");
        assert_eq!(s.completed, s.requests + s.retries);
        assert!(s.p50_ms > 0.0);
    }

    #[test]
    fn composed_axes_keep_the_sequential_service_driver() {
        // The monitor forces the sequential driver; the plain twin takes
        // the sharded engine. Both must land every request.
        let sc = service_scenario(2_000);
        let sharded = ScenarioRunner::new().run(&sc);
        let sequential = ScenarioRunner::new().with_monitor(1.0).run(&sc);
        for rep in [&sharded, &sequential] {
            let s = rep.service.as_ref().expect("service report");
            assert_eq!(s.requests, 2_000);
            assert_eq!(s.completed, s.requests + s.retries);
        }
        assert!(sequential.monitor.is_some(), "monitored run kept its summary");
    }

    #[test]
    fn timeouts_trigger_exactly_one_retry() {
        let mut sc = service_scenario(400);
        let mut spec = ServiceSpec::new(vec![0, 1, 2, 3], RoutePolicy::Nearest);
        // Impossible deadline: every original times out, every retry
        // completes without re-arming.
        spec.timeout_secs = 1e-9;
        spec.slo_secs = 1e-9;
        sc.service = Some(spec);
        let rep = ScenarioRunner::new().run(&sc);
        let s = rep.service.as_ref().expect("service report");
        assert_eq!(s.requests, 400);
        assert_eq!(s.timeouts, 400);
        assert_eq!(s.retries, 400);
        assert_eq!(s.completed, 800);
        assert_eq!(s.slo_violations, 800);
    }

    #[test]
    fn monitored_run_collects_samples() {
        let runner = ScenarioRunner::new().with_monitor(1.0);
        let rep = runner.run(&smoke(Framework::SectorSphere, 20_000_000));
        let m = rep.monitor.expect("monitor summary");
        assert!(m.samples > 0, "no samples over {:.1}s", rep.simulated_secs);
        assert!(m.busy_nodes > 0);
        // The quantile rollup orders sanely over busy nodes.
        assert!(m.nic_rate_p50 > 0.0, "p50 = {}", m.nic_rate_p50);
        assert!(m.nic_rate_p99 >= m.nic_rate_p50);
        let text = rep.to_json().to_string();
        let back = RunReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, rep);
    }

    #[test]
    fn provisioning_pays_imaging_and_lightpath_before_work() {
        let sc = Testbed::builder()
            .framework(Framework::SectorSphere)
            .workload(WorkloadSpec::malstone_a(2_000_000))
            .image("sector-sphere-1.24", 2.0)
            .lightpath(10.0)
            .name("provisioned-smoke")
            .build();
        let rep = ScenarioRunner::new().run(&sc);
        let m = |k: &str| rep.metric(k).unwrap_or_else(|| panic!("missing metric {k}"));
        // Imaging moved real bytes and took real simulated time; the
        // lightpath grant paid exactly its signalling latency.
        assert!(m("imaging_secs") > IMAGE_BOOT_SECS, "imaging {}", m("imaging_secs"));
        assert_eq!(m("lightpath_setup_secs"), LightpathSpec::DEFAULT_SETUP_SECS);
        // The workload waited for the slower provisioning arm.
        let slower = m("imaging_secs").max(m("lightpath_setup_secs"));
        assert!(m("provision_secs") >= slower - 1e-9);
        // Solo run: admitted at t=0, so started == provision, no queue.
        assert_eq!(m("queued_secs"), 0.0);
        assert!((m("started_secs") - m("provision_secs")).abs() < 1e-9);
        assert!(m("workload_secs") > 0.0);
        assert!((rep.simulated_secs - (m("started_secs") + m("workload_secs"))).abs() < 1e-6);
        // An unprovisioned twin reports none of the provisioning metrics
        // and finishes in the workload time alone.
        let plain = ScenarioRunner::new().run(&smoke(Framework::SectorSphere, 2_000_000));
        assert!(plain.metric("imaging_secs").is_none());
        assert!(plain.simulated_secs < rep.simulated_secs);
        // The enriched report round-trips through JSON.
        let back = RunReport::from_json(&Json::parse(&rep.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, rep);
    }

    #[test]
    fn under_provisioned_lightpath_slows_the_run() {
        let run = |gbps: f64| {
            ScenarioRunner::new().run(
                &Testbed::builder()
                    .framework(Framework::SectorSphere)
                    .workload(WorkloadSpec::malstone_a(20_000_000))
                    .lightpath(gbps)
                    .name("wave")
                    .build(),
            )
        };
        let full = run(10.0);
        let thin = run(0.25);
        let wl = |r: &RunReport| r.metric("workload_secs").unwrap();
        // Same workload, same setup latency — only the grant differs,
        // and the thin wave costs real time.
        assert!(wl(&thin) > 1.1 * wl(&full), "thin {} vs full {}", wl(&thin), wl(&full));
        assert_eq!(full.metric("lightpath_setup_secs"), thin.metric("lightpath_setup_secs"));
    }

    #[test]
    fn tenants_share_one_testbed_and_queue_on_inventory() {
        // Three 16-per-site tenants on 32-node sites: the third queues
        // until an earlier slice releases.
        let tenant = |name: &str| {
            Testbed::builder()
                .framework(Framework::SectorSphere)
                .workload(WorkloadSpec::malstone_a(2_000_000))
                .placement(Placement::PerSite(16))
                .tenant(name, 0)
                .name(&format!("tenant-{name}"))
                .build()
        };
        let scs = vec![tenant("a"), tenant("b"), tenant("c")];
        let reps = ScenarioRunner::new().run_tenants(&scs);
        assert_eq!(reps.len(), 3);
        let m = |r: &RunReport, k: &str| r.metric(k).unwrap_or_else(|| panic!("missing {k}"));
        assert_eq!(m(&reps[0], "queued_secs"), 0.0);
        assert_eq!(m(&reps[1], "queued_secs"), 0.0);
        assert!(m(&reps[2], "queued_secs") > 0.0, "third tenant admitted immediately");
        // The queued tenant started only after an earlier run finished.
        let first_finish = reps[0].simulated_secs.min(reps[1].simulated_secs);
        assert!(m(&reps[2], "started_secs") >= first_finish - 1e-9);
        // All three completed, and the first two overlapped in time.
        for r in &reps {
            assert!(m(r, "workload_secs") > 0.0, "{}", r.scenario);
        }
        assert!(m(&reps[0], "started_secs") < reps[1].simulated_secs);
        assert!(m(&reps[1], "started_secs") < reps[0].simulated_secs);
        // Tenant reports survive the JSON round-trip.
        let back =
            RunReport::from_json(&Json::parse(&reps[2].to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, reps[2]);
    }

    #[test]
    fn every_report_carries_profile_counters() {
        let rep = ScenarioRunner::new().run(&smoke(Framework::SectorSphere, 2_000_000));
        assert!(rep.profile.events > 0, "no events counted");
        assert!(rep.profile.timers_armed > 0, "no timers counted");
        assert!(rep.profile.refill_components > 0, "no water-filling counted");
        assert!(rep.profile.dirty_links >= rep.profile.refill_components);
        // Sequential run: no shard channel, no sched profile.
        assert_eq!(rep.profile.channel_messages, 0);
        assert!(rep.profile.sched.is_none());
        // The counters survive the JSON round-trip and sit inside
        // equality.
        let back = RunReport::from_json(&Json::parse(&rep.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.profile, rep.profile);
        // The sharded path sums per-shard counters and keeps the
        // channel + sched lanes.
        let mega = Testbed::builder()
            .topology(TopologySpec::Oct2009)
            .placement(Placement::PerSite(30))
            .framework(Framework::MegaChurn)
            .workload(WorkloadSpec::malstone_a(400))
            .name("mega-profile")
            .build();
        let mrep = ScenarioRunner::new().with_threads(2).run(&mega);
        assert!(mrep.profile.events > 0);
        assert!(mrep.profile.channel_messages > 0, "WAN slots crossed the channel");
        let sched = mrep.profile.sched.as_ref().expect("sharded runs carry a sched profile");
        assert!(sched.rounds > 0);
        let u = sched.lookahead_utilization();
        assert!((0.0..=1.0).contains(&u), "utilization {u}");
    }

    #[test]
    fn tracing_changes_no_report_bytes() {
        let sc = smoke(Framework::SectorSphere, 2_000_000);
        let plain = ScenarioRunner::new().run(&sc);
        let (traced, stream) =
            ScenarioRunner::new().with_trace(TraceSpec::new()).run_with_trace(&sc);
        assert!(!stream.is_empty(), "traced run recorded nothing");
        assert_eq!(plain.to_json().to_string(), traced.to_json().to_string());
        // The stream exports flow spans from the workload's transfers.
        let js = stream.to_chrome_json();
        assert!(js.contains("\"flow\""), "{}", &js[..js.len().min(600)]);
        // An untraced runner hands back an empty stream, same report.
        let (plain2, empty) = ScenarioRunner::new().run_with_trace(&sc);
        assert!(empty.is_empty());
        assert_eq!(plain2, plain);
    }

    #[test]
    fn node_crash_is_detected_healed_and_survived() {
        use crate::ops::{AlertKind, FaultPlan};
        let sc = Testbed::builder()
            .framework(Framework::HadoopMr)
            .workload(WorkloadSpec::malstone_a(50_000_000))
            .faults(FaultPlan::new().node_crash(20.0, 7))
            .name("ops-crash-smoke")
            .build();
        let rep = ScenarioRunner::new().run(&sc);
        // MalStone completed despite the mid-run crash.
        assert!(rep.simulated_secs > 20.0);
        let ops = rep.ops.as_ref().expect("a fault plan implies an ops report");
        assert_eq!(ops.crashed_nodes, 1);
        assert_eq!(ops.dead_declared, 1);
        assert_eq!(ops.false_dead, 0);
        // Bounded detection: missed-heartbeat threshold + heartbeat phase
        // + relay + check-tick granularity, in heartbeat units.
        let bound = 8.0 * ops.heartbeat_interval;
        assert!(
            ops.detection_latency_max > 0.0 && ops.detection_latency_max <= bound,
            "latency {} vs bound {bound}",
            ops.detection_latency_max
        );
        // The dead worker's lost maps were re-executed on survivors.
        assert!(ops.reexecuted_tasks >= 1, "nothing re-executed");
        assert!(rep.metric("reexecuted_tasks").unwrap() >= 1.0);
        assert!(ops.remediation_ops >= 1, "no drain emitted");
        assert!(ops.alerts.iter().any(|a| a.kind == AlertKind::NodeDead));
        // In-band telemetry consumed real (but small) WAN bandwidth.
        assert!(ops.telemetry_wan_bytes > 0.0);
        assert!(
            ops.telemetry_wan_bytes < 0.01 * rep.wan_bytes,
            "telemetry {} vs workload wan {}",
            ops.telemetry_wan_bytes,
            rep.wan_bytes
        );
        // The enriched report still round-trips through JSON.
        let text = rep.to_json().to_string();
        let back = RunReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, rep);
    }
}

//! Paper-style table presentation over registry reports.
//!
//! The pre-0.2 `run_table1` / `run_table2` drivers (and their deprecated
//! shims) are gone: every experiment runs a registry set through
//! [`ScenarioRunner`](super::runner::ScenarioRunner). What remains here
//! is the *presentation* layer — folding a set's [`RunReport`]s into the
//! paper's row shapes and printing them in its format.

use super::runner::{wide_area_penalty, RunReport};

/// One Table 1 row: a framework's MalStone-A and MalStone-B times.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub framework: String,
    pub a_secs: f64,
    pub b_secs: f64,
    /// Paper-measured values for the side-by-side (seconds).
    pub paper_a: f64,
    pub paper_b: f64,
}

/// One Table 2 row: local vs distributed and the wide-area penalty.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub framework: String,
    pub local_secs: f64,
    pub dist_secs: f64,
    pub paper_local: f64,
    pub paper_dist: f64,
}

impl Table2Row {
    pub fn penalty(&self) -> f64 {
        (self.dist_secs - self.local_secs) / self.local_secs
    }

    pub fn paper_penalty(&self) -> f64 {
        (self.paper_dist - self.paper_local) / self.paper_local
    }
}

/// Fold `table1` registry reports (scenario order: framework-major,
/// variant-minor, so A/B pairs are adjacent) into paper-style rows.
pub fn table1_rows(reports: &[RunReport]) -> Vec<Table1Row> {
    assert!(reports.len() % 2 == 0, "table1 reports come in A/B pairs");
    reports
        .chunks(2)
        .map(|pair| {
            let (a, b) = (&pair[0], &pair[1]);
            assert_eq!(a.framework, b.framework, "A/B pair spans frameworks");
            Table1Row {
                framework: a.framework.clone(),
                a_secs: a.simulated_secs,
                b_secs: b.simulated_secs,
                paper_a: a.paper_secs.unwrap_or(0.0),
                paper_b: b.paper_secs.unwrap_or(0.0),
            }
        })
        .collect()
}

/// Fold `table2` registry reports (scenario order: framework-major,
/// local/dist-minor) into paper-style rows with display names.
pub fn table2_rows(reports: &[RunReport]) -> Vec<Table2Row> {
    assert!(reports.len() % 2 == 0, "table2 reports come in local/dist pairs");
    reports
        .chunks(2)
        .map(|pair| {
            let (local, dist) = (&pair[0], &pair[1]);
            assert_eq!(local.framework, dist.framework, "local/dist pair spans frameworks");
            let framework = match local.framework.as_str() {
                "hadoop-mapreduce" => "hadoop (3 replicas)".to_string(),
                "hadoop-mapreduce-r1" => "hadoop (1 replica)".to_string(),
                "sector-sphere" => "sector".to_string(),
                other => other.to_string(),
            };
            Table2Row {
                framework,
                local_secs: local.simulated_secs,
                dist_secs: dist.simulated_secs,
                paper_local: local.paper_secs.unwrap_or(0.0),
                paper_dist: dist.paper_secs.unwrap_or(0.0),
            }
        })
        .collect()
}

/// Pretty-print Table 1 in the paper's format.
pub fn format_table1(rows: &[Table1Row]) -> String {
    use crate::util::units::fmt_paper_time;
    let mut s = String::new();
    s.push_str(&format!(
        "{:<20} {:>14} {:>14} {:>14} {:>14}\n",
        "", "MalStone-A", "MalStone-B", "paper-A", "paper-B"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<20} {:>14} {:>14} {:>14} {:>14}\n",
            r.framework,
            fmt_paper_time(r.a_secs),
            fmt_paper_time(r.b_secs),
            fmt_paper_time(r.paper_a),
            fmt_paper_time(r.paper_b),
        ));
    }
    s
}

/// Pretty-print Table 2 in the paper's format.
pub fn format_table2(rows: &[Table2Row]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<20} {:>12} {:>14} {:>9} {:>13}\n",
        "", "28 local (s)", "7×4 dist (s)", "penalty", "paper penalty"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<20} {:>12.0} {:>14.0} {:>8.1}% {:>12.1}%\n",
            r.framework,
            r.local_secs,
            r.dist_secs,
            100.0 * r.penalty(),
            100.0 * r.paper_penalty(),
        ));
    }
    s
}

/// Sanity helper used by presentation tests: row penalties must agree
/// with the shared [`wide_area_penalty`] definition.
pub fn row_penalty_consistent(row: &Table2Row, local: &RunReport, dist: &RunReport) -> bool {
    (row.penalty() - wide_area_penalty(local, dist)).abs() < 1e-12
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::find_set;
    use crate::coordinator::runner::ScenarioRunner;

    #[test]
    fn registry_reports_fold_into_table1_rows() {
        let set = find_set("table1").expect("table1 registered").scaled_down(2000);
        let reports = ScenarioRunner::new().run_all(&set.scenarios);
        let rows = table1_rows(&reports);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].framework, "hadoop-mapreduce");
        assert_eq!(rows[2].framework, "sector-sphere");
        assert!(rows.iter().all(|r| r.a_secs > 0.0 && r.b_secs > 0.0 && r.paper_a > 0.0));
    }

    #[test]
    fn registry_reports_fold_into_table2_rows() {
        let set = find_set("table2").expect("table2 registered").scaled_down(3000);
        let reports = ScenarioRunner::new().run_all(&set.scenarios);
        let rows = table2_rows(&reports);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].framework, "hadoop (3 replicas)");
        assert_eq!(rows[1].framework, "hadoop (1 replica)");
        assert_eq!(rows[2].framework, "sector");
        assert!(rows.iter().all(|r| r.penalty().is_finite()));
        for (i, row) in rows.iter().enumerate() {
            assert!(row_penalty_consistent(row, &reports[2 * i], &reports[2 * i + 1]));
        }
    }

    #[test]
    fn formatting_matches_paper_style() {
        let rows = vec![Table1Row {
            framework: "hadoop-mapreduce".to_string(),
            a_secs: 454.0 * 60.0 + 13.0,
            b_secs: 840.0 * 60.0 + 50.0,
            paper_a: 1.0,
            paper_b: 2.0,
        }];
        let s = format_table1(&rows);
        assert!(s.contains("454m 13s"));
        assert!(s.contains("840m 50s"));
        let s2 = format_table2(&[Table2Row {
            framework: "sector".to_string(),
            local_secs: 100.0,
            dist_secs: 105.0,
            paper_local: 4200.0,
            paper_dist: 4400.0,
        }]);
        assert!(s2.contains("5.0%"), "{s2}");
    }
}

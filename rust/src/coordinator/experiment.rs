//! Experiment drivers: regenerate Table 1 and Table 2.
//!
//! Acceptance is *shape*, not absolute seconds (DESIGN.md §3): ordering
//! (Sector < Streams < Hadoop-MR), the Sector-vs-Hadoop ratio, and the
//! wide-area penalty gap (Hadoop ≈ 30–35%, Sector < 6%). The drivers are
//! shared by `cargo bench`, the examples, and integration tests.

use std::cell::RefCell;
use std::rc::Rc;

use crate::hadoop::hdfs::{HdfsConfig, Namenode};
use crate::hadoop::mapreduce::{malstone_jobs, uniform_shards, MapReduceEngine};
use crate::hadoop::FrameworkParams;
use crate::malstone::record::RECORD_BYTES;
use crate::malstone::scale::Workload;
use crate::net::{Cluster, NodeId, Topology};
use crate::sector::master::{SectorMaster, Segment};
use crate::sector::sphere::SphereEngine;
use crate::sim::Engine;

/// One Table 1 row: a framework's MalStone-A and MalStone-B times.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub framework: &'static str,
    pub a_secs: f64,
    pub b_secs: f64,
    /// Paper-measured values for the side-by-side (seconds).
    pub paper_a: f64,
    pub paper_b: f64,
}

/// One Table 2 row: local vs distributed and the wide-area penalty.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub framework: &'static str,
    pub local_secs: f64,
    pub dist_secs: f64,
    pub paper_local: f64,
    pub paper_dist: f64,
}

impl Table2Row {
    pub fn penalty(&self) -> f64 {
        (self.dist_secs - self.local_secs) / self.local_secs
    }

    pub fn paper_penalty(&self) -> f64 {
        (self.paper_dist - self.paper_local) / self.paper_local
    }
}

/// Run one Hadoop MalStone (two chained MR jobs); returns simulated secs.
pub fn run_hadoop(
    topo_builder: impl Fn() -> Topology,
    nodes_of: impl Fn(&Topology) -> Vec<NodeId>,
    params: &FrameworkParams,
    total_records: u64,
    variant_b: bool,
) -> f64 {
    let cluster = Cluster::new(topo_builder());
    let nodes = nodes_of(&cluster.topo);
    let nn = Rc::new(RefCell::new(Namenode::with_members(
        cluster.topo.clone(),
        HdfsConfig { replication: params.output_replication, ..Default::default() },
        42,
        nodes.clone(),
    )));
    let shards = uniform_shards(&nodes, total_records);
    let (job1, job2_of) = malstone_jobs(params, &nodes, &shards, variant_b, 64 * 1024 * 1024);
    let mut eng = Engine::new();
    let finished = Rc::new(RefCell::new(None));
    let f2 = finished.clone();
    let cluster2 = cluster.clone();
    let nn2 = nn.clone();
    MapReduceEngine::simulate(&cluster, &nn, &mut eng, job1, move |eng, r1| {
        let job2 = job2_of(&r1);
        let f3 = f2.clone();
        MapReduceEngine::simulate(&cluster2, &nn2, eng, job2, move |eng, _r2| {
            *f3.borrow_mut() = Some(eng.now());
        });
    });
    eng.run();
    let t = finished.borrow().expect("hadoop run did not complete");
    t
}

/// Run one Sector/Sphere MalStone; returns simulated seconds.
pub fn run_sphere_sim(
    topo_builder: impl Fn() -> Topology,
    nodes_of: impl Fn(&Topology) -> Vec<NodeId>,
    total_records: u64,
    variant_b: bool,
) -> f64 {
    let cluster = Cluster::new(topo_builder());
    let nodes = nodes_of(&cluster.topo);
    let mut master = SectorMaster::new(cluster.topo.clone());
    let per = total_records.div_ceil(nodes.len() as u64);
    // Sector stores shards as several segments so SPE slots stay busy
    // and stealing has granularity (64 MB segments like the real SDFS).
    let seg_bytes: u64 = 64 * 1024 * 1024;
    let mut segments = Vec::new();
    for &n in &nodes {
        let mut remaining_b = per * RECORD_BYTES as u64;
        let mut remaining_r = per;
        while remaining_b > 0 {
            let b = remaining_b.min(seg_bytes);
            let r = ((b as f64 / (per * RECORD_BYTES as u64) as f64) * per as f64).round() as u64;
            segments.push(Segment { node: n, bytes: b, records: r.min(remaining_r).max(1) });
            remaining_b -= b;
            remaining_r = remaining_r.saturating_sub(r);
        }
    }
    master.register_file("malstone", segments);
    let mut eng = Engine::new();
    let finished = Rc::new(RefCell::new(None));
    let f = finished.clone();
    SphereEngine::simulate(
        &cluster,
        &master,
        &mut eng,
        "malstone",
        &nodes,
        FrameworkParams::sphere(),
        variant_b,
        move |eng, _r| *f.borrow_mut() = Some(eng.now()),
    );
    eng.run();
    let t = finished.borrow().expect("sphere run did not complete");
    t
}

fn first_n_per_site(topo: &Topology, per_site: usize) -> Vec<NodeId> {
    let mut nodes = Vec::new();
    for rack in 0..topo.racks.len() {
        for i in 0..per_site.min(topo.racks[rack].nodes.len()) {
            nodes.push(topo.racks[rack].nodes[i]);
        }
    }
    nodes
}

fn first_n_one_site(topo: &Topology, n: usize) -> Vec<NodeId> {
    topo.racks[0].nodes.iter().copied().take(n).collect()
}

/// Table 1: MalStone-A/B on 10B records over 20 OCT nodes (5 per site),
/// three frameworks. `scale_div` divides the record count for quick runs
/// (1 = paper scale; timing scales ~linearly so shape is preserved).
pub fn run_table1(scale_div: u64) -> Vec<Table1Row> {
    let w = Workload::table1().scaled_down(scale_div);
    let records = w.total_records;
    let nodes20 = |t: &Topology| first_n_per_site(t, 5);
    let scale = scale_div as f64;
    let mut rows = Vec::new();
    for (params, paper_a, paper_b) in [
        (FrameworkParams::hadoop_mapreduce(), 454.0 * 60.0 + 13.0, 840.0 * 60.0 + 50.0),
        (FrameworkParams::hadoop_streams(), 87.0 * 60.0 + 29.0, 142.0 * 60.0 + 32.0),
    ] {
        let a = run_hadoop(Topology::oct_2009, nodes20, &params, records, false);
        let b = run_hadoop(Topology::oct_2009, nodes20, &params, records, true);
        rows.push(Table1Row {
            framework: params.name,
            a_secs: a,
            b_secs: b,
            paper_a: paper_a / scale,
            paper_b: paper_b / scale,
        });
    }
    let a = run_sphere_sim(Topology::oct_2009, nodes20, records, false);
    let b = run_sphere_sim(Topology::oct_2009, nodes20, records, true);
    rows.push(Table1Row {
        framework: "sector-sphere",
        a_secs: a,
        b_secs: b,
        paper_a: (33.0 * 60.0 + 40.0) / scale,
        paper_b: (43.0 * 60.0 + 44.0) / scale,
    });
    rows
}

/// Table 2: 15B records — 28 nodes in one site vs 7×4 distributed;
/// Hadoop (3 and 1 replicas) and Sector. The paper calls the workload
/// only "a computation"; its per-record rate matches the MalStone-A
/// profile (Table 1's B-variant rate is ~4× slower than Table 2's rows
/// imply), so the driver runs the A variant.
pub fn run_table2(scale_div: u64) -> Vec<Table2Row> {
    let w = Workload::table2().scaled_down(scale_div);
    let records = w.total_records;
    let scale = scale_div as f64;
    let local = |t: &Topology| first_n_one_site(t, 28);
    let dist = |t: &Topology| first_n_per_site(t, 7);
    let mut rows = Vec::new();
    for (params, pl, pd) in [
        (FrameworkParams::hadoop_mapreduce(), 8650.0, 11600.0),
        (FrameworkParams::hadoop_mapreduce_r1(), 7300.0, 9600.0),
    ] {
        let l = run_hadoop(Topology::oct_2009, local, &params, records, false);
        let d = run_hadoop(Topology::oct_2009, dist, &params, records, false);
        rows.push(Table2Row {
            framework: if params.output_replication == 3 { "hadoop (3 replicas)" } else { "hadoop (1 replica)" },
            local_secs: l,
            dist_secs: d,
            paper_local: pl / scale,
            paper_dist: pd / scale,
        });
    }
    let l = run_sphere_sim(Topology::oct_2009, local, records, false);
    let d = run_sphere_sim(Topology::oct_2009, dist, records, false);
    rows.push(Table2Row {
        framework: "sector",
        local_secs: l,
        dist_secs: d,
        paper_local: 4200.0 / scale,
        paper_dist: 4400.0 / scale,
    });
    rows
}

/// Pretty-print Table 1 in the paper's format.
pub fn format_table1(rows: &[Table1Row]) -> String {
    use crate::util::units::fmt_paper_time;
    let mut s = String::new();
    s.push_str(&format!(
        "{:<20} {:>14} {:>14} {:>14} {:>14}\n",
        "", "MalStone-A", "MalStone-B", "paper-A", "paper-B"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<20} {:>14} {:>14} {:>14} {:>14}\n",
            r.framework,
            fmt_paper_time(r.a_secs),
            fmt_paper_time(r.b_secs),
            fmt_paper_time(r.paper_a),
            fmt_paper_time(r.paper_b),
        ));
    }
    s
}

/// Pretty-print Table 2 in the paper's format.
pub fn format_table2(rows: &[Table2Row]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<20} {:>12} {:>14} {:>9} {:>13}\n",
        "", "28 local (s)", "7×4 dist (s)", "penalty", "paper penalty"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<20} {:>12.0} {:>14.0} {:>8.1}% {:>12.1}%\n",
            r.framework,
            r.local_secs,
            r.dist_secs,
            100.0 * r.penalty(),
            100.0 * r.paper_penalty(),
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    // Scaled-down runs keep the event count small while preserving shape.
    const SCALE: u64 = 200; // 50M records table1, 75M table2

    #[test]
    fn table1_shape_holds() {
        let rows = run_table1(SCALE);
        assert_eq!(rows.len(), 3);
        let (mr, st, sp) = (&rows[0], &rows[1], &rows[2]);
        // Ordering: Sector < Streams < Hadoop-MR, for both variants.
        assert!(sp.a_secs < st.a_secs && st.a_secs < mr.a_secs,
            "A ordering broken: {} {} {}", sp.a_secs, st.a_secs, mr.a_secs);
        assert!(sp.b_secs < st.b_secs && st.b_secs < mr.b_secs,
            "B ordering broken: {} {} {}", sp.b_secs, st.b_secs, mr.b_secs);
        // Sector beats Hadoop-MR by a large factor (paper: 13×/19×).
        assert!(mr.b_secs / sp.b_secs > 5.0, "ratio {}", mr.b_secs / sp.b_secs);
        // B slower than A everywhere.
        for r in &rows {
            assert!(r.b_secs > r.a_secs, "{}: B !> A", r.framework);
        }
    }

    #[test]
    fn table2_shape_holds() {
        let rows = run_table2(SCALE);
        assert_eq!(rows.len(), 3);
        let (r3, r1, sec) = (&rows[0], &rows[1], &rows[2]);
        // Hadoop pays a large wide-area penalty; Sector a small one.
        assert!(r3.penalty() > 0.15, "r3 penalty {}", r3.penalty());
        assert!(r1.penalty() > 0.04, "r1 penalty {}", r1.penalty());
        assert!(sec.penalty().abs() < 0.06, "sector penalty {}", sec.penalty());
        assert!(sec.penalty() < r1.penalty() && sec.penalty() < r3.penalty());
        // 1-replica Hadoop is faster than 3-replica in both settings.
        assert!(r1.local_secs < r3.local_secs);
        assert!(r1.dist_secs < r3.dist_secs);
        // Sector fastest overall.
        assert!(sec.dist_secs < r1.dist_secs);
    }

    #[test]
    fn formatting_matches_paper_style() {
        let rows = vec![Table1Row {
            framework: "hadoop-mapreduce",
            a_secs: 454.0 * 60.0 + 13.0,
            b_secs: 840.0 * 60.0 + 50.0,
            paper_a: 1.0,
            paper_b: 2.0,
        }];
        let s = format_table1(&rows);
        assert!(s.contains("454m 13s"));
        assert!(s.contains("840m 50s"));
    }
}

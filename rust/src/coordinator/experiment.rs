//! Deprecated compatibility shims for the pre-0.2 experiment drivers.
//!
//! `run_table1` / `run_table2` used to hand-wire topologies, namenodes,
//! and engines per call site; they are now thin adapters over the
//! unified scenario API ([`crate::coordinator::scenario`],
//! [`crate::coordinator::runner`], [`crate::coordinator::registry`]) and
//! will be removed one release after 0.2. New code should run registry
//! sets (or `Testbed::builder()` scenarios) through [`ScenarioRunner`]
//! and consume [`RunReport`]s directly.

use super::registry::find_set;
use super::runner::{RunReport, ScenarioRunner};
use super::scenario::Framework;

/// One Table 1 row: a framework's MalStone-A and MalStone-B times.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub framework: &'static str,
    pub a_secs: f64,
    pub b_secs: f64,
    /// Paper-measured values for the side-by-side (seconds).
    pub paper_a: f64,
    pub paper_b: f64,
}

/// One Table 2 row: local vs distributed and the wide-area penalty.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub framework: &'static str,
    pub local_secs: f64,
    pub dist_secs: f64,
    pub paper_local: f64,
    pub paper_dist: f64,
}

impl Table2Row {
    pub fn penalty(&self) -> f64 {
        (self.dist_secs - self.local_secs) / self.local_secs
    }

    pub fn paper_penalty(&self) -> f64 {
        (self.paper_dist - self.paper_local) / self.paper_local
    }
}

/// Table 1 at `1/scale_div` of paper scale, as legacy rows.
#[deprecated(
    since = "0.2.0",
    note = "run the `table1` registry set through coordinator::ScenarioRunner instead"
)]
pub fn run_table1(scale_div: u64) -> Vec<Table1Row> {
    let set = find_set("table1").expect("table1 set registered").scaled_down(scale_div);
    let reports = ScenarioRunner::new().run_all(&set.scenarios);
    let mut rows = Vec::new();
    for (i, sc) in set.scenarios.iter().enumerate().step_by(2) {
        let (a, b): (&RunReport, &RunReport) = (&reports[i], &reports[i + 1]);
        rows.push(Table1Row {
            framework: sc.framework.name(),
            a_secs: a.simulated_secs,
            b_secs: b.simulated_secs,
            paper_a: a.paper_secs.unwrap_or(0.0),
            paper_b: b.paper_secs.unwrap_or(0.0),
        });
    }
    rows
}

/// Table 2 at `1/scale_div` of paper scale, as legacy rows.
#[deprecated(
    since = "0.2.0",
    note = "run the `table2` registry set through coordinator::ScenarioRunner instead"
)]
pub fn run_table2(scale_div: u64) -> Vec<Table2Row> {
    let set = find_set("table2").expect("table2 set registered").scaled_down(scale_div);
    let reports = ScenarioRunner::new().run_all(&set.scenarios);
    let mut rows = Vec::new();
    for (i, sc) in set.scenarios.iter().enumerate().step_by(2) {
        let (local, dist): (&RunReport, &RunReport) = (&reports[i], &reports[i + 1]);
        rows.push(Table2Row {
            framework: match sc.framework {
                Framework::HadoopMr => "hadoop (3 replicas)",
                Framework::HadoopMrR1 => "hadoop (1 replica)",
                _ => "sector",
            },
            local_secs: local.simulated_secs,
            dist_secs: dist.simulated_secs,
            paper_local: local.paper_secs.unwrap_or(0.0),
            paper_dist: dist.paper_secs.unwrap_or(0.0),
        });
    }
    rows
}

/// Pretty-print Table 1 in the paper's format.
pub fn format_table1(rows: &[Table1Row]) -> String {
    use crate::util::units::fmt_paper_time;
    let mut s = String::new();
    s.push_str(&format!(
        "{:<20} {:>14} {:>14} {:>14} {:>14}\n",
        "", "MalStone-A", "MalStone-B", "paper-A", "paper-B"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<20} {:>14} {:>14} {:>14} {:>14}\n",
            r.framework,
            fmt_paper_time(r.a_secs),
            fmt_paper_time(r.b_secs),
            fmt_paper_time(r.paper_a),
            fmt_paper_time(r.paper_b),
        ));
    }
    s
}

/// Pretty-print Table 2 in the paper's format.
pub fn format_table2(rows: &[Table2Row]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<20} {:>12} {:>14} {:>9} {:>13}\n",
        "", "28 local (s)", "7×4 dist (s)", "penalty", "paper penalty"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<20} {:>12.0} {:>14.0} {:>8.1}% {:>12.1}%\n",
            r.framework,
            r.local_secs,
            r.dist_secs,
            100.0 * r.penalty(),
            100.0 * r.paper_penalty(),
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_still_produce_rows() {
        let rows = run_table1(2000); // 5M records: a quick smoke
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].framework, "hadoop-mapreduce");
        assert_eq!(rows[2].framework, "sector-sphere");
        assert!(rows.iter().all(|r| r.a_secs > 0.0 && r.b_secs > 0.0 && r.paper_a > 0.0));

        let rows2 = run_table2(3000); // 5M records
        assert_eq!(rows2.len(), 3);
        assert_eq!(rows2[0].framework, "hadoop (3 replicas)");
        assert_eq!(rows2[1].framework, "hadoop (1 replica)");
        assert_eq!(rows2[2].framework, "sector");
        assert!(rows2.iter().all(|r| r.penalty().is_finite()));
    }

    #[test]
    fn formatting_matches_paper_style() {
        let rows = vec![Table1Row {
            framework: "hadoop-mapreduce",
            a_secs: 454.0 * 60.0 + 13.0,
            b_secs: 840.0 * 60.0 + 50.0,
            paper_a: 1.0,
            paper_b: 2.0,
        }];
        let s = format_table1(&rows);
        assert!(s.contains("454m 13s"));
        assert!(s.contains("840m 50s"));
    }
}

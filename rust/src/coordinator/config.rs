//! TOML-subset configuration parser (offline build — no toml/serde crate).
//!
//! Supports what OCT configs need: `[section]` headers (dotted names fine),
//! `key = value` with string/int/float/bool/array-of-scalars values, `#`
//! comments, and blank lines. Lookup is by `"section.key"`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed config document.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(src: &str) -> Result<Config, String> {
        let mut section = String::new();
        let mut values = BTreeMap::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let full =
                if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            let value =
                parse_value(val.trim()).map_err(|e| format!("line {}: {e}", lineno + 1))?;
            values.insert(full, value);
        }
        Ok(Config { values })
    }

    pub fn load(path: &std::path::Path) -> Result<Config, String> {
        let src = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Config::parse(&src)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn get_i64(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_i64).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Some(q) = s.strip_prefix('"') {
        let q = q.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(q.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    // Split on commas outside quotes (arrays are scalar-only; no nesting).
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# testbed description
[testbed]
sites = 4
nodes_per_rack = 32          # Figure 2
wan_gbps = 10.0
name = "oct-2009"
growing = true

[workload]
records = 10_000_000_000
frameworks = ["hadoop", "sector"]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_i64("testbed.sites", 0), 4);
        assert_eq!(c.get_i64("testbed.nodes_per_rack", 0), 32);
        assert_eq!(c.get_f64("testbed.wan_gbps", 0.0), 10.0);
        assert_eq!(c.get_str("testbed.name", ""), "oct-2009");
        assert!(c.get_bool("testbed.growing", false));
        assert_eq!(c.get_i64("workload.records", 0), 10_000_000_000);
    }

    #[test]
    fn parses_arrays() {
        let c = Config::parse(SAMPLE).unwrap();
        match c.get("workload.frameworks") {
            Some(Value::Arr(v)) => {
                assert_eq!(v.len(), 2);
                assert_eq!(v[0].as_str(), Some("hadoop"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.get_i64("nope", 7), 7);
        assert_eq!(c.get_str("nope", "x"), "x");
    }

    #[test]
    fn comments_inside_strings_kept() {
        let c = Config::parse(r##"k = "a # b""##).unwrap();
        assert_eq!(c.get_str("k", ""), "a # b");
    }

    #[test]
    fn errors_are_located() {
        let err = Config::parse("[oops\n").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let err2 = Config::parse("justakey\n").unwrap_err();
        assert!(err2.contains("key = value"), "{err2}");
        assert!(Config::parse("k = @wat").is_err());
    }

    #[test]
    fn float_and_negative() {
        let c = Config::parse("a = -3\nb = 2.5e3").unwrap();
        assert_eq!(c.get_i64("a", 0), -3);
        assert_eq!(c.get_f64("b", 0.0), 2500.0);
    }
}

//! Node and network provisioning (paper §1, §2.1: networks as "first
//! class controllable, adjustable resources", and §2.2's growth plan).
//!
//! The provisioner owns a mutable [`Topology`] between experiment runs:
//! grow sites/racks (the 2009 expansion toward 250 nodes/1000 cores),
//! retune WAN links (dynamic lightpath provisioning [13]), drain nodes,
//! and stamp out per-experiment subsets. During a run, dynamic changes go
//! through `FlowNet::set_capacity` / `CpuPool::set_speed` — the
//! provisioner records the *intent* so a testbed config can be replayed.

use crate::net::topology::NodeSpec;
use crate::net::{Cluster, NodeId, SiteId, Topology};

use super::config::Config;

/// A provisioning log entry (replayable intent).
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    AddSite { name: String },
    AddRack { site: usize, nodes: usize },
    ConnectSites { a: usize, b: usize, gbps: f64, rtt_ms: f64 },
    SetWanCapacity { a: usize, b: usize, gbps: f64 },
    DrainNode { node: usize },
}

/// Builds and evolves testbed topologies.
pub struct Provisioner {
    topo: Topology,
    spec: NodeSpec,
    log: Vec<Op>,
    drained: Vec<NodeId>,
}

impl Default for Provisioner {
    fn default() -> Self {
        Self::new()
    }
}

impl Provisioner {
    pub fn new() -> Self {
        Provisioner { topo: Topology::new(), spec: NodeSpec::default(), log: Vec::new(), drained: Vec::new() }
    }

    /// Start from the paper's Figure-2 testbed.
    pub fn oct_2009() -> Self {
        Provisioner {
            topo: Topology::oct_2009(),
            spec: NodeSpec::default(),
            log: Vec::new(),
            drained: Vec::new(),
        }
    }

    /// Build from a `[testbed]` config section (sites, nodes_per_rack,
    /// wan_gbps, rtt_ms defaults).
    pub fn from_config(cfg: &Config) -> Self {
        let sites = cfg.get_i64("testbed.sites", 4).max(1) as usize;
        let nodes = cfg.get_i64("testbed.nodes_per_rack", 32).max(1) as usize;
        let wan_gbps = cfg.get_f64("testbed.wan_gbps", 10.0);
        let rtt_ms = cfg.get_f64("testbed.rtt_ms", 40.0);
        let mut p = Provisioner::new();
        for i in 0..sites {
            p.add_site(&format!("site{i}"));
            p.add_rack(i, nodes);
        }
        for a in 0..sites {
            for b in a + 1..sites {
                p.connect_sites(a, b, wan_gbps, rtt_ms);
            }
        }
        p
    }

    pub fn add_site(&mut self, name: &str) -> SiteId {
        self.log.push(Op::AddSite { name: name.to_string() });
        self.topo.add_site(name)
    }

    pub fn add_rack(&mut self, site: usize, nodes: usize) {
        self.log.push(Op::AddRack { site, nodes });
        self.topo.add_rack(SiteId(site), nodes, &self.spec, 1.25e9);
    }

    pub fn connect_sites(&mut self, a: usize, b: usize, gbps: f64, rtt_ms: f64) {
        self.log.push(Op::ConnectSites { a, b, gbps, rtt_ms });
        self.topo.connect_sites(SiteId(a), SiteId(b), gbps * 1e9 / 8.0, rtt_ms / 1e3);
    }

    /// Mark a node out of service (engines must skip drained nodes).
    pub fn drain_node(&mut self, node: usize) {
        self.log.push(Op::DrainNode { node });
        if !self.drained.contains(&NodeId(node)) {
            self.drained.push(NodeId(node));
        }
    }

    pub fn drained(&self) -> &[NodeId] {
        &self.drained
    }

    pub fn log(&self) -> &[Op] {
        &self.log
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Finalize into a cluster (consumes the builder's current topology).
    pub fn build(self) -> Cluster {
        Cluster::new(self.topo)
    }

    /// §2.2 expansion: add MIT-LL and PSC racks to the 2009 testbed and
    /// interconnect them at 10 Gb/s.
    pub fn expand_2009_plan(&mut self) {
        let base_sites = self.topo.sites.len();
        let mit = self.add_site("MIT-LL");
        self.add_rack(mit.0, 30);
        let psc = self.add_site("PSC-CMU");
        self.add_rack(psc.0, 30);
        for s in 0..base_sites {
            self.connect_sites(s, mit.0, 10.0, 30.0);
            self.connect_sites(s, psc.0, 10.0, 25.0);
        }
        self.connect_sites(mit.0, psc.0, 10.0, 18.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_config_builds_requested_shape() {
        let cfg = Config::parse("[testbed]\nsites = 2\nnodes_per_rack = 4\nwan_gbps = 1.0\n").unwrap();
        let p = Provisioner::from_config(&cfg);
        assert_eq!(p.topology().sites.len(), 2);
        assert_eq!(p.topology().num_nodes(), 8);
        let lid = p.topology().wan_link(SiteId(0), SiteId(1)).unwrap();
        assert!((p.topology().link(lid).capacity - 1.25e8).abs() < 1.0);
    }

    #[test]
    fn expansion_plan_reaches_growth_target() {
        let mut p = Provisioner::oct_2009();
        p.expand_2009_plan();
        // 128 + 60 — "by then the OCT will have about 250 nodes"
        // (two more 32-node racks were also planned; we model the two
        // named sites).
        assert_eq!(p.topology().num_nodes(), 188);
        assert_eq!(p.topology().sites.len(), 6);
        // Fully connected: every site pair has a WAN link.
        for a in 0..6 {
            for b in 0..6 {
                if a != b {
                    assert!(p.topology().wan_link(SiteId(a), SiteId(b)).is_some(), "{a}->{b}");
                }
            }
        }
    }

    #[test]
    fn log_records_intent() {
        let mut p = Provisioner::new();
        p.add_site("x");
        p.add_rack(0, 2);
        p.drain_node(1);
        assert_eq!(
            p.log(),
            &[
                Op::AddSite { name: "x".into() },
                Op::AddRack { site: 0, nodes: 2 },
                Op::DrainNode { node: 1 }
            ]
        );
        assert_eq!(p.drained(), &[NodeId(1)]);
    }

    #[test]
    fn build_yields_cluster() {
        let c = Provisioner::oct_2009().build();
        assert_eq!(c.topo.num_nodes(), 128);
    }
}

//! Node and network provisioning (paper §1, §2.1: networks as "first
//! class controllable, adjustable resources", and §2.2's growth plan).
//!
//! The abstract promises "novel node and network provisioning services";
//! this module is that subsystem's intent layer. A [`Provisioner`] owns a
//! mutable [`Topology`] and a replayable [`Op`] log covering the full
//! provisioning vocabulary:
//!
//! - **growth** — add sites/racks (the 2009 expansion toward 250
//!   nodes/1000 cores), connect and retune WAN links;
//! - **node imaging** — stamp an image onto a node
//!   ([`Provisioner::image_node`]); the *runtime* imaging latency (image
//!   fetch + install as simulated time) is paid by the scenario runner,
//!   while the provisioner records which image each node carries;
//! - **dynamic lightpaths** — provision and tear down wide-area waves
//!   ([`Provisioner::provision_lightpath`] /
//!   [`Provisioner::teardown_lightpath`], the paper's [13]); a torn-down
//!   wave keeps a routed-IP control floor of [`LIGHTPATH_FLOOR_BPS`]
//!   because capacity links cannot vanish mid-simulation;
//! - **tenant slices** — carve and release subsets of nodes plus an
//!   optional dedicated wave ([`Provisioner::carve_slice`] /
//!   [`Provisioner::release_slice`]), the unit of multi-tenant admission;
//! - **service state** — drain and undrain nodes.
//!
//! During a run, dynamic changes go through `FlowNet::set_capacity` /
//! `CpuPool::set_speed`; the provisioner records the *intent* so a
//! testbed configuration can be replayed. [`SliceScheduler`] sits on top:
//! it admits or queues slice requests against the finite inventory (free
//! nodes per site, spare wave spectrum) that one shared testbed offers
//! concurrent tenants.

use std::collections::BTreeMap;

use crate::net::topology::NodeSpec;
use crate::net::{Cluster, LinkId, NodeId, SiteId, Topology};

use super::config::Config;

/// Live capacity a torn-down lightpath falls back to (bytes/s): the wave
/// is gone but the routed IP control path remains, so the link never hits
/// the fluid network's capacity-must-be-positive wall. Also the dark
/// level a provisioned-but-not-yet-granted wave idles at.
pub const LIGHTPATH_FLOOR_BPS: f64 = 1.25e6;

/// A provisioning log entry (replayable intent).
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    AddSite { name: String },
    AddRack { site: usize, nodes: usize },
    ConnectSites { a: usize, b: usize, gbps: f64, rtt_ms: f64 },
    SetWanCapacity { a: usize, b: usize, gbps: f64 },
    DrainNode { node: usize },
    /// Return a drained node to service (the inverse of `DrainNode`):
    /// repaired hardware re-enters the pool.
    UndrainNode { node: usize },
    /// Stamp `image` onto a node (Bare/previous image → `image`).
    ImageNode { node: usize, image: String },
    /// Light a new duplex wave of `gbps` per direction across the testbed.
    ProvisionLightpath { label: String, gbps: f64 },
    /// Darken a provisioned wave down to [`LIGHTPATH_FLOOR_BPS`].
    TeardownLightpath { label: String },
    /// Dedicate `nodes` (and optionally a `lightpath_gbps` wave grant) to
    /// a tenant.
    CarveSlice { tenant: String, nodes: Vec<usize>, lightpath_gbps: Option<f64> },
    /// Return a tenant's slice to the shared pool.
    ReleaseSlice { tenant: String },
}

/// A provisioned wave: its links exist in the topology forever; `lit`
/// says whether it currently carries its granted capacity or the floor.
#[derive(Debug, Clone, PartialEq)]
pub struct Lightpath {
    pub label: String,
    pub gbps: f64,
    pub east: LinkId,
    pub west: LinkId,
    pub lit: bool,
}

/// A recorded tenant slice (provisioner-side state; the runtime
/// counterpart handed to tenants is [`Slice`]).
#[derive(Debug, Clone, PartialEq)]
pub struct SliceRecord {
    pub tenant: String,
    pub nodes: Vec<usize>,
    pub lightpath_gbps: Option<f64>,
}

/// Builds and evolves testbed topologies.
pub struct Provisioner {
    topo: Topology,
    spec: NodeSpec,
    log: Vec<Op>,
    drained: Vec<NodeId>,
    images: BTreeMap<usize, String>,
    lightpaths: Vec<Lightpath>,
    slices: Vec<SliceRecord>,
}

impl Default for Provisioner {
    fn default() -> Self {
        Self::new()
    }
}

impl Provisioner {
    pub fn new() -> Self {
        Provisioner {
            topo: Topology::new(),
            spec: NodeSpec::default(),
            log: Vec::new(),
            drained: Vec::new(),
            images: BTreeMap::new(),
            lightpaths: Vec::new(),
            slices: Vec::new(),
        }
    }

    /// Start from the paper's Figure-2 testbed.
    pub fn oct_2009() -> Self {
        Provisioner { topo: Topology::oct_2009(), ..Provisioner::new() }
    }

    /// Build from a `[testbed]` config section (sites, nodes_per_rack,
    /// wan_gbps, rtt_ms defaults).
    pub fn from_config(cfg: &Config) -> Self {
        let sites = cfg.get_i64("testbed.sites", 4).max(1) as usize;
        let nodes = cfg.get_i64("testbed.nodes_per_rack", 32).max(1) as usize;
        let wan_gbps = cfg.get_f64("testbed.wan_gbps", 10.0);
        let rtt_ms = cfg.get_f64("testbed.rtt_ms", 40.0);
        let mut p = Provisioner::new();
        for i in 0..sites {
            p.add_site(&format!("site{i}"));
            p.add_rack(i, nodes);
        }
        for a in 0..sites {
            for b in a + 1..sites {
                p.connect_sites(a, b, wan_gbps, rtt_ms);
            }
        }
        p
    }

    pub fn add_site(&mut self, name: &str) -> SiteId {
        self.log.push(Op::AddSite { name: name.to_string() });
        self.topo.add_site(name)
    }

    pub fn add_rack(&mut self, site: usize, nodes: usize) {
        self.log.push(Op::AddRack { site, nodes });
        self.topo.add_rack(SiteId(site), nodes, &self.spec, 1.25e9);
    }

    pub fn connect_sites(&mut self, a: usize, b: usize, gbps: f64, rtt_ms: f64) {
        self.log.push(Op::ConnectSites { a, b, gbps, rtt_ms });
        self.topo.connect_sites(SiteId(a), SiteId(b), gbps * 1e9 / 8.0, rtt_ms / 1e3);
    }

    /// Retune an existing WAN link pair (dynamic lightpath provisioning
    /// [13]): both directions get the new capacity.
    pub fn set_wan_capacity(&mut self, a: usize, b: usize, gbps: f64) {
        self.log.push(Op::SetWanCapacity { a, b, gbps });
        for (x, y) in [(a, b), (b, a)] {
            let lid = self
                .topo
                .wan_link(SiteId(x), SiteId(y))
                .unwrap_or_else(|| panic!("no WAN link {x}->{y} to retune"));
            self.topo.set_link_capacity(lid, gbps * 1e9 / 8.0);
        }
    }

    /// Stamp `image` onto a node: the intent side of node imaging. The
    /// scenario runner pays the imaging *latency* (image fetch over the
    /// fabric plus install time, on the event engine); the provisioner
    /// tracks which image every node ends up carrying.
    ///
    /// ```
    /// use oct::coordinator::Provisioner;
    /// let mut p = Provisioner::new();
    /// p.add_site("east");
    /// p.add_rack(0, 4);
    /// assert_eq!(p.node_image(2), None); // bare metal
    /// p.image_node(2, "hadoop-0.18.3");
    /// assert_eq!(p.node_image(2), Some("hadoop-0.18.3"));
    /// // The intent replays: a rebuilt provisioner carries the image too.
    /// let r = Provisioner::replay(p.log());
    /// assert_eq!(r.node_image(2), Some("hadoop-0.18.3"));
    /// ```
    pub fn image_node(&mut self, node: usize, image: &str) {
        self.log.push(Op::ImageNode { node, image: image.to_string() });
        self.images.insert(node, image.to_string());
    }

    /// The image a node currently carries (`None` = bare metal).
    pub fn node_image(&self, node: usize) -> Option<&str> {
        self.images.get(&node).map(String::as_str)
    }

    /// Node → image map (nodes absent are bare).
    pub fn images(&self) -> &BTreeMap<usize, String> {
        &self.images
    }

    /// Light a new duplex wave of `gbps` per direction across the fiber
    /// plant and return its directed `(east, west)` links. The wave is
    /// added to the topology at its granted capacity but routes nothing
    /// by itself — a tenant view's `route_over_wave` (or a replayed
    /// config) decides who rides it.
    ///
    /// ```
    /// use oct::coordinator::Provisioner;
    /// let mut p = Provisioner::oct_2009();
    /// let links_before = p.topology().links.len();
    /// let (east, west) = p.provision_lightpath("alice", 10.0);
    /// assert_eq!(p.topology().links.len(), links_before + 2);
    /// assert!((p.topology().link(east).capacity - 1.25e9).abs() < 1.0);
    /// p.teardown_lightpath("alice");
    /// assert!(p.topology().link(east).capacity < 2e6); // control floor
    /// assert_eq!(p.topology().link(west).kind, p.topology().link(east).kind);
    /// ```
    pub fn provision_lightpath(&mut self, label: &str, gbps: f64) -> (LinkId, LinkId) {
        assert!(gbps > 0.0, "lightpath grant must be positive");
        self.log.push(Op::ProvisionLightpath { label: label.to_string(), gbps });
        let (east, west) = self.topo.add_wave(gbps * 1e9 / 8.0, label);
        self.lightpaths.push(Lightpath { label: label.to_string(), gbps, east, west, lit: true });
        (east, west)
    }

    /// Darken a provisioned wave: both directions drop to
    /// [`LIGHTPATH_FLOOR_BPS`] (the routed control path) and the wave is
    /// marked unlit. Tears down the *most recently lit* wave with this
    /// label; panics if none is lit.
    pub fn teardown_lightpath(&mut self, label: &str) {
        self.log.push(Op::TeardownLightpath { label: label.to_string() });
        let lp = self
            .lightpaths
            .iter_mut()
            .rev()
            .find(|l| l.lit && l.label == label)
            .unwrap_or_else(|| panic!("no lit lightpath '{label}' to tear down"));
        lp.lit = false;
        let (east, west) = (lp.east, lp.west);
        self.topo.set_link_capacity(east, LIGHTPATH_FLOOR_BPS);
        self.topo.set_link_capacity(west, LIGHTPATH_FLOOR_BPS);
    }

    /// Every wave ever provisioned, in order, with its lit/dark state.
    pub fn lightpaths(&self) -> &[Lightpath] {
        &self.lightpaths
    }

    /// Dedicate `nodes` to `tenant`, optionally alongside a wave grant.
    /// The provisioner records intent only — admission control against
    /// live inventory is [`SliceScheduler`]'s job. A tenant may hold at
    /// most one slice at a time.
    pub fn carve_slice(&mut self, tenant: &str, nodes: &[usize], lightpath_gbps: Option<f64>) {
        assert!(
            !self.slices.iter().any(|s| s.tenant == tenant),
            "tenant '{tenant}' already holds a slice"
        );
        self.log.push(Op::CarveSlice {
            tenant: tenant.to_string(),
            nodes: nodes.to_vec(),
            lightpath_gbps,
        });
        self.slices.push(SliceRecord {
            tenant: tenant.to_string(),
            nodes: nodes.to_vec(),
            lightpath_gbps,
        });
    }

    /// Return a tenant's slice to the pool. Idempotent (releasing a
    /// tenant that holds nothing only records the intent).
    pub fn release_slice(&mut self, tenant: &str) {
        self.log.push(Op::ReleaseSlice { tenant: tenant.to_string() });
        self.slices.retain(|s| s.tenant != tenant);
    }

    /// Currently-carved slices.
    pub fn slices(&self) -> &[SliceRecord] {
        &self.slices
    }

    /// Apply one logged operation (the replay primitive). Every public
    /// mutator routes through the same methods, so applying an op both
    /// re-logs and re-executes it.
    pub fn apply(&mut self, op: &Op) {
        match op {
            Op::AddSite { name } => {
                self.add_site(name);
            }
            Op::AddRack { site, nodes } => self.add_rack(*site, *nodes),
            Op::ConnectSites { a, b, gbps, rtt_ms } => self.connect_sites(*a, *b, *gbps, *rtt_ms),
            Op::SetWanCapacity { a, b, gbps } => self.set_wan_capacity(*a, *b, *gbps),
            Op::DrainNode { node } => self.drain_node(*node),
            Op::UndrainNode { node } => self.undrain_node(*node),
            Op::ImageNode { node, image } => self.image_node(*node, image),
            Op::ProvisionLightpath { label, gbps } => {
                self.provision_lightpath(label, *gbps);
            }
            Op::TeardownLightpath { label } => self.teardown_lightpath(label),
            Op::CarveSlice { tenant, nodes, lightpath_gbps } => {
                self.carve_slice(tenant, nodes, *lightpath_gbps)
            }
            Op::ReleaseSlice { tenant } => self.release_slice(tenant),
        }
    }

    /// Rebuild a provisioner from a recorded op log — the "replayable
    /// intent" promise: replaying a log captured from an empty start
    /// reproduces the topology exactly. Logs recorded over a seeded base
    /// (e.g. [`Provisioner::oct_2009`]) must be applied onto the same
    /// base with [`Provisioner::apply`].
    pub fn replay(ops: &[Op]) -> Provisioner {
        let mut p = Provisioner::new();
        for op in ops {
            p.apply(op);
        }
        p
    }

    /// Mark a node out of service (engines must skip drained nodes).
    pub fn drain_node(&mut self, node: usize) {
        self.log.push(Op::DrainNode { node });
        if !self.drained.contains(&NodeId(node)) {
            self.drained.push(NodeId(node));
        }
    }

    /// Return a node to service — the inverse of
    /// [`Provisioner::drain_node`]. Idempotent (undraining a node that
    /// was never drained only records the intent).
    pub fn undrain_node(&mut self, node: usize) {
        self.log.push(Op::UndrainNode { node });
        self.drained.retain(|&n| n != NodeId(node));
    }

    pub fn drained(&self) -> &[NodeId] {
        &self.drained
    }

    pub fn log(&self) -> &[Op] {
        &self.log
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Finalize into a cluster (consumes the builder's current topology).
    pub fn build(self) -> Cluster {
        Cluster::new(self.topo)
    }

    /// §2.2 expansion: add MIT-LL and PSC racks to the 2009 testbed and
    /// interconnect them at 10 Gb/s.
    pub fn expand_2009_plan(&mut self) {
        let base_sites = self.topo.sites.len();
        let mit = self.add_site("MIT-LL");
        self.add_rack(mit.0, 30);
        let psc = self.add_site("PSC-CMU");
        self.add_rack(psc.0, 30);
        for s in 0..base_sites {
            self.connect_sites(s, mit.0, 10.0, 30.0);
            self.connect_sites(s, psc.0, 10.0, 25.0);
        }
        self.connect_sites(mit.0, psc.0, 10.0, 18.0);
    }
}

/// A carved tenant slice: the runtime handle [`SliceScheduler::try_carve`]
/// returns, naming the dedicated nodes and (when granted) the tenant's
/// wave links and spectrum reservation.
#[derive(Debug, Clone, PartialEq)]
pub struct Slice {
    pub tenant: String,
    pub nodes: Vec<NodeId>,
    /// The tenant's dedicated wave as `(east, west)` links, when one was
    /// requested (`None` = the slice rides the shared testbed wave).
    pub wave: Option<(LinkId, LinkId)>,
    /// Spectrum reserved from the scheduler's spare pool, Gb/s.
    pub lightpath_gbps: Option<f64>,
}

/// Spare optical spectrum of the default [`SliceScheduler`]: two
/// additional 10 Gb/s lambdas on the national fiber plant beyond the
/// always-lit shared CiscoWave.
pub const DEFAULT_SPARE_WAVE_GBPS: f64 = 20.0;

/// Admission control for tenant slices over one live testbed.
///
/// The inventory is finite — free nodes per site and spare wave spectrum
/// ([`DEFAULT_SPARE_WAVE_GBPS`] by default) — so a request either carves
/// immediately or must wait for a running tenant's release; callers queue
/// and retry (the multi-tenant scenario runner retries FIFO on every
/// completion). Every admission and release is logged as a replayable
/// [`Op`].
///
/// ```
/// use oct::coordinator::SliceScheduler;
/// use oct::net::Topology;
/// use std::rc::Rc;
///
/// let topo = Rc::new(Topology::oct_2009()); // 4 sites × 32 nodes
/// let mut sched = SliceScheduler::new(topo, 20.0);
/// let a = sched.try_carve("alice", 20, Some(10.0), None).expect("fits");
/// assert_eq!(a.nodes.len(), 80);
/// // 12 free nodes left per site: a 20-per-site request must queue...
/// assert!(sched.try_carve("bob", 20, None, None).is_none());
/// // ...until alice releases her slice.
/// sched.release(&a);
/// assert!(sched.try_carve("bob", 20, None, None).is_some());
/// ```
pub struct SliceScheduler {
    topo: std::rc::Rc<Topology>,
    /// Per-node availability (false = carved out or drained).
    free: Vec<bool>,
    spare_gbps: f64,
    /// Tenants currently holding a slice (one slice per tenant, so the
    /// by-name `ReleaseSlice` intent stays unambiguous under replay).
    holders: Vec<String>,
    log: Vec<Op>,
}

impl SliceScheduler {
    /// A scheduler over `topo` with `spare_gbps` of unlit spectrum.
    pub fn new(topo: std::rc::Rc<Topology>, spare_gbps: f64) -> SliceScheduler {
        let free = vec![true; topo.num_nodes()];
        SliceScheduler { topo, free, spare_gbps, holders: Vec::new(), log: Vec::new() }
    }

    /// Remove drained nodes from the carvable pool.
    pub fn exclude(&mut self, nodes: &[NodeId]) {
        for &n in nodes {
            self.free[n.0] = false;
        }
    }

    /// Try to admit a slice of `nodes_per_site` nodes from *every* site
    /// plus an optional `lightpath_gbps` spectrum reservation. Returns
    /// `None` — with the inventory untouched — when any site runs short
    /// or the spare spectrum cannot cover the grant; the caller queues
    /// and retries after a [`SliceScheduler::release`]. `wave` carries
    /// the tenant's pre-provisioned wave links through to the slice.
    /// A tenant holds at most one slice at a time (like
    /// [`Provisioner::carve_slice`], so the by-name release intent stays
    /// unambiguous under replay); re-carving a holder panics.
    pub fn try_carve(
        &mut self,
        tenant: &str,
        nodes_per_site: usize,
        lightpath_gbps: Option<f64>,
        wave: Option<(LinkId, LinkId)>,
    ) -> Option<Slice> {
        assert!(nodes_per_site > 0, "empty slice request");
        assert!(
            !self.holders.iter().any(|t| t == tenant),
            "tenant '{tenant}' already holds a slice"
        );
        if let Some(g) = lightpath_gbps {
            assert!(g > 0.0);
            if g > self.spare_gbps + 1e-9 {
                return None;
            }
        }
        let mut nodes: Vec<NodeId> = Vec::with_capacity(nodes_per_site * self.topo.sites.len());
        for site in &self.topo.sites {
            let mut got = 0;
            'racks: for rid in &site.racks {
                for &n in &self.topo.racks[rid.0].nodes {
                    if got == nodes_per_site {
                        break 'racks;
                    }
                    if self.free[n.0] {
                        nodes.push(n);
                        got += 1;
                    }
                }
            }
            if got < nodes_per_site {
                return None; // inventory untouched: nothing was committed
            }
        }
        for &n in &nodes {
            self.free[n.0] = false;
        }
        if let Some(g) = lightpath_gbps {
            self.spare_gbps -= g;
        }
        self.holders.push(tenant.to_string());
        self.log.push(Op::CarveSlice {
            tenant: tenant.to_string(),
            nodes: nodes.iter().map(|n| n.0).collect(),
            lightpath_gbps,
        });
        Some(Slice { tenant: tenant.to_string(), nodes, wave, lightpath_gbps })
    }

    /// Return a slice's nodes and spectrum to the pool.
    pub fn release(&mut self, slice: &Slice) {
        for &n in &slice.nodes {
            self.free[n.0] = true;
        }
        if let Some(g) = slice.lightpath_gbps {
            self.spare_gbps += g;
        }
        self.holders.retain(|t| t != &slice.tenant);
        self.log.push(Op::ReleaseSlice { tenant: slice.tenant.clone() });
    }

    /// Nodes currently carvable.
    pub fn free_nodes(&self) -> usize {
        self.free.iter().filter(|&&f| f).count()
    }

    /// Unreserved spectrum, Gb/s.
    pub fn spare_gbps(&self) -> f64 {
        self.spare_gbps
    }

    /// The admission log: carves and releases as replayable intents.
    pub fn log(&self) -> &[Op] {
        &self.log
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::rc::Rc;

    #[test]
    fn from_config_builds_requested_shape() {
        let cfg =
            Config::parse("[testbed]\nsites = 2\nnodes_per_rack = 4\nwan_gbps = 1.0\n").unwrap();
        let p = Provisioner::from_config(&cfg);
        assert_eq!(p.topology().sites.len(), 2);
        assert_eq!(p.topology().num_nodes(), 8);
        let lid = p.topology().wan_link(SiteId(0), SiteId(1)).unwrap();
        assert!((p.topology().link(lid).capacity - 1.25e8).abs() < 1.0);
    }

    #[test]
    fn expansion_plan_reaches_growth_target() {
        let mut p = Provisioner::oct_2009();
        p.expand_2009_plan();
        // 128 + 60 — "by then the OCT will have about 250 nodes"
        // (two more 32-node racks were also planned; we model the two
        // named sites).
        assert_eq!(p.topology().num_nodes(), 188);
        assert_eq!(p.topology().sites.len(), 6);
        // Fully connected: every site pair has a WAN link.
        for a in 0..6 {
            for b in 0..6 {
                if a != b {
                    assert!(p.topology().wan_link(SiteId(a), SiteId(b)).is_some(), "{a}->{b}");
                }
            }
        }
    }

    #[test]
    fn log_records_intent() {
        let mut p = Provisioner::new();
        p.add_site("x");
        p.add_rack(0, 2);
        p.drain_node(1);
        assert_eq!(
            p.log(),
            &[
                Op::AddSite { name: "x".into() },
                Op::AddRack { site: 0, nodes: 2 },
                Op::DrainNode { node: 1 }
            ]
        );
        assert_eq!(p.drained(), &[NodeId(1)]);
    }

    #[test]
    fn build_yields_cluster() {
        let c = Provisioner::oct_2009().build();
        assert_eq!(c.topo.num_nodes(), 128);
    }

    #[test]
    fn replaying_the_op_log_reproduces_the_topology() {
        // Build a non-trivial testbed through every op kind.
        let mut p = Provisioner::new();
        p.add_site("east");
        p.add_site("west");
        p.add_site("south");
        p.add_rack(0, 6);
        p.add_rack(1, 4);
        p.add_rack(2, 5);
        p.connect_sites(0, 1, 10.0, 40.0);
        p.connect_sites(0, 2, 10.0, 25.0);
        p.connect_sites(1, 2, 1.0, 60.0);
        p.set_wan_capacity(0, 1, 2.5); // lightpath retune after the fact
        p.drain_node(3);

        let r = Provisioner::replay(p.log());
        // Identical shape: site/node/link counts.
        assert_eq!(r.topology().sites.len(), p.topology().sites.len());
        assert_eq!(r.topology().num_nodes(), p.topology().num_nodes());
        assert_eq!(r.topology().links.len(), p.topology().links.len());
        // Identical WAN capacities in both directions of every pair,
        // including the retuned one.
        for a in 0..3 {
            for b in 0..3 {
                if a == b {
                    continue;
                }
                let (la, lb) = (
                    p.topology().wan_link(SiteId(a), SiteId(b)).unwrap(),
                    r.topology().wan_link(SiteId(a), SiteId(b)).unwrap(),
                );
                assert_eq!(la, lb, "link ids diverge for {a}->{b}");
                assert_eq!(
                    p.topology().link(la).capacity,
                    r.topology().link(lb).capacity,
                    "capacity diverges for {a}->{b}"
                );
            }
        }
        let retuned = r.topology().wan_link(SiteId(0), SiteId(1)).unwrap();
        assert!((r.topology().link(retuned).capacity - 2.5e9 / 8.0).abs() < 1.0);
        // Drains and the log itself replay too.
        assert_eq!(r.drained(), p.drained());
        assert_eq!(r.log(), p.log());
    }

    #[test]
    fn drain_undrain_round_trip_replays() {
        let mut p = Provisioner::new();
        p.add_site("x");
        p.add_rack(0, 4);
        p.drain_node(1);
        p.drain_node(2);
        p.undrain_node(1);
        assert_eq!(p.drained(), &[NodeId(2)]);
        // The round trip is fully recorded and replays to the same state.
        let r = Provisioner::replay(p.log());
        assert_eq!(r.drained(), p.drained());
        assert_eq!(r.log(), p.log());
        assert!(r.log().contains(&Op::UndrainNode { node: 1 }));
        // Undrain of a never-drained node: intent logged, state unchanged.
        let mut q = Provisioner::new();
        q.add_site("y");
        q.add_rack(0, 2);
        q.undrain_node(0);
        assert!(q.drained().is_empty());
        let rq = Provisioner::replay(q.log());
        assert!(rq.drained().is_empty());
        assert_eq!(rq.log(), q.log());
        // Drain → undrain → drain ends drained, under replay too.
        let mut z = Provisioner::new();
        z.add_site("z");
        z.add_rack(0, 2);
        z.drain_node(0);
        z.undrain_node(0);
        z.drain_node(0);
        assert_eq!(z.drained(), &[NodeId(0)]);
        assert_eq!(Provisioner::replay(z.log()).drained(), z.drained());
    }

    #[test]
    fn apply_replays_onto_a_seeded_base() {
        let mut recorded = Provisioner::oct_2009();
        recorded.expand_2009_plan();
        let mut replayed = Provisioner::oct_2009();
        for op in recorded.log().to_vec() {
            replayed.apply(&op);
        }
        assert_eq!(replayed.topology().num_nodes(), recorded.topology().num_nodes());
        assert_eq!(replayed.topology().sites.len(), recorded.topology().sites.len());
        assert_eq!(replayed.log(), recorded.log());
    }

    #[test]
    fn imaging_lightpath_and_slice_ops_replay_from_empty() {
        // Exercise every new op kind and replay the log from scratch.
        let mut p = Provisioner::new();
        p.add_site("east");
        p.add_site("west");
        p.add_rack(0, 4);
        p.add_rack(1, 4);
        p.connect_sites(0, 1, 10.0, 40.0);
        p.image_node(0, "hadoop-0.18.3");
        p.image_node(1, "hadoop-0.18.3");
        p.image_node(0, "sector-sphere-1.24"); // re-image: latest wins
        let (east, west) = p.provision_lightpath("alice", 10.0);
        p.provision_lightpath("bob", 2.5);
        p.teardown_lightpath("bob");
        p.carve_slice("alice", &[0, 1, 4, 5], Some(10.0));
        p.carve_slice("carol", &[2, 6], None);
        p.release_slice("carol");

        let r = Provisioner::replay(p.log());
        assert_eq!(r.log(), p.log());
        assert_eq!(r.images(), p.images());
        assert_eq!(r.node_image(0), Some("sector-sphere-1.24"));
        assert_eq!(r.node_image(2), None);
        assert_eq!(r.lightpaths(), p.lightpaths());
        assert_eq!(r.slices(), p.slices());
        // Alice's slice survived, carol's release removed hers.
        assert_eq!(r.slices().len(), 1);
        assert_eq!(r.slices()[0].tenant, "alice");
        assert_eq!(r.slices()[0].lightpath_gbps, Some(10.0));
        // Wave links landed at the same ids and capacities on both sides.
        assert_eq!(r.topology().links.len(), p.topology().links.len());
        assert!((r.topology().link(east).capacity - 1.25e9).abs() < 1.0);
        assert!((r.topology().link(west).capacity - 1.25e9).abs() < 1.0);
        // The torn-down wave sits at the control floor under replay too.
        let bob = &r.lightpaths()[1];
        assert!(!bob.lit);
        assert_eq!(r.topology().link(bob.east).capacity, LIGHTPATH_FLOOR_BPS);
        assert_eq!(r.topology().link(bob.west).capacity, LIGHTPATH_FLOOR_BPS);
    }

    #[test]
    fn new_ops_replay_onto_a_seeded_base() {
        // Record over the Figure-2 base, then apply the same log onto a
        // fresh copy of the base: identical end state.
        let mut recorded = Provisioner::oct_2009();
        recorded.image_node(7, "malstone-bench");
        recorded.provision_lightpath("tenant-a", 10.0);
        recorded.carve_slice("tenant-a", &[0, 1, 32, 33], Some(10.0));
        recorded.teardown_lightpath("tenant-a");
        recorded.release_slice("tenant-a");
        let mut replayed = Provisioner::oct_2009();
        for op in recorded.log().to_vec() {
            replayed.apply(&op);
        }
        assert_eq!(replayed.log(), recorded.log());
        assert_eq!(replayed.images(), recorded.images());
        assert_eq!(replayed.lightpaths(), recorded.lightpaths());
        assert_eq!(replayed.slices(), recorded.slices());
        assert_eq!(replayed.topology().links.len(), recorded.topology().links.len());
    }

    #[test]
    fn interleaved_drain_undrain_carve_sequence_replays() {
        // The satellite case: service state and slice state interleave.
        let mut p = Provisioner::new();
        p.add_site("s");
        p.add_rack(0, 8);
        p.drain_node(3);
        p.carve_slice("t1", &[0, 1], None);
        p.undrain_node(3);
        p.image_node(3, "repaired-baseline");
        p.carve_slice("t2", &[2, 3], Some(2.5));
        p.drain_node(5);
        p.release_slice("t1");
        p.carve_slice("t3", &[0, 1, 4], None);
        p.undrain_node(5);
        let r = Provisioner::replay(p.log());
        assert_eq!(r.log(), p.log());
        assert_eq!(r.drained(), p.drained());
        assert_eq!(r.slices(), p.slices());
        assert_eq!(r.images(), p.images());
        assert!(r.drained().is_empty());
        let tenants: Vec<&str> = r.slices().iter().map(|s| s.tenant.as_str()).collect();
        assert_eq!(tenants, vec!["t2", "t3"]);
    }

    #[test]
    fn scheduler_admits_against_inventory_and_queues_the_rest() {
        let topo = Rc::new(Topology::oct_2009());
        let mut sched = SliceScheduler::new(topo.clone(), DEFAULT_SPARE_WAVE_GBPS);
        assert_eq!(sched.free_nodes(), 128);
        let a = sched.try_carve("alice", 5, Some(10.0), None).expect("alice fits");
        assert_eq!(a.nodes.len(), 20);
        let b = sched.try_carve("bob", 5, Some(10.0), None).expect("bob fits");
        // Slices are disjoint and take first-free nodes per site.
        assert!(a.nodes.iter().all(|n| !b.nodes.contains(n)));
        assert_eq!(sched.free_nodes(), 128 - 40);
        assert_eq!(sched.spare_gbps(), 0.0);
        // Eve's nodes would fit but the spectrum pool is exhausted.
        assert!(sched.try_carve("eve", 5, Some(10.0), None).is_none());
        // The denial left the inventory untouched.
        assert_eq!(sched.free_nodes(), 88);
        // A waveless request still fits on nodes alone.
        let c = sched.try_carve("carol", 20, None, None).expect("carol fits");
        assert_eq!(c.nodes.len(), 80);
        // Now nodes run short too (2 free per site < 5).
        assert!(sched.try_carve("dave", 5, None, None).is_none());
        // Releases return both nodes and spectrum; eve then admits.
        sched.release(&a);
        sched.release(&c);
        let e = sched.try_carve("eve", 5, Some(10.0), None).expect("eve admitted after release");
        assert_eq!(e.nodes.len(), 20);
        assert!((sched.spare_gbps() - 0.0).abs() < 1e-9);
        // The admission log is replayable intent.
        let carves = sched.log().iter().filter(|op| matches!(op, Op::CarveSlice { .. })).count();
        let releases =
            sched.log().iter().filter(|op| matches!(op, Op::ReleaseSlice { .. })).count();
        assert_eq!((carves, releases), (4, 2));
        let mut p = Provisioner::oct_2009();
        for op in sched.log().to_vec() {
            p.apply(&op);
        }
        let tenants: Vec<&str> = p.slices().iter().map(|s| s.tenant.as_str()).collect();
        assert_eq!(tenants, vec!["bob", "eve"]);
    }

    #[test]
    #[should_panic(expected = "already holds a slice")]
    fn scheduler_rejects_a_double_carve_by_the_same_tenant() {
        let mut sched = SliceScheduler::new(Rc::new(Topology::oct_2009()), 0.0);
        let _first = sched.try_carve("alice", 2, None, None).expect("fits");
        // A second live slice for the same tenant would make the by-name
        // ReleaseSlice intent ambiguous under replay.
        let _ = sched.try_carve("alice", 2, None, None);
    }

    #[test]
    fn scheduler_respects_exclusions() {
        let mut t = Topology::new();
        t.add_site("s");
        t.add_rack(SiteId(0), 4, &NodeSpec::default(), 1.25e9);
        let mut sched = SliceScheduler::new(Rc::new(t), 0.0);
        sched.exclude(&[NodeId(0), NodeId(1)]);
        assert_eq!(sched.free_nodes(), 2);
        let s = sched.try_carve("t", 2, None, None).expect("two nodes remain");
        assert_eq!(s.nodes, vec![NodeId(2), NodeId(3)]);
        assert!(sched.try_carve("u", 1, None, None).is_none());
    }
}

//! Node and network provisioning (paper §1, §2.1: networks as "first
//! class controllable, adjustable resources", and §2.2's growth plan).
//!
//! The provisioner owns a mutable [`Topology`] between experiment runs:
//! grow sites/racks (the 2009 expansion toward 250 nodes/1000 cores),
//! retune WAN links (dynamic lightpath provisioning [13]), drain nodes,
//! and stamp out per-experiment subsets. During a run, dynamic changes go
//! through `FlowNet::set_capacity` / `CpuPool::set_speed` — the
//! provisioner records the *intent* so a testbed config can be replayed.

use crate::net::topology::NodeSpec;
use crate::net::{Cluster, NodeId, SiteId, Topology};

use super::config::Config;

/// A provisioning log entry (replayable intent).
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    AddSite { name: String },
    AddRack { site: usize, nodes: usize },
    ConnectSites { a: usize, b: usize, gbps: f64, rtt_ms: f64 },
    SetWanCapacity { a: usize, b: usize, gbps: f64 },
    DrainNode { node: usize },
    /// Return a drained node to service (the inverse of `DrainNode`):
    /// repaired hardware re-enters the pool.
    UndrainNode { node: usize },
}

/// Builds and evolves testbed topologies.
pub struct Provisioner {
    topo: Topology,
    spec: NodeSpec,
    log: Vec<Op>,
    drained: Vec<NodeId>,
}

impl Default for Provisioner {
    fn default() -> Self {
        Self::new()
    }
}

impl Provisioner {
    pub fn new() -> Self {
        Provisioner {
            topo: Topology::new(),
            spec: NodeSpec::default(),
            log: Vec::new(),
            drained: Vec::new(),
        }
    }

    /// Start from the paper's Figure-2 testbed.
    pub fn oct_2009() -> Self {
        Provisioner {
            topo: Topology::oct_2009(),
            spec: NodeSpec::default(),
            log: Vec::new(),
            drained: Vec::new(),
        }
    }

    /// Build from a `[testbed]` config section (sites, nodes_per_rack,
    /// wan_gbps, rtt_ms defaults).
    pub fn from_config(cfg: &Config) -> Self {
        let sites = cfg.get_i64("testbed.sites", 4).max(1) as usize;
        let nodes = cfg.get_i64("testbed.nodes_per_rack", 32).max(1) as usize;
        let wan_gbps = cfg.get_f64("testbed.wan_gbps", 10.0);
        let rtt_ms = cfg.get_f64("testbed.rtt_ms", 40.0);
        let mut p = Provisioner::new();
        for i in 0..sites {
            p.add_site(&format!("site{i}"));
            p.add_rack(i, nodes);
        }
        for a in 0..sites {
            for b in a + 1..sites {
                p.connect_sites(a, b, wan_gbps, rtt_ms);
            }
        }
        p
    }

    pub fn add_site(&mut self, name: &str) -> SiteId {
        self.log.push(Op::AddSite { name: name.to_string() });
        self.topo.add_site(name)
    }

    pub fn add_rack(&mut self, site: usize, nodes: usize) {
        self.log.push(Op::AddRack { site, nodes });
        self.topo.add_rack(SiteId(site), nodes, &self.spec, 1.25e9);
    }

    pub fn connect_sites(&mut self, a: usize, b: usize, gbps: f64, rtt_ms: f64) {
        self.log.push(Op::ConnectSites { a, b, gbps, rtt_ms });
        self.topo.connect_sites(SiteId(a), SiteId(b), gbps * 1e9 / 8.0, rtt_ms / 1e3);
    }

    /// Retune an existing WAN link pair (dynamic lightpath provisioning
    /// [13]): both directions get the new capacity.
    pub fn set_wan_capacity(&mut self, a: usize, b: usize, gbps: f64) {
        self.log.push(Op::SetWanCapacity { a, b, gbps });
        for (x, y) in [(a, b), (b, a)] {
            let lid = self
                .topo
                .wan_link(SiteId(x), SiteId(y))
                .unwrap_or_else(|| panic!("no WAN link {x}->{y} to retune"));
            self.topo.set_link_capacity(lid, gbps * 1e9 / 8.0);
        }
    }

    /// Apply one logged operation (the replay primitive). Every public
    /// mutator routes through the same methods, so applying an op both
    /// re-logs and re-executes it.
    pub fn apply(&mut self, op: &Op) {
        match op {
            Op::AddSite { name } => {
                self.add_site(name);
            }
            Op::AddRack { site, nodes } => self.add_rack(*site, *nodes),
            Op::ConnectSites { a, b, gbps, rtt_ms } => self.connect_sites(*a, *b, *gbps, *rtt_ms),
            Op::SetWanCapacity { a, b, gbps } => self.set_wan_capacity(*a, *b, *gbps),
            Op::DrainNode { node } => self.drain_node(*node),
            Op::UndrainNode { node } => self.undrain_node(*node),
        }
    }

    /// Rebuild a provisioner from a recorded op log — the "replayable
    /// intent" promise: replaying a log captured from an empty start
    /// reproduces the topology exactly. Logs recorded over a seeded base
    /// (e.g. [`Provisioner::oct_2009`]) must be applied onto the same
    /// base with [`Provisioner::apply`].
    pub fn replay(ops: &[Op]) -> Provisioner {
        let mut p = Provisioner::new();
        for op in ops {
            p.apply(op);
        }
        p
    }

    /// Mark a node out of service (engines must skip drained nodes).
    pub fn drain_node(&mut self, node: usize) {
        self.log.push(Op::DrainNode { node });
        if !self.drained.contains(&NodeId(node)) {
            self.drained.push(NodeId(node));
        }
    }

    /// Return a node to service — the inverse of
    /// [`Provisioner::drain_node`]. Idempotent (undraining a node that
    /// was never drained only records the intent).
    pub fn undrain_node(&mut self, node: usize) {
        self.log.push(Op::UndrainNode { node });
        self.drained.retain(|&n| n != NodeId(node));
    }

    pub fn drained(&self) -> &[NodeId] {
        &self.drained
    }

    pub fn log(&self) -> &[Op] {
        &self.log
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Finalize into a cluster (consumes the builder's current topology).
    pub fn build(self) -> Cluster {
        Cluster::new(self.topo)
    }

    /// §2.2 expansion: add MIT-LL and PSC racks to the 2009 testbed and
    /// interconnect them at 10 Gb/s.
    pub fn expand_2009_plan(&mut self) {
        let base_sites = self.topo.sites.len();
        let mit = self.add_site("MIT-LL");
        self.add_rack(mit.0, 30);
        let psc = self.add_site("PSC-CMU");
        self.add_rack(psc.0, 30);
        for s in 0..base_sites {
            self.connect_sites(s, mit.0, 10.0, 30.0);
            self.connect_sites(s, psc.0, 10.0, 25.0);
        }
        self.connect_sites(mit.0, psc.0, 10.0, 18.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_config_builds_requested_shape() {
        let cfg =
            Config::parse("[testbed]\nsites = 2\nnodes_per_rack = 4\nwan_gbps = 1.0\n").unwrap();
        let p = Provisioner::from_config(&cfg);
        assert_eq!(p.topology().sites.len(), 2);
        assert_eq!(p.topology().num_nodes(), 8);
        let lid = p.topology().wan_link(SiteId(0), SiteId(1)).unwrap();
        assert!((p.topology().link(lid).capacity - 1.25e8).abs() < 1.0);
    }

    #[test]
    fn expansion_plan_reaches_growth_target() {
        let mut p = Provisioner::oct_2009();
        p.expand_2009_plan();
        // 128 + 60 — "by then the OCT will have about 250 nodes"
        // (two more 32-node racks were also planned; we model the two
        // named sites).
        assert_eq!(p.topology().num_nodes(), 188);
        assert_eq!(p.topology().sites.len(), 6);
        // Fully connected: every site pair has a WAN link.
        for a in 0..6 {
            for b in 0..6 {
                if a != b {
                    assert!(p.topology().wan_link(SiteId(a), SiteId(b)).is_some(), "{a}->{b}");
                }
            }
        }
    }

    #[test]
    fn log_records_intent() {
        let mut p = Provisioner::new();
        p.add_site("x");
        p.add_rack(0, 2);
        p.drain_node(1);
        assert_eq!(
            p.log(),
            &[
                Op::AddSite { name: "x".into() },
                Op::AddRack { site: 0, nodes: 2 },
                Op::DrainNode { node: 1 }
            ]
        );
        assert_eq!(p.drained(), &[NodeId(1)]);
    }

    #[test]
    fn build_yields_cluster() {
        let c = Provisioner::oct_2009().build();
        assert_eq!(c.topo.num_nodes(), 128);
    }

    #[test]
    fn replaying_the_op_log_reproduces_the_topology() {
        // Build a non-trivial testbed through every op kind.
        let mut p = Provisioner::new();
        p.add_site("east");
        p.add_site("west");
        p.add_site("south");
        p.add_rack(0, 6);
        p.add_rack(1, 4);
        p.add_rack(2, 5);
        p.connect_sites(0, 1, 10.0, 40.0);
        p.connect_sites(0, 2, 10.0, 25.0);
        p.connect_sites(1, 2, 1.0, 60.0);
        p.set_wan_capacity(0, 1, 2.5); // lightpath retune after the fact
        p.drain_node(3);

        let r = Provisioner::replay(p.log());
        // Identical shape: site/node/link counts.
        assert_eq!(r.topology().sites.len(), p.topology().sites.len());
        assert_eq!(r.topology().num_nodes(), p.topology().num_nodes());
        assert_eq!(r.topology().links.len(), p.topology().links.len());
        // Identical WAN capacities in both directions of every pair,
        // including the retuned one.
        for a in 0..3 {
            for b in 0..3 {
                if a == b {
                    continue;
                }
                let (la, lb) = (
                    p.topology().wan_link(SiteId(a), SiteId(b)).unwrap(),
                    r.topology().wan_link(SiteId(a), SiteId(b)).unwrap(),
                );
                assert_eq!(la, lb, "link ids diverge for {a}->{b}");
                assert_eq!(
                    p.topology().link(la).capacity,
                    r.topology().link(lb).capacity,
                    "capacity diverges for {a}->{b}"
                );
            }
        }
        let retuned = r.topology().wan_link(SiteId(0), SiteId(1)).unwrap();
        assert!((r.topology().link(retuned).capacity - 2.5e9 / 8.0).abs() < 1.0);
        // Drains and the log itself replay too.
        assert_eq!(r.drained(), p.drained());
        assert_eq!(r.log(), p.log());
    }

    #[test]
    fn drain_undrain_round_trip_replays() {
        let mut p = Provisioner::new();
        p.add_site("x");
        p.add_rack(0, 4);
        p.drain_node(1);
        p.drain_node(2);
        p.undrain_node(1);
        assert_eq!(p.drained(), &[NodeId(2)]);
        // The round trip is fully recorded and replays to the same state.
        let r = Provisioner::replay(p.log());
        assert_eq!(r.drained(), p.drained());
        assert_eq!(r.log(), p.log());
        assert!(r.log().contains(&Op::UndrainNode { node: 1 }));
        // Undrain of a never-drained node: intent logged, state unchanged.
        let mut q = Provisioner::new();
        q.add_site("y");
        q.add_rack(0, 2);
        q.undrain_node(0);
        assert!(q.drained().is_empty());
        let rq = Provisioner::replay(q.log());
        assert!(rq.drained().is_empty());
        assert_eq!(rq.log(), q.log());
        // Drain → undrain → drain ends drained, under replay too.
        let mut z = Provisioner::new();
        z.add_site("z");
        z.add_rack(0, 2);
        z.drain_node(0);
        z.undrain_node(0);
        z.drain_node(0);
        assert_eq!(z.drained(), &[NodeId(0)]);
        assert_eq!(Provisioner::replay(z.log()).drained(), z.drained());
    }

    #[test]
    fn apply_replays_onto_a_seeded_base() {
        let mut recorded = Provisioner::oct_2009();
        recorded.expand_2009_plan();
        let mut replayed = Provisioner::oct_2009();
        for op in recorded.log().to_vec() {
            replayed.apply(&op);
        }
        assert_eq!(replayed.topology().num_nodes(), recorded.topology().num_nodes());
        assert_eq!(replayed.topology().sites.len(), recorded.topology().sites.len());
        assert_eq!(replayed.log(), recorded.log());
    }
}

//! The shared framework runtime: the distributed-dataflow skeleton that
//! `hadoop::mapreduce` and `sector::sphere` are thin instantiations of.
//!
//! The paper's stated purpose is to "benchmark … and investigate
//! interoperability" across Hadoop, Sector/Sphere, CloudStore (KFS) and
//! Thrift (§1, §7). Both of our engines used to carry a private copy of
//! the same machinery — per-node task slots, locality-tiered scheduling
//! with segment stealing, replica-aware input reads, a partition exchange
//! over a [`crate::transport::Protocol`], a phase barrier, and a
//! replicated output write. This module owns that machinery once:
//!
//! - [`storage::StorageModel`] — how a framework's storage layer resolves
//!   input replicas and places output replicas: HDFS (rack-aware 3-way
//!   synchronous pipeline), Sector (writer-local, lazy background
//!   replication), and CloudStore/KFS (chunk-lease grant from a
//!   metaserver, rack-oblivious chunkserver placement).
//! - [`schedule::SlotScheduler`] — per-node slots with locality-first
//!   list scheduling and a pluggable [`schedule::StealPolicy`] (the
//!   paper's "bandwidth load balancing").
//! - [`exchange::ExchangeModel`] — how intermediate data moves: Hadoop's
//!   barrier-then-pull all-to-all shuffle with bounded parallel copies,
//!   or Sphere's streamed bucket push overlapped with the scan.
//! - [`runtime::DataflowEngine`] — the two-phase engine that composes the
//!   three layers on the discrete-event substrate and reports per-layer
//!   byte/steal accounting ([`runtime::DataflowReport`]).
//!
//! Because the layers are orthogonal, the §7 interoperability studies are
//! just new compositions: `Framework::CloudStoreMr` (MapReduce scheduling
//! + TCP shuffle over KFS chunk storage) and `Framework::HadoopOverSector`
//! (MapReduce scheduling over Sector placement with a UDT exchange) — see
//! the `interop` scenario set in [`crate::coordinator::registry`].

pub mod exchange;
pub mod runtime;
pub mod schedule;
pub mod storage;

pub use exchange::ExchangeModel;
pub use runtime::{DataflowControl, DataflowEngine, DataflowReport, DataflowSpec, TaskInput};
pub use schedule::{SlotScheduler, StealPolicy};
pub use storage::{pipeline_write, HdfsStorage, KfsStorage, SectorStorage, StorageModel};

//! The shared two-phase dataflow engine.
//!
//! Phase 1 ("map"/"scan"): the [`super::SlotScheduler`] assigns input
//! tasks to worker slots locality-first; each task streams its input from
//! the [`super::StorageModel`]'s chosen source, burns per-record CPU, and
//! emits intermediate data through the [`super::ExchangeModel`] — a local
//! spill (shuffle pull) or an overlapped bucket push. Phase 2: after the
//! barrier, reducers pull + merge + reduce (shuffle pull) or every node
//! folds its buckets (bucket push), and any job output is written back
//! through the storage model's replication pipeline.
//!
//! `hadoop::mapreduce::MapReduceEngine` and `sector::sphere::SphereEngine`
//! are thin instantiations of this runtime; their timing semantics are
//! preserved event-for-event (the table1/table2 shape checks and the
//! MalStone oracle-equality tests are the guard). The §7 interop
//! compositions (`CloudStoreMr`, `HadoopOverSector`) are new
//! storage × schedule × exchange combinations of the same machinery.
//!
//! The dataflow's barrier and shuffle couple every node to every other
//! through shared scheduler state (not messages with a latency floor),
//! so these frameworks run on the sequential engine; only workloads
//! whose cross-domain traffic is channel-shaped (mega-churn) take the
//! sharded path — see [`crate::sim::par`] and
//! [`crate::coordinator::ScenarioRunner`].

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};
use std::rc::Rc;

use crate::net::{Cluster, NodeId};
use crate::sim::resources::CpuPool;
use crate::sim::Engine;
use crate::trace::Arg;
use crate::transport::{self, Protocol};

use super::exchange::ExchangeModel;
use super::schedule::{SlotScheduler, StealPolicy};
use super::storage::{self, StorageModel};

/// One unit of phase-1 input: location, bytes, records. (Hadoop calls
/// this an `InputBlock`, Sector a `Segment`; structurally identical.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskInput {
    pub node: NodeId,
    pub bytes: u64,
    pub records: u64,
}

/// A fully-resolved dataflow for the runtime: workload shape, per-record
/// costs, and the three layer choices (storage is passed separately).
#[derive(Debug, Clone)]
pub struct DataflowSpec {
    pub name: String,
    /// Worker nodes participating in the job.
    pub nodes: Vec<NodeId>,
    pub tasks: Vec<TaskInput>,
    pub slots_per_node: usize,
    pub task_overhead: f64,
    pub map_cpu_per_record: f64,
    /// CPU per input record in the reduce/aggregate phase (variant
    /// factors pre-applied by the caller).
    pub reduce_cpu_per_record: f64,
    /// Bytes per input record surviving into the exchange.
    pub intermediate_bytes_per_record: f64,
    /// Bytes per input record written back through storage as job output
    /// (0 = aggregation-only dataflow, no output write).
    pub output_bytes_per_record: f64,
    /// Extra disk passes over fetched data before reducing (shuffle pull).
    pub merge_passes: f64,
    /// Reduce task count. Bucket-push dataflows aggregate one bucket per
    /// node, so this must equal `nodes.len()` there.
    pub num_reducers: usize,
    pub protocol: Protocol,
    pub exchange: ExchangeModel,
    pub steal: StealPolicy,
}

/// Timing + per-layer accounting for one dataflow run.
#[derive(Debug, Clone)]
pub struct DataflowReport {
    pub name: String,
    pub makespan: f64,
    /// Map/scan phase duration (start → barrier).
    pub phase1: f64,
    /// Shuffle+reduce / aggregate phase duration (barrier → done).
    pub phase2: f64,
    pub tasks: usize,
    pub reducers: usize,
    /// Phase-1 tasks run away from their home node (steals / remote maps).
    pub remote_tasks: usize,
    /// All bytes that moved through the exchange, including node-local
    /// partitions (Hadoop's `shuffle_bytes` accounting).
    pub exchange_bytes: f64,
    /// The subset of exchange bytes that crossed the network (Sphere's
    /// `exchange_bytes` accounting).
    pub exchange_remote_bytes: f64,
    /// Input bytes read through the storage layer.
    pub storage_read_bytes: f64,
    /// Output bytes written through the storage layer, replicas included.
    pub storage_write_bytes: f64,
    /// Logical job output bytes (single copy).
    pub output_bytes: f64,
    /// Phase-1 tasks re-queued onto survivors after a node failure
    /// ([`DataflowControl::heal_node`]): lost in-flight work plus, under a
    /// shuffle-pull exchange, completed tasks whose spill died with the
    /// node.
    pub reexecuted: usize,
    /// Where the output landed (primary replicas): feeds chained jobs.
    pub output: Vec<TaskInput>,
}

struct RtState {
    cluster: Cluster,
    storage: Rc<RefCell<dyn StorageModel>>,
    spec: DataflowSpec,
    sched: SlotScheduler,
    /// Per-node intermediate bytes/records: producer totals under shuffle
    /// pull, destination bucket totals under bucket push.
    inter_bytes: BTreeMap<NodeId, f64>,
    inter_records: BTreeMap<NodeId, f64>,
    tasks_done: usize,
    tasks_total: usize,
    phase1_end: f64,
    reducers_done: usize,
    start: f64,
    output: Vec<TaskInput>,
    exchange_bytes: f64,
    exchange_remote_bytes: f64,
    storage_read_bytes: f64,
    storage_write_bytes: f64,
    output_bytes: f64,
    /// Nodes marked crashed ([`DataflowControl::crash_node`]): their
    /// phase-1 completions are ignored until healed.
    crashed: BTreeSet<NodeId>,
    /// Monotone id per phase-1 assignment; a completion whose id is gone
    /// from `live` is stale (the assignment was re-queued elsewhere).
    next_assign: u64,
    /// In-flight phase-1 assignments: id → (worker, task).
    live: BTreeMap<u64, (NodeId, TaskInput)>,
    /// Completed phase-1 tasks by worker, remembered so a later failure
    /// of that worker can re-execute them (shuffle pull: the spill lived
    /// on its disk).
    completed_p1: BTreeMap<NodeId, Vec<TaskInput>>,
    reexecuted: usize,
    done_cb: Option<Box<dyn FnOnce(&mut Engine, DataflowReport)>>,
    /// Trace span ids (0 = this run is untraced): the `dataflow` span
    /// and the currently-open phase span. Task span ids are
    /// `trace_df << 32 | assignment id`.
    trace_df: u64,
    trace_phase: u64,
}

/// Handle onto a running dataflow — the operations plane's failure and
/// recovery entry points. Cloneable (an `Rc` inside); outlives the run
/// harmlessly (post-completion calls are no-ops).
#[derive(Clone)]
pub struct DataflowControl {
    st: Rc<RefCell<RtState>>,
}

impl DataflowControl {
    /// Mark a worker crashed *right now*: its in-flight phase-1 work stops
    /// making progress (completions are silently dropped), its sensor has
    /// presumably gone dark, and nothing recovers until
    /// [`DataflowControl::heal_node`] re-queues the lost work. Phase-2
    /// (reduce/aggregate) events are not interrupted — a crash after the
    /// barrier models "outputs already safely off the node".
    pub fn crash_node(&self, node: NodeId) {
        self.st.borrow_mut().crashed.insert(node);
    }

    /// The recovery half (what a JobTracker does when it finally declares
    /// a TaskTracker lost): remove `node` from the worker set and re-queue
    /// its lost phase-1 work onto the survivors — in-flight assignments,
    /// plus (under a shuffle-pull exchange) completed tasks whose map
    /// spill lived on the node, exactly as Hadoop re-executes completed
    /// maps of a lost tracker. Returns the number of re-queued tasks.
    /// A no-op once phase 1 is complete or if `node` is not a worker.
    pub fn heal_node(&self, eng: &mut Engine, node: NodeId) -> usize {
        let mut requeued = 0;
        {
            let mut s = self.st.borrow_mut();
            s.crashed.insert(node);
            if s.tasks_done == s.tasks_total || !s.spec.nodes.contains(&node) {
                return 0;
            }
            s.spec.nodes.retain(|&n| n != node);
            assert!(!s.spec.nodes.is_empty(), "every worker failed");
            if s.spec.exchange == ExchangeModel::BucketPush {
                // One bucket per surviving node; the dead node's bucket
                // (dropped below) died with its disk.
                s.spec.num_reducers = s.spec.nodes.len();
            }
            s.sched.remove_node(node);
            let lost: Vec<u64> = s
                .live
                .iter()
                .filter(|(_, (n, _))| *n == node)
                .map(|(&id, _)| id)
                .collect();
            for id in lost {
                let (_, t) = s.live.remove(&id).unwrap();
                s.sched.requeue(t, true);
                requeued += 1;
            }
            if matches!(s.spec.exchange, ExchangeModel::ShufflePull { .. }) {
                if let Some(done) = s.completed_p1.remove(&node) {
                    for t in done {
                        s.tasks_done -= 1;
                        s.sched.requeue(t, false);
                        requeued += 1;
                    }
                }
            }
            // Every entry under the node's key — spills it produced
            // (shuffle pull) or the bucket it hosted (bucket push) — is
            // gone with its disk.
            s.inter_bytes.remove(&node);
            s.inter_records.remove(&node);
            s.reexecuted += requeued;
        }
        if requeued > 0 {
            DataflowEngine::fill_slots(&self.st, eng);
        }
        requeued
    }
}

/// The shared dataflow timing engine.
pub struct DataflowEngine;

impl DataflowEngine {
    /// Run a dataflow on the event engine; `done` receives the report.
    /// The returned [`DataflowControl`] lets an operations plane inject
    /// node failures and trigger recovery mid-run; callers without one
    /// simply drop it.
    pub fn run<F: FnOnce(&mut Engine, DataflowReport) + 'static>(
        cluster: &Cluster,
        storage: Rc<RefCell<dyn StorageModel>>,
        eng: &mut Engine,
        spec: DataflowSpec,
        done: F,
    ) -> DataflowControl {
        assert!(!spec.nodes.is_empty() && !spec.tasks.is_empty());
        assert!(spec.num_reducers > 0);
        if spec.exchange == ExchangeModel::BucketPush {
            assert_eq!(
                spec.num_reducers,
                spec.nodes.len(),
                "bucket push aggregates one bucket per node"
            );
        }
        let tasks_total = spec.tasks.len();
        // Dataflow + phase-1 spans live on the control domain (the job
        // spans every site); ids come from the recorder's counter.
        let mut trace_df = 0;
        let mut trace_phase = 0;
        {
            let t = eng.now();
            if let Some(rec) = eng.recorder() {
                let dom = cluster.topo.num_domains() as u16;
                trace_df = rec.fresh_id();
                trace_phase = rec.fresh_id();
                let name = [("name", Arg::S(spec.name.clone()))];
                rec.begin(t, dom, 0, "dataflow", trace_df, &name);
                let tasks = [("tasks", Arg::U(tasks_total as u64))];
                rec.begin(t, dom, 0, "phase.map", trace_phase, &tasks);
            }
        }
        let sched = SlotScheduler::new(
            spec.nodes.clone(),
            spec.slots_per_node,
            spec.tasks.clone(),
            spec.steal,
        );
        let st = Rc::new(RefCell::new(RtState {
            cluster: cluster.clone(),
            storage,
            sched,
            inter_bytes: BTreeMap::new(),
            inter_records: BTreeMap::new(),
            tasks_done: 0,
            tasks_total,
            phase1_end: 0.0,
            reducers_done: 0,
            start: eng.now(),
            output: Vec::new(),
            exchange_bytes: 0.0,
            exchange_remote_bytes: 0.0,
            storage_read_bytes: 0.0,
            storage_write_bytes: 0.0,
            output_bytes: 0.0,
            crashed: BTreeSet::new(),
            next_assign: 0,
            live: BTreeMap::new(),
            completed_p1: BTreeMap::new(),
            reexecuted: 0,
            done_cb: Some(Box::new(done)),
            trace_df,
            trace_phase,
            spec,
        }));
        Self::fill_slots(&st, eng);
        DataflowControl { st }
    }

    /// True when this assignment must stop progressing: its worker crashed
    /// or the assignment was re-queued elsewhere by a heal.
    fn doomed(st: &Rc<RefCell<RtState>>, aid: u64, node: NodeId) -> bool {
        let s = st.borrow();
        !s.live.contains_key(&aid) || s.crashed.contains(&node)
    }

    /// Drain the scheduler: assign tasks until no worker slot may take one.
    fn fill_slots(st: &Rc<RefCell<RtState>>, eng: &mut Engine) {
        loop {
            let (task, stole) = {
                let mut s = st.borrow_mut();
                let topo = s.cluster.topo.clone();
                let before = s.sched.stolen();
                let task = s.sched.next_assignment(&topo);
                (task, s.sched.stolen() > before)
            };
            match task {
                Some((node, t)) => {
                    if stole {
                        let df = st.borrow().trace_df;
                        if df != 0 {
                            let tnow = eng.now();
                            let dom = st.borrow().cluster.topo.node(node).site.0 as u16;
                            if let Some(rec) = eng.recorder() {
                                let home = [("home", Arg::U(t.node.0 as u64))];
                                rec.instant(tnow, dom, node.0 as u32, "steal", 0, &home);
                            }
                        }
                    }
                    Self::run_task(st, eng, node, t)
                }
                None => break,
            }
        }
    }

    /// One phase-1 task: (possibly remote) storage read → CPU → exchange
    /// output stage → slot release. Each boundary re-checks that the
    /// assignment is still live — a crashed worker's pipeline stops
    /// producing effects at its next step.
    fn run_task(st: &Rc<RefCell<RtState>>, eng: &mut Engine, node: NodeId, task: TaskInput) {
        let (cluster, proto, overhead, source, aid) = {
            let mut s = st.borrow_mut();
            s.storage_read_bytes += task.bytes as f64;
            let mut source = s.storage.borrow().read_source(task.node, node);
            // A crashed replica host cannot serve reads; a re-executed
            // task streams from a surviving replica instead, modeled as
            // worker-local (the data is not resurrected from the dead box).
            if s.crashed.contains(&source) {
                source = node;
            }
            let aid = s.next_assign;
            s.next_assign += 1;
            s.live.insert(aid, (node, task));
            (s.cluster.clone(), s.spec.protocol.clone(), s.spec.task_overhead, source, aid)
        };
        let df = st.borrow().trace_df;
        if df != 0 {
            let t = eng.now();
            let dom = cluster.topo.node(node).site.0 as u16;
            if let Some(rec) = eng.recorder() {
                let args = [("bytes", Arg::U(task.bytes)), ("records", Arg::U(task.records))];
                rec.begin(t, dom, node.0 as u32, "task", df << 32 | aid, &args);
            }
        }
        let st2 = st.clone();
        let net = cluster.net.clone();
        let topo = cluster.topo.clone();
        eng.schedule_in(overhead, move |eng| {
            if Self::doomed(&st2, aid, node) {
                return;
            }
            let st3 = st2.clone();
            let after_read = move |eng: &mut Engine| {
                if Self::doomed(&st3, aid, node) {
                    return;
                }
                let (pool, cpu) = {
                    let s = st3.borrow();
                    (s.cluster.pool(node).clone(), task.records as f64 * s.spec.map_cpu_per_record)
                };
                let st4 = st3.clone();
                CpuPool::submit(&pool, eng, cpu, move |eng| {
                    Self::task_output(&st4, eng, node, task, aid);
                });
            };
            if source == node {
                transport::disk_read(&net, &topo, eng, node, task.bytes as f64, after_read);
            } else {
                // Remote input (non-local map / stolen segment): stream it
                // from its source over the dataflow's protocol.
                let net2 = net.clone();
                let topo2 = topo.clone();
                transport::disk_read(&net, &topo, eng, source, task.bytes as f64, move |eng| {
                    transport::send(
                        &net2,
                        &topo2,
                        eng,
                        source,
                        node,
                        task.bytes as f64,
                        &proto,
                        after_read,
                    );
                });
            }
        });
    }

    /// Route a finished task's intermediate output through the exchange.
    fn task_output(
        st: &Rc<RefCell<RtState>>,
        eng: &mut Engine,
        node: NodeId,
        task: TaskInput,
        aid: u64,
    ) {
        if Self::doomed(st, aid, node) {
            return;
        }
        let exchange = st.borrow().spec.exchange;
        match exchange {
            ExchangeModel::ShufflePull { .. } => {
                // Local spill of the task's intermediate output; fetched
                // by reducers after the barrier.
                let (cluster, spill) = {
                    let s = st.borrow();
                    (
                        s.cluster.clone(),
                        task.records as f64 * s.spec.intermediate_bytes_per_record,
                    )
                };
                let st2 = st.clone();
                transport::disk_write(&cluster.net, &cluster.topo, eng, node, spill, move |eng| {
                    Self::task_finished(&st2, eng, node, task, spill, aid);
                });
            }
            ExchangeModel::BucketPush => Self::bucket_push(st, eng, node, task, aid),
        }
    }

    /// Push the task's partitioned output into bucket files on every node,
    /// overlapped (the task completes when its slowest push lands).
    fn bucket_push(
        st: &Rc<RefCell<RtState>>,
        eng: &mut Engine,
        node: NodeId,
        task: TaskInput,
        aid: u64,
    ) {
        let (cluster, proto, out_bytes, nodes) = {
            let s = st.borrow();
            let out = task.records as f64 * s.spec.intermediate_bytes_per_record;
            (s.cluster.clone(), s.spec.protocol.clone(), out, s.spec.nodes.clone())
        };
        let n = nodes.len() as f64;
        let share_bytes = out_bytes / n;
        let share_records = task.records as f64 / n;
        let legs = Rc::new(RefCell::new(nodes.len()));
        let st2 = st.clone();
        let arrive =
            move |st: &Rc<RefCell<RtState>>, eng: &mut Engine, legs: &Rc<RefCell<usize>>| {
                let mut l = legs.borrow_mut();
                *l -= 1;
                if *l == 0 {
                    Self::push_task_finished(st, eng, node, aid);
                }
            };
        for &dst in &nodes {
            {
                let mut s = st.borrow_mut();
                *s.inter_bytes.entry(dst).or_insert(0.0) += share_bytes;
                *s.inter_records.entry(dst).or_insert(0.0) += share_records;
                s.exchange_bytes += share_bytes;
                if dst != node {
                    s.exchange_remote_bytes += share_bytes;
                }
            }
            let st3 = st2.clone();
            let legs2 = legs.clone();
            let done = move |eng: &mut Engine| arrive(&st3, eng, &legs2);
            if dst == node {
                transport::disk_write(&cluster.net, &cluster.topo, eng, node, share_bytes, done);
            } else {
                let net = cluster.net.clone();
                let topo = cluster.topo.clone();
                transport::send(
                    &cluster.net,
                    &cluster.topo,
                    eng,
                    node,
                    dst,
                    share_bytes,
                    &proto,
                    move |eng| {
                        transport::disk_write(&net, &topo, eng, dst, share_bytes, done);
                    },
                );
            }
        }
    }

    /// Close a task span (no-op for untraced runs or doomed assignments).
    fn trace_task_end(st: &Rc<RefCell<RtState>>, eng: &mut Engine, node: NodeId, aid: u64) {
        let df = st.borrow().trace_df;
        if df == 0 {
            return;
        }
        let t = eng.now();
        let dom = st.borrow().cluster.topo.node(node).site.0 as u16;
        if let Some(rec) = eng.recorder() {
            rec.end(t, dom, node.0 as u32, "task", df << 32 | aid, &[]);
        }
    }

    /// Close `phase.map` and open `phase.reduce`, both at the barrier.
    fn trace_barrier(st: &Rc<RefCell<RtState>>, eng: &mut Engine) {
        let (df, phase, dom, reducers) = {
            let s = st.borrow();
            let dom = s.cluster.topo.num_domains() as u16;
            (s.trace_df, s.trace_phase, dom, s.spec.num_reducers)
        };
        if df == 0 {
            return;
        }
        let t = eng.now();
        if let Some(rec) = eng.recorder() {
            rec.end(t, dom, 0, "phase.map", phase, &[]);
            let pid = rec.fresh_id();
            let args = [("reducers", Arg::U(reducers as u64))];
            rec.begin(t, dom, 0, "phase.reduce", pid, &args);
            st.borrow_mut().trace_phase = pid;
        }
    }

    /// Shuffle-pull task completion: account the spill under its producer.
    fn task_finished(
        st: &Rc<RefCell<RtState>>,
        eng: &mut Engine,
        node: NodeId,
        task: TaskInput,
        out_bytes: f64,
        aid: u64,
    ) {
        if Self::doomed(st, aid, node) {
            return;
        }
        Self::trace_task_end(st, eng, node, aid);
        let all_done = {
            let mut s = st.borrow_mut();
            s.live.remove(&aid);
            s.completed_p1.entry(node).or_default().push(task);
            *s.inter_bytes.entry(node).or_insert(0.0) += out_bytes;
            *s.inter_records.entry(node).or_insert(0.0) += task.records as f64;
            s.tasks_done += 1;
            s.sched.release(node);
            if s.tasks_done == s.tasks_total {
                s.phase1_end = eng.now();
                true
            } else {
                false
            }
        };
        Self::fill_slots(st, eng);
        if all_done {
            Self::trace_barrier(st, eng);
            Self::start_shuffle(st, eng);
        }
    }

    /// Bucket-push task completion (all pushes landed).
    fn push_task_finished(st: &Rc<RefCell<RtState>>, eng: &mut Engine, node: NodeId, aid: u64) {
        if Self::doomed(st, aid, node) {
            return;
        }
        Self::trace_task_end(st, eng, node, aid);
        let all_done = {
            let mut s = st.borrow_mut();
            s.live.remove(&aid);
            s.tasks_done += 1;
            s.sched.release(node);
            if s.tasks_done == s.tasks_total {
                s.phase1_end = eng.now();
                true
            } else {
                false
            }
        };
        Self::fill_slots(st, eng);
        if all_done {
            Self::trace_barrier(st, eng);
            Self::start_aggregate(st, eng);
        }
    }

    /// Shuffle + reduce. Reducers are placed round-robin over the job's
    /// nodes; each pulls its partition of every producer node's output
    /// with at most `parallel_copies` concurrent streams.
    fn start_shuffle(st: &Rc<RefCell<RtState>>, eng: &mut Engine) {
        let (reducers, fetch_lists, k) = {
            let s = st.borrow();
            let r = s.spec.num_reducers;
            let reducers: Vec<NodeId> =
                (0..r).map(|i| s.spec.nodes[i % s.spec.nodes.len()]).collect();
            // Each reducer fetches bytes/r from every producer node.
            let mut lists: Vec<Vec<(NodeId, f64)>> = vec![Vec::new(); r];
            for (&m, &bytes) in &s.inter_bytes {
                for list in lists.iter_mut() {
                    list.push((m, bytes / r as f64));
                }
            }
            let k = match s.spec.exchange {
                ExchangeModel::ShufflePull { parallel_copies } => parallel_copies.max(1),
                ExchangeModel::BucketPush => 1,
            };
            (reducers, lists, k)
        };
        for (rnode, fetches) in reducers.into_iter().zip(fetch_lists) {
            let queue = Rc::new(RefCell::new(fetches));
            let inflight = Rc::new(RefCell::new(0usize));
            let fetched = Rc::new(RefCell::new(0.0f64));
            Self::pump_fetches(st, eng, rnode, queue, inflight, fetched, k);
        }
    }

    fn pump_fetches(
        st: &Rc<RefCell<RtState>>,
        eng: &mut Engine,
        rnode: NodeId,
        queue: Rc<RefCell<Vec<(NodeId, f64)>>>,
        inflight: Rc<RefCell<usize>>,
        fetched: Rc<RefCell<f64>>,
        k: usize,
    ) {
        loop {
            let next = {
                let mut q = queue.borrow_mut();
                if *inflight.borrow() >= k || q.is_empty() {
                    None
                } else {
                    *inflight.borrow_mut() += 1;
                    Some(q.pop().unwrap())
                }
            };
            let Some((mnode, bytes)) = next else { break };
            let (cluster, proto) = {
                let s = st.borrow();
                (s.cluster.clone(), s.spec.protocol.clone())
            };
            let st2 = st.clone();
            let queue2 = queue.clone();
            let inflight2 = inflight.clone();
            let fetched2 = fetched.clone();
            let deliver = move |eng: &mut Engine| {
                *inflight2.borrow_mut() -= 1;
                *fetched2.borrow_mut() += bytes;
                {
                    let mut s = st2.borrow_mut();
                    s.exchange_bytes += bytes;
                    if mnode != rnode {
                        s.exchange_remote_bytes += bytes;
                    }
                }
                let done = queue2.borrow().is_empty() && *inflight2.borrow() == 0;
                if done {
                    Self::merge_and_reduce(&st2, eng, rnode, *fetched2.borrow());
                } else {
                    Self::pump_fetches(&st2, eng, rnode, queue2, inflight2, fetched2, k);
                }
            };
            if mnode == rnode {
                // Local partition: already on disk; charge a disk read.
                transport::disk_read(&cluster.net, &cluster.topo, eng, rnode, bytes, deliver);
            } else {
                let net = cluster.net.clone();
                let topo = cluster.topo.clone();
                transport::disk_read(&cluster.net, &cluster.topo, eng, mnode, bytes, move |eng| {
                    transport::send(&net, &topo, eng, mnode, rnode, bytes, &proto, deliver);
                });
            }
        }
    }

    /// Merge passes on disk, then reduce CPU, then the output write.
    fn merge_and_reduce(st: &Rc<RefCell<RtState>>, eng: &mut Engine, rnode: NodeId, bytes: f64) {
        let (cluster, merge_bytes, cpu, out_bytes, out_records) = {
            let s = st.borrow();
            let total_recs: f64 = s.inter_records.values().sum();
            let recs = total_recs / s.spec.num_reducers as f64;
            let merge = 2.0 * s.spec.merge_passes * bytes; // read+write per pass
            let cpu = recs * s.spec.reduce_cpu_per_record;
            let out_b = recs * s.spec.output_bytes_per_record;
            (s.cluster.clone(), merge, cpu, out_b, recs)
        };
        let st2 = st.clone();
        let finish = move |eng: &mut Engine| {
            Self::write_output(&st2, eng, rnode, out_bytes, out_records);
        };
        let pool = cluster.pool(rnode).clone();
        transport::disk_write(&cluster.net, &cluster.topo, eng, rnode, merge_bytes, move |eng| {
            CpuPool::submit(&pool, eng, cpu, finish);
        });
    }

    /// Stage 2 of a bucket-push dataflow: every node folds its bucket.
    fn start_aggregate(st: &Rc<RefCell<RtState>>, eng: &mut Engine) {
        let nodes = st.borrow().spec.nodes.clone();
        for node in nodes {
            let (cluster, bytes, records, cpu_per_rec, obpr) = {
                let s = st.borrow();
                (
                    s.cluster.clone(),
                    s.inter_bytes.get(&node).copied().unwrap_or(0.0),
                    s.inter_records.get(&node).copied().unwrap_or(0.0),
                    s.spec.reduce_cpu_per_record,
                    s.spec.output_bytes_per_record,
                )
            };
            let st2 = st.clone();
            let pool = cluster.pool(node).clone();
            transport::disk_read(&cluster.net, &cluster.topo, eng, node, bytes, move |eng| {
                let st3 = st2.clone();
                CpuPool::submit(&pool, eng, records * cpu_per_rec, move |eng| {
                    Self::write_output(&st3, eng, node, records * obpr, records);
                });
            });
        }
    }

    /// Write a reducer's output back through the storage layer (skipped
    /// entirely for aggregation-only dataflows), then count it done.
    fn write_output(
        st: &Rc<RefCell<RtState>>,
        eng: &mut Engine,
        writer: NodeId,
        out_bytes: f64,
        out_records: f64,
    ) {
        if out_bytes <= 0.0 {
            Self::reducer_finished(st, eng, writer, out_bytes, out_records);
            return;
        }
        let (cluster, proto, replicas, setup) = {
            let mut s = st.borrow_mut();
            let replicas = s.storage.borrow_mut().place_output(writer);
            let setup = s.storage.borrow().write_setup_latency(writer);
            s.storage_write_bytes += out_bytes * replicas.len() as f64;
            (s.cluster.clone(), s.spec.protocol.clone(), replicas, setup)
        };
        let st2 = st.clone();
        let net = cluster.net.clone();
        let topo = cluster.topo.clone();
        let block_bytes = out_bytes.ceil();
        let write = move |eng: &mut Engine| {
            storage::pipeline_write(&net, &topo, eng, &replicas, block_bytes, &proto, move |eng| {
                Self::reducer_finished(&st2, eng, writer, out_bytes, out_records);
            });
        };
        if setup > 0.0 {
            eng.schedule_in(setup, write);
        } else {
            write(eng);
        }
    }

    fn reducer_finished(
        st: &Rc<RefCell<RtState>>,
        eng: &mut Engine,
        writer: NodeId,
        out_bytes: f64,
        out_records: f64,
    ) {
        let finished = {
            let mut s = st.borrow_mut();
            s.output_bytes += out_bytes;
            if out_bytes > 0.0 {
                s.output.push(TaskInput {
                    node: writer,
                    bytes: out_bytes.ceil() as u64,
                    records: out_records.ceil() as u64,
                });
            }
            s.reducers_done += 1;
            if s.reducers_done == s.spec.num_reducers {
                let report = DataflowReport {
                    name: s.spec.name.clone(),
                    makespan: eng.now() - s.start,
                    phase1: s.phase1_end - s.start,
                    phase2: eng.now() - s.phase1_end,
                    tasks: s.tasks_total,
                    reducers: s.spec.num_reducers,
                    remote_tasks: s.sched.stolen(),
                    exchange_bytes: s.exchange_bytes,
                    exchange_remote_bytes: s.exchange_remote_bytes,
                    storage_read_bytes: s.storage_read_bytes,
                    storage_write_bytes: s.storage_write_bytes,
                    output_bytes: s.output_bytes,
                    reexecuted: s.reexecuted,
                    output: s.output.clone(),
                };
                Some((s.done_cb.take().unwrap(), report))
            } else {
                None
            }
        };
        if let Some((cb, report)) = finished {
            let (df, phase, dom) = {
                let s = st.borrow();
                (s.trace_df, s.trace_phase, s.cluster.topo.num_domains() as u16)
            };
            if df != 0 {
                let t = eng.now();
                if let Some(rec) = eng.recorder() {
                    rec.end(t, dom, 0, "phase.reduce", phase, &[]);
                    rec.end(t, dom, 0, "dataflow", df, &[]);
                }
            }
            cb(eng, report);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::storage::{KfsStorage, SectorStorage};
    use crate::net::Topology;

    fn spec(nodes: Vec<NodeId>, tasks: Vec<TaskInput>, exchange: ExchangeModel) -> DataflowSpec {
        let num_reducers = match exchange {
            ExchangeModel::BucketPush => nodes.len(),
            ExchangeModel::ShufflePull { .. } => 4,
        };
        DataflowSpec {
            name: "rt-test".to_string(),
            nodes,
            tasks,
            slots_per_node: 2,
            task_overhead: 0.5,
            map_cpu_per_record: 2e-6,
            reduce_cpu_per_record: 1e-6,
            intermediate_bytes_per_record: 30.0,
            output_bytes_per_record: 1.0,
            merge_passes: 0.5,
            num_reducers,
            protocol: Protocol::tcp(),
            exchange,
            steal: StealPolicy::Anywhere,
        }
    }

    fn setup(per_site: usize, per_node_records: u64) -> (Cluster, Vec<NodeId>, Vec<TaskInput>) {
        let cluster = Cluster::new(Topology::oct_2009());
        let mut nodes = Vec::new();
        for r in 0..4 {
            for i in 0..per_site {
                nodes.push(cluster.topo.racks[r].nodes[i]);
            }
        }
        let tasks: Vec<TaskInput> = nodes
            .iter()
            .map(|&n| TaskInput {
                node: n,
                bytes: per_node_records * 100,
                records: per_node_records,
            })
            .collect();
        (cluster, nodes, tasks)
    }

    fn run_dataflow(
        cluster: &Cluster,
        storage: Rc<RefCell<dyn StorageModel>>,
        spec: DataflowSpec,
    ) -> DataflowReport {
        let mut eng = Engine::new();
        let out = Rc::new(RefCell::new(None));
        let o = out.clone();
        DataflowEngine::run(cluster, storage, &mut eng, spec, move |_, r| {
            *o.borrow_mut() = Some(r)
        });
        eng.run();
        let r = out.borrow_mut().take().expect("dataflow did not finish");
        r
    }

    #[test]
    fn shuffle_pull_accounts_layers_and_output() {
        let (cluster, nodes, tasks) = setup(2, 200_000);
        let sp = spec(nodes.clone(), tasks, ExchangeModel::ShufflePull { parallel_copies: 4 });
        let storage = Rc::new(RefCell::new(SectorStorage::new()));
        let r = run_dataflow(&cluster, storage, sp);
        assert!(r.makespan > 0.0 && r.phase1 > 0.0 && r.phase2 > 0.0);
        assert_eq!(r.tasks, 8);
        assert_eq!(r.reducers, 4);
        assert_eq!(r.output.len(), 4);
        // Every spilled byte is fetched: total exchange = intermediate.
        let inter = 8.0 * 200_000.0 * 30.0;
        assert!((r.exchange_bytes - inter).abs() / inter < 1e-9, "{}", r.exchange_bytes);
        assert!(r.exchange_remote_bytes > 0.0 && r.exchange_remote_bytes < r.exchange_bytes);
        assert_eq!(r.storage_read_bytes, 8.0 * 200_000.0 * 100.0);
        // Single-replica storage: write bytes equal logical output bytes.
        assert!((r.storage_write_bytes - r.output_bytes).abs() < 1e-6);
    }

    #[test]
    fn bucket_push_overlaps_and_skips_output_when_zero() {
        let (cluster, nodes, tasks) = setup(2, 200_000);
        let mut sp = spec(nodes.clone(), tasks, ExchangeModel::BucketPush);
        sp.output_bytes_per_record = 0.0;
        let storage = Rc::new(RefCell::new(SectorStorage::new()));
        let r = run_dataflow(&cluster, storage, sp);
        assert!(r.makespan > 0.0);
        assert_eq!(r.reducers, nodes.len());
        assert!(r.output.is_empty());
        assert_eq!(r.output_bytes, 0.0);
        assert_eq!(r.storage_write_bytes, 0.0);
        // 8 nodes: 7/8 of each push crosses the network.
        let inter = 8.0 * 200_000.0 * 30.0;
        assert!((r.exchange_bytes - inter).abs() / inter < 1e-9);
        assert!((r.exchange_remote_bytes - inter * 7.0 / 8.0).abs() / inter < 1e-9);
    }

    #[test]
    fn traced_dataflow_emits_phase_and_task_spans() {
        use crate::trace::{Recorder, Stream, TraceSpec};
        let (cluster, nodes, tasks) = setup(2, 50_000);
        let sp = spec(nodes, tasks, ExchangeModel::ShufflePull { parallel_copies: 4 });
        let storage = Rc::new(RefCell::new(SectorStorage::new()));
        let mut eng = Engine::new();
        eng.set_recorder(Recorder::new(&TraceSpec::new()));
        let out = Rc::new(RefCell::new(None));
        let o = out.clone();
        DataflowEngine::run(&cluster, storage, &mut eng, sp, move |_, r| {
            *o.borrow_mut() = Some(r)
        });
        eng.run();
        assert!(out.borrow().is_some(), "dataflow did not finish");
        let mut s = Stream::new(cluster.topo.sites.len());
        s.absorb(eng.take_recorder().unwrap());
        let js = s.to_chrome_json();
        // One begin + one end each for the job and both phases; 8 tasks.
        for (name, events) in
            [("dataflow", 2), ("phase.map", 2), ("phase.reduce", 2), ("task", 16)]
        {
            let hits = js.matches(&format!("\"name\":\"{name}\"")).count();
            assert_eq!(hits, events, "{name}: {hits} events");
        }
    }

    #[test]
    fn replicated_storage_multiplies_write_bytes() {
        let (cluster, nodes, tasks) = setup(2, 100_000);
        let sp = spec(nodes.clone(), tasks, ExchangeModel::ShufflePull { parallel_copies: 4 });
        let kfs = Rc::new(RefCell::new(KfsStorage::new(
            cluster.topo.clone(),
            nodes.clone(),
            3,
            17,
        )));
        let r = run_dataflow(&cluster, kfs, sp);
        assert!((r.storage_write_bytes - 3.0 * r.output_bytes).abs() < 1e-6);
        assert!(r.output_bytes > 0.0);
    }

    #[test]
    fn write_setup_latency_slows_the_run() {
        let (cluster, nodes, tasks) = setup(1, 50_000);
        let sp =
            spec(nodes.clone(), tasks.clone(), ExchangeModel::ShufflePull { parallel_copies: 4 });
        let sector = Rc::new(RefCell::new(SectorStorage::new()));
        let base = run_dataflow(&cluster, sector, sp.clone());
        // KFS with replication 1 places identically to Sector (writer
        // local) but pays the chunk-lease grant before every write.
        let (cluster2, _, _) = setup(1, 50_000);
        let kfs = Rc::new(RefCell::new(KfsStorage::new(cluster2.topo.clone(), nodes, 1, 17)));
        let leased = run_dataflow(&cluster2, kfs, sp);
        assert!(
            leased.makespan > base.makespan,
            "lease latency lost: {} !> {}",
            leased.makespan,
            base.makespan
        );
    }

    /// Run a dataflow returning (control, report cell, engine) so crash
    /// tests can schedule failures around the run.
    fn run_with_control(
        cluster: &Cluster,
        storage: Rc<RefCell<dyn StorageModel>>,
        sp: DataflowSpec,
    ) -> (Engine, DataflowControl, Rc<RefCell<Option<DataflowReport>>>) {
        let mut eng = Engine::new();
        let out = Rc::new(RefCell::new(None));
        let o = out.clone();
        let control = DataflowEngine::run(cluster, storage, &mut eng, sp, move |_, r| {
            *o.borrow_mut() = Some(r)
        });
        (eng, control, out)
    }

    #[test]
    fn crash_mid_task_heal_reexecutes_inflight_work() {
        let (cluster, nodes, _) = setup(2, 400_000);
        // Two tasks per node so the victim holds both its slots.
        let tasks: Vec<TaskInput> = nodes
            .iter()
            .flat_map(|&n| {
                (0..2).map(move |_| TaskInput { node: n, bytes: 400_000 * 100, records: 400_000 })
            })
            .collect();
        let sp = spec(nodes.clone(), tasks, ExchangeModel::ShufflePull { parallel_copies: 4 });
        let storage = Rc::new(RefCell::new(SectorStorage::new()));
        let (mut eng, control, out) = run_with_control(&cluster, storage, sp);
        let victim = nodes[0];
        // Every task needs ≥ 1.9s (overhead + disk + cpu), so at t=1 both
        // of the victim's assignments are in flight; detection "arrives"
        // at t=8 and re-queues them.
        let c = control.clone();
        eng.schedule_at(1.0, move |_| c.crash_node(victim));
        let healed = Rc::new(RefCell::new(0usize));
        let (c, h) = (control.clone(), healed.clone());
        eng.schedule_at(8.0, move |eng| *h.borrow_mut() = c.heal_node(eng, victim));
        eng.run();
        let r = out.borrow_mut().take().expect("dataflow did not survive the crash");
        assert_eq!(*healed.borrow(), 2, "both in-flight assignments re-queued");
        assert_eq!(r.reexecuted, 2);
        assert_eq!(r.tasks, 16);
        // Reducers avoid the dead node; no output lands there.
        assert!(r.output.iter().all(|t| t.node != victim), "{:?}", r.output);
        // Healing the same node again is a no-op, as is a post-run heal.
        let mut eng2 = Engine::new();
        assert_eq!(control.heal_node(&mut eng2, victim), 0);
    }

    #[test]
    fn crash_after_completion_reruns_lost_spills() {
        let (cluster, nodes, _) = setup(2, 400_000);
        // The victim's tasks are short (finish ~0.7s); everyone else's
        // take ≥ 2.9s (two 40 MB reads share one spindle).
        let victim = nodes[0];
        let tasks: Vec<TaskInput> = nodes
            .iter()
            .flat_map(|&n| {
                let records = if n == victim { 50_000 } else { 400_000 };
                (0..2).map(move |_| TaskInput { node: n, bytes: records * 100, records })
            })
            .collect();
        let sp = spec(nodes.clone(), tasks, ExchangeModel::ShufflePull { parallel_copies: 4 });
        let storage = Rc::new(RefCell::new(SectorStorage::new()));
        let (mut eng, control, out) = run_with_control(&cluster, storage, sp);
        // At t=1.5 the victim has completed both tasks (spills on its
        // disk) and holds nothing in flight; the crash+heal must rerun
        // the completed tasks because their spills died with the node.
        let c = control.clone();
        eng.schedule_at(1.5, move |_| c.crash_node(victim));
        let healed = Rc::new(RefCell::new(0usize));
        let (c, h) = (control, healed.clone());
        eng.schedule_at(2.0, move |eng| *h.borrow_mut() = c.heal_node(eng, victim));
        eng.run();
        let r = out.borrow_mut().take().expect("dataflow did not survive the crash");
        assert_eq!(*healed.borrow(), 2, "completed-then-lost tasks re-queued");
        assert_eq!(r.reexecuted, 2);
        assert_eq!(r.tasks, 16);
    }

    #[test]
    fn crash_after_barrier_is_a_noop() {
        let (cluster, nodes, tasks) = setup(2, 200_000);
        let sp = spec(nodes.clone(), tasks, ExchangeModel::ShufflePull { parallel_copies: 4 });
        let storage = Rc::new(RefCell::new(SectorStorage::new()));
        let baseline = run_dataflow(&cluster, storage.clone(), sp.clone());
        let (cluster2, _, _) = setup(2, 200_000);
        let (mut eng, control, out) = run_with_control(
            &cluster2,
            Rc::new(RefCell::new(SectorStorage::new())),
            sp,
        );
        let victim = nodes[0];
        // Well past phase 1 (baseline's barrier): outputs are safe, so a
        // crash changes nothing and heal re-queues nothing.
        let at = baseline.phase1 + 0.5 * baseline.phase2;
        let c = control.clone();
        eng.schedule_at(at, move |_| c.crash_node(victim));
        let healed = Rc::new(RefCell::new(usize::MAX));
        let (c, h) = (control, healed.clone());
        eng.schedule_at(at + 0.1, move |eng| *h.borrow_mut() = c.heal_node(eng, victim));
        eng.run();
        let r = out.borrow_mut().take().expect("dataflow did not finish");
        assert_eq!(*healed.borrow(), 0);
        assert_eq!(r.reexecuted, 0);
        assert!((r.makespan - baseline.makespan).abs() < 1e-6, "timing drifted");
    }

    #[test]
    fn bucket_push_crash_heal_completes() {
        let (cluster, nodes, _) = setup(2, 400_000);
        let tasks: Vec<TaskInput> = nodes
            .iter()
            .flat_map(|&n| {
                (0..2).map(move |_| TaskInput { node: n, bytes: 400_000 * 100, records: 400_000 })
            })
            .collect();
        let mut sp = spec(nodes.clone(), tasks, ExchangeModel::BucketPush);
        sp.output_bytes_per_record = 0.0;
        let storage = Rc::new(RefCell::new(SectorStorage::new()));
        let (mut eng, control, out) = run_with_control(&cluster, storage, sp);
        let victim = nodes[1];
        let c = control.clone();
        eng.schedule_at(1.0, move |_| c.crash_node(victim));
        let c = control;
        eng.schedule_at(8.0, move |eng| {
            c.heal_node(eng, victim);
        });
        eng.run();
        let r = out.borrow_mut().take().expect("bucket-push dataflow hung after crash");
        assert!(r.reexecuted >= 1);
        // One bucket per *survivor* — the dead node's bucket died with it.
        assert_eq!(r.reducers, nodes.len() - 1);
        assert_eq!(r.tasks, 16);
    }

    #[test]
    fn straggler_is_absorbed_by_stealing() {
        let (cluster, nodes, tasks) = setup(2, 400_000);
        let mut sp = spec(nodes.clone(), tasks, ExchangeModel::BucketPush);
        sp.output_bytes_per_record = 0.0;
        let healthy = run_dataflow(
            &cluster,
            Rc::new(RefCell::new(SectorStorage::new())),
            sp.clone(),
        );
        let (cluster2, nodes2, tasks2) = setup(2, 400_000);
        cluster2.set_node_speed(nodes2[0], 0.25);
        let mut sp2 = sp.clone();
        sp2.tasks = tasks2;
        let degraded =
            run_dataflow(&cluster2, Rc::new(RefCell::new(SectorStorage::new())), sp2);
        assert!(
            degraded.makespan < healthy.makespan * 2.0,
            "straggler not absorbed: {} vs {}",
            degraded.makespan,
            healthy.makespan
        );
    }
}

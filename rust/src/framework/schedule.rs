//! Slot-based locality scheduling with pluggable stealing — the task
//! assignment loop both 2009 engines shared (Hadoop's JobTracker list
//! scheduler, Sphere's SPE segment scheduler with "bandwidth load
//! balancing").

use std::collections::BTreeMap;

use crate::net::{NodeId, Topology};

use super::runtime::TaskInput;

/// How far from a task's home node a worker may reach for it.
///
/// Distances follow [`Topology::distance`]: 0 = same node, 1 = same rack,
/// 2 = same site, 3 = across the WAN. Both 2009 engines steal from
/// anywhere — Hadoop runs remote-read maps, Sphere streams stolen
/// segments over UDT — so [`StealPolicy::Anywhere`] reproduces them; the
/// tighter tiers exist for ablations ("what does stealing buy?").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StealPolicy {
    /// Steal from any node, paying the network distance (both engines).
    Anywhere,
    /// Steal only within the task's home site (no WAN reads).
    SameSite,
    /// Strict locality: only node-local tasks run. Callers must ensure
    /// every task's home node is in the worker set or the job never
    /// drains.
    LocalOnly,
}

impl StealPolicy {
    /// May a worker at `distance` from the task's home run it?
    pub fn allows(&self, distance: u32) -> bool {
        match self {
            StealPolicy::Anywhere => true,
            StealPolicy::SameSite => distance <= 2,
            StealPolicy::LocalOnly => distance == 0,
        }
    }
}

/// Per-node slot accounting plus the locality-first assignment scan.
///
/// `next_assignment` reproduces the engines' shared loop exactly: walk
/// the workers in order, and for the first one with a free slot pick the
/// pending task minimizing topological distance (stopping early on a
/// node-local hit), counting any non-local assignment as a steal.
pub struct SlotScheduler {
    nodes: Vec<NodeId>,
    slots_free: BTreeMap<NodeId, usize>,
    pending: Vec<TaskInput>,
    running: usize,
    stolen: usize,
    policy: StealPolicy,
}

impl SlotScheduler {
    pub fn new(
        nodes: Vec<NodeId>,
        slots_per_node: usize,
        pending: Vec<TaskInput>,
        policy: StealPolicy,
    ) -> Self {
        assert!(!nodes.is_empty());
        assert!(slots_per_node >= 1);
        let slots_free = nodes.iter().map(|&n| (n, slots_per_node)).collect();
        SlotScheduler { nodes, slots_free, pending, running: 0, stolen: 0, policy }
    }

    /// Claim the next (worker, task) pair, or `None` when no worker with
    /// a free slot may run any pending task.
    pub fn next_assignment(&mut self, topo: &Topology) -> Option<(NodeId, TaskInput)> {
        if self.pending.is_empty() {
            return None;
        }
        for &n in &self.nodes {
            if self.slots_free[&n] == 0 {
                continue;
            }
            // Best pending task for this worker.
            let mut best: Option<(usize, u32)> = None;
            for (i, t) in self.pending.iter().enumerate() {
                let d = topo.distance(n, t.node);
                if self.policy.allows(d) && best.map_or(true, |(_, bd)| d < bd) {
                    best = Some((i, d));
                }
                if d == 0 {
                    break;
                }
            }
            if let Some((i, d)) = best {
                let t = self.pending.swap_remove(i);
                *self.slots_free.get_mut(&n).unwrap() -= 1;
                self.running += 1;
                if d > 0 {
                    self.stolen += 1;
                }
                return Some((n, t));
            }
        }
        None
    }

    /// Return a worker's slot after its task finishes.
    pub fn release(&mut self, node: NodeId) {
        *self.slots_free.get_mut(&node).unwrap() += 1;
        self.running -= 1;
    }

    /// Remove a failed worker: it gets no further assignments and its
    /// slots are forgotten. Tasks it was running must be put back with
    /// [`SlotScheduler::requeue`] — the scheduler has no record of *which*
    /// tasks a worker holds (the runtime tracks assignments).
    pub fn remove_node(&mut self, node: NodeId) {
        self.nodes.retain(|&n| n != node);
        self.slots_free.remove(&node);
    }

    /// Put a task back on the pending queue: a lost in-flight assignment
    /// (`was_running = true`, releases its claim on the running count) or
    /// a completed task whose output died with its node
    /// (`was_running = false`).
    pub fn requeue(&mut self, task: TaskInput, was_running: bool) {
        self.pending.push(task);
        if was_running {
            self.running -= 1;
        }
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn running(&self) -> usize {
        self.running
    }

    /// Tasks assigned to a worker other than their home node so far.
    pub fn stolen(&self) -> usize {
        self.stolen
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Topology;

    fn task(node: NodeId) -> TaskInput {
        TaskInput { node, bytes: 1, records: 1 }
    }

    #[test]
    fn prefers_local_then_closest() {
        let topo = Topology::oct_2009();
        let local = topo.racks[0].nodes[0];
        let rackmate = topo.racks[0].nodes[1];
        let remote = topo.racks[3].nodes[0];
        let mut s = SlotScheduler::new(
            vec![local],
            3,
            vec![task(remote), task(rackmate), task(local)],
            StealPolicy::Anywhere,
        );
        let (_, t1) = s.next_assignment(&topo).unwrap();
        assert_eq!(t1.node, local);
        let (_, t2) = s.next_assignment(&topo).unwrap();
        assert_eq!(t2.node, rackmate);
        let (_, t3) = s.next_assignment(&topo).unwrap();
        assert_eq!(t3.node, remote);
        assert_eq!(s.stolen(), 2);
        assert!(s.next_assignment(&topo).is_none(), "no slots left");
    }

    #[test]
    fn slots_bound_concurrency_and_release_reopens() {
        let topo = Topology::oct_2009();
        let n = topo.racks[0].nodes[0];
        let mut s =
            SlotScheduler::new(vec![n], 1, vec![task(n), task(n)], StealPolicy::Anywhere);
        assert!(s.next_assignment(&topo).is_some());
        assert_eq!(s.running(), 1);
        assert!(s.next_assignment(&topo).is_none(), "slot occupied");
        s.release(n);
        assert!(s.next_assignment(&topo).is_some());
        assert_eq!(s.pending_len(), 0);
    }

    #[test]
    fn same_site_policy_refuses_wan_steals() {
        let topo = Topology::oct_2009();
        let worker = topo.racks[0].nodes[0];
        let far = topo.racks[3].nodes[0];
        let near = topo.racks[0].nodes[5];
        let mut s = SlotScheduler::new(
            vec![worker],
            2,
            vec![task(far), task(near)],
            StealPolicy::SameSite,
        );
        let (_, t) = s.next_assignment(&topo).unwrap();
        assert_eq!(t.node, near);
        // The cross-WAN task is ineligible even with a free slot.
        assert!(s.next_assignment(&topo).is_none());
        assert_eq!(s.pending_len(), 1);
    }

    #[test]
    fn local_only_policy_never_steals() {
        let topo = Topology::oct_2009();
        let worker = topo.racks[0].nodes[0];
        let rackmate = topo.racks[0].nodes[1];
        let mut s = SlotScheduler::new(
            vec![worker],
            2,
            vec![task(rackmate), task(worker)],
            StealPolicy::LocalOnly,
        );
        let (_, t) = s.next_assignment(&topo).unwrap();
        assert_eq!(t.node, worker);
        assert!(s.next_assignment(&topo).is_none());
        assert_eq!(s.stolen(), 0);
    }

    #[test]
    fn removed_node_gets_no_assignments_and_requeue_reschedules() {
        let topo = Topology::oct_2009();
        let dead = topo.racks[0].nodes[0];
        let alive = topo.racks[0].nodes[1];
        let mut s = SlotScheduler::new(
            vec![dead, alive],
            1,
            vec![task(dead), task(dead)],
            StealPolicy::Anywhere,
        );
        // Both workers take one task each (dead's is local, alive steals).
        let (w1, t1) = s.next_assignment(&topo).unwrap();
        assert_eq!(w1, dead);
        let (w2, _) = s.next_assignment(&topo).unwrap();
        assert_eq!(w2, alive);
        assert_eq!(s.running(), 2);
        // The dead worker fails mid-task: remove it and requeue its task.
        s.remove_node(dead);
        s.requeue(t1, true);
        assert_eq!(s.running(), 1);
        assert_eq!(s.pending_len(), 1);
        // No free slot anywhere (alive is busy) → no assignment yet.
        assert!(s.next_assignment(&topo).is_none());
        s.release(alive);
        let (w3, t3) = s.next_assignment(&topo).unwrap();
        assert_eq!(w3, alive, "requeued task must land on a survivor");
        assert_eq!(t3.node, dead);
        // A completed-then-lost task requeues without touching running.
        s.requeue(task(dead), false);
        assert_eq!(s.running(), 1);
        assert_eq!(s.pending_len(), 1);
    }

    #[test]
    fn policy_distance_tiers() {
        assert!(StealPolicy::Anywhere.allows(3));
        assert!(StealPolicy::SameSite.allows(2) && !StealPolicy::SameSite.allows(3));
        assert!(StealPolicy::LocalOnly.allows(0) && !StealPolicy::LocalOnly.allows(1));
    }
}

//! Exchange models: how intermediate data crosses the cluster between a
//! dataflow's two phases.
//!
//! The two 2009 archetypes:
//!
//! - **Shuffle pull** (Hadoop): map output spills to local disk; after
//!   the map barrier, each reducer *pulls* its partition from every
//!   producer node with at most `parallel_copies` concurrent fetches
//!   (`mapred.reduce.parallel.copies`), then merges and reduces.
//! - **Bucket push** (Sphere): each task *pushes* its hash-partitioned
//!   output into bucket files on every node as it is produced, overlapped
//!   with the scan — the exchange is mostly paid for by the time the scan
//!   barrier clears.
//!
//! Which transport carries the bytes ([`crate::transport::Protocol`]) is
//! a separate axis carried by the dataflow spec: Hadoop shuffles over
//! TCP, Sphere pushes over UDT, and the interop compositions mix freely.

/// The intermediate-data movement pattern of a dataflow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeModel {
    /// Barrier-then-pull all-to-all shuffle with bounded parallel fetch
    /// streams per reducer (Hadoop).
    ShufflePull { parallel_copies: usize },
    /// Streamed per-task bucket push to every node, overlapped with the
    /// scan phase (Sphere).
    BucketPush,
}

impl ExchangeModel {
    pub fn name(&self) -> &'static str {
        match self {
            ExchangeModel::ShufflePull { .. } => "shuffle-pull",
            ExchangeModel::BucketPush => "bucket-push",
        }
    }

    /// Does the exchange overlap phase 1 (push) or wait for the barrier
    /// (pull)?
    pub fn overlaps_scan(&self) -> bool {
        matches!(self, ExchangeModel::BucketPush)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_overlap() {
        let pull = ExchangeModel::ShufflePull { parallel_copies: 5 };
        assert_eq!(pull.name(), "shuffle-pull");
        assert!(!pull.overlaps_scan());
        assert_eq!(ExchangeModel::BucketPush.name(), "bucket-push");
        assert!(ExchangeModel::BucketPush.overlaps_scan());
    }
}

//! Storage models: how a framework's distributed file system resolves
//! input reads and places/pipelines output writes.
//!
//! The runtime only needs three answers from a storage layer: *where do I
//! read this block from*, *where do this writer's output replicas go*,
//! and *what control-plane latency precedes a write*. Everything else
//! (the actual disk and network timing) is shared: every model's write
//! goes through [`pipeline_write`], the replication pipeline that HDFS,
//! KFS, and Sector's synchronous first copy all use — they differ only in
//! the replica lists they produce.

use std::cell::RefCell;
use std::rc::Rc;

use crate::hadoop::hdfs::Namenode;
use crate::net::{FlowNet, NodeId, Topology};
use crate::sim::Engine;
use crate::transport::{self, Protocol};
use crate::util::Rng;

/// What the dataflow runtime asks of a storage layer.
pub trait StorageModel {
    /// Node to stream a task's input from, given the block's primary
    /// location and the worker about to read it.
    fn read_source(&self, primary: NodeId, reader: NodeId) -> NodeId;

    /// Replica targets for an output block written from `writer`; the
    /// first entry is the primary (the pipeline head).
    fn place_output(&mut self, writer: NodeId) -> Vec<NodeId>;

    /// Control-plane latency charged before an output write from `writer`
    /// starts (e.g. KFS's chunk-lease grant round-trip). Zero-latency
    /// models add no event to the engine.
    fn write_setup_latency(&self, _writer: NodeId) -> f64 {
        0.0
    }

    fn name(&self) -> &'static str;
}

/// HDFS (Hadoop 0.18): rack-aware synchronous replication through the
/// namenode's placement policy; reads come from the closest replica.
pub struct HdfsStorage {
    nn: Rc<RefCell<Namenode>>,
    replication: usize,
}

impl HdfsStorage {
    pub fn new(nn: Rc<RefCell<Namenode>>, replication: usize) -> Self {
        assert!(replication >= 1);
        HdfsStorage { nn, replication }
    }
}

impl StorageModel for HdfsStorage {
    fn read_source(&self, primary: NodeId, reader: NodeId) -> NodeId {
        self.nn.borrow().closest_source(primary, reader)
    }

    fn place_output(&mut self, writer: NodeId) -> Vec<NodeId> {
        self.nn.borrow_mut().place_replicas_n(writer, self.replication)
    }

    fn name(&self) -> &'static str {
        "hdfs"
    }
}

/// Sector (1.20): files live as whole segments on their home slave;
/// writes land on the writer and replicate lazily in the background, so
/// jobs see single-copy write cost (the Table 2 mechanism).
#[derive(Debug, Clone, Copy, Default)]
pub struct SectorStorage;

impl SectorStorage {
    pub fn new() -> Self {
        SectorStorage
    }
}

impl StorageModel for SectorStorage {
    fn read_source(&self, primary: NodeId, _reader: NodeId) -> NodeId {
        primary
    }

    fn place_output(&mut self, writer: NodeId) -> Vec<NodeId> {
        vec![writer]
    }

    fn name(&self) -> &'static str {
        "sector"
    }
}

/// CloudStore/KFS (the paper's third storage substrate, §7): a GFS-style
/// chunk store whose writes are gated by a chunk-lease grant from the
/// metaserver and whose 2009 placement was rack-*oblivious* — replicas go
/// to random chunkservers, so on a wide-area deployment the replication
/// pipeline tends to cross the WAN more often than HDFS 0.18's
/// second-and-third-on-one-remote-rack policy.
pub struct KfsStorage {
    topo: Rc<Topology>,
    /// Chunkserver membership (the deployment's nodes).
    members: Vec<NodeId>,
    replication: usize,
    /// Where the metaserver runs (lease grants round-trip here).
    metaserver: NodeId,
    rng: Rng,
}

impl KfsStorage {
    pub fn new(topo: Rc<Topology>, members: Vec<NodeId>, replication: usize, seed: u64) -> Self {
        assert!(!members.is_empty());
        assert!(replication >= 1);
        let metaserver = members[0];
        KfsStorage { topo, members, replication, metaserver, rng: Rng::new(seed) }
    }
}

impl StorageModel for KfsStorage {
    fn read_source(&self, primary: NodeId, _reader: NodeId) -> NodeId {
        primary
    }

    /// Writer-local first chunk copy, then random distinct chunkservers.
    fn place_output(&mut self, writer: NodeId) -> Vec<NodeId> {
        let mut out = vec![writer];
        let mut candidates: Vec<NodeId> =
            self.members.iter().copied().filter(|&n| n != writer).collect();
        while out.len() < self.replication && !candidates.is_empty() {
            let i = self.rng.gen_range(candidates.len() as u64) as usize;
            out.push(candidates.swap_remove(i));
        }
        out
    }

    /// One chunk-lease request/grant round-trip to the metaserver (KFS
    /// leases are per-chunk; connectionless request + reply).
    fn write_setup_latency(&self, writer: NodeId) -> f64 {
        transport::control_message_latency(self.topo.rtt(writer, self.metaserver), true) * 2.0
    }

    fn name(&self) -> &'static str {
        "kfs"
    }
}

/// Timed pipelined write of one output block from `replicas[0]` through
/// the replica chain: a disk write on every replica plus one network hop
/// per pipeline edge, all concurrent (the pipeline streams packets), done
/// when the slowest leg lands. This is the single replication pipeline
/// every storage model shares; `hdfs::write_block` delegates here.
#[allow(clippy::too_many_arguments)]
pub fn pipeline_write<F: FnOnce(&mut Engine) + 'static>(
    net: &Rc<RefCell<FlowNet>>,
    topo: &Rc<Topology>,
    eng: &mut Engine,
    replicas: &[NodeId],
    bytes: f64,
    proto: &Protocol,
    done: F,
) {
    assert!(!replicas.is_empty());
    // Legs: one disk write per replica + one network hop per pipeline edge.
    let legs = 2 * replicas.len() - 1;
    let remaining = Rc::new(RefCell::new(legs));
    // Completion joiner.
    let done_cell = Rc::new(RefCell::new(Some(done)));
    let arm = move |remaining: &Rc<RefCell<usize>>, done_cell: &Rc<RefCell<Option<F>>>| {
        let remaining = remaining.clone();
        let done_cell = done_cell.clone();
        move |eng: &mut Engine| {
            let mut r = remaining.borrow_mut();
            *r -= 1;
            if *r == 0 {
                if let Some(d) = done_cell.borrow_mut().take() {
                    d(eng);
                }
            }
        }
    };
    // Disk write on every replica.
    for &r in replicas {
        transport::disk_write(net, topo, eng, r, bytes, arm(&remaining, &done_cell));
    }
    // Network hops along the pipeline chain.
    for w in replicas.windows(2) {
        transport::send(net, topo, eng, w[0], w[1], bytes, proto, arm(&remaining, &done_cell));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadoop::hdfs::HdfsConfig;

    fn topo() -> Rc<Topology> {
        Rc::new(Topology::oct_2009())
    }

    #[test]
    fn hdfs_storage_places_through_the_namenode_policy() {
        let t = topo();
        let nn = Rc::new(RefCell::new(Namenode::new(t.clone(), HdfsConfig::default(), 5)));
        let mut s = HdfsStorage::new(nn, 3);
        let reps = s.place_output(NodeId(7));
        assert_eq!(reps.len(), 3);
        assert_eq!(reps[0], NodeId(7));
        // 0.18 policy: second replica off-rack, third with the second.
        assert!(!t.same_rack(reps[0], reps[1]));
        assert!(t.same_rack(reps[1], reps[2]));
        assert_eq!(s.write_setup_latency(NodeId(7)), 0.0);
        assert_eq!(s.read_source(NodeId(3), NodeId(9)), NodeId(3));
    }

    #[test]
    fn sector_storage_is_writer_local_single_copy() {
        let mut s = SectorStorage::new();
        assert_eq!(s.place_output(NodeId(11)), vec![NodeId(11)]);
        assert_eq!(s.read_source(NodeId(2), NodeId(40)), NodeId(2));
        assert_eq!(s.write_setup_latency(NodeId(11)), 0.0);
    }

    #[test]
    fn kfs_storage_charges_a_lease_and_places_randomly() {
        let t = topo();
        let members = t.node_ids();
        let mut s = KfsStorage::new(t.clone(), members, 3, 99);
        for _ in 0..20 {
            let reps = s.place_output(NodeId(0));
            assert_eq!(reps.len(), 3);
            assert_eq!(reps[0], NodeId(0));
            let mut uniq = reps.clone();
            uniq.sort();
            uniq.dedup();
            assert_eq!(uniq.len(), 3, "duplicate chunkservers: {reps:?}");
        }
        // The lease pays at least one metaserver round-trip; a writer far
        // from the metaserver pays more than the metaserver itself.
        let near = s.write_setup_latency(NodeId(0));
        let far = s.write_setup_latency(t.racks[3].nodes[0]);
        assert!(near > 0.0);
        assert!(far > near, "far {far} !> near {near}");
    }

    #[test]
    fn kfs_single_replication_degenerates_to_local() {
        let t = topo();
        let mut s = KfsStorage::new(t.clone(), t.node_ids(), 1, 3);
        assert_eq!(s.place_output(NodeId(5)), vec![NodeId(5)]);
    }

    #[test]
    fn pipeline_write_completes_with_all_legs() {
        let t = topo();
        let net = FlowNet::new(&t);
        let mut eng = Engine::new();
        let done_at = Rc::new(RefCell::new(0.0));
        let d = done_at.clone();
        let replicas = [NodeId(0), t.racks[1].nodes[0], t.racks[1].nodes[1]];
        pipeline_write(&net, &t, &mut eng, &replicas, 64e6, &Protocol::tcp(), move |e| {
            *d.borrow_mut() = e.now();
        });
        eng.run();
        // 3 disk legs + 2 network hops, gated by the WAN TCP hop.
        assert_eq!(net.borrow().completions(), 5);
        assert!(*done_at.borrow() > 1.0);
    }
}

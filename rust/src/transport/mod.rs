//! Transport protocol models: TCP (Reno-era, 2009 stacks) and UDT.
//!
//! The paper attributes Sector's negligible wide-area penalty to UDT [12]:
//! a rate-based UDP transport whose sustained throughput is essentially
//! RTT-insensitive, where TCP's is bounded both by the Mathis steady-state
//! law `1.22·MSS/(RTT·√p)` and by the receive-window ceiling `W/RTT`. Both
//! laws are implemented here and turned into per-flow **rate caps** for the
//! fluid network ([`crate::net::FlowNet`]); the Table 2 penalty gap then
//! *emerges* from Hadoop moving shuffle/replica bytes over TCP while
//! Sector moves them over UDT.
//!
//! Connection setup and slow-start ramp are modeled as a latency overhead
//! prepended to each transfer ([`Protocol::transfer_overhead`]); GMP's
//! connectionless advantage for small control messages (paper §4) is the
//! same model with zero setup.

use std::cell::RefCell;
use std::rc::Rc;

use crate::net::{FlowNet, NodeId, Topology};
use crate::sim::Engine;

/// 2009-era TCP throughput model.
#[derive(Debug, Clone)]
pub struct TcpModel {
    /// Maximum segment size, bytes.
    pub mss: f64,
    /// Steady-state loss probability on clean short paths.
    pub loss: f64,
    /// Loss probability once the flow rides the *shared* wide-area wave:
    /// many synchronized TCP flows over a saturated high-BDP lambda see
    /// congestion/recovery loss orders of magnitude above the lightpath
    /// bit-error floor — the well-documented TCP limitation the paper
    /// cites ([13], and the UDT paper's motivation).
    pub wan_loss: f64,
    /// RTT above which a path counts as wide-area for `wan_loss`.
    pub wan_rtt_threshold: f64,
    /// Effective max window (socket buffers / autotuning limit), bytes.
    pub max_wnd: f64,
    /// Initial congestion window, bytes (slow-start origin).
    pub init_wnd: f64,
}

impl Default for TcpModel {
    fn default() -> Self {
        // 256 KiB effective window: 2009 Linux defaults plus Hadoop's
        // un-tuned HTTP shuffle buffers. On the 58 ms Chicago–San Diego
        // path this caps a flow near 4.4 MB/s — "the limitations of TCP
        // [over wide areas] are well documented" (paper §6).
        TcpModel {
            mss: 1460.0,
            loss: 5e-7,
            wan_loss: 5.0e-4,
            wan_rtt_threshold: 5e-3,
            max_wnd: (256u64 << 10) as f64,
            init_wnd: 4.0 * 1460.0,
        }
    }
}

/// UDT rate-based model (DAIMD): converges near the available bandwidth
/// regardless of RTT.
#[derive(Debug, Clone)]
pub struct UdtModel {
    /// Fraction of the bottleneck sustained on short paths (protocol +
    /// framing overhead).
    pub efficiency: f64,
    /// Fraction sustained on wide-area paths: the UDT evaluation [12]
    /// reports ~90% on high-RTT lambdas vs ~95% locally (rate-probe
    /// convergence + recovery cost). Still ~RTT-insensitive, unlike TCP's
    /// 1/RTT collapse.
    pub wan_efficiency: f64,
    /// RTT above which `wan_efficiency` applies.
    pub wan_rtt_threshold: f64,
}

impl Default for UdtModel {
    fn default() -> Self {
        UdtModel { efficiency: 0.93, wan_efficiency: 0.88, wan_rtt_threshold: 5e-3 }
    }
}

/// A transport protocol choice for a transfer.
#[derive(Debug, Clone)]
pub enum Protocol {
    Tcp(TcpModel),
    Udt(UdtModel),
}

impl Protocol {
    pub fn tcp() -> Self {
        Protocol::Tcp(TcpModel::default())
    }

    pub fn udt() -> Self {
        Protocol::Udt(UdtModel::default())
    }

    pub fn name(&self) -> &'static str {
        match self {
            Protocol::Tcp(_) => "tcp",
            Protocol::Udt(_) => "udt",
        }
    }

    /// Sustained-rate cap (bytes/s) on a path with round-trip `rtt` whose
    /// narrowest link has capacity `bottleneck` (bytes/s).
    pub fn rate_cap(&self, rtt: f64, bottleneck: f64) -> f64 {
        assert!(rtt > 0.0 && bottleneck > 0.0);
        match self {
            Protocol::Tcp(m) => {
                let loss = if rtt > m.wan_rtt_threshold { m.wan_loss } else { m.loss };
                let mathis = 1.22 * m.mss / (rtt * loss.sqrt());
                let window = m.max_wnd / rtt;
                mathis.min(window).min(bottleneck)
            }
            Protocol::Udt(m) => {
                let eff = if rtt > m.wan_rtt_threshold { m.wan_efficiency } else { m.efficiency };
                eff * bottleneck
            }
        }
    }

    /// Latency overhead before a transfer of `bytes` reaches its sustained
    /// rate: connection setup plus a slow-start/ramp approximation.
    pub fn transfer_overhead(&self, bytes: f64, rtt: f64, bottleneck: f64) -> f64 {
        match self {
            Protocol::Tcp(m) => {
                let setup = 1.5 * rtt; // SYN, SYN-ACK, ACK+first data
                // Slow start doubles cwnd each RTT from init_wnd to the
                // operating window; bytes sent during the ramp are roughly
                // one window, so charge log2 RTTs.
                let target_wnd = (self.rate_cap(rtt, bottleneck) * rtt).min(bytes).max(m.init_wnd);
                let rounds = (target_wnd / m.init_wnd).log2().max(0.0);
                setup + rounds * rtt
            }
            Protocol::Udt(_) => {
                // Single handshake; DAIMD ramps within a few RTTs.
                1.0 * rtt + 2.0 * rtt
            }
        }
    }

    /// Analytic time to move `bytes` alone over a path (no contention):
    /// overhead + bytes/cap. Used by unit tests and quick estimates; the
    /// engines use [`send`] so contention is handled by the fluid network.
    pub fn transfer_time(&self, bytes: f64, rtt: f64, bottleneck: f64) -> f64 {
        self.transfer_overhead(bytes, rtt, bottleneck) + bytes / self.rate_cap(rtt, bottleneck)
    }
}

/// One-way delivery latency of a small control message (paper §4):
/// connectionless GMP sends immediately; TCP pays connection setup first.
pub fn control_message_latency(rtt: f64, connectionless: bool) -> f64 {
    let proc = 40e-6; // endpoint processing
    if connectionless {
        0.5 * rtt + proc
    } else {
        1.5 * rtt + 0.5 * rtt + proc
    }
}

/// Start a node-to-node transfer over the fluid network using `proto`'s
/// rate cap and latency overhead. `done` fires when the last byte lands.
pub fn send<F: FnOnce(&mut Engine) + 'static>(
    net: &Rc<RefCell<FlowNet>>,
    topo: &Topology,
    eng: &mut Engine,
    src: NodeId,
    dst: NodeId,
    bytes: f64,
    proto: &Protocol,
    done: F,
) {
    if src == dst {
        // Local move: charge the disk path only if callers model it; here
        // an in-memory handoff is immediate.
        eng.schedule_in(0.0, done);
        return;
    }
    let route = topo.route(src, dst);
    let rtt = topo.rtt(src, dst);
    let bottleneck =
        route.path.iter().map(|l| topo.link(*l).capacity).fold(f64::INFINITY, f64::min);
    let cap = proto.rate_cap(rtt, bottleneck);
    let overhead = proto.transfer_overhead(bytes, rtt, bottleneck);
    let net = net.clone();
    eng.schedule_in(overhead, move |eng| {
        FlowNet::start_route(&net, eng, route, bytes, cap, done);
    });
}

/// Sequential disk read (a flow across the node's disk link).
pub fn disk_read<F: FnOnce(&mut Engine) + 'static>(
    net: &Rc<RefCell<FlowNet>>,
    topo: &Topology,
    eng: &mut Engine,
    node: NodeId,
    bytes: f64,
    done: F,
) {
    FlowNet::start_route(net, eng, topo.disk_route(node), bytes, f64::INFINITY, done);
}

/// Sequential disk write (same shared disk link; SATA is half-duplex-ish
/// under mixed load, which sharing one link approximates).
pub fn disk_write<F: FnOnce(&mut Engine) + 'static>(
    net: &Rc<RefCell<FlowNet>>,
    topo: &Topology,
    eng: &mut Engine,
    node: NodeId,
    bytes: f64,
    done: F,
) {
    disk_read(net, topo, eng, node, bytes, done)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topology::NodeSpec;

    const NIC: f64 = 117.5e6; // bytes/s

    #[test]
    fn tcp_matches_mathis_on_wan() {
        let p = Protocol::tcp();
        // 58 ms RTT (Chicago–San Diego) with shared-wave congestion loss
        // 5e-4: Mathis-limited near 1.4 MB/s, far below window and NIC.
        let cap = p.rate_cap(0.058, 1.25e9);
        let mathis = 1.22 * 1460.0 / (0.058 * (5.0e-4f64).sqrt());
        assert!((cap - mathis).abs() / cap < 1e-9, "cap {cap} mathis {mathis}");
        assert!(cap < 2e6);
        // Below the WAN threshold the clean-path loss applies and the
        // window cap binds instead.
        let lan_ish = p.rate_cap(2e-3, 1.25e9);
        assert!((lan_ish - (256u64 << 10) as f64 / 2e-3).abs() / lan_ish < 1e-9);
    }

    #[test]
    fn tcp_reaches_line_rate_on_lan() {
        let p = Protocol::tcp();
        let cap = p.rate_cap(100e-6, NIC);
        assert_eq!(cap, NIC); // bottleneck-bound, not protocol-bound
    }

    #[test]
    fn udt_is_rtt_insensitive() {
        // ~RTT-insensitive: ≤ 6% droop from LAN to coast-to-coast, unlike
        // TCP's order-of-magnitude collapse.
        let p = Protocol::udt();
        let lan = p.rate_cap(100e-6, NIC);
        let wan = p.rate_cap(0.075, NIC);
        assert!((lan - wan) / lan < 0.06);
        assert!((lan - 0.93 * NIC).abs() < 1.0);
        assert!((wan - 0.88 * NIC).abs() < 1.0);
    }

    #[test]
    fn udt_beats_tcp_on_wan_not_lan() {
        let tcp = Protocol::tcp();
        let udt = Protocol::udt();
        // WAN: the paper's §6 mechanism.
        assert!(udt.rate_cap(0.058, NIC) > 5.0 * tcp.rate_cap(0.058, NIC));
        // LAN: near parity (TCP slightly ahead since UDT pays 7% overhead).
        let (t, u) = (tcp.rate_cap(1e-4, NIC), udt.rate_cap(1e-4, NIC));
        assert!((t - u) / t < 0.1);
    }

    #[test]
    fn tcp_cap_monotone_in_rtt_and_loss() {
        crate::proptest::check("tcp cap monotone", 50, |rng| {
            let rtt1 = 1e-4 + rng.f64() * 0.05;
            let rtt2 = rtt1 + 1e-3 + rng.f64() * 0.05;
            let p = Protocol::tcp();
            if p.rate_cap(rtt2, 1e12) <= p.rate_cap(rtt1, 1e12) + 1e-9 {
                Ok(())
            } else {
                Err(format!("cap not decreasing in rtt: {rtt1} vs {rtt2}"))
            }
        });
    }

    #[test]
    fn mathis_window_crossover_flips_at_the_wan_threshold() {
        // With the default parameterization the two TCP ceilings both
        // scale as 1/RTT, so which one binds is decided by the loss
        // regime: clean-path loss (5e-7) keeps Mathis far above the
        // receive window; shared-wave loss (5e-4) pulls it far below.
        let m = TcpModel::default();
        let p = Protocol::tcp();
        let bn = 1e12; // never bottleneck-bound in this test
        let rtt_lo = m.wan_rtt_threshold * 0.98;
        let window_lo = m.max_wnd / rtt_lo;
        let mathis_clean = 1.22 * m.mss / (rtt_lo * m.loss.sqrt());
        assert!(window_lo < mathis_clean, "window must bind below the threshold");
        let cap_lo = p.rate_cap(rtt_lo, bn);
        assert!((cap_lo - window_lo).abs() / cap_lo < 1e-9, "cap {cap_lo} window {window_lo}");
        let rtt_hi = m.wan_rtt_threshold * 1.02;
        let mathis_wan = 1.22 * m.mss / (rtt_hi * m.wan_loss.sqrt());
        assert!(mathis_wan < m.max_wnd / rtt_hi, "Mathis must bind above the threshold");
        let cap_hi = p.rate_cap(rtt_hi, bn);
        assert!((cap_hi - mathis_wan).abs() / cap_hi < 1e-9, "cap {cap_hi} mathis {mathis_wan}");
    }

    #[test]
    fn wan_loss_kicks_in_above_rtt_threshold() {
        let m = TcpModel::default();
        let p = Protocol::tcp();
        let below = p.rate_cap(m.wan_rtt_threshold * 0.99, 1e12);
        let above = p.rate_cap(m.wan_rtt_threshold * 1.01, 1e12);
        // ~2% more RTT but ~3.4× less throughput: the loss *regime*
        // moved (window-bound → shared-wave Mathis), not the RTT term,
        // which alone would account for a 2% drop.
        assert!(below / above > 2.5, "below {below} above {above}");
        assert!(below / above < 5.0, "discontinuity larger than the model predicts");
        // Within one regime the cap is RTT-continuous (pure 1/RTT).
        let a = p.rate_cap(0.040, 1e12);
        let b = p.rate_cap(0.041, 1e12);
        assert!((a / b - 0.041 / 0.040).abs() < 1e-9);
    }

    #[test]
    fn udt_cap_is_rtt_insensitive_across_three_decades() {
        let p = Protocol::udt();
        let caps: Vec<f64> =
            [1e-4, 1e-3, 1e-2, 1e-1].iter().map(|&rtt| p.rate_cap(rtt, NIC)).collect();
        let (min, max) = caps.iter().fold((f64::INFINITY, 0.0f64), |(lo, hi), &c| {
            (lo.min(c), hi.max(c))
        });
        // Worst-case droop is the LAN→WAN efficiency step, under 6%.
        assert!((max - min) / max < 0.06, "caps {caps:?}");
        // Contrast: TCP collapses by orders of magnitude over the range.
        let tcp = Protocol::tcp();
        assert!(tcp.rate_cap(1e-4, NIC) / tcp.rate_cap(1e-1, NIC) > 50.0);
    }

    #[test]
    fn zero_byte_control_message_pays_setup_only() {
        let rtt = 0.022;
        let tcp = Protocol::tcp();
        // No payload → no slow-start ramp: exactly the 1.5-RTT handshake.
        assert!((tcp.transfer_overhead(0.0, rtt, NIC) - 1.5 * rtt).abs() < 1e-12);
        let udt = Protocol::udt();
        // UDT: one handshake RTT + the fixed DAIMD ramp allowance.
        assert!((udt.transfer_overhead(0.0, rtt, NIC) - 3.0 * rtt).abs() < 1e-12);
        // And the analytic transfer time adds no bandwidth term.
        assert_eq!(tcp.transfer_time(0.0, rtt, NIC), tcp.transfer_overhead(0.0, rtt, NIC));
        assert_eq!(udt.transfer_time(0.0, rtt, NIC), udt.transfer_overhead(0.0, rtt, NIC));
    }

    #[test]
    fn setup_overhead_orders_gmp_before_tcp() {
        let rtt = 0.022;
        assert!(control_message_latency(rtt, true) < control_message_latency(rtt, false));
        // connectionless saves exactly the handshake + piggyback round.
        let saved = control_message_latency(rtt, false) - control_message_latency(rtt, true);
        assert!((saved - 1.5 * rtt).abs() < 1e-12);
    }

    #[test]
    fn transfer_time_includes_ramp() {
        let p = Protocol::tcp();
        let t_small = p.transfer_time(10e3, 0.022, NIC);
        // A 10 kB transfer is dominated by setup+ramp, not bandwidth.
        assert!(t_small > 1.5 * 0.022);
        let t_big = p.transfer_time(1e9, 0.022, NIC);
        assert!(t_big > 8.0); // ≥ bytes/cap
    }

    #[test]
    fn send_over_fluid_network_completes() {
        let mut topo = Topology::new();
        let a = topo.add_site("a");
        let b = topo.add_site("b");
        let spec = NodeSpec::default();
        topo.add_rack(a, 2, &spec, 1.25e9);
        topo.add_rack(b, 2, &spec, 1.25e9);
        topo.connect_sites(a, b, 1.25e9, 0.058);
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let done_at = std::rc::Rc::new(std::cell::RefCell::new(0.0));
        let d = done_at.clone();
        let src = topo.racks[0].nodes[0];
        let dst = topo.racks[1].nodes[0];
        let bytes = 100e6;
        send(&net, &topo, &mut eng, src, dst, bytes, &Protocol::tcp(), move |e| {
            *d.borrow_mut() = e.now();
        });
        eng.run();
        // TCP on 58 ms is window-limited ≈ 18 MB/s → ≥ 5.5 s for 100 MB.
        let t = *done_at.borrow();
        assert!(t > 5.0, "tcp wan transfer suspiciously fast: {t}");
        // Same transfer over UDT is ~NIC-bound → under 1.1 s.
        let net2 = FlowNet::new(&topo);
        let mut eng2 = Engine::new();
        let d2 = done_at.clone();
        send(&net2, &topo, &mut eng2, src, dst, bytes, &Protocol::udt(), move |e| {
            *d2.borrow_mut() = e.now();
        });
        eng2.run();
        assert!(*done_at.borrow() < 1.5, "udt: {}", done_at.borrow());
    }

    #[test]
    fn disk_flows_share_spindle() {
        let mut topo = Topology::new();
        let s = topo.add_site("s");
        topo.add_rack(s, 1, &NodeSpec { nic_bps: NIC, disk_bps: 65e6, cpu_slots: 4 }, 1.25e9);
        let n0 = topo.racks[0].nodes[0];
        let net = FlowNet::new(&topo);
        let mut eng = Engine::new();
        let done = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        for _ in 0..2 {
            let done = done.clone();
            disk_read(&net, &topo, &mut eng, n0, 65e6, move |e| {
                done.borrow_mut().push(e.now());
            });
        }
        eng.run();
        // Two 65 MB reads on a 65 MB/s spindle → both finish at t=2.
        for &t in done.borrow().iter() {
            assert!((t - 2.0).abs() < 1e-6, "{t}");
        }
    }
}

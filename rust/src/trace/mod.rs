//! # trace — deterministic tracing & self-profiling
//!
//! A zero-dependency structured-tracing subsystem for the simulator
//! itself: which layer a mega-churn second is spent in, why a shard
//! stalls at its lookahead horizon, whether incremental water-filling's
//! recompute scope actually shrank. Three pieces:
//!
//! 1. **Sim-time spans/events.** A per-shard ring-buffered [`Recorder`]
//!    (bounded, drop-oldest with a dropped-count, off by default) lives
//!    on each [`crate::sim::Engine`] and records events keyed
//!    `(sim_time, domain, seq)`. Emission happens **only from
//!    engine-event execution context**, so each shard's stream is a pure
//!    function of its deterministic event order — never of wall-clock
//!    interleaving. Enabled per scenario via
//!    `Scenario::trace(TraceSpec)` or the CLI `--trace` / `oct trace`.
//!
//! 2. **Canonical merge + Chrome export.** Shard streams are absorbed
//!    into a [`Stream`] in shard-index order and stably sorted by
//!    `(time, domain)`; because per-shard streams are identical at any
//!    thread count (the conservative engine executes the same events in
//!    the same order — see [`crate::sim::par`]) and the tie-break within
//!    a `(time, domain)` cell is the per-shard append order, the merged
//!    stream — and its [`Stream::to_chrome_json`] Chrome Trace Format
//!    export — is **byte-identical across `OCT_THREADS=1/N`**. One pid
//!    per site/WAN/control domain, one tid per node/shard lane; the file
//!    loads directly in Perfetto (`ui.perfetto.dev`) or
//!    `chrome://tracing`.
//!
//! 3. **Self-profiler.** Always-on cheap counters ([`ProfileReport`]:
//!    events executed, timers armed/cancelled, cross-shard channel
//!    messages, water-filling components re-filled + dirty links) ride
//!    in every `RunReport` and stay *inside* JSON byte-identity — they
//!    are deterministic by the same argument as the spans. The
//!    scheduler-lane numbers that are **not** deterministic (horizon
//!    stall rounds, wall time per pump stage — both depend on how fast
//!    peer threads happen to run) live in [`SchedProfile`], excluded
//!    from equality and serialization exactly like
//!    `coordinator::runner::WallStats`.
//!
//! ## Span taxonomy
//!
//! | name | kind | domain / lane | emitted by |
//! |------|------|---------------|------------|
//! | `flow` | span | flow's domain / first path link | `net/flows.rs` start → complete |
//! | `flow.retune` | instant | flow's domain / first path link | each deterministic retune (args: rate) |
//! | `link.retune` | instant | link's domain / link | capacity changes (`set_capacities`) |
//! | `dataflow`, `phase.map`, `phase.reduce` | span | control / 0 | `framework/runtime.rs` |
//! | `task` | span | node's site / node | task assignment → completion |
//! | `steal` | instant | node's site / thief node | cross-node slot steals |
//! | `service.request` | span | user's site / replica site | service driver: arrival → response delivered (args: replica, retry) |
//! | `provision.image` | span | control / 0 | imaging admission → all nodes imaged (args: image, bytes) |
//! | `provision.lightpath` | span | WAN / 0 | lightpath request → grant applied (args: gbps) |
//! | `tenant.admit` | instant | control / 0 | slice admission in `run_tenants` (args: tenant) |
//! | `fault.crash`, `fault.nic`, `fault.wave` | instant | subject's domain | fault injection |
//! | `alert.*` | instant | subject's domain | ops-plane detection + remediation; `alert.dead` carries `fault_t`, the injection time of the causing fault span |
//! | `sync.msg` | instant | receiving shard / sending shard | cross-shard delivery (`sim/par.rs`) |
//!
//! RPC request/response spans in [`crate::gmp`] run on real UDP sockets
//! and wall-clock deadlines with no engine anywhere near them, so they
//! *cannot* be part of the deterministic merge; they go to a thread-safe
//! [`WallSpanLog`] instead, explicitly outside byte-identity.

use std::collections::{BTreeSet, VecDeque};
use std::fmt::Write as _;

use crate::util::json::{obj, Json};

/// Tracing configuration carried by a scenario. Off by default — a
/// `Scenario` traces only when it (or the runner override) carries one
/// of these.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceSpec {
    /// Per-shard ring capacity in events. When full, the **oldest**
    /// event drops and the stream's dropped-count rises; the tail of a
    /// run is always retained.
    pub cap: usize,
}

impl TraceSpec {
    /// Default per-shard ring capacity.
    pub const DEFAULT_CAP: usize = 1 << 16;

    pub fn new() -> TraceSpec {
        TraceSpec { cap: Self::DEFAULT_CAP }
    }

    /// A spec with an explicit ring capacity (events per shard).
    pub fn with_cap(cap: usize) -> TraceSpec {
        assert!(cap > 0, "trace ring capacity must be positive");
        TraceSpec { cap }
    }
}

impl Default for TraceSpec {
    fn default() -> Self {
        Self::new()
    }
}

/// Chrome Trace Format phase of one event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ph {
    /// Async span begin (`"b"`).
    B,
    /// Async span end (`"e"`).
    E,
    /// Thread-scoped instant (`"i"`).
    I,
}

/// One typed argument value on an event.
#[derive(Debug, Clone, PartialEq)]
pub enum Arg {
    U(u64),
    F(f64),
    S(String),
}

/// One recorded event. `seq` is the recorder-local emission index — it
/// orders same-`(t, domain)` events within a shard and is never
/// exported.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub t: f64,
    pub domain: u16,
    pub lane: u32,
    pub ph: Ph,
    pub name: &'static str,
    pub id: u64,
    pub args: Vec<(&'static str, Arg)>,
    seq: u64,
}

/// A per-shard bounded event recorder. Lives on the shard's
/// [`crate::sim::Engine`]; instrumentation sites emit through
/// [`crate::sim::Engine::recorder`], so every emission happens inside
/// the engine's deterministic event order.
#[derive(Debug)]
pub struct Recorder {
    cap: usize,
    ring: VecDeque<TraceEvent>,
    dropped: u64,
    seq: u64,
    ids: u64,
}

impl Recorder {
    pub fn new(spec: &TraceSpec) -> Recorder {
        Recorder { cap: spec.cap, ring: VecDeque::new(), dropped: 0, seq: 0, ids: 0 }
    }

    /// A fresh span id, unique within this recorder and deterministic
    /// (a plain counter). Callers that have no natural stable id (e.g. a
    /// dataflow run) draw one here at span begin and reuse it at end.
    pub fn fresh_id(&mut self) -> u64 {
        self.ids += 1;
        self.ids
    }

    fn push(
        &mut self,
        t: f64,
        domain: u16,
        lane: u32,
        ph: Ph,
        name: &'static str,
        id: u64,
        args: &[(&'static str, Arg)],
    ) {
        debug_assert!(t.is_finite() && t >= 0.0, "trace event at invalid time {t}");
        if self.ring.len() == self.cap {
            self.ring.pop_front();
            self.dropped += 1;
        }
        let seq = self.seq;
        self.seq += 1;
        self.ring.push_back(TraceEvent { t, domain, lane, ph, name, id, args: args.to_vec(), seq });
    }

    /// Record an async span begin.
    pub fn begin(
        &mut self,
        t: f64,
        domain: u16,
        lane: u32,
        name: &'static str,
        id: u64,
        args: &[(&'static str, Arg)],
    ) {
        self.push(t, domain, lane, Ph::B, name, id, args);
    }

    /// Record an async span end (matches a [`Recorder::begin`] by
    /// `(name, id)`).
    pub fn end(
        &mut self,
        t: f64,
        domain: u16,
        lane: u32,
        name: &'static str,
        id: u64,
        args: &[(&'static str, Arg)],
    ) {
        self.push(t, domain, lane, Ph::E, name, id, args);
    }

    /// Record a thread-scoped instant.
    pub fn instant(
        &mut self,
        t: f64,
        domain: u16,
        lane: u32,
        name: &'static str,
        id: u64,
        args: &[(&'static str, Arg)],
    ) {
        self.push(t, domain, lane, Ph::I, name, id, args);
    }

    /// Events currently retained in the ring.
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Events dropped to the ring bound so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

/// The merged trace of a run (or a whole scenario set): shard streams
/// absorbed in shard-index order, exported in the canonical
/// `(time, domain)` order. Only this type crosses module boundaries —
/// simlint rule SIM007 keeps raw event types confined to `trace/`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Stream {
    events: Vec<TraceEvent>,
    /// Total events dropped to ring bounds across absorbed recorders.
    pub dropped: u64,
    /// Site count of the topology the events were recorded against —
    /// fixes the pid naming (`site0..siteN-1`, `wan`, `control`).
    pub num_sites: usize,
}

impl Stream {
    pub fn new(num_sites: usize) -> Stream {
        Stream { events: Vec::new(), dropped: 0, num_sites }
    }

    /// The WAN pseudo-domain index for a testbed with `num_sites` sites.
    pub fn wan_domain(num_sites: usize) -> u16 {
        num_sites as u16
    }

    /// The control pseudo-domain (provisioning, tenancy, dataflow
    /// phases — testbed-wide events with no single site).
    pub fn control_domain(num_sites: usize) -> u16 {
        num_sites as u16 + 1
    }

    /// Absorb one shard's recorder (its events are already in the
    /// shard's deterministic emission order). Call in shard-index order.
    pub fn absorb(&mut self, rec: Recorder) {
        self.dropped += rec.dropped;
        self.events.extend(rec.ring);
    }

    /// Append another merged stream (scenario-set concatenation).
    pub fn append(&mut self, mut other: Stream) {
        self.dropped += other.dropped;
        self.num_sites = self.num_sites.max(other.num_sites);
        self.events.append(&mut other.events);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events in canonical order. Sim times are non-negative, so the
    /// IEEE bit pattern of `t` sorts numerically; the sort is stable, so
    /// events equal on `(t, domain)` keep their per-shard emission
    /// order. In sharded runs a domain is owned by exactly one shard,
    /// which makes this a total deterministic order at any thread count.
    fn canonical(&self) -> Vec<&TraceEvent> {
        let mut evs: Vec<&TraceEvent> = self.events.iter().collect();
        evs.sort_by_key(|e| (e.t.to_bits(), e.domain));
        evs
    }

    fn domain_name(&self, d: u16) -> String {
        if (d as usize) < self.num_sites {
            format!("site{d}")
        } else if d == Self::wan_domain(self.num_sites) {
            "wan".to_string()
        } else if d == Self::control_domain(self.num_sites) {
            "control".to_string()
        } else {
            format!("domain{d}")
        }
    }

    /// Export as Chrome Trace Format JSON (the object form, loadable in
    /// Perfetto / `chrome://tracing`): one pid per domain, one tid per
    /// lane, `ts` in microseconds of simulated time. Byte-identical
    /// across thread counts for the same run — `tests/determinism.rs`
    /// asserts exactly that.
    pub fn to_chrome_json(&self) -> String {
        let evs = self.canonical();
        let mut pids: BTreeSet<u16> = BTreeSet::new();
        let mut tids: BTreeSet<(u16, u32)> = BTreeSet::new();
        for e in &evs {
            pids.insert(e.domain);
            tids.insert((e.domain, e.lane));
        }
        let mut out = String::with_capacity(evs.len() * 112 + 1024);
        out.push_str("{\"traceEvents\":[");
        let mut first = true;
        for d in &pids {
            sep(&mut out, &mut first);
            let _ = write!(
                out,
                "{{\"ph\":\"M\",\"pid\":{},\"name\":\"process_name\",\"args\":{{\"name\":{}}}}}",
                *d as u32 + 1,
                esc(&self.domain_name(*d)),
            );
        }
        for (d, l) in &tids {
            sep(&mut out, &mut first);
            let _ = write!(
                out,
                "{{\"ph\":\"M\",\"pid\":{},\"tid\":{},\"name\":\"thread_name\",\"args\":{{\"name\":\"lane{l}\"}}}}",
                *d as u32 + 1,
                l + 1,
            );
        }
        for e in evs {
            sep(&mut out, &mut first);
            out.push_str("{\"ph\":\"");
            out.push_str(match e.ph {
                Ph::B => "b",
                Ph::E => "e",
                Ph::I => "i",
            });
            out.push('"');
            match e.ph {
                Ph::B | Ph::E => {
                    let _ = write!(out, ",\"cat\":\"oct\",\"id\":\"0x{:x}\"", e.id);
                }
                Ph::I => out.push_str(",\"s\":\"t\""),
            }
            let _ = write!(
                out,
                ",\"name\":\"{}\",\"pid\":{},\"tid\":{},\"ts\":{}",
                e.name,
                e.domain as u32 + 1,
                e.lane + 1,
                e.t * 1e6,
            );
            out.push_str(",\"args\":{");
            let mut afirst = true;
            if e.ph == Ph::I && e.id != 0 {
                let _ = write!(out, "\"id\":{}", e.id);
                afirst = false;
            }
            for (k, v) in &e.args {
                if !afirst {
                    out.push(',');
                }
                afirst = false;
                let _ = write!(out, "\"{k}\":");
                match v {
                    Arg::U(u) => {
                        let _ = write!(out, "{u}");
                    }
                    Arg::F(f) => {
                        debug_assert!(f.is_finite(), "non-finite trace arg {k}={f}");
                        let _ = write!(out, "{f}");
                    }
                    Arg::S(s) => out.push_str(&esc(s)),
                }
            }
            out.push_str("}}");
        }
        let _ = write!(
            out,
            "],\"displayTimeUnit\":\"ms\",\"otherData\":{{\"events\":\"{}\",\"dropped\":\"{}\"}}}}",
            self.events.len(),
            self.dropped,
        );
        out
    }
}

fn sep(out: &mut String, first: &mut bool) {
    if !*first {
        out.push(',');
    }
    *first = false;
}

/// JSON-escape a string (quotes included). Event names are `&'static
/// str` literals that never need escaping; this is for dynamic strings
/// (tenant names, domain labels).
fn esc(s: &str) -> String {
    Json::Str(s.to_string()).to_string()
}

// ---------------------------------------------------------------------
// Self-profiler
// ---------------------------------------------------------------------

/// Always-on engine hot-path counters, surfaced in every `RunReport`.
/// Every field except [`ProfileReport::sched`] is a pure function of
/// the deterministic event order, so the counters sit *inside* report
/// byte-identity across thread counts; `sched` is wall-clock-derived
/// and excluded from equality and serialization, exactly like
/// `WallStats`.
#[derive(Debug, Clone, Default)]
pub struct ProfileReport {
    /// Events executed across all engines (shards summed).
    pub events: u64,
    /// Timers armed (`Engine::schedule_at` / `schedule_in`).
    pub timers_armed: u64,
    /// Timers cancelled before firing (`Engine::cancel` hits).
    pub timers_cancelled: u64,
    /// Cross-shard messages scheduled (`Engine::schedule_msg`).
    pub channel_messages: u64,
    /// Water-filling components re-filled (scope of each recompute).
    pub refill_components: u64,
    /// Dirty links visited by incremental water-filling.
    pub dirty_links: u64,
    /// Scheduler-lane profile (sharded runs only) — host-time derived,
    /// outside identity.
    pub sched: Option<SchedProfile>,
}

impl PartialEq for ProfileReport {
    /// `sched` is wall-clock-derived and deliberately excluded — two
    /// runs of the same scenario at different thread counts are equal.
    fn eq(&self, other: &Self) -> bool {
        self.events == other.events
            && self.timers_armed == other.timers_armed
            && self.timers_cancelled == other.timers_cancelled
            && self.channel_messages == other.channel_messages
            && self.refill_components == other.refill_components
            && self.dirty_links == other.dirty_links
    }
}

impl ProfileReport {
    /// Fold another engine's (or shard's) counters into this one.
    pub fn add(&mut self, other: &ProfileReport) {
        self.events += other.events;
        self.timers_armed += other.timers_armed;
        self.timers_cancelled += other.timers_cancelled;
        self.channel_messages += other.channel_messages;
        self.refill_components += other.refill_components;
        self.dirty_links += other.dirty_links;
        if let Some(s) = &other.sched {
            self.sched.get_or_insert_with(SchedProfile::default).add(s);
        }
    }

    /// Deterministic counters only — `sched` never serializes.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("events", Json::Num(self.events as f64)),
            ("timers_armed", Json::Num(self.timers_armed as f64)),
            ("timers_cancelled", Json::Num(self.timers_cancelled as f64)),
            ("channel_messages", Json::Num(self.channel_messages as f64)),
            ("refill_components", Json::Num(self.refill_components as f64)),
            ("dirty_links", Json::Num(self.dirty_links as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> ProfileReport {
        let num = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0) as u64;
        ProfileReport {
            events: num("events"),
            timers_armed: num("timers_armed"),
            timers_cancelled: num("timers_cancelled"),
            channel_messages: num("channel_messages"),
            refill_components: num("refill_components"),
            dirty_links: num("dirty_links"),
            sched: None,
        }
    }
}

/// Host-side scheduler-lane profile of a sharded run, sampled only at
/// shard pump boundaries. Stall counts and stage times depend on how
/// fast peer *threads* happen to run, so none of this is deterministic
/// — it rides along for diagnosis and stays out of identity.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SchedProfile {
    /// Pump rounds executed across all shards.
    pub rounds: u64,
    /// Rounds in which a shard executed no event and received no
    /// message — it was blocked at its lookahead horizon (EIT).
    pub stalled_rounds: u64,
    /// Host seconds draining input channels into engine events.
    pub host_drain_secs: f64,
    /// Host seconds executing events below the safe horizon.
    pub host_run_secs: f64,
    /// Host seconds flushing outboxes and publishing EOT.
    pub host_publish_secs: f64,
}

impl SchedProfile {
    pub fn add(&mut self, other: &SchedProfile) {
        self.rounds += other.rounds;
        self.stalled_rounds += other.stalled_rounds;
        self.host_drain_secs += other.host_drain_secs;
        self.host_run_secs += other.host_run_secs;
        self.host_publish_secs += other.host_publish_secs;
    }

    /// Fraction of pump rounds that made progress inside the lookahead
    /// window (1.0 = shards never waited at the horizon).
    pub fn lookahead_utilization(&self) -> f64 {
        if self.rounds == 0 {
            return 1.0;
        }
        1.0 - self.stalled_rounds as f64 / self.rounds as f64
    }
}

// ---------------------------------------------------------------------
// Wall-domain spans (gmp RPC)
// ---------------------------------------------------------------------

/// One wall-clock span from the real-UDP RPC layer. Offsets are
/// microseconds since the log's creation.
#[derive(Debug, Clone, PartialEq)]
pub struct WallSpan {
    pub name: String,
    pub start_us: u64,
    pub dur_us: u64,
    pub ok: bool,
}

/// A thread-safe span log for layers that run on real wall time —
/// [`crate::gmp`]'s UDP endpoint and RPC threads have no engine and no
/// simulated clock, so their request/response spans **cannot** join the
/// deterministic merge; they are collected here and documented as
/// outside byte-identity.
#[derive(Clone)]
pub struct WallSpanLog {
    inner: std::sync::Arc<std::sync::Mutex<Vec<WallSpan>>>,
    t0: std::time::Instant,
}

impl WallSpanLog {
    pub fn new() -> WallSpanLog {
        WallSpanLog {
            inner: std::sync::Arc::new(std::sync::Mutex::new(Vec::new())),
            // simlint: allow(SIM002) — wall-domain RPC spans measure real UDP round-trips, outside simulated time
            t0: std::time::Instant::now(),
        }
    }

    /// Record a span that started at `started` (a caller-side
    /// `Instant::now()` taken before the RPC) and just finished.
    pub fn record(&self, name: &str, started: std::time::Instant, ok: bool) {
        let start_us = started.duration_since(self.t0).as_micros() as u64;
        // simlint: allow(SIM002) — wall-domain RPC spans measure real UDP round-trips, outside simulated time
        let dur_us = started.elapsed().as_micros() as u64;
        self.inner.lock().unwrap().push(WallSpan { name: name.to_string(), start_us, dur_us, ok });
    }

    /// Snapshot of all spans recorded so far, in completion order.
    pub fn snapshot(&self) -> Vec<WallSpan> {
        self.inner.lock().unwrap().clone()
    }
}

impl Default for WallSpanLog {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(cap: usize) -> Recorder {
        Recorder::new(&TraceSpec::with_cap(cap))
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let mut r = rec(3);
        for i in 0..5u64 {
            r.instant(i as f64, 0, 0, "e", i, &[]);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let mut s = Stream::new(1);
        s.absorb(r);
        assert_eq!(s.dropped, 2);
        // The tail survived: ids 2, 3, 4.
        let ids: Vec<u64> = s.canonical().iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![2, 3, 4]);
    }

    #[test]
    fn canonical_order_is_time_then_domain_then_emission() {
        // Two "shards": domain 1 and domain 0, absorbed in shard order.
        let mut a = rec(16);
        a.begin(1.0, 1, 0, "x", 1, &[]);
        a.end(2.0, 1, 0, "x", 1, &[]);
        let mut b = rec(16);
        b.instant(1.0, 0, 0, "y", 1, &[]);
        b.instant(1.0, 0, 0, "z", 2, &[]);
        let mut s = Stream::new(2);
        s.absorb(a);
        s.absorb(b);
        let names: Vec<&str> = s.canonical().iter().map(|e| e.name).collect();
        // t=1: domain 0 first (y before z by emission order), then
        // domain 1; t=2 last.
        assert_eq!(names, vec!["y", "z", "x", "x"]);
    }

    #[test]
    fn chrome_export_is_valid_json_with_domain_pids() {
        let mut r = rec(16);
        r.begin(0.5, 0, 3, "flow", 7, &[("bytes", Arg::F(1e6)), ("src", Arg::U(3))]);
        r.instant(0.75, 2, 0, "tenant.admit", 1, &[("tenant", Arg::S("a\"b".into()))]);
        r.end(1.5, 0, 3, "flow", 7, &[]);
        let mut s = Stream::new(1);
        s.absorb(r);
        let js = s.to_chrome_json();
        let parsed = Json::parse(&js).expect("chrome trace must parse");
        let evs = match parsed.get("traceEvents") {
            Some(Json::Arr(v)) => v,
            other => panic!("traceEvents missing: {other:?}"),
        };
        // 2 process_name + 2 thread_name metadata + 3 events.
        assert_eq!(evs.len(), 7);
        let meta: Vec<String> = evs
            .iter()
            .filter(|e| e.get("name") == Some(&Json::Str("process_name".into())))
            .map(|e| match e.get("args").and_then(|a| a.get("name")) {
                Some(Json::Str(s)) => s.clone(),
                _ => panic!("unnamed process"),
            })
            .collect();
        // Domain 0 is site0; domain 2 == control for a 1-site testbed.
        assert_eq!(meta, vec!["site0".to_string(), "control".to_string()]);
        // ts is in microseconds of sim time.
        let flow = evs.iter().find(|e| e.get("ph") == Some(&Json::Str("b".into()))).unwrap();
        assert_eq!(flow.get("ts"), Some(&Json::Num(500000.0)));
    }

    #[test]
    fn export_is_independent_of_absorb_interleaving_given_fixed_shard_order() {
        // The same two per-shard streams always merge to the same bytes.
        let build = || {
            let mut a = rec(8);
            a.instant(1.0, 0, 0, "a1", 1, &[]);
            a.instant(3.0, 0, 0, "a2", 2, &[]);
            let mut b = rec(8);
            b.instant(1.0, 1, 0, "b1", 1, &[]);
            b.instant(2.0, 1, 0, "b2", 2, &[]);
            let mut s = Stream::new(2);
            s.absorb(a);
            s.absorb(b);
            s.to_chrome_json()
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn profile_report_identity_excludes_sched() {
        let mut a = ProfileReport { events: 10, timers_armed: 4, ..Default::default() };
        let b = ProfileReport {
            events: 10,
            timers_armed: 4,
            sched: Some(SchedProfile { rounds: 99, stalled_rounds: 3, ..Default::default() }),
            ..Default::default()
        };
        assert_eq!(a, b);
        let j = b.to_json().to_string();
        assert!(!j.contains("rounds"), "sched leaked into serialization: {j}");
        let back = ProfileReport::from_json(&Json::parse(&j).unwrap());
        assert_eq!(back, b);
        // add() sums counters and merges sched.
        a.add(&b);
        assert_eq!(a.events, 20);
        assert_eq!(a.sched.as_ref().unwrap().rounds, 99);
        let util = b.sched.as_ref().unwrap().lookahead_utilization();
        assert!((util - (1.0 - 3.0 / 99.0)).abs() < 1e-12);
    }

    #[test]
    fn wall_span_log_records_outside_sim_time() {
        let log = WallSpanLog::new();
        // simlint: allow(SIM002) — exercising the wall-domain span API itself
        let t = std::time::Instant::now();
        log.record("echo", t, true);
        let spans = log.snapshot();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].name, "echo");
        assert!(spans[0].ok);
    }
}
